"""Graph topologies and mixing matrices for decentralized optimization.

Implements the mixing-matrix requirements of the paper (Section 4):
  (i)   graph sparsity:  w_{m,l} = 0 unless (m,l) in E or m == l
  (ii)  symmetry:        W = W^T
  (iii) null-space:      null(I - W) = span{1_N}
  (iv)  spectral:        0 <= W <= I

The paper uses the Laplacian-based constant edge weight matrix
W = I - L/tau with tau >= lambda_max(L)/2 (Section 7). We also provide
Metropolis-Hastings weights and standard pod topologies (ring, torus,
Erdos-Renyi) for the pod-axis runtime.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected connected graph over nodes 0..n-1."""

    n: int
    edges: tuple[tuple[int, int], ...]  # (i, j) with i < j, no self loops

    def __post_init__(self):
        """Validate edge endpoints against the node range."""
        for i, j in self.edges:
            if not (0 <= i < j < self.n):
                raise ValueError(f"bad edge ({i},{j}) for n={self.n}")

    @property
    def adjacency(self) -> np.ndarray:
        """(n, n) symmetric 0/1 adjacency matrix."""
        a = np.zeros((self.n, self.n), dtype=np.float64)
        for i, j in self.edges:
            a[i, j] = a[j, i] = 1.0
        return a

    @property
    def laplacian(self) -> np.ndarray:
        """Graph Laplacian L = D - A."""
        a = self.adjacency
        return np.diag(a.sum(1)) - a

    @property
    def degrees(self) -> np.ndarray:
        """(n,) per-node degree vector."""
        return self.adjacency.sum(1).astype(np.int64)

    @property
    def max_degree(self) -> int:
        """Delta(G), the paper's dense per-iteration communication factor."""
        return int(self.degrees.max())

    def neighbors(self, n: int) -> list[int]:
        """Nodes adjacent to `n` (unsorted)."""
        return [j for i, j in self.edges if i == n] + [
            i for i, j in self.edges if j == n
        ]

    def is_connected(self) -> bool:
        """BFS reachability of every node from node 0."""
        seen = {0}
        frontier = [0]
        adj = {i: self.neighbors(i) for i in range(self.n)}
        while frontier:
            v = frontier.pop()
            for u in adj[v]:
                if u not in seen:
                    seen.add(u)
                    frontier.append(u)
        return len(seen) == self.n

    def distances_from(self, src: int) -> np.ndarray:
        """BFS topological distances xi_i (eq. 33)."""
        dist = np.full(self.n, -1, dtype=np.int64)
        dist[src] = 0
        frontier = [src]
        adj = {i: self.neighbors(i) for i in range(self.n)}
        while frontier:
            nxt = []
            for v in frontier:
                for u in adj[v]:
                    if dist[u] < 0:
                        dist[u] = dist[v] + 1
                        nxt.append(u)
            frontier = nxt
        return dist

    @property
    def diameter(self) -> int:
        """max_{u,v} xi(u, v) — the relay protocol's warm-up horizon."""
        return int(max(self.distances_from(s).max() for s in range(self.n)))

    def subgraph(self, keep: "list[int] | tuple[int, ...]") -> "Graph":
        """Induced subgraph on `keep` (renumbered 0..len(keep)-1, in order).

        The churn path uses this for survivor graphs; the result may be
        disconnected — callers that need a connected mixing graph should
        check ``is_connected()`` (e.g. before ``laplacian_mixing``).
        """
        keep = list(keep)
        if len(set(keep)) != len(keep):
            raise ValueError("subgraph keep-list has duplicates")
        remap = {old: new for new, old in enumerate(keep)}
        edges = tuple(
            (min(remap[i], remap[j]), max(remap[i], remap[j]))
            for i, j in self.edges
            if i in remap and j in remap
        )
        return Graph(len(keep), edges)


def ring_graph(n: int) -> Graph:
    """Cycle over n nodes (diameter n//2 — the deepest standard relay)."""
    if n < 2:
        raise ValueError("ring needs n >= 2")
    if n == 2:
        return Graph(2, ((0, 1),))
    edges = tuple(sorted((i, (i + 1) % n)) for i in range(n))
    return Graph(n, tuple((min(a, b), max(a, b)) for a, b in edges))


def complete_graph(n: int) -> Graph:
    """All-to-all graph (diameter 1)."""
    return Graph(n, tuple((i, j) for i in range(n) for j in range(i + 1, n)))


def torus_graph(rows: int, cols: int) -> Graph:
    """2-D torus; matches ICI wiring of TPU pod slices."""
    n = rows * cols
    edges = set()

    def nid(r, c):
        return (r % rows) * cols + (c % cols)

    for r in range(rows):
        for c in range(cols):
            for rr, cc in ((r + 1, c), (r, c + 1)):
                a, b = nid(r, c), nid(rr, cc)
                if a != b:
                    edges.add((min(a, b), max(a, b)))
    return Graph(n, tuple(sorted(edges)))


def erdos_renyi_graph(n: int, p: float, seed: int = 0) -> Graph:
    """Random G(n, p); resamples until connected (paper: N=10, p=0.4)."""
    rng = np.random.default_rng(seed)
    for _ in range(1000):
        edges = tuple(
            (i, j)
            for i in range(n)
            for j in range(i + 1, n)
            if rng.random() < p
        )
        g = Graph(n, edges)
        if g.is_connected():
            return g
    raise RuntimeError("failed to sample a connected graph")


def exponential_graph(n: int) -> Graph:
    """Hypercube-like exponential graph: i ~ i +/- 2^k (mod n).

    O(log n) degree with O(log n) diameter -- the standard choice for
    large decentralized deployments (1000+ nodes).
    """
    edges = set()
    k = 1
    while k < n:
        for i in range(n):
            j = (i + k) % n
            if i != j:
                edges.add((min(i, j), max(i, j)))
        k *= 2
    return Graph(n, tuple(sorted(edges)))


def laplacian_mixing(graph: Graph, scale: float | None = None) -> np.ndarray:
    """Paper Section 7: W = I - L/tau, tau >= lambda_max(L)/2.

    Default tau = lambda_max(L)/2 * (1 + 1e-9) -- but note tau must also keep
    W >= 0 spectrally; lambda_max/2 gives eigenvalues in [-1, 1]*... actually
    eig(W) = 1 - eig(L)/tau in [1 - lmax/tau, 1] = [-1, 1] at tau = lmax/2.
    Condition (iv) requires 0 <= W, so we default tau = lambda_max(L) which
    gives eig(W) in [0, 1], and expose `scale` for the paper's tau.
    """
    lap = graph.laplacian
    lmax = float(np.linalg.eigvalsh(lap).max())
    tau = scale if scale is not None else lmax
    if tau < lmax / 2:
        raise ValueError(f"tau={tau} < lambda_max/2={lmax / 2}")
    return np.eye(graph.n) - lap / tau


def metropolis_mixing(graph: Graph) -> np.ndarray:
    """Metropolis-Hastings weights: w_ij = 1/(1+max(d_i,d_j)); doubly stochastic."""
    deg = graph.degrees
    w = np.zeros((graph.n, graph.n))
    for i, j in graph.edges:
        w[i, j] = w[j, i] = 1.0 / (1.0 + max(deg[i], deg[j]))
    np.fill_diagonal(w, 1.0 - w.sum(1))
    return w


def validate_mixing(w: np.ndarray, graph: Graph, atol: float = 1e-10) -> None:
    """Assert conditions (i)-(iv) of Section 4."""
    n = graph.n
    adj = graph.adjacency + np.eye(n)
    if np.any((np.abs(w) > atol) & (adj == 0)):
        raise AssertionError("graph sparsity violated")
    if not np.allclose(w, w.T, atol=atol):
        raise AssertionError("symmetry violated")
    eigvals, eigvecs = np.linalg.eigh(w)
    # null(I - W) = span{1}: exactly one eigenvalue == 1, eigenvector ~ 1/sqrt(n)
    ones = np.isclose(eigvals, 1.0, atol=1e-8)
    if ones.sum() != 1:
        raise AssertionError(f"null-space property violated: {eigvals}")
    v = eigvecs[:, np.argmax(eigvals)]
    if not np.allclose(np.abs(v), 1.0 / np.sqrt(n), atol=1e-6):
        raise AssertionError("leading eigenvector is not the consensus vector")
    if eigvals.min() < -atol or eigvals.max() > 1 + 1e-8:
        raise AssertionError(f"spectral property violated: {eigvals}")


def spectral_gap(w: np.ndarray) -> float:
    """1 - |lambda_2(W)|: positive iff the mixing matrix contracts consensus.

    lambda_2 is the second-largest eigenvalue *in magnitude* (the largest is
    the consensus eigenvalue 1). Per-segment gaps of a graph schedule are
    recorded in ``SolveResult.extras["schedule"]`` — each segment's geometric
    consensus rate is governed by its own gap.
    """
    eigvals = np.sort(np.abs(np.linalg.eigvalsh(np.asarray(w, np.float64))))
    if eigvals.size == 1:
        return 1.0
    return float(1.0 - eigvals[-2])


def graph_gamma(w: np.ndarray) -> float:
    """gamma = smallest *nonzero* singular value of U^2 = W_tilde - W = (I-W)/2.

    The paper's graph condition number is kappa_g = 1/gamma (Theorem 6.1).
    """
    m = (np.eye(w.shape[0]) - w) / 2.0
    s = np.linalg.svd(m, compute_uv=False)
    nz = s[s > 1e-12]
    return float(nz.min())


def graph_condition_number(w: np.ndarray) -> float:
    """kappa_g = 1/gamma (Theorem 6.1's graph condition number)."""
    return 1.0 / graph_gamma(w)


def w_tilde(w: np.ndarray) -> np.ndarray:
    """W_tilde = (W + I)/2 (eq. 24)."""
    return (w + np.eye(w.shape[0])) / 2.0


def make_pod_mixing(
    n_pods: int, topology: str = "ring", seed: int = 0
) -> tuple[Graph, np.ndarray]:
    """Graph + Laplacian mixing matrix for the pod axis of a TPU mesh."""
    if topology == "ring":
        g = ring_graph(n_pods) if n_pods > 1 else Graph(1, ())
    elif topology == "complete":
        g = complete_graph(n_pods)
    elif topology == "exponential":
        g = exponential_graph(n_pods)
    elif topology == "erdos_renyi":
        g = erdos_renyi_graph(n_pods, 0.4, seed)
    else:
        raise ValueError(f"unknown topology {topology!r}")
    if n_pods == 1:
        return g, np.ones((1, 1))
    return g, laplacian_mixing(g)

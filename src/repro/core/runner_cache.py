"""Keyed caches of compiled solver runners — the sweep-engine backbone.

Every experiment in this repo is sweep-shaped: many ``solve()`` calls over a
(lam, alpha, method, seed) grid on one problem shape. Before this module
each call baked fresh step closures and re-traced/re-compiled its jitted
scan (~1-2 s on CPU), so benchmark wall time was XLA compilation, not the
solver. Now ``core.solvers`` (dense chunked scan) and ``core.sparse_comm``
(the relay scan) compile ONE runner per cache key and pass hyperparameter
*values* as traced arguments, so every later call on the same problem shape
hits a warm executable.

Keying rules (see docs/solvers.md for the authored contract):

* The *caller* builds the key: method name, comm backend, operator family,
  data-array shapes/dtypes, graph edges, a mixing-matrix content
  fingerprint, and the *static* hyperparameter structure. Hyperparameter
  values never enter the key — they are traced runner arguments.
* Object-identity components (the dataset) are keyed by ``id()`` with a
  strong reference held in the entry ("guard"), so a recycled ``id`` can
  never alias a live key: if the id matches, it *is* the same object.
  Corollary: datasets are treated as immutable — mutating a dataset's
  arrays IN PLACE keeps its id and silently replays the runner baked from
  the pre-edit data (build a new dataset object, or ``clear()``).
* Entries are LRU-bounded (default 32) so long-lived processes sweeping
  many distinct problems do not accumulate unbounded device constants.

Stats are per-cache and process-global. ``traces`` is incremented from
*inside* the traced function (via ``note_trace``) — i.e. it counts actual
XLA (re)traces, not calls — so tests can assert "second call, new
hyperparameter values, zero new traces" directly
(tests/test_runner_cache.py).
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Any, Callable

import numpy as np


@dataclasses.dataclass
class _Entry:
    """One cached runner: the built value plus its identity guards."""

    guards: tuple
    value: Any


class RunnerCache:
    """A bounded, stats-tracking LRU mapping of runner keys to built runners."""

    def __init__(self, name: str, capacity: int = 32):
        """Create an empty cache. ``name`` labels it in aggregated stats."""
        self.name = name
        self.capacity = capacity
        self._entries: OrderedDict[tuple, _Entry] = OrderedDict()
        self._stats = {"hits": 0, "misses": 0, "traces": 0, "evictions": 0}

    def get_or_build(
        self, key: tuple, guards: tuple, build: Callable[[], Any]
    ) -> Any:
        """Return the cached value for ``key`` or build, insert, and return it.

        ``guards`` are the objects whose ``id()`` participates in ``key``;
        the entry holds them strongly so the ids stay valid for its
        lifetime. A hit requires every guard to be the *same object* as at
        insert time (belt and braces on top of the id keying).
        """
        entry = self._entries.get(key)
        if entry is not None and all(
            a is b for a, b in zip(entry.guards, guards)
        ):
            self._stats["hits"] += 1
            self._entries.move_to_end(key)
            return entry.value
        self._stats["misses"] += 1
        value = build()
        self._entries[key] = _Entry(guards=tuple(guards), value=value)
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self._stats["evictions"] += 1
        return value

    def note_trace(self) -> None:
        """Record one XLA trace. Call from INSIDE the to-be-jitted function:
        the Python body runs only while tracing, so this counts compiles,
        not calls."""
        self._stats["traces"] += 1

    def stats(self) -> dict[str, int]:
        """Copy of {hits, misses, traces, evictions, size}."""
        return dict(self._stats, size=len(self._entries))

    def clear(self) -> None:
        """Drop every entry and zero the stats (tests and benchmarks)."""
        self._entries.clear()
        for k in self._stats:
            self._stats[k] = 0


# The process-global caches: the dense chunked-scan runners of
# core.solvers.solve / solve_many, the sparse relay scans of
# core.sparse_comm, and the shard_map runners of the sharded backend.
# Module-level so stats survive across solve() calls. Separate caches per
# backend (plus ``mesh_fingerprint`` in the sharded keys) guarantee a
# cached runner never crosses comm backends or device meshes.
DENSE = RunnerCache("dense")
SPARSE = RunnerCache("sparse")
SHARDED = RunnerCache("sharded")


def problem_fingerprint(data, operator_spec, graph, w) -> tuple:
    """The shared problem-shape component of a runner key.

    One definition for both caches (the dense runners in ``core.solvers``
    and the relay scans in ``core.sparse_comm``), so the keying schema
    cannot drift between them: dataset identity (guard the object!),
    padded-CSR shapes/dtype, operator family, graph edges, and a mixing-
    matrix content fingerprint.
    """
    return (
        id(data),
        (data.n_nodes, data.q, data.k, data.d,
         str(np.asarray(data.val).dtype)),
        operator_spec,
        (graph.n, tuple(graph.edges)),
        array_fingerprint(w),
    )


def array_fingerprint(a) -> tuple:
    """Content key for a small array (the mixing matrix): shape, dtype, hash.

    Problems rebuilt per sweep point (bench_table1 makes one per ``lam``)
    carry *equal* but not *identical* W arrays; fingerprinting by content
    lets them share one compiled runner.
    """
    a = np.ascontiguousarray(a)
    return (
        a.shape,
        str(a.dtype),
        hashlib.blake2b(a.tobytes(), digest_size=16).hexdigest(),
    )


def fault_fingerprint(
    has_link: bool, has_straggler: bool, n_slots: int = 0
) -> tuple:
    """The fault-structure component of a runner key.

    Only the STRUCTURE of the injected faults enters the key — which
    families are active and how many straggler buffer slots the step
    threads through its carry. The per-step masks are runtime scan
    inputs, so one compiled fault runner serves every drop rate / seed,
    exactly like hyperparameter values. A fault-free runner has no
    ``("faults", ...)`` component at all, so it can never collide with a
    faulty one.
    """
    return ("faults", bool(has_link), bool(has_straggler), int(n_slots))


def mesh_fingerprint(mesh) -> tuple:
    """Content key for a device mesh: axis names/sizes + device ids.

    Part of every sharded runner key: two meshes with the same axes but
    different device assignments (or sizes) must compile distinct
    ``shard_map`` programs, and a dense runner (no mesh) can never collide
    with a sharded one (separate cache AND incompatible key schema).
    """
    return (
        tuple(mesh.axis_names),
        tuple(mesh.devices.shape),
        tuple(int(d.id) for d in mesh.devices.flat),
    )


def stats() -> dict[str, dict[str, int]]:
    """{cache name: stats} for every runner cache in the process."""
    return {c.name: c.stats() for c in (DENSE, SPARSE, SHARDED)}


def clear() -> None:
    """Reset every runner cache (cold-start benchmarks, test isolation)."""
    DENSE.clear()
    SPARSE.clear()
    SHARDED.clear()

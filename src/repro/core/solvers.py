"""One solver API: ``Problem`` + ``SolverSpec`` registry + ``solve()``.

The paper recasts decentralized learning as monotone-operator root finding;
this module makes that the *interface*: a ``Problem`` bundles the operator
family, the node-local data, the communication graph, the mixing matrix and
the ``z*`` oracle, while a ``SolverSpec`` registry (mirroring the
``KernelSpec`` registry in ``kernels/ops.py``) makes the *method*
(``dsba``/``dsa`` per Algorithm 1 and Remark 5.1, ``extra``/``dlm``/``ssda``
per the deterministic baselines of Table 1) and the *communication backend*
(``dense`` neighbor exchange vs. the paper's sparse delta relay of Section
5.1) two orthogonal axes of a single call::

    problem = make_problem("ridge", data, graph)
    problem.solve_star()                      # cache the centralized root
    res = solve(problem, method="dsba", comm="sparse", steps=4000)

``solve`` is the only non-deprecated run entrypoint. ``core.dsba.run`` and
``core.baselines.run_extra/run_dlm/run_ssda`` are thin deprecated shims
delegating here, pinned trace-identical by ``tests/test_solvers.py``.

Every run returns the same ``SolveResult`` schema, including cumulative
communicated DOUBLEs/ints per node: measured by the relay's closed-form
accounting when ``comm="sparse"``, and from the ``deg(n) * D`` dense-exchange
model otherwise — so sparse-vs-dense communication cost is comparable in one
result type. Authoring contract and backend-resolution rules are documented
in docs/solvers.md.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import reference
from repro.core.dsba import (
    DSBAConfig,
    draw_indices,
    init_state as _dsba_init_state,
    make_step_fn as _dsba_make_step_fn,
)
from repro.core.mixing import Graph, laplacian_mixing, w_tilde
from repro.core.operators import OperatorSpec
from repro.core import sparse_comm as _sparse_comm
from repro.core.sparse_comm import dense_doubles_per_iter

COMM_BACKENDS = ("dense", "sparse")


# ---------------------------------------------------------------------------
# Problem: everything a solver needs, bundled once
# ---------------------------------------------------------------------------


def graph_from_mixing(w: np.ndarray, atol: float = 1e-12) -> Graph:
    """Recover the communication ``Graph`` from a mixing matrix's support.

    Section 4's sparsity condition makes W and the graph carry the same
    information (``w[m,l] != 0`` iff ``(m,l)`` is an edge or ``m == l``), so
    legacy callers that only pass W still get full communication accounting.
    """
    w = np.asarray(w)
    n = w.shape[0]
    edges = tuple(
        (i, j)
        for i in range(n)
        for j in range(i + 1, n)
        if abs(w[i, j]) > atol
    )
    return Graph(n, edges)


@dataclasses.dataclass
class Problem:
    """A decentralized root-finding problem instance.

    Bundles the operator family (``spec``), the per-node data (padded-CSR
    ``SparseDataset``), the communication ``graph``, the mixing matrix ``w``
    (defaults to the paper's Laplacian weights on ``graph``), the l2
    regularizer ``lam`` (part of the *problem*, not the solver), and an
    optional cached centralized root ``z_star``.
    """

    spec: OperatorSpec
    data: Any  # repro.data.synthetic.SparseDataset (duck-typed)
    graph: Graph
    w: np.ndarray | None = None
    lam: float = 0.0
    z_star: np.ndarray | None = None

    def __post_init__(self):
        """Default ``w`` to Laplacian mixing and sanity-check shapes."""
        if self.w is None:
            self.w = laplacian_mixing(self.graph)
        self.w = np.asarray(self.w)
        if self.w.shape != (self.graph.n, self.graph.n):
            raise ValueError(
                f"mixing matrix {self.w.shape} != graph size {self.graph.n}"
            )
        if self.data.n_nodes != self.graph.n:
            raise ValueError(
                f"data has {self.data.n_nodes} nodes, graph {self.graph.n}"
            )

    @property
    def dim(self) -> int:
        """Total iterate dimension D = d + tail_dim."""
        return self.data.d + self.spec.tail_dim

    def solve_star(self, **kwargs) -> np.ndarray:
        """Compute (once) and cache the centralized root ``z*``.

        Delegates to ``reference.solve_root``; extra kwargs (``iters``,
        ``tol``) pass through. Idempotent: repeated calls return the cache.
        """
        if self.z_star is None:
            self.z_star = reference.solve_root(
                self.spec, self.data, self.lam, **kwargs
            )
        return self.z_star


def make_problem(
    task: str,
    data,
    graph: Graph,
    w: np.ndarray | None = None,
    lam: float | None = None,
) -> Problem:
    """Build a ``Problem`` from a task name with the paper's conventions.

    task: ``"ridge" | "logistic" | "auc"`` (AUC reads the positive-class
    ratio from the data). ``lam`` defaults to the paper's 1/(10 Q).
    """
    if task == "auc":
        spec = OperatorSpec("auc", p=data.positive_ratio())
    elif task in ("ridge", "logistic"):
        spec = OperatorSpec(task)
    else:
        raise ValueError(f"unknown task {task!r}")
    if lam is None:
        lam = 1.0 / (10.0 * data.total)
    return Problem(spec=spec, data=data, graph=graph, w=w, lam=lam)


# ---------------------------------------------------------------------------
# SolverSpec registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SolverSpec:
    """One solver's contract with ``solve()`` (see docs/solvers.md).

    ``init``/``step``/``z_of`` are *factories* over ``(problem, hp)`` so each
    entry can bake data, mixing matrices and hyperparameters into device
    arrays exactly once per run:

    - ``init(problem, hp, z0) -> state``: initial state pytree from a (N, D)
      starting point (scan-compatible: every leaf is a jax array).
    - ``step(problem, hp) -> fn(state, i_t) -> state``: the per-iteration
      transition, safe to call inside jit/lax.scan. ``i_t`` is the (N,)
      sample draw of this iteration; deterministic solvers ignore it.
    - ``z_of(problem, hp) -> fn(state) -> (N, D)``: iterate read-out (SSDA's
      primal read-out is a real computation, hence a factory too).
    - ``defaults``: the solver's hyperparameters with default values; the
      keys are also the *schema* — ``solve()`` rejects unknown overrides.
    - ``sparse_run``: optional sparse-communication backend with signature
      ``(problem, hp, steps, indices, z0, options) -> SparseRunResult``.
      ``None`` means the method has no sparse protocol (the deterministic
      baselines exchange dense vectors by construction).
    """

    name: str
    init: Callable[[Problem, Mapping[str, float], jax.Array], Any]
    step: Callable[[Problem, Mapping[str, float]], Callable]
    z_of: Callable[[Problem, Mapping[str, float]], Callable]
    defaults: Mapping[str, float]
    sparse_run: Callable | None = None

    def supports_sparse_comm(self) -> bool:
        """Whether this method has a sparse-communication backend."""
        return self.sparse_run is not None


_REGISTRY: dict[str, SolverSpec] = {}


def register_solver(spec: SolverSpec) -> SolverSpec:
    """Add a ``SolverSpec`` to the registry (name must be unused)."""
    if spec.name in _REGISTRY:
        raise ValueError(f"solver {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get_solver(name: str) -> SolverSpec:
    """Look up a registered solver by name."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown method {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def available_solvers() -> dict[str, bool]:
    """{name: supports_sparse_comm} for every registered solver."""
    return {
        name: spec.supports_sparse_comm()
        for name, spec in sorted(_REGISTRY.items())
    }


# ---------------------------------------------------------------------------
# SolveResult + the shared metrics recorder
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SolveResult:
    """Uniform result of ``solve()`` for every method x comm backend.

    Record-point arrays all share the leading axis R = len(iters):
    ``dist2`` is empty when the problem has no cached ``z_star``;
    ``doubles_received``/``ints_received`` are *cumulative* per-node message
    counts at each record point (closed-form relay accounting for
    ``comm="sparse"``, the ``deg(n) * D`` dense-exchange model otherwise —
    index ints are zero for dense, the values travel as one dense block).
    ``state`` is the solver's final state pytree (``None`` for sparse runs:
    the relay engine returns trajectories, not solver internals);
    ``extras`` carries backend-specific outputs (sparse: ``z_trace``,
    ``recon_max_err``).
    """

    method: str
    comm: str
    iters: np.ndarray  # (R,) iteration counts at record points
    dist2: np.ndarray  # (R,) mean_n ||z_n - z*||^2 (empty without z_star)
    consensus: np.ndarray  # (R,) mean_n ||z_n - zbar||^2
    doubles_received: np.ndarray  # (R, N) cumulative DOUBLEs per node
    ints_received: np.ndarray  # (R, N) cumulative index ints per node
    wall_time: float  # seconds in the solver (setup + scan + metrics)
    z: np.ndarray  # (N, D) final iterates
    state: Any  # final solver state pytree (None for sparse runs)
    zs: np.ndarray | None = None  # (R, N, D) snapshots if requested
    extras: dict = dataclasses.field(default_factory=dict)


def _record_points(steps: int, record_every: int) -> list[int]:
    """Iteration counts to record at: every ``record_every``, plus the end."""
    pts = list(range(record_every, steps + 1, record_every))
    if not pts or pts[-1] != steps:
        pts.append(steps)
    return pts


class _Recorder:
    """The one metrics recorder shared by every method and comm backend.

    Replaces the per-method metric loops the legacy entrypoints each
    reimplemented (``core.dsba.run``'s chunked loop, ``baselines``'
    ``_metrics_loop``): push (iteration, iterates) pairs, read back the
    uniform record arrays.
    """

    def __init__(self, z_star: np.ndarray | None, keep_snapshots: bool):
        self.z_star = None if z_star is None else np.asarray(z_star)
        self.iters: list[int] = []
        self.dist2: list[float] = []
        self.consensus: list[float] = []
        self.zs: list[np.ndarray] | None = [] if keep_snapshots else None

    def push(self, it: int, z) -> None:
        """Record consensus / distance-to-z* of iterates ``z`` at step ``it``."""
        z = np.asarray(z)
        zbar = z.mean(0, keepdims=True)
        self.iters.append(it)
        self.consensus.append(float(np.mean(np.sum((z - zbar) ** 2, -1))))
        if self.z_star is not None:
            self.dist2.append(
                float(np.mean(np.sum((z - self.z_star[None]) ** 2, -1)))
            )
        if self.zs is not None:
            self.zs.append(z)

    def arrays(self) -> tuple[np.ndarray, np.ndarray, np.ndarray, Any]:
        """(iters, dist2, consensus, zs) as numpy arrays."""
        return (
            np.asarray(self.iters),
            np.asarray(self.dist2) if self.dist2 else np.zeros(0),
            np.asarray(self.consensus),
            np.stack(self.zs) if self.zs else None,
        )


# ---------------------------------------------------------------------------
# solve(): the single entrypoint
# ---------------------------------------------------------------------------


def solve(
    problem: Problem,
    method: str = "dsba",
    comm: str = "dense",
    *,
    steps: int,
    record_every: int = 50,
    seed: int = 0,
    z0: np.ndarray | None = None,
    indices: np.ndarray | None = None,
    keep_snapshots: bool = False,
    comm_options: dict | None = None,
    **hyperparams,
) -> SolveResult:
    """Run ``method`` on ``problem`` over ``comm`` and return a SolveResult.

    method: a registered solver name (``available_solvers()`` lists them).
    comm: ``"dense"`` (true neighbor exchange, the mixing matmul) or
        ``"sparse"`` (the paper's delta relay — methods with a sparse
        backend only; see ``SolverSpec.supports_sparse_comm``).
    steps / record_every: iterations to run / metric recording period (the
        final iteration is always recorded).
    seed: RNG seed for the per-node sample draws when ``indices`` is not
        given; ``indices`` is an explicit (steps, N) stream for replayable
        runs (shared across methods and comm backends).
    z0: (N, D) starting point, default zeros.
    comm_options: backend passthrough for ``comm="sparse"`` (``engine``,
        ``verify``, ``use_pallas``).
    **hyperparams: solver hyperparameter overrides; the valid keys are the
        solver's ``defaults`` keys (anything else raises ``TypeError``).
    """
    spec = get_solver(method)
    if comm not in COMM_BACKENDS:
        raise ValueError(f"unknown comm backend {comm!r}; one of {COMM_BACKENDS}")
    if steps < 1:
        raise ValueError("steps must be >= 1")
    if record_every < 1:
        raise ValueError("record_every must be >= 1")
    if comm_options and comm != "sparse":
        raise ValueError("comm_options only apply to comm='sparse'")

    hp = dict(spec.defaults)
    unknown = set(hyperparams) - set(hp)
    if unknown:
        raise TypeError(
            f"{method!r} got unknown hyperparameters {sorted(unknown)}; "
            f"accepts {sorted(hp)}"
        )
    hp.update(hyperparams)

    data = problem.data
    n, D = data.n_nodes, problem.dim
    dt = data.val.dtype
    if z0 is None:
        z0 = np.zeros((n, D), dtype=dt)
    if indices is None:
        indices = draw_indices(steps, n, data.q, seed)
    indices = np.asarray(indices)
    if indices.ndim != 2 or indices.shape[0] < steps or indices.shape[1] != n:
        raise ValueError(
            f"indices must be (>= steps, N) = (>={steps}, {n}), "
            f"got {indices.shape}"
        )
    pts = _record_points(steps, record_every)
    rec = _Recorder(problem.z_star, keep_snapshots)

    if comm == "sparse":
        if not spec.supports_sparse_comm():
            raise ValueError(
                f"method {method!r} has no sparse-communication backend"
            )
        t0 = time.perf_counter()
        sres = spec.sparse_run(
            problem, hp, steps, indices, z0, dict(comm_options or {})
        )
        wall = time.perf_counter() - t0
        for pt in pts:
            rec.push(pt, sres.z_trace[pt])
        iters, dist2, cons, zs = rec.arrays()
        sel = np.asarray(pts) - 1
        return SolveResult(
            method=method,
            comm=comm,
            iters=iters,
            dist2=dist2,
            consensus=cons,
            doubles_received=sres.doubles_received[sel],
            ints_received=sres.ints_received[sel],
            wall_time=wall,
            z=sres.z_trace[-1],
            state=None,
            zs=zs,
            extras={
                "z_trace": sres.z_trace,
                "recon_max_err": sres.recon_max_err,
            },
        )

    # ---- dense backend: chunked scan between record points ----------------
    t0 = time.perf_counter()
    step_fn = spec.step(problem, hp)
    z_of = spec.z_of(problem, hp)
    idx_j = jnp.asarray(indices[:steps], jnp.int32)

    @jax.jit
    def chunk(state, idx_block):
        st, _ = jax.lax.scan(
            lambda s, i: (step_fn(s, i), None), state, idx_block
        )
        return st

    state = spec.init(problem, hp, jnp.asarray(z0))
    prev = 0
    for pt in pts:
        state = chunk(state, idx_j[prev:pt])
        prev = pt
        rec.push(pt, z_of(state))
    wall = time.perf_counter() - t0

    iters, dist2, cons, zs = rec.arrays()
    per_node = dense_doubles_per_iter(problem.graph, D)  # (N,)
    doubles = iters[:, None] * per_node[None, :]
    return SolveResult(
        method=method,
        comm=comm,
        iters=iters,
        dist2=dist2,
        consensus=cons,
        doubles_received=doubles,
        ints_received=np.zeros_like(doubles),
        wall_time=wall,
        z=np.asarray(z_of(state)),
        state=state,
        zs=zs,
    )


# ---------------------------------------------------------------------------
# Registry entries: DSBA / DSA (Algorithm 1 + Remark 5.1)
# ---------------------------------------------------------------------------


def _dsba_cfg(problem: Problem, hp, method: str) -> DSBAConfig:
    """Map (problem, hyperparams) onto the Algorithm-1 step config."""
    return DSBAConfig(
        spec=problem.spec, alpha=hp["alpha"], lam=problem.lam, method=method
    )


def _make_dsba_family(method: str, default_alpha: float) -> SolverSpec:
    """Registry entry for the stochastic family: shared step, both comms."""

    def init(problem, hp, z0):
        """SAGA-table warm start (Algorithm 1 line 1) at ``z0``."""
        return _dsba_init_state(_dsba_cfg(problem, hp, method), problem.data, z0)

    def step(problem, hp):
        """Device-resident Algorithm-1 step via ``dsba.make_step_fn``."""
        return _dsba_make_step_fn(
            _dsba_cfg(problem, hp, method), problem.data, problem.w
        )

    def z_of(problem, hp):
        """Iterates live directly on the state."""
        return lambda state: state.z

    def sparse_run(problem, hp, steps, indices, z0, options):
        """The Section-5.1 delta relay (``core.sparse_comm.run_sparse``)."""
        return _sparse_comm.run_sparse(
            _dsba_cfg(problem, hp, method),
            problem.data,
            problem.graph,
            problem.w,
            steps,
            indices,
            z0=z0,
            **options,
        )

    return SolverSpec(
        name=method,
        init=init,
        step=step,
        z_of=z_of,
        defaults={"alpha": default_alpha},
        sparse_run=sparse_run,
    )


register_solver(_make_dsba_family("dsba", default_alpha=0.5))
register_solver(_make_dsba_family("dsa", default_alpha=0.2))


# ---------------------------------------------------------------------------
# Registry entries: deterministic baselines (EXTRA / DLM / SSDA)
# ---------------------------------------------------------------------------


def _full_operator(spec: OperatorSpec, feats, labels, lam):
    """G(Z): (N, D) -> (N, D), full local operator incl. regularizer."""
    t = spec.tail_dim
    d = feats.shape[-1]

    def G(Z):
        head, tail = Z[:, :d], Z[:, d:]
        u = jnp.einsum("nqd,nd->nq", feats, head)
        tails = jnp.broadcast_to(tail[:, None, :], u.shape + (t,))
        g, tail_out = spec.coeff_and_tail(u, labels, tails)
        out_head = jnp.einsum("nq,nqd->nd", g, feats) / feats.shape[1]
        if t:
            out = jnp.concatenate([out_head, tail_out.mean(1)], axis=1)
        else:
            out = out_head
        return out + lam * Z

    return G


def _dense_setup(problem: Problem):
    """(feats, labels, G-factory inputs) shared by the dense baselines."""
    feats = jnp.asarray(problem.data.dense())
    labels = jnp.asarray(problem.data.y)
    return feats, labels


def _extra_init(problem, hp, z0):
    """EXTRA state: (z, z_prev, g_prev, t) with a scan-compatible counter."""
    zeros = jnp.zeros_like(z0)
    return (z0, zeros, zeros, jnp.zeros((), jnp.int32))


def _extra_step(problem, hp):
    """EXTRA (Shi et al. 2015a), eq. (47) form with first-step special case."""
    feats, labels = _dense_setup(problem)
    G = _full_operator(problem.spec, feats, labels, problem.lam)
    alpha = hp["alpha"]
    dt = feats.dtype
    wj = jnp.asarray(problem.w, dt)
    wtj = jnp.asarray(w_tilde(problem.w), dt)

    def step(carry, i_t):
        z, z_prev, g_prev, t = carry
        g = G(z)
        z1 = jnp.where(
            t == 0,
            wj @ z - alpha * g,
            z + wj @ z - wtj @ z_prev - alpha * (g - g_prev),
        )
        return (z1, z, g, t + 1)

    return step


def _dlm_init(problem, hp, z0):
    """DLM state: (z, dual multipliers)."""
    return (z0, jnp.zeros_like(z0))


def _dlm_step(problem, hp):
    """DLM (Ling et al. 2015): linearized decentralized ADMM."""
    feats, labels = _dense_setup(problem)
    G = _full_operator(problem.spec, feats, labels, problem.lam)
    c, beta = hp["c"], hp["beta"]
    dt = feats.dtype
    lap = jnp.asarray(problem.graph.laplacian, dt)
    deg = jnp.asarray(problem.graph.degrees, dt)[:, None]

    def step(carry, i_t):
        z, lam_dual = carry
        grad_aug = G(z) + lam_dual + 2.0 * c * (lap @ z)
        z1 = z - grad_aug / (2.0 * c * deg + beta)
        lam1 = lam_dual + c * (lap @ z1)
        return (z1, lam1)

    return step


# Single-slot share of the grad f* closure: solve() invokes the step and
# z_of factories back to back on the same (problem, hp), and the build is
# real work (Gram + N Cholesky factorizations for ridge). The slot holds the
# problem strongly, so the identity check cannot alias a recycled id; the
# value snapshots (data, lam, spec) at build time so mutating the problem
# invalidates the hit.
_SSDA_CG_CACHE: list = []


def _ssda_conj_grad(problem: Problem, hp):
    """grad f*_n read-out: Cholesky for ridge, damped Newton otherwise.

    Built once per (problem, hp) — see ``_SSDA_CG_CACHE``.
    """
    for p, data_ref, lam_ref, spec_ref, hp_ref, cg in _SSDA_CG_CACHE:
        if (p is problem and p.data is data_ref and p.lam == lam_ref
                and p.spec == spec_ref and hp_ref == dict(hp)):
            return cg
    cg = _build_ssda_conj_grad(problem, hp)
    _SSDA_CG_CACHE[:] = [
        (problem, problem.data, problem.lam, problem.spec, dict(hp), cg)
    ]
    return cg


def _build_ssda_conj_grad(problem: Problem, hp):
    """Construct the grad f*_n closure (the cached work behind the cache)."""
    spec, lam = problem.spec, problem.lam
    if spec.tail_dim:
        raise NotImplementedError(
            "SSDA requires grad f*; the paper notes it does not apply to AUC"
        )
    feats = jnp.asarray(problem.data.dense())  # (N, q, d)
    labels = jnp.asarray(problem.data.y)
    n, q, d = feats.shape
    dt = feats.dtype
    inner_newton = int(hp["inner_newton"])

    if spec.kind == "ridge":
        # grad f_n(x) = A^T(Ax - y)/q + lam x ; grad f*_n(s) solves it = s
        gram = jnp.einsum("nqd,nqe->nde", feats, feats) / q
        gram = gram + lam * jnp.eye(d, dtype=dt)[None]
        rhs0 = jnp.einsum("nqd,nq->nd", feats, labels) / q
        chol = jax.vmap(jnp.linalg.cholesky)(gram)

        def conj_grad(S):  # (N, d) -> (N, d): x_n = grad f*_n(s_n)
            return jax.vmap(
                lambda L, r: jax.scipy.linalg.cho_solve((L, True), r)
            )(chol, S + rhs0)

    else:

        def conj_grad(S):
            # invert grad f_n via damped Newton with explicit per-node jacobians
            def one(fe, la, s):
                def gn(x):
                    u = fe @ x
                    g, _ = spec.coeff_and_tail(u, la, jnp.zeros((q, 0), dt))
                    return fe.T @ g / q + lam * x

                x = jnp.zeros((d,), dt)
                jac = jax.jacfwd(gn)
                for _ in range(inner_newton):
                    x = x - jnp.linalg.solve(jac(x), gn(x) - s)
                return x

            return jax.vmap(one)(feats, labels, S)

    return conj_grad


def _ssda_init(problem, hp, z0):
    """SSDA state: (momentum iterate, previous momentum iterate) on the dual."""
    n, d = problem.data.n_nodes, problem.data.d
    dt = jnp.asarray(problem.data.val).dtype
    zeros = jnp.zeros((n, d), dt)
    return (zeros, zeros)


def _ssda_step(problem, hp):
    """SSDA (Scaman et al. 2017): accelerated gradient ascent on the dual."""
    conj_grad = _ssda_conj_grad(problem, hp)
    eta, momentum = hp["eta"], hp["momentum"]
    n = problem.data.n_nodes
    dt = jnp.asarray(problem.data.val).dtype
    i_minus_w = jnp.eye(n, dtype=dt) - jnp.asarray(problem.w, dt)

    def step(carry, i_t):
        m, m_prev = carry
        v = m + momentum * (m - m_prev)
        x = conj_grad(-v)  # primal read-out: grad f*(-(U Lambda)_n)
        m1 = v + eta * (i_minus_w @ x)
        return (m1, m)

    return step


def _ssda_z_of(problem, hp):
    """Primal read-out grad f*(-m): a real computation, not a field access."""
    conj_grad = _ssda_conj_grad(problem, hp)
    read = jax.jit(lambda m: conj_grad(-m))
    return lambda state: read(state[0])


register_solver(
    SolverSpec(
        name="extra",
        init=_extra_init,
        step=_extra_step,
        z_of=lambda problem, hp: lambda state: state[0],
        defaults={"alpha": 0.3},
    )
)
register_solver(
    SolverSpec(
        name="dlm",
        init=_dlm_init,
        step=_dlm_step,
        z_of=lambda problem, hp: lambda state: state[0],
        defaults={"c": 0.3, "beta": 1.0},
    )
)
register_solver(
    SolverSpec(
        name="ssda",
        init=_ssda_init,
        step=_ssda_step,
        z_of=_ssda_z_of,
        defaults={"eta": 0.05, "momentum": 0.5, "inner_newton": 8},
    )
)

"""One solver API: ``Problem`` + ``SolverSpec`` registry + ``solve()``.

The paper recasts decentralized learning as monotone-operator root finding;
this module makes that the *interface*: a ``Problem`` bundles the operator
family, the node-local data, the communication graph, the mixing matrix and
the ``z*`` oracle, while a ``SolverSpec`` registry (mirroring the
``KernelSpec`` registry in ``kernels/ops.py``) makes the *method*
(``dsba``/``dsa`` per Algorithm 1 and Remark 5.1, ``extra``/``dlm``/``ssda``
per the deterministic baselines of Table 1) and the *communication backend*
(``dense`` neighbor exchange vs. the paper's sparse delta relay of Section
5.1) two orthogonal axes of a single call::

    problem = make_problem("ridge", data, graph)
    problem.solve_star()                      # cache the centralized root
    res = solve(problem, method="dsba", comm="sparse", steps=4000)

``solve`` is the per-run entrypoint; ``solve_many`` runs a whole
hyperparameter/seed grid as one vmapped computation. Both are backed by a
keyed cache of compiled runners (``core.runner_cache``): the jitted chunked
scan is compiled once per (method, comm, problem shape, static-hp
structure) with hyperparameter *values* passed as traced arguments, so
sweep-shaped experiments (bench_table1's lam grid, seed replications) pay
XLA compilation once. ``core.dsba.run`` and
``core.baselines.run_extra/run_dlm/run_ssda`` are thin deprecated shims
delegating here, pinned trace-identical by ``tests/test_solvers.py``.

Every run returns the same ``SolveResult`` schema, including cumulative
communicated DOUBLEs/ints per node: measured by the relay's closed-form
accounting when ``comm="sparse"``, and from the ``deg(n) * D`` dense-exchange
model otherwise — so sparse-vs-dense communication cost is comparable in one
result type. Authoring contract and backend-resolution rules are documented
in docs/solvers.md.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

from repro.core import reference, runner_cache
from repro.core.comm import (
    DenseComm,
    FaultyDenseComm,
    FaultyShardedComm,
    ShardedComm,
    shard_map as _shard_map,
)
from repro.core.dsba import (
    DSBAConfig,
    draw_indices,
    init_state as _dsba_init_state,
    make_step_fn as _dsba_make_step_fn,
)
from repro.core.mixing import Graph, laplacian_mixing, spectral_gap, w_tilde
from repro.core.operators import (
    FAMILIES,
    MINIMIZATION_FAMILIES,
    OperatorSpec,
)
from repro.core.runner_cache import (
    clear as clear_runner_caches,  # noqa: F401  (public re-export)
    stats as runner_cache_stats,  # noqa: F401  (public re-export)
)
from repro.core import sparse_comm as _sparse_comm
from repro.core.sparse_comm import dense_doubles_per_iter

COMM_BACKENDS = ("dense", "sparse", "sharded")


# ---------------------------------------------------------------------------
# Problem: everything a solver needs, bundled once
# ---------------------------------------------------------------------------


def graph_from_mixing(w: np.ndarray, atol: float = 1e-12) -> Graph:
    """Recover the communication ``Graph`` from a mixing matrix's support.

    Section 4's sparsity condition makes W and the graph carry the same
    information (``w[m,l] != 0`` iff ``(m,l)`` is an edge or ``m == l``), so
    legacy callers that only pass W still get full communication accounting.
    """
    w = np.asarray(w)
    n = w.shape[0]
    edges = tuple(
        (i, j)
        for i in range(n)
        for j in range(i + 1, n)
        if abs(w[i, j]) > atol
    )
    return Graph(n, edges)


@dataclasses.dataclass
class Problem:
    """A decentralized root-finding problem instance.

    Bundles the operator family (``spec``), the per-node data (padded-CSR
    ``SparseDataset``), the communication ``graph``, the mixing matrix ``w``
    (defaults to the paper's Laplacian weights on ``graph``), the l2
    regularizer ``lam`` (part of the *problem*, not the solver), and an
    optional cached centralized root ``z_star``.

    ``lam`` may be a scalar or an (N,) per-node array (personalization);
    per-node lam runs on ``comm="dense"`` with methods advertising
    ``supports_per_node_lam`` — anything else is a ``CapabilityError``.

    ``schedule`` makes the network axis time-varying: a sequence of
    ``(start_iter, Graph-or-W)`` segments. ``solve()`` runs each segment
    through its own cached runner (edge colorings / relay waves re-derived
    per segment) carrying the solver state across boundaries, and records
    each segment's spectral gap in ``SolveResult.extras["schedule"]``. A
    segment given as a ``Graph`` gets the paper's Laplacian mixing; one
    given as a W matrix recovers its graph from the support. If no segment
    starts at 0, the problem's own (graph, w) opens the schedule.
    """

    spec: OperatorSpec
    data: Any  # repro.data.synthetic.SparseDataset (duck-typed)
    graph: Graph
    w: np.ndarray | None = None
    lam: float | np.ndarray = 0.0
    z_star: np.ndarray | None = None
    schedule: Any = None  # normalized to ((start, Graph, W), ...) or None

    def __post_init__(self):
        """Default ``w`` to Laplacian mixing and sanity-check shapes."""
        if self.w is None:
            self.w = laplacian_mixing(self.graph)
        self.w = np.asarray(self.w)
        if self.w.shape != (self.graph.n, self.graph.n):
            raise ValueError(
                f"mixing matrix {self.w.shape} != graph size {self.graph.n}"
            )
        if self.data.n_nodes != self.graph.n:
            raise ValueError(
                f"data has {self.data.n_nodes} nodes, graph {self.graph.n}"
            )
        if np.ndim(self.lam) > 0:
            self.lam = np.asarray(self.lam, dtype=np.float64)
            if self.lam.shape != (self.graph.n,):
                raise ValueError(
                    f"per-node lam must be ({self.graph.n},), "
                    f"got {self.lam.shape}"
                )
        if self.schedule is not None:
            self.schedule = _normalize_schedule(
                self.schedule, self.graph, self.w, self.data.n_nodes
            )

    @property
    def dim(self) -> int:
        """Total iterate dimension D = d + tail_dim."""
        return self.data.d + self.spec.tail_dim

    def solve_star(self, **kwargs) -> np.ndarray:
        """Compute (once) and cache the centralized root ``z*``.

        Delegates to ``reference.solve_root``; extra kwargs (``iters``,
        ``tol``) pass through. Idempotent: repeated calls return the cache.
        Per-node ``lam`` has no single centralized root — use
        ``personalized_root`` for those problems.
        """
        if self.z_star is None:
            if np.ndim(self.lam) > 0:
                raise ValueError(
                    "per-node lam has no centralized root; use "
                    "core.solvers.personalized_root for the coupled system"
                )
            self.z_star = reference.solve_root(
                self.spec, self.data, self.lam, **kwargs
            )
        return self.z_star


def _normalize_schedule(schedule, graph0: Graph, w0, n: int):
    """Normalize ``(start, Graph-or-W)`` entries to ``(start, Graph, W)``.

    Starts must be unique non-negative ints; segments are sorted and, when
    none starts at 0, the problem's own (graph, w) opens the schedule.
    """
    segs = []
    for start, g in schedule:
        start = int(start)
        if start < 0:
            raise ValueError(f"schedule segment start {start} < 0")
        if isinstance(g, Graph):
            seg_graph, seg_w = g, laplacian_mixing(g)
        else:
            seg_w = np.asarray(g)
            if seg_w.shape != (n, n):
                raise ValueError(
                    f"schedule segment W {seg_w.shape} != ({n}, {n})"
                )
            seg_graph = graph_from_mixing(seg_w)
        if seg_graph.n != n:
            raise ValueError(
                f"schedule segment graph has {seg_graph.n} nodes, "
                f"problem has {n}"
            )
        segs.append((start, seg_graph, seg_w))
    segs.sort(key=lambda s: s[0])
    starts = [s[0] for s in segs]
    if len(set(starts)) != len(starts):
        raise ValueError(f"duplicate schedule segment starts {starts}")
    if not segs or segs[0][0] != 0:
        segs.insert(0, (0, graph0, np.asarray(w0)))
    return tuple(segs)


def make_problem(
    task: str,
    data,
    graph: Graph,
    w: np.ndarray | None = None,
    lam: float | None = None,
    gamma: float = 1.0,
) -> Problem:
    """Build a ``Problem`` from a task name with the paper's conventions.

    task: ``"ridge" | "logistic" | "auc" | "bilinear"`` (AUC reads the
    positive-class ratio from the data; ``bilinear`` is the saddle-point
    minimax family with dual strong-concavity ``gamma``). ``lam`` defaults
    to the paper's 1/(10 Q); for ``bilinear`` it regularizes both blocks
    (+lam/2 on the primal, -lam/2 on the dual) so ``solve_star()`` is the
    regularized saddle point.
    """
    if task == "auc":
        spec = OperatorSpec("auc", p=data.positive_ratio())
    elif task == "bilinear":
        spec = OperatorSpec("bilinear", gamma=gamma)
    elif task in ("ridge", "logistic"):
        spec = OperatorSpec(task)
    else:
        raise ValueError(f"unknown task {task!r}; one of {FAMILIES}")
    if lam is None:
        lam = 1.0 / (10.0 * data.total)
    return Problem(spec=spec, data=data, graph=graph, w=w, lam=lam)


# ---------------------------------------------------------------------------
# Fault plans (churn / link faults / stragglers) applied mid-run by solve().
# The schemas live in ``repro.ft.faults`` (plain-numpy, import-light);
# ChurnEvent/ChurnPlan are re-exported here for the PR 8 call sites.
# ---------------------------------------------------------------------------

from repro.ft.faults import (  # noqa: E402  (grouped with the fault layer)
    ChurnEvent,
    ChurnPlan,
    FaultPlan,
    LinkFault,
    StragglerSpec,
    as_fault_plan,
    delivered_in_messages,
    fault_message_totals,
    link_delivered_mask,
    source_sent_mask,
    straggler_delivered_mask,
)
from repro.ckpt.checkpoint import (  # noqa: E402
    CheckpointManager,
    CheckpointSpec,
    load_checkpoint,
    restore_checkpoint,
)


# ---------------------------------------------------------------------------
# SolverSpec registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SolverSpec:
    """One solver's contract with ``solve()`` (see docs/solvers.md).

    ``init``/``step``/``z_of`` are *factories* over ``(problem, hp)`` so each
    entry can bake data and mixing matrices into device arrays exactly once
    per compiled runner. Hyperparameter VALUES are not baked: the functions
    a factory returns receive the runtime hyperparameters as a final ``hp``
    argument — a dict of scalars that the compiled-runner cache passes as
    *traced* jit arguments, so a sweep over values reuses one executable:

    - ``init(problem, hp, z0) -> state``: initial state pytree from a (N, D)
      starting point (scan-compatible: every leaf is a jax array).
    - ``step(problem, hp, comm) -> fn(state, i_t, hp) -> state``: the
      per-iteration transition, safe to call inside jit/lax.scan. ``i_t``
      is the (N,) sample draw of this iteration; deterministic solvers
      ignore it. The inner ``hp`` dict carries every non-static
      hyperparameter plus ``"lam"`` (unless ``bake_lam``). ``comm`` is the
      communication backend (``core.comm``): ALL neighbor exchange must go
      through ``comm.matvec(M, dtype)`` and all reads of node-indexed
      constants through ``comm.local`` — never an inline ``W @ X`` — so
      the one step definition runs under dense and sharded execution.
    - ``z_of(problem, hp, comm) -> fn(state, hp) -> (N, D)``: iterate
      read-out (SSDA's primal read-out is a real computation, hence a
      factory too; it receives ``comm`` for the same reason the step does).
    - ``defaults``: the solver's hyperparameters with default values; the
      keys are also the *schema* — ``solve()`` rejects unknown overrides.
    - ``static_hp``: names of hyperparameters that are *structural* (Python
      loop counts, shapes) and must be baked at factory time. They join the
      runner cache key; changing them recompiles. At factory time the ``hp``
      mapping resolves static names only — reading a runtime-traced name
      there raises, so a value can never be silently baked stale.
    - ``bake_lam``: bake ``problem.lam`` at factory time instead of tracing
      it (SSDA's conjugate-gradient map is factorized around ``lam``).
    - ``sparse_run``: optional sparse-communication backend with signature
      ``(problem, hp, steps, indices, z0, options) -> SparseRunResult``.
      ``None`` means the method has no sparse protocol (the deterministic
      baselines exchange dense vectors by construction).
    - ``sparse_run_many``: optional batched sparse backend with signature
      ``(problem, merged, steps, idx_b, z0, options) ->
      list[SparseRunResult] | None`` (``merged``: one resolved hp dict per
      run; ``idx_b``: (B, >= steps, N) sample streams). Returning ``None``
      declines the batch (e.g. ``engine="reference"``) and ``solve_many``
      falls back to sequential warm ``solve()`` calls.
    - ``problem_families``: the operator families (``OperatorSpec.kind``
      values) the method supports; ``solve()`` raises ``CapabilityError``
      for anything else (e.g. descent-only methods on saddle families).
    - ``supports_sharded``: whether the step is sharded-backend safe (all
      registered methods are today; the flag exists so a future
      non-``comm.matvec`` method degrades to a typed error, not a crash).
    - ``comm_rounds``: optional accounting hook mapping (resolved hp,
      cumulative iteration counts) -> cumulative *dense-exchange rounds*
      per node at those counts. ``None`` means one round per iteration
      (every pre-PR-7 method). Mudag's K inner gossip rounds (2K/iter)
      and sliding's skipped rounds (2*ceil(iters/period)) report through
      this hook, so ``SolveResult.doubles_received`` stays honest.
    - ``supports_schedule``: the method's fixed point is preserved under a
      mid-run change of the mixing matrix, so ``solve()`` may carry its
      state across the segments of a ``Problem.schedule``
      (restart-on-new-W — docs/algorithm.md). Methods whose *state*
      encodes W (EXTRA/DLM's duals, SSDA's dual momentum) must leave this
      False: carrying their state over a W change targets a stale fixed
      point, and that is a ``CapabilityError``, not a silent restart.
    - ``supports_churn``: the state pytree keeps all per-node quantities
      on leading-N leaves AND the fixed point survives membership change,
      so ``ft.elastic.ElasticGossip`` shrink/grow remapping is sound.
    - ``reanchor``: optional ``(state) -> state`` applied after an
      elastic churn remap. Difference-form methods (DSBA/DSA) conserve a
      telescoped mean-drift invariant anchored by their t=0 step; a
      membership change alters the node mean, so the anchor must re-run
      on the new membership or the run converges to the OLD system's
      root (docs/algorithm.md). A W-only switch preserves the invariant
      (1^T W = 1^T for any doubly stochastic W) and does NOT reanchor.
    - ``supports_per_node_lam``: the step accepts ``lam`` as an (N,)
      array (personalized regularization) — dense backend only.
    - ``supports_link_faults``: the step routes ALL neighbor exchange
      through ``comm.matvec``, so a per-step delivery mask (masked mixing
      rows with row-renormalization) injects cleanly. True for every
      registered method — the flag exists so a future method with
      out-of-band exchange degrades to a typed error.
    - ``supports_stragglers``: the step's matvec call sites are each
      invoked a FIXED number of times per iteration at the top level of
      the traced step, so last-delivered-value buffers can be threaded
      through the scan carry. False for methods that apply ``matvec``
      inside an inner traced loop (mudag's FastMix — the buffer write
      would escape the loop trace) or gate it on a traced round predicate
      (sliding — off-round iterations exchange nothing to delay).
    """

    name: str
    init: Callable[[Problem, Mapping[str, float], jax.Array], Any]
    step: Callable[[Problem, Mapping[str, float], Any], Callable]
    z_of: Callable[[Problem, Mapping[str, float], Any], Callable]
    defaults: Mapping[str, float]
    sparse_run: Callable | None = None
    sparse_run_many: Callable | None = None
    static_hp: tuple[str, ...] = ()
    bake_lam: bool = False
    problem_families: tuple[str, ...] = ("ridge", "logistic", "auc")
    supports_sharded: bool = True
    comm_rounds: Callable[[Mapping[str, float], np.ndarray], np.ndarray] | None = None
    supports_schedule: bool = False
    supports_churn: bool = False
    supports_per_node_lam: bool = False
    reanchor: Callable[[Any], Any] | None = None
    supports_link_faults: bool = True
    supports_stragglers: bool = True

    def supports_sparse_comm(self) -> bool:
        """Whether this method has a sparse-communication backend."""
        return self.sparse_run is not None

    def capabilities(self) -> "SolverCapabilities":
        """The typed capability record ``available_solvers()`` exposes."""
        return SolverCapabilities(
            supports_sparse_comm=self.sparse_run is not None,
            supports_sharded=self.supports_sharded,
            problem_families=tuple(self.problem_families),
            supports_schedule=self.supports_schedule,
            supports_churn=self.supports_churn,
            supports_per_node_lam=self.supports_per_node_lam,
            supports_link_faults=self.supports_link_faults,
            supports_stragglers=self.supports_stragglers,
        )


@dataclasses.dataclass(frozen=True)
class SolverCapabilities:
    """What one registered solver supports, as data (see docs/solvers.md).

    Returned per method by ``available_solvers()``. ``solve()`` enforces
    exactly this record: a (method, comm backend, operator family)
    combination outside it raises ``CapabilityError`` — never a silent
    fallback to a backend the caller did not ask for. The same rule
    covers the dynamic-network axes: a multi-segment ``schedule``, a
    churn ``fault_plan``, or a per-node ``lam`` on a method that does
    not advertise the capability raises before any factory runs — never
    a silent static fallback.
    """

    supports_sparse_comm: bool
    supports_sharded: bool
    problem_families: tuple[str, ...]
    supports_schedule: bool = False
    supports_churn: bool = False
    supports_per_node_lam: bool = False
    supports_link_faults: bool = True
    supports_stragglers: bool = True

    def comm_backends(self) -> tuple[str, ...]:
        """The comm backends this solver accepts (dense is universal)."""
        out = ["dense"]
        if self.supports_sparse_comm:
            out.append("sparse")
        if self.supports_sharded:
            out.append("sharded")
        return tuple(out)

    def supports(self, comm: str, family: str) -> bool:
        """Whether (comm backend, operator family) is inside this record."""
        return comm in self.comm_backends() and family in self.problem_families


class CapabilityError(ValueError):
    """A (method, comm backend, operator family) combination is unsupported.

    Subclasses ``ValueError`` so callers catching the registry's value
    errors keep working; carries the offending combination as attributes
    for programmatic handling.
    """

    def __init__(self, method: str, comm: str, family: str, reason: str):
        super().__init__(
            f"unsupported combination (method={method!r}, comm={comm!r}, "
            f"operator family={family!r}): {reason}"
        )
        self.method = method
        self.comm = comm
        self.family = family


def _check_capability(
    spec: "SolverSpec",
    comm: str,
    family: str,
    *,
    schedule: bool = False,
    churn: bool = False,
    per_node_lam: bool = False,
    link_faults: bool = False,
    stragglers: bool = False,
) -> None:
    """Raise ``CapabilityError`` unless (spec, comm, family) is supported.

    The keyword flags add the dynamic-network and fault-injection axes: a
    multi-segment graph ``schedule``, a ``churn`` plan, a ``per_node_lam``
    array, ``link_faults`` (per-edge drops) and ``stragglers`` (delayed
    delivery). Runs before any solver factory, so an unsupported
    combination can never silently fall back to a static run.
    """
    caps = spec.capabilities()
    if family not in caps.problem_families:
        raise CapabilityError(
            spec.name, comm, family,
            f"method {spec.name!r} supports operator families "
            f"{list(caps.problem_families)}",
        )
    if comm == "sparse" and not caps.supports_sparse_comm:
        raise CapabilityError(
            spec.name, comm, family,
            f"method {spec.name!r} has no sparse-communication backend",
        )
    if comm == "sharded" and not caps.supports_sharded:
        raise CapabilityError(
            spec.name, comm, family,
            f"method {spec.name!r} does not run under the sharded backend",
        )
    if schedule and not caps.supports_schedule:
        raise CapabilityError(
            spec.name, comm, family,
            f"method {spec.name!r} does not support graph schedules: its "
            "state would carry a stale fixed point across a W change",
        )
    if churn and not caps.supports_churn:
        raise CapabilityError(
            spec.name, comm, family,
            f"method {spec.name!r} does not support node churn "
            "(fault_plan): its state cannot be elastically remapped",
        )
    if link_faults and not caps.supports_link_faults:
        raise CapabilityError(
            spec.name, comm, family,
            f"method {spec.name!r} does not support link faults: its "
            "neighbor exchange does not route through comm.matvec",
        )
    if stragglers and not caps.supports_stragglers:
        raise CapabilityError(
            spec.name, comm, family,
            f"method {spec.name!r} does not support stragglers: its "
            "matvec call sites are not fixed-count per iteration "
            "(inner gossip loop or traced round gating)",
        )
    if stragglers and comm != "dense":
        raise CapabilityError(
            spec.name, comm, family,
            "stragglers (delayed delivery buffers) run on comm='dense' "
            "only; link faults cover the sharded and sparse backends",
        )
    if per_node_lam and not caps.supports_per_node_lam:
        raise CapabilityError(
            spec.name, comm, family,
            f"method {spec.name!r} does not support per-node lam "
            "(personalization); see available_solvers()",
        )
    if per_node_lam and comm != "dense":
        raise CapabilityError(
            spec.name, comm, family,
            "per-node lam (personalization) runs on comm='dense' only",
        )


#: per-backend comm_options schema enforced by ``_validate_options``
_COMM_OPTION_KEYS = {
    "dense": ("fault_plan",),
    "sparse": ("engine", "verify", "use_pallas", "fault_plan"),
    "sharded": ("mesh", "fault_plan"),
}


def _validate_options(comm: str, comm_options: Mapping | None) -> dict:
    """The one comm_options gate shared by every backend resolution path.

    Returns a mutable copy; unknown keys fail loudly instead of being
    silently dropped (dense accepts none — passing sparse-engine options
    to a dense run is a bug, not a no-op).
    """
    opts = dict(comm_options or {})
    allowed = _COMM_OPTION_KEYS[comm]
    unknown = sorted(set(opts) - set(allowed))
    if unknown:
        raise ValueError(
            f"unknown {comm} comm_options {unknown}; "
            f"accepts {sorted(allowed)}"
        )
    return opts


_REGISTRY: dict[str, SolverSpec] = {}


def register_solver(spec: SolverSpec) -> SolverSpec:
    """Add a ``SolverSpec`` to the registry (name must be unused)."""
    if spec.name in _REGISTRY:
        raise ValueError(f"solver {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get_solver(name: str) -> SolverSpec:
    """Look up a registered solver by name."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown method {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def available_solvers() -> dict[str, SolverCapabilities]:
    """{name: SolverCapabilities} for every registered solver.

    The values are typed capability records (sparse/sharded backend
    support plus the supported operator families) — exactly what
    ``solve()`` enforces via ``CapabilityError``.
    """
    return {
        name: spec.capabilities() for name, spec in sorted(_REGISTRY.items())
    }


# ---------------------------------------------------------------------------
# Compiled-runner cache: one jitted chunked scan per (method, problem shape).
# Hyperparameter values are traced arguments — a sweep compiles once.
# ---------------------------------------------------------------------------


class TracedHPError(KeyError):
    """A factory read a runtime-traced hyperparameter at bake time."""

    def __str__(self):
        """The message verbatim (KeyError would repr-quote it)."""
        return self.args[0]


class _FactoryHP(Mapping):
    """Factory-time view of the hyperparameters: the *static* names only.

    Static names resolve to their values (they are part of the cache key);
    as a Mapping this contains nothing else, so ``in`` / ``.get`` /
    iteration answer honestly. Subscripting a runtime-traced name raises
    ``TracedHPError`` (a KeyError) with a pointer to the ``hp`` argument —
    a factory can never silently bake a value that later sweep calls would
    then reuse stale.
    """

    def __init__(self, values: Mapping[str, float], static: tuple[str, ...]):
        self._values = dict(values)
        self._static = frozenset(static) & set(self._values)

    def __getitem__(self, name: str):
        if name in self._static:
            return self._values[name]
        if name in self._values:
            raise TracedHPError(
                f"hyperparameter {name!r} is runtime-traced; read it from "
                "the hp argument inside the step/z_of function, or declare "
                "it in SolverSpec.static_hp"
            )
        raise KeyError(name)

    def __iter__(self):
        return iter(k for k in self._values if k in self._static)

    def __len__(self):
        return len(self._static)


def _dynamic_hp(spec: SolverSpec, problem: Problem, hp: Mapping) -> dict:
    """The runtime-traced hp dict: non-static names + lam (unless baked).

    Values are normalized to Python floats so jit sees one weak-typed
    scalar signature per runner — different values never retrace.
    """
    dyn = {
        k: float(v) for k, v in hp.items() if k not in spec.static_hp
    }
    if not spec.bake_lam:
        # per-node lam stays an (N,) array in the data dtype (one traced
        # signature); scalar lam stays a weak-typed python float
        dyn["lam"] = (
            float(problem.lam)
            if np.ndim(problem.lam) == 0
            else np.asarray(problem.lam, dtype=problem.data.val.dtype)
        )
    return dyn


def _runner_key(spec: SolverSpec, problem: Problem, hp: Mapping):
    """(key, guards) for one (method, problem shape, static-hp structure).

    The dataset enters by identity (guarded by a strong reference in the
    entry); the mixing matrix by content fingerprint, so problems rebuilt
    per sweep point (same data/graph, fresh equal W, different lam) share
    one compiled runner. Hyperparameter *values* never enter the key —
    only the static structure does.
    """
    key = (
        spec.name,
        runner_cache.problem_fingerprint(
            problem.data, problem.spec, problem.graph, problem.w
        ),
        tuple((k, float(hp[k])) for k in spec.static_hp),
        float(problem.lam) if spec.bake_lam else None,
    )
    return key, (problem.data,)


@dataclasses.dataclass
class _DenseRunner:
    """One compiled dense-backend runner: chunked scan + iterate read-out.

    ``chunk``/``z_read`` are the jitted entrypoints; ``run_chunk``/``z_fn``
    are the untraced callables kept for ``solve_many`` to vmap (the batched
    variants compile lazily into ``batched``, keyed by vmap signature).
    """

    init: Callable  # (z0) -> state, eager
    run_chunk: Callable  # (state, idx_block, hp) -> state, untraced
    z_fn: Callable  # (state, hp) -> (N, D), untraced
    chunk: Callable  # jitted run_chunk (donated carry off-CPU)
    z_read: Callable  # jitted z_fn
    donates: bool  # whether chunk donates its carry argument
    batched: dict = dataclasses.field(default_factory=dict)


def _get_dense_runner(spec: SolverSpec, problem: Problem, hp: Mapping):
    """Fetch (or compile) the dense runner for this (spec, problem, hp)."""
    key, guards = _runner_key(spec, problem, hp)

    def build() -> _DenseRunner:
        comm = DenseComm(problem.graph)
        fhp = _FactoryHP(hp, spec.static_hp)
        step_fn = spec.step(problem, fhp, comm)
        z_fn = spec.z_of(problem, fhp, comm)

        def run_chunk(state, idx_block, hp_dyn):
            runner_cache.DENSE.note_trace()  # trace-time only
            st, _ = jax.lax.scan(
                lambda s, i: (step_fn(s, i, hp_dyn), None), state, idx_block
            )
            return st

        def read(state, hp_dyn):
            runner_cache.DENSE.note_trace()
            return z_fn(state, hp_dyn)

        # donating the scan carry lets XLA reuse the state buffers in
        # place; CPU does not implement donation (it would only warn)
        donates = jax.default_backend() != "cpu"
        return _DenseRunner(
            init=lambda z0: spec.init(problem, fhp, z0),
            run_chunk=run_chunk,
            z_fn=z_fn,
            chunk=jax.jit(run_chunk, donate_argnums=(0,) if donates else ()),
            z_read=jax.jit(read),
            donates=donates,
        )

    return runner_cache.DENSE.get_or_build(key, guards, build)


@dataclasses.dataclass
class _ShardedRunner:
    """One compiled sharded-backend runner: shard_mapped scan + read-out.

    ``chunk``/``z_read`` are jitted ``shard_map`` wrappers over the same
    chunked scan the dense runner compiles — the solver step itself is
    shared; only the comm primitive differs. ``measured`` caches the
    HLO-derived per-iteration collective traffic, keyed by chunk length
    (each distinct length is its own compiled program).
    """

    init: Callable  # (z0) -> state, eager (global (N, ...) leaves)
    chunk: Callable  # jitted shard_map'd (state, idx_block, hp) -> state
    z_read: Callable  # jitted shard_map'd (state, hp) -> (N, D)
    mesh: Any
    measured: dict = dataclasses.field(default_factory=dict)

    def collective_costs(self, state, idx_block, hp_dyn) -> dict:
        """Per-iteration collective bytes/counts of this chunk's program.

        Lowers and compiles the chunk AOT once per chunk length and parses
        the optimized HLO (``launch.hlo_analysis``). The duplicate compile
        is absorbed by jax's persistent compilation cache
        (``launch.compile_cache``), enabled on ``import repro.core``.
        """
        from repro.launch.hlo_analysis import compiled_collective_costs

        length = int(idx_block.shape[0])
        if length not in self.measured:
            compiled = self.chunk.lower(state, idx_block, hp_dyn).compile()
            self.measured[length] = compiled_collective_costs(
                compiled, iterations=length
            )
        return self.measured[length]


def _node_partition_specs(state_proto, n: int):
    """Partition specs for a state pytree: leading-N leaves shard on "node".

    Every registered solver keeps its per-node state with a leading N axis
    (docs/solvers.md authoring contract); scalars (step counters) are
    replicated. A leaf that is neither is ambiguous — fail loudly rather
    than silently replicate what should be distributed.
    """

    def spec_of(leaf):
        if leaf.ndim >= 1 and leaf.shape[0] == n:
            return P("node", *([None] * (leaf.ndim - 1)))
        if leaf.ndim == 0:
            return P()
        raise ValueError(
            f"state leaf with shape {leaf.shape} has no leading node axis "
            f"(N = {n}) and is not a scalar; the sharded backend cannot "
            "place it (see docs/solvers.md)"
        )

    return jax.tree_util.tree_map(spec_of, state_proto)


def _get_sharded_runner(
    spec: SolverSpec, problem: Problem, hp: Mapping, mesh
):
    """Fetch (or compile) the shard_map runner for (spec, problem, hp, mesh)."""
    base_key, guards = _runner_key(spec, problem, hp)
    key = base_key + (runner_cache.mesh_fingerprint(mesh),)

    def build() -> _ShardedRunner:
        comm = ShardedComm(problem.graph, mesh)
        fhp = _FactoryHP(hp, spec.static_hp)
        step_fn = spec.step(problem, fhp, comm)
        z_fn = spec.z_of(problem, fhp, comm)
        n, D = problem.graph.n, problem.dim
        dt = problem.data.val.dtype

        state_proto = jax.eval_shape(
            lambda z: spec.init(problem, fhp, z),
            jax.ShapeDtypeStruct((n, D), dt),
        )
        state_specs = _node_partition_specs(state_proto, n)
        hp_specs = {k: P() for k in _dynamic_hp(spec, problem, hp)}

        def run_chunk(state, idx_block, hp_dyn):
            runner_cache.SHARDED.note_trace()  # trace-time only
            st, _ = jax.lax.scan(
                lambda s, i: (step_fn(s, i, hp_dyn), None), state, idx_block
            )
            return st

        def read(state, hp_dyn):
            runner_cache.SHARDED.note_trace()
            return z_fn(state, hp_dyn)

        # check_rep=False: the replication checker has no rule for `while`,
        # and mudag's traced-trip-count fori_loop (the no-retrace K sweep)
        # lowers to one. Nothing here relies on replication inference — all
        # specs are explicit, and dense<->sharded parity is pinned at 1e-12
        # by tests/multidevice/test_sharded_inner.py.
        chunk = jax.jit(
            _shard_map(
                run_chunk, mesh=mesh,
                in_specs=(state_specs, P(None, "node"), hp_specs),
                out_specs=state_specs,
                check_rep=False,
            )
        )
        z_read = jax.jit(
            _shard_map(
                read, mesh=mesh,
                in_specs=(state_specs, hp_specs),
                out_specs=P("node", None),
            )
        )
        return _ShardedRunner(
            init=lambda z0: spec.init(problem, fhp, z0),
            chunk=chunk,
            z_read=z_read,
            mesh=mesh,
        )

    return runner_cache.SHARDED.get_or_build(key, (*guards, mesh), build)


@dataclasses.dataclass
class _DenseFaultRunner:
    """One compiled fault-injecting dense runner.

    The per-iteration delivery masks ride as scan inputs (like the
    hyperparameter values ride as traced arguments), so ONE compiled
    runner serves every drop rate, seed, and staleness bound of the same
    fault STRUCTURE — only which families are active enters the cache
    key (``runner_cache.fault_fingerprint``). Straggler last-delivered
    buffers thread through the scan carry next to the solver state.
    """

    init: Callable  # (z0) -> (state, bufs), eager
    chunk: Callable  # jitted (state, bufs, idx, mask, deliv, hp)
    z_read: Callable  # jitted (state, hp) -> (N, D)
    n_slots: int  # straggler buffer slots per iteration
    make_bufs: Callable = None  # () -> fresh zero buffers (phase entry)


def _get_dense_fault_runner(
    spec: SolverSpec, problem: Problem, hp: Mapping,
    *, has_link: bool, has_straggler: bool,
):
    """Fetch (or compile) the fault-injecting dense runner."""
    base_key, guards = _runner_key(spec, problem, hp)
    key = base_key + (
        runner_cache.fault_fingerprint(has_link, has_straggler),
    )

    def build() -> _DenseFaultRunner:
        comm = FaultyDenseComm(problem.graph, has_link, has_straggler)
        fhp = _FactoryHP(hp, spec.static_hp)
        step_fn = spec.step(problem, fhp, comm)
        z_fn = spec.z_of(problem, fhp, comm)
        n, D = problem.graph.n, problem.dim
        dt = problem.data.val.dtype

        # abstract probe: discover the straggler buffer slot shapes (one
        # per matvec invocation in the step) before assembling the carry
        comm.begin_probe()
        hp_probe = _dynamic_hp(spec, problem, hp)
        state_proto = jax.eval_shape(
            lambda z: spec.init(problem, fhp, z),
            jax.ShapeDtypeStruct((n, D), dt),
        )
        jax.eval_shape(
            lambda s, i: step_fn(s, i, hp_probe),
            state_proto,
            jax.ShapeDtypeStruct((n,), jnp.int32),
        )
        slots = comm.end_probe()

        def make_bufs():
            # buffers start at the t=0 "last delivered" convention: the
            # delivery masks force a fresh send on each node's first
            # iteration, so these zeros are never read
            return tuple(jnp.zeros(s.shape, s.dtype) for s in slots)

        def init(z0):
            return spec.init(problem, fhp, z0), make_bufs()

        def run_chunk(state, bufs, idx_block, mask_block, deliv_block,
                      hp_dyn):
            runner_cache.DENSE.note_trace()  # trace-time only

            def body(carry, xs):
                st, bf = carry
                i_t, mask_t, deliv_t = xs
                comm.begin_step(mask_t, deliv_t, bf)
                st2 = step_fn(st, i_t, hp_dyn)
                return (st2, comm.end_step()), None

            (st, bf), _ = jax.lax.scan(
                body, (state, bufs), (idx_block, mask_block, deliv_block)
            )
            return st, bf

        def read(state, hp_dyn):
            runner_cache.DENSE.note_trace()
            return z_fn(state, hp_dyn)

        return _DenseFaultRunner(
            init=init,
            chunk=jax.jit(run_chunk),
            z_read=jax.jit(read),
            n_slots=len(slots),
            make_bufs=make_bufs,
        )

    return runner_cache.DENSE.get_or_build(key, guards, build)


@dataclasses.dataclass
class _ShardedFaultRunner:
    """Sharded runner with a per-iteration link-delivery mask scan input.

    Every edge-color ``ppermute`` still executes physically (dropping at
    the receiver), so the HLO-measured collective traffic is identical to
    the fault-free program; only the modeled ``doubles_received`` counts
    delivered messages (see ``comm.FaultyShardedComm``).
    """

    init: Callable
    chunk: Callable  # jitted shard_map'd (state, idx, mask, hp) -> state
    z_read: Callable
    mesh: Any
    measured: dict = dataclasses.field(default_factory=dict)

    def collective_costs(self, state, idx_block, mask_block, hp_dyn) -> dict:
        """Per-iteration collective bytes/counts (same as fault-free)."""
        from repro.launch.hlo_analysis import compiled_collective_costs

        length = int(idx_block.shape[0])
        if length not in self.measured:
            compiled = self.chunk.lower(
                state, idx_block, mask_block, hp_dyn
            ).compile()
            self.measured[length] = compiled_collective_costs(
                compiled, iterations=length
            )
        return self.measured[length]


def _get_sharded_fault_runner(
    spec: SolverSpec, problem: Problem, hp: Mapping, mesh
):
    """Fetch (or compile) the link-fault shard_map runner."""
    base_key, guards = _runner_key(spec, problem, hp)
    key = base_key + (
        runner_cache.mesh_fingerprint(mesh),
        runner_cache.fault_fingerprint(True, False),
    )

    def build() -> _ShardedFaultRunner:
        comm = FaultyShardedComm(problem.graph, mesh)
        fhp = _FactoryHP(hp, spec.static_hp)
        step_fn = spec.step(problem, fhp, comm)
        z_fn = spec.z_of(problem, fhp, comm)
        n, D = problem.graph.n, problem.dim
        dt = problem.data.val.dtype

        state_proto = jax.eval_shape(
            lambda z: spec.init(problem, fhp, z),
            jax.ShapeDtypeStruct((n, D), dt),
        )
        state_specs = _node_partition_specs(state_proto, n)
        hp_specs = {k: P() for k in _dynamic_hp(spec, problem, hp)}

        def run_chunk(state, idx_block, mask_block, hp_dyn):
            runner_cache.SHARDED.note_trace()  # trace-time only

            def body(st, xs):
                i_t, mask_t = xs
                comm.begin_step(mask_t)
                st2 = step_fn(st, i_t, hp_dyn)
                comm.end_step()
                return st2, None

            st, _ = jax.lax.scan(body, state, (idx_block, mask_block))
            return st

        def read(state, hp_dyn):
            runner_cache.SHARDED.note_trace()
            return z_fn(state, hp_dyn)

        # the mask is replicated: each device reads its own row inside
        # the matvec via comm.local (see FaultyShardedComm)
        chunk = jax.jit(
            _shard_map(
                run_chunk, mesh=mesh,
                in_specs=(
                    state_specs, P(None, "node"), P(None, None, None),
                    hp_specs,
                ),
                out_specs=state_specs,
                check_rep=False,
            )
        )
        z_read = jax.jit(
            _shard_map(
                read, mesh=mesh,
                in_specs=(state_specs, hp_specs),
                out_specs=P("node", None),
            )
        )
        return _ShardedFaultRunner(
            init=lambda z0: spec.init(problem, fhp, z0),
            chunk=chunk,
            z_read=z_read,
            mesh=mesh,
        )

    return runner_cache.SHARDED.get_or_build(key, (*guards, mesh), build)


def _get_batched_fns(runner: _DenseRunner, dyn_names) -> tuple:
    """(chunk, z_read) vmapped over a leading (grid/seed) axis, cached.

    hp entries map over axis 0 except ``lam`` (problem-level, shared);
    state and the index stream always carry the batch axis.
    """
    sig = tuple(sorted(dyn_names))
    if sig not in runner.batched:
        hp_axes = {k: (None if k == "lam" else 0) for k in sig}
        runner.batched[sig] = (
            jax.jit(jax.vmap(runner.run_chunk, in_axes=(0, 0, hp_axes))),
            jax.jit(jax.vmap(runner.z_fn, in_axes=(0, hp_axes))),
        )
    return runner.batched[sig]


# ---------------------------------------------------------------------------
# SolveResult + the shared metrics recorder
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SolveResult:
    """Uniform result of ``solve()`` for every method x comm backend.

    Record-point arrays all share the leading axis R = len(iters):
    ``dist2`` is empty when the problem has no cached ``z_star``;
    ``doubles_received``/``ints_received`` are *cumulative* per-node message
    counts at each record point (closed-form relay accounting for
    ``comm="sparse"``, the ``deg(n) * D`` dense-exchange model otherwise —
    index ints are zero for dense, the values travel as one dense block).
    ``state`` is the solver's final state pytree (``None`` for sparse runs:
    the relay engine returns trajectories, not solver internals);
    ``extras`` carries backend-specific outputs (sparse: ``z_trace``,
    ``recon_max_err``; sharded: the per-iteration ``collectives`` detail).

    ``measured_collective_bytes`` is populated by ``comm="sharded"`` only:
    cumulative bytes per device actually moved through collectives
    (``collective-permute`` etc.), measured from the compiled program's
    optimized HLO (``launch.hlo_analysis``) — the *measured* counterpart
    of the modeled ``doubles_received`` accounting.
    """

    method: str
    comm: str
    iters: np.ndarray  # (R,) iteration counts at record points
    dist2: np.ndarray  # (R,) mean_n ||z_n - z*||^2 (empty without z_star)
    consensus: np.ndarray  # (R,) mean_n ||z_n - zbar||^2
    doubles_received: np.ndarray  # (R, N) cumulative DOUBLEs per node
    ints_received: np.ndarray  # (R, N) cumulative index ints per node
    wall_time: float  # seconds in the solver (setup + scan + metrics)
    z: np.ndarray  # (N, D) final iterates
    state: Any  # final solver state pytree (None for sparse runs)
    zs: np.ndarray | None = None  # (R, N, D) snapshots if requested
    extras: dict = dataclasses.field(default_factory=dict)
    measured_collective_bytes: np.ndarray | None = None  # (R,) per device


def _cumulative_rounds(spec: SolverSpec, hp: Mapping, iters) -> np.ndarray:
    """Cumulative dense-exchange rounds per node at each record point.

    Default (hook unset): one neighbor exchange per iteration — the
    pre-PR-7 model. Methods with inner gossip loops (mudag) or skipped
    rounds (sliding) override via ``SolverSpec.comm_rounds``.
    """
    iters = np.asarray(iters)
    if spec.comm_rounds is None:
        return iters
    return np.rint(np.asarray(spec.comm_rounds(hp, iters))).astype(np.int64)


def _record_points(steps: int, record_every: int) -> list[int]:
    """Iteration counts to record at: every ``record_every``, plus the end."""
    pts = list(range(record_every, steps + 1, record_every))
    if not pts or pts[-1] != steps:
        pts.append(steps)
    return pts


class _Recorder:
    """The one metrics recorder shared by every method and comm backend.

    Replaces the per-method metric loops the legacy entrypoints each
    reimplemented (``core.dsba.run``'s chunked loop, ``baselines``'
    ``_metrics_loop``): push (iteration, iterates) pairs, read back the
    uniform record arrays.
    """

    def __init__(self, z_star: np.ndarray | None, keep_snapshots: bool):
        self.z_star = None if z_star is None else np.asarray(z_star)
        self.iters: list[int] = []
        self.dist2: list[float] = []
        self.consensus: list[float] = []
        self.zs: list[np.ndarray] | None = [] if keep_snapshots else None

    def push(self, it: int, z, z_star=None) -> None:
        """Record consensus / distance-to-z* of iterates ``z`` at step ``it``.

        ``z`` is (N, D), or (B, N, D) for a batched ``solve_many`` run — the
        metrics reduce over the trailing (N, D) axes either way. ``z_star``
        overrides the recorder's reference root for this push — churn
        phases measure dist2 against the CURRENT membership's own root
        (only used when the recorder was built with a root at all, so
        ``dist2`` stays rectangular).
        """
        z = np.asarray(z)
        zbar = z.mean(-2, keepdims=True)
        self.iters.append(it)
        self.consensus.append(np.mean(np.sum((z - zbar) ** 2, -1), -1))
        if self.z_star is not None:
            ref = self.z_star if z_star is None else np.asarray(z_star)
            self.dist2.append(
                np.mean(np.sum((z - ref) ** 2, -1), -1)
            )
        if self.zs is not None:
            self.zs.append(z)

    def arrays(self) -> tuple[np.ndarray, np.ndarray, np.ndarray, Any]:
        """(iters, dist2, consensus, zs) as numpy arrays.

        Scalar pushes give (R,) metrics and (R, N, D) snapshots; batched
        pushes give (B, R) metrics and (B, R, N, D) snapshots — the record
        axis always ends up adjacent to the values it indexes.
        """

        def stack_metric(vals):
            a = np.asarray(vals)  # (R,) or (R, B)
            return a if a.ndim == 1 else np.moveaxis(a, 0, 1)

        zs = None
        if self.zs:
            zs = np.stack(self.zs)  # (R, [B,] N, D)
            if zs.ndim == 4:
                zs = np.moveaxis(zs, 0, 1)
        return (
            np.asarray(self.iters),
            stack_metric(self.dist2) if self.dist2 else np.zeros(0),
            stack_metric(self.consensus),
            zs,
        )


# ---------------------------------------------------------------------------
# Dynamic networks: phase resolution for schedules and churn plans
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Phase:
    """One static stretch of a dynamic run: fixed graph, W and membership.

    ``entry`` says how the phase was entered (how to transform the carried
    state at its start): None (run start), "switch" (new W, same
    membership — state carried as-is, the restart-on-new-W argument),
    "kill"/"join" (elastic remap via ``ft.elastic.ElasticGossip``).
    ``row_map`` maps this phase's nodes into the global accounting rows
    (N0 original nodes + one row per joined node); ``cols`` maps them
    into the columns of the master (steps, N0) sample-index stream.
    """

    start: int
    end: int
    problem: Problem
    entry: str | None
    event: ChurnEvent | None
    row_map: np.ndarray
    cols: np.ndarray


def _graph_fp(g: Graph | None):
    """Value fingerprint of an optional graph (for the churn-child cache)."""
    return None if g is None else (g.n, g.edges)


def _w_fp(w) -> bytes | None:
    """Value fingerprint of an optional mixing matrix."""
    return None if w is None else np.ascontiguousarray(w).tobytes()


def _churn_kill_child(problem: Problem, event: ChurnEvent):
    """(survivor Problem, keep list) for a kill event; memoized on problem.

    The child shares the parent's data arrays by slicing, so the runner
    cache compiles the survivor system once per distinct event shape even
    when the same plan replays across a sweep (children are memoized in
    ``problem.__dict__`` keyed by the event's value fingerprint).
    """
    n = problem.graph.n
    dead = sorted({int(x) for x in event.nodes})
    for x in dead:
        if not 0 <= x < n:
            raise ValueError(
                f"kill event names node {x} outside the current "
                f"membership 0..{n - 1}"
            )
    if len(dead) >= n:
        raise ValueError("kill event leaves no survivors")
    keep = [i for i in range(n) if i not in set(dead)]
    cache = problem.__dict__.setdefault("_churn_cache", {})
    key = ("kill", tuple(dead), _graph_fp(event.graph), _w_fp(event.w))
    if key not in cache:
        g = event.graph
        if g is None:
            g = problem.graph.subgraph(keep)
        if g.n != len(keep):
            raise ValueError(
                f"kill event graph has {g.n} nodes, {len(keep)} survive"
            )
        if not g.is_connected():
            raise ValueError(
                "survivor graph after kill is disconnected; pass "
                "ChurnEvent(graph=...) with a connected replacement"
            )
        data = problem.data
        ka = np.asarray(keep)
        child_data = dataclasses.replace(
            data, idx=data.idx[ka], val=data.val[ka], y=data.y[ka]
        )
        lam = problem.lam
        if np.ndim(lam) > 0:
            lam = np.asarray(lam)[ka]
        child = Problem(
            spec=problem.spec, data=child_data, graph=g, w=event.w, lam=lam
        )
        if problem.z_star is not None and np.ndim(lam) == 0:
            child.solve_star()  # the survivor system's own root
        cache[key] = child
    return cache[key], keep


def _churn_join_child(problem: Problem, event: ChurnEvent) -> Problem:
    """Grown Problem for a join event; newcomers replicate ``seed_from``'s
    data shard (the same seeding ``ElasticGossip.grow`` applies to state).
    Memoized like the kill children.
    """
    n = problem.graph.n
    sf = int(event.seed_from)
    if not 0 <= sf < n:
        raise ValueError(f"join seed_from {sf} outside membership 0..{n - 1}")
    cache = problem.__dict__.setdefault("_churn_cache", {})
    key = ("join", int(event.n_new), sf, _graph_fp(event.graph), _w_fp(event.w))
    if key not in cache:
        g = event.graph  # required (validated by ChurnEvent)
        if g.n != n + event.n_new:
            raise ValueError(
                f"join event graph has {g.n} nodes, membership grows "
                f"{n} -> {n + event.n_new}"
            )
        if not g.is_connected():
            raise ValueError("graph after join is disconnected")
        data = problem.data

        def rep(a):
            seed = np.broadcast_to(
                a[sf][None], (event.n_new,) + a.shape[1:]
            )
            return np.concatenate([a, seed], axis=0)

        child_data = dataclasses.replace(
            data, idx=rep(data.idx), val=rep(data.val), y=rep(data.y)
        )
        lam = problem.lam
        if np.ndim(lam) > 0:
            lam = np.concatenate(
                [np.asarray(lam), np.full(event.n_new, np.asarray(lam)[sf])]
            )
        child = Problem(
            spec=problem.spec, data=child_data, graph=g, w=event.w, lam=lam
        )
        if problem.z_star is not None and np.ndim(lam) == 0:
            child.solve_star()  # duplicated shards shift the global root
        cache[key] = child
    return cache[key]


def _resolve_phases(
    problem: Problem, steps: int, fault_plan
) -> list[_Phase]:
    """Split [0, steps) into static phases from a schedule or a fault plan.

    A single static run is the degenerate one-phase case; ``solve()``
    routes it through the ordinary static code path bit-for-bit.
    """
    n0 = problem.graph.n
    rows = np.arange(n0)
    if fault_plan is None:
        segs = [s for s in problem.schedule if s[0] < steps]
        phases = []
        for k, (start, g, w) in enumerate(segs):
            end = segs[k + 1][0] if k + 1 < len(segs) else steps
            if g is problem.graph and w is problem.w:
                child = problem
            else:
                child = dataclasses.replace(
                    problem, graph=g, w=w, schedule=None
                )
            phases.append(
                _Phase(
                    start, end, child, None if k == 0 else "switch",
                    None, rows, rows,
                )
            )
        return phases

    plan = fault_plan
    if isinstance(plan, ChurnEvent):
        plan = ChurnPlan((plan,))
    elif isinstance(plan, (list, tuple)):
        plan = ChurnPlan(tuple(plan))
    if not isinstance(plan, ChurnPlan):
        raise TypeError(
            f"fault_plan must be a ChurnPlan / ChurnEvent(s), got "
            f"{type(plan).__name__}"
        )
    for e in plan.events:
        if not 0 < e.at < steps:
            raise ValueError(
                f"churn event at iteration {e.at} outside (0, {steps})"
            )
    phases = []
    cur, cols, next_row = problem, np.arange(n0), n0
    start, entry, ev = 0, None, None
    for e in plan.events:
        phases.append(_Phase(start, int(e.at), cur, entry, ev, rows, cols))
        if e.kind == "kill":
            cur, keep = _churn_kill_child(cur, e)
            keep = np.asarray(keep)
            rows, cols = rows[keep], cols[keep]
        else:
            cur = _churn_join_child(cur, e)
            rows = np.concatenate(
                [rows, np.arange(next_row, next_row + e.n_new)]
            )
            # newcomers replay seed_from's sample stream — consistent
            # with their replicated data shard
            cols = np.concatenate(
                [cols, np.full(e.n_new, cols[int(e.seed_from)])]
            )
            next_row += e.n_new
        start, entry, ev = int(e.at), e.kind, e
    phases.append(_Phase(start, steps, cur, entry, ev, rows, cols))
    return phases


def _schedule_extras(phases: list[_Phase]) -> list[dict]:
    """The per-phase record for ``SolveResult.extras["schedule"]``."""
    return [
        {
            "start": ph.start,
            "end": ph.end,
            "n": ph.problem.graph.n,
            "spectral_gap": spectral_gap(ph.problem.w),
            "entry": ph.entry,
        }
        for ph in phases
    ]


def _elastic_remap(state, phase: _Phase, n_prev: int, spec: SolverSpec):
    """Apply a phase's entry transform to the carried solver state.

    Kill/join entries remap leading-N leaves through ``ElasticGossip``
    and then apply the solver's ``reanchor`` hook: difference-form
    methods conserve a mean-drift invariant whose level encodes the OLD
    membership's mean operator — without re-running the t=0 anchor on
    the survivors, the run stays pinned at the old system's root.
    A "switch" entry carries state untouched (the invariant only uses
    double stochasticity of W, which every segment satisfies).
    """
    if phase.entry not in ("kill", "join"):
        return state  # "switch" carries state as-is (restart-on-new-W)
    # lazy import: ft.elastic pulls in the training stack via core.gossip
    from repro.core.gossip import GossipConfig
    from repro.ft.elastic import ElasticGossip

    eg = ElasticGossip(GossipConfig(n_pods=n_prev))
    if phase.entry == "kill":
        dead = sorted({int(x) for x in phase.event.nodes})
        state, _ = eg.shrink(state, dead)
    else:
        state, _ = eg.grow(
            state, int(phase.event.n_new), int(phase.event.seed_from)
        )
    if spec.reanchor is not None:
        state = spec.reanchor(state)
    return state


def _rounds_at(spec: SolverSpec, hp: Mapping, t: int):
    """Cumulative dense-exchange rounds per node after ``t`` global steps.

    Global, not per-phase: solver step counters carry across phase
    boundaries, so e.g. sliding's communication cadence is a function of
    the global iteration. A phase's increment is the difference of this
    at its endpoints.
    """
    return _cumulative_rounds(spec, hp, np.asarray([t]))[0]


# ---------------------------------------------------------------------------
# Fault-mask resolution and delivered-only accounting
# ---------------------------------------------------------------------------


def _static_fault_masks(plan, graph, steps: int, start: int = 0):
    """Resolve a plan's link/straggler masks for one static phase.

    Returns ``(link_mask, strag_mask)`` with all-delivered masks
    collapsed to ``None`` — the caller routes a mask-free run through
    the PLAIN compiled runner, which makes a p=0 plan bit-equal to a
    plan-free run by construction (no masked arithmetic at all).
    """
    link_mask = strag_mask = None
    if plan is not None and plan.link is not None:
        m = link_delivered_mask(plan.link, graph, steps, start=start)
        if not bool(m.all()):
            link_mask = m
    if plan is not None and plan.straggler is not None:
        m = straggler_delivered_mask(
            plan.straggler, graph.n, steps, start=start
        )
        if not bool(m.all()):
            strag_mask = m
    return link_mask, strag_mask


def _fault_accounting(spec, hp, problem, link_mask, strag_mask, steps, iters):
    """Delivered-only doubles (R, N) plus the extras["faults"] record.

    The closed-form model charges one (D,)-double message per DELIVERED
    directed edge per exchange round: per-iteration delivered in-message
    counts from the masks, scaled by the method's rounds-per-iteration
    hook. With all-True masks this reduces exactly to the standard
    ``rounds * degree * D`` dense model.
    """
    D = problem.dim
    rr = _cumulative_rounds(spec, hp, np.arange(steps + 1))
    rdiff = np.diff(rr)  # rounds run during iteration t
    d_in = delivered_in_messages(problem.graph, link_mask, strag_mask, steps)
    per_step = rdiff[:, None] * d_in * D  # (steps, N)
    cumsum = np.cumsum(per_step, axis=0)
    doubles = cumsum[np.asarray(iters) - 1]  # (R, N)
    deg = np.asarray(problem.graph.degrees, dtype=np.int64)
    injected = int(rr[steps] * deg.sum())
    delivered = int((rdiff * d_in.sum(axis=1)).sum())
    extras = {
        "injected_messages": injected,
        "delivered_messages": delivered,
        "drop_rate": (
            0.0 if injected == 0 else 1.0 - delivered / injected
        ),
    }
    return doubles, extras


def _ckpt_meta(method: str, comm: str, record_every: int, rec) -> dict:
    """The JSON metadata committed with each ``solve()`` checkpoint.

    The recorder's scalars ride in the manifest (Python floats round-trip
    bit-exactly through ``repr`` in JSON), so resume can rebuild the
    record history without shape-templating run-length-dependent arrays.
    """
    return {
        "method": method,
        "comm": comm,
        "record_every": int(record_every),
        "rec_iters": [int(x) for x in rec.iters],
        "rec_dist2": [float(x) for x in rec.dist2],
        "rec_consensus": [float(x) for x in rec.consensus],
    }


# ---------------------------------------------------------------------------
# solve(): the single entrypoint
# ---------------------------------------------------------------------------


def solve(
    problem: Problem,
    method: str = "dsba",
    comm: str = "dense",
    *,
    steps: int,
    record_every: int = 50,
    seed: int = 0,
    z0: np.ndarray | None = None,
    indices: np.ndarray | None = None,
    keep_snapshots: bool = False,
    comm_options: dict | None = None,
    checkpoint: CheckpointSpec | None = None,
    resume: str | None = None,
    **hyperparams,
) -> SolveResult:
    """Run ``method`` on ``problem`` over ``comm`` and return a SolveResult.

    Compilation is amortized across calls: the jitted runner is fetched
    from the keyed compiled-runner cache (``core.runner_cache``) and the
    hyperparameter values (plus ``lam``) are traced arguments, so repeated
    calls on the same problem shape — a sweep — skip XLA entirely. For a
    whole grid in one call see ``solve_many``.

    method: a registered solver name (``available_solvers()`` lists them).
    comm: ``"dense"`` (single-device neighbor exchange, the mixing
        matmul), ``"sparse"`` (the paper's delta relay — methods with a
        sparse backend only; see ``SolverSpec.supports_sparse_comm``), or
        ``"sharded"`` (one graph node per device of a ``"node"``-axis
        mesh; mixing runs as real ``collective-permute`` exchange and the
        result carries HLO-measured collective bytes).
    steps / record_every: iterations to run / metric recording period (the
        final iteration is always recorded).
    seed: RNG seed for the per-node sample draws when ``indices`` is not
        given; ``indices`` is an explicit (steps, N) stream for replayable
        runs (shared across methods and comm backends).
    z0: (N, D) starting point, default zeros.
    comm_options: backend passthrough for ``comm="sparse"`` (``engine``,
        ``verify``, ``use_pallas``) and ``comm="sharded"`` (``mesh``, a
        prebuilt ``"node"``-axis mesh; defaults to
        ``launch.mesh.make_node_mesh(N)``). Every backend additionally
        accepts ``fault_plan`` — a ``repro.ft.FaultPlan`` (or a bare
        ``ChurnEvent``/``ChurnPlan``) composing node churn, link faults,
        and stragglers; families gate on the solver's capability record
        (``supports_churn`` / ``supports_link_faults`` /
        ``supports_stragglers`` — stragglers are dense-only), and
        ``extras["faults"]`` reports injected-vs-delivered counts with
        the doubles accounting charging delivered traffic only.
    checkpoint: a ``repro.ckpt.CheckpointSpec`` — snapshot solver state +
        recorder at record boundaries every ``checkpoint.every``
        iterations (dense and sparse backends).
    resume: a checkpoint directory — restore the newest committed
        snapshot and continue BIT-EQUAL to an uninterrupted run.
    **hyperparams: solver hyperparameter overrides; the valid keys are the
        solver's ``defaults`` keys (anything else raises ``TypeError``).
    """
    spec = get_solver(method)
    if comm not in COMM_BACKENDS:
        raise ValueError(f"unknown comm backend {comm!r}; one of {COMM_BACKENDS}")
    # peek fault_plan before schema validation so an unsupported (method,
    # comm) x fault-family combination surfaces as the typed CapabilityError
    plan = as_fault_plan((comm_options or {}).get("fault_plan"))
    churn_plan = plan.churn if plan is not None else None
    want_link = plan is not None and plan.link is not None
    want_strag = plan is not None and plan.straggler is not None
    multi = problem.schedule is not None and len(problem.schedule) > 1
    if problem.schedule is not None and plan is not None:
        raise ValueError(
            "a graph schedule and a fault_plan cannot be combined in one "
            "run; encode the W changes as schedule segments instead"
        )
    _check_capability(
        spec, comm, problem.spec.kind,
        schedule=multi,
        churn=churn_plan is not None,
        per_node_lam=np.ndim(problem.lam) > 0,
        link_faults=want_link,
        stragglers=want_strag,
    )
    opts = _validate_options(comm, comm_options)
    opts.pop("fault_plan", None)
    if churn_plan is not None and keep_snapshots:
        raise ValueError(
            "keep_snapshots is unavailable with a fault_plan: snapshot "
            "shapes change across churn events"
        )
    if churn_plan is not None:
        # node ids are relabeled across membership segments, so explicit
        # node/edge targets in the other families become ambiguous
        if want_link and plan.link.edges is not None:
            raise ValueError(
                "scheduled link faults (edges=) cannot be combined with "
                "node churn: node ids are relabeled across membership "
                "changes; use a probabilistic LinkFault(p=...)"
            )
        if want_strag and plan.straggler.nodes is not None:
            raise ValueError(
                "a straggler node subset (nodes=) cannot be combined with "
                "node churn: node ids are relabeled across membership "
                "changes; use a global StragglerSpec(p=...)"
            )
    if steps < 1:
        raise ValueError("steps must be >= 1")
    if record_every < 1:
        raise ValueError("record_every must be >= 1")
    if checkpoint is not None and not isinstance(checkpoint, CheckpointSpec):
        raise TypeError(
            f"checkpoint must be a CheckpointSpec, got "
            f"{type(checkpoint).__name__}"
        )
    if checkpoint is not None or resume is not None:
        if comm == "sharded":
            raise ValueError(
                "checkpoint/resume supports comm='dense' and comm='sparse'; "
                "the sharded backend is not checkpointable"
            )
        if problem.schedule is not None:
            raise ValueError(
                "checkpoint/resume cannot be combined with a graph schedule "
                "(phase boundaries are not checkpoint boundaries)"
            )
        if plan is not None:
            raise ValueError(
                "checkpoint/resume cannot be combined with a fault_plan: "
                "fault masks and straggler buffers are not part of the "
                "snapshot schema"
            )
        if keep_snapshots:
            raise ValueError(
                "checkpoint/resume does not support keep_snapshots"
            )
    if (
        checkpoint is not None
        and comm == "dense"
        and checkpoint.every % record_every != 0
    ):
        raise ValueError(
            f"checkpoint.every={checkpoint.every} must be a multiple of "
            f"record_every={record_every} on the dense backend (snapshots "
            "happen at record boundaries)"
        )

    hp = dict(spec.defaults)
    unknown = set(hyperparams) - set(hp)
    if unknown:
        raise TypeError(
            f"{method!r} got unknown hyperparameters {sorted(unknown)}; "
            f"accepts {sorted(hp)}"
        )
    hp.update(hyperparams)

    data = problem.data
    n, D = data.n_nodes, problem.dim
    dt = data.val.dtype
    if z0 is None:
        z0 = np.zeros((n, D), dtype=dt)
    if indices is None:
        indices = draw_indices(steps, n, data.q, seed)
    indices = np.asarray(indices)
    if indices.ndim != 2 or indices.shape[0] < steps or indices.shape[1] != n:
        raise ValueError(
            f"indices must be (>= steps, N) = (>={steps}, {n}), "
            f"got {indices.shape}"
        )
    # dynamic-network resolution: a schedule or fault_plan becomes a list
    # of static phases; the single-phase case routes through the ordinary
    # static path below (bit-for-bit — only extras gains the segment log)
    phases = None
    sched_x = None
    if problem.schedule is not None or churn_plan is not None:
        phases = _resolve_phases(problem, steps, churn_plan)
        sched_x = _schedule_extras(phases)
        if len(phases) == 1:
            problem = phases[0].problem
            phases = None

    pts = _record_points(steps, record_every)
    rec = _Recorder(problem.z_star, keep_snapshots)

    if comm == "sparse":
        if phases is not None:
            if any(ph.entry in ("kill", "join") for ph in phases):
                return _solve_sparse_churn(
                    spec, method, phases, hp, steps, pts, rec, indices,
                    z0, opts, sched_x, plan,
                )
            return _solve_sparse_schedule(
                spec, method, phases, hp, steps, pts, rec, indices, z0,
                opts, sched_x,
            )
        fault_x = None
        if want_link:
            sent = source_sent_mask(plan.link, problem.graph, steps)
            n_bcast = steps * problem.graph.n
            fault_x = {
                "injected_broadcasts": int(n_bcast),
                "delivered_broadcasts": int(sent.sum()),
                "drop_rate": 1.0 - float(sent.sum()) / n_bcast,
            }
            if not bool(sent.all()):
                # all-delivered plans route through the plain (byte-
                # identical) relay program — p=0 is bit-equal by routing
                opts["sent_mask"] = sent
        mgr = None
        if checkpoint is not None:
            mgr = CheckpointManager(
                checkpoint.directory, keep_last=checkpoint.keep_last
            )
            meta = {"method": method, "comm": comm}
            opts["ckpt_every"] = int(checkpoint.every)
            opts["ckpt_save"] = (
                lambda t_done, tree: mgr.save(
                    t_done, tree, metadata=meta, async_=False
                )
            )
        if resume is not None:
            step_r, meta_r, leaves = load_checkpoint(resume)
            if step_r is None:
                raise ValueError(
                    f"no committed checkpoint to resume in {resume!r}"
                )
            for key, val in (("method", method), ("comm", comm)):
                if meta_r.get(key) != val:
                    raise ValueError(
                        f"checkpoint {key}={meta_r.get(key)!r} does not "
                        f"match the resuming run's {key}={val!r}"
                    )
            if step_r > steps:
                raise ValueError(
                    f"checkpoint at step {step_r} is beyond steps={steps}; "
                    "resume with steps >= the checkpointed iteration"
                )
            opts["resume"] = (int(step_r), leaves)
        t0 = time.perf_counter()
        sres = spec.sparse_run(problem, hp, steps, indices, z0, opts)
        wall = time.perf_counter() - t0
        for pt in pts:
            rec.push(pt, sres.z_trace[pt])
        iters, dist2, cons, zs = rec.arrays()
        sel = np.asarray(pts) - 1
        extras = {
            "z_trace": sres.z_trace,
            "recon_max_err": sres.recon_max_err,
        }
        if fault_x is not None:
            extras["faults"] = fault_x
        if sched_x is not None:
            extras["schedule"] = sched_x
        return SolveResult(
            method=method,
            comm=comm,
            iters=iters,
            dist2=dist2,
            consensus=cons,
            doubles_received=sres.doubles_received[sel],
            ints_received=sres.ints_received[sel],
            wall_time=wall,
            z=sres.z_trace[-1],
            state=None,
            zs=zs,
            extras=extras,
        )

    if phases is not None:
        return _solve_phased(
            spec, method, comm, phases, hp, steps, pts, rec, indices, z0,
            opts, sched_x, plan,
        )

    if comm == "sharded":
        # ---- sharded backend: shard_map runner, measured collectives -----
        mesh = opts.pop("mesh", None)
        t0 = time.perf_counter()
        if mesh is None:
            from repro.launch.mesh import make_node_mesh

            mesh = make_node_mesh(n)
        hp_dyn = _dynamic_hp(spec, problem, hp)
        idx_j = jnp.asarray(indices[:steps], jnp.int32)
        link_mask, _ = _static_fault_masks(plan, problem.graph, steps)
        fault_x = None
        if link_mask is not None:
            # link-fault runner: every edge-color ppermute still executes
            # (measured bytes are identical); receivers drop masked edges
            # and redirect the lost mixing mass to their own iterate
            frunner = _get_sharded_fault_runner(spec, problem, hp, mesh)
            lm = jnp.asarray(link_mask)
            state = frunner.init(jnp.asarray(z0))
            costs = frunner.collective_costs(
                state, idx_j[: pts[0]], lm[: pts[0]], hp_dyn
            )
            prev = 0
            z_final = None
            for pt in pts:
                state = frunner.chunk(
                    state, idx_j[prev:pt], lm[prev:pt], hp_dyn
                )
                prev = pt
                z_final = frunner.z_read(state, hp_dyn)
                rec.push(pt, z_final)
            wall = time.perf_counter() - t0
            iters, dist2, cons, zs = rec.arrays()
            doubles, fault_x = _fault_accounting(
                spec, hp, problem, link_mask, None, steps, iters
            )
        else:
            runner = _get_sharded_runner(spec, problem, hp, mesh)
            state = runner.init(jnp.asarray(z0))
            costs = runner.collective_costs(state, idx_j[: pts[0]], hp_dyn)
            prev = 0
            z_final = None
            for pt in pts:
                state = runner.chunk(state, idx_j[prev:pt], hp_dyn)
                prev = pt
                z_final = runner.z_read(state, hp_dyn)
                rec.push(pt, z_final)
            wall = time.perf_counter() - t0
            iters, dist2, cons, zs = rec.arrays()
            per_node = dense_doubles_per_iter(problem.graph, D)  # (N,)
            rounds = _cumulative_rounds(spec, hp, iters)
            doubles = rounds[:, None] * per_node[None, :]
            if plan is not None and want_link:
                _, fault_x = _fault_accounting(
                    spec, hp, problem, None, None, steps, iters
                )
        extras = {
            "collectives": costs,
            "mesh_devices": int(mesh.shape["node"]),
        }
        if fault_x is not None:
            extras["faults"] = fault_x
        if sched_x is not None:
            extras["schedule"] = sched_x
        return SolveResult(
            method=method,
            comm=comm,
            iters=iters,
            dist2=dist2,
            consensus=cons,
            doubles_received=doubles,
            ints_received=np.zeros_like(doubles),
            wall_time=wall,
            z=np.asarray(z_final),
            state=state,
            zs=zs,
            extras=extras,
            # per-program measurement: collectives inside a traced-bound
            # inner loop (mudag's K gossip rounds) are counted once per
            # outer iteration — the modeled `doubles_received` carries the
            # K-aware accounting (docs/solvers.md)
            measured_collective_bytes=iters * costs["bytes_per_iter"],
        )

    # ---- dense backend: cached compiled runner, hp as traced arguments ----
    t0 = time.perf_counter()
    hp_dyn = _dynamic_hp(spec, problem, hp)
    idx_j = jnp.asarray(indices[:steps], jnp.int32)
    link_mask, strag_mask = _static_fault_masks(plan, problem.graph, steps)

    if link_mask is not None or strag_mask is not None:
        # fault-injecting runner: the per-iteration masks ride as scan
        # inputs; one compiled program per active-family STRUCTURE
        frunner = _get_dense_fault_runner(
            spec, problem, hp,
            has_link=link_mask is not None,
            has_straggler=strag_mask is not None,
        )
        lm = (
            jnp.asarray(link_mask)
            if link_mask is not None
            else jnp.ones((steps, 1, 1), bool)  # inert placeholder xs
        )
        sm = (
            jnp.asarray(strag_mask)
            if strag_mask is not None
            else jnp.ones((steps, 1), bool)
        )
        state, bufs = frunner.init(jnp.asarray(z0))
        prev = 0
        z_final = None
        for pt in pts:
            state, bufs = frunner.chunk(
                state, bufs, idx_j[prev:pt], lm[prev:pt], sm[prev:pt],
                hp_dyn,
            )
            prev = pt
            z_final = frunner.z_read(state, hp_dyn)
            rec.push(pt, z_final)
        wall = time.perf_counter() - t0
        iters, dist2, cons, zs = rec.arrays()
        doubles, fault_x = _fault_accounting(
            spec, hp, problem, link_mask, strag_mask, steps, iters
        )
        extras = {"faults": fault_x}
        if sched_x is not None:
            extras["schedule"] = sched_x
        return SolveResult(
            method=method,
            comm=comm,
            iters=iters,
            dist2=dist2,
            consensus=cons,
            doubles_received=doubles,
            ints_received=np.zeros_like(doubles),
            wall_time=wall,
            z=np.asarray(z_final),
            state=state,
            zs=zs,
            extras=extras,
        )

    runner = _get_dense_runner(spec, problem, hp)
    mgr = None
    if checkpoint is not None:
        mgr = CheckpointManager(
            checkpoint.directory, keep_last=checkpoint.keep_last
        )
    start = 0
    state = None
    if resume is not None:
        state, start = _restore_dense(
            resume, runner, rec, method=method, comm=comm,
            record_every=record_every, steps=steps, z0=z0,
        )
    if state is None:
        state = runner.init(jnp.asarray(z0))
        if runner.donates:
            # init factories may alias leaves (dsba's z/z_prev are the same
            # array at t=0); donation rejects duplicate buffers, so de-alias
            # the initial carry once — later carries are distinct scan
            # outputs
            state = jax.tree_util.tree_map(
                lambda x: jnp.array(x, copy=True), state
            )
    prev = start
    z_final = None
    for pt in pts:
        if pt <= start:
            continue  # already covered by the restored checkpoint
        state = runner.chunk(state, idx_j[prev:pt], hp_dyn)
        prev = pt
        z_final = runner.z_read(state, hp_dyn)
        rec.push(pt, z_final)
        if mgr is not None and pt % checkpoint.every == 0:
            mgr.save(
                pt, {"state": state},
                metadata=_ckpt_meta(method, comm, record_every, rec),
            )
    if mgr is not None:
        mgr.wait()
    if z_final is None:
        # resumed at (or past) the final record point: nothing to re-run
        z_final = runner.z_read(state, hp_dyn)
    wall = time.perf_counter() - t0

    iters, dist2, cons, zs = rec.arrays()
    per_node = dense_doubles_per_iter(problem.graph, D)  # (N,)
    rounds = _cumulative_rounds(spec, hp, iters)
    doubles = rounds[:, None] * per_node[None, :]
    extras = {} if sched_x is None else {"schedule": sched_x}
    if plan is not None and (want_link or want_strag):
        # p=0 plan: masks collapsed to the plain runner (bit-equal by
        # routing), but the delivered-vs-injected record is still reported
        _, fault_x = _fault_accounting(
            spec, hp, problem, None, None, steps, iters
        )
        extras["faults"] = fault_x
    return SolveResult(
        method=method,
        comm=comm,
        iters=iters,
        dist2=dist2,
        consensus=cons,
        doubles_received=doubles,
        ints_received=np.zeros_like(doubles),
        wall_time=wall,
        z=np.asarray(z_final),
        state=state,
        zs=zs,
        extras=extras,
    )


def _restore_dense(resume, runner, rec, *, method, comm, record_every,
                   steps, z0):
    """Restore a dense ``solve()`` from the newest committed checkpoint.

    Returns ``(state, start)``. The recorder history rides in the
    manifest metadata as Python floats (bit-exact JSON round-trip); the
    solver state restores strictly against a template built by the
    runner's own init (shapes are run-length independent).
    """
    step_r, meta, _ = load_checkpoint(resume)
    if step_r is None:
        raise ValueError(f"no committed checkpoint to resume in {resume!r}")
    for key, val in (("method", method), ("comm", comm),
                     ("record_every", record_every)):
        if meta.get(key) != val:
            raise ValueError(
                f"checkpoint {key}={meta.get(key)!r} does not match the "
                f"resuming run's {key}={val!r}"
            )
    if step_r > steps:
        raise ValueError(
            f"checkpoint at step {step_r} is beyond steps={steps}; "
            "resume with steps >= the checkpointed iteration"
        )
    template = runner.init(jnp.asarray(z0))
    tree, _ = restore_checkpoint(resume, {"state": template}, step=step_r)
    rec.iters.extend(int(x) for x in meta["rec_iters"])
    rec.dist2.extend(float(x) for x in meta["rec_dist2"])
    rec.consensus.extend(float(x) for x in meta["rec_consensus"])
    return tree["state"], int(step_r)


def _solve_phased(
    spec, method, comm, phases, hp, steps, pts, rec, indices, z0, opts,
    sched_x, plan=None,
) -> SolveResult:
    """Dense/sharded execution of a multi-phase (dynamic-network) run.

    Each phase runs through its own cached runner (edge colorings /
    meshes re-derived per phase); the solver state is carried across
    boundaries — as-is for a W switch (restart-on-new-W,
    docs/algorithm.md), elastically remapped for churn. Communication
    accounting folds per-phase increments into global per-row cumulative
    counts: rows are the N0 original nodes plus one row per joined node
    (``extras["churn_rows"]`` when membership changed).

    ``plan``: an optional ``FaultPlan`` whose link/straggler families
    compose with the churn phases — each phase resolves its own delivery
    masks against the phase graph (seeds fold the phase's global start
    iteration, so the mask stream is one continuous draw), straggler
    buffers re-zero at membership boundaries (the first post-churn
    iteration always delivers fresh), and the delivered-only accounting
    folds into the same per-row cumulative counts.
    """
    t0 = time.perf_counter()
    base = phases[0].problem
    D = base.dim
    total_rows = max(int(ph.row_map.max()) for ph in phases) + 1
    record_set = set(pts)
    cum = np.zeros(total_rows)
    doubles_rows: list[np.ndarray] = []
    measured: list[float] = []
    measured_base = 0.0
    costs0 = None
    mesh_opt = opts.get("mesh")
    mesh_devices = None
    state = None
    bufs = None
    z_final = None
    n_prev = base.graph.n
    injected_tot = delivered_tot = 0
    want_fault = plan is not None and (
        plan.link is not None or plan.straggler is not None
    )
    for ph in phases:
        p = ph.problem
        n_ph = p.graph.n
        seg = ph.end - ph.start
        if state is not None:
            state = _elastic_remap(state, ph, n_prev, spec)
        link_mask, strag_mask = _static_fault_masks(
            plan, p.graph, seg, start=ph.start
        )
        faulty = link_mask is not None or strag_mask is not None
        if comm == "sharded":
            if mesh_opt is not None and mesh_opt.shape["node"] == n_ph:
                mesh = mesh_opt
            else:
                from repro.launch.mesh import make_node_mesh

                mesh = make_node_mesh(n_ph)
            if faulty:
                runner = _get_sharded_fault_runner(spec, p, hp, mesh)
            else:
                runner = _get_sharded_runner(spec, p, hp, mesh)
            if mesh_devices is None:
                mesh_devices = int(mesh.shape["node"])
        elif faulty:
            runner = _get_dense_fault_runner(
                spec, p, hp,
                has_link=link_mask is not None,
                has_straggler=strag_mask is not None,
            )
        else:
            runner = _get_dense_runner(spec, p, hp)
        hp_dyn = _dynamic_hp(spec, p, hp)
        if state is None:
            if comm == "dense" and faulty:
                state, bufs = runner.init(jnp.asarray(z0))
            else:
                state = runner.init(jnp.asarray(z0))
            if comm == "dense" and not faulty and runner.donates:
                state = jax.tree_util.tree_map(
                    lambda x: jnp.array(x, copy=True), state
                )
        elif comm == "dense" and faulty:
            # straggler buffers do not survive membership remaps; the
            # phase's delivery masks force fresh sends at its first
            # iteration, so re-zeroed buffers are never read
            bufs = runner.make_bufs()
        if faulty:
            lm_ph = (
                jnp.asarray(link_mask)
                if link_mask is not None
                else jnp.ones((seg, 1, 1), bool)
            )
            sm_ph = (
                jnp.asarray(strag_mask)
                if strag_mask is not None
                else jnp.ones((seg, 1), bool)
            )
        rdiff_ph = np.diff(
            _cumulative_rounds(spec, hp, np.arange(ph.start, ph.end + 1))
        )
        d_in_ph = delivered_in_messages(p.graph, link_mask, strag_mask, seg)
        cum_ph = np.cumsum(rdiff_ph[:, None] * d_in_ph * D, axis=0)
        deg_ph = np.asarray(p.graph.degrees, dtype=np.int64)
        injected_tot += int(rdiff_ph.sum() * deg_ph.sum())
        delivered_tot += int((rdiff_ph * d_in_ph.sum(axis=1)).sum())
        costs = None
        marks = sorted(
            {pt for pt in pts if ph.start < pt <= ph.end} | {ph.end}
        )
        prev = ph.start
        for mk in marks:
            idx_blk = jnp.asarray(
                indices[prev:mk][:, ph.cols], jnp.int32
            )
            if comm == "sharded" and costs is None:
                if faulty:
                    costs = runner.collective_costs(
                        state, idx_blk, lm_ph[prev - ph.start:mk - ph.start],
                        hp_dyn,
                    )
                else:
                    costs = runner.collective_costs(state, idx_blk, hp_dyn)
                if costs0 is None:
                    costs0 = costs
            if not faulty:
                state = runner.chunk(state, idx_blk, hp_dyn)
            elif comm == "sharded":
                state = runner.chunk(
                    state, idx_blk,
                    lm_ph[prev - ph.start:mk - ph.start], hp_dyn,
                )
            else:
                state, bufs = runner.chunk(
                    state, bufs, idx_blk,
                    lm_ph[prev - ph.start:mk - ph.start],
                    sm_ph[prev - ph.start:mk - ph.start], hp_dyn,
                )
            prev = mk
            if mk in record_set:
                z_final = runner.z_read(state, hp_dyn)
                rec.push(mk, z_final, z_star=p.z_star)
                snap = cum.copy()
                snap[ph.row_map] += cum_ph[mk - ph.start - 1]
                doubles_rows.append(snap)
                if comm == "sharded":
                    measured.append(
                        measured_base
                        + (mk - ph.start) * costs["bytes_per_iter"]
                    )
        cum[ph.row_map] += cum_ph[-1]
        if comm == "sharded":
            measured_base += (ph.end - ph.start) * costs["bytes_per_iter"]
        n_prev = n_ph
    wall = time.perf_counter() - t0
    iters, dist2, cons, zs = rec.arrays()
    doubles = np.stack(doubles_rows)
    extras: dict = {"schedule": sched_x}
    if total_rows != base.graph.n or any(
        ph.entry in ("kill", "join") for ph in phases
    ):
        extras["churn_rows"] = total_rows
    if want_fault:
        extras["faults"] = {
            "injected_messages": injected_tot,
            "delivered_messages": delivered_tot,
            "drop_rate": (
                0.0 if injected_tot == 0
                else 1.0 - delivered_tot / injected_tot
            ),
        }
    if comm == "sharded":
        extras["collectives"] = costs0
        extras["mesh_devices"] = mesh_devices
    return SolveResult(
        method=method,
        comm=comm,
        iters=iters,
        dist2=dist2,
        consensus=cons,
        doubles_received=doubles,
        ints_received=np.zeros_like(doubles),
        wall_time=wall,
        z=np.asarray(z_final),
        state=state,
        zs=zs,
        extras=extras,
        measured_collective_bytes=(
            np.asarray(measured) if comm == "sharded" else None
        ),
    )


def _solve_sparse_schedule(
    spec, method, phases, hp, steps, pts, rec, indices, z0, opts, sched_x,
) -> SolveResult:
    """Sparse-relay execution of a graph schedule: chained segment runs.

    Each segment re-derives the relay protocol (reconstruction waves,
    broadcast trees) for its own graph; the solver state chains through
    ``SparseRunResult.state`` -> the next segment's ``state0`` (the
    restart path charges the extra z0-resync flood —
    ``core.sparse_comm``). Message accounting concatenates with each
    segment offset by the previous segment's final cumulative counts.
    """
    t0 = time.perf_counter()
    st = None
    z_traces = []
    doubles_parts, ints_parts = [], []
    d_off = i_off = 0  # int: keeps the concatenated counts integer-typed
    recon = []
    for k, ph in enumerate(phases):
        seg_steps = ph.end - ph.start
        o = dict(opts)
        if k == 0:
            sres = spec.sparse_run(
                ph.problem, hp, seg_steps,
                indices[ph.start:ph.end], z0, o,
            )
        else:
            o["state0"] = st
            sres = spec.sparse_run(
                ph.problem, hp, seg_steps,
                indices[ph.start:ph.end], None, o,
            )
        st = sres.state
        z_traces.append(sres.z_trace if k == 0 else sres.z_trace[1:])
        doubles_parts.append(sres.doubles_received + d_off)
        ints_parts.append(sres.ints_received + i_off)
        d_off = doubles_parts[-1][-1]
        i_off = ints_parts[-1][-1]
        recon.append(sres.recon_max_err)
    wall = time.perf_counter() - t0
    z_trace = np.concatenate(z_traces)  # (steps + 1, N, D)
    doubles_all = np.concatenate(doubles_parts)  # (steps, N) cumulative
    ints_all = np.concatenate(ints_parts)
    rc = np.asarray(recon, dtype=np.float64)
    recon_max = (
        float(np.nanmax(rc)) if not np.all(np.isnan(rc)) else float("nan")
    )
    for pt in pts:
        rec.push(pt, z_trace[pt])
    iters, dist2, cons, zs = rec.arrays()
    sel = np.asarray(pts) - 1
    return SolveResult(
        method=method,
        comm="sparse",
        iters=iters,
        dist2=dist2,
        consensus=cons,
        doubles_received=doubles_all[sel],
        ints_received=ints_all[sel],
        wall_time=wall,
        z=z_trace[-1],
        state=None,
        zs=zs,
        extras={
            "z_trace": z_trace,
            "recon_max_err": recon_max,
            "schedule": sched_x,
        },
    )


def _solve_sparse_churn(
    spec, method, phases, hp, steps, pts, rec, indices, z0, opts, sched_x,
    plan,
) -> SolveResult:
    """Sparse-relay execution of node churn: per-membership-segment relays.

    Each membership segment re-derives the relay protocol tables
    (reconstruction waves, DD delta ring, broadcast trees) for its own
    graph and chains through ``run_sparse(..., state0=)``. The carried
    state is elastically remapped at each boundary (``_elastic_remap``
    shrinks/grows the SAGA tables and applies the solver's ``reanchor``
    — DSBA resets its step counter to 0, so the segment re-runs the
    eq. 31 anchored update against the surviving/augmented membership
    and the restart path floods the remapped z0 once). Accounting folds
    per-segment delivered counts into global per-row cumulative totals,
    exactly like the dense churn path (rows = N0 originals + joiners).
    """
    t0 = time.perf_counter()
    base = phases[0].problem
    total_rows = max(int(ph.row_map.max()) for ph in phases) + 1
    cum_d = np.zeros(total_rows, dtype=np.int64)
    cum_i = np.zeros(total_rows, dtype=np.int64)
    out_d: list[np.ndarray] = []
    out_i: list[np.ndarray] = []
    recon = []
    injected_tot = delivered_tot = 0
    want_link = plan is not None and plan.link is not None
    st = None
    z_final = None
    for k, ph in enumerate(phases):
        p = ph.problem
        seg = ph.end - ph.start
        o = dict(opts)
        if want_link:
            sent = source_sent_mask(plan.link, p.graph, seg, start=ph.start)
            injected_tot += seg * p.graph.n
            delivered_tot += int(sent.sum())
            if not bool(sent.all()):
                o["sent_mask"] = sent
        idx_seg = indices[ph.start:ph.end][:, ph.cols]
        if st is None:
            sres = spec.sparse_run(p, hp, seg, idx_seg, z0, o)
        else:
            st = _elastic_remap(st, ph, n_prev, spec)
            o["state0"] = st
            sres = spec.sparse_run(p, hp, seg, idx_seg, None, o)
        st = sres.state
        n_prev = p.graph.n
        for pt in pts:
            if ph.start < pt <= ph.end:
                lt = pt - ph.start
                rec.push(pt, sres.z_trace[lt], z_star=p.z_star)
                snap_d = cum_d.copy()
                snap_d[ph.row_map] += sres.doubles_received[lt - 1]
                snap_i = cum_i.copy()
                snap_i[ph.row_map] += sres.ints_received[lt - 1]
                out_d.append(snap_d)
                out_i.append(snap_i)
        cum_d[ph.row_map] += sres.doubles_received[seg - 1]
        cum_i[ph.row_map] += sres.ints_received[seg - 1]
        recon.append(sres.recon_max_err)
        z_final = sres.z_trace[-1]
    wall = time.perf_counter() - t0
    rc = np.asarray(recon, dtype=np.float64)
    recon_max = (
        float(np.nanmax(rc)) if not np.all(np.isnan(rc)) else float("nan")
    )
    iters, dist2, cons, zs = rec.arrays()
    extras: dict = {
        "recon_max_err": recon_max,
        "schedule": sched_x,
        "churn_rows": total_rows,
    }
    if want_link:
        extras["faults"] = {
            "injected_broadcasts": injected_tot,
            "delivered_broadcasts": delivered_tot,
            "drop_rate": (
                0.0 if injected_tot == 0
                else 1.0 - delivered_tot / injected_tot
            ),
        }
    return SolveResult(
        method=method,
        comm="sparse",
        iters=iters,
        dist2=dist2,
        consensus=cons,
        doubles_received=np.stack(out_d),
        ints_received=np.stack(out_i),
        wall_time=wall,
        z=z_final,
        state=st,
        zs=zs,
        extras=extras,
    )


# ---------------------------------------------------------------------------
# solve_many(): the batched sweep entrypoint
# ---------------------------------------------------------------------------


def solve_many(
    problem: Problem,
    method: str = "dsba",
    comm: str = "dense",
    *,
    steps: int,
    grid: list[Mapping[str, float]] | None = None,
    seeds: list[int] | None = None,
    record_every: int = 50,
    seed: int = 0,
    z0: np.ndarray | None = None,
    indices: np.ndarray | None = None,
    keep_snapshots: bool = False,
    comm_options: dict | None = None,
    **common_hp,
) -> SolveResult:
    """Run a hyperparameter/seed sweep as ONE batched computation.

    The sweep axis B is ``len(grid)`` (per-entry hyperparameter overrides),
    ``len(seeds)`` (per-entry sample streams), or both (paired — equal
    lengths required). On the dense backend the whole grid is vmapped over
    a leading batch axis of the cached compiled runner: one executable,
    one scan, every grid point advancing in lockstep.

    ``comm="sparse"`` batches too: the relay scan is vmapped over (seed,
    alpha) with the closed-form message accounting applied per run after
    the scan, bit-identical to sequential calls. Fallback to the cached
    *sequential* path (one warm ``solve()`` per entry — still compile-free
    after the first) happens when the grid is not vmappable:

    - ``comm="sparse"`` with ``engine="reference"`` (the per-observer
      oracle loop) or a method without a batched sparse backend;
    - ``comm="sharded"`` — one mesh program advances one run; sweeps
      reuse the warm compiled runner sequentially;
    - a grid entry overrides a ``static_hp`` (structural, must recompile).

    Returns one ``SolveResult`` whose per-run arrays carry a leading B
    axis: ``dist2``/``consensus`` are (B, R), ``doubles_received``/
    ``ints_received`` (B, R, N), ``z`` (B, N, D), ``zs`` (B, R, N, D).
    ``iters`` stays (R,) — record points are shared. ``extras`` records
    ``grid``, ``seeds`` and whether the batched path ran (``"batched"``).

    indices: optional explicit sample streams — (>= steps, N) shared by
    every entry, or (B, >= steps, N) per entry. Defaults to
    ``draw_indices`` per entry seed (``seeds[b]``, else the shared
    ``seed``).
    """
    spec = get_solver(method)
    if comm not in COMM_BACKENDS:
        raise ValueError(f"unknown comm backend {comm!r}; one of {COMM_BACKENDS}")
    fault_plan = as_fault_plan((comm_options or {}).get("fault_plan"))
    if problem.schedule is not None and fault_plan is not None:
        raise ValueError(
            "a graph schedule and a fault_plan cannot be combined in one run"
        )
    _check_capability(
        spec, comm, problem.spec.kind,
        schedule=problem.schedule is not None and len(problem.schedule) > 1,
        churn=fault_plan is not None and fault_plan.churn is not None,
        per_node_lam=np.ndim(problem.lam) > 0,
        link_faults=fault_plan is not None and fault_plan.link is not None,
        stragglers=(
            fault_plan is not None and fault_plan.straggler is not None
        ),
    )
    _validate_options(comm, comm_options)
    # dynamic-network and fault-injected runs are per-entry sequential:
    # the vmapped batched paths assume one static fault-free (graph, W,
    # membership) for the whole scan
    dynamic = problem.schedule is not None or fault_plan is not None
    if grid is None and seeds is None:
        raise ValueError("solve_many needs a grid, seeds, or both")
    entries = [dict(e) for e in grid] if grid is not None else None
    if entries is not None and seeds is not None and len(entries) != len(seeds):
        raise ValueError(
            f"grid ({len(entries)}) and seeds ({len(seeds)}) must pair up"
        )
    n_runs = len(entries) if entries is not None else len(seeds)
    if n_runs < 1:
        raise ValueError("solve_many needs at least one grid/seed entry")
    if entries is None:
        entries = [{} for _ in range(n_runs)]
    seeds_list = list(seeds) if seeds is not None else [seed] * n_runs

    known = set(spec.defaults)
    for ent in (common_hp, *entries):
        unknown = set(ent) - known
        if unknown:
            raise TypeError(
                f"{method!r} got unknown hyperparameters {sorted(unknown)}; "
                f"accepts {sorted(known)}"
            )
    merged = [dict(spec.defaults, **common_hp, **e) for e in entries]

    data = problem.data
    n, q = data.n_nodes, data.q
    idx_b = _sweep_indices(indices, n_runs, steps, n, q, seeds_list)

    ragged = any(k in spec.static_hp for e in entries for k in e)
    if comm == "sparse" and not ragged and not dynamic:
        res = _solve_many_sparse_batched(
            problem, method, spec, steps=steps, record_every=record_every,
            z0=z0, keep_snapshots=keep_snapshots, comm_options=comm_options,
            merged=merged, entries=entries, seeds=seeds_list, idx_b=idx_b,
        )
        if res is not None:
            return res
    if comm != "dense" or ragged or dynamic:
        return _solve_many_sequential(
            problem, method, comm, steps=steps, record_every=record_every,
            z0=z0, keep_snapshots=keep_snapshots, comm_options=comm_options,
            merged=merged, entries=entries, seeds=seeds_list, idx_b=idx_b,
        )

    # ---- batched path: vmap the cached runner over the grid axis ----------
    if steps < 1:
        raise ValueError("steps must be >= 1")
    if record_every < 1:
        raise ValueError("record_every must be >= 1")
    D = problem.dim
    dt = data.val.dtype
    if z0 is None:
        z0 = np.zeros((n, D), dtype=dt)

    t0 = time.perf_counter()
    base_hp = dict(spec.defaults, **common_hp)
    runner = _get_dense_runner(spec, problem, base_hp)
    dyn_names = tuple(_dynamic_hp(spec, problem, base_hp))
    chunk_b, z_read_b = _get_batched_fns(runner, dyn_names)

    # hp arrays in the DATA dtype so batched arithmetic promotes exactly
    # like the sequential path's weak-typed python-float scalars
    hp_dyn = {
        k: np.asarray([m[k] for m in merged], dtype=dt)
        for k in dyn_names if k != "lam"
    }
    if "lam" in dyn_names:
        hp_dyn["lam"] = (
            float(problem.lam)
            if np.ndim(problem.lam) == 0
            else np.asarray(problem.lam, dtype=dt)
        )

    state0 = runner.init(jnp.asarray(z0))
    state = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_runs,) + x.shape), state0
    )
    idx_j = jnp.asarray(idx_b[:, :steps], jnp.int32)
    pts = _record_points(steps, record_every)
    rec = _Recorder(problem.z_star, keep_snapshots)
    prev = 0
    z_final = None
    for pt in pts:
        state = chunk_b(state, idx_j[:, prev:pt], hp_dyn)
        prev = pt
        z_final = z_read_b(state, hp_dyn)
        rec.push(pt, z_final)
    wall = time.perf_counter() - t0

    iters, dist2, cons, zs = rec.arrays()
    per_node = dense_doubles_per_iter(problem.graph, D)  # (N,)
    # rounds may differ per grid entry (e.g. a mudag gossip_rounds sweep)
    rounds_b = np.stack([_cumulative_rounds(spec, m, iters) for m in merged])
    doubles = rounds_b[:, :, None] * per_node[None, None, :]
    return SolveResult(
        method=method,
        comm=comm,
        iters=iters,
        dist2=dist2,
        consensus=cons,
        doubles_received=doubles,
        ints_received=np.zeros_like(doubles),
        wall_time=wall,
        z=np.asarray(z_final),
        state=state,
        zs=zs,
        extras={"batched": True, "grid": entries, "seeds": seeds_list},
    )


def _sweep_indices(indices, n_runs, steps, n, q, seeds_list) -> np.ndarray:
    """(B, >= steps, N) sample streams for a sweep, drawn or validated."""
    if indices is None:
        return np.stack(
            [draw_indices(steps, n, q, s) for s in seeds_list]
        )
    indices = np.asarray(indices)
    if indices.ndim == 2:
        indices = np.broadcast_to(
            indices[None], (n_runs,) + indices.shape
        )
    if (
        indices.ndim != 3
        or indices.shape[0] != n_runs
        or indices.shape[1] < steps
        or indices.shape[2] != n
    ):
        raise ValueError(
            f"indices must be (>= steps, N) or (B, >= steps, N) = "
            f"({n_runs}, >={steps}, {n}), got {indices.shape}"
        )
    return indices


def _solve_many_sparse_batched(
    problem, method, spec, *, steps, record_every, z0, keep_snapshots,
    comm_options, merged, entries, seeds, idx_b,
) -> SolveResult | None:
    """One vmapped relay scan for the whole sparse sweep, or None to decline.

    Declines (returns ``None``, sending ``solve_many`` to the sequential
    fallback) when the method has no batched sparse backend or the backend
    itself declines — e.g. ``engine="reference"``, the per-observer oracle
    loop. Results are bit-identical to the sequential path (the relay's
    message accounting is closed-form over the per-run nnz log, outside
    the scan). Capability (sparse backend present) is checked by
    ``solve_many`` before routing here.
    """
    if spec.sparse_run_many is None:
        return None
    if steps < 1:
        raise ValueError("steps must be >= 1")
    if record_every < 1:
        raise ValueError("record_every must be >= 1")
    t0 = time.perf_counter()
    sres = spec.sparse_run_many(
        problem, merged, steps, idx_b, z0, dict(comm_options or {})
    )
    if sres is None:
        return None
    wall = time.perf_counter() - t0
    pts = _record_points(steps, record_every)
    rec = _Recorder(problem.z_star, keep_snapshots)
    for pt in pts:
        rec.push(pt, np.stack([r.z_trace[pt] for r in sres]))
    iters, dist2, cons, zs = rec.arrays()
    sel = np.asarray(pts) - 1
    return SolveResult(
        method=method,
        comm="sparse",
        iters=iters,
        dist2=dist2,
        consensus=cons,
        doubles_received=np.stack([r.doubles_received[sel] for r in sres]),
        ints_received=np.stack([r.ints_received[sel] for r in sres]),
        wall_time=wall,
        z=np.stack([r.z_trace[-1] for r in sres]),
        state=None,
        zs=zs,
        extras={
            "batched": True,
            "grid": entries,
            "seeds": seeds,
            "per_run_extras": [
                {"z_trace": r.z_trace, "recon_max_err": r.recon_max_err}
                for r in sres
            ],
        },
    )


def _solve_many_sequential(
    problem, method, comm, *, steps, record_every, z0, keep_snapshots,
    comm_options, merged, entries, seeds, idx_b,
) -> SolveResult:
    """The documented fallback: one warm cached ``solve()`` per grid entry."""
    results = [
        solve(
            problem, method, comm, steps=steps, record_every=record_every,
            z0=z0, indices=idx_b[b], keep_snapshots=keep_snapshots,
            comm_options=comm_options, **merged[b],
        )
        for b in range(len(merged))
    ]
    r0 = results[0]
    return SolveResult(
        method=method,
        comm=comm,
        iters=r0.iters,
        dist2=np.stack([r.dist2 for r in results]),
        consensus=np.stack([r.consensus for r in results]),
        doubles_received=np.stack([r.doubles_received for r in results]),
        ints_received=np.stack([r.ints_received for r in results]),
        wall_time=sum(r.wall_time for r in results),
        z=np.stack([r.z for r in results]),
        state=[r.state for r in results],
        zs=(
            np.stack([r.zs for r in results])
            if keep_snapshots else None
        ),
        extras={
            "batched": False,
            "grid": entries,
            "seeds": seeds,
            "per_run_extras": [r.extras for r in results],
        },
    )


# ---------------------------------------------------------------------------
# Registry entries: DSBA / DSA (Algorithm 1 + Remark 5.1)
# ---------------------------------------------------------------------------


def _dsba_placeholder_cfg(problem: Problem, method: str) -> DSBAConfig:
    """Step config with hp placeholders (alpha/lam arrive traced at runtime).

    ``init_state`` reads only ``cfg.spec``; ``make_step_fn`` substitutes the
    traced values via its ``hp`` argument before any arithmetic touches the
    placeholders.
    """
    return DSBAConfig(spec=problem.spec, alpha=0.0, lam=0.0, method=method)


def _make_dsba_family(method: str, default_alpha: float) -> SolverSpec:
    """Registry entry for the stochastic family: shared step, both comms."""

    def init(problem, hp, z0):
        """SAGA-table warm start (Algorithm 1 line 1) at ``z0``."""
        return _dsba_init_state(
            _dsba_placeholder_cfg(problem, method), problem.data, z0
        )

    def step(problem, hp, comm):
        """Device-resident Algorithm-1 step via ``dsba.make_step_fn``.

        The mixing terms route through ``comm.matvec`` and the baked data
        arrays through ``comm.local`` inside ``make_step_fn``.
        """
        raw = _dsba_make_step_fn(
            _dsba_placeholder_cfg(problem, method), problem.data, problem.w,
            comm=comm,
        )

        def fn(state, i_t, hp_run):
            return raw(
                state, i_t,
                hp={"alpha": hp_run["alpha"], "lam": hp_run["lam"]},
            )

        return fn

    def z_of(problem, hp, comm):
        """Iterates live directly on the state."""
        return lambda state, hp_run: state.z

    def sparse_run(problem, hp, steps, indices, z0, options):
        """The Section-5.1 delta relay (``core.sparse_comm.run_sparse``)."""
        return _sparse_comm.run_sparse(
            DSBAConfig(
                spec=problem.spec, alpha=hp["alpha"], lam=problem.lam,
                method=method,
            ),
            problem.data,
            problem.graph,
            problem.w,
            steps,
            indices,
            z0=z0,
            **options,
        )

    def sparse_run_many(problem, merged, steps, idx_b, z0, options):
        """Vmapped relay sweep (``run_sparse_many``); declines "reference"."""
        options = dict(options)
        if options.pop("engine", "vectorized") != "vectorized":
            return None  # the oracle loop is per-run by construction
        return _sparse_comm.run_sparse_many(
            DSBAConfig(
                spec=problem.spec, alpha=merged[0]["alpha"],
                lam=problem.lam, method=method,
            ),
            problem.data,
            problem.graph,
            problem.w,
            steps,
            idx_b,
            [hp["alpha"] for hp in merged],
            z0=z0,
            **options,
        )

    return SolverSpec(
        name=method,
        init=init,
        step=step,
        z_of=z_of,
        defaults={"alpha": default_alpha},
        sparse_run=sparse_run,
        sparse_run_many=sparse_run_many,
        # the paper's monotone-operator framing is family-agnostic: the
        # SAGA table stores scalars for any linear-predictor operator,
        # including the bilinear saddle family (resolvent in closed form)
        problem_families=FAMILIES,
        # the fixed point z* = consensus root is W-independent and the
        # state is all leading-N leaves -> schedules, churn and per-node
        # regularization are all sound (docs/algorithm.md, docs/solvers.md)
        supports_schedule=True,
        supports_churn=True,
        supports_per_node_lam=True,
        # after a churn remap, re-enter the t=0 branch: the t>=1
        # difference recursion is stationary at ANY consensus point with
        # settled tables — only the step-0 psi (-alpha*phibar injection)
        # targets the new membership's root. Warm tables and iterates
        # are kept; phibar rows are node-local, so slicing/padding them
        # is exact (docs/algorithm.md).
        reanchor=lambda st: dataclasses.replace(
            st, step=jnp.zeros((), jnp.int32)
        ),
    )


register_solver(_make_dsba_family("dsba", default_alpha=0.5))
register_solver(_make_dsba_family("dsa", default_alpha=0.2))


# ---------------------------------------------------------------------------
# Registry entries: deterministic baselines (EXTRA / DLM / SSDA)
# ---------------------------------------------------------------------------


def _full_operator(spec: OperatorSpec, feats, labels, comm):
    """G(Z, lam): (N, D) -> (N, D), full local operator incl. regularizer.

    ``lam`` is a call-time argument (traced in the compiled runners), not a
    baked constant — a regularization-path sweep reuses one executable.
    The node-indexed data constants are read through ``comm.local`` at
    trace time, so under the sharded backend each device computes only its
    own node's operator (the whole map is node-local — no communication).
    """
    t = spec.tail_dim
    d = feats.shape[-1]

    def G(Z, lam):
        fe = comm.local(feats)
        la = comm.local(labels)
        head, tail = Z[:, :d], Z[:, d:]
        u = jnp.einsum("nqd,nd->nq", fe, head)
        tails = jnp.broadcast_to(tail[:, None, :], u.shape + (t,))
        g, tail_out = spec.coeff_and_tail(u, la, tails)
        out_head = jnp.einsum("nq,nqd->nd", g, fe) / fe.shape[1]
        if t:
            out = jnp.concatenate([out_head, tail_out.mean(1)], axis=1)
        else:
            out = out_head
        return out + lam * Z

    return G


def _dense_setup(problem: Problem):
    """(feats, labels, G-factory inputs) shared by the dense baselines."""
    feats = jnp.asarray(problem.data.dense())
    labels = jnp.asarray(problem.data.y)
    return feats, labels


def _extra_init(problem, hp, z0):
    """EXTRA state: (z, z_prev, g_prev, t) with a scan-compatible counter."""
    zeros = jnp.zeros_like(z0)
    return (z0, zeros, zeros, jnp.zeros((), jnp.int32))


def _extra_step(problem, hp, comm):
    """EXTRA (Shi et al. 2015a), eq. (47) form with first-step special case."""
    feats, labels = _dense_setup(problem)
    G = _full_operator(problem.spec, feats, labels, comm)
    dt = feats.dtype
    w_mix = comm.matvec(problem.w, dt)
    wt_mix = comm.matvec(w_tilde(problem.w), dt)

    def step(carry, i_t, hp_run):
        alpha, lam = hp_run["alpha"], hp_run["lam"]
        z, z_prev, g_prev, t = carry
        g = G(z, lam)
        z1 = jnp.where(
            t == 0,
            w_mix(z) - alpha * g,
            z + w_mix(z) - wt_mix(z_prev) - alpha * (g - g_prev),
        )
        return (z1, z, g, t + 1)

    return step


def _dlm_init(problem, hp, z0):
    """DLM state: (z, dual multipliers)."""
    return (z0, jnp.zeros_like(z0))


def _dlm_step(problem, hp, comm):
    """DLM (Ling et al. 2015): linearized decentralized ADMM."""
    feats, labels = _dense_setup(problem)
    G = _full_operator(problem.spec, feats, labels, comm)
    dt = feats.dtype
    lap_mix = comm.matvec(problem.graph.laplacian, dt)
    deg = jnp.asarray(problem.graph.degrees, dt)[:, None]

    def step(carry, i_t, hp_run):
        c, beta, lam = hp_run["c"], hp_run["beta"], hp_run["lam"]
        z, lam_dual = carry
        deg_l = comm.local(deg)
        grad_aug = G(z, lam) + lam_dual + 2.0 * c * lap_mix(z)
        z1 = z - grad_aug / (2.0 * c * deg_l + beta)
        lam1 = lam_dual + c * lap_mix(z1)
        return (z1, lam1)

    return step


# Single-slot share of the grad f* closure: the runner-cache build invokes
# the step and z_of factories back to back on the same (problem, hp), and
# the build is real work (Gram + N Cholesky factorizations for ridge). The
# slot holds the problem strongly, so the identity check cannot alias a
# recycled id; the value snapshots (data, lam, spec) at build time so
# mutating the problem invalidates the hit. lam is baked here — which is
# why the ssda SolverSpec sets ``bake_lam`` (the runner cache keys on lam).
_SSDA_CG_CACHE: list = []


def _ssda_conj_grad(problem: Problem, inner_newton: int):
    """grad f*_n read-out: Cholesky for ridge, damped Newton otherwise.

    Built once per (problem, inner_newton) — see ``_SSDA_CG_CACHE``.
    """
    for p, data_ref, lam_ref, spec_ref, inner_ref, cg in _SSDA_CG_CACHE:
        if (p is problem and p.data is data_ref and p.lam == lam_ref
                and p.spec == spec_ref and inner_ref == inner_newton):
            return cg
    cg = _build_ssda_conj_grad(problem, inner_newton)
    _SSDA_CG_CACHE[:] = [
        (problem, problem.data, problem.lam, problem.spec, inner_newton, cg)
    ]
    return cg


def _build_ssda_conj_grad(problem: Problem, inner_newton: int):
    """Construct the grad f*_n closure (the cached work behind the cache).

    The returned ``conj_grad(S, local)`` reads its baked per-node constants
    (Cholesky factors / features) through ``local`` — the comm backend's
    node-block view — so one cached closure serves both the dense runner
    (identity) and the sharded runner (this device's rows).
    """
    spec, lam = problem.spec, problem.lam
    if spec.tail_dim:
        raise NotImplementedError(
            "SSDA requires grad f*; the paper notes it does not apply to AUC"
        )
    feats = jnp.asarray(problem.data.dense())  # (N, q, d)
    labels = jnp.asarray(problem.data.y)
    n, q, d = feats.shape
    dt = feats.dtype

    if spec.kind == "ridge":
        # grad f_n(x) = A^T(Ax - y)/q + lam x ; grad f*_n(s) solves it = s
        gram = jnp.einsum("nqd,nqe->nde", feats, feats) / q
        gram = gram + lam * jnp.eye(d, dtype=dt)[None]
        rhs0 = jnp.einsum("nqd,nq->nd", feats, labels) / q
        chol = jax.vmap(jnp.linalg.cholesky)(gram)

        def conj_grad(S, local):  # (N, d) -> (N, d): x_n = grad f*_n(s_n)
            return jax.vmap(
                lambda L, r: jax.scipy.linalg.cho_solve((L, True), r)
            )(local(chol), S + local(rhs0))

    else:

        def conj_grad(S, local):
            # invert grad f_n via damped Newton with explicit per-node jacobians
            def one(fe, la, s):
                def gn(x):
                    u = fe @ x
                    g, _ = spec.coeff_and_tail(u, la, jnp.zeros((q, 0), dt))
                    return fe.T @ g / q + lam * x

                x = jnp.zeros((d,), dt)
                jac = jax.jacfwd(gn)
                for _ in range(inner_newton):
                    x = x - jnp.linalg.solve(jac(x), gn(x) - s)
                return x

            return jax.vmap(one)(local(feats), local(labels), S)

    return conj_grad


def _ssda_init(problem, hp, z0):
    """SSDA state: (momentum iterate, previous momentum iterate) on the dual."""
    n, d = problem.data.n_nodes, problem.data.d
    dt = jnp.asarray(problem.data.val).dtype
    zeros = jnp.zeros((n, d), dt)
    return (zeros, zeros)


def _ssda_step(problem, hp, comm):
    """SSDA (Scaman et al. 2017): accelerated gradient ascent on the dual."""
    conj_grad = _ssda_conj_grad(problem, int(hp["inner_newton"]))
    n = problem.data.n_nodes
    dt = jnp.asarray(problem.data.val).dtype
    imw_mix = comm.matvec(np.eye(n) - np.asarray(problem.w), dt)

    def step(carry, i_t, hp_run):
        eta, momentum = hp_run["eta"], hp_run["momentum"]
        m, m_prev = carry
        v = m + momentum * (m - m_prev)
        x = conj_grad(-v, comm.local)  # primal: grad f*(-(U Lambda)_n)
        m1 = v + eta * imw_mix(x)
        return (m1, m)

    return step


def _ssda_z_of(problem, hp, comm):
    """Primal read-out grad f*(-m): a real computation, not a field access.

    Jitted by the runner cache alongside the step — no inner jit here.
    """
    conj_grad = _ssda_conj_grad(problem, int(hp["inner_newton"]))
    return lambda state, hp_run: conj_grad(-state[0], comm.local)


register_solver(
    SolverSpec(
        name="extra",
        init=_extra_init,
        step=_extra_step,
        z_of=lambda problem, hp, comm: lambda state, hp_run: state[0],
        defaults={"alpha": 0.3},
    )
)
register_solver(
    SolverSpec(
        name="dlm",
        init=_dlm_init,
        step=_dlm_step,
        z_of=lambda problem, hp, comm: lambda state, hp_run: state[0],
        defaults={"c": 0.3, "beta": 1.0},
    )
)
register_solver(
    SolverSpec(
        name="ssda",
        init=_ssda_init,
        step=_ssda_step,
        z_of=_ssda_z_of,
        defaults={"eta": 0.05, "momentum": 0.5, "inner_newton": 8},
        # inner_newton is a Python loop count (structural); lam is baked
        # into the Cholesky / Newton factorization of grad f*.
        static_hp=("inner_newton",),
        bake_lam=True,
        # SSDA needs grad f*; the paper notes it does not apply to the
        # saddle families (AUC) — solve() now reports that as a typed
        # CapabilityError instead of a factory-time NotImplementedError.
        problem_families=MINIMIZATION_FAMILIES,
    )
)


# ---------------------------------------------------------------------------
# Registry entries: accelerated consensus (MUDAG) + communication sliding
# ---------------------------------------------------------------------------


def _fastmix_weight(w: np.ndarray) -> float:
    """The FastMix / Chebyshev momentum weight for mixing matrix ``w``.

    Liu & Morse (2011) accelerated gossip, as used by Mudag (Ye et al.
    2020):  x^{k+1} = (1 + eta_w) W x^k - eta_w x^{k-1}  with

        eta_w = (1 - sqrt(1 - sigma^2)) / (1 + sqrt(1 - sigma^2)),

    sigma the second-largest eigenvalue magnitude of W. Computed from the
    (static, numpy) mixing matrix at factory time — W's content is part of
    the runner cache key, so the baked scalar can never go stale.
    """
    eigs = np.sort(np.abs(np.linalg.eigvalsh(np.asarray(w, dtype=np.float64))))
    sigma = float(eigs[-2]) if eigs.size > 1 else 0.0
    sigma = min(max(sigma, 0.0), 1.0 - 1e-12)
    root = float(np.sqrt(1.0 - sigma * sigma))
    return (1.0 - root) / (1.0 + root)


def _make_fastmix(comm, w, dt):
    """K-round accelerated gossip through ``comm.matvec`` (K is traced).

    The Chebyshev combination (1 + eta_w) W x - eta_w x_prev has W's graph
    support plus the diagonal, so each inner round is exactly one
    ``comm.matvec`` application (one edge-colored ppermute sweep under the
    sharded backend) plus local arithmetic. ``lax.fori_loop`` with a
    traced trip count lowers to a while loop — K never triggers a
    retrace, which is what makes no-retrace K-sweeps possible.
    """
    w_mix = comm.matvec(w, dt)
    eta_w = _fastmix_weight(w)

    def fastmix(x, k):
        def body(_, carry):
            cur, prev = carry
            nxt = (1.0 + eta_w) * w_mix(cur) - eta_w * prev
            return (nxt, cur)

        cur, _ = jax.lax.fori_loop(0, k, body, (x, x))
        return cur

    return fastmix


def _mudag_init(problem, hp, z0):
    """MUDAG state: (x, y, tracked s, previous gradient, step counter)."""
    zeros = jnp.zeros_like(z0)
    return (z0, z0, zeros, zeros, jnp.zeros((), jnp.int32))


def _mudag_step(problem, hp, comm):
    """Mudag (Ye et al. 2020): Nesterov descent + K-round FastMix gossip.

    Gradient tracking keeps mean(s) = mean(G(y)) (both the tracking update
    and FastMix preserve the node mean), Nesterov momentum gives the
    sqrt(kappa) iteration rate, and each iteration spends 2K gossip rounds
    (one FastMix for the tracked gradient, one for the iterate) — reported
    by the ``comm_rounds`` hook as 2K dense exchanges per iteration.
    ``gossip_rounds`` arrives runtime-traced (cast to int32 here), so a
    K-sweep reuses one compiled runner.
    """
    feats, labels = _dense_setup(problem)
    G = _full_operator(problem.spec, feats, labels, comm)
    fastmix = _make_fastmix(comm, problem.w, feats.dtype)

    def step(carry, i_t, hp_run):
        eta, beta = hp_run["eta"], hp_run["momentum"]
        lam = hp_run["lam"]
        k = jnp.asarray(hp_run["gossip_rounds"]).astype(jnp.int32)
        x, y, s, g_prev, t = carry
        g = G(y, lam)
        s1 = fastmix(jnp.where(t == 0, g, s + g - g_prev), k)
        x1 = fastmix(y - eta * s1, k)
        y1 = x1 + beta * (x1 - x)
        return (x1, y1, s1, g, t + 1)

    return step


def _sliding_init(problem, hp, z0):
    """Sliding state: (z, tracked s, previous gradient, step counter)."""
    zeros = jnp.zeros_like(z0)
    return (z0, zeros, zeros, jnp.zeros((), jnp.int32))


def _sliding_step(problem, hp, comm):
    """Communication sliding (Lan-Lee-Zhou 2017 style, tracking variant).

    Multiple local primal steps per communication round: the mixing matvec
    is applied only when ``t % comm_period == 0`` (a ``jnp.where`` select,
    so one compiled step serves every phase); between rounds the nodes
    descend on their tracked gradient locally. Gradient tracking makes the
    periodic-mixing sequence B-connected, so the iterates still converge
    to the exact consensus root. The ``comm_rounds`` hook reports only the
    rounds actually taken — 2*ceil(iters/period) — which is the point:
    skipped rounds must show up as savings in ``doubles_received``. (Under
    the sharded backend the ppermute still executes physically every
    iteration and its result is discarded off-round; the *measured* bytes
    therefore reflect the SPMD program, the modeled doubles the algorithm.)
    """
    feats, labels = _dense_setup(problem)
    G = _full_operator(problem.spec, feats, labels, comm)
    w_mix = comm.matvec(problem.w, feats.dtype)

    def step(carry, i_t, hp_run):
        alpha, lam = hp_run["alpha"], hp_run["lam"]
        period = jnp.asarray(hp_run["comm_period"]).astype(jnp.int32)
        z, s, g_prev, t = carry
        g = G(z, lam)
        s1 = jnp.where(t == 0, g, s + g - g_prev)
        on_round = (t % period) == 0
        zc = jnp.where(on_round, w_mix(z), z)
        sc = jnp.where(on_round, w_mix(s1), s1)
        z1 = zc - alpha * sc
        return (z1, sc, g, t + 1)

    return step


def _mudag_rounds(hp, iters):
    """2K dense-exchange rounds per iteration (s-mix and x-mix FastMix)."""
    return 2 * int(round(hp["gossip_rounds"])) * np.asarray(iters)


def _sliding_rounds(hp, iters):
    """2*ceil(iters/period): z and s exchanged on communication rounds only."""
    period = max(1, int(round(hp["comm_period"])))
    return 2 * np.ceil(np.asarray(iters) / period)


register_solver(
    SolverSpec(
        name="mudag",
        init=_mudag_init,
        step=_mudag_step,
        z_of=lambda problem, hp, comm: lambda state, hp_run: state[0],
        # eta ~ 1/L (normalized rows give L <= 1 + lam); momentum ~
        # (sqrt(kappa)-1)/(sqrt(kappa)+1); K ~ O(1/sqrt(1-sigma)) gossip
        # rounds — benchmarks tune per task, these cover the paper's ridge
        defaults={"eta": 1.0, "momentum": 0.9, "gossip_rounds": 4},
        # Nesterov descent needs a convex minimization objective — the
        # saddle families (auc, bilinear) are excluded by capability
        problem_families=MINIMIZATION_FAMILIES,
        comm_rounds=_mudag_rounds,
        # gradient tracking preserves mean(s) = mean(g) under ANY doubly
        # stochastic W, and the FastMix weight is re-baked per segment
        # runner — schedules are sound. Churn needs the tracker RESET:
        # the telescoped tracker state encodes the departed membership's
        # mean gradient, so carrying it pins the survivors to the dead
        # system's root (docs/algorithm.md). The reanchor re-runs the
        # t=0 tracker seed (s = FastMix(g)) on the new membership, with
        # momentum restarted (y = x).
        supports_schedule=True,
        supports_churn=True,
        reanchor=lambda st: (
            st[0], st[0], jnp.zeros_like(st[2]), jnp.zeros_like(st[3]),
            jnp.zeros((), jnp.int32),
        ),
        # FastMix applies the matvec inside a traced-trip-count fori_loop:
        # a straggler buffer write there would escape the loop trace (the
        # link mask is a read-only capture, so link faults are fine)
        supports_stragglers=False,
    )
)
register_solver(
    SolverSpec(
        name="sliding",
        init=_sliding_init,
        step=_sliding_step,
        z_of=lambda problem, hp, comm: lambda state, hp_run: state[0],
        defaults={"alpha": 0.1, "comm_period": 4},
        problem_families=MINIMIZATION_FAMILIES,
        comm_rounds=_sliding_rounds,
        supports_schedule=True,  # tracking is W-agnostic (see mudag)
        supports_churn=True,
        # tracker reset on churn (see mudag); z itself carries over
        reanchor=lambda st: (
            st[0], jnp.zeros_like(st[1]), jnp.zeros_like(st[2]),
            jnp.zeros((), jnp.int32),
        ),
        # off-round iterations exchange nothing physically — a
        # last-delivered buffer updated by the where-gated matvec would
        # record "deliveries" on rounds that never happened
        supports_stragglers=False,
    )
)


# ---------------------------------------------------------------------------
# Registry entry: DSGDA — decentralized stochastic gradient descent ascent
# ---------------------------------------------------------------------------


def _dsgda_init(problem, hp, z0):
    """DSGDA state: (z, SAGA tables, table mean, tracker, v_prev, counter).

    Same warm start as Algorithm 1 line 1: the scalar tables hold the
    coefficient form of every component operator at z0, phibar their
    assembled mean — so the first variance-reduced estimate is exact. The
    gradient tracker and previous estimate start at zero; the step's
    ``t == 0`` branch seeds the tracker with the first estimate.
    """
    spec = problem.spec
    feats = jnp.asarray(problem.data.dense())  # (N, q, d)
    labels = jnp.asarray(problem.data.y)  # (N, q)
    t = spec.tail_dim
    d = feats.shape[-1]
    z0 = jnp.asarray(z0)
    head, tail = z0[:, :d], z0[:, d:]
    u = jnp.einsum("nqd,nd->nq", feats, head)
    tails = jnp.broadcast_to(tail[:, None, :], u.shape + (t,))
    g, tail_out = spec.coeff_and_tail(u, labels, tails)  # (N,q), (N,q,t)
    phibar_head = jnp.einsum("nq,nqd->nd", g, feats) / feats.shape[1]
    phibar = jnp.concatenate([phibar_head, tail_out.mean(1)], axis=1)
    zeros = jnp.zeros_like(z0)
    return (z0, g, tail_out, phibar, zeros, zeros, jnp.zeros((), jnp.int32))


def _dsgda_step(problem, hp, comm):
    """SAGA-variance-reduced decentralized SGDA with gradient tracking.

    One sampled component per node per iteration; the scalar-table
    estimator v = (g_i - table_i) x_i (+) tail delta + phibar + lam z is
    unbiased with variance shrinking as the tables fill in. The tracker
    y absorbs the node-local heterogeneity (plain mixed descent on v
    stalls at an O(alpha) bias because phibar_n is nonzero at the saddle
    — only the network mean vanishes); with tracking the fixed point is
    the exact regularized saddle and convergence is linear (the operator
    is strongly monotone once lam > 0). Descent on the primal block (step
    ``alpha``) and ascent on the dual block (step ``eta``) happen in one
    update because the tail carries -dL/dtheta.
    """
    spec = problem.spec
    feats, labels = _dense_setup(problem)  # (N, q, d), (N, q)
    t = spec.tail_dim
    q = feats.shape[1]
    d = feats.shape[-1]
    dt = feats.dtype
    w_mix = comm.matvec(problem.w, dt)
    head_mask = jnp.concatenate(
        [jnp.ones((d,), dt), jnp.zeros((t,), dt)]
    )

    def step(carry, i_t, hp_run):
        alpha, eta, lam = hp_run["alpha"], hp_run["eta"], hp_run["lam"]
        z, tab_g, tab_tail, phibar, y, v_prev, step_t = carry
        fe = comm.local(feats)
        la = comm.local(labels)
        n_loc = fe.shape[0]
        rows = jnp.take_along_axis(fe, i_t[:, None, None], axis=1)[:, 0, :]
        ys = jnp.take_along_axis(la, i_t[:, None], axis=1)[:, 0]
        head, tail = z[:, :d], z[:, d:]
        u = jnp.sum(rows * head, axis=-1)
        g, tail_out = spec.coeff_and_tail(u, ys, tail)  # (n,), (n,t)
        old_g = jnp.take_along_axis(tab_g, i_t[:, None], axis=1)[:, 0]
        old_tail = jnp.take_along_axis(
            tab_tail, i_t[:, None, None], axis=1
        )[:, 0, :]
        dg = g - old_g
        dtail = tail_out - old_tail
        delta = jnp.concatenate([dg[:, None] * rows, dtail], axis=1)
        v = delta + phibar + lam * z
        y1 = jnp.where(step_t == 0, v, w_mix(y) + v - v_prev)
        scale = alpha * head_mask + eta * (1.0 - head_mask)
        z1 = w_mix(z) - scale[None, :] * y1
        node = jnp.arange(n_loc)
        tab_g1 = tab_g.at[node, i_t].set(g)
        tab_tail1 = tab_tail.at[node, i_t].set(tail_out)
        return (
            z1, tab_g1, tab_tail1, phibar + delta / q, y1, v,
            step_t + 1,
        )

    return step


register_solver(
    SolverSpec(
        name="dsgda",
        init=_dsgda_init,
        step=_dsgda_step,
        z_of=lambda problem, hp, comm: lambda state, hp_run: state[0],
        defaults={"alpha": 0.3, "eta": 0.3},
        # descent-ascent targets the saddle families; the convex tasks
        # already have the full stochastic family (dsba/dsa)
        problem_families=("auc", "bilinear"),
        supports_schedule=True,  # tracking is W-agnostic (see mudag)
        supports_churn=True,
        # tracker reset on churn: keep the iterate and SAGA tables
        # (ElasticGossip remaps their node axes), zero the dual tracker
        # y and v_prev, and rewind t so the step re-seeds y = v on the
        # new membership (see mudag)
        reanchor=lambda st: (
            st[0], st[1], st[2], st[3],
            jnp.zeros_like(st[4]), jnp.zeros_like(st[5]),
            jnp.zeros((), jnp.int32),
        ),
    )
)


# ---------------------------------------------------------------------------
# Registry entry: personalized consensus-regularized descent
# ---------------------------------------------------------------------------


def _personal_init(problem, hp, z0):
    """Personalized-descent state: just the iterate block."""
    return (jnp.asarray(z0),)


def _personal_step(problem, hp, comm):
    """Consensus-regularized personalization (per-node lam, mu-coupling).

    Each node keeps its OWN solution of its locally regularized problem,
    coupled to its neighbors only through the graph-Laplacian penalty
    (mu/2) <Z, L Z>: the fixed point solves

        G_n(z_n) + lam_n z_n + mu (L Z)_n = 0      for every node n,

    the consensus-regularized personalization system (mu -> inf recovers
    exact consensus, mu = 0 fully local models). Plain forward descent on
    this monotone map — the point here is the problem geometry (per-node
    lam on non-iid shards), not acceleration. ``lam`` arrives traced and
    may be an (N,) array; the column reshape makes both shapes broadcast
    against the (N, D) iterate block.
    """
    feats, labels = _dense_setup(problem)
    G = _full_operator(problem.spec, feats, labels, comm)
    dt = feats.dtype
    lap_mix = comm.matvec(problem.graph.laplacian, dt)

    def step(carry, i_t, hp_run):
        alpha, mu, lam = hp_run["alpha"], hp_run["mu"], hp_run["lam"]
        (z,) = carry
        lam_col = lam[:, None] if jnp.ndim(lam) > 0 else lam
        g = G(z, 0.0) + lam_col * z
        return (z - alpha * (g + mu * lap_mix(z)),)

    return step


def personalized_root(
    problem: Problem, mu: float = 1.0, iters: int = 100, tol: float = 1e-12
) -> np.ndarray:
    """(N, D) root of the consensus-regularized personalization system.

    Damped Newton on the stacked map F(Z) = G(Z) + lam .* Z + mu L Z —
    the per-node-lam counterpart of ``Problem.solve_star()`` (which has
    no single centralized root to offer when lam varies per node). Use
    the SAME ``mu`` as the ``personal`` solver run being measured.
    """
    n, D = problem.graph.n, problem.dim
    comm = DenseComm(problem.graph)
    feats = jnp.asarray(problem.data.dense())
    labels = jnp.asarray(problem.data.y)
    dt = feats.dtype
    G = _full_operator(problem.spec, feats, labels, comm)
    lap = jnp.asarray(problem.graph.laplacian, dt)
    lam = problem.lam
    lam_col = (
        jnp.asarray(np.asarray(lam)[:, None], dt)
        if np.ndim(lam) > 0 else float(lam)
    )

    def F(zf):
        Z = zf.reshape(n, D)
        out = G(Z, 0.0) + lam_col * Z + mu * (lap @ Z)
        return out.reshape(-1)

    jacF = jax.jacfwd(F)
    z = jnp.zeros((n * D,), dt)
    eye = jnp.eye(n * D, dtype=dt)
    for _ in range(iters):
        f = F(z)
        nf = float(jnp.linalg.norm(f))
        if nf < tol:
            break
        delta = jnp.linalg.solve(jacF(z) + 1e-12 * eye, f)
        t = 1.0
        z_try = z - delta
        for _ in range(30):  # backtracking damping
            z_try = z - t * delta
            if float(jnp.linalg.norm(F(z_try))) <= (1.0 - 0.25 * t) * nf:
                break
            t *= 0.5
        z = z_try
    return np.asarray(z).reshape(n, D)


register_solver(
    SolverSpec(
        name="personal",
        init=_personal_init,
        step=_personal_step,
        z_of=lambda problem, hp, comm: lambda state, hp_run: state[0],
        defaults={"alpha": 0.2, "mu": 1.0},
        # forward descent needs a monotone minimization operator; the
        # saddle families couple blocks the Laplacian penalty ignores
        problem_families=MINIMIZATION_FAMILIES,
        # dense-only: an (N,) lam under shard_map would broadcast the
        # whole vector to every device block instead of its own entry
        supports_sharded=False,
        supports_schedule=True,
        supports_per_node_lam=True,
    )
)

"""Monotone operators and their resolvents (paper Sections 3-5, 7, appendix 9.6-9.7).

All operators here are *component* operators B_{n,i} built from one data
sample with a linear predictor, so the operator output decomposes as

    B(z) = g(u, y) * x  (+)  tail(u, z_tail)          u = x^T z_head

where ``x`` is the (sparse) feature vector, ``g`` a scalar coefficient
function, and ``tail`` a small dense tail (empty for ridge/logistic; the
(a, b, theta) block for AUC maximization). This is what makes the paper's
O(q) gradient-table storage (Schmidt et al. 2017) and the O(rho*d) sparse
delta communication possible: the SAGA table stores *scalars*, and
delta = (g_new - g_old) * x (+) tail difference has the sample's sparsity.

l2 regularization (paper Section 7): B^lam = B + lam*I. The lam*I part is
deterministic, so it is kept OUT of the SAGA table (which would otherwise
densify delta) and handled exactly inside the resolvent via the paper's
scaling trick:  J_{alpha B^lam}(psi) = J_{rho*alpha B}(rho*psi),
rho = 1/(1 + lam*alpha).  See core/dsba.py for the corrected psi recursion.

Resolvents:
  ridge     closed form (Section 7.1)
  logistic  1-D Newton, 20 iterations (appendix 9.6, eqs. 73-74)
  auc       4x4 linear solve (appendix 9.7, eqs. 75-82)

All rows are assumed normalized to ||x|| = 1 (the paper normalizes all
datasets); `resolvent_*` take ``xsq = ||x||^2`` anyway for generality.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

NEWTON_ITERS = 20  # paper: "20 newton iteration is sufficient for DSBA"


# ---------------------------------------------------------------------------
# scalar coefficient functions g(u, y):  B(z) = g(x^T z, y) x
# ---------------------------------------------------------------------------

def ridge_coeff(u, y):
    """B(z) = (x^T z - y) x."""
    return u - y


def logistic_coeff(u, y):
    """B(z) = -y / (1 + exp(y * x^T z)) * x."""
    return -y / (1.0 + jnp.exp(y * u))


def logistic_coeff_prime(u, y):
    """d/du of `logistic_coeff` (the Newton denominator of eq. 73)."""
    e = logistic_coeff(u, y)
    # de/du = -y*e - e^2   (verified against eq. 73's denominator)
    return -y * e - e * e


# ---------------------------------------------------------------------------
# scalar resolvents: solve  u + a_eff * g(u, y) * xsq = s  for u = x^T z
# at the resolvent point, and return g(u*, y).
#
# With regularization the caller passes a_eff = rho*alpha and s = rho*s_raw
# (rho = 1/(1+lam*alpha)); the full resolvent is then
#   z = rho*psi - rho*alpha*g(u*, y) * x.
# ---------------------------------------------------------------------------

def ridge_resolvent_coeff(s, y, a_eff, xsq):
    """Closed-form scalar resolvent of the ridge operator (Section 7.1)."""
    u = (s + a_eff * y * xsq) / (1.0 + a_eff * xsq)
    return ridge_coeff(u, y)


def logistic_resolvent_coeff(s, y, a_eff, xsq):
    """Newton iteration of eq. (73) generalized to ||x||^2 = xsq."""

    def body(_, u):
        e = logistic_coeff(u, y)
        f = u + a_eff * xsq * e - s
        fp = 1.0 + a_eff * xsq * logistic_coeff_prime(u, y)
        return u - f / fp

    u0 = jnp.zeros_like(s)
    u = jax.lax.fori_loop(0, NEWTON_ITERS, body, u0)
    return logistic_coeff(u, y)


# ---------------------------------------------------------------------------
# AUC maximization operators (appendix 9.7)
#
# z = [w (d); a; b; theta].  For one sample (x, y) with positive ratio p:
#   positive (y=+1):
#     B_w     = 2(1-p)((u - a) - (1+theta)) x
#     B_a     = -2(1-p)(u - a)
#     B_b     = 0
#     B_theta = 2p(1-p)theta + 2(1-p)u            (= -df/dtheta)
#   negative (y=-1):
#     B_w     = 2p((u - b) + (1+theta)) x
#     B_a     = 0
#     B_b     = -2p(u - b)
#     B_theta = 2p(1-p)theta - 2p u
# where u = x^T w.
# ---------------------------------------------------------------------------

def auc_coeff_and_tail(u, y, tail, p):
    """Returns (g, tail_out): B(z) = g*x (+) tail_out over (a, b, theta)."""
    a, b, theta = tail[..., 0], tail[..., 1], tail[..., 2]
    pos = y > 0
    g_pos = 2.0 * (1.0 - p) * ((u - a) - (1.0 + theta))
    g_neg = 2.0 * p * ((u - b) + (1.0 + theta))
    g = jnp.where(pos, g_pos, g_neg)
    ta = jnp.where(pos, -2.0 * (1.0 - p) * (u - a), 0.0)
    tb = jnp.where(pos, 0.0, -2.0 * p * (u - b))
    tt = 2.0 * p * (1.0 - p) * theta + jnp.where(
        pos, 2.0 * (1.0 - p) * u, -2.0 * p * u
    )
    return g, jnp.stack([ta, tb, tt], axis=-1)


def auc_resolvent(s, psi_tail, y, p, a_eff, xsq):
    """Solve the 4x4 system (eqs. 77-82) generalized to ||x||^2 = xsq.

    Solves  v + a_eff * B(v) = rhs  in the scalar coordinates
    v = (u, a, b, theta) where u = x^T w,  rhs = (s, psi_a, psi_b, psi_th).
    Returns (g, tail_solution): the full resolvent is
      w  = psi_w - a_eff * g * x,   (a, b, theta) = tail_solution.
    """
    beta_p = (1.0 - p) * a_eff
    beta_n = p * a_eff
    pos = y > 0

    def mat_pos():
        return jnp.array(
            [
                [1.0 + 2.0 * beta_p * xsq, -2.0 * beta_p * xsq, 0.0,
                 -2.0 * beta_p * xsq],
                [-2.0 * beta_p, 1.0 + 2.0 * beta_p, 0.0, 0.0],
                [0.0, 0.0, 1.0, 0.0],
                [2.0 * beta_p, 0.0, 0.0, 1.0 + 2.0 * p * (1.0 - p) * a_eff],
            ],
            dtype=s.dtype,
        )

    def mat_neg():
        return jnp.array(
            [
                [1.0 + 2.0 * beta_n * xsq, 0.0, -2.0 * beta_n * xsq,
                 2.0 * beta_n * xsq],
                [0.0, 1.0, 0.0, 0.0],
                [-2.0 * beta_n, 0.0, 1.0 + 2.0 * beta_n, 0.0],
                [-2.0 * beta_n, 0.0, 0.0, 1.0 + 2.0 * p * (1.0 - p) * a_eff],
            ],
            dtype=s.dtype,
        )

    mat = jnp.where(pos, mat_pos(), mat_neg())
    rhs0 = jnp.where(pos, s + 2.0 * beta_p * xsq, s - 2.0 * beta_n * xsq)
    rhs = jnp.concatenate(
        [rhs0[None], psi_tail.astype(s.dtype)], axis=0
    )
    sol = jnp.linalg.solve(mat, rhs)
    u, tail = sol[0], sol[1:]
    g, _ = auc_coeff_and_tail(u, y, tail, p)
    return g, tail


# ---------------------------------------------------------------------------
# Operator spec: uniform interface used by DSBA / DSA / EXTRA / ...
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OperatorSpec:
    """A family of component operators B_{n,i} with linear predictors.

    tail_dim: number of trailing dense coordinates in z (0 or 3 for AUC).
    p: positive-class ratio (AUC only).
    """

    kind: str  # 'ridge' | 'logistic' | 'auc'
    p: float = 0.5

    @property
    def tail_dim(self) -> int:
        """Trailing dense coordinates of z: 3 for AUC's (a, b, theta), else 0."""
        return 3 if self.kind == "auc" else 0

    def coeff_and_tail(self, u, y, tail):
        """g and tail-output of B at predictor value u, tail coords `tail`."""
        if self.kind == "ridge":
            return ridge_coeff(u, y), jnp.zeros_like(tail)
        if self.kind == "logistic":
            return logistic_coeff(u, y), jnp.zeros_like(tail)
        if self.kind == "auc":
            return auc_coeff_and_tail(u, y, tail, self.p)
        raise ValueError(self.kind)

    def resolvent_coeff_and_tail(self, s, psi_tail, y, a_eff, xsq):
        """Solve z + a_eff*B(z) = psi in scalar coordinates.

        Returns (g_at_solution, tail_solution). The caller reconstructs
        z_head = psi_head - a_eff * g * x and z_tail = tail_solution.
        """
        if self.kind == "ridge":
            g = ridge_resolvent_coeff(s, y, a_eff, xsq)
            return g, psi_tail
        if self.kind == "logistic":
            g = logistic_resolvent_coeff(s, y, a_eff, xsq)
            return g, psi_tail
        if self.kind == "auc":
            return auc_resolvent(s, psi_tail, y, self.p, a_eff, xsq)
        raise ValueError(self.kind)


# ---------------------------------------------------------------------------
# Dense full-operator evaluation (for baselines & reference solutions).
# ---------------------------------------------------------------------------

def full_operator_dense(spec: OperatorSpec, z, feats, labels, lam):
    """Mean_i B^lam_{n,i}(z) for one node, dense features (q, d).

    z: (d + tail_dim,). Returns same shape.
    """
    t = spec.tail_dim
    d = feats.shape[-1]
    head, tail = z[:d], z[d:]
    u = feats @ head  # (q,)
    tails = jnp.broadcast_to(tail, (feats.shape[0], t)) if t else jnp.zeros(
        (feats.shape[0], 0), z.dtype
    )
    g, tail_out = spec.coeff_and_tail(u, labels, tails)
    out_head = (g[:, None] * feats).mean(0)
    out_tail = tail_out.mean(0) if t else jnp.zeros((0,), z.dtype)
    return jnp.concatenate([out_head, out_tail]) + lam * z


def sample_operator_sparse(spec: OperatorSpec, z, idx, val, y, lam=0.0):
    """B_{n,i}(z) coefficient form for ONE sparse sample (no lam term).

    idx/val: (k,) padded sparse row (pad idx with 0 and val with 0).
    Returns (g, tail_out, u).
    """
    d = z.shape[0] - spec.tail_dim
    u = jnp.sum(val * z[idx])
    tail = z[d:]
    g, tail_out = spec.coeff_and_tail(u, y, tail)
    return g, tail_out, u

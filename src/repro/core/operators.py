"""Monotone operators and their resolvents (paper Sections 3-5, 7, appendix 9.6-9.7).

All operators here are *component* operators B_{n,i} built from one data
sample with a linear predictor, so the operator output decomposes as

    B(z) = g(u, y) * x  (+)  tail(u, z_tail)          u = x^T z_head

where ``x`` is the (sparse) feature vector, ``g`` a scalar coefficient
function, and ``tail`` a small dense tail (empty for ridge/logistic; the
(a, b, theta) block for AUC maximization). This is what makes the paper's
O(q) gradient-table storage (Schmidt et al. 2017) and the O(rho*d) sparse
delta communication possible: the SAGA table stores *scalars*, and
delta = (g_new - g_old) * x (+) tail difference has the sample's sparsity.

l2 regularization (paper Section 7): B^lam = B + lam*I. The lam*I part is
deterministic, so it is kept OUT of the SAGA table (which would otherwise
densify delta) and handled exactly inside the resolvent via the paper's
scaling trick:  J_{alpha B^lam}(psi) = J_{rho*alpha B}(rho*psi),
rho = 1/(1 + lam*alpha).  See core/dsba.py for the corrected psi recursion.

Resolvents:
  ridge     closed form (Section 7.1)
  logistic  1-D Newton, 20 iterations (appendix 9.6, eqs. 73-74)
  auc       4x4 linear solve (appendix 9.7, eqs. 75-82)

All rows are assumed normalized to ||x|| = 1 (the paper normalizes all
datasets); `resolvent_*` take ``xsq = ||x||^2`` anyway for generality.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

NEWTON_ITERS = 20  # paper: "20 newton iteration is sufficient for DSBA"


# ---------------------------------------------------------------------------
# scalar coefficient functions g(u, y):  B(z) = g(x^T z, y) x
# ---------------------------------------------------------------------------

def ridge_coeff(u, y):
    """B(z) = (x^T z - y) x."""
    return u - y


def logistic_coeff(u, y):
    """B(z) = -y / (1 + exp(y * x^T z)) * x."""
    return -y / (1.0 + jnp.exp(y * u))


def logistic_coeff_prime(u, y):
    """d/du of `logistic_coeff` (the Newton denominator of eq. 73)."""
    e = logistic_coeff(u, y)
    # de/du = -y*e - e^2   (verified against eq. 73's denominator)
    return -y * e - e * e


# ---------------------------------------------------------------------------
# Bilinear-coupled finite-sum minimax (decentralized SGDA, Gao 2022 setting).
#
# Per sample (x, y) the saddle function over z = [w (d); theta] is
#
#   L_i(w, theta) = 1/2 (u - y)^2 + theta * y * u - gamma/2 * theta^2,
#   u = x^T w,
#
# i.e. a least-squares primal bilinearly coupled to a scalar dual through
# the label. The associated monotone operator is B = [dL/dw; -dL/dtheta]:
#
#   B_w     = ((u - y) + theta * y) x
#   B_theta = gamma * theta - y * u
#
# whose Jacobian in (u, theta) is [[1, y], [-y, gamma]] — a PSD symmetric
# part plus an antisymmetric coupling, so B is monotone (strongly once
# lam*I is added) and the root of the regularized mean operator is the
# saddle point of mean_i L_i + lam/2 ||w||^2 - lam/2 theta^2.
# This reuses the AUC tail-block machinery with tail_dim = 1.
# ---------------------------------------------------------------------------

def bilinear_coeff_and_tail(u, y, tail, gamma):
    """Returns (g, tail_out): B(z) = g*x (+) tail_out over (theta,)."""
    theta = tail[..., 0]
    g = (u - y) + theta * y
    tt = gamma * theta - y * u
    return g, tt[..., None]


def bilinear_resolvent(s, psi_tail, y, gamma, a_eff, xsq):
    """Closed-form 2x2 resolvent: solve v + a_eff * B(v) = rhs.

    Scalar coordinates v = (u, theta), rhs = (s, psi_theta). The system is
    affine, so this is one 2x2 solve:

      (1 + a*xsq) u + a*xsq*y theta = s + a*xsq*y
      -a*y u + (1 + a*gamma) theta  = psi_theta

    with determinant (1+a*xsq)(1+a*gamma) + a^2*xsq*y^2 > 0 always.
    Returns (g_at_solution, tail_solution) like the other resolvents.
    """
    psi_th = psi_tail[..., 0]
    a11 = 1.0 + a_eff * xsq
    a12 = a_eff * xsq * y
    a21 = -a_eff * y
    a22 = 1.0 + a_eff * gamma
    r1 = s + a_eff * xsq * y
    r2 = psi_th
    det = a11 * a22 - a12 * a21
    u = (a22 * r1 - a12 * r2) / det
    theta = (a11 * r2 - a21 * r1) / det
    g, tail_out = bilinear_coeff_and_tail(u, y, theta[..., None], gamma)
    del tail_out  # resolvent returns the solution coordinates, not B(v)
    return g, theta[..., None]


# ---------------------------------------------------------------------------
# scalar resolvents: solve  u + a_eff * g(u, y) * xsq = s  for u = x^T z
# at the resolvent point, and return g(u*, y).
#
# With regularization the caller passes a_eff = rho*alpha and s = rho*s_raw
# (rho = 1/(1+lam*alpha)); the full resolvent is then
#   z = rho*psi - rho*alpha*g(u*, y) * x.
# ---------------------------------------------------------------------------

def ridge_resolvent_coeff(s, y, a_eff, xsq):
    """Closed-form scalar resolvent of the ridge operator (Section 7.1)."""
    u = (s + a_eff * y * xsq) / (1.0 + a_eff * xsq)
    return ridge_coeff(u, y)


def logistic_resolvent_coeff(s, y, a_eff, xsq):
    """Newton iteration of eq. (73) generalized to ||x||^2 = xsq."""

    def body(_, u):
        e = logistic_coeff(u, y)
        f = u + a_eff * xsq * e - s
        fp = 1.0 + a_eff * xsq * logistic_coeff_prime(u, y)
        return u - f / fp

    u0 = jnp.zeros_like(s)
    u = jax.lax.fori_loop(0, NEWTON_ITERS, body, u0)
    return logistic_coeff(u, y)


# ---------------------------------------------------------------------------
# AUC maximization operators (appendix 9.7)
#
# z = [w (d); a; b; theta].  For one sample (x, y) with positive ratio p:
#   positive (y=+1):
#     B_w     = 2(1-p)((u - a) - (1+theta)) x
#     B_a     = -2(1-p)(u - a)
#     B_b     = 0
#     B_theta = 2p(1-p)theta + 2(1-p)u            (= -df/dtheta)
#   negative (y=-1):
#     B_w     = 2p((u - b) + (1+theta)) x
#     B_a     = 0
#     B_b     = -2p(u - b)
#     B_theta = 2p(1-p)theta - 2p u
# where u = x^T w.
# ---------------------------------------------------------------------------

def auc_coeff_and_tail(u, y, tail, p):
    """Returns (g, tail_out): B(z) = g*x (+) tail_out over (a, b, theta)."""
    a, b, theta = tail[..., 0], tail[..., 1], tail[..., 2]
    pos = y > 0
    g_pos = 2.0 * (1.0 - p) * ((u - a) - (1.0 + theta))
    g_neg = 2.0 * p * ((u - b) + (1.0 + theta))
    g = jnp.where(pos, g_pos, g_neg)
    ta = jnp.where(pos, -2.0 * (1.0 - p) * (u - a), 0.0)
    tb = jnp.where(pos, 0.0, -2.0 * p * (u - b))
    tt = 2.0 * p * (1.0 - p) * theta + jnp.where(
        pos, 2.0 * (1.0 - p) * u, -2.0 * p * u
    )
    return g, jnp.stack([ta, tb, tt], axis=-1)


def auc_resolvent(s, psi_tail, y, p, a_eff, xsq):
    """Solve the 4x4 system (eqs. 77-82) generalized to ||x||^2 = xsq.

    Solves  v + a_eff * B(v) = rhs  in the scalar coordinates
    v = (u, a, b, theta) where u = x^T w,  rhs = (s, psi_a, psi_b, psi_th).
    Returns (g, tail_solution): the full resolvent is
      w  = psi_w - a_eff * g * x,   (a, b, theta) = tail_solution.
    """
    beta_p = (1.0 - p) * a_eff
    beta_n = p * a_eff
    pos = y > 0

    def mat_pos():
        return jnp.array(
            [
                [1.0 + 2.0 * beta_p * xsq, -2.0 * beta_p * xsq, 0.0,
                 -2.0 * beta_p * xsq],
                [-2.0 * beta_p, 1.0 + 2.0 * beta_p, 0.0, 0.0],
                [0.0, 0.0, 1.0, 0.0],
                [2.0 * beta_p, 0.0, 0.0, 1.0 + 2.0 * p * (1.0 - p) * a_eff],
            ],
            dtype=s.dtype,
        )

    def mat_neg():
        return jnp.array(
            [
                [1.0 + 2.0 * beta_n * xsq, 0.0, -2.0 * beta_n * xsq,
                 2.0 * beta_n * xsq],
                [0.0, 1.0, 0.0, 0.0],
                [-2.0 * beta_n, 0.0, 1.0 + 2.0 * beta_n, 0.0],
                [-2.0 * beta_n, 0.0, 0.0, 1.0 + 2.0 * p * (1.0 - p) * a_eff],
            ],
            dtype=s.dtype,
        )

    mat = jnp.where(pos, mat_pos(), mat_neg())
    rhs0 = jnp.where(pos, s + 2.0 * beta_p * xsq, s - 2.0 * beta_n * xsq)
    rhs = jnp.concatenate(
        [rhs0[None], psi_tail.astype(s.dtype)], axis=0
    )
    sol = jnp.linalg.solve(mat, rhs)
    u, tail = sol[0], sol[1:]
    g, _ = auc_coeff_and_tail(u, y, tail, p)
    return g, tail


# ---------------------------------------------------------------------------
# Operator spec: uniform interface used by DSBA / DSA / EXTRA / ...
# ---------------------------------------------------------------------------

#: operator families ("problem families" in solver capability records)
FAMILIES = ("ridge", "logistic", "auc", "bilinear")

#: families whose regularized mean operator is the gradient of a convex
#: objective (vs. a genuine saddle operator) — descent-only methods such
#: as Nesterov-accelerated consensus apply only to these.
MINIMIZATION_FAMILIES = ("ridge", "logistic")

_TAIL_DIMS = {"ridge": 0, "logistic": 0, "auc": 3, "bilinear": 1}


@dataclasses.dataclass(frozen=True)
class OperatorSpec:
    """A family of component operators B_{n,i} with linear predictors.

    tail_dim: number of trailing dense coordinates in z
      (3 for AUC's (a, b, theta), 1 for bilinear's theta, else 0).
    p: positive-class ratio (AUC only).
    gamma: dual strong-concavity modulus (bilinear only).
    """

    kind: str  # 'ridge' | 'logistic' | 'auc' | 'bilinear'
    p: float = 0.5
    gamma: float = 1.0

    @property
    def tail_dim(self) -> int:
        """Trailing dense coordinates of z (the non-predictor block)."""
        return _TAIL_DIMS[self.kind]

    def coeff_and_tail(self, u, y, tail):
        """g and tail-output of B at predictor value u, tail coords `tail`."""
        if self.kind == "ridge":
            return ridge_coeff(u, y), jnp.zeros_like(tail)
        if self.kind == "logistic":
            return logistic_coeff(u, y), jnp.zeros_like(tail)
        if self.kind == "auc":
            return auc_coeff_and_tail(u, y, tail, self.p)
        if self.kind == "bilinear":
            return bilinear_coeff_and_tail(u, y, tail, self.gamma)
        raise ValueError(self.kind)

    def resolvent_coeff_and_tail(self, s, psi_tail, y, a_eff, xsq):
        """Solve z + a_eff*B(z) = psi in scalar coordinates.

        Returns (g_at_solution, tail_solution). The caller reconstructs
        z_head = psi_head - a_eff * g * x and z_tail = tail_solution.
        """
        if self.kind == "ridge":
            g = ridge_resolvent_coeff(s, y, a_eff, xsq)
            return g, psi_tail
        if self.kind == "logistic":
            g = logistic_resolvent_coeff(s, y, a_eff, xsq)
            return g, psi_tail
        if self.kind == "auc":
            return auc_resolvent(s, psi_tail, y, self.p, a_eff, xsq)
        if self.kind == "bilinear":
            return bilinear_resolvent(s, psi_tail, y, self.gamma, a_eff, xsq)
        raise ValueError(self.kind)


# ---------------------------------------------------------------------------
# Dense full-operator evaluation (for baselines & reference solutions).
# ---------------------------------------------------------------------------

def full_operator_dense(spec: OperatorSpec, z, feats, labels, lam):
    """Mean_i B^lam_{n,i}(z) for one node, dense features (q, d).

    z: (d + tail_dim,). Returns same shape.
    """
    t = spec.tail_dim
    d = feats.shape[-1]
    head, tail = z[:d], z[d:]
    u = feats @ head  # (q,)
    tails = jnp.broadcast_to(tail, (feats.shape[0], t)) if t else jnp.zeros(
        (feats.shape[0], 0), z.dtype
    )
    g, tail_out = spec.coeff_and_tail(u, labels, tails)
    out_head = (g[:, None] * feats).mean(0)
    out_tail = tail_out.mean(0) if t else jnp.zeros((0,), z.dtype)
    return jnp.concatenate([out_head, out_tail]) + lam * z


def sample_operator_sparse(spec: OperatorSpec, z, idx, val, y, lam=0.0):
    """B_{n,i}(z) coefficient form for ONE sparse sample (no lam term).

    idx/val: (k,) padded sparse row (pad idx with 0 and val with 0).
    Returns (g, tail_out, u).
    """
    d = z.shape[0] - spec.tail_dim
    u = jnp.sum(val * z[idx])
    tail = z[d:]
    g, tail_out = spec.coeff_and_tail(u, y, tail)
    return g, tail_out, u

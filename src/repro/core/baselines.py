"""Deprecated shims for the deterministic baselines (EXTRA / DLM / SSDA).

The implementations live in the ``core.solvers`` registry now (entries
``extra``, ``dlm``, ``ssda``); ``core.solvers.solve`` is the one run
entrypoint. These wrappers keep the legacy signatures alive for external
callers, emit ``DeprecationWarning``, and are pinned trace-identical to
``solve(method=..., comm="dense")`` by ``tests/test_solvers.py``.

Background (paper Table 1):

  EXTRA  (Shi et al. 2015a)    — eq. (47) form: exact first-order correction
  DLM    (Ling et al. 2015)    — linearized decentralized ADMM
  SSDA   (Scaman et al. 2017)  — accelerated gradient on the dual, needs
                                 the conjugate gradient map grad f_n^*

All of them evaluate FULL local gradients/operators each iteration (cost
O(rho q d) per node) and exchange dense d-vectors with neighbors (cost
O(Delta(G) d)) — the two costs DSBA improves on.
"""
from __future__ import annotations

import numpy as np

from repro.core.deprecation import warn_once
from repro.core.dsba import RunResult
from repro.core.mixing import Graph
from repro.core.operators import OperatorSpec
from repro.core import solvers


def _deprecated(name: str, method: str) -> None:
    # once per process per shim; stacklevel=3 walks warn_once's caller
    # (this helper) -> the run_* shim -> the user's call site.
    warn_once(
        f"baselines.{name}",
        f"core.baselines.{name} is deprecated and will be REMOVED in v0.2 "
        f"(final warning); use core.solvers.solve("
        f"problem, method={method!r}, comm='dense') instead",
        stacklevel=3,
    )


def _legacy_solve(
    method: str,
    spec: OperatorSpec,
    data,
    graph: Graph,
    w: np.ndarray | None,
    lam: float,
    steps: int,
    z_star: np.ndarray | None,
    record_every: int,
    **hp,
) -> RunResult:
    problem = solvers.Problem(
        spec=spec, data=data, graph=graph, w=w, lam=lam, z_star=z_star
    )
    res = solvers.solve(
        problem, method=method, comm="dense", steps=steps,
        record_every=record_every, **hp,
    )
    return RunResult(res.state, res.iters, res.dist2, res.consensus, res.zs)


def run_extra(
    spec: OperatorSpec,
    data,
    w: np.ndarray,
    alpha: float,
    lam: float,
    steps: int,
    z_star: np.ndarray | None = None,
    record_every: int = 1,
) -> RunResult:
    """Deprecated: ``solve(problem, method="extra")`` replaces this."""
    _deprecated("run_extra", "extra")
    graph = solvers.graph_from_mixing(w)
    return _legacy_solve(
        "extra", spec, data, graph, w, lam, steps, z_star, record_every,
        alpha=alpha,
    )


def run_dlm(
    spec: OperatorSpec,
    data,
    graph: Graph,
    c: float,
    beta: float,
    lam: float,
    steps: int,
    z_star: np.ndarray | None = None,
    record_every: int = 1,
) -> RunResult:
    """Deprecated: ``solve(problem, method="dlm")`` replaces this."""
    _deprecated("run_dlm", "dlm")
    return _legacy_solve(
        "dlm", spec, data, graph, None, lam, steps, z_star, record_every,
        c=c, beta=beta,
    )


def run_ssda(
    spec: OperatorSpec,
    data,
    w: np.ndarray,
    eta: float,
    momentum: float,
    lam: float,
    steps: int,
    z_star: np.ndarray | None = None,
    record_every: int = 1,
    inner_newton: int = 8,
) -> RunResult:
    """Deprecated: ``solve(problem, method="ssda")`` replaces this."""
    _deprecated("run_ssda", "ssda")
    graph = solvers.graph_from_mixing(w)
    return _legacy_solve(
        "ssda", spec, data, graph, w, lam, steps, z_star, record_every,
        eta=eta, momentum=momentum, inner_newton=inner_newton,
    )

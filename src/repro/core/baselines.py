"""Deterministic decentralized baselines the paper compares against (Table 1).

  EXTRA  (Shi et al. 2015a)    — eq. (47) form: exact first-order correction
  DLM    (Ling et al. 2015)    — linearized decentralized ADMM
  SSDA   (Scaman et al. 2017)  — accelerated gradient on the dual, needs
                                 the conjugate gradient map grad f_n^*

All of them evaluate FULL local gradients/operators each iteration (cost
O(rho q d) per node) and exchange dense d-vectors with neighbors (cost
O(Delta(G) d)) — the two costs DSBA improves on.

All methods run on the same mixing matrix W. Dense features per node
(moderate d; the reference experiments match this).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mixing import Graph, w_tilde
from repro.core.operators import OperatorSpec
from repro.core.dsba import RunResult


def _full_op(spec: OperatorSpec, feats, labels, lam):
    """G(Z): (N, D) -> (N, D), full local operator incl. regularizer."""
    t = spec.tail_dim
    d = feats.shape[-1]

    def G(Z):
        head, tail = Z[:, :d], Z[:, d:]
        u = jnp.einsum("nqd,nd->nq", feats, head)
        tails = jnp.broadcast_to(tail[:, None, :], u.shape + (t,))
        g, tail_out = spec.coeff_and_tail(u, labels, tails)
        out_head = jnp.einsum("nq,nqd->nd", g, feats) / feats.shape[1]
        if t:
            out = jnp.concatenate([out_head, tail_out.mean(1)], axis=1)
        else:
            out = out_head
        return out + lam * Z

    return G


def _metrics_loop(step_fn, z_of, state, steps, record_every, z_star):
    iters, dist2, cons = [], [], []
    for it in range(1, steps + 1):
        state = step_fn(state)
        if it % record_every == 0 or it == steps:
            z = np.asarray(z_of(state))
            zbar = z.mean(0, keepdims=True)
            cons.append(float(np.mean(np.sum((z - zbar) ** 2, -1))))
            if z_star is not None:
                dist2.append(float(np.mean(np.sum((z - z_star[None]) ** 2, -1))))
            iters.append(it)
    return state, np.asarray(iters), np.asarray(dist2), np.asarray(cons)


# ---------------------------------------------------------------------------
# EXTRA
# ---------------------------------------------------------------------------

def run_extra(
    spec: OperatorSpec,
    data,
    w: np.ndarray,
    alpha: float,
    lam: float,
    steps: int,
    z_star: np.ndarray | None = None,
    record_every: int = 1,
) -> RunResult:
    feats = jnp.asarray(data.dense())
    labels = jnp.asarray(data.y)
    G = _full_op(spec, feats, labels, lam)
    n, D = data.n_nodes, data.d + spec.tail_dim
    dt = feats.dtype
    wj = jnp.asarray(w, dt)
    wtj = jnp.asarray(w_tilde(w), dt)

    @jax.jit
    def step(carry):
        z, z_prev, g_prev, t = carry
        g = G(z)
        z1 = jnp.where(
            t == 0,
            wj @ z - alpha * g,
            z + wj @ z - wtj @ z_prev - alpha * (g - g_prev),
        )
        return (z1, z, g, t + 1)

    state = (jnp.zeros((n, D), dt), jnp.zeros((n, D), dt), jnp.zeros((n, D), dt), 0)
    state, iters, dist2, cons = _metrics_loop(
        step, lambda s: s[0], state, steps, record_every, z_star
    )
    return RunResult(state, iters, dist2, cons, None)


# ---------------------------------------------------------------------------
# DLM — linearized decentralized ADMM
# ---------------------------------------------------------------------------

def run_dlm(
    spec: OperatorSpec,
    data,
    graph: Graph,
    c: float,
    beta: float,
    lam: float,
    steps: int,
    z_star: np.ndarray | None = None,
    record_every: int = 1,
) -> RunResult:
    feats = jnp.asarray(data.dense())
    labels = jnp.asarray(data.y)
    G = _full_op(spec, feats, labels, lam)
    n, D = data.n_nodes, data.d + spec.tail_dim
    dt = feats.dtype
    lap = jnp.asarray(graph.laplacian, dt)
    deg = jnp.asarray(graph.degrees, dt)[:, None]

    @jax.jit
    def step(carry):
        z, lam_dual = carry
        grad_aug = G(z) + lam_dual + 2.0 * c * (lap @ z)
        z1 = z - grad_aug / (2.0 * c * deg + beta)
        lam1 = lam_dual + c * (lap @ z1)
        return (z1, lam1)

    state = (jnp.zeros((n, D), dt), jnp.zeros((n, D), dt))
    state, iters, dist2, cons = _metrics_loop(
        step, lambda s: s[0], state, steps, record_every, z_star
    )
    return RunResult(state, iters, dist2, cons, None)


# ---------------------------------------------------------------------------
# SSDA — accelerated dual ascent. Needs grad f*_n: for ridge we precompute
# per-node Cholesky factors; for other losses we invert grad f_n with an
# inner damped-Newton solve (matrix-free, CG).
# ---------------------------------------------------------------------------

def run_ssda(
    spec: OperatorSpec,
    data,
    w: np.ndarray,
    eta: float,
    momentum: float,
    lam: float,
    steps: int,
    z_star: np.ndarray | None = None,
    record_every: int = 1,
    inner_newton: int = 8,
) -> RunResult:
    if spec.tail_dim:
        raise NotImplementedError(
            "SSDA requires grad f*; the paper notes it does not apply to AUC"
        )
    feats = jnp.asarray(data.dense())  # (N, q, d)
    labels = jnp.asarray(data.y)
    n, q, d = feats.shape
    dt = feats.dtype
    wj = jnp.asarray(w, dt)
    i_minus_w = jnp.eye(n, dtype=dt) - wj

    if spec.kind == "ridge":
        # grad f_n(x) = A^T(Ax - y)/q + lam x ; grad f*_n(s) solves it = s
        gram = jnp.einsum("nqd,nqe->nde", feats, feats) / q
        gram = gram + lam * jnp.eye(d, dtype=dt)[None]
        rhs0 = jnp.einsum("nqd,nq->nd", feats, labels) / q
        chol = jax.vmap(jnp.linalg.cholesky)(gram)

        def conj_grad(S):  # (N, d) -> (N, d): x_n = grad f*_n(s_n)
            return jax.vmap(
                lambda L, r: jax.scipy.linalg.cho_solve((L, True), r)
            )(chol, S + rhs0)

    else:

        def conj_grad(S):
            # invert grad f_n via damped Newton with explicit per-node jacobians
            def one(fe, la, s):
                def gn(x):
                    u = fe @ x
                    g, _ = spec.coeff_and_tail(u, la, jnp.zeros((q, 0), dt))
                    return fe.T @ g / q + lam * x

                x = jnp.zeros((d,), dt)
                jac = jax.jacfwd(gn)
                for _ in range(inner_newton):
                    x = x - jnp.linalg.solve(jac(x), gn(x) - s)
                return x

            return jax.vmap(one)(feats, labels, S)

    @jax.jit
    def step(carry):
        m, m_prev = carry
        v = m + momentum * (m - m_prev)
        x = conj_grad(-v)  # primal read-out: grad f*(-(U Lambda)_n)
        m1 = v + eta * (i_minus_w @ x)
        return (m1, m)

    state = (jnp.zeros((n, d), dt), jnp.zeros((n, d), dt))

    def z_of(s):
        return conj_grad(-s[0])

    state, iters, dist2, cons = _metrics_loop(
        step, z_of, state, steps, record_every, z_star
    )
    return RunResult(state, iters, dist2, cons, None)

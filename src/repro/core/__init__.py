# The paper's primary contribution: DSBA (Decentralized Stochastic Backward
# Aggregation) and its substrate — monotone operators, mixing matrices,
# baselines, sparse communication, and the pod-axis gossip generalization.
from repro.core.operators import OperatorSpec  # noqa: F401
from repro.core.dsba import (  # noqa: F401
    DSBAConfig, DSBAState, dsba_step, init_state, run,
)
from repro.core import mixing, baselines, reference  # noqa: F401

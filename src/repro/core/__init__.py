"""The paper's primary contribution and its substrate.

DSBA (Decentralized Stochastic Backward Aggregation) plus monotone
operators, mixing matrices, deterministic baselines, the sparse
communication relay, and the pod-axis gossip generalization. The public
run entrypoint is ``core.solvers.solve`` (Problem + SolverSpec registry);
``dsba.run`` and the ``baselines.run_*`` wrappers are deprecated shims.
"""
from repro.core.operators import OperatorSpec  # noqa: F401
from repro.core.dsba import (  # noqa: F401
    DSBAConfig, DSBAState, dsba_step, init_state,
)
from repro.core.solvers import (  # noqa: F401
    Problem, SolveResult, SolverSpec, available_solvers,
    clear_runner_caches, get_solver, make_problem, register_solver,
    runner_cache_stats, solve, solve_many,
)
from repro.core import mixing, baselines, reference, solvers  # noqa: F401

"""The paper's primary contribution and its substrate.

DSBA (Decentralized Stochastic Backward Aggregation) plus monotone
operators, mixing matrices, deterministic baselines, the sparse
communication relay, and the pod-axis gossip generalization. The public
run entrypoint is ``core.solvers.solve`` (Problem + SolverSpec registry);
``dsba.run`` and the ``baselines.run_*`` wrappers are deprecated shims.
"""
from repro.launch.compile_cache import enable_persistent_cache

# Persistent XLA compile cache: every entrypoint that imports repro.core
# (tests, benchmarks, notebooks) shares on-disk compiled executables across
# processes. Opt out with REPRO_NO_COMPILE_CACHE=1; relocate with
# REPRO_COMPILE_CACHE_DIR. See launch/compile_cache.py for policy.
enable_persistent_cache()

from repro.core.operators import OperatorSpec  # noqa: F401,E402
from repro.core.dsba import (  # noqa: F401,E402
    DSBAConfig, DSBAState, dsba_step, init_state,
)
from repro.core.solvers import (  # noqa: F401,E402
    CapabilityError, Problem, SolveResult, SolverCapabilities, SolverSpec,
    available_solvers, clear_runner_caches, get_solver, make_problem,
    register_solver, runner_cache_stats, solve, solve_many,
)
from repro.core import mixing, baselines, reference, solvers  # noqa: F401,E402

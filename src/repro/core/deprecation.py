"""Once-per-process ``DeprecationWarning`` for the legacy run shims.

``core.dsba.run`` and ``core.baselines.run_*`` are deprecated delegates to
``core.solvers.solve``. Sweep loops through legacy callers used to emit one
identical ``DeprecationWarning`` per call — hundreds per sweep once the
compiled-runner cache made the calls themselves cheap. Each shim now warns
exactly once per process (keyed by shim name), with ``stacklevel`` resolved
so the warning points at the *caller's* line, not at the shim internals.

``reset()`` clears the seen-set so tests can assert the warning fires
(tests/test_solvers.py wraps each legacy call in ``pytest.warns`` after a
reset).
"""
from __future__ import annotations

import warnings

_SEEN: set[str] = set()


def warn_once(key: str, message: str, *, stacklevel: int = 2) -> None:
    """Emit ``DeprecationWarning`` for ``key`` at most once per process.

    stacklevel counts from the *caller of this function*: 2 (the default)
    attributes the warning to the caller of the function that called
    ``warn_once`` — i.e. the user code invoking a deprecated shim directly.
    Shims wrapping the warn in an extra helper frame add 1 per frame.
    """
    if key in _SEEN:
        return
    _SEEN.add(key)
    # +1 for this frame: the requested level is relative to our caller.
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel + 1)


def reset() -> None:
    """Forget every emitted warning (test isolation)."""
    _SEEN.clear()

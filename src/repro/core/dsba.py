"""DSBA — Decentralized Stochastic Backward Aggregation (paper Algorithm 1).

Implements the node-local recursion (eqs. 27-31), vectorized over all N
nodes, with the SAGA scalar table (O(q) storage via linear predictors,
Schmidt et al. 2017) and sparse per-sample updates in padded-CSR form.

Exact l2 regularization
-----------------------
The paper regularizes B^lam = B + lam*I and computes the resolvent via the
scaling trick J_{alpha B^lam}(psi) = J_{rho alpha B}(rho psi),
rho = 1/(1+lam*alpha). The lam*I part is deterministic, so we keep it OUT of
the SAGA table (otherwise delta would densify, breaking the sparse
communication claim) and carry it exactly through the differencing of (24):

  (1+alpha*lam) z^{t+1} + alpha B_{n,i}(z^{t+1})
      = sum_m w~_{nm} (2 z_m^t - z_m^{t-1})            # mixing
        + alpha*lam z_n^t                              # exact reg carry-over
        + alpha ((q-1)/q delta_n^{t-1} + phi_{n,i}^t)  # SAGA correction
      =: psi_n^t                                        (generalizes eq. 29)

  t = 0 (eq. 31):  psi_n^0 = sum_m w_{nm} z_m^0 + alpha (phi_{n,i} - phibar_n)

Setting lam = 0 recovers the paper's recursion verbatim.

DSA (Mokhtari & Ribeiro 2016) is recovered by evaluating delta at z^t instead
of z^{t+1} (Remark 5.1) and taking a forward step — `method='dsa'`. With a
single node DSBA degenerates to Point-SAGA (tested in tests/test_dsba.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.operators import OperatorSpec
from repro.core.mixing import w_tilde


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DSBAState:
    """Vectorized state of Algorithm 1 across all N nodes."""

    z: jax.Array  # (N, D)  current iterates, D = d + tail_dim
    z_prev: jax.Array  # (N, D)
    table_g: jax.Array  # (N, q)    SAGA scalar coefficients c_{n,i}
    table_tail: jax.Array  # (N, q, t) SAGA tail outputs (t = 0 or 3)
    phibar: jax.Array  # (N, D)    mean of table operator outputs
    dg_prev: jax.Array  # (N,)      delta^{t-1} coefficient
    didx_prev: jax.Array  # (N, k)  delta^{t-1} sparse pattern
    dval_prev: jax.Array  # (N, k)
    dtail_prev: jax.Array  # (N, t)
    step: jax.Array  # ()


@dataclasses.dataclass(frozen=True)
class DSBAConfig:
    """Algorithm-1 step configuration (operator family, step size, reg)."""

    spec: OperatorSpec
    alpha: float  # step size
    lam: float | np.ndarray = 0.0  # l2 reg; (N,) = per-node personalization
    method: str = "dsba"  # 'dsba' (backward) | 'dsa' (forward, Remark 5.1)


def init_state(cfg: DSBAConfig, data, z0: jax.Array) -> DSBAState:
    """phi^0_{n,i} = B_{n,i}(z^0) (Algorithm 1 line 1), delta^0 = 0."""
    spec = cfg.spec
    idx = jnp.asarray(data.idx)
    val = jnp.asarray(data.val)
    y = jnp.asarray(data.y)
    n, q, k = idx.shape
    t = spec.tail_dim
    d = data.d
    if z0.shape != (n, d + t):
        raise ValueError(f"z0 shape {z0.shape} != {(n, d + t)}")

    u = jnp.einsum(
        "nqk,nqk->nq", val, jax.vmap(lambda zn, ix: zn[ix])(z0[:, :d], idx)
    )
    tails = jnp.broadcast_to(z0[:, None, d:], (n, q, t))
    g, tail_out = spec.coeff_and_tail(u, y, tails)

    def node_phibar(g_n, idx_n, val_n, tail_n):
        head = jnp.zeros((d,), z0.dtype).at[idx_n.reshape(-1)].add(
            (g_n[:, None] * val_n).reshape(-1) / q
        )
        return jnp.concatenate([head, tail_n.mean(0)])

    phibar = jax.vmap(node_phibar)(g, idx, val, tail_out)
    return DSBAState(
        z=z0,
        z_prev=z0,
        table_g=g,
        table_tail=tail_out,
        phibar=phibar,
        dg_prev=jnp.zeros((n,), z0.dtype),
        didx_prev=jnp.zeros((n, k), idx.dtype),
        dval_prev=jnp.zeros((n, k), z0.dtype),
        dtail_prev=jnp.zeros((n, t), z0.dtype),
        step=jnp.zeros((), jnp.int32),
    )


def _gather_rows(a, i):
    """Per-node gather of sampled rows: a (N, q, ...), i (N,) -> (N, ...)."""
    return jnp.take_along_axis(
        a, i.reshape(-1, *([1] * (a.ndim - 1))), axis=1
    ).squeeze(1)


def dsba_step(
    cfg: DSBAConfig,
    w: jax.Array,
    wt: jax.Array,
    data_idx: jax.Array,
    data_val: jax.Array,
    data_y: jax.Array,
    state: DSBAState,
    i_t: jax.Array,
    mix: jax.Array | None = None,
    *,
    mix_pair: tuple[jax.Array, jax.Array] | None = None,
) -> DSBAState:
    """One iteration of Algorithm 1 on every node simultaneously.

    i_t: (N,) int array — the sample index drawn by each node this step
    (passed in explicitly so the sparse-communication simulator can replay
    the identical stream; see core/sparse_comm.py).

    mix: optional (N, D) override of the neighbor-mixing term. The sparse-
    communication runtime computes this from each node's *reconstructed*
    delayed copies of the other iterates (Section 5.1) instead of the true
    Z — everything else in the update is node-local.

    mix_pair: optional ``(mix_0, mix_t)`` — the t=0 mixing ``W @ Z`` and
    the t>=1 mixing ``W~ @ (2Z - Z_prev)`` computed by a ``core.comm``
    backend (``make_step_fn`` supplies these so the same step runs under
    dense and sharded communication). Mutually exclusive with ``mix``;
    with neither, the matmuls are inlined from ``w``/``wt``.
    """
    spec, alpha, lam = cfg.spec, cfg.alpha, cfg.lam
    n, q, k = data_idx.shape
    t = spec.tail_dim
    d = state.z.shape[1] - t
    dt = state.z.dtype
    # per-node lam (personalization): lam is (N,) and rho/a_eff become
    # per-node vectors; the scalar path below is byte-identical to before
    per_node = jnp.ndim(lam) > 0
    lam_col = lam[:, None] if per_node else lam
    rho = 1.0 / (1.0 + alpha * lam)
    a_eff = rho * alpha
    idx_s = _gather_rows(data_idx, i_t)  # (N, k)
    val_s = _gather_rows(data_val, i_t)  # (N, k)
    y_s = _gather_rows(data_y, i_t)  # (N,)
    c_s = _gather_rows(state.table_g, i_t)  # (N,)
    ct_s = _gather_rows(state.table_tail, i_t)  # (N, t)

    is0 = state.step == 0

    def add_sparse(vec, idxs, vals, coef, tail):
        """vec (N, D) += coef * x (+) tail, batched over nodes."""

        def one(v, ix, vl, c, tl):
            v = v.at[ix].add(c * vl)
            if t:
                v = v.at[d:].add(tl)
            return v

        return jax.vmap(one)(vec, idxs, vals, coef, tail)

    # ---- psi (eq. 29 generalized; eq. 31 at t = 0) -------------------------
    scale = (q - 1.0) / q
    if mix_pair is not None:
        mix_0, mix_t = mix_pair
    else:
        mix_t = wt.astype(dt) @ (2.0 * state.z - state.z_prev) if mix is None else mix
        mix_0 = w.astype(dt) @ state.z if mix is None else mix
    psi_t = mix_t + alpha * lam_col * state.z
    psi_t = add_sparse(
        psi_t,
        state.didx_prev,
        state.dval_prev,
        alpha * scale * state.dg_prev,
        alpha * scale * state.dtail_prev,
    )
    psi_0 = mix_0 - alpha * state.phibar
    psi = jnp.where(is0, psi_0, psi_t)
    psi = add_sparse(psi, idx_s, val_s, alpha * c_s, alpha * ct_s)

    gather_u = jax.vmap(lambda p, ix, vl: jnp.sum(vl * p[ix]))
    xsq = jnp.sum(val_s * val_s, axis=-1)  # == 1 for normalized rows

    if cfg.method == "dsba":
        # backward step: z^{t+1} = J_{alpha B^lam_{n,i}}(psi)  (eq. 30)
        s = gather_u(psi[:, :d], idx_s, val_s)
        if per_node:
            # vmap the per-node rho/a_eff alongside the sampled rows
            g_new, tail_z = jax.vmap(
                lambda r_, a_, s_, pt_, y_, x_: spec.resolvent_coeff_and_tail(
                    r_ * s_, r_ * pt_, y_, a_, x_
                )
            )(rho, a_eff, s, psi[:, d:], y_s, xsq)
            z_new = rho[:, None] * psi
        else:
            g_new, tail_z = jax.vmap(
                lambda s_, pt_, y_, x_: spec.resolvent_coeff_and_tail(
                    rho * s_, rho * pt_, y_, a_eff, x_
                )
            )(s, psi[:, d:], y_s, xsq)
            z_new = rho * psi
        z_new = add_sparse(
            z_new, idx_s, val_s, -a_eff * g_new, jnp.zeros((n, t), dt)
        )
        if t:
            z_new = z_new.at[:, d:].set(tail_z)
        # operator outputs at the NEW point (for delta + table, Alg.1 l.7-8)
        u_new = rho * s - a_eff * g_new * xsq
        g_upd, tail_upd = spec.coeff_and_tail(u_new, y_s, tail_z)
    elif cfg.method == "dsa":
        # forward step: delta at z^t (eq. 32); no resolvent.
        #   z^{t+1} = psi - alpha*B_{n,i}(z^t) - alpha*lam*(2z^t - z^{t-1})
        # (at t=0 the lam correction is z^0; psi_0 carries no lam term)
        u_cur = gather_u(state.z[:, :d], idx_s, val_s)
        g_upd, tail_upd = spec.coeff_and_tail(u_cur, y_s, state.z[:, d:])
        # psi already contains +alpha*lam*z^t (t>=1); subtracting
        # alpha*lam*(2z^t - z^{t-1}) nets the forward-reg difference
        # -alpha*lam*(z^t - z^{t-1}). At t=0 psi has no lam term and the
        # forward step subtracts alpha*lam*z^0 directly.
        lam_pt = jnp.where(is0, state.z, 2.0 * state.z - state.z_prev)
        z_new = psi - alpha * lam_col * lam_pt
        z_new = add_sparse(z_new, idx_s, val_s, -alpha * g_upd, -alpha * tail_upd)
    else:
        raise ValueError(cfg.method)

    # ---- delta, table, phibar updates --------------------------------------
    dg = g_upd - c_s
    dtail = tail_upd - ct_s
    set_row = jax.vmap(lambda tb, i, v: tb.at[i].set(v))
    table_g = set_row(state.table_g, i_t, g_upd)
    table_tail = set_row(state.table_tail, i_t, tail_upd)
    phibar = add_sparse(state.phibar, idx_s, val_s, dg / q, dtail / q)

    return DSBAState(
        z=z_new,
        z_prev=state.z,
        table_g=table_g,
        table_tail=table_tail,
        phibar=phibar,
        dg_prev=dg,
        didx_prev=idx_s,
        dval_prev=val_s,
        dtail_prev=dtail,
        step=state.step + 1,
    )


def make_step_fn(cfg: DSBAConfig, data, w: np.ndarray, comm=None):
    """Device-resident local-update closure: step(state, i_t, mix=None, hp=None).

    Bakes the dataset and mixing matrices into device arrays ONCE and returns
    a pure function of (state, i_t, mix, hp) that is safe to call inside jit /
    lax.scan. This is the mix-row hook used by core.sparse_comm: the sparse-
    communication engine composes this step with its reconstruction-derived
    mixing rows entirely on device, so per-iteration state never round-trips
    through NumPy.

    comm: optional ``core.comm`` backend. When given, the neighbor-mixing
    terms run through ``comm.matvec`` (the pluggable mix primitive — a
    matmul under ``DenseComm``, edge-wise ``ppermute`` under
    ``ShardedComm``) and the baked dataset arrays are sliced to the
    caller's node block via ``comm.local`` inside the step, so the same
    closure runs unchanged under single-device and shard_map execution.
    ``comm=None`` keeps the legacy inline-matmul behavior (the sparse
    relay overrides ``mix`` explicitly and needs the full-N arrays).

    hp: optional mapping with ``"alpha"`` / ``"lam"`` overriding the values
    baked in ``cfg``. The compiled-runner cache (core.runner_cache) passes
    these as *traced* scalars so one compiled step serves every
    hyperparameter value on the same problem shape; ``hp=None`` keeps the
    legacy baked-constant behavior for direct callers.
    """
    dt = data.val.dtype
    w_j = jnp.asarray(w, dt)
    wt_j = jnp.asarray(w_tilde(w), dt)
    idx_j = jnp.asarray(data.idx)
    val_j = jnp.asarray(data.val)
    y_j = jnp.asarray(data.y)
    if comm is not None:
        w_mix = comm.matvec(w, dt)
        wt_mix = comm.matvec(w_tilde(w), dt)

    def step(
        state: DSBAState,
        i_t: jax.Array,
        mix: jax.Array | None = None,
        hp=None,
    ):
        c = cfg
        if hp is not None:
            c = dataclasses.replace(cfg, alpha=hp["alpha"], lam=hp["lam"])
        if comm is None:
            return dsba_step(c, w_j, wt_j, idx_j, val_j, y_j, state, i_t, mix)
        if mix is not None:
            raise ValueError("pass mix through the comm backend, not both")
        # eq. 31 at t = 0 mixes with W, eq. 29 with W~ over the
        # extrapolation — both through the backend's mix primitive
        mix_pair = (
            w_mix(state.z),
            wt_mix(2.0 * state.z - state.z_prev),
        )
        return dsba_step(
            c, w_j, wt_j,
            comm.local(idx_j), comm.local(val_j), comm.local(y_j),
            state, i_t, mix_pair=mix_pair,
        )

    return step


def draw_indices(steps: int, n_nodes: int, q: int, seed: int = 0) -> np.ndarray:
    """(steps, N) uniform sample indices — shared by dense and sparse runs."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, q, size=(steps, n_nodes)).astype(np.int32)


@dataclasses.dataclass
class RunResult:
    """Legacy result shape of `run` and the `core.baselines.run_*` shims."""

    state: DSBAState
    iters: np.ndarray  # iteration counts at record points
    dist2: np.ndarray  # mean_n ||z_n - z*||^2 (if z_star given)
    consensus: np.ndarray  # mean_n ||z_n - zbar||^2
    zs: np.ndarray | None  # optional snapshots (chunks, N, D)


def run(
    cfg: DSBAConfig,
    data,
    w: np.ndarray,
    steps: int,
    z0: np.ndarray | None = None,
    z_star: np.ndarray | None = None,
    record_every: int = 50,
    seed: int = 0,
    keep_snapshots: bool = False,
    indices: np.ndarray | None = None,
) -> RunResult:
    """Deprecated: ``core.solvers.solve(problem, method=cfg.method)``.

    Thin shim over the registry entrypoint, kept for legacy callers and
    pinned bit-identical by ``tests/test_solvers.py``. The communication
    graph is recovered from the support of ``w`` (Section 4's sparsity
    condition makes the two equivalent). One semantic nit versus the
    original loop: when ``steps`` is not a multiple of ``record_every`` the
    trailing remainder iterations now run (and are recorded) instead of
    being silently dropped.

    indices: optional (steps, N) pre-drawn sample indices (replayable runs).
    """
    from repro.core import solvers
    from repro.core.deprecation import warn_once

    warn_once(
        "dsba.run",
        "core.dsba.run is deprecated and will be REMOVED in v0.2 (final "
        "warning); use core.solvers.solve("
        f"problem, method={cfg.method!r}) instead",
        stacklevel=2,
    )
    problem = solvers.Problem(
        spec=cfg.spec,
        data=data,
        graph=solvers.graph_from_mixing(w),
        w=w,
        lam=cfg.lam,
        z_star=z_star,
    )
    res = solvers.solve(
        problem,
        method=cfg.method,
        comm="dense",
        steps=steps,
        record_every=record_every,
        seed=seed,
        z0=z0,
        indices=indices,
        keep_snapshots=keep_snapshots,
        alpha=cfg.alpha,
    )
    return RunResult(res.state, res.iters, res.dist2, res.consensus, res.zs)

"""Centralized reference solutions and objective values for the convex tasks.

Used to measure suboptimality / distance-to-optimum in the paper-reproduction
benchmarks. Solves the *global* problem: find z* with

    (1/(N q)) sum_{n,i} B_{n,i}(z*) + lam z* = 0

For ridge and AUC the mean operator is affine, so one Newton step (via an
explicit jacobian) is exact; for logistic we run damped Newton to machine
precision. Dense features only — reference problems use moderate d.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.operators import OperatorSpec


def mean_operator(spec: OperatorSpec, data, lam: float):
    """Returns F(z) = mean_{n,i} B_{n,i}(z) + lam z as a jnp function."""
    feats = jnp.asarray(data.dense().reshape(-1, data.d))  # (Nq, d)
    labels = jnp.asarray(data.y.reshape(-1))
    t = spec.tail_dim

    def F(z):
        head, tail = z[: data.d], z[data.d :]
        u = feats @ head
        tails = jnp.broadcast_to(tail, (feats.shape[0], t))
        g, tail_out = spec.coeff_and_tail(u, labels, tails)
        out_head = feats.T @ g / feats.shape[0]
        out = jnp.concatenate([out_head, tail_out.mean(0)]) if t else out_head
        return out + lam * z

    return F


def solve_root(
    spec: OperatorSpec, data, lam: float, iters: int = 50, tol: float = 1e-14
) -> np.ndarray:
    """Newton root-finder on the mean operator. Exact for affine operators."""
    F = mean_operator(spec, data, lam)
    D = data.d + spec.tail_dim
    z = jnp.zeros((D,), dtype=jnp.asarray(data.val).dtype)
    jac = jax.jacfwd(F)
    for _ in range(iters):
        r = F(z)
        if float(jnp.linalg.norm(r)) < tol:
            break
        z = z - jnp.linalg.solve(jac(z), r)
    return np.asarray(z)


def objective(spec: OperatorSpec, data, lam: float):
    """Primal objective f(z) (ridge/logistic) or saddle value terms (AUC).

    For AUC we return the primal minimax objective F(w_bar, theta) of eq. (11)
    evaluated at z = [w; a; b; theta] — used only for reporting.
    """
    feats = jnp.asarray(data.dense().reshape(-1, data.d))
    labels = jnp.asarray(data.y.reshape(-1))
    p = spec.p

    def f(z):
        head = z[: data.d]
        u = feats @ head
        if spec.kind == "ridge":
            loss = 0.5 * jnp.mean((u - labels) ** 2)
            return loss + 0.5 * lam * jnp.sum(z * z)
        if spec.kind == "logistic":
            loss = jnp.mean(jnp.log1p(jnp.exp(-labels * u)))
            return loss + 0.5 * lam * jnp.sum(z * z)
        if spec.kind == "auc":
            a, b, th = z[data.d], z[data.d + 1], z[data.d + 2]
            pos = labels > 0
            val = (
                -p * (1 - p) * th**2
                + jnp.mean(
                    jnp.where(pos, (1 - p) * (u - a) ** 2, p * (u - b) ** 2)
                )
                + jnp.mean(
                    2
                    * (1 + th)
                    * jnp.where(pos, -(1 - p) * u, p * u)
                )
            )
            return val + 0.5 * lam * jnp.sum(z * z)
        if spec.kind == "bilinear":
            th = z[data.d]
            val = (
                jnp.mean(0.5 * (u - labels) ** 2 + th * labels * u)
                - 0.5 * spec.gamma * th**2
            )
            # regularized saddle value: +lam/2 on the primal block,
            # -lam/2 on the dual block (matches B^lam = B + lam*I).
            head_sq = jnp.sum(z[: data.d] ** 2)
            return val + 0.5 * lam * head_sq - 0.5 * lam * th**2
        raise ValueError(spec.kind)

    return f


def auc_score(w: np.ndarray, data) -> float:
    """Exact pairwise AUC of linear scorer w on the pooled dataset."""
    feats = data.dense().reshape(-1, data.d)
    labels = data.y.reshape(-1)
    scores = feats @ w
    pos, neg = scores[labels > 0], scores[labels < 0]
    if len(pos) == 0 or len(neg) == 0:
        return float("nan")
    diff = pos[:, None] - neg[None, :]
    return float(((diff > 0).mean() + 0.5 * (diff == 0).mean()))

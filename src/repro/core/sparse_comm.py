"""DSBA-s: the sparse-communication implementation of Section 5.1.

This module is the ``comm="sparse"`` backend of the solver registry —
callers go through ``core.solvers.solve(problem, method, comm="sparse")``,
which forwards backend options (``engine``, ``verify``, ``use_pallas``)
into `run_sparse` and folds its accounting into the uniform SolveResult.

Every iteration each node broadcasts ONLY its sparse update difference
delta_n^t (eq. 27) — nnz = one data sample's pattern — and every other node
reconstructs the delayed network state from received deltas via the update
recursion (eq. 28), exactly as Algorithm 2 prescribes. Messages advance one
hop per iteration along BFS trees (the F_j^t relay of the paper), so node u
learns delta_l^tau at iteration tau + xi(l, u); the duplicate-suppression
rule ("only the minimum-index neighbor forwards") means each delta is
received exactly once per node, giving the paper's O(N rho d) per-node
per-iteration communication.

Availability invariant (proved by induction in the paper):
  node u can reconstruct z_l^s at iteration t  iff  s <= t + 1 - xi(l, u),
so in particular neighbors' *current* iterates z_m^t are reconstructable at
iteration t — which is exactly what psi_n^t (eq. 29) needs.

Initialization: the t=0 update (eq. 31) involves the dense, node-private
phibar_n^0, so z^1 cannot be reconstructed from deltas alone. The protocol
therefore floods the (dense) z^1 once during warm-up — a one-time O(N d)
cost that we account for honestly. z^0 is the shared consensus initializer.

Vectorized engine (default, ``engine="vectorized"``)
----------------------------------------------------
The eq. 28 recursion is the SAME affine map for every (observer, source)
pair, so the simulator batches it instead of looping in Python:

* **Ring-buffer reconstruction.** Per-pair stores keep only the last
  ``diameter + 2`` reconstructed iterates, ``R[s % depth, u, l] =`` node u's
  copy of ``z_l^s`` — O(N^2 * diam * d) memory instead of the previous
  O(N^2 * T * d) NaN-filled array. Dense per-source deltas live in a matching
  ``(depth, N, D)`` ring.
* **Distance waves.** At iteration t, pair (u, l) at distance xi advances by
  exactly one state, ``s = t + 1 - xi``. Pairs are grouped by distance and
  advanced farthest-first (the paper's V_j ordering) so a distance-xi pair
  can consume the value its distance-(xi+1) neighbor produced this same
  iteration. Each wave is one batched gather + fused AXPY over all its pairs.
* **Single XLA program.** The whole run — warm-up flood, waves, mixing rows,
  and the shared local update (core.dsba.make_step_fn) — is one jitted
  ``lax.scan``; per-iteration state never round-trips through NumPy.
* **Closed-form message accounting.** ``doubles_received``/``ints_received``
  are computed after the scan from the per-iteration nnz log:
  ``doubles[t, u] = sum_l nnz[t - xi(u,l), l] + tail`` (+ the one-time dense
  z^1 flood of D doubles at ``t == xi``), instead of inside the hop loop.
* **Pallas hot path.** Densifying the per-node sparse deltas is routed
  through ``kernels.ops.saga_sparse_axpy`` (one-hot-matmul scatter on the
  TPU MXU; ``interpret=True`` fallback off-TPU). The interpret-mode
  compute_dtype policy lives in kernels/ops.py — f64 runs stay bit-exact
  without this module re-deriving the dtype per call site.

``verify=True`` (debug mode) additionally carries an iterate-tag ring and a
truth ring through the scan: every read is checked against the availability
invariant (a violation raises ``ProtocolViolation``) and every reconstructed
value is compared against the true trajectory, reported as
``recon_max_err``. The fast path skips both and reports ``nan``.

``engine="reference"`` is the original per-observer Python loop (kept as the
parity oracle for tests; it always verifies).

Cost model (doubles_received): a delta message carries nnz(delta) = k values
(+ tail_dim scalars for AUC); index integers are tracked separately as
`ints_received` since the paper's C_max counts DOUBLEs. Dense baselines
receive deg(n) * d doubles per iteration.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import runner_cache
from repro.core.dsba import DSBAConfig, init_state, make_step_fn
from repro.core.mixing import Graph, w_tilde
from repro.kernels.ops import saga_sparse_axpy


class ProtocolViolation(AssertionError):
    """A reconstruction consumed a value the relay had not yet delivered."""


@dataclasses.dataclass
class SparseRunResult:
    """What `run_sparse` returns — the module's output contract.

    z_trace is the TRUE trajectory (identical across engines and to a dense
    `solve(..., comm="dense")` run with the same index stream — pinned by
    parity tests);
    doubles/ints are the paper's C_max message accounting (doubles exclude
    index ints by convention); recon_max_err is nan unless `verify=True`
    (the fast path does not carry the truth ring).
    """

    z_trace: np.ndarray  # (T+1, N, D)   true trajectory (z^0 .. z^T)
    doubles_received: np.ndarray  # (T, N) cumulative DOUBLEs per node
    ints_received: np.ndarray  # (T, N) cumulative index ints per node
    recon_max_err: float  # max |reconstruction - truth|; nan unless verified
    state: object | None = None  # final solver state (schedule chaining)


@dataclasses.dataclass(frozen=True)
class _Tables:
    """Static per-graph tables for the vectorized engine (the reference
    engine keeps its own inline dist/neighbor bookkeeping, verbatim from the
    original loop, so the parity oracle stays independent)."""

    dist: np.ndarray  # (N, N) BFS distances xi
    nbr_pad: np.ndarray  # (N, A) sorted neighbors + self, padded with self
    wt_pad: np.ndarray  # (N, A) matching W~ weights (0 on padding)
    pad_mask: np.ndarray  # (N, A) True on real entries
    pairs: dict[int, tuple[np.ndarray, np.ndarray]]  # xi -> (obs, src)
    dmax: int
    depth: int  # ring-buffer depth = diameter + 2


def _protocol_tables(graph: Graph, wt: np.ndarray) -> _Tables:
    n = graph.n
    dist = np.stack([graph.distances_from(u) for u in range(n)])
    lists = [sorted(graph.neighbors(u)) + [u] for u in range(n)]
    width = max(len(x) for x in lists)
    nbr_pad = np.empty((n, width), dtype=np.int32)
    wt_pad = np.zeros((n, width), dtype=wt.dtype)
    pad_mask = np.zeros((n, width), dtype=bool)
    for u, lst in enumerate(lists):
        nbr_pad[u, : len(lst)] = lst
        nbr_pad[u, len(lst) :] = u  # padding reads a live slot, weight 0
        wt_pad[u, : len(lst)] = wt[u, lst]
        pad_mask[u, : len(lst)] = True
    dmax = int(dist.max())
    pairs = {
        xi: tuple(np.nonzero(dist == xi)) for xi in range(1, dmax + 1)
    }
    return _Tables(dist, nbr_pad, wt_pad, pad_mask, pairs, dmax,
                   depth=max(3, dmax + 2))


def _closed_form_costs(
    nnz_log: np.ndarray, dist: np.ndarray, tail: int, d_total: int,
    restart: bool = False, sent: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Cumulative (doubles, ints) per node from the per-iteration nnz log.

    The delta broadcast by source l at iteration tau reaches observer u at
    iteration tau + xi(u, l); the dense z^1 flood (d_total doubles) arrives
    exactly at t == xi. Equivalent to the reference engine's in-loop
    accounting, but one vectorized pass over the (T, N, N) arrival grid.

    ``restart=True`` (a schedule-segment resync) charges a SECOND dense
    flood at t == xi: after a graph change the segment-entry iterates z^0
    are node-private (unlike the consensus-shared initializer of a fresh
    run), so they must be flooded alongside z^1 before any delta-based
    reconstruction can proceed.

    ``sent``: optional (T, N) link-fault mask — a suppressed broadcast
    (``sent[tau, l] == False``) never arrives anywhere, so neither its
    nnz payload nor the per-message tail is charged (delivered-only
    accounting; the one-time floods are fault-exempt, see run_sparse).
    """
    steps, n = nnz_log.shape
    ts = np.arange(steps)[:, None, None]  # (T, 1, 1)
    xi = dist[None, :, :]  # (1, obs, src)
    t_src = ts - xi  # broadcast delta emission time
    arrived = (t_src >= 0) & (xi > 0)
    src = np.arange(n)[None, None, :]
    if sent is not None:
        arrived &= sent[np.clip(t_src, 0, None), src]
    nnz = nnz_log[np.clip(t_src, 0, None), src]  # (T, obs, src)
    ints_inc = np.where(arrived, nnz, 0).sum(axis=2)
    doubles_inc = np.where(arrived, nnz + tail, 0).sum(axis=2)
    floods = 2 if restart else 1
    doubles_inc += floods * d_total * ((ts == xi) & (xi > 0)).sum(axis=2)
    return np.cumsum(doubles_inc, axis=0), np.cumsum(ints_inc, axis=0)


def run_sparse(
    cfg: DSBAConfig,
    data,
    graph: Graph,
    w: np.ndarray,
    steps: int,
    indices: np.ndarray,
    z0: np.ndarray | None = None,
    *,
    state0=None,
    engine: str = "vectorized",
    verify: bool = False,
    use_pallas: str = "auto",
    sent_mask: np.ndarray | None = None,
    ckpt_every: int | None = None,
    ckpt_save=None,
    resume=None,
) -> SparseRunResult:
    """Run DSBA-s (or DSA-s) for `steps` iterations on `graph`.

    engine: "vectorized" (batched jitted scan, default) or "reference"
        (the original per-observer Python loop; always verifies).
    verify: vectorized engine only — check the availability invariant and
        compare every reconstruction against the truth (recon_max_err).
    use_pallas: "auto" routes delta densification through the Pallas kernel
        (compiled on TPU, interpret=True fallback elsewhere); "on" forces the
        compiled kernel, "interpret" forces interpret mode, and "off" uses a
        plain jnp scatter (fastest to trace on CPU).
    state0: carried DSBAState from a previous schedule segment. When given,
        the run is a RESTART on (possibly new) `graph`/`w`: the solver
        continues from state0 (its SAGA tables, deltas and step counter
        intact), the t=0 mixing is ``w_tilde(w) @ (2 z - z_prev)`` from the
        carried iterates, and the segment-entry z^0 is flooded densely
        alongside z^1 (charged in the accounting — see _closed_form_costs).
        A ``state0`` whose step counter was REANCHORED to 0 (a churn
        segment — ``solvers._elastic_remap``) instead re-runs the eq. 31
        anchored t=0 update, mixing ``w @ state0.z``. ``z0`` must be None
        in either case.
    sent_mask: optional (steps, N) bool — link-fault injection. A False
        entry suppresses that node's delta broadcast for that iteration:
        every observer's reconstruction proceeds on a zeroed delta (the
        graceful-degradation path) and the closed-form accounting charges
        neither payload nor tail for it. The one-time z^1 / restart z^0
        floods are fault-exempt (they seed the protocol; dropping them
        would desynchronize the ring permanently, not degrade it).
        Vectorized engine only, and incompatible with ``verify`` (the
        truth check asserts exact reconstruction by design).
    ckpt_every / ckpt_save / resume: crash-safe chunked execution driven
        by ``solvers.solve(..., checkpoint=/resume=)``. The scan runs in
        chunks of ``ckpt_every`` iterations; after each boundary
        ``ckpt_save(t_done, tree)`` receives the raw carry plus the
        accumulated (zs, nnzs) logs. ``resume=(t_done, leaves)`` restores
        from ``ckpt.load_checkpoint`` leaves and continues — bit-equal to
        an uninterrupted run (absolute iteration numbers ride in the scan
        xs, so chunk boundaries are invisible to the per-step math).
    """
    if steps < 1:
        raise ValueError("steps must be >= 1")
    if state0 is not None and z0 is not None:
        raise ValueError("pass either z0 (fresh start) or state0 (restart)")
    if sent_mask is not None and verify:
        raise ValueError(
            "verify=True is incompatible with a link-fault sent_mask: the "
            "relay invariant check asserts exact reconstruction, which "
            "injected faults violate by design"
        )
    if engine == "reference":
        if sent_mask is not None:
            raise ValueError(
                "link faults need engine='vectorized' (the reference "
                "per-observer oracle assumes lossless broadcasts)"
            )
        if ckpt_every is not None or resume is not None:
            raise ValueError(
                "checkpoint/resume needs engine='vectorized'"
            )
        return _run_reference(cfg, data, graph, w, steps, indices, z0,
                              state0=state0)
    if engine != "vectorized":
        raise ValueError(f"unknown engine {engine!r}")
    return _run_vectorized(
        cfg, data, graph, w, steps, indices, z0, state0=state0,
        verify=verify, use_pallas=use_pallas, sent_mask=sent_mask,
        ckpt_every=ckpt_every, ckpt_save=ckpt_save, resume=resume,
    )


# ---------------------------------------------------------------------------
# Vectorized engine
# ---------------------------------------------------------------------------

def _sparse_scan_key(cfg, data, graph, w, verify, kernel_mode,
                     faulty=False):
    """(key, guards) for one compiled relay scan (see core.runner_cache).

    alpha/lam are NOT keyed — they are traced scan arguments, so a
    hyperparameter sweep over the same (method, problem shape, graph)
    reuses one executable. ``verify`` changes the carry structure,
    ``kernel_mode`` the densification lowering, and ``faulty`` the scan
    xs (the per-iteration sent mask), so each recompiles. The fault-free
    program stays byte-identical to the pre-fault build — p=0 plans are
    bit-equal by ROUTING, not by masked arithmetic.
    """
    key = (
        "relay",
        cfg.method,
        runner_cache.problem_fingerprint(data, cfg.spec, graph, w),
        bool(verify),
        kernel_mode,
        bool(faulty),
    )
    return key, (data,)


def _build_sparse_scan(cfg, data, graph, w, *, verify, kernel_mode,
                       faulty=False):
    """Compile the whole-run relay scan with (alpha, lam) traced.

    Returns ``(scan, tb)``: the jitted
    ``scan(carry0, xs, mix0, hp) -> (carry, (zs, nnzs))`` and the static
    protocol tables (the closed-form accounting needs ``tb.dist``).
    """
    spec = cfg.spec
    n = data.n_nodes
    q = data.q
    tail = spec.tail_dim
    d = data.d
    D = d + tail
    dt = data.val.dtype

    wt = w_tilde(w)
    tb = _protocol_tables(graph, wt)
    depth, dmax = tb.depth, tb.dmax
    scale = (q - 1.0) / q

    step = make_step_fn(cfg, data, w)

    # constants baked into the compiled scan
    dist_j = jnp.asarray(tb.dist, jnp.int32)
    nbr_j = jnp.asarray(tb.nbr_pad)
    wtn_j = jnp.asarray(tb.wt_pad, dt)
    padm_j = jnp.asarray(tb.pad_mask)
    iu = jnp.arange(n)
    width = tb.nbr_pad.shape[1]

    # padded per-distance pair tables for the wave scan: row i holds the
    # (observer, source) pairs at distance xi = dmax - i, padded to the
    # widest level with masked (0, 0) entries.
    if dmax > 0:
        pmax = max(len(u) for u, _ in tb.pairs.values())
        xis = np.arange(dmax, 0, -1, dtype=np.int32)
        up_t = np.zeros((dmax, pmax), np.int32)
        lp_t = np.zeros((dmax, pmax), np.int32)
        real_t = np.zeros((dmax, pmax), bool)
        for i, xi in enumerate(xis):
            u_xi, l_xi = tb.pairs[int(xi)]
            up_t[i, : len(u_xi)] = u_xi
            lp_t[i, : len(l_xi)] = l_xi
            real_t[i, : len(u_xi)] = True
        wave_xs = (
            jnp.asarray(xis),
            jnp.asarray(up_t),
            jnp.asarray(lp_t),
            jnp.asarray(real_t),
        )
    else:
        wave_xs = None

    interpret = kernel_mode == "interpret"

    def densify_delta(st) -> jax.Array:
        """(N, D) dense delta rows from the padded-CSR delta of this step."""
        base = jnp.zeros((n, D), dt)
        if tail:
            base = base.at[:, d:].set(st.dtail_prev)
        # compute_dtype is NOT passed: kernels.ops resolves it centrally
        # (interpret -> psi.dtype, so the f64 relay stays bit-exact;
        # compiled -> f32). See the sparse_axpy registry policy.
        return saga_sparse_axpy(
            base, st.didx_prev, st.dval_prev, st.dg_prev,
            jnp.ones((n,), dt), use_pallas=kernel_mode,
            node_block=n if interpret else 1,
        )

    def neighborhood_sum(g_cur, g_prev, wts):
        """sum_m wt[.,m] * (2 z_m^s - z_m^{s-1}), reference add order."""
        acc = jnp.zeros(g_cur.shape[::2], dt)  # (P, D)
        for a in range(width):
            acc = acc + wts[:, a, None] * (2.0 * g_cur[:, a] - g_prev[:, a])
        return acc

    def scan_all(carry0, xs, mix0, hp):
        # runs only while tracing: counts compiles, not calls
        runner_cache.SPARSE.note_trace()
        alpha, lam = hp["alpha"], hp["lam"]
        return jax.lax.scan(
            lambda carry, x: body(carry, x, mix0, alpha, lam, hp), carry0, xs
        )

    def body(carry, xs, mix0, alpha, lam, hp):
        state, z1, R, DD, SR, Z, err, ok = carry
        if faulty:
            t, i_t, sent_t = xs
        else:
            t, i_t = xs
        jt = t % depth
        jtm1 = (t - 1) % depth
        z_t = state.z

        # -- own history: z^t is exact and free (computed locally last step)
        R = R.at[jt, iu, iu].set(z_t)
        if verify:
            SR = SR.at[jt, iu, iu].set(t)
            Z = Z.at[jt].set(z_t)
        z1 = jnp.where(t == 1, z_t, z1)

        # -- one-time dense z^1 warm-up flood arrives at t == xi ------------
        def flood(ops):
            R_, SR_ = ops
            mask = dist_j == t
            R_ = R_.at[1].set(
                jnp.where(mask[:, :, None], z1[None, :, :], R_[1])
            )
            if verify:
                SR_ = SR_.at[1].set(jnp.where(mask, 1, SR_[1]))
            return R_, SR_

        R, SR = jax.lax.cond(
            (t >= 1) & (t <= dmax), flood, lambda ops: ops, (R, SR)
        )

        # -- reconstruction waves, farthest-first (paper's V_j ordering) ----
        # One inner scan over distance levels xi = dmax..1: every pair at
        # distance xi advances by exactly one reconstructed state,
        # s = t + 1 - xi. Warm-up (t <= xi) and row padding are handled by
        # masking the write: reads of not-yet-valid slots hit
        # zero-initialized memory (finite), and the value is discarded.
        def wave(wc, wx):
            R_, SR_, err_, ok_ = wc
            xi, up, lp, real = wx
            s = t + 1 - xi
            j1, j2, jn = (s - 1) % depth, (s - 2) % depth, s % depth
            m_idx = nbr_j[lp]  # (P, A)
            G1 = R_[j1, up[:, None], m_idx]  # (P, A, D) one fused gather
            G2 = R_[j2, up[:, None], m_idx]
            mix = neighborhood_sum(G1, G2, wtn_j[lp])
            corr = alpha * (scale * DD[j2, lp] - DD[j1, lp])
            self1 = R_[j1, up, lp]
            if cfg.method == "dsba":
                new = (mix + alpha * lam * self1 + corr) / (1.0 + alpha * lam)
            else:  # dsa
                self2 = R_[j2, up, lp]
                new = mix + corr - alpha * lam * (self1 - self2)
            write = real & (t >= xi + 1)  # (P,)
            new = jnp.where(write[:, None], new, R_[jn, up, lp])
            R_ = R_.at[jn, up, lp].set(new)
            if verify:
                S1 = SR_[j1, up[:, None], m_idx]
                S2 = SR_[j2, up[:, None], m_idx]
                reads = (S1 == s - 1) & (S2 == s - 2)
                checked = padm_j[lp] & write[:, None]
                ok_ &= jnp.all(jnp.where(checked, reads, True))
                SR_ = SR_.at[jn, up, lp].set(
                    jnp.where(write, s, SR_[jn, up, lp])
                )
                err_ = jnp.maximum(
                    err_,
                    jnp.max(
                        jnp.where(
                            write[:, None], jnp.abs(new - Z[jn, lp]), 0.0
                        )
                    ),
                )
            return (R_, SR_, err_, ok_), None

        if dmax > 0:
            (R, SR, err, ok), _ = jax.lax.scan(
                wave, (R, SR, err, ok), wave_xs
            )

        # -- mixing rows from each node's OWN reconstruction store ----------
        g_cur = R[jt, iu[:, None], nbr_j]  # (N, A, D)
        g_prev = R[jtm1, iu[:, None], nbr_j]
        mix_rows = neighborhood_sum(g_cur, g_prev, wtn_j)
        mix_rows = jnp.where(t == 0, mix0, mix_rows)
        if verify:
            s_cur = SR[jt, iu[:, None], nbr_j]
            s_prev = SR[jtm1, iu[:, None], nbr_j]
            ok &= (t == 0) | jnp.all(
                jnp.where(padm_j, (s_cur == t) & (s_prev == t - 1), True)
            )

        # -- advance all nodes with the shared local update -----------------
        state = step(state, i_t, mix_rows, hp=hp)
        dd = densify_delta(state)
        nnz_t = jnp.sum(state.dval_prev != 0, axis=-1).astype(jnp.int32)
        if faulty:
            # a suppressed broadcast: observers see a ZEROED delta in the
            # ring (their reconstructions degrade gracefully) and the nnz
            # log drops the row (delivered-only accounting). The source's
            # own row of R stays exact — a node always has its own state.
            dd = jnp.where(sent_t[:, None], dd, jnp.zeros_like(dd))
            nnz_t = jnp.where(sent_t, nnz_t, 0)
        DD = DD.at[jt].set(dd)
        return (state, z1, R, DD, SR, Z, err, ok), (state.z, nnz_t)

    return jax.jit(scan_all), tb


def _relay_carry0(cfg, data, z0, depth, verify, state0=None):
    """The relay scan's initial carry at the shared starting point ``z0``.

    With ``state0`` (a schedule-segment restart) the carried solver state is
    used as-is and the reconstruction ring is seeded with its iterates: the
    segment-entry z^0 := state0.z is flooded at segment start (see
    _closed_form_costs), so every observer's store legitimately holds it.
    """
    n = data.n_nodes
    D = data.d + cfg.spec.tail_dim
    dt = data.val.dtype
    if state0 is not None:
        z0 = state0.z
    else:
        state0 = init_state(cfg, data, jnp.asarray(z0))
    R0 = jnp.zeros((depth, n, n, D), dt)
    R0 = R0.at[0].set(jnp.broadcast_to(jnp.asarray(z0, dt), (n, n, D)))
    DD0 = jnp.zeros((depth, n, D), dt)
    if verify:
        SR0 = jnp.full((depth, n, n), -(2**30), jnp.int32).at[0].set(0)
        Z0 = jnp.zeros((depth, n, D), dt).at[0].set(jnp.asarray(z0, dt))
    else:  # zero-size placeholders keep the carry structure uniform
        SR0 = jnp.zeros((0,), jnp.int32)
        Z0 = jnp.zeros((0,), dt)
    return (
        state0,
        jnp.zeros((n, D), dt),  # z^1, captured at t == 1
        R0,
        DD0,
        SR0,
        Z0,
        jnp.zeros((), dt),
        jnp.ones((), bool),
    )


def _resolve_kernel_mode(use_pallas: str) -> str:
    """Resolve the relay's ``use_pallas`` option to a concrete kernel mode."""
    if use_pallas not in ("auto", "on", "interpret", "off"):
        raise ValueError(f"unknown use_pallas mode {use_pallas!r}")
    if use_pallas == "auto":
        return "on" if jax.default_backend() == "tpu" else "interpret"
    return use_pallas


def _carry_from_leaves(carry0, leaves):
    """Rebuild a relay carry from ``ckpt.load_checkpoint`` leaves.

    ``carry0`` templates the structure (the carry is run-length
    independent); leaves are path-matched under the ``{"carry": ...}``
    wrapper the checkpointing driver saved them with.
    """
    from repro.ckpt.checkpoint import _flatten_with_paths

    paths, tleaves, treedef = _flatten_with_paths({"carry": carry0})
    new = []
    for p, like in zip(paths, tleaves):
        if p not in leaves:
            raise ValueError(f"checkpoint is missing carry leaf {p!r}")
        new.append(jnp.asarray(leaves[p], getattr(like, "dtype", None)))
    return jax.tree_util.tree_unflatten(treedef, new)["carry"]


def _run_vectorized(
    cfg, data, graph, w, steps, indices, z0, *, state0=None, verify,
    use_pallas, sent_mask=None, ckpt_every=None, ckpt_save=None,
    resume=None,
) -> SparseRunResult:
    spec = cfg.spec
    n = data.n_nodes
    tail = spec.tail_dim
    D = data.d + tail
    dt = data.val.dtype
    restart = state0 is not None
    reanchored = restart and int(np.asarray(state0.step)) == 0
    if restart:
        z0 = np.asarray(state0.z)
    elif z0 is None:
        z0 = np.zeros((n, D), dtype=dt)
    faulty = sent_mask is not None
    if faulty:
        sent_mask = np.asarray(sent_mask, dtype=bool)
        if sent_mask.shape != (steps, n):
            raise ValueError(
                f"sent_mask must be (steps, N) = ({steps}, {n}), "
                f"got {sent_mask.shape}"
            )

    # This path follows the protocol spec rather than kernels.ops "auto"
    # (which falls back to the jnp oracle off-TPU): the relay's delta
    # densification stays on the Pallas kernel everywhere, interpret=True
    # being the CPU fallback. Resolve "auto" here, dispatch through ops.
    kernel_mode = _resolve_kernel_mode(use_pallas)

    key, guards = _sparse_scan_key(
        cfg, data, graph, w, verify, kernel_mode, faulty=faulty
    )
    scan, tb = runner_cache.SPARSE.get_or_build(
        key, guards,
        lambda: _build_sparse_scan(
            cfg, data, graph, w, verify=verify, kernel_mode=kernel_mode,
            faulty=faulty,
        ),
    )
    depth, dmax = tb.depth, tb.dmax

    carry0 = _relay_carry0(cfg, data, z0, depth, verify, state0=state0)
    ts = jnp.arange(steps, dtype=jnp.int32)
    idx_j = jnp.asarray(indices[:steps], jnp.int32)
    if reanchored:
        # a churn-remapped state: the step counter was reset to 0 (the
        # DSBA reanchor), so the scan's first iteration re-runs the
        # eq. 31 anchored update — its t=0 mixing is W against the
        # remapped iterates. The restart z^0 flood is still charged:
        # post-churn iterates are node-private, not consensus-shared.
        mix0 = jnp.asarray(w @ np.asarray(state0.z), dt)
    elif restart:
        # carried state: step > 0 routes through the eq. 29 psi path, whose
        # t=0 mixing is W~ against (2 z - z_prev) of the carried iterates
        mix0 = jnp.asarray(
            w_tilde(w) @ (2.0 * np.asarray(state0.z)
                          - np.asarray(state0.z_prev)), dt
        )
    else:
        mix0 = jnp.asarray(w @ z0, dt)  # t=0: z^0 is consensus-shared
    hp = {"alpha": float(cfg.alpha), "lam": float(cfg.lam)}

    def seg_xs(lo, hi):
        xs = (ts[lo:hi], idx_j[lo:hi])
        if faulty:
            xs = (*xs, jnp.asarray(sent_mask[lo:hi]))
        return xs

    if ckpt_every is None and resume is None:
        carry_f, (zs, nnzs) = scan(carry0, seg_xs(0, steps), mix0, hp)
        zs, nnzs = np.asarray(zs), np.asarray(nnzs)
    else:
        # chunked execution of the SAME cached scan: absolute iteration
        # numbers ride in the xs, so chunk boundaries are invisible to
        # the per-step math — resumed runs are bit-equal to uninterrupted
        start = 0
        carry = carry0
        zs_parts, nnz_parts = [], []
        if resume is not None:
            t_done, leaves = resume
            if not 0 < t_done <= steps:
                raise ValueError(
                    f"resume step {t_done} outside (0, {steps}]"
                )
            carry = _carry_from_leaves(carry0, leaves)
            zs_parts.append(np.asarray(leaves["['zs']"]))
            nnz_parts.append(np.asarray(leaves["['nnzs']"]))
            start = int(t_done)
        every = int(ckpt_every) if ckpt_every is not None else steps
        marks = sorted({*range(start + every, steps, every), steps})
        prev = start
        for mk in marks:
            if mk <= prev:
                continue  # resumed at (or past) this boundary already
            carry, (zs_c, nnz_c) = scan(carry, seg_xs(prev, mk), mix0, hp)
            zs_parts.append(np.asarray(zs_c))
            nnz_parts.append(np.asarray(nnz_c))
            prev = mk
            if ckpt_save is not None and mk % every == 0:
                ckpt_save(mk, {
                    "carry": carry,
                    "zs": np.concatenate(zs_parts),
                    "nnzs": np.concatenate(nnz_parts),
                })
        carry_f = carry
        zs = np.concatenate(zs_parts)
        nnzs = np.concatenate(nnz_parts)
    state_f, err, ok = carry_f[0], carry_f[-2], carry_f[-1]

    if verify and not bool(ok):
        raise ProtocolViolation(
            "relay schedule consumed a value before its arrival"
        )
    z_trace = np.concatenate([np.asarray(z0)[None], zs])
    doubles, ints = _closed_form_costs(
        nnzs, tb.dist, tail, D, restart=restart, sent=sent_mask
    )
    return SparseRunResult(
        z_trace=z_trace,
        doubles_received=doubles,
        ints_received=ints,
        recon_max_err=float(err) if verify else float("nan"),
        state=state_f,
    )


def run_sparse_many(
    cfg: DSBAConfig,
    data,
    graph: Graph,
    w: np.ndarray,
    steps: int,
    indices: np.ndarray,
    alphas,
    z0: np.ndarray | None = None,
    *,
    verify: bool = False,
    use_pallas: str = "auto",
) -> list[SparseRunResult]:
    """Run B relay sweeps as ONE vmapped scan: per-run seeds and alphas.

    ``indices`` is (B, >= steps, N) — one sample stream per run — and
    ``alphas`` a length-B sequence of step sizes (``cfg.alpha`` is ignored;
    ``cfg.lam``/``cfg.method`` are shared). The compiled relay scan is the
    SAME cached executable family as ``run_sparse``'s (hp values are traced
    arguments), wrapped in ``jax.vmap`` over (carry, indices, alpha) and
    re-jitted once per batch size. The per-run message accounting is
    already hoisted out of the scan (closed form over the nnz log), so
    batching adds no accounting approximation — results are bit-identical
    to B sequential ``run_sparse`` calls (pinned in tests/test_solvers.py).

    The starting point ``z0`` is shared across runs (it is consensus
    state, not a sweep axis). Returns one SparseRunResult per run.
    """
    spec = cfg.spec
    n = data.n_nodes
    tail = spec.tail_dim
    D = data.d + tail
    dt = data.val.dtype
    if z0 is None:
        z0 = np.zeros((n, D), dtype=dt)
    indices = np.asarray(indices)
    B = len(alphas)
    if indices.ndim != 3 or indices.shape[0] != B or indices.shape[1] < steps:
        raise ValueError(
            f"indices must be (B, >= steps, N) = ({B}, >={steps}, {n}), "
            f"got {indices.shape}"
        )
    kernel_mode = _resolve_kernel_mode(use_pallas)

    key, guards = _sparse_scan_key(cfg, data, graph, w, verify, kernel_mode)
    scan, tb = runner_cache.SPARSE.get_or_build(
        key, guards,
        lambda: _build_sparse_scan(
            cfg, data, graph, w, verify=verify, kernel_mode=kernel_mode
        ),
    )
    # The batched variant lives in the same cache under a derived key, so
    # it shares the LRU/stats machinery and is evicted with its parent.
    scan_b = runner_cache.SPARSE.get_or_build(
        ("batched", key), guards,
        lambda: jax.jit(jax.vmap(
            scan, in_axes=(0, (None, 0), None, {"alpha": 0, "lam": None})
        )),
    )

    carry0 = _relay_carry0(cfg, data, z0, tb.depth, verify)
    carry0_b = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (B,) + x.shape), carry0
    )
    ts = jnp.arange(steps, dtype=jnp.int32)
    idx_j = jnp.asarray(indices[:, :steps], jnp.int32)
    mix0 = jnp.asarray(w @ z0, dt)  # t=0 mixing: z^0 is consensus-shared
    # alphas in the DATA dtype: batched arithmetic then promotes exactly
    # like the sequential path's weak-typed python-float scalar
    hp = {"alpha": jnp.asarray(np.asarray(alphas, dtype=dt)),
          "lam": float(cfg.lam)}

    (_, _, _, _, _, _, err, ok), (zs, nnzs) = scan_b(
        carry0_b, (ts, idx_j), mix0, hp
    )

    if verify and not np.all(np.asarray(ok)):
        raise ProtocolViolation(
            "relay schedule consumed a value before its arrival"
        )
    zs = np.asarray(zs)
    nnzs = np.asarray(nnzs)
    err = np.asarray(err)
    out = []
    for b in range(B):
        doubles, ints = _closed_form_costs(nnzs[b], tb.dist, tail, D)
        out.append(SparseRunResult(
            z_trace=np.concatenate([np.asarray(z0)[None], zs[b]]),
            doubles_received=doubles,
            ints_received=ints,
            recon_max_err=float(err[b]) if verify else float("nan"),
        ))
    return out


# ---------------------------------------------------------------------------
# Reference engine — the original per-observer loop (parity oracle). Slow:
# O(N^2 T) Python-level reconstruct calls and an O(N^2 T D) store.
# ---------------------------------------------------------------------------

def _run_reference(
    cfg, data, graph, w, steps, indices, z0=None, state0=None
) -> SparseRunResult:
    spec = cfg.spec
    alpha, lam = cfg.alpha, cfg.lam
    n = data.n_nodes
    q, k = data.q, data.k
    tail = spec.tail_dim
    d = data.d
    D = d + tail
    dt = data.val.dtype
    restart = state0 is not None
    if restart:
        z0 = np.asarray(state0.z)
    elif z0 is None:
        z0 = np.zeros((n, D), dtype=dt)

    dist = np.stack([graph.distances_from(u) for u in range(n)])  # (N, N)
    wt = w_tilde(w)
    neighbors = {u: sorted(graph.neighbors(u)) for u in range(n)}

    state = state0 if restart else init_state(cfg, data, jnp.asarray(z0))
    step_fn = jax.jit(make_step_fn(cfg, data, w))

    # --- per-observer reconstruction stores ---------------------------------
    # recon[u, l, s] = node u's reconstruction of z_l^s (NaN = not yet known)
    recon = np.full((n, n, steps + 2, D), np.nan, dtype=dt)
    recon[:, :, 0, :] = z0[None, :, :]
    s_next = np.full((n, n), 2, dtype=np.int64)  # next s to reconstruct

    # true trajectory + delta log (the scheduler enforces availability)
    z_hist = np.zeros((steps + 2, n, D), dtype=dt)
    z_hist[0] = z0
    dg_log = np.zeros((steps, n), dtype=dt)
    didx_log = np.zeros((steps, n, k), dtype=np.int64)
    dval_log = np.zeros((steps, n, k), dtype=dt)
    dtail_log = np.zeros((steps, n, tail), dtype=dt)

    doubles = np.zeros((steps, n), dtype=np.int64)
    ints = np.zeros((steps, n), dtype=np.int64)
    recon_err = 0.0

    def delta_vec(t_src, l):
        v = np.zeros(D, dtype=dt)
        np.add.at(v[:d], didx_log[t_src, l], dg_log[t_src, l] * dval_log[t_src, l])
        if tail:
            v[d:] += dtail_log[t_src, l]
        return v

    def reconstruct(u, l, s, t):
        """z_l^s from u's store via the update recursion (eq. 28 + lam)."""
        mix = np.zeros(D, dtype=dt)
        for m in neighbors[l] + [l]:
            zm1 = recon[u, m, s - 1]
            zm2 = recon[u, m, s - 2]
            assert not np.isnan(zm1).any(), ("recon needs", u, m, s - 1, "at", t)
            assert not np.isnan(zm2).any(), ("recon needs", u, m, s - 2, "at", t)
            mix += wt[l, m] * (2.0 * zm1 - zm2)
        dm1 = delta_vec(s - 1, l)
        dm2 = delta_vec(s - 2, l)
        corr = alpha * ((q - 1.0) / q * dm2 - dm1)
        if cfg.method == "dsba":
            return (mix + alpha * lam * recon[u, l, s - 1] + corr) / (
                1.0 + alpha * lam
            )
        # dsa
        return mix + corr - alpha * lam * (recon[u, l, s - 1] - recon[u, l, s - 2])

    for t in range(steps):
        # ---- message arrivals + reconstruction, per observer --------------
        if t >= 1:
            for u in range(n):
                # own history is exact and free (z^t was computed locally
                # at the end of the previous iteration)
                recon[u, u, : t + 1, :] = z_hist[: t + 1, u]
                # arrivals first: dense z^1 warm-up flood + today's deltas
                for l in range(n):
                    if l == u:
                        continue
                    xi = dist[u, l]
                    if t == xi:
                        recon[u, l, 1] = z_hist[1, l]
                        doubles[t, u] += D  # one-time dense z^1 flood
                        if restart:
                            doubles[t, u] += D  # z^0 resync flood
                    if t - xi >= 0:
                        nnz = int((dval_log[t - xi, l] != 0).sum())
                        doubles[t, u] += nnz + tail
                        ints[t, u] += nnz
                # reconstruct farthest-first (paper's V_j ordering): a node
                # at distance xi+1 must advance before its distance-xi
                # neighbor consumes its s-1 value this same iteration.
                order = sorted(
                    (l for l in range(n) if l != u),
                    key=lambda l: -dist[u, l],
                )
                for l in order:
                    xi = dist[u, l]
                    while s_next[u, l] <= t + 1 - xi:
                        s = int(s_next[u, l])
                        # availability: uses delta_l^{s-1}; assert schedule
                        assert (s - 1) + xi <= t, (u, l, s, t)
                        recon[u, l, s] = reconstruct(u, l, s, t)
                        s_next[u, l] = s + 1

        # ---- mixing rows from each node's OWN reconstruction store --------
        if t == 0 and restart and int(np.asarray(state0.step)) == 0:
            # churn-reanchored state (step counter reset to 0): the scan
            # re-runs the eq. 31 anchored update, mixing W @ z
            mix = w @ np.asarray(state0.z)
        elif t == 0 and restart:
            # carried state: the eq. 29 psi path mixes W~ against
            # (2 z - z_prev) of the carried iterates
            mix = wt @ (2.0 * np.asarray(state0.z)
                        - np.asarray(state0.z_prev))
        elif t == 0:
            mix = w @ z_hist[0]  # z^0 is consensus-shared; local compute
        else:
            mix = np.zeros((n, D), dtype=dt)
            for u in range(n):
                for m in neighbors[u] + [u]:
                    zm_t = recon[u, m, t]
                    zm_tm1 = recon[u, m, t - 1]
                    assert not np.isnan(zm_t).any(), (u, m, t)
                    assert not np.isnan(zm_tm1).any(), (u, m, t - 1)
                    mix[u] += wt[u, m] * (2.0 * zm_t - zm_tm1)

        # ---- advance all nodes with the shared local update ----------------
        i_t = jnp.asarray(indices[t], jnp.int32)
        state = step_fn(state, i_t, jnp.asarray(mix))
        z_hist[t + 1] = np.asarray(state.z)
        dg_log[t] = np.asarray(state.dg_prev)
        didx_log[t] = np.asarray(state.didx_prev)
        dval_log[t] = np.asarray(state.dval_prev)
        if tail:
            dtail_log[t] = np.asarray(state.dtail_prev)

        # ---- verify reconstructions against truth --------------------------
        if t >= 1:
            for u in range(n):
                for l in range(n):
                    if l == u:
                        continue
                    hi = int(s_next[u, l])
                    diff = recon[u, l, 1:hi] - z_hist[1:hi, l]
                    diff = diff[~np.isnan(diff)]
                    if diff.size:
                        recon_err = max(recon_err, float(np.abs(diff).max()))

    return SparseRunResult(
        z_trace=z_hist[: steps + 1],
        doubles_received=np.cumsum(doubles, axis=0),
        ints_received=np.cumsum(ints, axis=0),
        recon_max_err=recon_err,
        state=state,
    )


# ---------------------------------------------------------------------------
# Closed-form communication cost models (validated against the simulator) —
# used by benchmarks for long horizons without running the full protocol.
# ---------------------------------------------------------------------------

def sparse_doubles_per_iter(n_nodes: int, k: int, tail_dim: int) -> int:
    """Steady-state DOUBLEs received per node per iteration under DSBA-s."""
    return (n_nodes - 1) * (k + tail_dim)


def dense_doubles_per_iter(graph: Graph, d_total: int) -> np.ndarray:
    """Per-node DOUBLEs received per iteration with dense neighbor exchange."""
    return graph.degrees * d_total

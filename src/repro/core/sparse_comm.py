"""DSBA-s: the sparse-communication implementation of Section 5.1.

Every iteration each node broadcasts ONLY its sparse update difference
delta_n^t (eq. 27) — nnz = one data sample's pattern — and every other node
reconstructs the delayed network state from received deltas via the update
recursion (eq. 28), exactly as Algorithm 2 prescribes. Messages advance one
hop per iteration along BFS trees (the F_j^t relay of the paper), so node u
learns delta_l^tau at iteration tau + xi(l, u); the duplicate-suppression
rule ("only the minimum-index neighbor forwards") means each delta is
received exactly once per node, giving the paper's O(N rho d) per-node
per-iteration communication.

Availability invariant (proved by induction in the paper; asserted here):
  node u can reconstruct z_l^s at iteration t  iff  s <= t + 1 - xi(l, u),
so in particular neighbors' *current* iterates z_m^t are reconstructable at
iteration t — which is exactly what psi_n^t (eq. 29) needs.

Initialization: the t=0 update (eq. 31) involves the dense, node-private
phibar_n^0, so z^1 cannot be reconstructed from deltas alone. The protocol
therefore floods the (dense) z^1 once during warm-up — a one-time O(N d)
cost that we account for honestly. z^0 is the shared consensus initializer.

The simulator advances all nodes with the SAME jitted local update as the
dense runtime (core.dsba.dsba_step), feeding each node a mixing row built
solely from its own reconstruction store — i.e. from information that the
relay schedule has actually delivered. Reconstructions are additionally
checked against the true trajectory (they agree to machine precision; any
formula error in (28)/(35) would explode this).

Cost model (doubles_received): a delta message carries nnz(delta) = k values
(+ tail_dim scalars for AUC); index integers are tracked separately as
`ints_received` since the paper's C_max counts DOUBLEs. Dense baselines
receive deg(n) * d doubles per iteration.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dsba import DSBAConfig, dsba_step, init_state
from repro.core.mixing import Graph, w_tilde


@dataclasses.dataclass
class SparseRunResult:
    z_trace: np.ndarray  # (T+1, N, D)   true trajectory (z^0 .. z^T)
    doubles_received: np.ndarray  # (T, N) cumulative DOUBLEs per node
    ints_received: np.ndarray  # (T, N) cumulative index ints per node
    recon_max_err: float  # max |reconstruction - truth| over the run


def run_sparse(
    cfg: DSBAConfig,
    data,
    graph: Graph,
    w: np.ndarray,
    steps: int,
    indices: np.ndarray,
    z0: np.ndarray | None = None,
) -> SparseRunResult:
    """Run DSBA-s (or DSA-s) for `steps` iterations on `graph`."""
    spec = cfg.spec
    alpha, lam = cfg.alpha, cfg.lam
    n = data.n_nodes
    q, k = data.q, data.k
    tail = spec.tail_dim
    d = data.d
    D = d + tail
    dt = data.val.dtype
    if z0 is None:
        z0 = np.zeros((n, D), dtype=dt)

    dist = np.stack([graph.distances_from(u) for u in range(n)])  # (N, N)
    wt = w_tilde(w)
    neighbors = {u: sorted(graph.neighbors(u)) for u in range(n)}

    state = init_state(cfg, data, jnp.asarray(z0))
    idx_j = jnp.asarray(data.idx)
    val_j = jnp.asarray(data.val)
    y_j = jnp.asarray(data.y)
    w_j = jnp.asarray(w, dt)
    wt_j = jnp.asarray(wt, dt)

    step_fn = jax.jit(
        lambda st, i_t, mix: dsba_step(cfg, w_j, wt_j, idx_j, val_j, y_j, st, i_t, mix)
    )

    # --- per-observer reconstruction stores ---------------------------------
    # recon[u, l, s] = node u's reconstruction of z_l^s (NaN = not yet known)
    recon = np.full((n, n, steps + 2, D), np.nan, dtype=dt)
    recon[:, :, 0, :] = z0[None, :, :]
    s_next = np.full((n, n), 2, dtype=np.int64)  # next s to reconstruct

    # true trajectory + delta log (the scheduler enforces availability)
    z_hist = np.zeros((steps + 2, n, D), dtype=dt)
    z_hist[0] = z0
    dg_log = np.zeros((steps, n), dtype=dt)
    didx_log = np.zeros((steps, n, k), dtype=np.int64)
    dval_log = np.zeros((steps, n, k), dtype=dt)
    dtail_log = np.zeros((steps, n, tail), dtype=dt)

    doubles = np.zeros((steps, n), dtype=np.int64)
    ints = np.zeros((steps, n), dtype=np.int64)
    recon_err = 0.0

    def delta_vec(t_src, l):
        v = np.zeros(D, dtype=dt)
        np.add.at(v[:d], didx_log[t_src, l], dg_log[t_src, l] * dval_log[t_src, l])
        if tail:
            v[d:] += dtail_log[t_src, l]
        return v

    def reconstruct(u, l, s, t):
        """z_l^s from u's store via the update recursion (eq. 28 + lam)."""
        mix = np.zeros(D, dtype=dt)
        for m in neighbors[l] + [l]:
            zm1 = recon[u, m, s - 1]
            zm2 = recon[u, m, s - 2]
            assert not np.isnan(zm1).any(), ("recon needs", u, m, s - 1, "at", t)
            assert not np.isnan(zm2).any(), ("recon needs", u, m, s - 2, "at", t)
            mix += wt[l, m] * (2.0 * zm1 - zm2)
        dm1 = delta_vec(s - 1, l)
        dm2 = delta_vec(s - 2, l)
        corr = alpha * ((q - 1.0) / q * dm2 - dm1)
        if cfg.method == "dsba":
            return (mix + alpha * lam * recon[u, l, s - 1] + corr) / (
                1.0 + alpha * lam
            )
        # dsa
        return mix + corr - alpha * lam * (recon[u, l, s - 1] - recon[u, l, s - 2])

    for t in range(steps):
        # ---- message arrivals + reconstruction, per observer --------------
        if t >= 1:
            for u in range(n):
                # own history is exact and free (z^t was computed locally
                # at the end of the previous iteration)
                recon[u, u, : t + 1, :] = z_hist[: t + 1, u]
                # arrivals first: dense z^1 warm-up flood + today's deltas
                for l in range(n):
                    if l == u:
                        continue
                    xi = dist[u, l]
                    if t == xi:
                        recon[u, l, 1] = z_hist[1, l]
                        doubles[t, u] += D  # one-time dense z^1 flood
                    if t - xi >= 0:
                        nnz = int((dval_log[t - xi, l] != 0).sum())
                        doubles[t, u] += nnz + tail
                        ints[t, u] += nnz
                # reconstruct farthest-first (paper's V_j ordering): a node
                # at distance xi+1 must advance before its distance-xi
                # neighbor consumes its s-1 value this same iteration.
                order = sorted(
                    (l for l in range(n) if l != u),
                    key=lambda l: -dist[u, l],
                )
                for l in order:
                    xi = dist[u, l]
                    while s_next[u, l] <= t + 1 - xi:
                        s = int(s_next[u, l])
                        # availability: uses delta_l^{s-1}; assert schedule
                        assert (s - 1) + xi <= t, (u, l, s, t)
                        recon[u, l, s] = reconstruct(u, l, s, t)
                        s_next[u, l] = s + 1

        # ---- mixing rows from each node's OWN reconstruction store --------
        if t == 0:
            mix = w @ z_hist[0]  # z^0 is consensus-shared; local compute
        else:
            mix = np.zeros((n, D), dtype=dt)
            for u in range(n):
                for m in neighbors[u] + [u]:
                    zm_t = recon[u, m, t]
                    zm_tm1 = recon[u, m, t - 1]
                    assert not np.isnan(zm_t).any(), (u, m, t)
                    assert not np.isnan(zm_tm1).any(), (u, m, t - 1)
                    mix[u] += wt[u, m] * (2.0 * zm_t - zm_tm1)

        # ---- advance all nodes with the shared local update ----------------
        i_t = jnp.asarray(indices[t], jnp.int32)
        prev_table = state.table_g
        state = step_fn(state, i_t, jnp.asarray(mix))
        z_hist[t + 1] = np.asarray(state.z)
        dg_log[t] = np.asarray(state.dg_prev)
        didx_log[t] = np.asarray(state.didx_prev)
        dval_log[t] = np.asarray(state.dval_prev)
        if tail:
            dtail_log[t] = np.asarray(state.dtail_prev)

        # ---- verify reconstructions against truth --------------------------
        if t >= 1:
            for u in range(n):
                for l in range(n):
                    if l == u:
                        continue
                    hi = int(s_next[u, l])
                    diff = recon[u, l, 1:hi] - z_hist[1:hi, l]
                    diff = diff[~np.isnan(diff)]
                    if diff.size:
                        recon_err = max(recon_err, float(np.abs(diff).max()))

    return SparseRunResult(
        z_trace=z_hist[: steps + 1],
        doubles_received=np.cumsum(doubles, axis=0),
        ints_received=np.cumsum(ints, axis=0),
        recon_max_err=recon_err,
    )


# ---------------------------------------------------------------------------
# Closed-form communication cost models (validated against the simulator) —
# used by benchmarks for long horizons without running the full protocol.
# ---------------------------------------------------------------------------

def sparse_doubles_per_iter(n_nodes: int, k: int, tail_dim: int) -> int:
    """Steady-state DOUBLEs received per node per iteration under DSBA-s."""
    return (n_nodes - 1) * (k + tail_dim)


def dense_doubles_per_iter(graph: Graph, d_total: int) -> np.ndarray:
    """Per-node DOUBLEs received per iteration with dense neighbor exchange."""
    return graph.degrees * d_total

"""Pluggable communication primitives: how a solver's mixing step executes.

Every solver in ``core.solvers`` is written against two primitives instead
of a literal matmul (docs/solvers.md has the authoring contract):

* ``comm.matvec(M, dtype)`` returns ``mix(X)`` computing ``M @ X`` for a
  graph-supported matrix ``M`` (off-diagonal nonzeros only on edges of the
  communication graph — W, W~, the Laplacian and I - W all qualify);
* ``comm.local(x)`` returns the caller's node-block of a leading-N array
  (the node-local data slice inside the traced step).

``DenseComm`` is the single-device backend: ``mix`` is the matmul itself
and ``local`` is the identity, so the compiled step is byte-for-byte the
pre-refactor inlined ``W @ X`` program. ``ShardedComm`` places one graph
node per device of a ``"node"``-axis mesh (``launch.mesh.make_node_mesh``)
and executes ``mix`` as real neighbor exchange: the graph's edges are
greedily edge-colored into matchings and each matching becomes ONE
``lax.ppermute`` carrying both directions, so a step moves O(deg) blocks
per node — never O(N) — and the emitted ``collective-permute`` ops are
measurable from HLO (``launch.hlo_analysis.collective_stats``).

The ``shard_map`` import shim below is the compatibility machinery shared
with ``core.gossip`` (jax >= 0.5 promotes it out of experimental).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.mixing import Graph

if hasattr(jax, "shard_map"):  # jax >= 0.5
    shard_map = jax.shard_map
else:  # jax 0.4.x keeps it under experimental
    from jax.experimental.shard_map import shard_map  # noqa: F401

NODE_AXIS = "node"


def edge_coloring(edges, n: int) -> list[list[tuple[int, int]]]:
    """Greedy proper edge coloring: partition ``edges`` into matchings.

    Each color class touches every node at most once, so its edges — both
    directions — fit in a single ``lax.ppermute`` (whose source/dest lists
    must each be distinct). Greedy over the sorted edge list uses at most
    2*maxdeg - 1 colors (Vizing needs maxdeg + 1; the difference is a few
    extra ppermutes, not correctness) and is deterministic, keeping the
    compiled HLO stable across processes.
    """
    colors: list[list[tuple[int, int]]] = []
    busy: list[set[int]] = []
    for i, j in sorted(edges):
        for c, nodes in enumerate(busy):
            if i not in nodes and j not in nodes:
                colors[c].append((i, j))
                nodes.update((i, j))
                break
        else:
            colors.append([(i, j)])
            busy.append({i, j})
    return colors


def _check_support(m: np.ndarray, graph: Graph, atol: float = 0.0) -> None:
    """Reject matrices with off-diagonal mass outside the graph's edges."""
    mask = np.zeros((graph.n, graph.n), dtype=bool)
    for i, j in graph.edges:
        mask[i, j] = mask[j, i] = True
    np.fill_diagonal(mask, True)
    bad = np.abs(np.where(mask, 0.0, m))
    if bad.max(initial=0.0) > atol:
        i, j = np.unravel_index(int(bad.argmax()), bad.shape)
        raise ValueError(
            f"matrix entry ({i}, {j}) = {m[i, j]} is nonzero but ({i}, {j}) "
            "is not an edge of the communication graph; sharded mixing only "
            "moves data along edges"
        )


class DenseComm:
    """Single-device backend: ``mix`` is the matmul, ``local`` the identity."""

    name = "dense"

    def __init__(self, graph: Graph):
        """Bind the communication graph (unused beyond documentation)."""
        self.graph = graph

    def matvec(self, m: np.ndarray, dtype) -> Callable[[jax.Array], jax.Array]:
        """``mix(X) = M @ X`` with ``M`` baked as a device constant."""
        m_j = jnp.asarray(m, dtype)
        return lambda x: m_j @ x

    def local(self, x: jax.Array) -> jax.Array:
        """Identity: the whole array is this (only) caller's block."""
        return x


class ShardedComm:
    """One graph node per mesh device; ``mix`` is edge-wise ``ppermute``.

    Requires ``mesh`` to carry a ``"node"`` axis of size exactly
    ``graph.n`` — the mapping of nodes to devices is positional. All
    methods other than the constructor must run INSIDE a ``shard_map``
    over that mesh (they read ``lax.axis_index``).
    """

    name = "sharded"
    axis = NODE_AXIS

    def __init__(self, graph: Graph, mesh: jax.sharding.Mesh):
        """Validate the mesh and precompute the edge-coloring schedule."""
        if self.axis not in mesh.axis_names:
            raise ValueError(
                f"sharded comm needs a {self.axis!r} mesh axis; "
                f"got axes {mesh.axis_names}"
            )
        n_devices = mesh.shape[self.axis]
        if n_devices != graph.n:
            raise ValueError(
                f"sharded comm places one graph node per device: graph has "
                f"{graph.n} nodes but the {self.axis!r} axis has {n_devices} "
                "devices (run under XLA_FLAGS="
                "--xla_force_host_platform_device_count=N to simulate)"
            )
        self.graph = graph
        self.mesh = mesh
        self.colors = edge_coloring(graph.edges, graph.n)
        # each matching -> one ppermute moving both directions at once
        self.perms = [
            [pair for (i, j) in color for pair in ((i, j), (j, i))]
            for color in self.colors
        ]

    def matvec(self, m: np.ndarray, dtype) -> Callable[[jax.Array], jax.Array]:
        """``mix(X) = M @ X`` as diag + one ``ppermute`` per edge color.

        The returned closure maps this device's (1, ...) block: it scales
        by ``M``'s diagonal, then for every color receives the permuted
        neighbor blocks and accumulates them weighted by the matching
        ``M[dest, src]`` entries (rows without an edge of that color
        receive zeros from ``ppermute`` and carry weight 0).
        """
        m = np.asarray(m)
        _check_support(m, self.graph)
        diag_j = jnp.asarray(np.diag(m).copy(), dtype)
        wrecvs = []
        for color in self.colors:
            wrecv = np.zeros(self.graph.n, dtype=m.dtype)
            for i, j in color:
                wrecv[i] = m[i, j]
                wrecv[j] = m[j, i]
            wrecvs.append(jnp.asarray(wrecv, dtype))

        def shaped(w_col, x):
            return w_col.reshape((-1,) + (1,) * (x.ndim - 1))

        def mix(x):
            out = shaped(self.local(diag_j), x) * x
            for perm, wrecv in zip(self.perms, wrecvs):
                recv = lax.ppermute(x, self.axis, perm)
                out = out + shaped(self.local(wrecv), x) * recv
            return out

        return mix

    def local(self, x: jax.Array) -> jax.Array:
        """This device's node block: row ``axis_index('node')`` of ``x``."""
        i = lax.axis_index(self.axis)
        return lax.dynamic_slice_in_dim(x, i, 1, axis=0)


# ---------------------------------------------------------------------------
# Fault-injecting backends (ft.faults plans, resolved to per-step masks)
# ---------------------------------------------------------------------------


class FaultyDenseComm(DenseComm):
    """DenseComm with link-drop masks and straggler delivery buffers.

    The fault runner in ``core.solvers`` drives the trace-time context:
    inside the scan body it calls ``begin_step(mask_t, deliv_t, bufs)``
    before the solver step and ``end_step()`` after, so the ``mix``
    closures (created once at factory time) read the CURRENT iteration's
    masks and buffers as captured tracers.

    Link faults (``has_link``): ``mix`` becomes a masked matvec with
    row-renormalization — dropped neighbor entries are zeroed and their
    mass redirected to the receiver's own (always fresh) value, so a
    row-stochastic ``W`` stays row-stochastic under any drop pattern.

    Stragglers (``has_straggler``): each ``mix`` invocation owns one
    last-delivered-value buffer slot, consumed in trace order (the same
    order every trace, since the step function is fixed). A sender whose
    ``deliv_t`` bit is off contributes its buffered value instead of the
    fresh one; the buffer then carries whatever value receivers actually
    used. The diagonal (self) term always reads the fresh value — a node
    never straggles to itself. Slot shapes are discovered by an abstract
    probe evaluation of the step function (``begin_probe``/``end_probe``)
    before the runner's scan carry is assembled.
    """

    name = "dense"

    def __init__(self, graph: Graph, has_link: bool, has_straggler: bool):
        """Bind the graph and which fault families are active."""
        super().__init__(graph)
        self.has_link = bool(has_link)
        self.has_straggler = bool(has_straggler)
        self._probing = False
        self._probe_shapes: list[jax.ShapeDtypeStruct] = []
        self._mask = None
        self._deliv = None
        self._bufs: tuple = ()
        self._new_bufs: list = []
        self._slot = 0

    # -- trace-time context driven by the fault runner ----------------------

    def begin_probe(self) -> None:
        """Enter shape-probe mode: ``mix`` runs plain, ``_use`` records."""
        self._probing = True
        self._probe_shapes = []

    def end_probe(self) -> list:
        """Leave probe mode; the recorded buffer slot shapes, in order."""
        self._probing = False
        shapes, self._probe_shapes = self._probe_shapes, []
        return shapes

    def begin_step(self, mask, deliv, bufs) -> None:
        """Install this iteration's masks and buffers (scan-body call)."""
        self._mask = mask
        self._deliv = deliv
        self._bufs = bufs
        self._new_bufs = []
        self._slot = 0

    def end_step(self) -> tuple:
        """The updated buffer tuple for the scan carry."""
        new = tuple(self._new_bufs)
        self._mask = self._deliv = None
        self._bufs, self._new_bufs = (), []
        return new

    def _use(self, x: jax.Array) -> jax.Array:
        """The value receivers see from each sender: fresh or buffered."""
        if not self.has_straggler:
            return x
        if self._probing:
            self._probe_shapes.append(jax.ShapeDtypeStruct(x.shape, x.dtype))
            return x
        buf = self._bufs[self._slot]
        self._slot += 1
        d = self._deliv.reshape((-1,) + (1,) * (x.ndim - 1))
        x_used = jnp.where(d, x, buf)
        self._new_bufs.append(x_used)
        return x_used

    def matvec(self, m: np.ndarray, dtype) -> Callable[[jax.Array], jax.Array]:
        """``mix(X) = M_eff(t) @ X_used(t)``: masked rows, buffered senders."""
        m_j = jnp.asarray(m, dtype)
        diag_j = jnp.asarray(np.diag(np.asarray(m)).copy(), dtype)

        def col(v, x):
            return v.reshape((-1,) + (1,) * (x.ndim - 1))

        def mix(x):
            if self._probing:
                return m_j @ self._use(x)
            x_used = self._use(x)
            if self.has_link:
                mask = self._mask
                zero = jnp.zeros((), dtype)
                kept = jnp.where(mask, m_j, zero)
                dropped = jnp.where(mask, zero, m_j).sum(axis=1)
                # dropped neighbor mass redirects to self — always fresh
                out = kept @ x_used + col(dropped, x) * x
            else:
                out = m_j @ x_used
            if self.has_straggler:
                # the self term must read the fresh value, not the buffer
                out = out + col(diag_j, x) * (x - x_used)
            return out

        return mix


class FaultyShardedComm(ShardedComm):
    """ShardedComm with a per-step link delivery mask (no stragglers).

    Each edge-color ``ppermute`` still executes physically — a dropped
    message is discarded at the RECEIVER (its weight is zeroed and the
    mass redirected to self), so the HLO-measured collective bytes are
    identical to the fault-free program while the modeled
    ``doubles_received`` accounting counts only delivered traffic
    (docs/solvers.md). The mask arrives replicated; each device reads its
    own row and, per color, the bit of its peer in that matching.
    """

    name = "sharded"

    def __init__(self, graph: Graph, mesh: jax.sharding.Mesh):
        """Precompute, per color, each node's peer index in the matching."""
        super().__init__(graph, mesh)
        self.srcs = []
        for color in self.colors:
            src = np.arange(graph.n)
            for i, j in color:
                src[i] = j
                src[j] = i
            self.srcs.append(jnp.asarray(src, jnp.int32))
        self._mask = None

    def begin_step(self, mask) -> None:
        """Install this iteration's (N, N) delivery mask (scan-body call)."""
        self._mask = mask

    def end_step(self) -> None:
        """Clear the per-step mask (no carried buffers on this backend)."""
        self._mask = None

    def matvec(self, m: np.ndarray, dtype) -> Callable[[jax.Array], jax.Array]:
        """Masked, renormalized ``mix``: ppermute everything, keep delivered."""
        m = np.asarray(m)
        _check_support(m, self.graph)
        diag_j = jnp.asarray(np.diag(m).copy(), dtype)
        wrecvs = []
        for color in self.colors:
            wrecv = np.zeros(self.graph.n, dtype=m.dtype)
            for i, j in color:
                wrecv[i] = m[i, j]
                wrecv[j] = m[j, i]
            wrecvs.append(jnp.asarray(wrecv, dtype))

        def shaped(w_col, x):
            return w_col.reshape((-1,) + (1,) * (x.ndim - 1))

        def mix(x):
            mask_row = self.local(self._mask)[0]  # (N,) — this node's row
            out = shaped(self.local(diag_j), x) * x
            dropped = jnp.zeros((1,) + (1,) * (x.ndim - 1), dtype)
            for perm, wrecv, src in zip(self.perms, wrecvs, self.srcs):
                recv = lax.ppermute(x, self.axis, perm)
                w_c = shaped(self.local(wrecv), x)
                peer = self.local(src)[0]  # this node's partner (self if none)
                deliv = jnp.take(mask_row, peer)  # diag is always True
                out = out + jnp.where(deliv, w_c, jnp.zeros_like(w_c)) * recv
                dropped = dropped + jnp.where(
                    deliv, jnp.zeros_like(w_c), w_c
                )
            return out + dropped * x

        return mix

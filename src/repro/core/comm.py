"""Pluggable communication primitives: how a solver's mixing step executes.

Every solver in ``core.solvers`` is written against two primitives instead
of a literal matmul (docs/solvers.md has the authoring contract):

* ``comm.matvec(M, dtype)`` returns ``mix(X)`` computing ``M @ X`` for a
  graph-supported matrix ``M`` (off-diagonal nonzeros only on edges of the
  communication graph — W, W~, the Laplacian and I - W all qualify);
* ``comm.local(x)`` returns the caller's node-block of a leading-N array
  (the node-local data slice inside the traced step).

``DenseComm`` is the single-device backend: ``mix`` is the matmul itself
and ``local`` is the identity, so the compiled step is byte-for-byte the
pre-refactor inlined ``W @ X`` program. ``ShardedComm`` places one graph
node per device of a ``"node"``-axis mesh (``launch.mesh.make_node_mesh``)
and executes ``mix`` as real neighbor exchange: the graph's edges are
greedily edge-colored into matchings and each matching becomes ONE
``lax.ppermute`` carrying both directions, so a step moves O(deg) blocks
per node — never O(N) — and the emitted ``collective-permute`` ops are
measurable from HLO (``launch.hlo_analysis.collective_stats``).

The ``shard_map`` import shim below is the compatibility machinery shared
with ``core.gossip`` (jax >= 0.5 promotes it out of experimental).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.mixing import Graph

if hasattr(jax, "shard_map"):  # jax >= 0.5
    shard_map = jax.shard_map
else:  # jax 0.4.x keeps it under experimental
    from jax.experimental.shard_map import shard_map  # noqa: F401

NODE_AXIS = "node"


def edge_coloring(edges, n: int) -> list[list[tuple[int, int]]]:
    """Greedy proper edge coloring: partition ``edges`` into matchings.

    Each color class touches every node at most once, so its edges — both
    directions — fit in a single ``lax.ppermute`` (whose source/dest lists
    must each be distinct). Greedy over the sorted edge list uses at most
    2*maxdeg - 1 colors (Vizing needs maxdeg + 1; the difference is a few
    extra ppermutes, not correctness) and is deterministic, keeping the
    compiled HLO stable across processes.
    """
    colors: list[list[tuple[int, int]]] = []
    busy: list[set[int]] = []
    for i, j in sorted(edges):
        for c, nodes in enumerate(busy):
            if i not in nodes and j not in nodes:
                colors[c].append((i, j))
                nodes.update((i, j))
                break
        else:
            colors.append([(i, j)])
            busy.append({i, j})
    return colors


def _check_support(m: np.ndarray, graph: Graph, atol: float = 0.0) -> None:
    """Reject matrices with off-diagonal mass outside the graph's edges."""
    mask = np.zeros((graph.n, graph.n), dtype=bool)
    for i, j in graph.edges:
        mask[i, j] = mask[j, i] = True
    np.fill_diagonal(mask, True)
    bad = np.abs(np.where(mask, 0.0, m))
    if bad.max(initial=0.0) > atol:
        i, j = np.unravel_index(int(bad.argmax()), bad.shape)
        raise ValueError(
            f"matrix entry ({i}, {j}) = {m[i, j]} is nonzero but ({i}, {j}) "
            "is not an edge of the communication graph; sharded mixing only "
            "moves data along edges"
        )


class DenseComm:
    """Single-device backend: ``mix`` is the matmul, ``local`` the identity."""

    name = "dense"

    def __init__(self, graph: Graph):
        """Bind the communication graph (unused beyond documentation)."""
        self.graph = graph

    def matvec(self, m: np.ndarray, dtype) -> Callable[[jax.Array], jax.Array]:
        """``mix(X) = M @ X`` with ``M`` baked as a device constant."""
        m_j = jnp.asarray(m, dtype)
        return lambda x: m_j @ x

    def local(self, x: jax.Array) -> jax.Array:
        """Identity: the whole array is this (only) caller's block."""
        return x


class ShardedComm:
    """One graph node per mesh device; ``mix`` is edge-wise ``ppermute``.

    Requires ``mesh`` to carry a ``"node"`` axis of size exactly
    ``graph.n`` — the mapping of nodes to devices is positional. All
    methods other than the constructor must run INSIDE a ``shard_map``
    over that mesh (they read ``lax.axis_index``).
    """

    name = "sharded"
    axis = NODE_AXIS

    def __init__(self, graph: Graph, mesh: jax.sharding.Mesh):
        """Validate the mesh and precompute the edge-coloring schedule."""
        if self.axis not in mesh.axis_names:
            raise ValueError(
                f"sharded comm needs a {self.axis!r} mesh axis; "
                f"got axes {mesh.axis_names}"
            )
        n_devices = mesh.shape[self.axis]
        if n_devices != graph.n:
            raise ValueError(
                f"sharded comm places one graph node per device: graph has "
                f"{graph.n} nodes but the {self.axis!r} axis has {n_devices} "
                "devices (run under XLA_FLAGS="
                "--xla_force_host_platform_device_count=N to simulate)"
            )
        self.graph = graph
        self.mesh = mesh
        self.colors = edge_coloring(graph.edges, graph.n)
        # each matching -> one ppermute moving both directions at once
        self.perms = [
            [pair for (i, j) in color for pair in ((i, j), (j, i))]
            for color in self.colors
        ]

    def matvec(self, m: np.ndarray, dtype) -> Callable[[jax.Array], jax.Array]:
        """``mix(X) = M @ X`` as diag + one ``ppermute`` per edge color.

        The returned closure maps this device's (1, ...) block: it scales
        by ``M``'s diagonal, then for every color receives the permuted
        neighbor blocks and accumulates them weighted by the matching
        ``M[dest, src]`` entries (rows without an edge of that color
        receive zeros from ``ppermute`` and carry weight 0).
        """
        m = np.asarray(m)
        _check_support(m, self.graph)
        diag_j = jnp.asarray(np.diag(m).copy(), dtype)
        wrecvs = []
        for color in self.colors:
            wrecv = np.zeros(self.graph.n, dtype=m.dtype)
            for i, j in color:
                wrecv[i] = m[i, j]
                wrecv[j] = m[j, i]
            wrecvs.append(jnp.asarray(wrecv, dtype))

        def shaped(w_col, x):
            return w_col.reshape((-1,) + (1,) * (x.ndim - 1))

        def mix(x):
            out = shaped(self.local(diag_j), x) * x
            for perm, wrecv in zip(self.perms, wrecvs):
                recv = lax.ppermute(x, self.axis, perm)
                out = out + shaped(self.local(wrecv), x) * recv
            return out

        return mix

    def local(self, x: jax.Array) -> jax.Array:
        """This device's node block: row ``axis_index('node')`` of ``x``."""
        i = lax.axis_index(self.axis)
        return lax.dynamic_slice_in_dim(x, i, 1, axis=0)

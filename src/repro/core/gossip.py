"""Pod-axis decentralized training — DSBA generalized to the TPU 'pod' mesh axis.

The paper's setting maps 1:1 onto multi-pod training: each pod is a graph
node holding a data shard and its own model replica; pods exchange parameter
information with GRAPH NEIGHBORS ONLY (collective-permute over the 'pod'
axis — the ICI/DCI-native pattern) instead of a global all-reduce; and the
wire payload is a SPARSE (values, indices) difference stream, the fixed-size
SPMD adaptation of the paper's delta_n^t messages (DESIGN.md §5).

Modes
  allreduce  synchronous DP baseline (dense global reduction — what the
             paper's Table 1 calls 'dense communication')
  dsgd       single-mix gossip:  theta <- Adam(W~ theta, g)  — practical
             Adam-preconditioned decentralized SGD
  dsba       the paper's update structure, faithfully:
               theta^{t+1} = W~ (2 theta^t - theta^{t-1}) - lr (g_t - g_{t-1})
             i.e. eq. (28)'s double-mix + update-DIFFERENCE correction
             (with B_{n,i} = grad of the local loss, forward-evaluated —
             the exact resolvent needs invertible I + alpha*B, DESIGN.md §6;
             stacking Adam on top of the extrapolation compounds momentum
             and diverges — tested).
Compression ('topk')
  CHOCO-style (Koloskova et al. 2019) reconstruction gossip: each pod keeps
  a reconstruction theta_hat of every stream it hears (its own + each
  neighbor's), communicates only top-k(|theta - theta_hat|) as (values,
  int32 indices), and applies the consensus correction
      theta <- theta + gamma * sum_m w~_pm (theta_hat_m - theta_hat_p).
  The untransmitted remainder stays in theta - theta_hat and is retried
  next round (self-correcting residual — no separate error-feedback
  accumulator is needed, and adding one double-counts and diverges; see
  tests/test_gossip.py::test_reconstruction_residual_is_self_correcting).
  This preserves the paper's O(rho d) wire complexity for dense NN params
  where exact data-sparsity (the convex case) no longer holds.

Topologies: ring (1 hop) and exponential (hypercube-like, log P hops) —
both ppermute-only, scaling O(deg) not O(P): the 1000+ node design point.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import mixing as MX
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.params import tree_pspecs, tree_sds

# version-compat shard_map shim shared with the solver comm backends
from repro.core.comm import shard_map as _shard_map
from repro.optim.adam import adam_init, adam_update
from repro.train.step import TrainConfig, local_grads


@dataclasses.dataclass(frozen=True)
class GossipConfig:
    """Pod-axis decentralized-training setup: topology, mode, compression."""

    n_pods: int = 2
    topology: str = "ring"  # ring | exponential | allreduce
    mode: str = "dsba"  # dsba | dsgd | allreduce
    # none | topk (exact global top-k; O(n log n) select) |
    # block_topk (top-k_b per fixed block — linear-time, embarrassingly
    # parallel, the wire format of kernels/topk_compress.py; the choice for
    # 10^9+-element leaves)
    compression: str = "none"
    topk_ratio: float = 0.01
    block_size: int = 4096  # block_topk selection granularity
    # kernels/ops.py use_pallas mode for the block_topk selection
    kernel_mode: str = "auto"
    consensus_lr: float = 0.9  # CHOCO gamma
    seed: int = 0

    def graph_and_weights(self) -> tuple[MX.Graph, np.ndarray]:
        """Pod graph + Laplacian mixing matrix for this topology."""
        g, w = MX.make_pod_mixing(self.n_pods, self.topology
                                  if self.topology != "allreduce" else "ring",
                                  self.seed)
        return g, w

    def shifts_and_weights(self) -> tuple[list[int], list[float], float]:
        """Ring/exponential graphs are circulant: mixing = self-weight +
        symmetric shifts. Returns (shifts, per-shift weight, self-weight)."""
        g, w = self.graph_and_weights()
        wt = MX.w_tilde(w)
        if self.n_pods == 1:
            return [], [], 1.0
        row = wt[0]
        shifts, weights = [], []
        for s in range(1, self.n_pods // 2 + 1):
            if abs(row[s]) > 1e-12:
                shifts.append(s)
                weights.append(float(row[s]))
        return shifts, weights, float(row[0])


# ---------------------------------------------------------------------------
# top-k difference compression (jnp reference; kernels/topk_compress.py is the
# Pallas version) + reconstruction scatter
# ---------------------------------------------------------------------------

def topk_compress(x: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Flattened top-k by |value|: returns (values (k,), indices (k,) int32)."""
    flat = x.reshape(-1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx.astype(jnp.int32)


def block_topk_compress(
    x: jax.Array, ratio: float, block: int, *, use_pallas: str = "auto"
) -> tuple[jax.Array, jax.Array]:
    """Block-local top-k: k_b = ratio*block entries per `block`-sized chunk.

    Linear-time selection (per-block), same fixed-size (values, GLOBAL idx)
    wire format as topk_compress. Selection dispatches through the
    kernels/ops.py registry ('block_topk'): the Pallas kernel on TPU, the
    lax.top_k oracle on CPU under 'auto'.
    """
    from repro.kernels.ops import topk_blocks

    n = x.size
    flat = x.reshape(-1)
    block = min(block, n)
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    nb = flat.size // block
    k_b = max(1, int(block * ratio))
    rows = flat.reshape(nb, block)
    vals, li = topk_blocks(rows, k_b, use_pallas=use_pallas)  # (nb, k_b)
    gi = (li + (jnp.arange(nb) * block)[:, None]).astype(jnp.int32)
    # padded tail indices point past n; zero their values so scatter is a noop
    valid = gi < n
    vals = jnp.where(valid, vals, 0.0)
    gi = jnp.where(valid, gi, 0)
    return vals.reshape(-1), gi.reshape(-1)


def scatter_decompress(shape, vals: jax.Array, idx: jax.Array) -> jax.Array:
    """Inverse of the top-k wire format: scatter (vals, idx) into `shape`."""
    out = jnp.zeros((int(np.prod(shape)),), vals.dtype)
    return out.at[idx].add(vals).reshape(shape)


def leaf_k(leaf_shape, ratio: float) -> int:
    """Per-leaf top-k count for a compression ratio (at least 1)."""
    n = int(np.prod(leaf_shape))
    return max(1, int(n * ratio))


# ---------------------------------------------------------------------------
# gossip state
# ---------------------------------------------------------------------------

def gossip_state_defs(cfg: ModelConfig, tc: TrainConfig, gc: GossipConfig):
    """(sds, pspecs) for the gossip train state — leading 'pod' dim on all
    replicated-per-pod leaves."""
    defs = T.model_defs(cfg)
    p_sds = tree_sds(defs, cfg.param_dtype)
    p_spec = tree_pspecs(defs)
    pod = lambda s: jax.ShapeDtypeStruct((gc.n_pods, *s.shape), s.dtype)
    pod_spec = lambda sp: P("pod", *sp)
    sds = {"params": jax.tree_util.tree_map(pod, p_sds),
           "step": jax.ShapeDtypeStruct((), jnp.int32)}
    spec = {"params": jax.tree_util.tree_map(pod_spec, p_spec), "step": P()}

    st_dt = tc.optimizer.state_dtype
    opt_sds = {"mu": tree_sds(defs, st_dt)}
    opt_spec = {"mu": p_spec}
    if tc.optimizer.kind != "sgdm":
        opt_sds["nu"] = tree_sds(defs, st_dt)
        opt_spec["nu"] = p_spec
    sds["opt"] = jax.tree_util.tree_map(pod, opt_sds)
    spec["opt"] = jax.tree_util.tree_map(pod_spec, opt_spec)

    if gc.mode == "dsba":
        sds["params_prev"] = sds["params"]
        spec["params_prev"] = spec["params"]
        sds["g_prev"] = sds["params"]
        spec["g_prev"] = spec["params"]
    if gc.compression != "none":
        shifts, _, _ = gc.shifts_and_weights()
        n_streams = 1 + 2 * len(shifts)  # own + each neighbor direction
        rec = lambda s: jax.ShapeDtypeStruct(
            (gc.n_pods, n_streams, *s.shape), s.dtype
        )
        rec_spec = lambda sp: P("pod", None, *sp)
        sds["recon"] = jax.tree_util.tree_map(rec, p_sds)
        spec["recon"] = jax.tree_util.tree_map(rec_spec, p_spec)
    return sds, spec


def init_gossip_state(cfg: ModelConfig, tc: TrainConfig, gc: GossipConfig, key):
    """Materialize (small configs only). All pods start at consensus."""
    from repro.models.params import tree_materialize

    defs = T.model_defs(cfg)
    params0 = tree_materialize(defs, key, cfg.param_dtype)
    tile = lambda x: jnp.broadcast_to(x[None], (gc.n_pods, *x.shape)).copy()
    params = jax.tree_util.tree_map(tile, params0)
    opt = jax.tree_util.tree_map(tile, adam_init(tc.optimizer, params0))
    state = {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}
    if gc.mode == "dsba":
        state["params_prev"] = params
        state["g_prev"] = jax.tree_util.tree_map(jnp.zeros_like, params)
    if gc.compression != "none":
        shifts, _, _ = gc.shifts_and_weights()
        n_streams = 1 + 2 * len(shifts)
        state["recon"] = jax.tree_util.tree_map(
            lambda p: jnp.zeros((gc.n_pods, n_streams, *p.shape[1:]), p.dtype),
            params,
        )
    return state


# ---------------------------------------------------------------------------
# exchange primitives
#
# Two interchangeable backends with IDENTICAL semantics (tested equal):
#   spmd  — shard_map over 'pod' + lax.ppermute: blocks move between devices;
#           this is what the production mesh compiles (collective-permute
#           only — O(deg), never O(P)).
#   local — jnp.roll over the leading pod dim (single-device tests; also the
#           semantic reference: roll(x, s)[j] = x[j-s] == ppermute send
#           i -> i+s).
# ---------------------------------------------------------------------------

def _perm(shift: int, n: int):
    return [(i, (i + shift) % n) for i in range(n)]


def _shift_fns(mesh, n):
    if mesh is None:
        return lambda x, s: jnp.roll(x, s, axis=0)
    return lambda x, s: jax.lax.ppermute(x, "pod", _perm(s, n))


def make_dense_mix(mesh, gc: GossipConfig, leaf_specs):
    """tree -> tree: x_p <- w_self x_p + sum_shift w_s (x_{p-s} + x_{p+s})."""
    shifts, weights, w_self = gc.shifts_and_weights()
    n = gc.n_pods
    shift = _shift_fns(mesh, n)

    def body(tree):
        def mix_leaf(x):
            out = w_self * x
            for s, wgt in zip(shifts, weights):
                # circulant symmetry: antipodal shift on even rings appears
                # once in the row, so halve the double-count
                scale = wgt if (2 * s) % n else wgt / 2.0
                out = out + scale * (shift(x, s) + shift(x, -s))
            return out

        return jax.tree_util.tree_map(mix_leaf, tree)

    if mesh is None:
        return body
    full_specs = jax.tree_util.tree_map(lambda sp: P("pod", *sp), leaf_specs)
    return _shard_map(
        body, mesh=mesh, in_specs=(full_specs,), out_specs=full_specs
    )


def make_topk_exchange(mesh, gc: GossipConfig, leaf_specs):
    """Compressed CHOCO exchange.

    Returns fn(source_tree, recon_tree) -> (correction_tree, new_recon_tree)
    where correction = gamma * sum_m w~_pm (theta_hat_m - theta_hat_p).
    Only the fixed-size top-k (values, int32 indices) streams move between
    pods. recon layout per leaf: (pods, streams, *shape): stream 0 = own
    broadcast reconstruction, then one per (shift, direction).
    """
    shifts, weights, w_self = gc.shifts_and_weights()
    n = gc.n_pods
    gamma = gc.consensus_lr
    shift = _shift_fns(mesh, n)

    def body(source, recon):
        # leading dim: n pods (local backend) or 1 (per-shard in shard_map).
        # Non-pod dims are SHARD-shaped inside shard_map, so the wire format
        # derives from the actual block shape: each device compresses its
        # own shard of every stream.
        def one(src, rec):
            shape = src.shape[1:]
            resid = (src - rec[:, 0]).astype(jnp.float32)
            if gc.compression == "block_topk":
                vals, idx = jax.vmap(
                    lambda r: block_topk_compress(r, gc.topk_ratio,
                                                  gc.block_size,
                                                  use_pallas=gc.kernel_mode)
                )(resid)
            else:
                k = leaf_k(shape, gc.topk_ratio)
                vals, idx = jax.vmap(lambda r: topk_compress(r, k))(resid)
            upd = jax.vmap(lambda v, i: scatter_decompress(shape, v, i))(
                vals, idx
            ).astype(src.dtype)
            new_rec0 = rec[:, 0] + upd
            new_rec = [new_rec0]
            corr = jnp.zeros(src.shape, jnp.float32)
            si = 1
            for s, wgt in zip(shifts, weights):
                scale = wgt if (2 * s) % n else wgt / 2.0
                for sign in (+1, -1):
                    v_in = shift(vals, sign * s)
                    i_in = shift(idx, sign * s)
                    inc = jax.vmap(
                        lambda v, i: scatter_decompress(shape, v, i)
                    )(v_in, i_in).astype(src.dtype)
                    rec_m = rec[:, si] + inc
                    new_rec.append(rec_m)
                    corr = corr + scale * (rec_m - new_rec0).astype(jnp.float32)
                    si += 1
            correction = (gamma * corr).astype(src.dtype)
            return correction, jnp.stack(new_rec, axis=1)

        flat_src, treedef = jax.tree_util.tree_flatten(source)
        flat_rec = treedef.flatten_up_to(recon)
        outs = [one(s_, r_) for s_, r_ in zip(flat_src, flat_rec)]
        corr = treedef.unflatten([o[0] for o in outs])
        new_rec = treedef.unflatten([o[1] for o in outs])
        return corr, new_rec

    if mesh is None:
        return body
    src_specs = jax.tree_util.tree_map(lambda sp: P("pod", *sp), leaf_specs)
    rec_specs = jax.tree_util.tree_map(lambda sp: P("pod", None, *sp), leaf_specs)
    return _shard_map(
        body, mesh=mesh,
        in_specs=(src_specs, rec_specs),
        out_specs=(src_specs, rec_specs),
    )


# ---------------------------------------------------------------------------
# the decentralized train step
# ---------------------------------------------------------------------------

def make_gossip_train_step(mesh, cfg: ModelConfig, tc: TrainConfig,
                           gc: GossipConfig):
    """Returns a jit-able step(state, batch) for the multi-pod mesh.

    batch leaves carry a leading (n_pods,) dim sharded over 'pod'; per-pod
    compute is vmapped with spmd_axis_name='pod' so internal sharding
    constraints stay pod-local.
    """
    defs = T.model_defs(cfg)
    leaf_specs = tree_pspecs(defs)
    if mesh is not None:
        from repro.models.params import shardable_pspecs

        leaf_specs = shardable_pspecs(
            leaf_specs, tree_sds(defs, cfg.param_dtype), mesh
        )
    dense_mix = make_dense_mix(mesh, gc, leaf_specs)
    topk_ex = (
        make_topk_exchange(mesh, gc, leaf_specs)
        if gc.compression != "none"
        else None
    )

    vgrads = jax.vmap(
        lambda p, b: local_grads(cfg, tc, p, b),
        spmd_axis_name="pod" if mesh is not None else None,
    )
    vadam = jax.vmap(
        lambda p, g, o, s: adam_update(tc.optimizer, p, g, o, s),
        in_axes=(0, 0, 0, None),
        spmd_axis_name="pod" if mesh is not None else None,
    )

    def step(state, batch):
        tm = jax.tree_util.tree_map
        params = state["params"]
        losses, grads = vgrads(params, batch)
        new_state = dict(state)

        if gc.mode == "dsba":
            # paper eq. (28): double-mix + update-difference correction.
            # CONSTANT step size: the g_t - g_{t-1} telescoping assumes the
            # same alpha on both terms (a warmup schedule silently breaks
            # the recursion's fixed point — observed as consensus blow-up).
            lr = tc.optimizer.lr
            extrap = tm(
                lambda p, pp: (2.0 * p.astype(jnp.float32)
                               - pp.astype(jnp.float32)).astype(p.dtype),
                params, state["params_prev"],
            )
            if gc.compression == "none":
                mixed = dense_mix(extrap)
            else:
                corr, new_rec = topk_ex(extrap, state["recon"])
                mixed = tm(lambda e, c: e + c, extrap, corr)
                new_state["recon"] = new_rec
            new_params = tm(
                lambda m, g, gp: (
                    m.astype(jnp.float32)
                    - lr * (g.astype(jnp.float32) - gp.astype(jnp.float32))
                ).astype(m.dtype),
                mixed, grads, state["g_prev"],
            )
            new_state["params_prev"] = params
            new_state["g_prev"] = tm(lambda g, p: g.astype(p.dtype),
                                     grads, params)
            gnorm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree_util.tree_leaves(grads)
            ))
            new_state["params"] = new_params
            new_state["step"] = state["step"] + 1
            return new_state, {"loss": losses.mean(), "grad_norm": gnorm}

        if gc.mode == "allreduce":
            grads = tm(
                lambda g: jnp.broadcast_to(
                    jnp.mean(g, axis=0, keepdims=True), g.shape
                ),
                grads,
            )
            mix_src = params
        else:  # dsgd
            mix_src = dense_mix(params) if gc.compression == "none" else params

        new_params, new_opt, metrics = vadam(
            mix_src, grads, state["opt"], state["step"]
        )
        if gc.compression != "none" and gc.mode == "dsgd":
            corr, new_rec = topk_ex(new_params, state["recon"])
            new_params = tm(lambda p, c: p + c, new_params, corr)
            new_state["recon"] = new_rec

        new_state["params"] = new_params
        new_state["opt"] = new_opt
        new_state["step"] = state["step"] + 1
        out_metrics = {
            "loss": losses.mean(),
            "grad_norm": metrics["grad_norm"].mean(),
        }
        return new_state, out_metrics

    return step


def gossip_batch_specs(cfg: ModelConfig) -> dict:
    """PartitionSpecs of the per-pod batch dict (pod axis leads)."""
    spec = {"tokens": P("pod", "data"), "targets": P("pod", "data")}
    if cfg.family == "encdec":
        spec["enc_embeds"] = P("pod", "data", None, None)
    return spec


def consensus_distance(params) -> jax.Array:
    """mean_p ||theta_p - theta_bar||^2 over the pod axis (diagnostics)."""
    def leaf(p):
        pb = p.mean(0, keepdims=True)
        return jnp.sum((p.astype(jnp.float32) - pb.astype(jnp.float32)) ** 2)

    return sum(jax.tree_util.tree_leaves(jax.tree_util.tree_map(leaf, params)))

"""Pallas TPU kernel: block-local top-k magnitude compression.

The gossip delta streams (core/gossip.py) need top-k over 10^8..10^11
element parameter leaves. A global sort is O(n log n) and serializes; the
production scheme is BLOCK-LOCAL top-k: reshape to (blocks, block_size),
keep k_b entries per block. Wire format stays fixed-size (values + local
indices), selection is embarrassingly parallel, and quality is within a few
percent of exact global top-k for heavy-tailed gradients.

In-kernel selection is k_b rounds of (argmax, mask) on the VPU — no sort.
Grid: one program per block row-group.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_kernel(x_ref, vals_ref, idx_ref, *, k: int, block: int):
    x = x_ref[0].astype(jnp.float32)  # (block,)
    mag = jnp.abs(x)
    iota = jax.lax.iota(jnp.int32, block)

    def body(i, carry):
        mag_c, = carry
        j = jnp.argmax(mag_c)
        vals_ref[0, i] = x[j].astype(vals_ref.dtype)
        idx_ref[0, i] = j.astype(jnp.int32)
        return (jnp.where(iota == j, -1.0, mag_c),)

    jax.lax.fori_loop(0, k, body, (mag,))


def block_topk(
    x: jax.Array,  # (n_blocks, block)
    k: int,
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Per-block top-k by |value|: (vals (nb, k), local idx (nb, k) int32)."""
    nb, block = x.shape
    kernel = functools.partial(_topk_kernel, k=k, block=block)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, k), x.dtype),
            jax.ShapeDtypeStruct((nb, k), jnp.int32),
        ],
        interpret=interpret,
    )(x)

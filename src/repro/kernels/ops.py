"""Backend registry + jit'd public wrappers for the Pallas kernels.

Every kernel is registered once as a :class:`KernelSpec` mapping its name to
the three backends the suite exercises

  pallas     the compiled Pallas kernel (TPU)
  interpret  the same kernel body under the Pallas interpreter (CPU parity)
  ref        the pure-jnp oracle in kernels/ref.py

plus a per-kernel tolerance policy (keyed by input dtype) and an optional
custom comparator. ``parity_check`` is the shared harness: it runs a kernel
in a given mode and in ``off`` (ref) mode and asserts agreement within the
kernel's declared tolerance — tests/test_ops_dispatch.py drives it over the
whole registry; tests/test_kernels.py uses the same policies for its shape
sweeps.

use_pallas modes: 'auto' picks the Pallas kernel on TPU and the jnp
reference on CPU (this container); 'on' forces the compiled kernel;
'interpret' forces the kernel body in interpret mode (how the tests
validate the kernels here); 'off' is the pure-jnp oracle.

dtype policy: kernels accumulate in f32 (the TPU MXU-native dtype). The
sparse kernels' interpret path is the one exception — it is the CPU
fallback of the DSBA relay (core/sparse_comm.py), whose f64 truth-checking
needs BIT EXACTNESS, so ``_resolve_compute_dtype`` (the registry adapters'
single policy point) picks psi.dtype under interpret mode, and the registry
declares an exact (0, 0) f64 sparse-AXPY tolerance that the parity harness
enforces.

gradient policy: the differentiable kernels (flash_attention, ssd_chunk —
``jax.custom_vjp`` with blocked Pallas backward kernels) additionally
declare ``grad_argnums`` (which positional args carry cotangents) and a
``grad_tol`` tolerance map. ``parity_check(..., grads=True)`` pulls vjp
outputs through the requested backend and through 'off' — where the
pure-jnp oracle's ordinary autodiff is the gradient ground truth — and
asserts agreement within the declared grad tolerance.
"""
from __future__ import annotations

import dataclasses
import inspect
from functools import partial, wraps
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import decode_attention as DA
from repro.kernels import flash_attention as FA
from repro.kernels import ref as R
from repro.kernels import ssd_scan as SSD
from repro.kernels.sparse_saga import sparse_axpy, sparse_dot
from repro.kernels.topk_compress import block_topk

MODES = ("auto", "on", "interpret", "off")
BACKENDS = ("pallas", "interpret", "ref")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_mode(use_pallas: str) -> str:
    """use_pallas mode -> backend name ('pallas' | 'interpret' | 'ref')."""
    if use_pallas not in MODES:
        raise ValueError(
            f"use_pallas={use_pallas!r} not in {MODES}"
        )
    if use_pallas == "auto":
        return "pallas" if _on_tpu() else "ref"
    return {"on": "pallas", "interpret": "interpret", "off": "ref"}[use_pallas]


@dataclasses.dataclass(frozen=True)
class Tolerance:
    """An (rtol, atol) parity bound; (0, 0) means bit-exact."""

    rtol: float
    atol: float


# default policies; kernels override per dtype at registration
_F32_TOL = Tolerance(2e-5, 2e-5)
_BF16_TOL = Tolerance(2e-2, 2e-2)
# gradient defaults: one recompute deeper than the forward, so ~10x looser
_F32_GRAD_TOL = Tolerance(2e-4, 2e-4)
_BF16_GRAD_TOL = Tolerance(5e-2, 5e-2)


def _strip_unknown_kwargs(fn: Callable) -> Callable:
    """Drop kernel-only kwargs (node_block, compute_dtype, block_d, ...)
    before calling a pure-jnp oracle, so one call site can dispatch to
    either backend with the kernel's full kwarg surface."""
    params = inspect.signature(fn).parameters.values()
    if any(p.kind == inspect.Parameter.VAR_KEYWORD for p in params):
        return fn
    accepted = {
        p.name for p in params
        if p.kind in (inspect.Parameter.POSITIONAL_OR_KEYWORD,
                      inspect.Parameter.KEYWORD_ONLY)
    }

    @wraps(fn)
    def stripped(*args, **kwargs):
        return fn(*args, **{k: v for k, v in kwargs.items() if k in accepted})

    return stripped


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One kernel's backends + parity policy.

    pallas: callable taking (*args, interpret: bool, **kw) — the Pallas
        launch wrapper. 'interpret' backend is the same callable with
        interpret=True.
    ref: pure-jnp oracle with the same positional surface; kernel-only
        kwargs it doesn't accept are stripped at dispatch (impl('ref')).
    tol: {dtype name: Tolerance} parity policy; missing dtypes fall back
        to float32's entry.
    compare: optional (args, got, want, tol) -> max_err comparator for
        kernels whose outputs match as sets rather than elementwise
        (block_topk); receives the input args for consistency checks.
    grad_argnums: positional args that carry cotangents (None = the kernel
        has no differentiable surface; parity_check(grads=True) rejects it).
    grad_tol: {dtype name: Tolerance} policy for vjp outputs; None falls
        back to the forward `tol` map.
    """

    name: str
    pallas: Callable
    ref: Callable
    tol: dict[str, Tolerance]
    compare: Callable | None = None
    grad_argnums: tuple[int, ...] | None = None
    grad_tol: dict[str, Tolerance] | None = None

    def impl(self, backend: str) -> Callable:
        """Resolve a backend name to its callable (see class docstring)."""
        if backend == "ref":
            return _strip_unknown_kwargs(self.ref)
        if backend == "pallas":
            return partial(self.pallas, interpret=False)
        if backend == "interpret":
            return partial(self.pallas, interpret=True)
        raise ValueError(f"backend {backend!r} not in {BACKENDS}")

    def tolerance(self, dtype) -> Tolerance:
        """Forward-output parity Tolerance for `dtype` (f32 fallback)."""
        key = jnp.dtype(dtype).name
        if key in self.tol:
            return self.tol[key]
        return self.tol.get("float32", _F32_TOL)

    def grad_tolerance(self, dtype) -> Tolerance:
        """Vjp-output parity Tolerance for `dtype` (falls back to `tol`)."""
        if self.grad_tol is None:
            return self.tolerance(dtype)
        key = jnp.dtype(dtype).name
        if key in self.grad_tol:
            return self.grad_tol[key]
        return self.grad_tol.get("float32", _F32_GRAD_TOL)


_REGISTRY: dict[str, KernelSpec] = {}


def register_kernel(spec: KernelSpec) -> KernelSpec:
    """Add `spec` to the registry; duplicate names are a hard error."""
    if spec.name in _REGISTRY:
        raise ValueError(f"kernel {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get_kernel(name: str) -> KernelSpec:
    """Look up a registered KernelSpec by name (KeyError if unknown)."""
    return _REGISTRY[name]


def registered_kernels() -> tuple[str, ...]:
    """Sorted names of every registered kernel."""
    return tuple(sorted(_REGISTRY))


def dispatch(name: str, *args, use_pallas: str = "auto", **kwargs):
    """Resolve (kernel, mode) -> backend impl and call it."""
    return get_kernel(name).impl(resolve_mode(use_pallas))(*args, **kwargs)


# ---------------------------------------------------------------------------
# parity harness
# ---------------------------------------------------------------------------

def _leaf_max_err(got, want) -> float:
    ga = np.asarray(got, np.float64)
    wa = np.asarray(want, np.float64)
    return float(np.max(np.abs(ga - wa))) if ga.size else 0.0


def _assert_leaves_close(name, got, want, tol: Tolerance) -> float:
    """Elementwise leaf comparison shared by the fwd and vjp parity paths."""
    got_leaves = jax.tree_util.tree_leaves(got)
    want_leaves = jax.tree_util.tree_leaves(want)
    assert len(got_leaves) == len(want_leaves), (name, got, want)
    max_err = 0.0
    for g, w in zip(got_leaves, want_leaves):
        if tol.rtol == 0.0 and tol.atol == 0.0:
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        else:
            np.testing.assert_allclose(
                np.asarray(g, np.float64), np.asarray(w, np.float64),
                rtol=tol.rtol, atol=tol.atol,
            )
        max_err = max(max_err, _leaf_max_err(g, w))
    return max_err


def _cotangents_like(out):
    """Deterministic non-constant cotangents for vjp parity (no PRNG key:
    a sin ramp avoids the symmetric cancellations an all-ones seed hides)."""

    def one(leaf):
        ramp = jnp.sin(jnp.arange(leaf.size, dtype=jnp.float32) * 0.7)
        return ramp.reshape(leaf.shape).astype(leaf.dtype)

    return jax.tree_util.tree_map(one, out)


def _vjp_outputs(spec: KernelSpec, backend: str, args, kwargs):
    """Pull deterministic cotangents back through `backend`'s kernel.

    Differentiates w.r.t. spec.grad_argnums only (sparse kernels carry int
    index args); non-diff args and kwargs are closed over. Returns the
    cotangent tuple, one entry per grad argnum.
    """
    if spec.grad_argnums is None:
        raise ValueError(f"kernel {spec.name!r} declares no grad_argnums")
    impl = spec.impl(backend)
    diff_args = tuple(args[i] for i in spec.grad_argnums)

    def fn(*diff):
        full = list(args)
        for i, a in zip(spec.grad_argnums, diff):
            full[i] = a
        return impl(*full, **kwargs)

    out, pullback = jax.vjp(fn, *diff_args)
    return pullback(_cotangents_like(out))


def parity_check(
    name: str, *args, use_pallas: str = "interpret", tol_dtype=None,
    grads: bool = False, **kwargs
) -> float:
    """Assert kernel-vs-oracle agreement within the declared tolerance.

    Runs `name` under `use_pallas` and under 'off', compares every output
    leaf with the kernel's Tolerance for `tol_dtype` (default: dtype of the
    first array argument), and returns the max abs error across leaves.
    A Tolerance of (0, 0) asserts bit-exactness.

    grads=True additionally compares vjp outputs (deterministic cotangents
    pulled back through the kernel's grad_argnums) under the kernel's
    grad tolerance — for the 'off' leg this is plain jax autodiff of the
    pure-jnp oracle, i.e. the registry-resolved custom_vjp backward is
    checked against reference autodiff. The returned max error covers both
    the forward and vjp leaves.
    """
    spec = get_kernel(name)
    if tol_dtype is None:
        tol_dtype = next(
            a.dtype for a in args if hasattr(a, "dtype")
            and jnp.issubdtype(a.dtype, jnp.floating)
        )
    tol = spec.tolerance(tol_dtype)
    got = dispatch(name, *args, use_pallas=use_pallas, **kwargs)
    want = dispatch(name, *args, use_pallas="off", **kwargs)
    if spec.compare is not None:
        max_err = spec.compare(args, got, want, tol)
    else:
        max_err = _assert_leaves_close(name, got, want, tol)
    if grads:
        backend = resolve_mode(use_pallas)
        got_ct = _vjp_outputs(spec, backend, args, kwargs)
        want_ct = _vjp_outputs(spec, "ref", args, kwargs)
        grad_err = _assert_leaves_close(
            f"{name}:vjp", got_ct, want_ct, spec.grad_tolerance(tol_dtype)
        )
        max_err = max(max_err, grad_err)
    return max_err


def _topk_compare(args, got, want, tol: Tolerance) -> float:
    """block_topk parity: selected SETS match (tie order may differ) AND
    every returned (value, index) pair is self-consistent with the input —
    gossip builds its wire-format global indices from these, so a value
    that doesn't live at its claimed index must fail parity."""
    x = np.asarray(args[0])
    vals, idx = (np.asarray(a) for a in got)
    vals_r, idx_r = (np.asarray(a) for a in want)
    gm = np.sort(np.abs(vals.astype(np.float64)), axis=1)
    wm = np.sort(np.abs(vals_r.astype(np.float64)), axis=1)
    np.testing.assert_allclose(gm, wm, rtol=tol.rtol, atol=tol.atol)
    # tolerance, not equality: the kernel body rounds through f32, so f64
    # inputs gather back 1 f32-ulp off; wrong indices miss by far more
    np.testing.assert_allclose(np.take_along_axis(x, idx, axis=1), vals,
                               rtol=tol.rtol, atol=tol.atol)
    np.testing.assert_allclose(np.take_along_axis(x, idx_r, axis=1), vals_r,
                               rtol=tol.rtol, atol=tol.atol)
    return float(np.max(np.abs(gm - wm))) if gm.size else 0.0


# ---------------------------------------------------------------------------
# registrations
# ---------------------------------------------------------------------------

def _flash_pallas(q, k, v, *, causal=True, window=None, softcap=None,
                  interpret=False):
    """Registry adapter: the flash-attention custom_vjp wrapper (forward
    kernel + blocked Pallas backward; statics are positional for
    jax.custom_vjp)."""
    return FA.flash_attention(
        q, k, v, causal, window, softcap, 128, 128, interpret
    )


register_kernel(KernelSpec(
    name="flash_attention",
    pallas=_flash_pallas,
    ref=R.attention_ref,
    tol={"float32": _F32_TOL, "bfloat16": _BF16_TOL},
    grad_argnums=(0, 1, 2),
    # vjp vs ref autodiff: blocked-recompute bwd measured <5e-6 f32 /
    # <4e-2 bf16 worst-case over the statics grid (tests/test_kernel_grads)
    grad_tol={"float32": _F32_GRAD_TOL, "bfloat16": _BF16_GRAD_TOL},
))


def _decode_attn_pallas(q, k_pool, v_pool, table, lengths, *, window=None,
                        softcap=None, interpret=False):
    """Registry adapter: the paged single-query decode-attention launch
    (block-table gather in the scalar-prefetch index maps)."""
    return DA.decode_attention(
        q, k_pool, v_pool, table, lengths,
        window=window, softcap=softcap, interpret=interpret,
    )


register_kernel(KernelSpec(
    name="decode_attention",
    pallas=_decode_attn_pallas,
    ref=R.decode_attention_ref,
    # decode is inference-only: no grad surface is declared
    tol={"float32": _F32_TOL, "bfloat16": _BF16_TOL},
))


def _ssd_pallas(xdt, cum, Bc, Cc, *, head_block=None, interpret=False):
    """Registry adapter: the ssd_chunk custom_vjp wrapper (within-chunk
    forward kernel + chunked backward kernel over the saved residuals).
    head_block=None picks the largest grid-legal block (<= 4 heads) that
    divides the model's head count."""
    if head_block is None:
        nh = xdt.shape[3]
        head_block = next(hb for hb in (4, 3, 2, 1) if nh % hb == 0)
    return SSD.ssd_chunk(xdt, cum, Bc, Cc, head_block, interpret)


register_kernel(KernelSpec(
    name="ssd_chunk",
    pallas=_ssd_pallas,
    ref=R.ssd_chunk_ref,
    tol={"float32": _F32_TOL, "bfloat16": _BF16_TOL},
    grad_argnums=(0, 1, 2, 3),
    # vjp vs ref autodiff measured <6e-5 f32 worst-case; models/ssm.py
    # always feeds f32, so no bf16 grad policy is declared
    grad_tol={"float32": _F32_GRAD_TOL},
))


def _resolve_compute_dtype(psi, interpret, compute_dtype):
    """THE one place the sparse-kernel dtype policy lives: the interpret
    (CPU-fallback) path computes in the model dtype — the f64 DSBA relay
    stays bit-exact — while the compiled TPU kernel accumulates in
    MXU-native f32."""
    if compute_dtype is not None:
        return compute_dtype
    return psi.dtype if interpret else jnp.float32


def _sparse_dot_pallas(psi, idx, val, *, interpret=False, compute_dtype=None,
                       **kw):
    return sparse_dot(
        psi, idx, val, interpret=interpret,
        compute_dtype=_resolve_compute_dtype(psi, interpret, compute_dtype),
        **kw,
    )


def _sparse_axpy_pallas(psi, idx, val, coef, rho, *, interpret=False,
                        compute_dtype=None, **kw):
    return sparse_axpy(
        psi, idx, val, coef, rho, interpret=interpret,
        compute_dtype=_resolve_compute_dtype(psi, interpret, compute_dtype),
        **kw,
    )


register_kernel(KernelSpec(
    name="sparse_dot",
    pallas=_sparse_dot_pallas,
    ref=R.sparse_dot_ref,
    tol={"float32": Tolerance(1e-5, 1e-5), "float64": Tolerance(1e-12, 1e-12)},
))

register_kernel(KernelSpec(
    name="sparse_axpy",
    pallas=_sparse_axpy_pallas,
    ref=R.sparse_axpy_ref,
    # f64 interpret is the DSBA relay's CPU fallback: BIT EXACT by policy
    # for the relay's call shape (rho = 1, distinct per-row indices —
    # delta densification). Arbitrary rho can differ by 1 ulp via legal
    # FMA fusion of rho*psi + coef*scat.
    tol={"float32": Tolerance(1e-5, 1e-5), "float64": Tolerance(0.0, 0.0)},
))

register_kernel(KernelSpec(
    name="block_topk",
    pallas=block_topk,
    ref=R.block_topk_ref,
    tol={"float32": Tolerance(1e-6, 1e-6)},
    compare=_topk_compare,
))


# ---------------------------------------------------------------------------
# jit'd public wrappers (the stable call surface; modes are static)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("causal", "window", "softcap", "use_pallas"))
def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    use_pallas: str = "auto"):
    """Registry-dispatched attention, differentiable under every mode
    (custom_vjp blocked backward on the kernel backends, plain autodiff of
    the oracle under 'off'/CPU-'auto')."""
    return dispatch("flash_attention", q, k, v, causal=causal, window=window,
                    softcap=softcap, use_pallas=use_pallas)


@partial(jax.jit, static_argnames=("window", "softcap", "use_pallas"))
def decode_attention(q, k_pool, v_pool, table, lengths, *, window=None,
                     softcap=None, use_pallas: str = "auto"):
    """Registry-dispatched paged single-query decode attention (the serving
    hot path; ModelConfig.decode_kernel picks the mode)."""
    return dispatch(
        "decode_attention", q, k_pool, v_pool, table, lengths,
        window=window, softcap=softcap, use_pallas=use_pallas,
    )


@partial(jax.jit, static_argnames=("use_pallas",))
def ssd_chunk(xdt, cum, Bc, Cc, *, use_pallas: str = "auto"):
    """Registry-dispatched within-chunk SSD -> (y_intra, chunk states);
    differentiable under every mode (chunked custom_vjp backward on the
    kernel backends)."""
    return dispatch("ssd_chunk", xdt, cum, Bc, Cc, use_pallas=use_pallas)


@partial(jax.jit, static_argnames=("use_pallas",))
def saga_sparse_dot(psi, idx, val, *, use_pallas: str = "auto"):
    """Registry-dispatched per-node sparse dot (DSBA step, eq. 30 input)."""
    return dispatch("sparse_dot", psi, idx, val, use_pallas=use_pallas)


@partial(
    jax.jit, static_argnames=("use_pallas", "compute_dtype", "node_block")
)
def saga_sparse_axpy(psi, idx, val, coef, rho, *, use_pallas: str = "auto",
                     compute_dtype=None, node_block: int = 1):
    """Registry-dispatched sparse AXPY row update (the DSBA-s relay's
    densification hot path)."""
    # compute_dtype=None -> the registry adapter's central policy
    # (_resolve_compute_dtype); the ref backend strips kernel-only kwargs
    return dispatch(
        "sparse_axpy", psi, idx, val, coef, rho, use_pallas=use_pallas,
        compute_dtype=compute_dtype, node_block=node_block,
    )


@partial(jax.jit, static_argnames=("k", "use_pallas"))
def topk_blocks(x, k: int, *, use_pallas: str = "auto"):
    """Registry-dispatched block-local top-|value| selection (gossip)."""
    return dispatch("block_topk", x, k, use_pallas=use_pallas)

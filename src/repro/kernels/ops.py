"""jit'd public wrappers for the Pallas kernels with backend dispatch.

use_pallas: 'auto' picks the Pallas kernel on TPU and the jnp reference on
CPU (this container); 'interpret' forces the kernel body in interpret mode
(how the tests validate the kernels here); 'off' is the pure-jnp oracle.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.sparse_saga import sparse_axpy, sparse_dot
from repro.kernels.ssd_scan import ssd_chunk_fwd
from repro.kernels.topk_compress import block_topk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _mode(use_pallas: str) -> str:
    if use_pallas == "auto":
        return "pallas" if _on_tpu() else "ref"
    return {"on": "pallas", "interpret": "interpret", "off": "ref"}[use_pallas]


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "use_pallas"))
def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    use_pallas: str = "auto"):
    m = _mode(use_pallas)
    if m == "ref":
        return R.attention_ref(q, k, v, causal=causal, window=window,
                               softcap=softcap)
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap,
        interpret=(m == "interpret"),
    )


@partial(jax.jit, static_argnames=("use_pallas",))
def ssd_chunk(xdt, cum, Bc, Cc, *, use_pallas: str = "auto"):
    m = _mode(use_pallas)
    if m == "ref":
        return R.ssd_chunk_ref(xdt, cum, Bc, Cc)
    return ssd_chunk_fwd(xdt, cum, Bc, Cc, interpret=(m == "interpret"))


@partial(jax.jit, static_argnames=("use_pallas",))
def saga_sparse_dot(psi, idx, val, *, use_pallas: str = "auto"):
    m = _mode(use_pallas)
    if m == "ref":
        return R.sparse_dot_ref(psi, idx, val)
    return sparse_dot(psi, idx, val, interpret=(m == "interpret"))


@partial(
    jax.jit, static_argnames=("use_pallas", "compute_dtype", "node_block")
)
def saga_sparse_axpy(psi, idx, val, coef, rho, *, use_pallas: str = "auto",
                     compute_dtype=None, node_block: int = 1):
    m = _mode(use_pallas)
    if m == "ref":
        return R.sparse_axpy_ref(psi, idx, val, coef, rho)
    return sparse_axpy(
        psi, idx, val, coef, rho, interpret=(m == "interpret"),
        compute_dtype=compute_dtype or jnp.float32, node_block=node_block,
    )


@partial(jax.jit, static_argnames=("k", "use_pallas"))
def topk_blocks(x, k: int, *, use_pallas: str = "auto"):
    m = _mode(use_pallas)
    if m == "ref":
        return R.block_topk_ref(x, k)
    return block_topk(x, k, interpret=(m == "interpret"))

"""Pallas TPU paged-cache decode attention (single-query, block tables).

Serving decodes one token per sequence per step against a PAGED KV cache:
K/V live in a preallocated block pool ``(n_blocks, block_size, Hkv, D)``
shared by every sequence, and each sequence owns an int32 block-table row
naming which pool pages hold its history. The kernel is the cache-aware hot
path: it gathers exactly the referenced pages — cost scales with the LIVE
tokens, not the dense worst case (the same active-set argument as the
paper's DSBA-s sparse relay).

The gather is expressed in the grid spec, not in kernel-body DMAs: the
block table and per-sequence lengths ride in scalar-prefetch position
(``pltpu.PrefetchScalarGridSpec``), so the K/V BlockSpec index maps can
read ``table[b, i]`` and point page ``i`` of sequence ``b`` straight at its
pool page. Pallas then pipelines one (block_size, D) tile per grid step —
unreferenced pool pages are never touched.

Grid: ``(B, Hkv, n_pages)`` with the page axis innermost and sequential;
an online-softmax carry (m / l / acc) persists in VMEM scratch across the
page axis, exactly like the q-block carry in kernels/flash_attention.py.
Pages past a sequence's length are skipped (``pl.when``); partial last
pages are masked by position, never read out of bounds. Empty slots
(length 0 — the scheduler's padding lanes) produce an all-zero output row
via the ``max(l, eps)`` guard.

GQA is free here: one program instance handles a kv head's whole query
group, so the (group, block_size) score tile never replicates K/V.

Validated against kernels/ref.py ``decode_attention_ref`` in interpret
mode; dispatch and tolerance policy live in kernels/ops.py
(``ModelConfig.decode_kernel`` routes the serving path through it).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref, *,
    block_size: int, n_pages: int, window: int | None,
    softcap: float | None, scale: float,
):
    """One (sequence, kv-head, page) program instance.

    table_ref/len_ref: scalar-prefetch refs (full (B, n_pages) / (B,));
    q_ref: (1, group, D) — this kv head's query group;
    k_ref/v_ref: (1, block_size, 1, D) — the pool page the index map
    gathered through the block table; o_ref: (1, group, D);
    acc/m/l: VMEM online-softmax carry persisting across the page axis.
    """
    b = pl.program_id(0)
    i = pl.program_id(2)
    length = len_ref[b]

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # pages at or past the sequence length hold no valid tokens: skip the
    # matmul entirely (the index map already pointed them at page 0).
    @pl.when(i * block_size < length)
    def _update():
        q = q_ref[0].astype(jnp.float32) * scale  # (group, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (block_size, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (group, block_size)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        pos = i * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_size), 1
        )
        mask = pos < length
        if window is not None:
            # the single query sits at position length - 1
            mask &= pos >= length - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # (group, 1)
        m_cur = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_cur

    @pl.when(i == n_pages - 1)
    def _finalize():
        l_safe = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


def _pad_last(x: jax.Array, to: int) -> jax.Array:
    pad = (-x.shape[-1]) % to
    if not pad:
        return x
    return jnp.pad(x, ((0, 0),) * (x.ndim - 1) + ((0, pad),))


def decode_attention(
    q: jax.Array,  # (B, Hq, D) — one query token per sequence
    k_pool: jax.Array,  # (n_blocks, block_size, Hkv, D) shared page pool
    v_pool: jax.Array,
    table: jax.Array,  # (B, n_pages) int32 — pool page ids per sequence
    lengths: jax.Array,  # (B,) int32 — valid tokens incl. the current one
    *,
    window: int | None = None,
    softcap: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Paged single-query attention launch -> (B, Hq, D) in q.dtype.

    ``lengths[b]`` counts the tokens already written to sequence b's pages
    (including the token being decoded, at position ``lengths[b] - 1``);
    page ``i`` covers positions ``[i * block_size, (i+1) * block_size)``.
    Unused table entries may point anywhere in range (the scheduler points
    them at the reserved null page 0) — they are masked, never read beyond
    a DMA the carry ignores. D is zero-padded to the 128 lane width; padded
    columns contribute nothing and are sliced off.
    """
    B, Hq, D = q.shape
    n_blocks, block_size, Hkv, _ = k_pool.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    n_pages = table.shape[1]
    scale = 1.0 / math.sqrt(D)

    qp = _pad_last(q, 128)
    kp = _pad_last(k_pool, 128)
    vp = _pad_last(v_pool, 128)
    Dp = qp.shape[-1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, group, Dp), lambda b, h, i, t, le: (b, h, 0)),
            pl.BlockSpec(
                (1, block_size, 1, Dp),
                lambda b, h, i, t, le: (t[b, i], 0, h, 0),
            ),
            pl.BlockSpec(
                (1, block_size, 1, Dp),
                lambda b, h, i, t, le: (t[b, i], 0, h, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, group, Dp), lambda b, h, i, t, le: (b, h, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((group, Dp), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _decode_kernel, block_size=block_size, n_pages=n_pages,
        window=window, softcap=softcap, scale=scale,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, Dp), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), lengths.astype(jnp.int32), qp, kp, vp)
    return out[..., :D]

"""Pallas TPU kernel for the Mamba2/SSD WITHIN-CHUNK computation.

The chunked SSD algorithm (models/ssm.py) splits into:
  (a) within-chunk: y_intra = ((C B^T) .* L) (x dt)  and the per-chunk state
      contribution  S_c = B^T (decay-to-end .* x dt)  — all dense matmuls
      over (Q, ds, hd) tiles -> MXU work. THIS kernel.
  (b) across-chunk: a length-nc linear recurrence + rank-1 read-out —
      negligible FLOPs, kept in jnp (lax.scan).

This split is the TPU-native adaptation of the paper's GPU kernel: the
within-chunk part is blocked to VMEM with (Q x Q) decay tiles built on the
VPU and contracted on the MXU.

Grid: (batch, n_chunks, head_blocks). Per-instance working set:
  xdt (Q, hb, hd), cum (Q, hb), B/C (Q, ds), out y (Q, hb, hd),
  states (hb, ds, hd)  — for Q=128, hb=4, hd=64, ds=128: ~0.5 MB. VMEM-safe.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(xdt_ref, cum_ref, b_ref, c_ref, y_ref, st_ref, *,
                      head_block: int):
    """One (batch, chunk, head-block) instance.

    xdt_ref: (1, 1, Q, hb, hd)   x * dt, fp32
    cum_ref: (1, 1, Q, hb)       inclusive cumsum of log-decay
    b_ref:   (1, 1, Q, ds)
    c_ref:   (1, 1, Q, ds)
    y_ref:   (1, 1, Q, hb, hd)   intra-chunk output
    st_ref:  (1, 1, hb, ds, hd)  chunk state contribution
    """
    xdt = xdt_ref[0, 0].astype(jnp.float32)  # (Q, hb, hd)
    cum = cum_ref[0, 0].astype(jnp.float32)  # (Q, hb)
    Bm = b_ref[0, 0].astype(jnp.float32)  # (Q, ds)
    Cm = c_ref[0, 0].astype(jnp.float32)  # (Q, ds)
    Q = xdt.shape[0]

    scores = Cm @ Bm.T  # (Q, Q) shared across heads in the block
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    )

    for h in range(head_block):  # static unroll over the head block
        ch = cum[:, h]
        decay = jnp.exp(ch[:, None] - ch[None, :])
        L = jnp.where(tri, decay, 0.0)
        y_h = (scores * L) @ xdt[:, h, :]  # (Q, hd)
        y_ref[0, 0, :, h, :] = y_h.astype(y_ref.dtype)
        dte = jnp.exp(ch[-1] - ch)  # decay to end of chunk
        st_h = (Bm * dte[:, None]).T @ xdt[:, h, :]  # (ds, hd)
        st_ref[0, 0, h] = st_h.astype(st_ref.dtype)


def ssd_chunk_fwd(
    xdt: jax.Array,  # (B, nc, Q, nh, hd) fp32
    cum: jax.Array,  # (B, nc, Q, nh)
    Bc: jax.Array,  # (B, nc, Q, ds)
    Cc: jax.Array,  # (B, nc, Q, ds)
    *,
    head_block: int = 4,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y_intra (B,nc,Q,nh,hd), states (B,nc,nh,ds,hd))."""
    B, nc, Q, nh, hd = xdt.shape
    ds = Bc.shape[-1]
    head_block = min(head_block, nh)
    assert nh % head_block == 0
    hb_count = nh // head_block

    kernel = functools.partial(_ssd_chunk_kernel, head_block=head_block)
    grid = (B, nc, hb_count)
    y, st = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, head_block, hd),
                         lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, head_block), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1, Q, ds), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, ds), lambda b, c, h: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, head_block, hd),
                         lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, head_block, ds, hd),
                         lambda b, c, h: (b, c, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, Q, nh, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, nh, ds, hd), jnp.float32),
        ],
        interpret=interpret,
    )(xdt, cum, Bc, Cc)
    return y, st

"""Pallas TPU kernel for the Mamba2/SSD WITHIN-CHUNK computation.

The chunked SSD algorithm (models/ssm.py) splits into:
  (a) within-chunk: y_intra = ((C B^T) .* L) (x dt)  and the per-chunk state
      contribution  S_c = B^T (decay-to-end .* x dt)  — all dense matmuls
      over (Q, ds, hd) tiles -> MXU work. THIS kernel.
  (b) across-chunk: a length-nc linear recurrence + rank-1 read-out —
      negligible FLOPs, kept in jnp (lax.scan).

This split is the TPU-native adaptation of the paper's GPU kernel: the
within-chunk part is blocked to VMEM with (Q x Q) decay tiles built on the
VPU and contracted on the MXU.

Grid: (batch, n_chunks, head_blocks). Per-instance working set:
  xdt (Q, hb, hd), cum (Q, hb), B/C (Q, ds), out y (Q, hb, hd),
  states (hb, ds, hd)  — for Q=128, hb=4, hd=64, ds=128: ~0.5 MB. VMEM-safe.

``ssd_chunk`` is the differentiable entry point (``jax.custom_vjp``).
Residual contract: the forward saves only the INPUTS (xdt, cum, Bc, Cc) —
no (Q x Q) tile survives the forward. The backward is one chunked Pallas
kernel over the same grid that recomputes each chunk's decay tile and
score matrix from the saved residuals and emits (dxdt, dcum, dB, dC);
dB/dC are shared across head blocks, so the kernel accumulates them across
the (sequentially iterated) head-block grid axis into a revisited output
block. `cum` is the caller-side inclusive cumsum, so its cotangent is
w.r.t. the cumsum output (models/ssm.py's autodiff handles the chain to
the raw decays).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(xdt_ref, cum_ref, b_ref, c_ref, y_ref, st_ref, *,
                      head_block: int):
    """One (batch, chunk, head-block) instance.

    xdt_ref: (1, 1, Q, hb, hd)   x * dt, fp32
    cum_ref: (1, 1, Q, hb)       inclusive cumsum of log-decay
    b_ref:   (1, 1, Q, ds)
    c_ref:   (1, 1, Q, ds)
    y_ref:   (1, 1, Q, hb, hd)   intra-chunk output
    st_ref:  (1, 1, hb, ds, hd)  chunk state contribution
    """
    xdt = xdt_ref[0, 0].astype(jnp.float32)  # (Q, hb, hd)
    cum = cum_ref[0, 0].astype(jnp.float32)  # (Q, hb)
    Bm = b_ref[0, 0].astype(jnp.float32)  # (Q, ds)
    Cm = c_ref[0, 0].astype(jnp.float32)  # (Q, ds)
    Q = xdt.shape[0]

    scores = Cm @ Bm.T  # (Q, Q) shared across heads in the block
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    )

    for h in range(head_block):  # static unroll over the head block
        ch = cum[:, h]
        decay = jnp.exp(ch[:, None] - ch[None, :])
        L = jnp.where(tri, decay, 0.0)
        y_h = (scores * L) @ xdt[:, h, :]  # (Q, hd)
        y_ref[0, 0, :, h, :] = y_h.astype(y_ref.dtype)
        dte = jnp.exp(ch[-1] - ch)  # decay to end of chunk
        st_h = (Bm * dte[:, None]).T @ xdt[:, h, :]  # (ds, hd)
        st_ref[0, 0, h] = st_h.astype(st_ref.dtype)


def ssd_chunk_fwd(
    xdt: jax.Array,  # (B, nc, Q, nh, hd) fp32
    cum: jax.Array,  # (B, nc, Q, nh)
    Bc: jax.Array,  # (B, nc, Q, ds)
    Cc: jax.Array,  # (B, nc, Q, ds)
    *,
    head_block: int = 4,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y_intra (B,nc,Q,nh,hd), states (B,nc,nh,ds,hd))."""
    B, nc, Q, nh, hd = xdt.shape
    ds = Bc.shape[-1]
    head_block = min(head_block, nh)
    assert nh % head_block == 0
    hb_count = nh // head_block

    kernel = functools.partial(_ssd_chunk_kernel, head_block=head_block)
    grid = (B, nc, hb_count)
    y, st = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, head_block, hd),
                         lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, head_block), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1, Q, ds), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, ds), lambda b, c, h: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, head_block, hd),
                         lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, head_block, ds, hd),
                         lambda b, c, h: (b, c, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, Q, nh, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, nh, ds, hd), jnp.float32),
        ],
        interpret=interpret,
    )(xdt, cum, Bc, Cc)
    return y, st


def _ssd_bwd_kernel(xdt_ref, cum_ref, b_ref, c_ref, dy_ref, dst_ref,
                    dxdt_ref, dcum_ref, db_ref, dc_ref, *, head_block: int):
    """Backward of one (batch, chunk, head-block) instance.

    Recomputes the (Q, Q) decay tile and score matrix per head from the
    saved inputs — mirror of the forward body, transposed. dB/dC blocks are
    revisited across the head-block grid axis: initialized at h == 0, then
    accumulated (the axis is innermost, so revisits are consecutive).
    """
    h_blk = pl.program_id(2)
    xdt = xdt_ref[0, 0].astype(jnp.float32)  # (Q, hb, hd)
    cum = cum_ref[0, 0].astype(jnp.float32)  # (Q, hb)
    Bm = b_ref[0, 0].astype(jnp.float32)  # (Q, ds)
    Cm = c_ref[0, 0].astype(jnp.float32)  # (Q, ds)
    dy = dy_ref[0, 0].astype(jnp.float32)  # (Q, hb, hd)
    dst = dst_ref[0, 0].astype(jnp.float32)  # (hb, ds, hd)
    Q = xdt.shape[0]

    scores = Cm @ Bm.T  # (Q, Q)
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    )

    dscores = jnp.zeros((Q, Q), jnp.float32)
    dB = jnp.zeros_like(Bm)
    for h in range(head_block):  # static unroll over the head block
        ch = cum[:, h]
        decay = jnp.exp(ch[:, None] - ch[None, :])
        L = jnp.where(tri, decay, 0.0)
        X = xdt[:, h, :]  # (Q, hd)
        dy_h = dy[:, h, :]
        dst_h = dst[h]  # (ds, hd)

        # y_h = (scores * L) @ X
        dM = dy_h @ X.T  # (Q, Q)
        dX = (scores * L).T @ dy_h
        dscores = dscores + dM * L
        dLL = dM * scores * L  # d cum via L = tri * exp(ch_i - ch_j)
        dch = dLL.sum(1) - dLL.sum(0)

        # st_h = (Bm * dte)^T @ X,  dte = exp(ch[Q-1] - ch)
        dte = jnp.exp(ch[Q - 1] - ch)
        dX = dX + (Bm * dte[:, None]) @ dst_h
        dBw = X @ dst_h.T  # (Q, ds)
        dB = dB + dBw * dte[:, None]
        ddte_dte = jnp.sum(dBw * Bm, axis=1) * dte  # (Q,)
        dch = dch - ddte_dte
        dch = dch.at[Q - 1].add(ddte_dte.sum())

        dxdt_ref[0, 0, :, h, :] = dX.astype(dxdt_ref.dtype)
        dcum_ref[0, 0, :, h] = dch.astype(dcum_ref.dtype)

    @pl.when(h_blk == 0)
    def _init():
        db_ref[0, 0] = jnp.zeros_like(db_ref[0, 0])
        dc_ref[0, 0] = jnp.zeros_like(dc_ref[0, 0])

    db_ref[0, 0] += (dscores.T @ Cm + dB).astype(db_ref.dtype)
    dc_ref[0, 0] += (dscores @ Bm).astype(dc_ref.dtype)


def ssd_chunk_bwd(
    xdt: jax.Array,
    cum: jax.Array,
    Bc: jax.Array,
    Cc: jax.Array,
    dy: jax.Array,  # (B, nc, Q, nh, hd) cotangent of y_intra
    dst: jax.Array,  # (B, nc, nh, ds, hd) cotangent of chunk states
    *,
    head_block: int = 4,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Chunked backward launch: (dxdt, dcum, dBc, dCc). Shapes as forward."""
    B, nc, Q, nh, hd = xdt.shape
    ds = Bc.shape[-1]
    head_block = min(head_block, nh)
    assert nh % head_block == 0
    hb_count = nh // head_block

    kernel = functools.partial(_ssd_bwd_kernel, head_block=head_block)
    grid = (B, nc, hb_count)
    dxdt, dcum, dB, dC = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, head_block, hd),
                         lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, head_block), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1, Q, ds), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, ds), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, head_block, hd),
                         lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, head_block, ds, hd),
                         lambda b, c, h: (b, c, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, head_block, hd),
                         lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, head_block), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1, Q, ds), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, ds), lambda b, c, h: (b, c, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, Q, nh, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, Q, nh), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, Q, ds), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, Q, ds), jnp.float32),
        ],
        interpret=interpret,
    )(xdt, cum, Bc, Cc, dy, dst)
    return (
        dxdt.astype(xdt.dtype), dcum.astype(cum.dtype),
        dB.astype(Bc.dtype), dC.astype(Cc.dtype),
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def ssd_chunk(xdt, cum, Bc, Cc, head_block=4, interpret=False):
    """Differentiable within-chunk SSD (positional statics for custom_vjp)."""
    return ssd_chunk_fwd(
        xdt, cum, Bc, Cc, head_block=head_block, interpret=interpret
    )


def _ssd_fwd(xdt, cum, Bc, Cc, head_block, interpret):
    """custom_vjp forward: run the kernel, save only the inputs."""
    out = ssd_chunk_fwd(
        xdt, cum, Bc, Cc, head_block=head_block, interpret=interpret
    )
    return out, (xdt, cum, Bc, Cc)


def _ssd_bwd(head_block, interpret, res, cts):
    """custom_vjp backward: dispatch the chunked Pallas gradient kernel."""
    xdt, cum, Bc, Cc = res
    dy, dst = cts
    return ssd_chunk_bwd(
        xdt, cum, Bc, Cc, dy, dst, head_block=head_block, interpret=interpret
    )


ssd_chunk.defvjp(_ssd_fwd, _ssd_bwd)

"""Pallas TPU kernels for the paper's and substrate's compute hot-spots.

  flash_attention  GQA/causal/window/softcap online-softmax attention,
                   custom_vjp with blocked backward kernels (dq + dk/dv
                   tiles recomputed from the saved log-sum-exp)
  ssd_scan         Mamba2/SSD within-chunk compute (MXU blocking),
                   custom_vjp with a chunked backward kernel
  sparse_saga      DSBA per-node sparse row update (one-hot-matmul
                   gather/scatter — the TPU adaptation, DESIGN.md §5)
  topk_compress    block-local top-k for gossip delta streams

Each kernel: <name>.py (pl.pallas_call + BlockSpec); ops.py is the backend
REGISTRY (KernelSpec: pallas/interpret/ref impls + per-kernel forward AND
gradient tolerance policies + the parity_check harness) plus jit'd public
wrappers; ref.py the pure-jnp oracles whose autodiff is also the gradient
ground truth (tests/test_kernels.py sweeps shapes/dtypes in interpret mode;
tests/test_ops_dispatch.py sweeps the registry; tests/test_kernel_grads.py
sweeps the vjps). See docs/kernels.md for the authoring guide.
"""

# Pallas TPU kernels for the paper's and substrate's compute hot-spots:
#   flash_attention  GQA/causal/window/softcap online-softmax attention
#   ssd_scan         Mamba2/SSD within-chunk compute (MXU blocking)
#   sparse_saga      DSBA per-node sparse row update (one-hot-matmul
#                    gather/scatter — the TPU adaptation, DESIGN.md §5)
#   topk_compress    block-local top-k for gossip delta streams
# Each kernel: <name>.py (pl.pallas_call + BlockSpec); ops.py is the
# backend REGISTRY (KernelSpec: pallas/interpret/ref impls + per-kernel
# tolerance policy + the parity_check harness) plus jit'd public wrappers;
# ref.py the pure-jnp oracles (tests/test_kernels.py sweeps shapes/dtypes
# in interpret mode; tests/test_ops_dispatch.py sweeps the registry).

"""Pallas TPU flash attention: GQA + causal + sliding window + softcap.

Blocked online-softmax attention — the S x S score matrix never
materializes; the working set is one (block_q, head_dim) query tile plus
streamed K/V tiles, sized for VMEM, with MXU-aligned (128-multiple) matmul
dims. GQA is expressed in the BlockSpec index maps: the kv specs map query
head h -> kv head h // group_size, so no K/V replication is staged.

Layout: q (B, Hq, S, D), k/v (B, Hkv, S, D) — heads-major so a (S, D) tile
per head streams contiguously from HBM.

K/V streaming uses the current Pallas ref-indexing semantics
(``ref[0, 0, pl.ds(start, size), :]``); ragged sequence lengths are handled
by padding q/k/v to block multiples in the wrapper (zero pad + in-kernel
validity masks), so no dynamic slice ever reads out of bounds.

The forward kernel also emits the per-row log-sum-exp, which
``flash_attention`` (a ``jax.custom_vjp``) saves as a residual: the backward
pass reconstructs the probabilities from (q, k, v, o, lse) directly instead
of re-running a reference forward under autodiff.

Validated against kernels/ref.py in interpret mode (tests/test_kernels.py);
dispatch and tolerance policy live in kernels/ops.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, lse_ref, *,
    block_q: int, block_k: int, seq_k: int, causal: bool,
    window: int | None, softcap: float | None, scale: float,
):
    """One (batch, q-head, q-block) program instance.

    q_ref: (1, 1, block_q, D); k_ref/v_ref: (1, 1, seq_k_pad, D);
    o_ref: (1, 1, block_q, D); lse_ref: (1, 1, block_q).
    """
    q_blk = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale  # (block_q, D)
    D = q.shape[-1]
    q_pos = q_blk * block_q + jax.lax.iota(jnp.int32, block_q)

    num_k_blocks = pl.cdiv(seq_k, block_k)

    def body(i, carry):
        acc, m_prev, l_prev = carry
        # seq_k_pad is a multiple of block_k (wrapper zero-pads), so the
        # dynamic slice is always in bounds; pad rows are masked below.
        k_tile = k_ref[0, 0, pl.ds(i * block_k, block_k), :].astype(
            jnp.float32
        )
        v_tile = v_ref[0, 0, pl.ds(i * block_k, block_k), :].astype(
            jnp.float32
        )
        k_pos = i * block_k + jax.lax.iota(jnp.int32, block_k)
        s = q @ k_tile.T  # (block_q, block_k)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        mask &= (k_pos < seq_k)[None, :]
        s = jnp.where(mask, s, NEG_INF)

        m_cur = jnp.maximum(m_prev, s.max(-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + p.sum(-1)
        acc = acc * alpha[:, None] + p @ v_tile
        return acc, m_cur, l_cur

    acc0 = jnp.zeros((block_q, D), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)

    if causal:
        # only stream kv blocks that intersect the causal/window band
        hi = jnp.minimum(
            num_k_blocks, (q_blk + 1) * block_q // block_k + 1
        )
    else:
        hi = num_k_blocks
    lo = 0
    if window is not None:
        lo = jnp.maximum(0, (q_blk * block_q - window) // block_k)
    acc, m, l = jax.lax.fori_loop(lo, hi, body, (acc0, m0, l0))
    l_safe = jnp.maximum(l, 1e-30)
    o_ref[0, 0] = (acc / l_safe[:, None]).astype(o_ref.dtype)
    lse_ref[0, 0] = m + jnp.log(l_safe)


def _pad_seq(x: jax.Array, to: int) -> jax.Array:
    pad = (-x.shape[2]) % to
    if not pad:
        return x
    return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))


def flash_attention_fwd(
    q: jax.Array,  # (B, Hq, S, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    return_lse: bool = False,
):
    """Forward kernel launch. Returns o, or (o, lse (B, Hq, S) f32)."""
    B, Hq, S, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    block_q = min(block_q, S)
    block_k = min(block_k, Sk)

    # zero-pad ragged sequences to block multiples: every q block and every
    # streamed K/V slice is full-sized, and validity is a mask, not an OOB
    # read (padded q rows are fully masked -> finite garbage, sliced off).
    qp = _pad_seq(q, block_q)
    kp = _pad_seq(k, block_k)
    vp = _pad_seq(v, block_k)
    Sp, Skp = qp.shape[2], kp.shape[2]

    grid = (B, Hq, Sp // block_q)
    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, seq_k=Sk,
        causal=causal, window=window, softcap=softcap, scale=scale,
    )
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Skp, D), lambda b, h, i: (b, h // group, 0, 0)),
            pl.BlockSpec((1, 1, Skp, D), lambda b, h, i: (b, h // group, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, Sp, D), q.dtype),
            jax.ShapeDtypeStruct((B, Hq, Sp), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    o = o[:, :, :S]
    if return_lse:
        return o, lse[:, :, :S]
    return o


# ---------------------------------------------------------------------------
# custom VJP: forward = the Pallas kernel (saving lse), backward = the
# standard flash-attention gradient reconstructed from saved residuals.
# The score/mask semantics come from kernels/ref.py attention_scores — the
# single definition shared with the oracle, so forward and gradient cannot
# drift apart.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(
    q, k, v, causal=True, window=None, softcap=None,
    block_q=128, block_k=128, interpret=False,
):
    """Differentiable flash attention (positional statics for custom_vjp)."""
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


def _fa_fwd(q, k, v, causal, window, softcap, block_q, block_k, interpret):
    o, lse = flash_attention_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=interpret,
        return_lse=True,
    )
    return o, (q, k, v, o, lse)


def _fa_bwd(causal, window, softcap, block_q, block_k, interpret, res, do):
    from repro.kernels.ref import attention_scores

    q, k, v, o, lse = res
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    scale = 1.0 / math.sqrt(D)

    s, mask = attention_scores(q, k, causal=causal, window=window,
                               softcap=softcap)
    grp = lambda x: x.reshape(B, Hkv, g, *x.shape[2:]).astype(jnp.float32)
    do_g, o_g, lse_g = grp(do), grp(o), grp(lse)

    # p = softmax reconstructed exactly from the saved log-sum-exp
    p = jnp.where(
        mask[None, None, None], jnp.exp(s - lse_g[..., None]), 0.0
    )
    dv = jnp.einsum("bkgst,bkgsd->bktd", p, do_g)
    dp = jnp.einsum("bkgsd,bktd->bkgst", do_g, v.astype(jnp.float32))
    delta = jnp.sum(do_g * o_g, axis=-1)  # rowsum(do * o)
    ds = p * (dp - delta[..., None])
    if softcap is not None:
        ds = ds * (1.0 - jnp.square(s / softcap))  # d softcap*tanh(x/softcap)
    dq = scale * jnp.einsum("bkgst,bktd->bkgsd", ds, k.astype(jnp.float32))
    dk = scale * jnp.einsum("bkgst,bkgsd->bktd", ds,
                            q.reshape(B, Hkv, g, S, D).astype(jnp.float32))
    return (
        dq.reshape(B, Hq, S, D).astype(q.dtype),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
    )


flash_attention.defvjp(_fa_fwd, _fa_bwd)

"""Pallas TPU flash attention: GQA + causal + sliding window + softcap.

Blocked online-softmax attention — the S x S score matrix never
materializes; the working set is one (block_q, head_dim) query tile plus
streamed K/V tiles, sized for VMEM, with MXU-aligned (128-multiple) matmul
dims. GQA is expressed in the BlockSpec index maps: the kv specs map query
head h -> kv head h // group_size, so no K/V replication is staged.

Layout: q (B, Hq, S, D), k/v (B, Hkv, S, D) — heads-major so a (S, D) tile
per head streams contiguously from HBM.

K/V streaming uses the current Pallas ref-indexing semantics
(``ref[0, 0, pl.ds(start, size), :]``); ragged sequence lengths are handled
by padding q/k/v to block multiples in the wrapper (zero pad + in-kernel
validity masks), so no dynamic slice ever reads out of bounds.

The forward kernel also emits the per-row log-sum-exp, which
``flash_attention`` (a ``jax.custom_vjp``) saves as a residual.

Residual contract: the forward saves (q, k, v, o, lse) and NOTHING that is
O(S^2). The backward is the blocked flash-attention gradient — two Pallas
kernels that recompute the probabilities per (q-block, kv-block) TILE from
the saved log-sum-exp (p = exp(s - lse)), so no S x S probability matrix
ever materializes in either direction:

  dq kernel   grid (B, Hq, q-blocks): holds one dq tile, streams K/V
  dk/dv kernel  grid (B, Hq, kv-blocks): holds one dk/dv tile, streams
              Q/dO/lse/delta; per-q-head partials are group-summed into
              kv heads by the wrapper (GQA)

delta = rowsum(dO * O) — the softmax-gradient row correction — is a cheap
O(S) jnp precomputation shared by both kernels.

Validated against kernels/ref.py in interpret mode (tests/test_kernels.py,
tests/test_kernel_grads.py asserts vjp==ref-autodiff and the no-S^2
property); dispatch and tolerance policy live in kernels/ops.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, lse_ref, *,
    block_q: int, block_k: int, seq_k: int, causal: bool,
    window: int | None, softcap: float | None, scale: float,
):
    """One (batch, q-head, q-block) program instance.

    q_ref: (1, 1, block_q, D); k_ref/v_ref: (1, 1, seq_k_pad, D);
    o_ref: (1, 1, block_q, D); lse_ref: (1, 1, block_q).
    """
    q_blk = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale  # (block_q, D)
    D = q.shape[-1]
    q_pos = q_blk * block_q + jax.lax.iota(jnp.int32, block_q)

    num_k_blocks = pl.cdiv(seq_k, block_k)

    def body(i, carry):
        acc, m_prev, l_prev = carry
        # seq_k_pad is a multiple of block_k (wrapper zero-pads), so the
        # dynamic slice is always in bounds; pad rows are masked below.
        k_tile = k_ref[0, 0, pl.ds(i * block_k, block_k), :].astype(
            jnp.float32
        )
        v_tile = v_ref[0, 0, pl.ds(i * block_k, block_k), :].astype(
            jnp.float32
        )
        k_pos = i * block_k + jax.lax.iota(jnp.int32, block_k)
        s = q @ k_tile.T  # (block_q, block_k)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        mask &= (k_pos < seq_k)[None, :]
        s = jnp.where(mask, s, NEG_INF)

        m_cur = jnp.maximum(m_prev, s.max(-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + p.sum(-1)
        acc = acc * alpha[:, None] + p @ v_tile
        return acc, m_cur, l_cur

    acc0 = jnp.zeros((block_q, D), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)

    if causal:
        # only stream kv blocks that intersect the causal/window band
        hi = jnp.minimum(
            num_k_blocks, (q_blk + 1) * block_q // block_k + 1
        )
    else:
        hi = num_k_blocks
    lo = 0
    if window is not None:
        lo = jnp.maximum(0, (q_blk * block_q - window) // block_k)
    acc, m, l = jax.lax.fori_loop(lo, hi, body, (acc0, m0, l0))
    l_safe = jnp.maximum(l, 1e-30)
    o_ref[0, 0] = (acc / l_safe[:, None]).astype(o_ref.dtype)
    lse_ref[0, 0] = m + jnp.log(l_safe)


def _pad_seq(x: jax.Array, to: int) -> jax.Array:
    pad = (-x.shape[2]) % to
    if not pad:
        return x
    return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))


def flash_attention_fwd(
    q: jax.Array,  # (B, Hq, S, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    return_lse: bool = False,
):
    """Forward kernel launch. Returns o, or (o, lse (B, Hq, S) f32)."""
    B, Hq, S, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    block_q = min(block_q, S)
    block_k = min(block_k, Sk)

    # zero-pad ragged sequences to block multiples: every q block and every
    # streamed K/V slice is full-sized, and validity is a mask, not an OOB
    # read (padded q rows are fully masked -> finite garbage, sliced off).
    qp = _pad_seq(q, block_q)
    kp = _pad_seq(k, block_k)
    vp = _pad_seq(v, block_k)
    Sp, Skp = qp.shape[2], kp.shape[2]

    grid = (B, Hq, Sp // block_q)
    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, seq_k=Sk,
        causal=causal, window=window, softcap=softcap, scale=scale,
    )
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Skp, D), lambda b, h, i: (b, h // group, 0, 0)),
            pl.BlockSpec((1, 1, Skp, D), lambda b, h, i: (b, h // group, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, Sp, D), q.dtype),
            jax.ShapeDtypeStruct((B, Hq, Sp), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    o = o[:, :, :S]
    if return_lse:
        return o, lse[:, :, :S]
    return o


# ---------------------------------------------------------------------------
# blocked backward kernels
#
# Both recompute the (block_q, block_k) probability tile from the saved lse
# (p = exp(s - lse); masked entries are NEG_INF before the subtraction, so
# they reconstruct to exactly 0 — including the zero-padded rows, whose
# padded lse of 0 is never reached by a live probability). The score/mask
# semantics mirror the forward kernel body above tile for tile, so the
# gradient cannot drift from the forward.
# ---------------------------------------------------------------------------


def _bwd_tile(q, k, v, do, lse, delta, q_pos, k_pos, *,
              seq_q, seq_k, causal, window, softcap):
    """Shared per-tile math: (p, ds) from one (block_q, block_k) tile.

    q is pre-scaled; all operands f32. Invalid (masked / padded) pairs
    yield p = ds = 0 exactly.
    """
    s = q @ k.T  # (block_q, block_k), pre-softcap
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = jnp.ones(s.shape, jnp.bool_)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    mask &= (q_pos < seq_q)[:, None] & (k_pos < seq_k)[None, :]
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])  # rebuilt from the residual, <= 1
    dp = do @ v.T  # (block_q, block_k)
    ds = p * (dp - delta[:, None])
    if softcap is not None:
        # d/dx softcap*tanh(x/softcap) = 1 - tanh^2 = 1 - (s/softcap)^2
        ds = ds * jnp.where(mask, 1.0 - jnp.square(s / softcap), 0.0)
    return p, ds


def _attn_bwd_dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref, dq_ref, *,
    block_q: int, block_k: int, seq_q: int, seq_k: int, causal: bool,
    window: int | None, softcap: float | None, scale: float,
):
    """dq for one (batch, q-head, q-block): stream KV tiles, accumulate.

    q/do/dq refs: (1, 1, block_q, D); k/v refs: (1, 1, seq_k_pad, D);
    lse/dl refs: (1, 1, block_q).
    """
    q_blk = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)
    delta = dl_ref[0, 0].astype(jnp.float32)
    D = q.shape[-1]
    q_pos = q_blk * block_q + jax.lax.iota(jnp.int32, block_q)
    num_k_blocks = pl.cdiv(seq_k, block_k)

    def body(i, acc):
        k_tile = k_ref[0, 0, pl.ds(i * block_k, block_k), :].astype(
            jnp.float32
        )
        v_tile = v_ref[0, 0, pl.ds(i * block_k, block_k), :].astype(
            jnp.float32
        )
        k_pos = i * block_k + jax.lax.iota(jnp.int32, block_k)
        _, ds = _bwd_tile(
            q, k_tile, v_tile, do, lse, delta, q_pos, k_pos,
            seq_q=seq_q, seq_k=seq_k, causal=causal, window=window,
            softcap=softcap,
        )
        return acc + ds @ k_tile

    if causal:
        hi = jnp.minimum(num_k_blocks, (q_blk + 1) * block_q // block_k + 1)
    else:
        hi = num_k_blocks
    lo = 0
    if window is not None:
        lo = jnp.maximum(0, (q_blk * block_q - window) // block_k)
    acc = jax.lax.fori_loop(lo, hi, body, jnp.zeros((block_q, D), jnp.float32))
    dq_ref[0, 0] = (scale * acc).astype(dq_ref.dtype)


def _attn_bwd_dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref, dk_ref, dv_ref, *,
    block_q: int, block_k: int, seq_q: int, seq_k: int, causal: bool,
    window: int | None, softcap: float | None, scale: float,
):
    """dk/dv (per q head) for one (batch, q-head, kv-block): stream Q tiles.

    k/v/dk/dv refs: (1, 1, block_k, D); q/do refs: (1, 1, seq_q_pad, D);
    lse/dl refs: (1, 1, seq_q_pad). GQA group-sum happens in the wrapper.
    """
    k_blk = pl.program_id(2)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    D = k.shape[-1]
    k_pos = k_blk * block_k + jax.lax.iota(jnp.int32, block_k)
    num_q_blocks = pl.cdiv(seq_q, block_q)

    def body(i, carry):
        dk_acc, dv_acc = carry
        q_tile = q_ref[0, 0, pl.ds(i * block_q, block_q), :].astype(
            jnp.float32
        ) * scale
        do_tile = do_ref[0, 0, pl.ds(i * block_q, block_q), :].astype(
            jnp.float32
        )
        lse = lse_ref[0, 0, pl.ds(i * block_q, block_q)].astype(jnp.float32)
        delta = dl_ref[0, 0, pl.ds(i * block_q, block_q)].astype(jnp.float32)
        q_pos = i * block_q + jax.lax.iota(jnp.int32, block_q)
        p, ds = _bwd_tile(
            q_tile, k, v, do_tile, lse, delta, q_pos, k_pos,
            seq_q=seq_q, seq_k=seq_k, causal=causal, window=window,
            softcap=softcap,
        )
        return dk_acc + ds.T @ q_tile, dv_acc + p.T @ do_tile

    # only q blocks intersecting the causal/window band see this kv tile
    lo = k_blk * block_k // block_q if causal else 0
    hi = num_q_blocks
    if window is not None:
        hi = jnp.minimum(
            num_q_blocks, ((k_blk + 1) * block_k - 1 + window) // block_q + 1
        )
    zeros = jnp.zeros((block_k, D), jnp.float32)
    dk_acc, dv_acc = jax.lax.fori_loop(lo, hi, body, (zeros, zeros))
    # q_tile is pre-scaled, so ds^T @ q_tile already carries the 1/sqrt(D)
    dk_ref[0, 0] = dk_acc.astype(dk_ref.dtype)
    dv_ref[0, 0] = dv_acc.astype(dv_ref.dtype)


def flash_attention_bwd(
    q: jax.Array,  # (B, Hq, S, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,
    o: jax.Array,  # (B, Hq, S, D)   saved forward output
    lse: jax.Array,  # (B, Hq, S) f32  saved log-sum-exp
    do: jax.Array,  # (B, Hq, S, D)   output cotangent
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Blocked backward launch: (dq, dk, dv) from the saved residuals.

    Two tiled ``pl.pallas_call`` grids (dq over q blocks, dk/dv over kv
    blocks) with the same causal/window/softcap statics as the forward;
    per-q-head dk/dv partials are summed over each GQA group here.
    """
    B, Hq, S, D = q.shape
    _, Hkv, Sk, _ = k.shape
    group = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    block_q = min(block_q, S)
    block_k = min(block_k, Sk)

    # delta = rowsum(do * o): the softmax-gradient row term, O(S) memory
    delta = jnp.sum(
        do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1
    )  # (B, Hq, S)

    qp, dop = _pad_seq(q, block_q), _pad_seq(do, block_q)
    kp, vp = _pad_seq(k, block_k), _pad_seq(v, block_k)
    pad_q = qp.shape[2] - S
    lsep = jnp.pad(lse, ((0, 0), (0, 0), (0, pad_q)))
    deltap = jnp.pad(delta, ((0, 0), (0, 0), (0, pad_q)))
    Sp, Skp = qp.shape[2], kp.shape[2]

    statics = dict(
        block_q=block_q, block_k=block_k, seq_q=S, seq_k=Sk, causal=causal,
        window=window, softcap=softcap, scale=scale,
    )
    dq = pl.pallas_call(
        functools.partial(_attn_bwd_dq_kernel, **statics),
        grid=(B, Hq, Sp // block_q),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Skp, D), lambda b, h, i: (b, h // group, 0, 0)),
            pl.BlockSpec((1, 1, Skp, D), lambda b, h, i: (b, h // group, 0, 0)),
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i: (b, h, i)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i: (b, h, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sp, D), jnp.float32),
        interpret=interpret,
    )(qp, kp, vp, dop, lsep, deltap)

    dkq, dvq = pl.pallas_call(
        functools.partial(_attn_bwd_dkv_kernel, **statics),
        grid=(B, Hq, Skp // block_k),
        in_specs=[
            pl.BlockSpec((1, 1, Sp, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, Sp, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, Sp), lambda b, h, j: (b, h, 0)),
            pl.BlockSpec((1, 1, Sp), lambda b, h, j: (b, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, j: (b, h, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, Skp, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hq, Skp, D), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp, dop, lsep, deltap)

    # GQA: sum the per-q-head partials into their kv head
    dk = dkq.reshape(B, Hkv, group, Skp, D).sum(2)[:, :, :Sk]
    dv = dvq.reshape(B, Hkv, group, Skp, D).sum(2)[:, :, :Sk]
    return (
        dq[:, :, :S].astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)
    )


# ---------------------------------------------------------------------------
# custom VJP: forward = the Pallas kernel (saving lse), backward = the
# blocked Pallas gradient above. The ref oracle's autodiff
# (jax.grad of kernels/ref.py attention_ref) is the gradient ground truth
# the parity harness compares against.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(
    q, k, v, causal=True, window=None, softcap=None,
    block_q=128, block_k=128, interpret=False,
):
    """Differentiable flash attention (positional statics for custom_vjp)."""
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


def _fa_fwd(q, k, v, causal, window, softcap, block_q, block_k, interpret):
    """custom_vjp forward: run the kernel, save (q, k, v, o, lse)."""
    o, lse = flash_attention_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=interpret,
        return_lse=True,
    )
    return o, (q, k, v, o, lse)


def _fa_bwd(causal, window, softcap, block_q, block_k, interpret, res, do):
    """custom_vjp backward: dispatch the blocked Pallas gradient kernels."""
    q, k, v, o, lse = res
    return flash_attention_bwd(
        q, k, v, o, lse, do, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


flash_attention.defvjp(_fa_fwd, _fa_bwd)

"""Pallas TPU flash attention (fwd): GQA + causal + sliding window + softcap.

Blocked online-softmax attention — the S x S score matrix never
materializes; the working set is one (block_q, head_dim) query tile plus
streamed K/V tiles, sized for VMEM, with MXU-aligned (128-multiple) matmul
dims. GQA is expressed in the BlockSpec index maps: the kv specs map query
head h -> kv head h // group_size, so no K/V replication is staged.

Layout: q (B, Hq, S, D), k/v (B, Hkv, S, D) — heads-major so a (S, D) tile
per head streams contiguously from HBM.

Validated against kernels/ref.py in interpret mode (tests/test_kernels.py);
the bwd pass recomputes through the reference path (ops.flash_attention).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, *,
    block_q: int, block_k: int, seq_k: int, causal: bool,
    window: int | None, softcap: float | None, scale: float,
):
    """One (batch, q-head, q-block) program instance.

    q_ref: (block_q, D); k_ref/v_ref: (seq_k, D); o_ref: (block_q, D).
    """
    q_blk = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale  # (block_q, D)
    D = q.shape[-1]
    q_pos = q_blk * block_q + jax.lax.iota(jnp.int32, block_q)

    num_k_blocks = pl.cdiv(seq_k, block_k)

    def body(i, carry):
        acc, m_prev, l_prev = carry
        # pl.load (not ref[...]): its OOB-read semantics on the ragged last
        # block are well-defined here and masked below; the ref[] indexing
        # path miscompiles the padded tail in interpret mode.
        k_tile = pl.load(
            k_ref, (0, 0, pl.dslice(i * block_k, block_k), slice(None))
        ).astype(jnp.float32)
        v_tile = pl.load(
            v_ref, (0, 0, pl.dslice(i * block_k, block_k), slice(None))
        ).astype(jnp.float32)
        k_pos = i * block_k + jax.lax.iota(jnp.int32, block_k)
        valid = (k_pos < seq_k)[:, None]
        k_tile = jnp.where(valid, k_tile, 0.0)  # OOB pad rows -> 0, not NaN
        v_tile = jnp.where(valid, v_tile, 0.0)
        s = q @ k_tile.T  # (block_q, block_k)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        mask &= (k_pos < seq_k)[None, :]
        s = jnp.where(mask, s, NEG_INF)

        m_cur = jnp.maximum(m_prev, s.max(-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + p.sum(-1)
        acc = acc * alpha[:, None] + p @ v_tile
        return acc, m_cur, l_cur

    acc0 = jnp.zeros((block_q, D), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)

    if causal:
        # only stream kv blocks that intersect the causal/window band
        hi = jnp.minimum(
            num_k_blocks, (q_blk + 1) * block_q // block_k + 1
        )
    else:
        hi = num_k_blocks
    lo = 0
    if window is not None:
        lo = jnp.maximum(0, (q_blk * block_q - window) // block_k)
    acc, m, l = jax.lax.fori_loop(lo, hi, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,  # (B, Hq, S, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, S, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    block_q = min(block_q, S)
    block_k = min(block_k, Sk)

    grid = (B, Hq, pl.cdiv(S, block_q))
    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, seq_k=Sk,
        causal=causal, window=window, softcap=softcap, scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Sk, D), lambda b, h, i: (b, h // group, 0, 0)),
            pl.BlockSpec((1, 1, Sk, D), lambda b, h, i: (b, h // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, D), q.dtype),
        interpret=interpret,
    )(q, k, v)

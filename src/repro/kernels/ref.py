"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests).

These are also the GRADIENT oracles: each oracle is plain differentiable
jnp, so ``jax.grad`` through it is the reference the registry's
``parity_check(..., grads=True)`` compares the custom_vjp blocked backward
kernels against (kernels/ops.py grad-tolerance policies).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_scores(
    q: jax.Array,  # (B, Hq, S, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
) -> tuple[jax.Array, jax.Array]:
    """THE definition of the attention score semantics: grouped-GQA
    (B, Hkv, g, S, Sk) f32 scores (scaled, softcapped) + (S, Sk) bool mask.

    Shared by the oracle forward below and the flash-attention custom_vjp
    backward (kernels/flash_attention.py), so a semantics change cannot
    drift between the forward and its gradient.
    """
    B, Hq, S, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, Hkv, g, S, D).astype(jnp.float32) / math.sqrt(D)
    s = jnp.einsum("bkgsd,bktd->bkgst", qg, k.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(S)
    kp = jnp.arange(Sk)
    mask = jnp.ones((S, Sk), bool)
    if causal:
        mask &= qp[:, None] >= kp[None, :]
    if window is not None:
        mask &= qp[:, None] - kp[None, :] < window
    return s, mask


def attention_ref(
    q: jax.Array,  # (B, Hq, S, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
) -> jax.Array:
    """Dense softmax-attention oracle: (B, Hq, S, D) output in q.dtype."""
    B, Hq, S, D = q.shape
    s, mask = attention_scores(q, k, causal=causal, window=window,
                               softcap=softcap)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, S, D).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,  # (B, Hq, D) — one query token per sequence
    k_pool: jax.Array,  # (n_blocks, block_size, Hkv, D)
    v_pool: jax.Array,
    table: jax.Array,  # (B, n_pages) int32
    lengths: jax.Array,  # (B,) int32 — valid tokens incl. the current one
    *,
    window: int | None = None,
    softcap: float | None = None,
) -> jax.Array:
    """Paged single-query attention oracle: jnp gather through the block
    table, then masked GQA softmax-attention over the flattened pages.
    Rows with ``lengths == 0`` (scheduler padding lanes) return zeros, to
    match the kernel's ``max(l, eps)`` guard."""
    B, Hq, D = q.shape
    block_size, Hkv = k_pool.shape[1], k_pool.shape[2]
    g = Hq // Hkv
    L = table.shape[1] * block_size
    k = k_pool[table].reshape(B, L, Hkv, D).astype(jnp.float32)
    v = v_pool[table].reshape(B, L, Hkv, D).astype(jnp.float32)
    qg = q.reshape(B, Hkv, g, D).astype(jnp.float32) / math.sqrt(D)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(L)
    mask = pos[None, :] < lengths[:, None]  # (B, L)
    if window is not None:
        # the single query sits at position lengths - 1
        mask &= pos[None, :] >= lengths[:, None] - window
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows would softmax to uniform; zero them instead
    p = jnp.where(mask[:, None, None, :], p, 0.0)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v)
    return o.reshape(B, Hq, D).astype(q.dtype)


def ssd_chunk_ref(xdt, cum, Bc, Cc):
    """Within-chunk SSD: (y_intra, chunk states). Shapes as ssd_chunk_fwd."""
    Q = xdt.shape[2]
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,i,j,nh)
    # mask BEFORE the exp: above-diagonal diffs can overflow exp to inf,
    # and jax.grad(where(tri, exp(diff), 0)) then propagates inf * 0 = NaN
    # cotangents through the masked-out lanes (the exp VJP multiplies the
    # zero upstream cotangent by the inf primal)
    decay = jnp.where(tri, jnp.exp(jnp.where(tri, diff, 0.0)), 0.0)
    scores = jnp.einsum("bcis,bcjs->bcij", Cc, Bc)
    y = jnp.einsum("bcij,bcijh,bcjhd->bcihd", scores, decay, xdt)
    dte = jnp.exp(cum[:, :, -1:, :] - cum)
    st = jnp.einsum("bcjs,bcjh,bcjhd->bchsd", Bc, dte, xdt)
    return y, st


def sparse_dot_ref(psi, idx, val):
    """Per-node sparse dot oracle: out[n] = sum_k val[n,k] * psi[n, idx[n,k]]."""
    # f32 floor matches the TPU kernel's MXU accumulation; f64 inputs stay
    # f64 so the interpret-mode parity policy (1e-12) is meetable
    ct = jnp.promote_types(psi.dtype, jnp.float32)
    return jax.vmap(lambda p, i, v: jnp.sum(v * p[i]))(
        psi.astype(ct), idx, val.astype(ct)
    )


def sparse_axpy_ref(psi, idx, val, coef, rho):
    """Sparse AXPY oracle: out[n] = rho[n] * psi[n] + coef[n] * scatter(val)."""

    def one(p, i, v, c, r):
        return (r * p).at[i].add(c * v)

    return jax.vmap(one)(psi, idx, val, coef.astype(psi.dtype),
                         rho.astype(psi.dtype))


def block_topk_ref(x, k):
    """Per-block top-k-by-|value| oracle via lax.top_k: (vals, int32 idx)."""

    def one(row):
        _, i = jax.lax.top_k(jnp.abs(row), k)
        return row[i], i.astype(jnp.int32)

    return jax.vmap(one)(x)

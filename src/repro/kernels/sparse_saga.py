"""Pallas TPU kernels for the DSBA per-iteration sparse row update.

The paper's per-node hot loop with linear predictors is:
  (1) s   = x^T psi            sparse gather-dot   (nnz = k elements)
  (2) g   = resolvent scalar   (O(1), stays in jnp)
  (3) z   = rho psi - a g x    sparse AXPY          (k elements)

GPUs do (1)/(3) with native gather/scatter; TPUs have no efficient VMEM
gather, so the TPU-native adaptation processes the d-dimensional model row
in VMEM blocks and expresses gather/scatter as ONE-HOT MATMULS against the
in-block index match — turning irregular memory access into MXU contractions
(DESIGN.md §5). Cost per node: O(k * d_block) per block, O(k * d) total —
the same O(rho d) as the paper.

Grid: (N nodes, d blocks). sparse_dot accumulates per-node partial dots via
an output block revisited across the d grid axis; sparse_axpy is elementwise
per block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dot_kernel(psi_ref, idx_ref, val_ref, out_ref, *, block_d: int, d: int,
                compute_dtype):
    """Accumulate sum(val * psi[idx]) for indices landing in this d-block."""
    j = pl.program_id(1)
    psi = psi_ref[0].astype(compute_dtype)  # (block_d,)
    idx = idx_ref[0]  # (k,)
    val = val_ref[0].astype(compute_dtype)  # (k,)
    lo = j * block_d
    # ragged last block: out-of-range pad columns read garbage/NaN -> zero
    col = lo + jax.lax.iota(jnp.int32, block_d)
    psi = jnp.where(col < d, psi, 0.0)
    local = idx - lo
    in_blk = (local >= 0) & (local < block_d)
    # one-hot (k, block_d) match -> gather as a matvec on the MXU
    onehot = (
        local[:, None]
        == jax.lax.broadcasted_iota(jnp.int32, (1, block_d), 1)
    ) & in_blk[:, None]
    gathered = (onehot.astype(compute_dtype) @ psi[:, None])[:, 0]  # (k,)
    partial = jnp.sum(val * gathered)

    @pl.when(j == 0)
    def _init():
        out_ref[0] = jnp.zeros_like(out_ref[0])

    out_ref[0] += partial.astype(out_ref.dtype)


def sparse_dot(
    psi: jax.Array,  # (N, D)
    idx: jax.Array,  # (N, k) int32
    val: jax.Array,  # (N, k)
    *,
    block_d: int = 512,
    interpret: bool = False,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Per-node sparse dot products: out[n] = sum_k val[n,k] * psi[n, idx[n,k]].

    compute_dtype: accumulation dtype inside the kernel. float32 is the TPU
    MXU-native default; pass psi.dtype (e.g. float64 in interpret mode on
    CPU) when the caller needs bit-exact agreement with a f64 reference.
    """
    N, D = psi.shape
    k = idx.shape[1]
    block_d = min(block_d, D)
    grid = (N, pl.cdiv(D, block_d))
    kernel = functools.partial(
        _dot_kernel, block_d=block_d, d=D, compute_dtype=compute_dtype
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_d), lambda n, j: (n, j)),
            pl.BlockSpec((1, k), lambda n, j: (n, 0)),
            pl.BlockSpec((1, k), lambda n, j: (n, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda n, j: (n,)),
        out_shape=jax.ShapeDtypeStruct((N,), compute_dtype),
        interpret=interpret,
    )(psi, idx.astype(jnp.int32), val)


def _axpy_kernel(psi_ref, idx_ref, val_ref, coef_ref, rho_ref, out_ref, *,
                 block_d: int, compute_dtype):
    """out_block = rho * psi_block + coef * scatter(val at idx) in-block.

    Handles a (node_block, block_d) tile: the one-hot match is batched over
    the node axis, so a single grid cell can cover several nodes (node_block
    > 1 keeps the interpret-mode grid tiny on CPU).
    """
    j = pl.program_id(1)
    psi = psi_ref[...].astype(compute_dtype)  # (nb, block_d)
    idx = idx_ref[...]  # (nb, k)
    val = val_ref[...].astype(compute_dtype)
    coef = coef_ref[...].astype(compute_dtype)  # (nb,)
    rho = rho_ref[...].astype(compute_dtype)
    lo = j * block_d
    local = idx - lo
    in_blk = (local >= 0) & (local < block_d)
    onehot = (
        local[:, :, None]
        == jax.lax.broadcasted_iota(jnp.int32, (1, 1, block_d), 2)
    ) & in_blk[:, :, None]
    # batched gather-as-matmul: (nb, k) x (nb, k, block_d) -> (nb, block_d)
    scat = jnp.einsum(
        "nk,nkb->nb", val, onehot.astype(compute_dtype),
        preferred_element_type=compute_dtype,
    )
    out = rho[:, None] * psi + coef[:, None] * scat
    out_ref[...] = out.astype(out_ref.dtype)


def sparse_axpy(
    psi: jax.Array,  # (N, D)
    idx: jax.Array,  # (N, k)
    val: jax.Array,  # (N, k)
    coef: jax.Array,  # (N,)   e.g. -a_eff * g_n
    rho: jax.Array,  # (N,)   e.g. 1/(1+alpha lam)
    *,
    block_d: int = 512,
    interpret: bool = False,
    compute_dtype=jnp.float32,
    node_block: int = 1,
) -> jax.Array:
    """out[n] = rho[n] * psi[n] + coef[n] * x_n (sparse row scatter).

    compute_dtype: in-kernel arithmetic dtype (see sparse_dot). The output
    keeps psi.dtype either way.
    node_block: nodes per grid cell. 1 (default) is the TPU layout; CPU
    interpret-mode callers pass node_block=N to collapse the grid to a
    single cell (the emulated grid is a compile-time loop, so a small grid
    keeps trace/compile time flat).
    """
    N, D = psi.shape
    k = idx.shape[1]
    block_d = min(block_d, D)
    node_block = min(node_block, N)
    if N % node_block:
        raise ValueError(f"node_block={node_block} must divide N={N}")
    grid = (N // node_block, pl.cdiv(D, block_d))
    kernel = functools.partial(
        _axpy_kernel, block_d=block_d, compute_dtype=compute_dtype
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((node_block, block_d), lambda n, j: (n, j)),
            pl.BlockSpec((node_block, k), lambda n, j: (n, 0)),
            pl.BlockSpec((node_block, k), lambda n, j: (n, 0)),
            pl.BlockSpec((node_block,), lambda n, j: (n,)),
            pl.BlockSpec((node_block,), lambda n, j: (n,)),
        ],
        out_specs=pl.BlockSpec((node_block, block_d), lambda n, j: (n, j)),
        out_shape=jax.ShapeDtypeStruct((N, D), psi.dtype),
        interpret=interpret,
    )(psi, idx.astype(jnp.int32), val, coef, rho)

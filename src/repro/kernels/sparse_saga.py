"""Pallas TPU kernels for the DSBA per-iteration sparse row update.

The paper's per-node hot loop with linear predictors is:
  (1) s   = x^T psi            sparse gather-dot   (nnz = k elements)
  (2) g   = resolvent scalar   (O(1), stays in jnp)
  (3) z   = rho psi - a g x    sparse AXPY          (k elements)

GPUs do (1)/(3) with native gather/scatter; TPUs have no efficient VMEM
gather, so the TPU-native adaptation processes the d-dimensional model row
in VMEM blocks and expresses gather/scatter as ONE-HOT MATMULS against the
in-block index match — turning irregular memory access into MXU contractions
(DESIGN.md §5). Cost per node: O(k * d_block) per block, O(k * d) total —
the same O(rho d) as the paper.

Grid: (N nodes, d blocks). sparse_dot accumulates per-node partial dots via
an output block revisited across the d grid axis; sparse_axpy is elementwise
per block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dot_kernel(psi_ref, idx_ref, val_ref, out_ref, *, block_d: int, d: int):
    """Accumulate sum(val * psi[idx]) for indices landing in this d-block."""
    j = pl.program_id(1)
    psi = psi_ref[0].astype(jnp.float32)  # (block_d,)
    idx = idx_ref[0]  # (k,)
    val = val_ref[0].astype(jnp.float32)  # (k,)
    lo = j * block_d
    # ragged last block: out-of-range pad columns read garbage/NaN -> zero
    col = lo + jax.lax.iota(jnp.int32, block_d)
    psi = jnp.where(col < d, psi, 0.0)
    local = idx - lo
    in_blk = (local >= 0) & (local < block_d)
    # one-hot (k, block_d) match -> gather as a matvec on the MXU
    onehot = (
        local[:, None]
        == jax.lax.broadcasted_iota(jnp.int32, (1, block_d), 1)
    ) & in_blk[:, None]
    gathered = (onehot.astype(jnp.float32) @ psi[:, None])[:, 0]  # (k,)
    partial = jnp.sum(val * gathered)

    @pl.when(j == 0)
    def _init():
        out_ref[0] = jnp.zeros_like(out_ref[0])

    out_ref[0] += partial.astype(out_ref.dtype)


def sparse_dot(
    psi: jax.Array,  # (N, D)
    idx: jax.Array,  # (N, k) int32
    val: jax.Array,  # (N, k)
    *,
    block_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Per-node sparse dot products: out[n] = sum_k val[n,k] * psi[n, idx[n,k]]."""
    N, D = psi.shape
    k = idx.shape[1]
    block_d = min(block_d, D)
    grid = (N, pl.cdiv(D, block_d))
    kernel = functools.partial(_dot_kernel, block_d=block_d, d=D)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_d), lambda n, j: (n, j)),
            pl.BlockSpec((1, k), lambda n, j: (n, 0)),
            pl.BlockSpec((1, k), lambda n, j: (n, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda n, j: (n,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.float32),
        interpret=interpret,
    )(psi, idx.astype(jnp.int32), val)


def _axpy_kernel(psi_ref, idx_ref, val_ref, coef_ref, rho_ref, out_ref, *,
                 block_d: int):
    """out_block = rho * psi_block + coef * scatter(val at idx) in-block."""
    j = pl.program_id(1)
    psi = psi_ref[0].astype(jnp.float32)
    idx = idx_ref[0]
    val = val_ref[0].astype(jnp.float32)
    coef = coef_ref[0].astype(jnp.float32)
    rho = rho_ref[0].astype(jnp.float32)
    lo = j * block_d
    local = idx - lo
    in_blk = (local >= 0) & (local < block_d)
    onehot = (
        local[:, None]
        == jax.lax.broadcasted_iota(jnp.int32, (1, block_d), 1)
    ) & in_blk[:, None]
    scat = (val[None, :] @ onehot.astype(jnp.float32))[0]  # (block_d,)
    out_ref[0] = (rho * psi + coef * scat).astype(out_ref.dtype)


def sparse_axpy(
    psi: jax.Array,  # (N, D)
    idx: jax.Array,  # (N, k)
    val: jax.Array,  # (N, k)
    coef: jax.Array,  # (N,)   e.g. -a_eff * g_n
    rho: jax.Array,  # (N,)   e.g. 1/(1+alpha lam)
    *,
    block_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """out[n] = rho[n] * psi[n] + coef[n] * x_n (sparse row scatter)."""
    N, D = psi.shape
    k = idx.shape[1]
    block_d = min(block_d, D)
    grid = (N, pl.cdiv(D, block_d))
    kernel = functools.partial(_axpy_kernel, block_d=block_d)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_d), lambda n, j: (n, j)),
            pl.BlockSpec((1, k), lambda n, j: (n, 0)),
            pl.BlockSpec((1, k), lambda n, j: (n, 0)),
            pl.BlockSpec((1,), lambda n, j: (n,)),
            pl.BlockSpec((1,), lambda n, j: (n,)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda n, j: (n, j)),
        out_shape=jax.ShapeDtypeStruct((N, D), psi.dtype),
        interpret=interpret,
    )(psi, idx.astype(jnp.int32), val, coef, rho)

from repro.train.step import (  # noqa: F401
    TrainConfig,
    ce_loss,
    make_train_state_defs,
    train_step,
)

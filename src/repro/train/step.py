"""Training step: CE loss, grad-accumulation microbatching, AdamW, sharding.

The single-pod step is a plain pjit program: FSDP (params/opt-state over
'data') x TP (heads/mlp/experts/vocab over 'model'), batch over 'data'.
The multi-pod decentralized step lives in core/gossip.py and reuses
`local_grads` / `apply_updates` from here.

The backward pass of `local_grads` is where the kernel registry's
custom_vjp backends pay off: with `ModelConfig.attention_kernel` /
`ssm_kernel` set to a use_pallas mode, jax.grad routes attention and SSD
gradients through the blocked Pallas backward kernels (kernels/ops.py) —
the model's most memory-hungry cotangents never materialize an S x S
intermediate. Nothing in this module changes per mode; routing is entirely
config-driven.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.params import tree_pspecs, tree_sds
from repro.optim.adam import AdamConfig, adam_init, adam_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamConfig = AdamConfig()
    microbatches: int = 1  # gradient accumulation steps per train_step
    batch_axes: tuple[str, ...] = ("data",)  # ('pod','data') for sync multipod


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def ce_loss(logits: jax.Array, targets: jax.Array, mask=None) -> jax.Array:
    """Token-mean cross-entropy in fp32. logits (B,S,V), targets (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(cfg: ModelConfig, params, batch) -> jax.Array:
    logits = T.forward(
        cfg, params, batch["tokens"], enc_embeds=batch.get("enc_embeds")
    )
    return ce_loss(logits, batch["targets"], batch.get("mask"))


def local_grads(cfg: ModelConfig, tc: TrainConfig, params, batch):
    """(loss, grads) with optional microbatch accumulation via lax.scan."""
    if tc.microbatches <= 1:
        return jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)

    mb = tc.microbatches
    split = jax.tree_util.tree_map(
        lambda x: x.reshape(mb, x.shape[0] // mb, *x.shape[1:]), batch
    )

    def body(acc, mbatch):
        l, g = jax.value_and_grad(lambda p: loss_fn(cfg, p, mbatch))(params)
        acc_l, acc_g = acc
        return (acc_l + l / mb,
                jax.tree_util.tree_map(lambda a, b: a + b / mb, acc_g, g)), None

    zero_g = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    (loss, grads), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zero_g),
                                    split)
    return loss, grads


# ---------------------------------------------------------------------------
# state defs + step
# ---------------------------------------------------------------------------

def make_train_state_defs(cfg: ModelConfig, tc: TrainConfig):
    """(sds_tree, pspec_tree) for {'params', 'opt', 'step'} — dry-run ready."""
    defs = T.model_defs(cfg)
    p_sds = tree_sds(defs, cfg.param_dtype)
    p_spec = tree_pspecs(defs)
    st_dt = tc.optimizer.state_dtype
    o_sds = {"mu": tree_sds(defs, st_dt)}
    o_spec = {"mu": p_spec}
    if tc.optimizer.kind != "sgdm":
        o_sds["nu"] = tree_sds(defs, st_dt)
        o_spec["nu"] = p_spec
    sds = {"params": p_sds, "opt": o_sds,
           "step": jax.ShapeDtypeStruct((), jnp.int32)}
    spec = {"params": p_spec, "opt": o_spec, "step": P()}
    return sds, spec


def init_train_state(cfg: ModelConfig, tc: TrainConfig, key):
    from repro.models.params import tree_materialize

    defs = T.model_defs(cfg)
    params = tree_materialize(defs, key, cfg.param_dtype)
    return {
        "params": params,
        "opt": adam_init(tc.optimizer, params),
        "step": jnp.zeros((), jnp.int32),
    }


def train_step(cfg: ModelConfig, tc: TrainConfig, state, batch):
    """One optimizer step. Returns (new_state, metrics)."""
    loss, grads = local_grads(cfg, tc, state["params"], batch)
    params, opt, metrics = adam_update(
        tc.optimizer, state["params"], grads, state["opt"], state["step"]
    )
    metrics["loss"] = loss
    new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
    return new_state, metrics


def batch_specs(cfg: ModelConfig, tc: TrainConfig) -> dict:
    b = P(tc.batch_axes)
    spec = {"tokens": b, "targets": b}
    if cfg.family == "encdec":
        spec["enc_embeds"] = P(tc.batch_axes, None, None)
    return spec


def batch_sds(cfg: ModelConfig, batch: int, seq: int) -> dict:
    out = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "targets": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.family == "encdec":
        out["enc_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_len, cfg.d_model), cfg.compute_dtype
        )
    return out


def make_jitted_train_step(mesh, cfg: ModelConfig, tc: TrainConfig):
    """jit with explicit in/out shardings on `mesh` (lower()-ready)."""
    _, spec = make_train_state_defs(cfg, tc)
    st_sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), spec)
    b_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), batch_specs(cfg, tc)
    )
    return jax.jit(
        lambda state, batch: train_step(cfg, tc, state, batch),
        in_shardings=(st_sh, b_sh),
        out_shardings=(st_sh, None),
        donate_argnums=(0,),
    )

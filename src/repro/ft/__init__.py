"""Fault tolerance: elastic membership, heartbeats, and fault plans.

Re-exports are lazy so that ``repro.ft.faults`` (plain numpy fault-plan
schemas used by ``core.solvers``) can be imported without pulling in the
elastic/gossip training stack.
"""
from __future__ import annotations

_ELASTIC = ("ElasticGossip", "HeartbeatMonitor", "BoundedStalenessBuffer")
_FAULTS = (
    "ChurnEvent",
    "ChurnPlan",
    "FaultPlan",
    "LinkFault",
    "StragglerSpec",
    "as_fault_plan",
)

__all__ = list(_ELASTIC + _FAULTS)


def __getattr__(name: str):
    """Resolve re-exports on first access (PEP 562)."""
    if name in _ELASTIC:
        from repro.ft import elastic

        return getattr(elastic, name)
    if name in _FAULTS:
        from repro.ft import faults

        return getattr(faults, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

from repro.ft.elastic import ElasticGossip, HeartbeatMonitor  # noqa: F401

"""Unified fault-injection plans for the solver stack (``solve()``).

One ``FaultPlan`` composes the three fault families the robustness suite
injects into a run, each validated up front and capability-typed per
(method, comm backend) exactly like PR 8's dynamic-network axes:

* **node churn** — the existing kill/join machinery (``ChurnPlan`` /
  ``ChurnEvent`` live here now; ``core.solvers`` re-exports them), now
  legal under ``comm="sparse"`` too (per-membership-segment relay
  protocol re-derivation);
* **link faults** (``LinkFault``) — per-directed-edge message drops,
  probabilistic (drop probability ``p`` per edge per iteration) or
  scheduled (explicit ``edges`` at explicit iterations ``at``), applied
  inside the dense/sharded mixing matvec as a masked mixing row with
  row-renormalization (dropped neighbor mass redirects to self, so the
  effective matrix stays row-stochastic for stochastic ``W``), and
  inside the sparse relay as a suppressed broadcast (the receiver's
  reconstruction wave sees a zero delta — a conservative model of a
  root-hop drop);
* **stragglers** (``StragglerSpec``) — delayed delivery: a straggling
  sender's neighbors keep using its *last delivered* value, never more
  than ``max_staleness`` iterations old (the ``ft.elastic``
  ``BoundedStalenessBuffer`` semantics wired into the traced step as a
  last-delivered-value buffer; delivery is forced when the bound is
  reached).

The plan is resolved to plain numpy masks host-side
(``link_delivered_mask`` / ``straggler_delivered_mask``) — the traced
runners consume the masks as scan inputs, so one compiled runner serves
every drop rate. The delivered-message accounting
(``delivered_in_messages``) counts only messages that actually arrived;
``solve()`` reports injected-vs-delivered totals in
``SolveResult.extras["faults"]``.

This module imports only numpy + ``core.mixing`` so constructing and
validating plans never pulls in the training stack (``ft/__init__``
re-exports lazily for the same reason).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.mixing import Graph


# ---------------------------------------------------------------------------
# Node churn (moved verbatim from core.solvers; re-exported there)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class ChurnEvent:
    """One membership change at iteration ``at`` (after ``at`` steps ran).

    kind="kill": ``nodes`` (in the membership numbering CURRENT at ``at``)
    leave; survivors keep going on ``graph`` (default: the induced
    subgraph, which must be connected) with mixing ``w`` (default: the
    paper's Laplacian weights). kind="join": ``n_new`` nodes join,
    seeded — state rows AND data shard — from node ``seed_from``
    (matching ``ElasticGossip.grow``); ``graph`` over the grown
    membership is required (the old graph says nothing about the
    newcomers' wiring).
    """

    at: int
    kind: str  # "kill" | "join"
    nodes: tuple[int, ...] = ()
    n_new: int = 0
    seed_from: int = 0
    graph: Graph | None = None
    w: np.ndarray | None = None

    def __post_init__(self):
        """Validate the event's own fields (graph-vs-membership at use)."""
        if self.kind not in ("kill", "join"):
            raise ValueError(f"churn event kind {self.kind!r} is not kill|join")
        object.__setattr__(self, "nodes", tuple(int(x) for x in self.nodes))
        if self.kind == "kill" and not self.nodes:
            raise ValueError("kill event needs at least one node")
        if self.kind == "join":
            if self.n_new < 1:
                raise ValueError("join event needs n_new >= 1")
            if self.graph is None:
                raise ValueError(
                    "join event requires a graph over the grown membership"
                )


@dataclasses.dataclass(frozen=True, eq=False)
class ChurnPlan:
    """An ordered fault-injection plan: strictly increasing event times.

    Passed to ``solve()`` as ``comm_options={"fault_plan": plan}`` (all
    three backends; methods advertising ``supports_churn``). Tests
    use it to kill/join nodes deterministically and assert re-convergence
    on the survivor system.
    """

    events: tuple[ChurnEvent, ...]

    def __post_init__(self):
        """Normalize to a tuple and check event times are increasing."""
        object.__setattr__(self, "events", tuple(self.events))
        ats = [e.at for e in self.events]
        if any(b <= a for a, b in zip(ats, ats[1:])):
            raise ValueError(f"churn event times must strictly increase: {ats}")
        if not self.events:
            raise ValueError("ChurnPlan needs at least one event")


# ---------------------------------------------------------------------------
# Link faults and stragglers
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class LinkFault:
    """Per-directed-edge message drops, probabilistic and/or scheduled.

    ``p``: per-iteration drop probability of each directed graph edge,
    drawn independently per (iteration, edge) from ``seed`` (host-side;
    the draw also folds in the churn-phase start so re-derived masks stay
    deterministic across membership segments). ``edges`` + ``at``:
    deterministic drops — every listed directed ``(src, dst)`` pair
    (default: ALL directed edges) is dropped at each iteration in ``at``.
    Both mechanisms compose by OR. On the sparse relay a drop suppresses
    the source's whole broadcast for that iteration (see module docs).
    """

    p: float = 0.0
    seed: int = 0
    edges: tuple[tuple[int, int], ...] | None = None
    at: tuple[int, ...] | None = None

    def __post_init__(self):
        """Validate probability range and normalize the schedule tuples."""
        if not 0.0 <= float(self.p) <= 1.0:
            raise ValueError(f"link drop probability p={self.p} not in [0, 1]")
        if self.edges is not None:
            object.__setattr__(
                self,
                "edges",
                tuple((int(a), int(b)) for a, b in self.edges),
            )
        if self.at is not None:
            ats = tuple(int(t) for t in self.at)
            if any(t < 0 for t in ats):
                raise ValueError(f"scheduled drop iterations must be >= 0: {ats}")
            object.__setattr__(self, "at", ats)
        if self.edges is not None and self.at is None:
            raise ValueError("LinkFault.edges without .at has no effect; set at=")


@dataclasses.dataclass(frozen=True, eq=False)
class StragglerSpec:
    """Delayed delivery: senders whose messages arrive late, bounded.

    Each iteration, each straggling node fails to deliver a fresh value
    with probability ``p`` (drawn from ``seed``); its neighbors keep
    using the last value it delivered. Delivery is FORCED once the
    buffered value is ``max_staleness`` iterations old — the bound of
    ``ft.elastic.BoundedStalenessBuffer``, here resolved host-side into
    a delivery mask the traced step consumes. ``nodes`` restricts
    straggling to a subset (default: every node can straggle).
    """

    p: float = 0.0
    max_staleness: int = 2
    nodes: tuple[int, ...] | None = None
    seed: int = 0

    def __post_init__(self):
        """Validate probability and bound; normalize the node subset."""
        if not 0.0 <= float(self.p) <= 1.0:
            raise ValueError(f"straggler probability p={self.p} not in [0, 1]")
        if int(self.max_staleness) < 1:
            raise ValueError(
                f"max_staleness must be >= 1, got {self.max_staleness}"
            )
        if self.nodes is not None:
            object.__setattr__(
                self, "nodes", tuple(int(x) for x in self.nodes)
            )


@dataclasses.dataclass(frozen=True, eq=False)
class FaultPlan:
    """The composed fault-injection plan ``solve()`` accepts.

    Any subset of the three families may be present (at least one must
    be). Passed as ``comm_options={"fault_plan": plan}``; a bare
    ``ChurnPlan`` / ``ChurnEvent`` / list of events is still accepted
    everywhere a plan is (``as_fault_plan`` normalizes).
    """

    churn: ChurnPlan | None = None
    link: LinkFault | None = None
    straggler: StragglerSpec | None = None

    def __post_init__(self):
        """Normalize the churn member and require at least one family."""
        churn = self.churn
        if isinstance(churn, ChurnEvent):
            churn = ChurnPlan((churn,))
        elif isinstance(churn, (list, tuple)):
            churn = ChurnPlan(tuple(churn))
        if churn is not None and not isinstance(churn, ChurnPlan):
            raise TypeError(
                f"FaultPlan.churn must be a ChurnPlan/ChurnEvent(s), got "
                f"{type(self.churn).__name__}"
            )
        object.__setattr__(self, "churn", churn)
        if self.link is not None and not isinstance(self.link, LinkFault):
            raise TypeError(
                f"FaultPlan.link must be a LinkFault, got "
                f"{type(self.link).__name__}"
            )
        if self.straggler is not None and not isinstance(
            self.straggler, StragglerSpec
        ):
            raise TypeError(
                f"FaultPlan.straggler must be a StragglerSpec, got "
                f"{type(self.straggler).__name__}"
            )
        if self.churn is None and self.link is None and self.straggler is None:
            raise ValueError("FaultPlan needs at least one fault family")


def as_fault_plan(obj) -> FaultPlan | None:
    """Normalize ``comm_options["fault_plan"]`` to a ``FaultPlan`` (or None).

    Accepts the PR 8 shapes unchanged: a bare ``ChurnPlan``, a single
    ``ChurnEvent``, or a list/tuple of events all become churn-only
    plans.
    """
    if obj is None or isinstance(obj, FaultPlan):
        return obj
    if isinstance(obj, (ChurnPlan, ChurnEvent, list, tuple)):
        return FaultPlan(churn=obj)
    raise TypeError(
        f"fault_plan must be a FaultPlan / ChurnPlan / ChurnEvent(s), got "
        f"{type(obj).__name__}"
    )


# ---------------------------------------------------------------------------
# Host-side mask resolution (the traced runners consume these as scan xs)
# ---------------------------------------------------------------------------


def _directed_adjacency(graph: Graph) -> np.ndarray:
    """(N, N) bool: ``adj[u, m]`` — ``u`` receives from neighbor ``m``."""
    adj = np.zeros((graph.n, graph.n), dtype=bool)
    for i, j in graph.edges:
        adj[i, j] = adj[j, i] = True
    return adj


def link_delivered_mask(
    link: LinkFault | None, graph: Graph, steps: int, start: int = 0
) -> np.ndarray:
    """(steps, N, N) bool delivery mask: ``mask[t, u, m]`` = message
    ``m -> u`` at global iteration ``start + t`` arrives.

    Non-edges and the diagonal are always True (they carry no message;
    keeping them True makes the masked-matvec renormalization a no-op
    there). ``start`` offsets both the probabilistic draw (folded into
    the rng seed, so each churn phase re-derives deterministically) and
    the scheduled ``at`` times (which are global iteration numbers).
    """
    n = graph.n
    adj = _directed_adjacency(graph)
    mask = np.ones((steps, n, n), dtype=bool)
    if link is None:
        return mask
    if link.p > 0.0:
        rng = np.random.default_rng([int(link.seed), 0x11F, int(start)])
        drop = rng.random((steps, n, n)) < float(link.p)
        mask &= ~(drop & adj[None])
    if link.at is not None:
        if link.edges is None:
            sched = adj
        else:
            sched = np.zeros((n, n), dtype=bool)
            for src, dst in link.edges:
                if not (0 <= src < n and 0 <= dst < n):
                    raise ValueError(
                        f"scheduled drop edge ({src}, {dst}) outside the "
                        f"current membership 0..{n - 1}"
                    )
                if not adj[dst, src]:
                    raise ValueError(
                        f"scheduled drop edge ({src}, {dst}) is not an edge "
                        "of the communication graph"
                    )
                sched[dst, src] = True
        for t in link.at:
            tt = t - start
            if 0 <= tt < steps:
                mask[tt] &= ~sched
    return mask


def straggler_delivered_mask(
    strag: StragglerSpec | None, n: int, steps: int, start: int = 0
) -> np.ndarray:
    """(steps, N) bool delivery mask with the staleness bound applied.

    ``out[t, m]`` — node ``m`` delivers a FRESH value at global iteration
    ``start + t``. The host replay enforces the bound: after
    ``max_staleness`` consecutive non-deliveries, delivery is forced, so
    the value a receiver uses is never more than ``max_staleness``
    iterations old. Ages start at the bound, so the first iteration of a
    run (or churn phase) always delivers — receivers never read an
    uninitialized buffer.
    """
    out = np.ones((steps, n), dtype=bool)
    if strag is None or strag.p <= 0.0:
        return out
    rng = np.random.default_rng([int(strag.seed), 0x57A, int(start)])
    late = rng.random((steps, n)) < float(strag.p)
    if strag.nodes is not None:
        allowed = np.zeros(n, dtype=bool)
        for x in strag.nodes:
            if not 0 <= x < n:
                raise ValueError(
                    f"straggler node {x} outside the membership 0..{n - 1}"
                )
            allowed[x] = True
        late &= allowed[None]
    bound = int(strag.max_staleness)
    age = np.full(n, bound, dtype=np.int64)
    for t in range(steps):
        deliver = (~late[t]) | (age >= bound)
        out[t] = deliver
        age = np.where(deliver, 0, age + 1)
    return out


def source_sent_mask(
    link: LinkFault | None, graph: Graph, steps: int, start: int = 0
) -> np.ndarray:
    """(steps, N) bool: the sparse relay's per-source broadcast mask.

    The relay forwards one compressed delta per source per iteration
    along broadcast trees; a per-edge drop model does not map onto the
    shared reconstruction ring, so on the sparse backend a link fault
    suppresses the source's WHOLE broadcast for that iteration — the
    conservative root-hop-drop reading. ``p`` becomes the per-broadcast
    suppression probability; a scheduled ``(src, dst)`` drop suppresses
    ``src``'s broadcast at the scheduled iterations. Deterministic in
    ``(seed, start)`` like the dense masks.
    """
    n = graph.n
    sent = np.ones((steps, n), dtype=bool)
    if link is None:
        return sent
    if link.p > 0.0:
        rng = np.random.default_rng([int(link.seed), 0x5B, int(start)])
        sent &= ~(rng.random((steps, n)) < float(link.p))
    if link.at is not None:
        if link.edges is None:
            srcs = list(range(n))
        else:
            srcs = sorted({int(src) for src, _ in link.edges})
            for s in srcs:
                if not 0 <= s < n:
                    raise ValueError(
                        f"scheduled drop source {s} outside the membership "
                        f"0..{n - 1}"
                    )
        for t in link.at:
            tt = t - start
            if 0 <= tt < steps:
                sent[tt, srcs] = False
    return sent


# ---------------------------------------------------------------------------
# Delivered-message accounting (host-side, from the resolved masks)
# ---------------------------------------------------------------------------


def delivered_in_messages(
    graph: Graph,
    link_mask: np.ndarray | None,
    deliver_mask: np.ndarray | None,
    steps: int,
) -> np.ndarray:
    """(steps, N) int: neighbor messages node ``u`` receives per iteration.

    A message ``m -> u`` at iteration ``t`` arrives iff the link is up
    (``link_mask[t, u, m]``) AND the sender delivered fresh that
    iteration (``deliver_mask[t, m]`` — a straggling sender sends
    nothing; its forced catch-up delivery counts as one message). With
    no faults this is ``deg(u)`` every iteration — exactly the dense
    accounting model.
    """
    adj = _directed_adjacency(graph)
    up = np.broadcast_to(adj[None], (steps,) + adj.shape).copy()
    if link_mask is not None:
        up &= link_mask[:steps]
    if deliver_mask is not None:
        up &= deliver_mask[:steps, None, :]
    return up.sum(axis=2).astype(np.int64)


def fault_message_totals(
    graph: Graph,
    link_mask: np.ndarray | None,
    deliver_mask: np.ndarray | None,
    steps: int,
) -> dict:
    """The ``SolveResult.extras["faults"]`` record for one phase.

    ``injected_messages`` counts every neighbor exchange the no-fault
    protocol would have performed over ``steps`` iterations (one message
    per directed edge per round); ``delivered_messages`` counts only the
    ones that arrived under the masks. Per-iteration granularity — the
    caller scales by the method's rounds-per-iteration hook.
    """
    deg = np.asarray(graph.degrees, dtype=np.int64)
    d_in = delivered_in_messages(graph, link_mask, deliver_mask, steps)
    injected = int(steps * deg.sum())
    delivered = int(d_in.sum())
    return {
        "injected_messages": injected,
        "delivered_messages": delivered,
        "drop_rate": (
            0.0 if injected == 0 else 1.0 - delivered / injected
        ),
    }

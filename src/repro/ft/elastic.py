"""Fault tolerance for decentralized pod-level training.

A core practical advantage of the paper's setting: decentralized methods
have NO global barrier, so pod failure degrades locally instead of stalling
the fleet. This module provides the control-plane pieces (simulated
single-process, as the compute plane is):

  HeartbeatMonitor  failure detector: pods report heartbeats; a pod missing
                    `timeout` ticks is declared dead.
  ElasticGossip     elastic membership: on pod death/join, rebuild the
                    mixing graph over the survivors and remap the gossip
                    state (drop or seed the pod-replica rows). DSBA then
                    simply continues on the new W — no global re-init.
                    Straggler mitigation: bounded staleness — a late
                    neighbor's contribution reuses its last delivered
                    value for up to `max_staleness` rounds (Wu et al. 2016
                    asynchrony, which the paper builds on).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gossip import GossipConfig


class HeartbeatMonitor:
    def __init__(self, n_pods: int, timeout: int = 3):
        self.timeout = timeout
        self.last_seen = {p: 0 for p in range(n_pods)}
        self.tick_now = 0
        self.declared_dead: set[int] = set()

    def heartbeat(self, pod: int):
        self.last_seen[pod] = self.tick_now
        self.declared_dead.discard(pod)  # a live heartbeat resurrects

    def tick(self) -> list[int]:
        """Advance time; returns pods declared DEAD *this* tick.

        Each death is reported exactly once: a pod stays in `last_seen`
        (so a late heartbeat can resurrect it) but moves into
        `declared_dead` so subsequent ticks stop re-reporting it.
        """
        self.tick_now += 1
        dead = [
            p for p, t in self.last_seen.items()
            if self.tick_now - t >= self.timeout
            and p not in self.declared_dead
        ]
        self.declared_dead.update(dead)
        return dead

    def remove(self, pod: int):
        """Stop monitoring ``pod``. Raises KeyError if the pod is not
        monitored — a silent no-op here would mask a supervisor
        double-shrink (the same dead pod removed twice)."""
        if pod not in self.last_seen:
            raise KeyError(
                f"pod {pod} is not monitored; known: {sorted(self.last_seen)}"
            )
        del self.last_seen[pod]
        self.declared_dead.discard(pod)

    def add(self, pod: int):
        """Start monitoring ``pod`` as of the current tick. Raises
        ValueError if the pod is already monitored — resetting a live
        pod's deadline implicitly would hide a join/id collision; call
        ``heartbeat(pod)`` to refresh or ``remove(pod)`` first."""
        if pod in self.last_seen:
            raise ValueError(
                f"pod {pod} is already monitored; heartbeat() refreshes "
                "it, remove() + add() re-registers it"
            )
        self.last_seen[pod] = self.tick_now
        self.declared_dead.discard(pod)


@dataclasses.dataclass
class ElasticGossip:
    """Membership + state remapping for the pod axis."""

    gc: GossipConfig

    def shrink(self, state: dict, dead: list[int]) -> tuple[dict, GossipConfig]:
        """Drop dead pods' replica rows; rebuild mixing over survivors."""
        n = self.gc.n_pods
        keep = np.asarray([p for p in range(n) if p not in dead])
        new_gc = dataclasses.replace(self.gc, n_pods=len(keep))

        def slice_pod(x):
            if hasattr(x, "shape") and x.ndim >= 1 and x.shape[0] == n:
                return jnp.asarray(np.asarray(x)[keep])
            return x

        new_state = jax.tree_util.tree_map(slice_pod, state)
        return new_state, new_gc

    def grow(self, state: dict, n_new: int, seed_from: int = 0
             ) -> tuple[dict, GossipConfig]:
        """Join pods: seed new replicas from pod `seed_from` (consensus warm
        start); DSBA's mixing pulls them into agreement."""
        n = self.gc.n_pods
        new_gc = dataclasses.replace(self.gc, n_pods=n + n_new)

        def pad_pod(x):
            if hasattr(x, "shape") and x.ndim >= 1 and x.shape[0] == n:
                seed_rows = jnp.broadcast_to(
                    x[seed_from][None], (n_new, *x.shape[1:])
                )
                return jnp.concatenate([x, seed_rows], axis=0)
            return x

        return jax.tree_util.tree_map(pad_pod, state), new_gc


@dataclasses.dataclass
class BoundedStalenessBuffer:
    """Straggler mitigation: per-neighbor last-delivered values with ages.

    get(neighbor) returns the freshest delivered value if it is at most
    `max_staleness` rounds old; otherwise signals the caller to drop the
    neighbor's term this round (weights renormalized by the caller).
    """

    max_staleness: int

    def __post_init__(self):
        self._buf: dict[int, tuple[int, object]] = {}
        self._round = 0

    def deliver(self, neighbor: int, value):
        self._buf[neighbor] = (self._round, value)

    def advance(self):
        self._round += 1

    def get(self, neighbor: int):
        if neighbor not in self._buf:
            return None
        t, v = self._buf[neighbor]
        if self._round - t > self.max_staleness:
            return None
        return v

"""Sharded checkpointing with atomic commit and async write.

Layout (tensorstore-free, per-host):

  <dir>/step_<N>.tmp/           staged writes
  <dir>/step_<N>/               committed (atomic rename)
      manifest.json             tree structure + shapes/dtypes + metadata
      arr_<i>.npy               one file per leaf (host-local shard in a
                                multi-host deployment; full array here)

Fault-tolerance contract:
  * a crash mid-write leaves only a .tmp dir -> ignored on restore
  * restore picks the newest COMMITTED step
  * saves can run on a background thread (async=True) so the train loop
    overlaps the host write with the next steps
  * keep_last prunes old steps after commit

Works for any pytree of arrays (train state, gossip state incl. per-pod
replicas, paper-core DSBA state).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import shutil
import threading

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class CheckpointSpec:
    """How ``solve(..., checkpoint=...)`` snapshots a run.

    ``directory``: where the ``step_<N>`` checkpoint dirs go.
    ``every``: checkpoint period in solver ITERATIONS; on the dense
    backend it must be a multiple of ``record_every`` (snapshots happen
    at record boundaries, where the chunked scan already pauses).
    ``keep_last``: how many committed checkpoints to retain.

    ``solve(..., resume=directory)`` restores the newest committed
    checkpoint and continues BIT-EQUAL to an uninterrupted run: solver
    state, recorder contents, and the sample-stream position all resume
    exactly (the per-node index streams are prefix-stable in ``steps``
    by construction — ``draw_indices`` fills row-major).
    """

    directory: str | pathlib.Path
    every: int
    keep_last: int = 3

    def __post_init__(self):
        """Validate the checkpoint period."""
        if int(self.every) < 1:
            raise ValueError(f"checkpoint every={self.every} must be >= 1")


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(directory, step: int, tree, *, keep_last: int = 3,
                    metadata: dict | None = None) -> pathlib.Path:
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f"step_{step}.tmp"
    final = directory / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    paths, leaves, _ = _flatten_with_paths(tree)
    manifest = {
        "step": step,
        "metadata": metadata or {},
        "leaves": [],
    }
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(leaf)
        np.save(tmp / f"arr_{i}.npy", arr)
        manifest["leaves"].append(
            {"path": p, "file": f"arr_{i}.npy", "shape": list(arr.shape),
             "dtype": str(arr.dtype)}
        )
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit

    # prune
    steps = sorted(committed_steps(directory))
    for s in steps[:-keep_last] if keep_last else []:
        shutil.rmtree(directory / f"step_{s}", ignore_errors=True)
    return final


def committed_steps(directory) -> list[int]:
    directory = pathlib.Path(directory)
    out = []
    if not directory.exists():
        return out
    for p in directory.iterdir():
        if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
    return sorted(out)


def restore_checkpoint(directory, tree_like, step: int | None = None):
    """Restore into the structure of `tree_like`. Returns (tree, step) or
    (None, None) when no committed checkpoint exists."""
    directory = pathlib.Path(directory)
    steps = committed_steps(directory)
    if not steps:
        return None, None
    step = steps[-1] if step is None else step
    d = directory / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())

    paths, leaves, treedef = _flatten_with_paths(tree_like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    if set(paths) != set(by_path):
        missing = set(paths) ^ set(by_path)
        raise ValueError(f"checkpoint tree mismatch; differing paths: {missing}")
    new_leaves = []
    for p, like in zip(paths, leaves):
        e = by_path[p]
        arr = np.load(d / e["file"])
        if tuple(arr.shape) != tuple(np.shape(like)):
            raise ValueError(f"shape mismatch at {p}: {arr.shape} vs {np.shape(like)}")
        new_leaves.append(jax.numpy.asarray(
            arr, dtype=like.dtype if hasattr(like, "dtype") else None
        ))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step


def load_checkpoint(directory, step: int | None = None):
    """Load a committed checkpoint WITHOUT a template tree.

    Returns ``(step, metadata, {path: np.ndarray})`` for the newest (or
    requested) committed step, or ``(None, None, None)`` when the
    directory holds no committed checkpoint. The loose counterpart of
    ``restore_checkpoint`` for callers whose tree structure depends on
    run-length state (``solve()``'s recorder arrays grow with the number
    of record points, so a strict structural restore cannot be templated
    before reading the checkpoint).
    """
    directory = pathlib.Path(directory)
    steps = committed_steps(directory)
    if not steps:
        return None, None, None
    step = steps[-1] if step is None else step
    if step not in steps:
        raise ValueError(
            f"no committed checkpoint for step {step} in {directory}; "
            f"committed: {steps}"
        )
    d = directory / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves = {
        e["path"]: np.load(d / e["file"]) for e in manifest["leaves"]
    }
    return step, manifest.get("metadata", {}), leaves


class CheckpointManager:
    """Async checkpointing: save() stages a host copy and writes on a
    background thread; wait() joins before exit/next save."""

    def __init__(self, directory, keep_last: int = 3):
        self.directory = pathlib.Path(directory)
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, tree, *, metadata=None, async_: bool = True):
        self.wait()
        # device->host copy happens here, synchronously (cheap vs the write)
        host_tree = jax.tree_util.tree_map(np.asarray, tree)
        if not async_:
            save_checkpoint(self.directory, step, host_tree,
                            keep_last=self.keep_last, metadata=metadata)
            return

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree,
                                keep_last=self.keep_last, metadata=metadata)
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore(self, tree_like, step=None):
        self.wait()
        return restore_checkpoint(self.directory, tree_like, step)

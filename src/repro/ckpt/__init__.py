from repro.ckpt.checkpoint import (  # noqa: F401
    CheckpointManager,
    CheckpointSpec,
    committed_steps,
    load_checkpoint,
    restore_checkpoint,
    save_checkpoint,
)

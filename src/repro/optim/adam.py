"""AdamW (+ SGD-momentum) with configurable state dtype.

State is a pytree mirroring params (ZeRO-3: states inherit the parameters'
shardings, so FSDP over 'data' automatically shards optimizer state).
Global-norm clipping and decoupled weight decay included; learning-rate
schedule is a plain callable step -> lr.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32  # bf16 for the 405B/1T configs
    kind: str = "adamw"  # adamw | sgdm
    warmup_steps: int = 100

    def lr_at(self, step):
        warm = jnp.minimum(1.0, (step + 1) / max(1, self.warmup_steps))
        return self.lr * warm


def adam_init(cfg: AdamConfig, params):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    if cfg.kind == "sgdm":
        return {"mu": jax.tree_util.tree_map(zeros, params)}
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))


def adam_update(cfg: AdamConfig, params, grads, opt_state, step):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12))
    lr = cfg.lr_at(step)

    def upd(p, g, mu, nu=None):
        g = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        if cfg.kind == "sgdm":
            delta = mu32
        else:
            nu32 = nu.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g * g
            mu_hat = mu32 / (1 - cfg.b1 ** (step + 1))
            nu_hat = nu32 / (1 - cfg.b2 ** (step + 1))
            delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (delta + cfg.weight_decay * p32)
        out = [p_new.astype(p.dtype), mu32.astype(mu.dtype)]
        if cfg.kind != "sgdm":
            out.append(nu32.astype(nu.dtype))
        return tuple(out)

    if cfg.kind == "sgdm":
        pairs = jax.tree_util.tree_map(upd, params, grads, opt_state["mu"])
        is_pair = lambda x: isinstance(x, tuple)
        new_p = jax.tree_util.tree_map(lambda t: t[0], pairs, is_leaf=is_pair)
        new_mu = jax.tree_util.tree_map(lambda t: t[1], pairs, is_leaf=is_pair)
        return new_p, {"mu": new_mu}, {"grad_norm": gnorm, "lr": lr}

    triples = jax.tree_util.tree_map(
        upd, params, grads, opt_state["mu"], opt_state["nu"]
    )
    is_t = lambda x: isinstance(x, tuple)
    new_p = jax.tree_util.tree_map(lambda t: t[0], triples, is_leaf=is_t)
    new_mu = jax.tree_util.tree_map(lambda t: t[1], triples, is_leaf=is_t)
    new_nu = jax.tree_util.tree_map(lambda t: t[2], triples, is_leaf=is_t)
    return new_p, {"mu": new_mu, "nu": new_nu}, {"grad_norm": gnorm, "lr": lr}

"""The paper's own experiment configurations (Section 7).

N=10 nodes, Erdos-Renyi(0.4) topology, Laplacian-based constant edge weight
mixing, lambda = 1/(10 Q), rows normalized to ||a|| = 1. Dataset presets
mirror News20/RCV1/Sector statistics (synthetic — see data/synthetic.py).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperExperiment:
    task: str  # ridge | logistic | auc
    dataset: str  # preset name in data/synthetic.DATASET_PRESETS
    n_nodes: int = 10
    q: int = 100
    er_p: float = 0.4
    alpha: float = 0.5
    seed: int = 0


EXPERIMENTS = {
    "ridge_rcv1": PaperExperiment("ridge", "rcv1", alpha=0.5),
    "ridge_sector": PaperExperiment("ridge", "sector", alpha=0.5),
    "logistic_rcv1": PaperExperiment("logistic", "rcv1", alpha=4.0),
    "logistic_news20": PaperExperiment("logistic", "news20", alpha=4.0),
    "auc_rcv1": PaperExperiment("auc", "rcv1", alpha=1.0),
    "auc_sector": PaperExperiment("auc", "sector", alpha=1.0),
    # small variants for quick runs / CI
    "ridge_small": PaperExperiment("ridge", "small", q=50, alpha=0.5),
    "logistic_small": PaperExperiment("logistic", "small", q=50, alpha=4.0),
    "auc_small": PaperExperiment("auc", "small", q=50, alpha=1.0),
}

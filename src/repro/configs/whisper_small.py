"""whisper-small [audio]: enc-dec, conv frontend STUB [arXiv:2212.04356; unverified].

12+12L d_model=768 12H (MHA kv=12) d_ff=3072 vocab=51865. The audio conv
frontend is stubbed per the assignment: input_specs() provides precomputed
(batch, 1500, d_model) frame embeddings.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    n_encoder_layers=12,
    encoder_len=1500,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51_865,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, n_encoder_layers=2, encoder_len=32, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
        remat="none",
    )

"""Assigned-architecture registry: --arch <id> selects one of these.

Each module exposes CONFIG (the exact assigned configuration) and reduced()
(a small same-family config for CPU smoke tests). dsba_paper.py carries the
paper's own convex-experiment configurations.
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "minitron_8b",
    "gemma2_2b",
    "qwen2_72b",
    "llama3_405b",
    "zamba2_1p2b",
    "whisper_small",
    "kimi_k2",
    "qwen2_moe",
    "chameleon_34b",
    "mamba2_1p3b",
]

# external ids (with dashes/dots) -> module names
ALIASES = {
    "minitron-8b": "minitron_8b",
    "gemma2-2b": "gemma2_2b",
    "qwen2-72b": "qwen2_72b",
    "llama3-405b": "llama3_405b",
    "zamba2-1.2b": "zamba2_1p2b",
    "whisper-small": "whisper_small",
    "kimi-k2-1t-a32b": "kimi_k2",
    "qwen2-moe-a2.7b": "qwen2_moe",
    "chameleon-34b": "chameleon_34b",
    "mamba2-1.3b": "mamba2_1p3b",
}


def get_config(arch: str):
    mod = ALIASES.get(arch, arch).replace("-", "_")
    return importlib.import_module(f"repro.configs.{mod}").CONFIG


def get_reduced(arch: str):
    mod = ALIASES.get(arch, arch).replace("-", "_")
    return importlib.import_module(f"repro.configs.{mod}").reduced()


def list_archs() -> list[str]:
    return list(ARCH_IDS)

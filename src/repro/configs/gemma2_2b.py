"""gemma2-2b [dense]: local+global alternating, logit softcaps [arXiv:2408.00118; hf].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000, window=4096,
attn softcap 50, final softcap 30, head_dim 256, tied embeddings.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    sliding_window=4096,
    local_global=True,
    attn_softcap=50.0,
    final_softcap=30.0,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, sliding_window=8, remat="none",
    )

"""mamba2-1.3b [ssm]: SSD, attention-free [arXiv:2405.21060; unverified].

48L d_model=2048 ssm_state=128 vocab=50280, head_dim 64, expand 2.
Sub-quadratic: runs the long_500k shape (O(1)-state decode).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_head_dim=64,
    supports_long_context=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, vocab_size=256, ssm_state=16,
        ssm_head_dim=16, remat="none",
    )

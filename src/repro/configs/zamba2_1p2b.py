"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention [arXiv:2411.15242; hf].

38 Mamba2 layers d_model=2048 ssm_state=64; one SHARED attention block
(32H MHA kv=32, d_ff=8192) applied every 6 layers; vocab 32000.
Sub-quadratic: runs the long_500k shape.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32_000,
    ssm_state=64,
    ssm_head_dim=64,
    hybrid_period=6,
    supports_long_context=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, ssm_state=16, ssm_head_dim=16,
        hybrid_period=2, remat="none",
    )

"""chameleon-34b [vlm]: early-fusion, VQ image tokens [arXiv:2405.09818; unverified].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 (unified text+image
token vocabulary). The VQ image tokenizer is a STUB per the assignment:
input_specs() provides fused token ids over the unified vocab.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="dense",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65_536,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, remat="none",
    )

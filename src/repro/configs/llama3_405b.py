"""llama3-405b [dense]: GQA, 128k vocab [arXiv:2407.21783; unverified].

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.

param_dtype is bf16 here: at 405B params, fp32 master + fp32 Adam states do
not fit 256 x 16 GB v5e HBM; bf16 params + fp32 Adam m/v (10 bytes/param
sharded ZeRO-3) do. See EXPERIMENTS.md §Dry-run for the measured bytes.
"""
import dataclasses

import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128_256,
    rope_theta=500_000.0,
    param_dtype=jnp.bfloat16,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, remat="none", param_dtype=jnp.float32,
    )

"""kimi-k2-1t-a32b [moe]: trillion-param MoE [arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048, 384 experts top-8
(+1 shared expert), vocab=163840.

bf16 params: 1T fp32 masters cannot fit the single-pod mesh.
"""
import dataclasses

import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,  # all-MoE FFNs
    vocab_size=163_840,
    n_experts=384,
    experts_per_token=8,
    moe_d_ff=2048,
    shared_expert_d_ff=2048,
    param_dtype=jnp.bfloat16,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        vocab_size=256, n_experts=8, experts_per_token=2, moe_d_ff=32,
        shared_expert_d_ff=32, remat="none", param_dtype=jnp.float32,
        capacity_factor=8.0,  # dropless at test scale: decode == forward
    )

"""qwen2-moe-a2.7b [moe]: 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

24L d_model=2048 16H (MHA kv=16) expert d_ff=1408, 60 experts top-4,
shared expert d_ff 5632 (= 4 x 1408), vocab=151936.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=0,
    vocab_size=151_936,
    n_experts=60,
    experts_per_token=4,
    moe_d_ff=1408,
    shared_expert_d_ff=5632,
    qkv_bias=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        vocab_size=256, n_experts=8, experts_per_token=2, moe_d_ff=32,
        shared_expert_d_ff=64, remat="none",
        capacity_factor=8.0,  # dropless at test scale: decode == forward
    )

"""Serving subsystem: paged KV cache + continuous-batching scheduler.

Three pieces (docs/serving.md):

  cache.py      CachePool — a preallocated KV page pool shared by every
                sequence, per-slot block tables, host-side page/slot
                accounting, and slot adapters for the SSM / conv / whisper
                cross caches.
  engine.py     generate() — the shared contiguous-cache prefill+decode
                loop behind launch/serve.py and examples/serve_decode.py
                (one jitted decode_step, not two).
  scheduler.py  Scheduler — continuous batching at a fixed max-batch
                shape: admit between decode steps, evict finished,
                preempt on pool OOM; per-step ServeStats counters.
"""
from repro.serve.cache import CachePool, PoolConfig
from repro.serve.engine import GenResult, generate
from repro.serve.scheduler import Request, Scheduler, ServeStats, StepStats

__all__ = [
    "CachePool", "PoolConfig", "GenResult", "generate",
    "Request", "Scheduler", "ServeStats", "StepStats",
]

"""Continuous-batching scheduler over the paged cache pool.

Between decode steps the scheduler admits queued requests into free
slots (prefill at a fixed ``(1, prompt_pad)`` shape), evicts finished
sequences, and — when the page pool runs dry mid-decode — preempts the
youngest active sequence back to the queue.  Decode always runs at the
fixed ``(max_batch, 1)`` shape with padding lanes masked by length 0
and null block tables, so the warm runner NEVER recompiles: every jit
in the loop is shape-stable and trace-counted (``trace_counts``).

Admission policy (documented in docs/serving.md): FIFO, admit while a
free slot exists and the pool can cover the prompt; a request larger
than ``prompt_pad`` is rejected at submit.  Preemption restarts the
victim from scratch — generated tokens are discarded, the original
request returns to the FRONT of the queue (it was admitted first).  A
request preempted ``max_preempts`` times is exempt from further
preemption (oldest-first fallback among exempt slots) so no request
thrashes forever.

Per-step counters (queue depth, active slots, pool occupancy,
admissions/evictions/preemptions, tokens generated) accumulate in a
``ServeStats`` record for benchmarks and tests.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque

import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serve.cache import CachePool, PoolConfig, TracedJit


@dataclasses.dataclass
class Request:
    """One generation request.

    enc_embeds (encoder_len, d_model) is required for the encdec
    family (whisper) and ignored otherwise.
    """

    rid: int
    tokens: np.ndarray  # (prompt_len,) int token ids
    max_new_tokens: int
    enc_embeds: np.ndarray | None = None


@dataclasses.dataclass
class StepStats:
    """Counters for one scheduler step (recorded after admission)."""

    step: int
    queue_depth: int
    active_slots: int
    pool_occupancy: float
    admitted: int
    finished: int
    preempted: int
    tokens_generated: int


@dataclasses.dataclass
class ServeStats:
    """Per-step counter trace for a scheduler run.

    ``preempt_counts`` maps request id -> how many times that request was
    preempted over the run (the starvation-guard witness: no entry may
    exceed ``Scheduler.max_preempts`` unless the oldest-first fallback had
    no non-exempt victim left).
    """

    steps: list[StepStats] = dataclasses.field(default_factory=list)
    preempt_counts: dict[int, int] = dataclasses.field(default_factory=dict)

    @property
    def total_tokens(self) -> int:
        return sum(s.tokens_generated for s in self.steps)

    @property
    def peak_active(self) -> int:
        return max((s.active_slots for s in self.steps), default=0)

    @property
    def peak_occupancy(self) -> float:
        return max((s.pool_occupancy for s in self.steps), default=0.0)

    @property
    def preemptions(self) -> int:
        return sum(s.preempted for s in self.steps)


@dataclasses.dataclass
class _Active:
    req: Request
    generated: list[int]
    target: int  # total tokens to generate (capped by pool max_len)


class Scheduler:
    """Continuous batching: fixed-shape decode, dynamic membership."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        pool_cfg: PoolConfig,
        *,
        temperature: float = 0.0,
        seed: int = 0,
        max_preempts: int = 3,
    ):
        self.cfg = cfg
        self.params = params
        self.pool = CachePool(cfg, pool_cfg)
        self.temperature = temperature
        self.max_preempts = max_preempts
        self._rng = np.random.default_rng(seed)
        self.queue: deque[Request] = deque()
        self.active: dict[int, _Active] = {}
        self._admit_order: list[int] = []  # slots, oldest admission first
        self._cur_tok = np.zeros((pool_cfg.max_batch, 1), np.int32)
        self.results: dict[int, np.ndarray] = {}
        self.stats = ServeStats()
        self._step_idx = 0
        self._prefill = TracedJit(functools.partial(T.prefill, cfg))
        self._decode = TracedJit(functools.partial(T.decode_step_paged, cfg))
        self._encode = TracedJit(
            lambda p, e: T.encode_cross_cache(cfg, p, e, 1)
        )

    @property
    def trace_counts(self) -> dict[str, int]:
        """Jit trace counts — the zero-recompile-after-warmup witness."""
        return {
            "prefill": self._prefill.traces,
            "decode": self._decode.traces,
            "encode": self._encode.traces,
            "pool": self.pool.trace_count,
        }

    # -- request intake -----------------------------------------------------

    def submit(self, req: Request) -> None:
        plen = len(req.tokens)
        pc = self.pool.pc
        if not 1 <= plen <= pc.prompt_pad:
            raise ValueError(
                f"prompt length {plen} not in [1, prompt_pad={pc.prompt_pad}]"
            )
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.cfg.family == "encdec" and req.enc_embeds is None:
            raise ValueError("encdec requests need enc_embeds")
        self.queue.append(req)

    # -- sampling -----------------------------------------------------------

    def _sample(self, logits_row: np.ndarray) -> int:
        if self.temperature > 0:
            g = self._rng.gumbel(size=logits_row.shape)
            return int(np.argmax(logits_row / self.temperature + g))
        return int(np.argmax(logits_row))

    # -- admission ----------------------------------------------------------

    def _finish(self, slot: int) -> None:
        st = self.active.pop(slot)
        self._admit_order.remove(slot)
        self.results[st.req.rid] = np.asarray(st.generated, np.int32)
        self.pool.release(slot)

    def _admit_one(self) -> bool:
        req = self.queue[0]
        plen = len(req.tokens)
        slot = self.pool.alloc_slot()
        if slot is None:
            return False
        if not self.pool.ensure(slot, plen):
            self.pool.release(slot)  # returns the empty slot
            return False
        self.queue.popleft()
        pc = self.pool.pc

        padded = np.zeros((1, pc.prompt_pad), np.int64)
        padded[0, :plen] = np.asarray(req.tokens)
        cache = T.init_cache(self.cfg, 1, pc.prompt_pad)
        if self.cfg.family == "encdec":
            cache["cross"] = self._encode(
                self.params, jnp.asarray(req.enc_embeds)[None]
            )
        cache, logits = self._prefill(
            self.params, jnp.asarray(padded), cache,
            valid_len=jnp.asarray([plen], jnp.int32),
        )
        self.pool.write_prefill(slot, cache)
        self.pool.set_length(slot, plen)

        # the prefill logits already yield the first generated token: a
        # decode step per NEW token, not per request token
        g0 = self._sample(np.asarray(logits)[0])
        target = min(req.max_new_tokens, pc.max_len - plen + 1)
        st = _Active(req, [g0], target)
        if target <= 1:
            self.results[req.rid] = np.asarray(st.generated, np.int32)
            self.pool.release(slot)
            return True
        self.active[slot] = st
        self._admit_order.append(slot)
        self._cur_tok[slot, 0] = g0
        return True

    def _admit(self) -> int:
        admitted = 0
        while self.queue and self._admit_one():
            admitted += 1
        return admitted

    # -- preemption ---------------------------------------------------------

    def _preempt_youngest(self, protect: int) -> bool:
        """Evict an active slot (except `protect`) back to the queue
        front, discarding its progress.

        Starvation guard: plain youngest-first can thrash a request
        forever at high load (admit -> immediately re-preempt, every
        step). A request preempted ``max_preempts`` times becomes EXEMPT:
        the victim search is youngest-first over non-exempt slots, and
        only when every candidate is exempt does it fall back to the
        OLDEST candidate (which has been resident longest, so evicting
        it lets the exempt cohort drain before it thrashes anew)."""
        candidates = [s for s in self._admit_order if s != protect]
        victim = next(
            (s for s in reversed(candidates)
             if self.stats.preempt_counts.get(self.active[s].req.rid, 0)
             < self.max_preempts),
            candidates[0] if candidates else None,
        )
        if victim is None:
            return False
        st = self.active.pop(victim)
        self._admit_order.remove(victim)
        self.pool.release(victim)
        self._cur_tok[victim, 0] = 0
        self.queue.appendleft(st.req)
        rid = st.req.rid
        self.stats.preempt_counts[rid] = (
            self.stats.preempt_counts.get(rid, 0) + 1
        )
        return True

    def _ensure_capacity(self) -> int:
        """Every active slot gets a page for this step's K/V write —
        preempting youngest-first when the pool runs dry."""
        preempted = 0
        for slot in list(self._admit_order):
            if slot not in self.active:
                continue
            need = int(self.pool.lengths[slot]) + 1
            while not self.pool.ensure(slot, need):
                if not self._preempt_youngest(protect=slot):
                    raise RuntimeError(
                        "page pool too small for a single sequence: "
                        f"slot {slot} needs {need} tokens, "
                        f"{self.pool.free_page_count} pages free"
                    )
                preempted += 1
        return preempted

    # -- the step -----------------------------------------------------------

    def step(self) -> StepStats:
        """Admit, ensure capacity (preempting if needed), decode one
        token for every active slot, evict finished sequences."""
        admitted = self._admit()
        preempted = self._ensure_capacity()
        finished = 0
        tokens_generated = 0

        if self.active:
            pools, logits = self._decode(
                self.params,
                jnp.asarray(self._cur_tok),
                self.pool.pools,
                self.pool.device_table(),
                self.pool.device_lengths(),
            )
            self.pool.pools = pools
            logits_np = np.asarray(logits)
            slots = list(self._admit_order)
            self.pool.bump_lengths(slots)
            for slot in slots:
                st = self.active[slot]
                nxt = self._sample(logits_np[slot])
                st.generated.append(nxt)
                self._cur_tok[slot, 0] = nxt
                tokens_generated += 1
                if len(st.generated) >= st.target:
                    self._finish(slot)
                    finished += 1

        stats = StepStats(
            step=self._step_idx,
            queue_depth=len(self.queue),
            active_slots=len(self.active),
            pool_occupancy=self.pool.occupancy(),
            admitted=admitted,
            finished=finished,
            preempted=preempted,
            tokens_generated=tokens_generated,
        )
        self.stats.steps.append(stats)
        self._step_idx += 1
        return stats

    def run(
        self,
        requests: list[Request] | None = None,
        *,
        max_steps: int | None = None,
    ) -> tuple[dict[int, np.ndarray], ServeStats]:
        """Drain the queue: step until every request completes.

        Returns ({rid: generated token ids}, per-step ServeStats).
        """
        for req in requests or ():
            self.submit(req)
        limit = max_steps if max_steps is not None else 100_000
        steps = 0
        while (self.queue or self.active) and steps < limit:
            self.step()
            steps += 1
        if self.queue or self.active:
            raise RuntimeError(f"scheduler did not drain in {limit} steps")
        return self.results, self.stats

"""Paged KV cache: a shared page pool with per-slot block tables.

Device memory for attention K/V is one preallocated pool of
``(n_blocks, block_size, KV, Dh)`` pages per layer (see
``transformer.paged_cache_defs``).  A sequence occupies a *slot*
(0..max_batch) and references pages through a host-side
``(max_batch, n_pages)`` block table — pool memory scales with live
tokens across all sequences, not ``max_batch * max_len``.

Page 0 is the reserved **null page**: it is never handed out, inactive
slots point every table entry at it, and prefill scatters pad blocks
into it.  Reads through the null page are masked out by the decode
kernel (length 0 ⇒ fully masked), so padding lanes stay harmless at a
fixed compiled shape.

State that is length-independent — SSM recurrent state, conv history,
whisper cross K/V — does not need paging; it lives in per-slot arrays
indexed by slot id.  ``write_prefill`` hides the difference: it takes
a contiguous batch-1 prefill cache (from ``transformer.prefill``) and
lands it in the pool, whatever the family.

All device writes go through ``TracedJit`` wrappers so the scheduler
can assert zero recompiles after warmup.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig


class TracedJit:
    """jax.jit wrapper that counts traces.

    The counter increments inside the traced function — a Python side
    effect that only fires at trace time — so ``traces`` is exactly the
    number of compilations this instance has triggered.
    """

    def __init__(self, fn, **jit_kwargs):
        self.traces = 0

        def counted(*args, **kwargs):
            self.traces += 1
            return fn(*args, **kwargs)

        self._fn = jax.jit(counted, **jit_kwargs)

    def __call__(self, *args, **kwargs):
        return self._fn(*args, **kwargs)


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    """Sizing for a CachePool.

    max_batch   scheduler slots (fixed decode batch shape)
    block_size  tokens per KV page
    n_blocks    total pages in the pool, INCLUDING the reserved null
                page 0 (so n_blocks - 1 are allocatable)
    max_len     per-sequence token capacity (prompt + generated)
    prompt_pad  fixed padded prompt length for prefill; must be a
                multiple of block_size so prompt K/V tiles onto pages
    """

    max_batch: int = 8
    block_size: int = 16
    n_blocks: int = 64
    max_len: int = 128
    prompt_pad: int = 32

    def __post_init__(self):
        if self.prompt_pad % self.block_size != 0:
            raise ValueError("prompt_pad must be a multiple of block_size")
        if self.max_len < self.prompt_pad:
            raise ValueError("max_len must cover prompt_pad")
        if self.n_blocks < 2:
            raise ValueError("need at least the null page + one real page")

    @property
    def n_pages(self) -> int:
        """Block-table width: pages needed to cover max_len tokens."""
        return -(-self.max_len // self.block_size)


def _scatter_blocks(pool, vals, page_ids):
    """Write a contiguous (n, P, KV, Dh) K/V slab into pool pages.

    page_ids has P // block_size entries; entries equal to 0 dump their
    (pad) block into the null page.  Duplicate indices only ever occur
    at page 0, where the result is garbage either way.
    """
    n, P = vals.shape[0], vals.shape[1]
    bs = pool.shape[2]
    blocks = vals.reshape(n, P // bs, bs, *vals.shape[2:])
    return pool.at[:, page_ids].set(blocks.astype(pool.dtype))


def _set_slot(arr, val, slot):
    """Write a batch-1 per-slot state (n, 1, ...) into row `slot`."""
    return arr.at[:, slot].set(val[:, 0].astype(arr.dtype))


class CachePool:
    """Page pool + block tables + slot accounting for one served model.

    Host side: free-page and free-slot lists, the block table, and
    per-slot lengths (all numpy).  Device side: the pool arrays from
    ``paged_cache_defs`` (mutated functionally each step — the
    scheduler reassigns ``self.pools``).

    Typical life of a sequence:
        slot = pool.alloc_slot()
        pool.ensure(slot, prompt_len)        # pages for the prompt
        pool.write_prefill(slot, cache)      # land prefill K/V + state
        pool.set_length(slot, prompt_len)
        ... per decode step: pool.ensure(slot, length + 1) ...
        pool.release(slot)                   # pages back to the free list
    """

    def __init__(self, cfg: ModelConfig, pc: PoolConfig):
        self.cfg = cfg
        self.pc = pc
        self.n_pages = pc.n_pages
        self.pools = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            T.paged_cache_defs(
                cfg, pc.max_batch, pc.n_blocks, pc.block_size, self.n_pages
            ),
        )
        # attention-free families (ssm) never touch the page pool; the
        # null table still feeds decode_step_paged's (ignored) args
        self.paged = cfg.family in ("dense", "moe", "hybrid", "encdec")
        self.table = np.zeros((pc.max_batch, self.n_pages), np.int32)
        self.lengths = np.zeros((pc.max_batch,), np.int32)
        self._pages_of: list[list[int]] = [[] for _ in range(pc.max_batch)]
        self._free_pages = list(range(pc.n_blocks - 1, 0, -1))  # 0 = null
        self._free_slots = list(range(pc.max_batch - 1, -1, -1))
        self._dirty = True
        self._table_dev = None
        self._lengths_dev = None
        self._scatter = TracedJit(_scatter_blocks)
        self._set_slot = TracedJit(_set_slot)

    # -- accounting ---------------------------------------------------------

    @property
    def free_page_count(self) -> int:
        return len(self._free_pages)

    @property
    def used_page_count(self) -> int:
        return (self.pc.n_blocks - 1) - len(self._free_pages)

    @property
    def free_slot_count(self) -> int:
        return len(self._free_slots)

    @property
    def active_slots(self) -> list[int]:
        free = set(self._free_slots)
        return [s for s in range(self.pc.max_batch) if s not in free]

    def occupancy(self) -> float:
        """Fraction of allocatable pages currently held by slots."""
        denom = self.pc.n_blocks - 1
        return self.used_page_count / denom if denom else 0.0

    @property
    def trace_count(self) -> int:
        return self._scatter.traces + self._set_slot.traces

    def pages_needed(self, n_tokens: int) -> int:
        if not self.paged:
            return 0
        return -(-n_tokens // self.pc.block_size)

    # -- slot / page lifecycle ----------------------------------------------

    def alloc_slot(self) -> int | None:
        """Claim a free scheduler slot (or None if the batch is full)."""
        if not self._free_slots:
            return None
        return self._free_slots.pop()

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow slot's page allocation to cover n_tokens; False on OOM.

        On failure nothing changes — the caller preempts a victim and
        retries, or gives up.
        """
        if n_tokens > self.pc.max_len:
            raise ValueError(
                f"n_tokens={n_tokens} exceeds max_len={self.pc.max_len}"
            )
        need = self.pages_needed(n_tokens) - len(self._pages_of[slot])
        if need <= 0:
            return True
        if need > len(self._free_pages):
            return False
        for _ in range(need):
            page = self._free_pages.pop()
            self.table[slot, len(self._pages_of[slot])] = page
            self._pages_of[slot].append(page)
        self._dirty = True
        return True

    def release(self, slot: int) -> None:
        """Return slot's pages to the free list and reset its table row.

        Per-slot state (ssm/conv/cross) is NOT zeroed — the next
        write_prefill into this slot overwrites it entirely.
        """
        self._free_pages.extend(reversed(self._pages_of[slot]))
        self._pages_of[slot] = []
        self.table[slot, :] = 0
        self.lengths[slot] = 0
        self._free_slots.append(slot)
        self._dirty = True

    def set_length(self, slot: int, n_tokens: int) -> None:
        self.lengths[slot] = n_tokens
        self._dirty = True

    def bump_lengths(self, slots: list[int]) -> None:
        """Advance lengths after a decode step appended one token/slot."""
        for s in slots:
            self.lengths[s] += 1
        self._dirty = True

    # -- device views -------------------------------------------------------

    def device_table(self) -> jax.Array:
        self._refresh()
        return self._table_dev

    def device_lengths(self) -> jax.Array:
        self._refresh()
        return self._lengths_dev

    def _refresh(self) -> None:
        if self._dirty or self._table_dev is None:
            self._table_dev = jnp.asarray(self.table)
            self._lengths_dev = jnp.asarray(self.lengths)
            self._dirty = False

    # -- landing prefill results --------------------------------------------

    def _prompt_page_ids(self, slot: int) -> jax.Array:
        """Page ids for the prompt_pad // block_size prefill blocks.

        Blocks past the slot's allocation (prompt padding) target the
        null page; their garbage K/V is never read back.
        """
        n_prompt = self.pc.prompt_pad // self.pc.block_size
        ids = np.zeros((n_prompt,), np.int32)
        own = self._pages_of[slot][:n_prompt]
        ids[: len(own)] = own
        return jnp.asarray(ids)

    def write_prefill(self, slot: int, cache: dict) -> None:
        """Land a batch-1 contiguous prefill cache into the pool.

        `cache` comes from ``transformer.prefill`` run at shape
        (1, prompt_pad).  Attention K/V slabs are scattered onto this
        slot's pages; slot-indexed state (ssm/conv/cross) is written at
        row `slot`.  Call ``set_length`` afterwards with the TRUE
        prompt length (pad blocks land in the null page and pad
        positions within the last valid block are masked by length).
        """
        fam = self.cfg.family
        slot_dev = jnp.int32(slot)
        if fam in ("dense", "moe"):
            ids = self._prompt_page_ids(slot)
            self.pools = {
                "k": self._scatter(self.pools["k"], cache["k"][:, 0], ids),
                "v": self._scatter(self.pools["v"], cache["v"][:, 0], ids),
            }
        elif fam == "ssm":
            self.pools = {
                k: self._set_slot(self.pools[k], cache[k], slot_dev)
                for k in ("state", "conv")
            }
        elif fam == "hybrid":
            ids = self._prompt_page_ids(slot)
            self.pools = {
                "ssm": {
                    k: self._set_slot(
                        self.pools["ssm"][k], cache["ssm"][k], slot_dev
                    )
                    for k in ("state", "conv")
                },
                "attn": {
                    k: self._scatter(
                        self.pools["attn"][k], cache["attn"][k][:, 0], ids
                    )
                    for k in ("k", "v")
                },
            }
        elif fam == "encdec":
            ids = self._prompt_page_ids(slot)
            self.pools = {
                "self": {
                    k: self._scatter(
                        self.pools["self"][k], cache["self"][k][:, 0], ids
                    )
                    for k in ("k", "v")
                },
                "cross": {
                    k: self._set_slot(
                        self.pools["cross"][k], cache["cross"][k], slot_dev
                    )
                    for k in ("k", "v")
                },
            }
        else:
            raise ValueError(fam)

    # -- debugging / parity helpers -----------------------------------------

    def gather_kv(self, slot: int, n_tokens: int) -> dict | None:
        """Read back slot's K/V as contiguous (n, n_tokens, KV, Dh) numpy
        arrays (dense/moe only) — parity-test convenience, host-side."""
        if self.cfg.family not in ("dense", "moe"):
            return None
        k = np.asarray(self.pools["k"])
        v = np.asarray(self.pools["v"])
        pages = self._pages_of[slot]
        bs = self.pc.block_size
        out = {}
        for name, pool in (("k", k), ("v", v)):
            slab = pool[:, pages]  # (n, P, bs, KV, Dh)
            n = slab.shape[0]
            slab = slab.reshape(n, len(pages) * bs, *slab.shape[3:])
            out[name] = slab[:, :n_tokens]
        return out

"""Shared generation loop: one jitted decode_step behind every driver.

``launch/serve.py`` and ``examples/serve_decode.py`` used to hand-roll
identical prefill/decode jits and a python token loop — including
jitting the SAME ``decode_step`` signature twice.  ``generate()`` is
that loop, once: a single jitted step per ModelConfig (prefill and
decode differ only in the token-axis shape, so they are two traces of
one callable, not two callables), greedy or temperature sampling, and
wall-clock accounting.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig


@functools.lru_cache(maxsize=None)
def _jitted_step(cfg: ModelConfig):
    """One jitted decode_step per (hashable, frozen) config.

    Prefill reuses this callable at (B, prompt_len); decode at (B, 1).
    Different shapes mean separate traces but a shared cache — no
    double-jit of the same signature.
    """
    return jax.jit(functools.partial(T.decode_step, cfg))


@dataclasses.dataclass
class GenResult:
    """Tokens plus timing from one generate() call."""

    tokens: np.ndarray  # (B, max_new_tokens) int32
    prefill_s: float
    decode_s: float
    prompt_tokens: int
    new_tokens: int

    @property
    def prefill_tok_s(self) -> float:
        return self.prompt_tokens / self.prefill_s if self.prefill_s else 0.0

    @property
    def decode_tok_s(self) -> float:
        return self.new_tokens / self.decode_s if self.decode_s else 0.0


def generate(
    cfg: ModelConfig,
    params: dict,
    prompts: jax.Array,  # (B, prompt_len) int token ids
    *,
    max_new_tokens: int,
    temperature: float = 0.0,
    seed: int = 1,
    enc_embeds: jax.Array | None = None,
) -> GenResult:
    """Prefill the prompts, then decode max_new_tokens greedily (or with
    temperature sampling).  Contiguous per-request caches — the simple
    batch path; the scheduler owns the paged continuous-batching path."""
    B, P = prompts.shape
    cache = T.init_cache(cfg, B, P + max_new_tokens)
    if cfg.family == "encdec":
        if enc_embeds is None:
            raise ValueError("encdec family needs enc_embeds")
        cache["cross"] = T.encode_cross_cache(cfg, params, enc_embeds, B)
    step = _jitted_step(cfg)
    key = jax.random.PRNGKey(seed)

    t0 = time.time()
    cache, logits = step(params, prompts, cache)
    jax.block_until_ready(logits)
    prefill_s = time.time() - t0

    def sample(logits, key):
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / temperature)[:, None]
        else:
            tok = jnp.argmax(logits, -1)[:, None]
        return tok.astype(jnp.int32), key

    out = []
    tok, key = sample(logits, key)
    t0 = time.time()
    for _ in range(max_new_tokens):
        out.append(tok)
        cache, logits = step(params, tok, cache)
        tok, key = sample(logits, key)
    jax.block_until_ready(tok)
    decode_s = time.time() - t0

    return GenResult(
        tokens=np.asarray(jnp.concatenate(out, axis=1)),
        prefill_s=prefill_s,
        decode_s=decode_s,
        prompt_tokens=B * P,
        new_tokens=B * max_new_tokens,
    )

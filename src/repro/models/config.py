"""Model configuration covering all assigned architecture families.

One frozen dataclass drives dense / MoE / SSM / hybrid / enc-dec / VLM
backbones. Family semantics:

  dense    decoder-only transformer (minitron, qwen2, llama3, chameleon,
           gemma2 via local/global options)
  moe      dense attention + routed-expert FFN (kimi-k2, qwen2-moe)
  ssm      pure Mamba2/SSD stack, attention-free (mamba2)
  hybrid   Mamba2 backbone + shared attention block every `hybrid_period`
           layers (zamba2)
  encdec   encoder-decoder with stubbed modality frontend (whisper)

Modality frontends ([audio]/[vlm]) are STUBS per the assignment: input_specs
provide precomputed frame embeddings (whisper) or fused token ids over the
unified vocab (chameleon).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any

import jax.numpy as jnp


def _kernel_default() -> str:
    """Default use_pallas mode for the kernel-routing knobs.

    'auto' (Pallas on TPU, jnp oracle on CPU) unless the REPRO_KERNEL_MODE
    env var overrides it — the escape hatch back to 'jnp' (the inline
    einsum paths) or to a forced mode, without touching configs.
    """
    return os.environ.get("REPRO_KERNEL_MODE", "auto")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention options ---------------------------------------------
    qkv_bias: bool = False  # qwen2
    rope_theta: float = 10_000.0
    sliding_window: int | None = None  # gemma2 local layers
    local_global: bool = False  # gemma2: alternate local/global layers
    attn_softcap: float | None = None  # gemma2: 50.0
    final_softcap: float | None = None  # gemma2: 30.0

    # --- MoE options ------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    shared_expert_d_ff: int = 0  # 0 = no shared expert
    capacity_factor: float = 1.25
    # 0 = scatter/gather dispatch (simple; XLA reshards it badly at scale).
    # >0 = GShard-style grouped one-hot EINSUM dispatch with this many token
    # groups (set = data-shard count): dispatch becomes (G,Tg,E,C) one-hot
    # contractions that are data/model-local by construction — trades
    # ~2x MoE flops for eliminating the dispatch collectives (§Perf).
    moe_groups: int = 0

    # --- SSM (Mamba2/SSD) options ------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # >0: scan SSD within-chunk compute over head blocks of this size,
    # keeping the (Q x Q) decay tile per-block instead of materializing the
    # full (B, nc, Q, Q, nh) tensor — the jnp twin of the Pallas kernel's
    # grid blocking (§Perf lever for SSM training memory).
    ssm_head_block: int = 0

    # --- hybrid (zamba2) ----------------------------------------------------
    hybrid_period: int = 6  # shared attention block every k ssm layers

    # --- enc-dec (whisper) ----------------------------------------------------
    n_encoder_layers: int = 0
    encoder_len: int = 1500  # post-conv audio frames (frontend stubbed)

    # --- numerics / misc -----------------------------------------------------
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    # remat policy for the scanned blocks: 'none'|'full'|'dots_saveable'
    remat: str = "full"
    # perf options (EXPERIMENTS.md §Perf; defaults = naive baseline)
    blockwise_attention: bool = False  # online-softmax, no S x S buffer
    attention_block_k: int = 1024
    # route full-sequence self-attention through the kernels/ops.py backend
    # registry: 'jnp' = the sharded einsum path in models/layers.py,
    # otherwise a use_pallas mode ('auto'|'on'|'interpret'|'off') handed to
    # ops.flash_attention (custom_vjp Pallas kernel on TPU, jnp oracle on
    # CPU under 'auto' — the default; REPRO_KERNEL_MODE env var overrides).
    # Decode/cross paths stay on 'jnp'. The kernel is a custom_vjp, so
    # training gradients route through the blocked Pallas backward under
    # the same mode.
    attention_kernel: str = dataclasses.field(default_factory=_kernel_default)
    # route the SSD within-chunk compute (train/prefill) through the
    # registry's ssd_chunk custom_vjp kernel: 'jnp' = the inline einsum
    # path in models/ssm.py, otherwise a use_pallas mode (default 'auto';
    # REPRO_KERNEL_MODE overrides). The O(1) recurrent decode step stays
    # on 'jnp' (no chunk structure).
    ssm_kernel: str = dataclasses.field(default_factory=_kernel_default)
    # route paged-cache serving decode (src/repro/serve/) through the
    # registry's decode_attention kernel: a use_pallas mode (default
    # 'auto'; REPRO_KERNEL_MODE overrides). 'jnp' degrades to 'off' (the
    # jnp-gather oracle) — unlike train/prefill there is no separate
    # inline path, the oracle IS the reference implementation.
    decode_kernel: str = dataclasses.field(default_factory=_kernel_default)
    # shard attention compute by Q heads (n_heads) instead of KV heads:
    # GQA models with kv_heads < mesh 'model' size otherwise replicate the
    # whole attention computation across the model axis. Expands K/V per
    # group (the expansion is itself sharded, so per-device KV bytes are
    # unchanged) and removes the n_heads/kv_heads-fold compute redundancy.
    shard_q_heads: bool = False
    # shard the residual stream's d_model axis over 'model' (sequence-
    # parallel style): divides the per-layer saved activations (the remat
    # boundary carries) by the model-axis size, at the cost of per-layer
    # all-gathers. The lever for 100B+ training memory.
    shard_residual_embed: bool = False

    # --- shape-grid participation -------------------------------------------
    supports_long_context: bool = False  # run long_500k only if sub-quadratic
    has_decoder: bool = True  # decode shapes apply

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND model-flops accounting)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        dense_mlp = 3 * d * ff
        moe_mlp = self.n_experts * 3 * d * self.moe_d_ff + (
            3 * d * self.shared_expert_d_ff if self.shared_expert_d_ff else 0
        ) + d * self.n_experts  # router
        di, st, hd = self.ssm_d_inner, self.ssm_state, self.ssm_head_dim
        nh = self.ssm_heads if self.ssm_d_inner else 0
        ssm_blk = (
            d * (2 * di + 2 * st + nh)  # in_proj -> z, x, B, C, dt
            + (di + 2 * st) * self.ssm_conv_width  # conv
            + nh * 2  # A_log, D
            + di * d  # out_proj
        )
        per = {
            "dense": attn + dense_mlp,
            "moe": attn + moe_mlp,
            "ssm": ssm_blk,
            "hybrid": ssm_blk,  # + shared attn block counted once below
            "encdec": attn + dense_mlp,
        }[self.family]
        total = emb + self.n_layers * per
        if self.family == "hybrid":
            total += attn + dense_mlp  # one shared attention+mlp block
        if self.family == "encdec":
            # encoder layers + decoder cross-attention
            total += self.n_encoder_layers * (attn + dense_mlp)
            total += self.n_layers * attn  # cross-attn per decoder layer
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        act_mlp = self.experts_per_token * 3 * d * self.moe_d_ff + (
            3 * d * self.shared_expert_d_ff if self.shared_expert_d_ff else 0
        ) + d * self.n_experts
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return int(emb + self.n_layers * (attn + act_mlp))

"""Model assembly for every assigned architecture family.

Families:
  dense / moe / ssm : homogeneous stacks -> jax.lax.scan over stacked layer
                      params (compile-time O(1) in depth; required for the
                      126-layer / 1T-param dry-runs). gemma2's alternating
                      local/global attention scans over layer PAIRS — the
                      stacked params reshape (n, ...) -> (n//2, 2, ...) and
                      the body applies a local then a global block, so both
                      window sizes are STATIC and kernel-eligible.
  hybrid (zamba2)   : python-unrolled Mamba2 stack with a SHARED attention
                      block (one set of weights, applied every
                      cfg.hybrid_period layers).
  encdec (whisper)  : bidirectional encoder over stubbed frame embeddings +
                      causal decoder with cross-attention.

Public API:
  model_defs(cfg)                      -> ParamDef tree
  forward(cfg, params, batch)          -> logits            (train / scoring)
  cache_defs(cfg, batch, max_len)      -> decode-cache ShapeDtypeStructs
  prefill(cfg, params, tok, cache)     -> (cache, logits at valid_len - 1)
  decode_step(cfg, params, tok, cache) -> (cache, logits)

Serving API (the paged-pool twin, driven by src/repro/serve/):
  paged_cache_defs(cfg, max_batch, n_blocks, block_size, n_pages)
  decode_step_paged(cfg, params, tok, pools, table, lengths)
                                       -> (pools, logits)
K/V lives in a shared page pool with per-slot block tables instead of one
contiguous (B, max_len) buffer; attention gathers through the table via
the registry's decode_attention kernel (cfg.decode_kernel).

Kernel routing: `cfg.attention_kernel` / `cfg.ssm_kernel` swap the full-seq
attention and SSD within-chunk compute for the kernels/ops.py registry's
custom_vjp Pallas kernels — forward AND backward — so `jax.grad` through
`forward` (train/step.py local_grads) takes the blocked gradient kernels.
The remat policy composes with this unchanged: the custom_vjp boundary is
what gets rematerialized, and its residual contract (O(S), never O(S^2))
is exactly what the scan carries between layers.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import ParamDef
from repro.models import layers as L
from repro.models import ssm as S


# ---------------------------------------------------------------------------
# param defs
# ---------------------------------------------------------------------------

def _stack(defs: dict, n: int) -> dict:
    """Prepend a scanned 'layers' axis to every ParamDef leaf."""
    return jax.tree_util.tree_map(
        lambda d: ParamDef((n, *d.shape), ("layers", *d.axes), d.init, d.scale),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def _block_defs(cfg: ModelConfig) -> dict:
    blk = {
        "ln1": L.rms_norm_def(cfg.d_model),
        "attn": L.attention_defs(cfg),
        "ln2": L.rms_norm_def(cfg.d_model),
    }
    blk["moe" if cfg.family == "moe" else "mlp"] = (
        L.moe_defs(cfg) if cfg.family == "moe" else L.mlp_defs(cfg)
    )
    return blk


def _ssm_block_defs(cfg: ModelConfig) -> dict:
    return {"ln": L.rms_norm_def(cfg.d_model), "ssm": S.ssm_defs(cfg)}


def model_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    defs: dict[str, Any] = {
        "embed": ParamDef((cfg.vocab_size, d), ("vocab", "embed"), scale=1.0),
        "final_norm": L.rms_norm_def(d),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, cfg.vocab_size), ("embed", "vocab"))

    if cfg.family in ("dense", "moe"):
        defs["blocks"] = _stack(_block_defs(cfg), cfg.n_layers)
    elif cfg.family == "ssm":
        defs["blocks"] = _stack(_ssm_block_defs(cfg), cfg.n_layers)
    elif cfg.family == "hybrid":
        defs["blocks"] = _stack(_ssm_block_defs(cfg), cfg.n_layers)
        shared = _block_defs(cfg)
        defs["shared_attn"] = shared  # one attention+mlp block, reused
    elif cfg.family == "encdec":
        enc_blk = {
            "ln1": L.rms_norm_def(d),
            "attn": L.attention_defs(cfg),
            "ln2": L.rms_norm_def(d),
            "mlp": L.mlp_defs(cfg),
        }
        dec_blk = {
            "ln1": L.rms_norm_def(d),
            "attn": L.attention_defs(cfg),
            "ln_x": L.rms_norm_def(d),
            "xattn": L.attention_defs(cfg, cross=True),
            "ln2": L.rms_norm_def(d),
            "mlp": L.mlp_defs(cfg),
        }
        defs["encoder"] = _stack(enc_blk, cfg.n_encoder_layers)
        defs["decoder"] = _stack(dec_blk, cfg.n_layers)
        defs["enc_final_norm"] = L.rms_norm_def(d)
    else:
        raise ValueError(cfg.family)
    return defs


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def _layer_windows(cfg: ModelConfig) -> tuple[int | None, ...]:
    """STATIC per-scan-step window schedule.

    Uniform schedules scan one layer per step with cfg.sliding_window.
    gemma2-style alternation (cfg.local_global) scans layer PAIRS: each
    step applies a local (sliding_window) then a global (None) block, so
    both windows fold at trace time — no traced per-layer scalar, and the
    kernel routing (flash for train/prefill, decode_attention for serving)
    stays eligible."""
    if cfg.local_global and cfg.sliding_window:
        assert cfg.n_layers % 2 == 0, "local_global needs an even stack"
        return (cfg.sliding_window, None)
    return (cfg.sliding_window,)


def _embed(cfg: ModelConfig, params, tokens=None, inputs_embeds=None):
    if inputs_embeds is not None:
        return inputs_embeds.astype(cfg.compute_dtype)
    x = params["embed"][tokens]  # (B, S, d)
    return (x * jnp.asarray(cfg.d_model**0.5, x.dtype)).astype(cfg.compute_dtype)


def _unembed(cfg: ModelConfig, params, x):
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(cfg.compute_dtype)
    logits = (x @ head).astype(jnp.float32)
    logits = L.softcap(logits, cfg.final_softcap)
    return L.shard_act(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# dense / moe / ssm stacks (scanned)
# ---------------------------------------------------------------------------

def _dense_block(cfg: ModelConfig, p, x, positions, window, cache):
    # `window` is always STATIC (None / python int): the mask folds at
    # trace time and kernel routing stays eligible. gemma2's alternation
    # is expressed by the pair scan in _scan_stack, never a traced scalar.
    h, new_cache = L.multi_head_attention(
        cfg, p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps), positions,
        causal=True, window=window, cache=cache,
    )
    x = x + h
    inner = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        x = x + L.moe(cfg, p["moe"], inner)
    else:
        x = x + L.mlp(cfg, p["mlp"], inner)
    return x, new_cache


def _ssm_layer(cfg: ModelConfig, p, x, cache, valid_len=None):
    h, new_cache = S.ssm_block(
        cfg, p["ssm"], L.rms_norm(x, p["ln"], cfg.norm_eps), cache=cache,
        valid_len=valid_len,
    )
    return x + h, new_cache


def _substack(t, m: int):
    """Reshape a stacked leaf (n, ...) -> (n/m, m, ...) for the pair scan."""
    return t.reshape(t.shape[0] // m, m, *t.shape[1:])


def _unsubstack(t, m: int):
    """Inverse of _substack on a scan output: (n/m, m, ...) -> (n, ...)."""
    return t.reshape(t.shape[0] * m, *t.shape[2:])


def _scan_stack(cfg, blocks, x, positions, caches):
    """Scan over stacked layer params (+ optional cache).

    Uniform schedules scan one layer per step (STATIC cfg.sliding_window:
    the mask folds at trace time; kernel routing eligible). gemma2-style
    local/global alternation scans layer PAIRS instead — stacked leaves
    reshape (n, ...) -> (n//2, 2, ...) and the body applies the local then
    the global block, so both windows are static too (the carried-over
    traced-window thread is gone). caches['pos'] is a scalar shared by all
    layers, so it rides in the closure; only stacked k/v tensors scan.
    """
    has_cache = caches is not None
    pos = caches["pos"] if has_cache else None
    windows = _layer_windows(cfg)
    m = len(windows)
    blocks = jax.tree_util.tree_map(lambda t: _substack(t, m), blocks)

    def body(carry, xs):
        x = carry
        if has_cache:
            p, k, v = xs
            nk, nv = [], []
            for j, w in enumerate(windows):
                pj = jax.tree_util.tree_map(lambda a: a[j], p)
                x, c = _dense_block(
                    cfg, pj, x, positions, w,
                    {"k": k[j], "v": v[j], "pos": pos},
                )
                nk.append(c["k"])
                nv.append(c["v"])
            return x, (jnp.stack(nk), jnp.stack(nv))
        (p,) = xs
        for j, w in enumerate(windows):
            pj = jax.tree_util.tree_map(lambda a: a[j], p)
            x, _ = _dense_block(cfg, pj, x, positions, w, None)
        return x, None

    body = _remat(cfg, body)
    if has_cache:
        xs = (blocks, _substack(caches["k"], m), _substack(caches["v"], m))
        x, (nk, nv) = jax.lax.scan(body, x, xs)
        return x, {"k": _unsubstack(nk, m), "v": _unsubstack(nv, m),
                   "pos": pos + positions.shape[1]}
    x, _ = jax.lax.scan(body, x, (blocks,))
    return x, None


def _scan_ssm_stack(cfg, blocks, x, caches, valid_len=None):
    has_cache = caches is not None

    def body(carry, xs):
        x = carry
        if has_cache:
            p, c = xs
            x, new_c = _ssm_layer(cfg, p, x, c, valid_len)
            return x, new_c
        (p,) = xs
        x, _ = _ssm_layer(cfg, p, x, None)
        return x, None

    body = _remat(cfg, body)
    xs = (blocks, caches) if has_cache else (blocks,)
    x, new_caches = jax.lax.scan(body, x, xs)
    return x, new_caches


# ---------------------------------------------------------------------------
# forward (train / scoring): full-sequence logits
# ---------------------------------------------------------------------------

def forward(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array | None = None,  # (B, S) int32
    *,
    inputs_embeds: jax.Array | None = None,  # (B, S, d) modality stub
    enc_embeds: jax.Array | None = None,  # (B, S_enc, d) whisper frames
) -> jax.Array:
    if cfg.family == "encdec":
        return _forward_encdec(cfg, params, tokens, enc_embeds)

    B, Seq = (tokens.shape if tokens is not None else inputs_embeds.shape[:2])
    x = _embed(cfg, params, tokens, inputs_embeds)
    x = L.shard_act(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(Seq)[None], (B, Seq))

    if cfg.family in ("dense", "moe"):
        x, _ = _scan_stack(cfg, params["blocks"], x, positions, None)
    elif cfg.family == "ssm":
        x, _ = _scan_ssm_stack(cfg, params["blocks"], x, None)
    elif cfg.family == "hybrid":
        x = _hybrid_forward(cfg, params, x, positions, caches=None)[0]
    else:
        raise ValueError(cfg.family)
    return _unembed(cfg, params, x)


def _hybrid_forward(cfg, params, x, positions, caches, valid_len=None):
    """zamba2: mamba stack with the shared attention block interleaved."""
    blocks = params["blocks"]
    new_ssm_caches, new_attn_caches = [], []
    ai = 0
    block_fn = _remat(
        cfg, lambda p, x, c: _ssm_layer(cfg, p, x, c, valid_len)
    )
    for i in range(cfg.n_layers):
        p_i = jax.tree_util.tree_map(lambda a: a[i], blocks)
        c_i = None if caches is None else jax.tree_util.tree_map(
            lambda a: a[i], caches["ssm"]
        )
        x, nc = block_fn(p_i, x, c_i)
        if caches is not None:
            new_ssm_caches.append(nc)
        if (i + 1) % cfg.hybrid_period == 0:
            ca = None if caches is None else {
                "k": caches["attn"]["k"][ai],
                "v": caches["attn"]["v"][ai],
                "pos": caches["attn"]["pos"],
            }
            x, nca = _dense_block(
                cfg, params["shared_attn"], x, positions, None, ca,
            )
            if caches is not None:
                new_attn_caches.append(nca)
            ai += 1
    if caches is None:
        return x, None
    stack = lambda xs: jax.tree_util.tree_map(lambda *a: jnp.stack(a), *xs)
    new_caches = {
        "ssm": stack(new_ssm_caches),
        "attn": {
            "k": jnp.stack([c["k"] for c in new_attn_caches]),
            "v": jnp.stack([c["v"] for c in new_attn_caches]),
            "pos": new_attn_caches[0]["pos"],
        },
    }
    return x, new_caches


def _forward_encdec(cfg, params, tokens, enc_embeds):
    enc = _encode(cfg, params, enc_embeds)
    B, Sd = tokens.shape
    x = _embed(cfg, params, tokens)
    positions = jnp.broadcast_to(jnp.arange(Sd)[None], (B, Sd))
    enc_pos = jnp.broadcast_to(
        jnp.arange(enc.shape[1])[None], (B, enc.shape[1])
    )

    def body(carry, p):
        x = carry
        h, _ = L.multi_head_attention(
            cfg, p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps), positions,
            causal=True,
        )
        x = x + h
        h, _ = L.multi_head_attention(
            cfg, p["xattn"], L.rms_norm(x, p["ln_x"], cfg.norm_eps), positions,
            kv_x=enc, kv_positions=enc_pos, causal=False, use_rope=False,
        )
        x = x + h
        x = x + L.mlp(cfg, p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps))
        return x, None

    x, _ = jax.lax.scan(_remat(cfg, body), x, params["decoder"])
    return _unembed(cfg, params, x)


def _encode(cfg, params, enc_embeds):
    x = enc_embeds.astype(cfg.compute_dtype)
    B, Se, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))

    def body(carry, p):
        x = carry
        h, _ = L.multi_head_attention(
            cfg, p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps), positions,
            causal=False,
        )
        x = x + h
        x = x + L.mlp(cfg, p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps))
        return x, None

    x, _ = jax.lax.scan(_remat(cfg, body), x, params["encoder"])
    return L.rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# decode: cache defs + prefill + single-token step
# ---------------------------------------------------------------------------

def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """ShapeDtypeStruct tree for the decode cache (dry-run friendly)."""
    kv = lambda n: {
        "k": jax.ShapeDtypeStruct(
            (n, batch, max_len, cfg.n_kv_heads, cfg.head_dim), cfg.compute_dtype
        ),
        "v": jax.ShapeDtypeStruct(
            (n, batch, max_len, cfg.n_kv_heads, cfg.head_dim), cfg.compute_dtype
        ),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if cfg.family in ("dense", "moe"):
        return kv(cfg.n_layers)
    if cfg.family == "ssm":
        one = S.ssm_cache_defs(cfg, batch)
        return {
            k: jax.ShapeDtypeStruct((cfg.n_layers, *v.shape), v.dtype)
            for k, v in one.items()
        }
    if cfg.family == "hybrid":
        one = S.ssm_cache_defs(cfg, batch)
        n_attn = cfg.n_layers // cfg.hybrid_period
        return {
            "ssm": {
                k: jax.ShapeDtypeStruct((cfg.n_layers, *v.shape), v.dtype)
                for k, v in one.items()
            },
            "attn": kv(n_attn),
        }
    if cfg.family == "encdec":
        return {
            "self": kv(cfg.n_layers),
            "cross": {
                "k": jax.ShapeDtypeStruct(
                    (cfg.n_layers, batch, cfg.encoder_len, cfg.n_kv_heads,
                     cfg.head_dim), cfg.compute_dtype
                ),
                "v": jax.ShapeDtypeStruct(
                    (cfg.n_layers, batch, cfg.encoder_len, cfg.n_kv_heads,
                     cfg.head_dim), cfg.compute_dtype
                ),
            },
        }
    raise ValueError(cfg.family)


def cache_pspecs(cfg: ModelConfig) -> dict:
    """PartitionSpecs matching cache_defs: shard batch over 'data', kv heads
    over 'model' (ssm states: heads over 'model')."""
    from jax.sharding import PartitionSpec as P

    kvp = lambda: {
        "k": P(None, "data", None, "model", None),
        "v": P(None, "data", None, "model", None),
        "pos": P(),
    }
    if cfg.family in ("dense", "moe"):
        return kvp()
    if cfg.family == "ssm":
        return {
            "state": P(None, "data", "model", None, None),
            "conv": P(None, "data", None, "model"),
        }
    if cfg.family == "hybrid":
        return {
            "ssm": {
                "state": P(None, "data", "model", None, None),
                "conv": P(None, "data", None, "model"),
            },
            "attn": kvp(),
        }
    if cfg.family == "encdec":
        return {
            "self": kvp(),
            "cross": {
                "k": P(None, "data", None, "model", None),
                "v": P(None, "data", None, "model", None),
            },
        }
    raise ValueError(cfg.family)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_defs(cfg, batch, max_len)
    )


def _stack_apply(cfg, params, tokens, cache, enc_embeds, valid_len):
    """Shared decode/prefill body -> (new_cache, x (B, S, d))."""
    if cfg.family == "encdec":
        return _decode_encdec(cfg, params, tokens, cache, enc_embeds)
    B, Sq = tokens.shape
    x = _embed(cfg, params, tokens)
    pos0 = _cache_pos(cfg, cache)
    positions = pos0 + jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))

    if cfg.family in ("dense", "moe"):
        x, new_cache = _scan_stack(cfg, params["blocks"], x, positions, cache)
    elif cfg.family == "ssm":
        x, new_cache = _scan_ssm_stack(
            cfg, params["blocks"], x, cache, valid_len
        )
    elif cfg.family == "hybrid":
        x, new_cache = _hybrid_forward(
            cfg, params, x, positions, caches=cache, valid_len=valid_len
        )
    else:
        raise ValueError(cfg.family)
    return new_cache, x


def decode_step(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # (B, S_step) — S_step = 1 for decode, S for prefill
    cache: dict,
    *,
    enc_embeds: jax.Array | None = None,
) -> tuple[dict, jax.Array]:
    """Process tokens at positions cache['pos']..+S, return updated cache +
    logits for the last position."""
    new_cache, x = _stack_apply(cfg, params, tokens, cache, enc_embeds, None)
    logits = _unembed(cfg, params, x[:, -1:])
    return new_cache, logits[:, 0]


def prefill(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # (B, S) — prompts, right-padded to a fixed S
    cache: dict,
    *,
    enc_embeds: jax.Array | None = None,
    valid_len: jax.Array | None = None,  # (B,) true prompt lengths
) -> tuple[dict, jax.Array]:
    """Run the (padded) prompt through the stack once at a FIXED compiled
    shape, returning (cache, logits at each row's last valid position).

    valid_len=None means every row uses the full S (same as decode_step).
    With valid_len, rows are right-padded: attention is causal so pad
    positions never influence valid ones, and the SSM recurrence treats
    pad tokens as exact identity updates (dt forced to 0, conv history
    sliced at valid_len) — the state after prefill equals processing
    exactly valid_len tokens. Attention K/V *at pad positions* hold
    garbage; the serving layer only copies the valid blocks into the pool,
    and the contiguous cache's 'pos' advances by the PADDED S.
    """
    new_cache, x = _stack_apply(
        cfg, params, tokens, cache, enc_embeds, valid_len
    )
    if valid_len is None:
        xl = x[:, -1:]
    else:
        idx = jnp.maximum(valid_len.astype(jnp.int32) - 1, 0)
        xl = jnp.take_along_axis(x, idx[:, None, None], axis=1)
    logits = _unembed(cfg, params, xl)
    return new_cache, logits[:, 0]


def _cache_pos(cfg, cache):
    if cfg.family in ("dense", "moe"):
        return cache["pos"]
    if cfg.family == "ssm":
        return 0  # ssm caches carry no position (state is summary)
    if cfg.family == "hybrid":
        return cache["attn"]["pos"]
    raise ValueError(cfg.family)


def _decode_encdec(cfg, params, tokens, cache, enc_embeds):
    B, Sq = tokens.shape
    x = _embed(cfg, params, tokens)
    pos0 = cache["self"]["pos"]
    positions = pos0 + jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    enc_pos = jnp.broadcast_to(
        jnp.arange(cfg.encoder_len)[None], (B, cfg.encoder_len)
    )

    def body(carry, xs):
        x = carry
        p, ck, cv, xk, xv = xs
        h, nc = L.multi_head_attention(
            cfg, p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps), positions,
            causal=True, cache={"k": ck, "v": cv, "pos": pos0},
        )
        x = x + h
        h, _ = L.multi_head_attention(
            cfg, p["xattn"], L.rms_norm(x, p["ln_x"], cfg.norm_eps), positions,
            kv_x=jnp.zeros((B, 1, cfg.d_model), x.dtype),  # unused; cached K/V
            kv_positions=enc_pos, causal=False, use_rope=False,
            cache={"k": xk, "v": xv, "pos": jnp.int32(0)},
        )
        x = x + h
        x = x + L.mlp(cfg, p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps))
        return x, (nc["k"], nc["v"])

    xs = (
        params["decoder"],
        cache["self"]["k"], cache["self"]["v"],
        cache["cross"]["k"], cache["cross"]["v"],
    )
    x, (nk, nv) = jax.lax.scan(_remat(cfg, body), x, xs)
    new_cache = {
        "self": {"k": nk, "v": nv, "pos": pos0 + Sq},
        "cross": cache["cross"],
    }
    return new_cache, x


# ---------------------------------------------------------------------------
# paged decode: shared KV page pool + per-slot block tables (serving)
# ---------------------------------------------------------------------------

def paged_cache_defs(
    cfg: ModelConfig, max_batch: int, n_blocks: int, block_size: int,
    n_pages: int,
) -> dict:
    """ShapeDtypeStruct tree for the serving pool state.

    Attention K/V live in a SHARED page pool (n_layers, n_blocks,
    block_size, KV, Dh) — slots reference pages through the scheduler's
    (max_batch, n_pages) block table, so device memory scales with live
    tokens, not max_batch * max_len. SSM states, conv histories, and
    whisper cross K/V are per-slot fixed-size (their size is
    length-independent), indexed by slot id — the adapter that lets every
    family sit behind the same CachePool interface.
    """
    del n_pages  # table shape is scheduler state, not pool state
    kv = lambda n: {
        "k": jax.ShapeDtypeStruct(
            (n, n_blocks, block_size, cfg.n_kv_heads, cfg.head_dim),
            cfg.compute_dtype,
        ),
        "v": jax.ShapeDtypeStruct(
            (n, n_blocks, block_size, cfg.n_kv_heads, cfg.head_dim),
            cfg.compute_dtype,
        ),
    }
    if cfg.family in ("dense", "moe"):
        return kv(cfg.n_layers)
    if cfg.family == "ssm":
        one = S.ssm_cache_defs(cfg, max_batch)
        return {
            k: jax.ShapeDtypeStruct((cfg.n_layers, *v.shape), v.dtype)
            for k, v in one.items()
        }
    if cfg.family == "hybrid":
        one = S.ssm_cache_defs(cfg, max_batch)
        return {
            "ssm": {
                k: jax.ShapeDtypeStruct((cfg.n_layers, *v.shape), v.dtype)
                for k, v in one.items()
            },
            "attn": kv(cfg.n_layers // cfg.hybrid_period),
        }
    if cfg.family == "encdec":
        cross = lambda: jax.ShapeDtypeStruct(
            (cfg.n_layers, max_batch, cfg.encoder_len, cfg.n_kv_heads,
             cfg.head_dim), cfg.compute_dtype,
        )
        return {"self": kv(cfg.n_layers),
                "cross": {"k": cross(), "v": cross()}}
    raise ValueError(cfg.family)


def _paged_block(cfg, p, x, positions, window, pk, pv, table, lengths):
    h, pk, pv = L.paged_attention(
        cfg, p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps), positions,
        pk, pv, table, lengths, window=window,
    )
    x = x + h
    inner = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        x = x + L.moe(cfg, p["moe"], inner)
    else:
        x = x + L.mlp(cfg, p["mlp"], inner)
    return x, pk, pv


def _paged_scan_stack(cfg, blocks, x, positions, pools, table, lengths):
    """The paged twin of _scan_stack: k/v pool pages scanned per layer,
    table/lengths shared across layers in the closure. Same pair-scan
    treatment of gemma2's local/global alternation (static windows)."""
    windows = _layer_windows(cfg)
    m = len(windows)
    blocks = jax.tree_util.tree_map(lambda t: _substack(t, m), blocks)

    def body(carry, xs):
        x = carry
        p, k, v = xs
        nk, nv = [], []
        for j, w in enumerate(windows):
            pj = jax.tree_util.tree_map(lambda a: a[j], p)
            x, k1, v1 = _paged_block(
                cfg, pj, x, positions, w, k[j], v[j], table, lengths
            )
            nk.append(k1)
            nv.append(v1)
        return x, (jnp.stack(nk), jnp.stack(nv))

    xs = (blocks, _substack(pools["k"], m), _substack(pools["v"], m))
    x, (nk, nv) = jax.lax.scan(body, x, xs)
    return x, {"k": _unsubstack(nk, m), "v": _unsubstack(nv, m)}


def _paged_hybrid(cfg, params, x, positions, pools, table, lengths):
    blocks = params["blocks"]
    new_ssm, new_k, new_v = [], [], []
    ai = 0
    pk, pv = pools["attn"]["k"], pools["attn"]["v"]
    for i in range(cfg.n_layers):
        p_i = jax.tree_util.tree_map(lambda a: a[i], blocks)
        c_i = jax.tree_util.tree_map(lambda a: a[i], pools["ssm"])
        x, nc = _ssm_layer(cfg, p_i, x, c_i)
        new_ssm.append(nc)
        if (i + 1) % cfg.hybrid_period == 0:
            x, k1, v1 = _paged_block(
                cfg, params["shared_attn"], x, positions, None,
                pk[ai], pv[ai], table, lengths,
            )
            new_k.append(k1)
            new_v.append(v1)
            ai += 1
    stack = lambda xs: jax.tree_util.tree_map(lambda *a: jnp.stack(a), *xs)
    return x, {
        "ssm": stack(new_ssm),
        "attn": {"k": jnp.stack(new_k), "v": jnp.stack(new_v)},
    }


def _paged_encdec(cfg, params, x, positions, pools, table, lengths):
    B = x.shape[0]
    enc_pos = jnp.broadcast_to(
        jnp.arange(cfg.encoder_len)[None], (B, cfg.encoder_len)
    )

    def body(carry, xs):
        x = carry
        p, pk, pv, xk, xv = xs
        h, pk, pv = L.paged_attention(
            cfg, p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps), positions,
            pk, pv, table, lengths, window=None,
        )
        x = x + h
        # cross K/V are per-slot contiguous (encoder length is fixed and
        # fully live — paging buys nothing); reuse the cached-K/V MHA path
        h, _ = L.multi_head_attention(
            cfg, p["xattn"], L.rms_norm(x, p["ln_x"], cfg.norm_eps),
            positions,
            kv_x=jnp.zeros((B, 1, cfg.d_model), x.dtype),  # unused; cached
            kv_positions=enc_pos, causal=False, use_rope=False,
            cache={"k": xk, "v": xv, "pos": jnp.int32(0)},
        )
        x = x + h
        x = x + L.mlp(cfg, p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps))
        return x, (pk, pv)

    xs = (
        params["decoder"],
        pools["self"]["k"], pools["self"]["v"],
        pools["cross"]["k"], pools["cross"]["v"],
    )
    x, (nk, nv) = jax.lax.scan(body, x, xs)
    return x, {"self": {"k": nk, "v": nv}, "cross": pools["cross"]}


def decode_step_paged(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # (B, 1) — one new token per scheduler slot
    pools: dict,  # paged_cache_defs-shaped pool state
    table: jax.Array,  # (B, n_pages) int32 — pool page ids per slot
    lengths: jax.Array,  # (B,) int32 — tokens already cached per slot
) -> tuple[dict, jax.Array]:
    """One serving decode step at a fixed (max_batch, 1) shape.

    The new token is appended at position lengths[b] (its page/offset come
    from the block table), attention covers lengths + 1 tokens, and rope
    positions are per-slot (slots decode at different depths in the same
    jitted step — the continuous-batching contract). Inactive padding
    slots carry length 0 and all-null table rows: they compute garbage
    into the reserved null page and are ignored by the scheduler. SSM /
    conv / cross caches are slot-indexed; their padding rows idle
    harmlessly. Returns (new_pools, logits (B, vocab)).
    """
    x = _embed(cfg, params, tokens)
    positions = lengths[:, None].astype(jnp.int32)  # (B, 1)
    if cfg.family in ("dense", "moe"):
        x, pools = _paged_scan_stack(
            cfg, params["blocks"], x, positions, pools, table, lengths
        )
    elif cfg.family == "ssm":
        # the recurrent state is a length-independent summary: the paged
        # interface is the slot adapter, the math is the contiguous step
        x, pools = _scan_ssm_stack(cfg, params["blocks"], x, pools)
    elif cfg.family == "hybrid":
        x, pools = _paged_hybrid(
            cfg, params, x, positions, pools, table, lengths
        )
    elif cfg.family == "encdec":
        x, pools = _paged_encdec(
            cfg, params, x, positions, pools, table, lengths
        )
    else:
        raise ValueError(cfg.family)
    logits = _unembed(cfg, params, x[:, -1:])
    return pools, logits[:, 0]


def encode_cross_cache(cfg, params, enc_embeds, batch) -> dict:
    """Whisper: run the encoder once, precompute per-layer cross K/V."""
    enc = _encode(cfg, params, enc_embeds)
    dt = cfg.compute_dtype

    def body(_, p):
        k = jnp.einsum("bsd,dhq->bshq", enc, p["xattn"]["wk"].astype(dt))
        v = jnp.einsum("bsd,dhq->bshq", enc, p["xattn"]["wv"].astype(dt))
        return None, (k, v)

    _, (ks, vs) = jax.lax.scan(body, None, params["decoder"])
    return {"k": ks, "v": vs}

"""Model assembly for every assigned architecture family.

Families:
  dense / moe / ssm : homogeneous stacks -> jax.lax.scan over stacked layer
                      params (compile-time O(1) in depth; required for the
                      126-layer / 1T-param dry-runs). gemma2's alternating
                      local/global attention is handled by a per-layer window
                      array threaded through the scan.
  hybrid (zamba2)   : python-unrolled Mamba2 stack with a SHARED attention
                      block (one set of weights, applied every
                      cfg.hybrid_period layers).
  encdec (whisper)  : bidirectional encoder over stubbed frame embeddings +
                      causal decoder with cross-attention.

Public API:
  model_defs(cfg)                      -> ParamDef tree
  forward(cfg, params, batch)          -> logits            (train / scoring)
  cache_defs(cfg, batch, max_len)      -> decode-cache ShapeDtypeStructs
  prefill(cfg, params, batch, cache)   -> (cache, last_logits)
  decode_step(cfg, params, tok, cache) -> (cache, logits)

Kernel routing: `cfg.attention_kernel` / `cfg.ssm_kernel` swap the full-seq
attention and SSD within-chunk compute for the kernels/ops.py registry's
custom_vjp Pallas kernels — forward AND backward — so `jax.grad` through
`forward` (train/step.py local_grads) takes the blocked gradient kernels.
The remat policy composes with this unchanged: the custom_vjp boundary is
what gets rematerialized, and its residual contract (O(S), never O(S^2))
is exactly what the scan carries between layers.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import ParamDef
from repro.models import layers as L
from repro.models import ssm as S


# ---------------------------------------------------------------------------
# param defs
# ---------------------------------------------------------------------------

def _stack(defs: dict, n: int) -> dict:
    """Prepend a scanned 'layers' axis to every ParamDef leaf."""
    return jax.tree_util.tree_map(
        lambda d: ParamDef((n, *d.shape), ("layers", *d.axes), d.init, d.scale),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def _block_defs(cfg: ModelConfig) -> dict:
    blk = {
        "ln1": L.rms_norm_def(cfg.d_model),
        "attn": L.attention_defs(cfg),
        "ln2": L.rms_norm_def(cfg.d_model),
    }
    blk["moe" if cfg.family == "moe" else "mlp"] = (
        L.moe_defs(cfg) if cfg.family == "moe" else L.mlp_defs(cfg)
    )
    return blk


def _ssm_block_defs(cfg: ModelConfig) -> dict:
    return {"ln": L.rms_norm_def(cfg.d_model), "ssm": S.ssm_defs(cfg)}


def model_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    defs: dict[str, Any] = {
        "embed": ParamDef((cfg.vocab_size, d), ("vocab", "embed"), scale=1.0),
        "final_norm": L.rms_norm_def(d),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, cfg.vocab_size), ("embed", "vocab"))

    if cfg.family in ("dense", "moe"):
        defs["blocks"] = _stack(_block_defs(cfg), cfg.n_layers)
    elif cfg.family == "ssm":
        defs["blocks"] = _stack(_ssm_block_defs(cfg), cfg.n_layers)
    elif cfg.family == "hybrid":
        defs["blocks"] = _stack(_ssm_block_defs(cfg), cfg.n_layers)
        shared = _block_defs(cfg)
        defs["shared_attn"] = shared  # one attention+mlp block, reused
    elif cfg.family == "encdec":
        enc_blk = {
            "ln1": L.rms_norm_def(d),
            "attn": L.attention_defs(cfg),
            "ln2": L.rms_norm_def(d),
            "mlp": L.mlp_defs(cfg),
        }
        dec_blk = {
            "ln1": L.rms_norm_def(d),
            "attn": L.attention_defs(cfg),
            "ln_x": L.rms_norm_def(d),
            "xattn": L.attention_defs(cfg, cross=True),
            "ln2": L.rms_norm_def(d),
            "mlp": L.mlp_defs(cfg),
        }
        defs["encoder"] = _stack(enc_blk, cfg.n_encoder_layers)
        defs["decoder"] = _stack(dec_blk, cfg.n_layers)
        defs["enc_final_norm"] = L.rms_norm_def(d)
    else:
        raise ValueError(cfg.family)
    return defs


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def _window_schedule(cfg: ModelConfig) -> jnp.ndarray | None:
    """Per-layer sliding window for the scan, or None when uniform.

    gemma2-style alternation (odd layers global, -1) needs a traced
    per-layer scalar threaded through the scan; every other schedule is
    uniform and stays STATIC (None here; _scan_stack then applies
    cfg.sliding_window at trace time)."""
    if cfg.local_global and cfg.sliding_window:
        w = [cfg.sliding_window if i % 2 == 0 else -1
             for i in range(cfg.n_layers)]
        return jnp.asarray(w, jnp.int32)
    return None


def _embed(cfg: ModelConfig, params, tokens=None, inputs_embeds=None):
    if inputs_embeds is not None:
        return inputs_embeds.astype(cfg.compute_dtype)
    x = params["embed"][tokens]  # (B, S, d)
    return (x * jnp.asarray(cfg.d_model**0.5, x.dtype)).astype(cfg.compute_dtype)


def _unembed(cfg: ModelConfig, params, x):
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(cfg.compute_dtype)
    logits = (x @ head).astype(jnp.float32)
    logits = L.softcap(logits, cfg.final_softcap)
    return L.shard_act(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# dense / moe / ssm stacks (scanned)
# ---------------------------------------------------------------------------

def _dense_block(cfg: ModelConfig, p, x, positions, window, cache):
    # `window` is either static (None / python int — uniform schedules, so
    # the mask folds at trace time and kernel routing stays eligible) or a
    # traced per-layer scalar from the scanned gemma2-style schedule.
    static = window is None or isinstance(window, int)
    h, new_cache = L.multi_head_attention(
        cfg, p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps), positions,
        causal=True, window=window if static else None, cache=cache,
        _traced_window=None if static else window,
    )
    x = x + h
    inner = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        x = x + L.moe(cfg, p["moe"], inner)
    else:
        x = x + L.mlp(cfg, p["mlp"], inner)
    return x, new_cache


def _ssm_layer(cfg: ModelConfig, p, x, cache):
    h, new_cache = S.ssm_block(
        cfg, p["ssm"], L.rms_norm(x, p["ln"], cfg.norm_eps), cache=cache
    )
    return x + h, new_cache


def _scan_stack(cfg, blocks, x, positions, windows, caches):
    """Scan over stacked layer params (+ per-layer window + optional cache).

    windows=None means a uniform schedule: every layer gets the STATIC
    cfg.sliding_window instead of threading a traced per-layer scalar
    through the scan (mask folds at trace time; kernel routing eligible).
    caches['pos'] is a scalar shared by all layers, so it rides in the
    closure; only the stacked k/v tensors are scanned.
    """
    has_cache = caches is not None
    pos = caches["pos"] if has_cache else None
    uniform = windows is None

    def body(carry, xs):
        x = carry
        if has_cache:
            (p, k, v) = xs if uniform else (xs[0], xs[2], xs[3])
            w = cfg.sliding_window if uniform else xs[1]
            x, new_c = _dense_block(
                cfg, p, x, positions, w, {"k": k, "v": v, "pos": pos}
            )
            return x, (new_c["k"], new_c["v"])
        p = xs[0]
        w = cfg.sliding_window if uniform else xs[1]
        x, _ = _dense_block(cfg, p, x, positions, w, None)
        return x, None

    body = _remat(cfg, body)
    if has_cache:
        xs = ((blocks, caches["k"], caches["v"]) if uniform
              else (blocks, windows, caches["k"], caches["v"]))
        x, (nk, nv) = jax.lax.scan(body, x, xs)
        return x, {"k": nk, "v": nv, "pos": pos + positions.shape[1]}
    x, _ = jax.lax.scan(body, x, (blocks,) if uniform else (blocks, windows))
    return x, None


def _scan_ssm_stack(cfg, blocks, x, caches):
    has_cache = caches is not None

    def body(carry, xs):
        x = carry
        if has_cache:
            p, c = xs
            x, new_c = _ssm_layer(cfg, p, x, c)
            return x, new_c
        (p,) = xs
        x, _ = _ssm_layer(cfg, p, x, None)
        return x, None

    body = _remat(cfg, body)
    xs = (blocks, caches) if has_cache else (blocks,)
    x, new_caches = jax.lax.scan(body, x, xs)
    return x, new_caches


# ---------------------------------------------------------------------------
# forward (train / scoring): full-sequence logits
# ---------------------------------------------------------------------------

def forward(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array | None = None,  # (B, S) int32
    *,
    inputs_embeds: jax.Array | None = None,  # (B, S, d) modality stub
    enc_embeds: jax.Array | None = None,  # (B, S_enc, d) whisper frames
) -> jax.Array:
    if cfg.family == "encdec":
        return _forward_encdec(cfg, params, tokens, enc_embeds)

    B, Seq = (tokens.shape if tokens is not None else inputs_embeds.shape[:2])
    x = _embed(cfg, params, tokens, inputs_embeds)
    x = L.shard_act(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(Seq)[None], (B, Seq))

    if cfg.family in ("dense", "moe"):
        windows = _window_schedule(cfg)
        x, _ = _scan_stack(cfg, params["blocks"], x, positions, windows, None)
    elif cfg.family == "ssm":
        x, _ = _scan_ssm_stack(cfg, params["blocks"], x, None)
    elif cfg.family == "hybrid":
        x = _hybrid_forward(cfg, params, x, positions, caches=None)[0]
    else:
        raise ValueError(cfg.family)
    return _unembed(cfg, params, x)


def _hybrid_forward(cfg, params, x, positions, caches):
    """zamba2: mamba stack with the shared attention block interleaved."""
    blocks = params["blocks"]
    new_ssm_caches, new_attn_caches = [], []
    ai = 0
    block_fn = _remat(cfg, lambda p, x, c: _ssm_layer(cfg, p, x, c))
    for i in range(cfg.n_layers):
        p_i = jax.tree_util.tree_map(lambda a: a[i], blocks)
        c_i = None if caches is None else jax.tree_util.tree_map(
            lambda a: a[i], caches["ssm"]
        )
        x, nc = block_fn(p_i, x, c_i)
        if caches is not None:
            new_ssm_caches.append(nc)
        if (i + 1) % cfg.hybrid_period == 0:
            ca = None if caches is None else {
                "k": caches["attn"]["k"][ai],
                "v": caches["attn"]["v"][ai],
                "pos": caches["attn"]["pos"],
            }
            x, nca = _dense_block(
                cfg, params["shared_attn"], x, positions, None, ca,
            )
            if caches is not None:
                new_attn_caches.append(nca)
            ai += 1
    if caches is None:
        return x, None
    stack = lambda xs: jax.tree_util.tree_map(lambda *a: jnp.stack(a), *xs)
    new_caches = {
        "ssm": stack(new_ssm_caches),
        "attn": {
            "k": jnp.stack([c["k"] for c in new_attn_caches]),
            "v": jnp.stack([c["v"] for c in new_attn_caches]),
            "pos": new_attn_caches[0]["pos"],
        },
    }
    return x, new_caches


def _forward_encdec(cfg, params, tokens, enc_embeds):
    enc = _encode(cfg, params, enc_embeds)
    B, Sd = tokens.shape
    x = _embed(cfg, params, tokens)
    positions = jnp.broadcast_to(jnp.arange(Sd)[None], (B, Sd))
    enc_pos = jnp.broadcast_to(
        jnp.arange(enc.shape[1])[None], (B, enc.shape[1])
    )

    def body(carry, p):
        x = carry
        h, _ = L.multi_head_attention(
            cfg, p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps), positions,
            causal=True,
        )
        x = x + h
        h, _ = L.multi_head_attention(
            cfg, p["xattn"], L.rms_norm(x, p["ln_x"], cfg.norm_eps), positions,
            kv_x=enc, kv_positions=enc_pos, causal=False, use_rope=False,
        )
        x = x + h
        x = x + L.mlp(cfg, p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps))
        return x, None

    x, _ = jax.lax.scan(_remat(cfg, body), x, params["decoder"])
    return _unembed(cfg, params, x)


def _encode(cfg, params, enc_embeds):
    x = enc_embeds.astype(cfg.compute_dtype)
    B, Se, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))

    def body(carry, p):
        x = carry
        h, _ = L.multi_head_attention(
            cfg, p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps), positions,
            causal=False,
        )
        x = x + h
        x = x + L.mlp(cfg, p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps))
        return x, None

    x, _ = jax.lax.scan(_remat(cfg, body), x, params["encoder"])
    return L.rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# decode: cache defs + prefill + single-token step
# ---------------------------------------------------------------------------

def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """ShapeDtypeStruct tree for the decode cache (dry-run friendly)."""
    kv = lambda n: {
        "k": jax.ShapeDtypeStruct(
            (n, batch, max_len, cfg.n_kv_heads, cfg.head_dim), cfg.compute_dtype
        ),
        "v": jax.ShapeDtypeStruct(
            (n, batch, max_len, cfg.n_kv_heads, cfg.head_dim), cfg.compute_dtype
        ),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if cfg.family in ("dense", "moe"):
        return kv(cfg.n_layers)
    if cfg.family == "ssm":
        one = S.ssm_cache_defs(cfg, batch)
        return {
            k: jax.ShapeDtypeStruct((cfg.n_layers, *v.shape), v.dtype)
            for k, v in one.items()
        }
    if cfg.family == "hybrid":
        one = S.ssm_cache_defs(cfg, batch)
        n_attn = cfg.n_layers // cfg.hybrid_period
        return {
            "ssm": {
                k: jax.ShapeDtypeStruct((cfg.n_layers, *v.shape), v.dtype)
                for k, v in one.items()
            },
            "attn": kv(n_attn),
        }
    if cfg.family == "encdec":
        return {
            "self": kv(cfg.n_layers),
            "cross": {
                "k": jax.ShapeDtypeStruct(
                    (cfg.n_layers, batch, cfg.encoder_len, cfg.n_kv_heads,
                     cfg.head_dim), cfg.compute_dtype
                ),
                "v": jax.ShapeDtypeStruct(
                    (cfg.n_layers, batch, cfg.encoder_len, cfg.n_kv_heads,
                     cfg.head_dim), cfg.compute_dtype
                ),
            },
        }
    raise ValueError(cfg.family)


def cache_pspecs(cfg: ModelConfig) -> dict:
    """PartitionSpecs matching cache_defs: shard batch over 'data', kv heads
    over 'model' (ssm states: heads over 'model')."""
    from jax.sharding import PartitionSpec as P

    kvp = lambda: {
        "k": P(None, "data", None, "model", None),
        "v": P(None, "data", None, "model", None),
        "pos": P(),
    }
    if cfg.family in ("dense", "moe"):
        return kvp()
    if cfg.family == "ssm":
        return {
            "state": P(None, "data", "model", None, None),
            "conv": P(None, "data", None, "model"),
        }
    if cfg.family == "hybrid":
        return {
            "ssm": {
                "state": P(None, "data", "model", None, None),
                "conv": P(None, "data", None, "model"),
            },
            "attn": kvp(),
        }
    if cfg.family == "encdec":
        return {
            "self": kvp(),
            "cross": {
                "k": P(None, "data", None, "model", None),
                "v": P(None, "data", None, "model", None),
            },
        }
    raise ValueError(cfg.family)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_defs(cfg, batch, max_len)
    )


def decode_step(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # (B, S_step) — S_step = 1 for decode, S for prefill
    cache: dict,
    *,
    enc_embeds: jax.Array | None = None,
) -> tuple[dict, jax.Array]:
    """Process tokens at positions cache['pos']..+S, return updated cache +
    logits for the last position."""
    if cfg.family == "encdec":
        return _decode_encdec(cfg, params, tokens, cache, enc_embeds)

    B, Sq = tokens.shape
    x = _embed(cfg, params, tokens)
    pos0 = _cache_pos(cfg, cache)
    positions = pos0 + jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))

    if cfg.family in ("dense", "moe"):
        windows = _window_schedule(cfg)
        x, new_cache = _scan_stack(
            cfg, params["blocks"], x, positions, windows, cache
        )
    elif cfg.family == "ssm":
        x, new_cache = _scan_ssm_stack(cfg, params["blocks"], x, cache)
    elif cfg.family == "hybrid":
        x, new_cache = _hybrid_forward(cfg, params, x, positions, caches=cache)
    else:
        raise ValueError(cfg.family)
    logits = _unembed(cfg, params, x[:, -1:])
    return new_cache, logits[:, 0]


def _cache_pos(cfg, cache):
    if cfg.family in ("dense", "moe"):
        return cache["pos"]
    if cfg.family == "ssm":
        return 0  # ssm caches carry no position (state is summary)
    if cfg.family == "hybrid":
        return cache["attn"]["pos"]
    raise ValueError(cfg.family)


def _decode_encdec(cfg, params, tokens, cache, enc_embeds):
    B, Sq = tokens.shape
    x = _embed(cfg, params, tokens)
    pos0 = cache["self"]["pos"]
    positions = pos0 + jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    enc_pos = jnp.broadcast_to(
        jnp.arange(cfg.encoder_len)[None], (B, cfg.encoder_len)
    )

    def body(carry, xs):
        x = carry
        p, ck, cv, xk, xv = xs
        h, nc = L.multi_head_attention(
            cfg, p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps), positions,
            causal=True, cache={"k": ck, "v": cv, "pos": pos0},
        )
        x = x + h
        h, _ = L.multi_head_attention(
            cfg, p["xattn"], L.rms_norm(x, p["ln_x"], cfg.norm_eps), positions,
            kv_x=jnp.zeros((B, 1, cfg.d_model), x.dtype),  # unused; cached K/V
            kv_positions=enc_pos, causal=False, use_rope=False,
            cache={"k": xk, "v": xv, "pos": jnp.int32(0)},
        )
        x = x + h
        x = x + L.mlp(cfg, p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps))
        return x, (nc["k"], nc["v"])

    xs = (
        params["decoder"],
        cache["self"]["k"], cache["self"]["v"],
        cache["cross"]["k"], cache["cross"]["v"],
    )
    x, (nk, nv) = jax.lax.scan(_remat(cfg, body), x, xs)
    new_cache = {
        "self": {"k": nk, "v": nv, "pos": pos0 + Sq},
        "cross": cache["cross"],
    }
    logits = _unembed(cfg, params, x[:, -1:])
    return new_cache, logits[:, 0]


def encode_cross_cache(cfg, params, enc_embeds, batch) -> dict:
    """Whisper: run the encoder once, precompute per-layer cross K/V."""
    enc = _encode(cfg, params, enc_embeds)
    dt = cfg.compute_dtype

    def body(_, p):
        k = jnp.einsum("bsd,dhq->bshq", enc, p["xattn"]["wk"].astype(dt))
        v = jnp.einsum("bsd,dhq->bshq", enc, p["xattn"]["wv"].astype(dt))
        return None, (k, v)

    _, (ks, vs) = jax.lax.scan(body, None, params["decoder"])
    return {"k": ks, "v": vs}

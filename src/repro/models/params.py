"""Parameter definitions with logical sharding axes (single source of truth).

Each model declares a nested dict of ParamDef(shape, logical axes). From the
same tree we derive:
  * materialized params            (smoke tests, examples — small configs)
  * jax.ShapeDtypeStruct stand-ins (dry-run — no allocation, 405B+ safe)
  * PartitionSpecs via LOGICAL_RULES (FSDP over 'data', TP/EP over 'model',
    decentralized replicas over 'pod' — see train/sharding notes)

Logical axis vocabulary:
  vocab      embedding rows / LM head cols          -> 'model'
  embed      the d_model axis of weight matrices    -> 'data'  (ZeRO-3/FSDP)
  heads      attention query heads                  -> 'model'
  kv_heads   attention kv heads                     -> 'model'
  mlp        feed-forward hidden                    -> 'model'
  expert     MoE expert index                       -> 'model' (EP)
  ssm_inner  mamba inner channels                   -> 'model'
  layers     scanned layer stack                    -> None (replicated axis)
  + None for small axes (head_dim, state, conv taps, biases...)
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

LOGICAL_RULES: dict[str, str | None] = {
    "vocab": "model",
    "embed": "data",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "expert": "model",
    "ssm_inner": "model",
    "layers": None,
    None: None,
}


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones
    scale: float | None = None  # stddev; default 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def pspec(self, rules=None) -> P:
        rules = rules or LOGICAL_RULES
        return P(*(rules.get(a) for a in self.axes))

    def sds(self, dtype) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, dtype)

    def materialize(self, key, dtype) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        scale = self.scale if self.scale is not None else 1.0 / math.sqrt(fan_in)
        return (scale * jax.random.normal(key, self.shape)).astype(dtype)


def tree_pspecs(defs, rules=None):
    return jax.tree_util.tree_map(
        lambda d: d.pspec(rules), defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def tree_sds(defs, dtype):
    return jax.tree_util.tree_map(
        lambda d: d.sds(dtype), defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def tree_materialize(defs, key, dtype):
    leaves, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )
    keys = jax.random.split(key, len(leaves))
    vals = [d.materialize(k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def shardable_pspecs(spec_tree, sds_tree, mesh):
    """Drop mesh axes that do not evenly divide the corresponding dim.

    Small models on big meshes (gemma2: 4 kv heads on a 16-wide 'model'
    axis; whisper: vocab 51865) simply leave those dims unsharded.
    """
    from jax.sharding import PartitionSpec as P

    def axis_size(ax) -> int:
        if isinstance(ax, (tuple, list)):
            n = 1
            for a in ax:
                n *= mesh.shape[a]
            return n
        return mesh.shape[ax]

    def fix(spec, sds):
        if spec is None:
            return spec
        entries = list(spec) + [None] * (len(sds.shape) - len(spec))
        out = []
        for dim, ax in zip(sds.shape, entries):
            if ax is None:
                out.append(None)
            elif dim % axis_size(ax) == 0:
                out.append(ax)
            else:
                out.append(None)
        return P(*out)

    return jax.tree_util.tree_map(fix, spec_tree, sds_tree)


def tree_num_params(defs) -> int:
    leaves = jax.tree_util.tree_leaves(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )
    return sum(math.prod(d.shape) for d in leaves)

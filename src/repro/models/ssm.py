"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) blocks.

Training/prefill uses the chunked SSD algorithm: within-chunk computation is
a masked attention-like matmul (MXU-friendly — this is the TPU-native
adaptation of the paper's GPU kernel), across-chunk state is a short scan.
Decode is the O(1) recurrent update on a (B, nh, dstate, headdim) state.

ngroups = 1 (B and C shared across heads), scalar decay A per head — the
standard Mamba2 configuration.

kernels/ssd_scan.py implements the within-chunk compute as a Pallas kernel
(a custom_vjp, so the training backward is the chunked Pallas gradient);
``ModelConfig.ssm_kernel`` routes the train/prefill path through it via the
kernels/ops.py registry, while this file's inline einsums are the pure-jnp
reference used on CPU and by kernel tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import ParamDef
from repro.models.layers import rms_norm_def, rms_norm, shard_act


def ssm_defs(cfg: ModelConfig) -> dict:
    d, din, ds = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state
    nh, w = cfg.ssm_heads, cfg.ssm_conv_width
    return {
        "wz": ParamDef((d, din), ("embed", "ssm_inner")),
        "wx": ParamDef((d, din), ("embed", "ssm_inner")),
        "wB": ParamDef((d, ds), ("embed", None)),
        "wC": ParamDef((d, ds), ("embed", None)),
        "wdt": ParamDef((d, nh), ("embed", None)),
        "dt_bias": ParamDef((nh,), (None,), init="zeros"),
        "A_log": ParamDef((nh,), (None,), init="ones"),
        "D": ParamDef((nh,), (None,), init="ones"),
        "conv_x": ParamDef((w, din), (None, "ssm_inner"), scale=0.5),
        "conv_B": ParamDef((w, ds), (None, None), scale=0.5),
        "conv_C": ParamDef((w, ds), (None, None), scale=0.5),
        "norm": rms_norm_def(din),
        "wo": ParamDef((din, d), ("ssm_inner", "embed")),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B, S, C), w: (W, C) -> causal depthwise conv, silu activation."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, k : k + x.shape[1], :] * w[k] for k in range(W))
    return jax.nn.silu(out)


def _depthwise_conv_valid(x: jax.Array, w: jax.Array) -> jax.Array:
    """No-padding depthwise conv: (B, S, C), (W, C) -> (B, S-W+1, C), silu."""
    W = w.shape[0]
    S_out = x.shape[1] - W + 1
    out = sum(x[:, k : k + S_out, :] * w[k] for k in range(W))
    return jax.nn.silu(out)


def _ssd_chunked(xh, dt, a_log, Bc, Cc, chunk, h0=None, head_block=0,
                 kernel="jnp"):
    """Chunked SSD scan.

    xh: (B, S, nh, hd)  inputs per head
    dt: (B, S, nh)      step sizes (post-softplus)
    a_log: (B, S, nh)   per-step log-decay (dt * A, A < 0)
    Bc, Cc: (B, S, ds)  input/output projections (shared across heads)
    h0: optional initial state (B, nh, ds, hd)
    head_block: >0 streams the within-chunk compute over head blocks so the
      (i, j) decay tile is (B, nc, Q, Q, head_block) instead of
      (B, nc, Q, Q, nh) — an nh/head_block-fold cut of the dominant buffer.
    kernel: 'jnp' keeps the inline einsum within-chunk path; any use_pallas
      mode dispatches it through ops.ssd_chunk (custom_vjp — forward AND
      backward are the blocked Pallas kernels under 'on'/'interpret'). The
      across-chunk recurrence stays in jnp either way (negligible FLOPs).
    Returns y: (B, S, nh, hd), final_state: (B, nh, ds, hd)
    """
    if head_block and head_block < xh.shape[2]:
        nh = xh.shape[2]
        assert nh % head_block == 0, (nh, head_block)
        nb = nh // head_block
        r = lambda t: jnp.moveaxis(
            t.reshape(*t.shape[:-1], nb, head_block)
            if t.ndim == 3 else
            t.reshape(t.shape[0], t.shape[1], nb, head_block, t.shape[3]),
            2, 0,
        )
        xh_b, dt_b, al_b = r(xh), r(dt), r(a_log)
        h0_b = (
            None if h0 is None
            else jnp.moveaxis(
                h0.reshape(h0.shape[0], nb, head_block, *h0.shape[2:]), 1, 0
            )
        )

        def one(args):
            xh_i, dt_i, al_i, h0_i = args
            return _ssd_chunked(xh_i, dt_i, al_i, Bc, Cc, chunk,
                                h0=h0_i, head_block=0, kernel=kernel)

        ys, hs = jax.lax.map(
            one,
            (xh_b, dt_b, al_b,
             h0_b if h0_b is not None else jnp.zeros(
                 (nb, xh.shape[0], head_block, Bc.shape[-1], xh.shape[3]),
                 jnp.promote_types(xh.dtype, jnp.float32),
             )),
        )
        y = jnp.moveaxis(ys, 0, 2).reshape(*xh.shape[:2], nh, xh.shape[3])
        h = jnp.moveaxis(hs, 0, 1).reshape(xh.shape[0], nh, Bc.shape[-1],
                                           xh.shape[3])
        return y, h
    Bsz, S_in, nh, hd = xh.shape
    ds = Bc.shape[-1]
    Q = min(chunk, S_in)
    pad = (-S_in) % Q
    if pad:
        # zero-pad: dt=0 => decay 1 and contribution 0, so state is exact
        zp = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        xh, dt, a_log, Bc, Cc = map(zp, (xh, dt, a_log, Bc, Cc))
    S = S_in + pad
    nc = S // Q

    # at least fp32 internal state; preserves f64 when the caller uses it
    f32 = jnp.promote_types(xh.dtype, jnp.float32)
    xdt = (xh * dt[..., None]).astype(f32)
    r = lambda t, shape: t.reshape(shape)
    xdt = r(xdt, (Bsz, nc, Q, nh, hd))
    al = r(a_log.astype(f32), (Bsz, nc, Q, nh))
    Bc_ = r(Bc.astype(f32), (Bsz, nc, Q, ds))
    Cc_ = r(Cc.astype(f32), (Bsz, nc, Q, ds))

    cum = jnp.cumsum(al, axis=2)  # (B, nc, Q, nh) inclusive
    if kernel != "jnp" and f32 == jnp.float32:
        # registry-dispatched within-chunk kernel (custom_vjp: the training
        # backward is the chunked Pallas gradient). f64 callers fall through
        # to the inline path — the kernel accumulates in f32 only.
        from repro.kernels import ops as KO

        y_intra, states = KO.ssd_chunk(xdt, cum, Bc_, Cc_, use_pallas=kernel)
    else:
        # intra-chunk: y_i += sum_{j<=i} (C_i.B_j) exp(cum_i - cum_j) xdt_j
        # (masked before the exp — see kernels/ref.py ssd_chunk_ref for why
        # the naive where(tri, exp(diff), 0) NaNs the cotangents)
        tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
        diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,i,j,nh)
        decay = jnp.where(tri, jnp.exp(jnp.where(tri, diff, 0.0)), 0.0)
        scores = jnp.einsum("bcis,bcjs->bcij", Cc_, Bc_)  # (B, nc, i, j)
        y_intra = jnp.einsum("bcij,bcijh,bcjhd->bcihd", scores, decay, xdt)

        # chunk states: state_c = sum_j exp(cum_last - cum_j) B_j (x) xdt_j
        dte = jnp.exp(cum[:, :, -1:, :] - cum)  # (B, nc, Q, nh)
        states = jnp.einsum("bcjs,bcjh,bcjhd->bchsd", Bc_, dte, xdt)  # (B,nc,nh,ds,hd)

    # inter-chunk recurrence
    total = jnp.exp(cum[:, :, -1, :])  # (B, nc, nh)

    def scan_fn(h, inp):
        tot_c, st_c = inp
        h_new = tot_c[:, :, None, None] * h + st_c
        return h_new, h  # emit PREVIOUS state (pre-chunk)

    if h0 is None:
        h0 = jnp.zeros((Bsz, nh, ds, hd), f32)
    h_final, h_prevs = jax.lax.scan(
        scan_fn,
        h0.astype(f32),
        (jnp.moveaxis(total, 1, 0), jnp.moveaxis(states, 1, 0)),
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # (B, nc, nh, ds, hd)

    y_inter = jnp.einsum("bcis,bchsd->bcihd", Cc_, h_prevs) * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(Bsz, S, nh, hd)[:, :S_in]
    return y, h_final


def ssm_block(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,  # (B, S, d_model)
    *,
    cache: dict | None = None,  # decode: {'state': (B,nh,ds,hd), 'conv': (B,W-1,C)}
    valid_len: jax.Array | None = None,  # (B,) prefill: true prompt lengths
) -> tuple[jax.Array, dict | None]:
    dt_c = cfg.compute_dtype
    B, S, _ = x.shape
    din, ds, nh, hd = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    W = cfg.ssm_conv_width
    xc = x.astype(dt_c)

    z = xc @ p["wz"].astype(dt_c)  # gate
    xi = xc @ p["wx"].astype(dt_c)
    Bc = xc @ p["wB"].astype(dt_c)
    Cc = xc @ p["wC"].astype(dt_c)
    dt_raw = xc @ p["wdt"].astype(dt_c)
    xi = shard_act(xi, "batch", "seq", "mlp")

    conv_in = jnp.concatenate([xi, Bc, Cc], axis=-1)  # (B, S, din+2ds)
    conv_w = jnp.concatenate(
        [p["conv_x"], p["conv_B"], p["conv_C"]], axis=-1
    ).astype(dt_c)

    new_cache = None
    if cache is None:
        conv_out = _causal_depthwise_conv(conv_in, conv_w)
    else:
        # prepend the conv history window (works for prefill S>1 and decode S=1)
        conv_full = jnp.concatenate([cache["conv"], conv_in], axis=1)
        conv_out = _depthwise_conv_valid(conv_full, conv_w)  # (B, S, C)
        if valid_len is None:
            new_conv = conv_full[:, -(W - 1):]
        else:
            # right-padded prefill: the history window must end at each
            # row's LAST VALID token (token t sits at conv_full row
            # W-1+t, so the window is rows [valid_len, valid_len+W-1))
            new_conv = jax.vmap(
                lambda cb, s: jax.lax.dynamic_slice_in_dim(cb, s, W - 1, 0)
            )(conv_full, valid_len.astype(jnp.int32))

    xi, Bc, Cc = (
        conv_out[..., :din],
        conv_out[..., din : din + ds],
        conv_out[..., din + ds :],
    )
    xh = xi.reshape(B, S, nh, hd)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )
    if valid_len is not None and cache is not None and S > 1:
        # pad tokens become exact identity updates: dt = 0 gives decay
        # exp(0) = 1 and contribution 0 (the same trick _ssd_chunked uses
        # for its internal chunk padding), so the prefill state equals
        # processing exactly valid_len tokens
        keep = jnp.arange(S)[None, :] < valid_len[:, None]  # (B, S)
        dt = jnp.where(keep[..., None], dt, 0.0)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (nh,) negative
    a_log = dt * A[None, None, :]

    if cache is None:
        y, _ = _ssd_chunked(xh, dt, a_log, Bc, Cc, cfg.ssm_chunk,
                            head_block=cfg.ssm_head_block,
                            kernel=cfg.ssm_kernel)
    elif S == 1:
        # recurrent step: h = exp(dt A) h + B (x) (dt x);  y = C.h
        h = cache["state"].astype(jnp.float32)  # (B, nh, ds, hd)
        xdt = (xh[:, 0].astype(jnp.float32) * dt[:, 0, :, None])
        h = jnp.exp(a_log[:, 0])[:, :, None, None] * h + jnp.einsum(
            "bs,bhd->bhsd", Bc[:, 0].astype(jnp.float32), xdt
        )
        y = jnp.einsum("bs,bhsd->bhd", Cc[:, 0].astype(jnp.float32), h)[:, None]
        new_cache = {"state": h.astype(jnp.float32), "conv": new_conv}
    else:
        # prefill with cache: chunked scan from the cached state
        y, h_final = _ssd_chunked(
            xh, dt, a_log, Bc, Cc, cfg.ssm_chunk, h0=cache["state"],
            head_block=cfg.ssm_head_block, kernel=cfg.ssm_kernel,
        )
        new_cache = {"state": h_final.astype(jnp.float32), "conv": new_conv}

    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, din).astype(dt_c)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)  # gated norm
    out = y @ p["wo"].astype(dt_c)
    return shard_act(out, "batch", "seq", "embed"), new_cache


def ssm_cache_defs(cfg: ModelConfig, batch: int) -> dict:
    """Decode-cache shapes for ONE ssm block."""
    din, ds = cfg.ssm_d_inner, cfg.ssm_state
    C = din + 2 * ds
    return {
        "state": jax.ShapeDtypeStruct(
            (batch, cfg.ssm_heads, ds, cfg.ssm_head_dim), jnp.float32
        ),
        "conv": jax.ShapeDtypeStruct(
            (batch, cfg.ssm_conv_width - 1, C), cfg.compute_dtype
        ),
    }


def init_ssm_cache(cfg: ModelConfig, batch: int) -> dict:
    sds = ssm_cache_defs(cfg, batch)
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), sds)

"""Core transformer layers: norm, RoPE, GQA attention, (gated) MLP, MoE.

Pure-functional: every layer is (cfg, params_subtree, activations) -> out.
Forward math runs in cfg.compute_dtype; softmax/norm statistics in fp32.
Activation sharding hints go through `shard_act` (no-op without a mesh).

The jnp attention here is the reference path; kernels/flash_attention.py is
the TPU Pallas version (validated against this in interpret mode). Dispatch
is by config (`ModelConfig.attention_kernel`) — the CPU dry-run and
numerics tests use this path. The registry-dispatched kernel is a
custom_vjp, so when a config routes attention through it the TRAINING
BACKWARD also runs the blocked Pallas gradient kernels (dq + dk/dv tiles
recomputed from the saved log-sum-exp) — no S x S probability matrix in
either direction.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.params import ParamDef

# ---------------------------------------------------------------------------
# activation sharding
# ---------------------------------------------------------------------------

ACT_RULES: dict[str, str | tuple | None] = {
    "batch": "data",
    "seq": None,
    "heads": "model",
    "kv_heads": "model",
    "embed": None,
    "mlp": "model",
    "expert": "model",
    "capacity": "data",
    "vocab": "model",
    None: None,
}


# The mesh used for activation constraints. `with mesh:` does NOT set the
# abstract mesh that with_sharding_constraint needs (jax 0.8), so launchers
# register it explicitly via use_constraint_mesh().
_CONSTRAINT_MESH = None
_ACT_OVERRIDES: dict | None = None


class use_constraint_mesh:
    """Context manager: activation shard_act constraints target this mesh.

    act_overrides: optional {logical_axis: mesh_axis} overrides — e.g.
    {'embed': 'model'} turns on residual-stream sharding
    (ModelConfig.shard_residual_embed).
    """

    def __init__(self, mesh, act_overrides: dict | None = None):
        self.mesh = mesh
        self.overrides = act_overrides
        self.prev = None

    def __enter__(self):
        global _CONSTRAINT_MESH, _ACT_OVERRIDES
        self.prev = (_CONSTRAINT_MESH, _ACT_OVERRIDES)
        _CONSTRAINT_MESH = self.mesh
        _ACT_OVERRIDES = self.overrides
        return self.mesh

    def __exit__(self, *exc):
        global _CONSTRAINT_MESH, _ACT_OVERRIDES
        _CONSTRAINT_MESH, _ACT_OVERRIDES = self.prev
        return False


def shard_act(x: jax.Array, *axes: str | None) -> jax.Array:
    """with_sharding_constraint by logical axis names.

    No-op without a registered mesh; axes that don't exist on the mesh or
    don't divide the dim evenly degrade to unsharded (small models on big
    meshes).
    """
    mesh = _CONSTRAINT_MESH
    if mesh is None:
        return x
    spec = []
    for dim, a in zip(x.shape, axes):
        r = (_ACT_OVERRIDES or {}).get(a, ACT_RULES.get(a))
        if r is None or r not in mesh.axis_names or dim % mesh.shape[r] != 0:
            spec.append(None)
        else:
            spec.append(r)
    from jax.sharding import NamedSharding

    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


# ---------------------------------------------------------------------------
# norm / rope / embedding
# ---------------------------------------------------------------------------

def rms_norm_def(d: int) -> ParamDef:
    return ParamDef((d,), (None,), init="ones")


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh) rotated pairwise; positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention_defs(cfg: ModelConfig, cross: bool = False) -> dict:
    d, qd, kvd, hd = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.head_dim
    defs = {
        "wq": ParamDef((d, cfg.n_heads, hd), ("embed", "heads", None)),
        "wk": ParamDef((d, cfg.n_kv_heads, hd), ("embed", "kv_heads", None)),
        "wv": ParamDef((d, cfg.n_kv_heads, hd), ("embed", "kv_heads", None)),
        "wo": ParamDef((cfg.n_heads, hd, d), ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((cfg.n_heads, hd), ("heads", None), init="zeros")
        defs["bk"] = ParamDef((cfg.n_kv_heads, hd), ("kv_heads", None), init="zeros")
        defs["bv"] = ParamDef((cfg.n_kv_heads, hd), ("kv_heads", None), init="zeros")
    return defs


def _attn_scores_mask(q_pos, k_pos, window, causal):
    """(S_q, S_k) boolean mask: True = attend.

    `window` is always STATIC (None or a python int): gemma2-style
    local/global alternation is expressed by the pair scan in
    models/transformer.py, not by threading a traced per-layer scalar.
    """
    m = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def multi_head_attention(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,  # (B, S, d)
    positions: jax.Array,  # (B, S)
    *,
    kv_x: jax.Array | None = None,  # cross-attention source
    kv_positions: jax.Array | None = None,
    causal: bool = True,
    window: int | None = None,
    use_rope: bool = True,
    cache: dict | None = None,  # {'k','v': (B, L, KV, Dh), 'pos': ()} decode
) -> tuple[jax.Array, dict | None]:
    dt = cfg.compute_dtype
    B, S, _ = x.shape
    kv_src = x if kv_x is None else kv_x
    kv_pos = positions if kv_positions is None else kv_positions

    q = jnp.einsum("bsd,dhq->bshq", x.astype(dt), p["wq"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
    if cache is not None and "k" in cache and kv_x is not None:
        # cross-attention decode: reuse precomputed enc K/V
        k, v = cache["k"], cache["v"]
    else:
        k = jnp.einsum("bsd,dhq->bshq", kv_src.astype(dt), p["wk"].astype(dt))
        v = jnp.einsum("bsd,dhq->bshq", kv_src.astype(dt), p["wv"].astype(dt))
        if "bk" in p:
            k = k + p["bk"].astype(dt)
            v = v + p["bv"].astype(dt)
        if use_rope:
            k = rope(k, kv_pos, cfg.rope_theta)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and kv_x is None:
        # self-attention decode: insert current K/V at position `pos`
        pos = cache["pos"]  # scalar int
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(dt), pos, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(dt), pos, 1)
        k, v = ck, cv
        new_cache = {"k": ck, "v": cv, "pos": pos + S}
        kv_pos = jnp.broadcast_to(jnp.arange(ck.shape[1])[None], (B, ck.shape[1]))
    elif cache is not None:
        new_cache = cache

    use_kernel = (
        cfg.attention_kernel != "jnp" and cache is None and kv_x is None
        and not cfg.blockwise_attention
    )
    if not use_kernel:
        # GQA grouping
        G = cfg.n_heads // cfg.n_kv_heads
        if cfg.shard_q_heads and G > 1:
            # expand K/V per group so the attention einsum is sharded by Q
            # heads ('heads' -> model) instead of replicated when
            # kv_heads < |model| (per-device KV bytes unchanged: the
            # expansion is sharded away)
            k = jnp.repeat(k, G, axis=2)
            v = jnp.repeat(v, G, axis=2)
            k = shard_act(k, "batch", "seq", "heads", None)
            v = shard_act(v, "batch", "seq", "heads", None)
            qg = q.reshape(B, q.shape[1], cfg.n_heads, 1, cfg.head_dim)
            qg = shard_act(qg, "batch", "seq", "heads", None, None)
        else:
            k = shard_act(k, "batch", "seq", "kv_heads", None)
            v = shard_act(v, "batch", "seq", "kv_heads", None)
            qg = q.reshape(B, q.shape[1], cfg.n_kv_heads, G, cfg.head_dim)
            qg = shard_act(qg, "batch", "seq", "kv_heads", None, None)
        scale = cfg.head_dim ** -0.5

        q_pos_row = positions[0] if cache is None else (
            jnp.arange(S) + (cache["pos"] if kv_x is None else 0)
        )
        k_pos_row = kv_pos[0]

    if use_kernel:
        # Registry-dispatched flash attention (kernels/ops.py): heads-major
        # (B, H, S, D) layout, GQA via the kernel's head->kv_head index map,
        # custom_vjp backward. Full-sequence self-attention only (positions
        # here are arange(S) for every no-cache caller).
        from repro.kernels import ops as KO

        o = KO.flash_attention(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
            jnp.swapaxes(v, 1, 2), causal=causal, window=window,
            softcap=cfg.attn_softcap, use_pallas=cfg.attention_kernel,
        )
        out = jnp.swapaxes(o, 1, 2).astype(dt)  # (B, S, H, Dh)
    elif cfg.blockwise_attention:
        out = _blockwise_attention(
            qg * scale, k, v, q_pos_row, k_pos_row,
            causal=causal and kv_x is None, window=window,
            softcap_v=cfg.attn_softcap,
            block_k=cfg.attention_block_k,
            valid_len=(cache["pos"] + S)
            if (cache is not None and kv_x is None) else None,
        ).astype(dt)
        out = out.reshape(B, q.shape[1], cfg.n_heads, cfg.head_dim)
    else:
        scores = jnp.einsum("bskgh,btkh->bkgst", qg, k) * scale
        scores = shard_act(scores, "batch", "kv_heads", None, None, None)
        scores = softcap(scores.astype(jnp.float32), cfg.attn_softcap)
        mask = _attn_scores_mask(
            q_pos_row, k_pos_row, window, causal and kv_x is None,
        )
        if cache is not None and kv_x is None:
            # only cache slots already written are valid
            mask &= (jnp.arange(k.shape[1]) < cache["pos"] + S)[None, :]
        scores = jnp.where(mask, scores, -1e30)

        probs = jax.nn.softmax(scores, axis=-1).astype(dt)
        probs = shard_act(probs, "batch", "kv_heads", None, None, None)
        out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
        out = out.reshape(B, q.shape[1], cfg.n_heads, cfg.head_dim)
    y = jnp.einsum("bshq,hqd->bsd", out, p["wo"].astype(dt))
    return shard_act(y, "batch", "seq", "embed"), new_cache


# ---------------------------------------------------------------------------
# paged attention (serving decode against a shared KV block pool)
# ---------------------------------------------------------------------------

def paged_attention(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,  # (B, 1, d) — one new token per sequence slot
    positions: jax.Array,  # (B, 1) — rope position of the new token
    pool_k: jax.Array,  # (n_blocks, block_size, KV, Dh) shared page pool
    pool_v: jax.Array,
    table: jax.Array,  # (B, n_pages) int32 — pool page ids per slot
    lengths: jax.Array,  # (B,) int32 — tokens already cached per slot
    *,
    window: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token self-attention against a paged KV pool (serving decode).

    The new token's K/V are written in place at page ``table[b, len//bs]``
    offset ``len % bs``, then attention runs over ``lengths + 1`` tokens
    through the registry's decode_attention kernel
    (``cfg.decode_kernel`` picks the backend; 'jnp' degrades to the
    jnp-gather oracle). Inactive slots (length 0, all-null table rows)
    write to the reserved null page and read back zeros — padding lanes
    cost one masked page, not a recompile.

    Returns (y (B, 1, d), new_pool_k, new_pool_v).
    """
    from repro.kernels import ops as KO

    dt = cfg.compute_dtype
    B = x.shape[0]
    xc = x.astype(dt)
    q = jnp.einsum("bsd,dhq->bshq", xc, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhq->bshq", xc, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhq->bshq", xc, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    block_size = pool_k.shape[1]
    page = table[jnp.arange(B), lengths // block_size]  # (B,)
    off = lengths % block_size
    pool_k = pool_k.at[page, off].set(k[:, 0].astype(pool_k.dtype))
    pool_v = pool_v.at[page, off].set(v[:, 0].astype(pool_v.dtype))

    mode = "off" if cfg.decode_kernel == "jnp" else cfg.decode_kernel
    o = KO.decode_attention(
        q[:, 0], pool_k, pool_v, table, lengths + 1,
        window=window, softcap=cfg.attn_softcap, use_pallas=mode,
    )  # (B, Hq, Dh)
    y = jnp.einsum("bhq,hqd->bd", o.astype(dt), p["wo"].astype(dt))
    return y[:, None], pool_k, pool_v


# ---------------------------------------------------------------------------
# blockwise attention (online softmax over KV blocks — the jnp twin of
# kernels/flash_attention.py). No (S_q x S_k) buffer ever materializes:
# the working set is one KV block per scan step. This is the §Perf
# optimization for the memory-dominated train/prefill cells; enable with
# ModelConfig.blockwise_attention.
# ---------------------------------------------------------------------------

def _blockwise_attention(
    qg: jax.Array,  # (B, Sq, KV, G, Dh) — pre-scaled queries
    k: jax.Array,  # (B, Sk, KV, Dh)
    v: jax.Array,  # (B, Sk, KV, Dh)
    q_pos: jax.Array,  # (Sq,)
    k_pos: jax.Array,  # (Sk,)
    *,
    causal: bool,
    window: int | None,
    softcap_v: float | None,
    block_k: int,
    valid_len: jax.Array | None = None,  # decode: cache fill level
) -> jax.Array:
    B, Sq, KV, G, Dh = qg.shape
    Sk = k.shape[1]
    block_k = min(block_k, Sk)
    pad = (-Sk) % block_k
    if pad:
        zp = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        k, v = zp(k), zp(v)
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-10**9)
    nb = k.shape[1] // block_k

    qf = qg.astype(jnp.float32)
    kb = k.reshape(B, nb, block_k, KV, Dh)
    vb = v.reshape(B, nb, block_k, KV, Dh)
    pb = k_pos.reshape(nb, block_k)

    def body(carry, inp):
        acc, m_prev, l_prev = carry
        k_t, v_t, p_t = inp
        s = jnp.einsum("bskgh,btkh->bkgst", qf, k_t.astype(jnp.float32))
        if softcap_v is not None:
            s = softcap_v * jnp.tanh(s / softcap_v)
        mask = jnp.ones((Sq, block_k), bool)
        if causal:
            mask &= q_pos[:, None] >= p_t[None, :]
        if window is not None:
            mask &= q_pos[:, None] - p_t[None, :] < window
        mask &= (p_t >= 0)[None, :]
        if valid_len is not None:
            mask &= (p_t < valid_len)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_cur = jnp.maximum(m_prev, s.max(-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[..., None])
        l_cur = l_prev * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkh->bkgsh", p, v_t.astype(jnp.float32)
        )
        return (acc, m_cur, l_cur), None

    acc0 = jnp.zeros((B, KV, G, Sq, Dh), jnp.float32)
    m0 = jnp.full((B, KV, G, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), pb),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, KV, G, Sq, Dh)
    return jnp.moveaxis(out, 3, 1)  # (B, Sq, KV, G, Dh)


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wg": ParamDef((d, f), ("embed", "mlp")),
        "wu": ParamDef((d, f), ("embed", "mlp")),
        "wd": ParamDef((f, d), ("mlp", "embed")),
    }


def mlp(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    dt = cfg.compute_dtype
    h = jax.nn.silu(x @ p["wg"].astype(dt)) * (x @ p["wu"].astype(dt))
    h = shard_act(h, "batch", "seq", "mlp")
    return shard_act(h @ p["wd"].astype(dt), "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Mixture of Experts (capacity-based top-k dispatch, EP over 'model')
# ---------------------------------------------------------------------------

def moe_defs(cfg: ModelConfig) -> dict:
    # EP: experts over 'model', intra-expert matrices FSDP over 'data'.
    # (expert AND mlp cannot both map to 'model' in one spec.)
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    defs = {
        "router": ParamDef((d, e), ("embed", None), scale=0.02),
        "wg": ParamDef((e, d, f), ("expert", "embed", None)),
        "wu": ParamDef((e, d, f), ("expert", "embed", None)),
        "wd": ParamDef((e, f, d), ("expert", None, "embed")),
    }
    if cfg.shared_expert_d_ff:
        defs["shared"] = mlp_defs(cfg, cfg.shared_expert_d_ff)
    return defs


def moe(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.moe_groups > 0:
        return _moe_grouped_einsum(cfg, p, x)
    return _moe_scatter(cfg, p, x)


def _moe_grouped_einsum(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    """GShard-style dispatch: tokens split into G groups (= data shards);
    per-group one-hot dispatch/combine einsums keep every contraction local
    to the (data, model) device pair — no dispatch collectives.

    buf[g,e,c,:] = sum_t dispatch[g,t,e,c] * x[g,t,:]
    y[g,t,:]     = sum_{e,c} combine[g,t,e,c] * out[g,e,c,:]
    """
    dt = cfg.compute_dtype
    B, S, d = x.shape
    T = B * S
    G = math_gcd_groups(cfg.moe_groups, T)
    Tg = T // G
    E, K = cfg.n_experts, cfg.experts_per_token
    C = max(1, int(Tg * K / E * cfg.capacity_factor))
    C = -(-C // 8) * 8  # small alignment

    xt = x.reshape(G, Tg, d)
    xt = shard_act(xt, "batch", None, "embed")
    logits = (xt @ p["router"].astype(dt)).astype(jnp.float32)  # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, K)  # (G, Tg, K)
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)

    oh_e = jax.nn.one_hot(top_i, E, dtype=jnp.int32)  # (G, Tg, K, E)
    # position of (token, slot) within its expert, PER GROUP
    pos = jnp.cumsum(oh_e.reshape(G, Tg * K, E), axis=1).reshape(
        G, Tg, K, E
    ) * oh_e - 1  # -1 where not routed
    pos_k = pos.max(-1)  # (G, Tg, K)
    keep = (pos_k >= 0) & (pos_k < C)
    oh_c = jax.nn.one_hot(jnp.where(keep, pos_k, -1), C, dtype=dt)  # (G,Tg,K,C)
    w_k = jnp.where(keep, top_p, 0.0).astype(dt)

    # (G, Tg, E, C) dispatch/combine one-hots (sum over K slots)
    dispatch = jnp.einsum("gtke,gtkc->gtec", oh_e.astype(dt), oh_c)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", oh_e.astype(dt), oh_c, w_k)
    dispatch = shard_act(dispatch, "batch", None, "expert", None)
    combine = shard_act(combine, "batch", None, "expert", None)

    buf = jnp.einsum("gtec,gtd->gecd", dispatch, xt)  # (G, E, C, d)
    buf = shard_act(buf, "batch", "expert", None, None)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["wg"].astype(dt)))
    h = h * jnp.einsum("gecd,edf->gecf", buf, p["wu"].astype(dt))
    h = shard_act(h, "batch", "expert", None, None)
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["wd"].astype(dt))
    out_buf = shard_act(out_buf, "batch", "expert", None, None)
    y = jnp.einsum("gtec,gecd->gtd", combine, out_buf)

    if cfg.shared_expert_d_ff:
        y = y + mlp(cfg, p["shared"], xt.reshape(B, S, d)).reshape(G, Tg, d)
    return shard_act(y.reshape(B, S, d), "batch", "seq", "embed")


def math_gcd_groups(g: int, t: int) -> int:
    while t % g:
        g -= 1
    return max(1, g)


def _moe_scatter(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    """x: (B, S, d) -> (B, S, d). Deterministic capacity-based dispatch."""
    dt = cfg.compute_dtype
    B, S, d = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.experts_per_token
    C = max(1, int(T * K / E * cfg.capacity_factor))
    C = -(-C // 128) * 128 if C > 128 else C  # 128-align: MXU + shardable

    xt = x.reshape(T, d)
    logits = (xt @ p["router"].astype(dt)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, K)  # (T, K)
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)  # renormalize

    flat_e = top_i.reshape(-1)  # (T*K,)
    flat_w = top_p.reshape(-1).astype(dt)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*K, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot  # 1-based positions
    pos = jnp.sum(pos_in_e, axis=-1) - 1  # (T*K,)
    keep = pos < C
    safe_pos = jnp.where(keep, pos, 0)

    # scatter tokens -> (E, C, d) buffers
    tok_rep = jnp.repeat(xt.astype(dt), K, axis=0)  # (T*K, d)
    buf = jnp.zeros((E, C, d), dt)
    buf = buf.at[flat_e, safe_pos].add(
        jnp.where(keep[:, None], tok_rep, 0.0)
    )
    buf = shard_act(buf, "expert", "capacity", None)

    # expert computation (einsum over stacked experts = EP over 'model')
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(dt)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["wu"].astype(dt))
    h = shard_act(h, "expert", "capacity", None)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wd"].astype(dt))
    out_buf = shard_act(out_buf, "expert", "capacity", None)

    # gather back + combine
    gathered = out_buf[flat_e, safe_pos]  # (T*K, d)
    gathered = jnp.where(keep[:, None], gathered, 0.0) * flat_w[:, None]
    y = gathered.reshape(T, K, d).sum(1)

    if cfg.shared_expert_d_ff:
        y = y + mlp(cfg, p["shared"], xt.reshape(B, S, d)).reshape(T, d)
    return shard_act(y.reshape(B, S, d), "batch", "seq", "embed")

"""Deterministic, resumable, sharded LM token pipeline.

Design goals (the ones that matter at 1000-node scale):
  * deterministic as a function of (seed, step, shard) — any host can
    reconstruct any batch, so restart-after-failure needs NO data state
    beyond the step counter already in the checkpoint
  * sharded: each (pod, data) slice reads only its shard
  * zero-copy resume: `start_step` fast-forwards by arithmetic, not by
    replaying the stream
  * background prefetch of the next batch

Synthetic token source (offline container): a seeded counter-based PRNG per
(step, shard) cell. Swapping in a real tokenized corpus = replacing
`_cell_tokens` with an indexed read; the determinism contract is unchanged.
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class LoaderConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    n_shards: int = 1  # data-parallel shards reading disjoint rows
    seed: int = 0

    @property
    def shard_batch(self) -> int:
        assert self.global_batch % self.n_shards == 0
        return self.global_batch // self.n_shards


class ShardedTokenLoader:
    def __init__(self, cfg: LoaderConfig, shard: int = 0, start_step: int = 0,
                 prefetch: int = 2):
        self.cfg = cfg
        self.shard = shard
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _cell_tokens(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            (self.cfg.seed * 1_000_003 + step) * 65_537 + self.shard
        )
        return rng.integers(
            0, self.cfg.vocab_size,
            size=(self.cfg.shard_batch, self.cfg.seq_len + 1),
            dtype=np.int32,
        )

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            toks = self._cell_tokens(step)
            batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def __iter__(self):
        return self

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


def batch_at(cfg: LoaderConfig, step: int) -> dict:
    """Direct (thread-free) deterministic access: the resume contract."""
    shards = [
        ShardedTokenLoader.__new__(ShardedTokenLoader) for _ in range(cfg.n_shards)
    ]
    rows = []
    for s in range(cfg.n_shards):
        ld = shards[s]
        ld.cfg, ld.shard = cfg, s
        rows.append(ld._cell_tokens(step))
    toks = np.concatenate(rows, axis=0)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

from repro.data.synthetic import (  # noqa: F401
    SparseDataset,
    make_classification,
    make_regression,
    DATASET_PRESETS,
)

"""Synthetic sparse datasets for the paper's convex experiments.

The paper uses News20-binary, RCV1 and Sector (LIBSVM). Those files are not
available offline, so we generate synthetic sparse datasets with matched
first-order statistics — dimension d, row sparsity rho, label balance — and
normalize every row to ||a|| = 1 exactly as the paper does. The presets below
carry the real datasets' (d, rho) so communication-cost ratios (O(rho*d) vs
O(d)) reproduce.

Rows are stored in padded-CSR form: idx (n, k) int32 + val (n, k) float,
k = max nnz per row; padding entries have val == 0 (idx 0). This is the
JAX-friendly fixed-shape sparse format used throughout core/.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# (d, nnz_per_row) matched to LIBSVM statistics (approx.)
DATASET_PRESETS = {
    "news20": dict(d=1_355_191, k=450),
    "rcv1": dict(d=47_236, k=74),
    "sector": dict(d=55_197, k=162),
    # small presets for tests/benchmarks
    "tiny": dict(d=64, k=8),
    "small": dict(d=2_000, k=40),
}


@dataclasses.dataclass
class SparseDataset:
    """Row-sparse dataset, split across N nodes with q rows each."""

    idx: np.ndarray  # (N, q, k) int32
    val: np.ndarray  # (N, q, k) float
    y: np.ndarray  # (N, q) float (+-1 for classification, real for regression)
    d: int

    @property
    def n_nodes(self) -> int:
        return self.idx.shape[0]

    @property
    def q(self) -> int:
        return self.idx.shape[1]

    @property
    def k(self) -> int:
        return self.idx.shape[2]

    @property
    def rho(self) -> float:
        """Fraction of nonzero features per row (paper's dataset sparsity)."""
        return float((self.val != 0).sum(-1).mean() / self.d)

    @property
    def total(self) -> int:
        return self.n_nodes * self.q

    def dense(self) -> np.ndarray:
        """(N, q, d) dense features — small problems only."""
        out = np.zeros((self.n_nodes, self.q, self.d), dtype=self.val.dtype)
        n_i = np.arange(self.n_nodes)[:, None, None]
        q_i = np.arange(self.q)[None, :, None]
        out[n_i, q_i, self.idx] += self.val  # pads add 0 at column 0
        return out

    def positive_ratio(self) -> float:
        return float((self.y > 0).mean())


def _sparse_rows(rng, n, d, k, dtype):
    """n normalized sparse rows with exactly k nonzeros each."""
    idx = np.empty((n, k), dtype=np.int32)
    for i in range(n):  # distinct indices per row
        idx[i] = rng.choice(d, size=k, replace=False)
    val = rng.standard_normal((n, k)).astype(dtype)
    val /= np.linalg.norm(val, axis=1, keepdims=True)  # ||a|| = 1 (paper)
    return idx, val


def _split(rng, idx, val, y, n_nodes):
    n = idx.shape[0]
    q = n // n_nodes
    perm = rng.permutation(n)[: q * n_nodes]
    shape = (n_nodes, q)
    return idx[perm].reshape(*shape, -1), val[perm].reshape(*shape, -1), y[
        perm
    ].reshape(shape)


def make_regression(
    n_nodes: int = 10,
    q: int = 50,
    d: int = 64,
    k: int = 8,
    noise: float = 0.1,
    seed: int = 0,
    dtype=np.float64,
) -> SparseDataset:
    """Sparse ridge-regression data: y = a^T w* + noise."""
    rng = np.random.default_rng(seed)
    n = n_nodes * q
    idx, val = _sparse_rows(rng, n, d, k, dtype)
    w_star = rng.standard_normal(d).astype(dtype)
    u = np.einsum("nk,nk->n", val, w_star[idx])
    y = u + noise * rng.standard_normal(n).astype(dtype)
    i, v, yy = _split(rng, idx, val, y, n_nodes)
    return SparseDataset(i, v, yy, d)


def make_classification(
    n_nodes: int = 10,
    q: int = 50,
    d: int = 64,
    k: int = 8,
    positive_ratio: float = 0.5,
    flip: float = 0.02,
    seed: int = 0,
    dtype=np.float64,
) -> SparseDataset:
    """Sparse binary classification (labels +-1), optionally imbalanced.

    For AUC experiments set positive_ratio << 0.5 (class imbalance is where
    AUC matters).
    """
    rng = np.random.default_rng(seed)
    n = n_nodes * q
    idx, val = _sparse_rows(rng, n, d, k, dtype)
    w_star = rng.standard_normal(d).astype(dtype)
    u = np.einsum("nk,nk->n", val, w_star[idx])
    thresh = np.quantile(u, 1.0 - positive_ratio)
    y = np.where(u > thresh, 1.0, -1.0).astype(dtype)
    flips = rng.random(n) < flip
    y[flips] *= -1.0
    i, v, yy = _split(rng, idx, val, y, n_nodes)
    return SparseDataset(i, v, yy, d)


def make_noniid_regression(
    n_nodes: int = 10,
    q: int = 50,
    d: int = 64,
    k: int = 8,
    shift: float = 1.0,
    noise: float = 0.1,
    seed: int = 0,
    dtype=np.float64,
) -> tuple[SparseDataset, np.ndarray]:
    """Deliberately non-iid splits: node n's labels come from its OWN model.

    Per-node ground truth w*_n = w_shared + shift * delta_n with delta_n a
    unit-norm node-specific direction, and each node samples its q rows
    locally (no global shuffle): the node marginals differ in both the
    label model and the draw. ``shift`` interpolates from the iid setting
    (0.0) to fully heterogeneous nodes. This is the personalization
    testbed: a single consensus model underfits every node, while per-node
    regularization (``Problem.lam`` as an (N,) array) trades local fit
    against consensus coupling.

    Returns ``(dataset, w_stars)`` with ``w_stars`` of shape (N, d) so
    tests can measure per-node excess risk against the true local models.
    """
    rng = np.random.default_rng(seed)
    w_shared = rng.standard_normal(d).astype(dtype)
    idx = np.empty((n_nodes, q, k), dtype=np.int32)
    val = np.empty((n_nodes, q, k), dtype=dtype)
    y = np.empty((n_nodes, q), dtype=dtype)
    w_stars = np.empty((n_nodes, d), dtype=dtype)
    for n in range(n_nodes):
        delta = rng.standard_normal(d).astype(dtype)
        delta /= np.linalg.norm(delta)
        w_stars[n] = w_shared + shift * delta
        i_n, v_n = _sparse_rows(rng, q, d, k, dtype)
        u = np.einsum("qk,qk->q", v_n, w_stars[n][i_n])
        idx[n], val[n] = i_n, v_n
        y[n] = u + noise * rng.standard_normal(q).astype(dtype)
    return SparseDataset(idx, val, y, d), w_stars


def from_preset(
    name: str, task: str = "classification", n_nodes: int = 10,
    q: int = 100, seed: int = 0
) -> SparseDataset:
    cfg = DATASET_PRESETS[name]
    if task == "regression":
        return make_regression(n_nodes, q, cfg["d"], cfg["k"], seed=seed)
    return make_classification(n_nodes, q, cfg["d"], cfg["k"], seed=seed)

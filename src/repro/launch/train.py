"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch minitron-8b \
        --steps 100 --batch 8 --seq 256 --reduced        # CPU-runnable
    PYTHONPATH=src python -m repro.launch.train --arch llama3-405b \
        --mesh single                                     # on a real pod

Wires together: config registry, mesh + sharding, deterministic resumable
data pipeline, AdamW train step (or multi-pod DSBA gossip), async sharded
checkpointing with exact resume, and the XLA latency-hiding flags for
collective/compute overlap on TPU.
"""
from __future__ import annotations

import argparse
import os
import time

# collective/compute overlap (no-ops on CPU; the TPU deployment flags)
os.environ.setdefault(
    "LIBTPU_INIT_ARGS",
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true",
)

import jax
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs import ALIASES, get_config, get_reduced
from repro.data.sharded_loader import LoaderConfig, batch_at
from repro.optim.adam import AdamConfig
from repro.train.step import TrainConfig, init_train_state, train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-8b", choices=list(ALIASES))
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="none", choices=["none", "single", "multi"],
                    help="'none' runs unsharded (CPU); single/multi build the "
                         "production mesh (needs real devices)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    tc = TrainConfig(
        optimizer=AdamConfig(lr=args.lr), microbatches=args.microbatches
    )
    ld = LoaderConfig(cfg.vocab_size, args.batch, args.seq, seed=args.seed)

    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh
        from repro.models.layers import use_constraint_mesh
        from repro.train.step import make_jitted_train_step

        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        ctx = use_constraint_mesh(mesh)
        ctx.__enter__()
        step_fn = make_jitted_train_step(mesh, cfg, tc)
    else:
        step_fn = jax.jit(lambda s, b: train_step(cfg, tc, s, b))

    mgr = CheckpointManager(args.ckpt_dir)
    state = init_train_state(cfg, tc, jax.random.PRNGKey(args.seed))
    restored, at = mgr.restore(state)
    if restored is not None:
        state = restored
        print(f"resumed from step {at}")

    t0 = time.time()
    start = int(state["step"])
    for i in range(start, args.steps):
        batch = {k: np.asarray(v) for k, v in batch_at(ld, i).items()}
        state, metrics = step_fn(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:5d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"({(time.time() - t0) / max(1, i - start + 1):.2f} s/step)",
                  flush=True)
        if args.ckpt_every and i and i % args.ckpt_every == 0:
            mgr.save(i, state, async_=True)
    mgr.wait()
    mgr.save(args.steps, state, async_=False)
    print("done; final checkpoint committed.")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we AOT-lower the real step function (train_step / serve_step)
with ShapeDtypeStruct inputs (zero allocation), compile it for the
production mesh, and record:

  memory_analysis()   -> bytes per device (proves fit / measures overflow)
  cost_analysis()     -> per-device HLO FLOPs + bytes (roofline terms)
  HLO collective scan -> per-device collective bytes by op (roofline term 3)

Single-pod mesh = (16, 16) ('data','model'); multi-pod = (2, 16, 16) with
the 'pod' axis running the paper's decentralized gossip step (train) or
pod-sharded batch (serve). Results land in experiments/dryrun/*.json;
benchmarks/roofline.py renders EXPERIMENTS.md tables from them.

Usage:
  python -m repro.launch.dryrun --arch minitron-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # every cell (slow)
  python -m repro.launch.dryrun --all --mesh multi
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import numpy as np

from repro.configs import ALIASES, get_config, list_archs
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, cells_for, input_specs, batch_axes_for
from repro.models import transformer as T
from repro.models.config import ModelConfig

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _serve_fn(cfg: ModelConfig):
    def serve_step(params, tokens, cache):
        return T.decode_step(cfg, params, tokens, cache)

    return serve_step


def build_cell(cfg: ModelConfig, shape_name: str, mesh, multi_pod: bool,
               gossip_kw: dict | None = None, microbatches: int = 1):
    """Returns (jitted_fn, example_args_sds) ready to .lower()."""
    from jax.sharding import NamedSharding

    from repro.core.gossip import (
        GossipConfig, gossip_batch_specs, gossip_state_defs,
        make_gossip_train_step,
    )
    from repro.train.step import (
        TrainConfig, make_train_state_defs, train_step,
    )

    from repro.models.params import shardable_pspecs

    shape = SHAPES[shape_name]
    ns = lambda tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree
    )
    fix = lambda spec, sds: shardable_pspecs(spec, sds, mesh)

    if shape.kind == "train":
        tc = TrainConfig(batch_axes=batch_axes_for(shape.batch, mesh),
                         microbatches=microbatches)
        args, arg_specs = input_specs(cfg, shape, mesh)
        if multi_pod:
            # the paper's feature: decentralized DSBA gossip over 'pod'
            gkw = {"mode": "dsba", **(gossip_kw or {})}
            gc = GossipConfig(n_pods=mesh.shape["pod"], **gkw)
            state_sds, state_spec = gossip_state_defs(cfg, tc, gc)
            state_spec = fix(state_spec, state_sds)
            # batch gets a leading pod dim
            pods = mesh.shape["pod"]
            bsds = jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(
                    (pods, s.shape[0] // pods, *s.shape[1:]), s.dtype
                ),
                args,
            )
            bspec = gossip_batch_specs(cfg)
            step = make_gossip_train_step(mesh, cfg, tc, gc)
            fn = jax.jit(
                step,
                in_shardings=(ns(state_spec), ns(bspec)),
                out_shardings=(ns(state_spec), None),
                donate_argnums=(0,),
            )
            return fn, (state_sds, bsds)
        state_sds, state_spec = make_train_state_defs(cfg, tc)
        state_spec = fix(state_spec, state_sds)
        fn = jax.jit(
            lambda st, b: train_step(cfg, tc, st, b),
            in_shardings=(ns(state_spec), ns(arg_specs)),
            out_shardings=(ns(state_spec), None),
            donate_argnums=(0,),
        )
        return fn, (state_sds, args)

    # serve (prefill or decode)
    from repro.models.params import tree_pspecs, tree_sds

    defs = T.model_defs(cfg)
    p_sds = tree_sds(defs, cfg.param_dtype)
    p_spec = fix(tree_pspecs(defs), p_sds)
    args, arg_specs = input_specs(cfg, shape, mesh)
    cache_spec = fix(arg_specs["cache"], args["cache"])
    fn = jax.jit(
        _serve_fn(cfg),
        in_shardings=(ns(p_spec), ns(arg_specs["tokens"]), ns(cache_spec)),
        out_shardings=None,
        donate_argnums=(2,),
    )
    return fn, (p_sds, args["tokens"], args["cache"])


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None,
             gossip_kw: dict | None = None,
             hlo_path: pathlib.Path | None = None,
             microbatches: int = 1) -> dict:
    """Lower + compile the cell; account costs with loop-trip multiplication.

    XLA's cost_analysis counts a `while` (lax.scan) body ONCE, not
    trip_count times, so a scanned-L-layer model under-reports flops/bytes/
    collectives by ~L x. hlo_analysis.program_costs walks the optimized
    HLO's call graph with loop trip counts and accumulates per-instruction
    costs at true execution multiplicity (validated in
    tests/test_hlo_analysis.py). memory_analysis needs no correction
    (while-loop buffers are allocated per iteration, sized correctly).

    overrides: ModelConfig field overrides for §Perf hillclimb variants.
    """
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    rec: dict = {
        "arch": cfg.name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    from repro.models.layers import use_constraint_mesh

    overrides_act = {"embed": "model"} if cfg.shard_residual_embed else None
    t0 = time.time()
    try:
        with mesh, use_constraint_mesh(mesh, overrides_act):
            fn, sds_args = build_cell(cfg, shape_name, mesh, multi_pod,
                                      gossip_kw, microbatches)
            lowered = fn.lower(*sds_args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            mem = compiled.memory_analysis()
            print(compiled.memory_analysis())  # proves it fits
            cost = H.xla_cost_analysis(compiled)
            print({k: cost[k] for k in ("flops", "bytes accessed") if k in cost})
            hlo_text = compiled.as_text()
            if hlo_path is not None:
                import zstandard

                hlo_path.write_bytes(
                    zstandard.ZstdCompressor(level=6).compress(
                        hlo_text.encode()
                    )
                )
            pc = H.program_costs(hlo_text)
        shape = SHAPES[shape_name]
        mf = H.model_flops(cfg, shape.kind, shape.batch, shape.seq)
        cost_x = {"flops": pc.flops, "bytes accessed": pc.bytes}
        colls_x = H.CollectiveStats(
            dict(pc.coll_bytes_by_op), dict(pc.coll_count_by_op)
        )
        rec["xla_cost_analysis"] = {  # uncorrected, for reference
            "hlo_flops": float(cost.get("flops", 0.0)),
            "hlo_bytes": float(cost.get("bytes accessed", 0.0)),
        }
        rl = H.roofline_terms(cost_x, colls_x, chips, mf)
        rec.update(
            ok=True,
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "code_bytes": mem.generated_code_size_in_bytes,
            },
            hlo_flops=rl.hlo_flops,
            hlo_bytes=rl.hlo_bytes,
            collective_bytes=rl.collective_bytes,
            collectives={"bytes": colls_x.bytes_by_op,
                         "count": colls_x.count_by_op},
            model_flops=mf,
            roofline={
                "compute_s": rl.compute_s,
                "memory_s": rl.memory_s,
                "collective_s": rl.collective_s,
                "dominant": rl.dominant,
                "useful_flop_ratio": rl.useful_flop_ratio,
                "roofline_fraction": rl.roofline_fraction,
            },
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def cell_list(archs, shapes, meshes):
    cells = []
    for arch in archs:
        cfg = get_config(arch)
        names = cells_for(cfg) if shapes is None else shapes
        for s in names:
            if s not in cells_for(cfg):
                continue
            for m in meshes:
                cells.append((arch, s, m == "multi"))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument("--tag", default="", help="suffix for experiment variants")
    ap.add_argument(
        "--set", nargs="*", default=[], metavar="FIELD=VALUE",
        help="ModelConfig overrides for perf variants, e.g. "
             "blockwise_attention=True remat=dots",
    )
    ap.add_argument("--gossip-mode", default=None,
                    choices=["dsba", "dsgd", "allreduce"])
    ap.add_argument("--gossip-compression", default=None,
                    choices=["none", "topk", "block_topk"])
    ap.add_argument("--gossip-topk-ratio", type=float, default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    import ast

    overrides = {}
    for kv in args.set:
        key, val = kv.split("=", 1)
        try:
            overrides[key] = ast.literal_eval(val)
        except (ValueError, SyntaxError):
            overrides[key] = val

    gossip_kw = {}
    if args.gossip_mode:
        gossip_kw["mode"] = args.gossip_mode
    if args.gossip_compression:
        gossip_kw["compression"] = args.gossip_compression
    if args.gossip_topk_ratio is not None:
        gossip_kw["topk_ratio"] = args.gossip_topk_ratio

    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = None if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    cells = cell_list(archs, shapes, meshes)
    print(f"{len(cells)} cells to run")
    for arch, shape, multi in cells:
        aid = ALIASES.get(arch, arch)
        tag = f"_{args.tag}" if args.tag else ""
        path = out / f"{aid}_{shape}_{'multi' if multi else 'single'}{tag}.json"
        if path.exists() and not args.force:
            print(f"skip (cached): {path.name}")
            continue
        print(f"=== {arch} x {shape} x {'multi' if multi else 'single'} "
              f"{overrides or ''} ===", flush=True)
        rec = run_cell(arch, shape, multi, overrides, gossip_kw,
                       hlo_path=path.with_suffix(".hlo.zst"),
                       microbatches=args.microbatches)
        if overrides or gossip_kw or args.microbatches > 1:
            rec["overrides"] = {k: str(v) for k, v in overrides.items()}
            rec["gossip"] = {k: str(v) for k, v in gossip_kw.items()}
            rec["microbatches"] = args.microbatches
        path.write_text(json.dumps(rec, indent=2, default=str))
        status = "OK" if rec.get("ok") else f"FAIL: {rec.get('error')}"
        print(f"--> {status} ({rec['total_s']}s)", flush=True)


if __name__ == "__main__":
    main()

"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state. Single pod = 16x16 = 256 chips ('data', 'model'); multi-pod adds the
'pod' axis (2 pods = 512 chips) — the decentralized-learning graph axis of
the paper (DESIGN.md §3).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    if len(jax.devices()) == n:
        return jax.make_mesh(shape, axes)
    # host-device dry-run: 512 placeholder devices back both meshes
    return jax.make_mesh(shape, axes, devices=np.asarray(jax.devices()[:n]))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many host devices a test configured."""
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=np.asarray(jax.devices()[:n]))

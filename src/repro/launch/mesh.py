"""Mesh construction: production pod meshes + the node-axis solver mesh.

Defined as FUNCTIONS so importing this module never touches jax device
state. Single pod = 16x16 = 256 chips ('data', 'model'); multi-pod adds the
'pod' axis (2 pods = 512 chips) — the decentralized-learning graph axis of
the paper (DESIGN.md §3).

``make_node_mesh`` is the solver-facing variant: a 1-D ``"node"`` axis
placing one graph node per device, the substrate of the ``comm="sharded"``
backend (``core.comm.ShardedComm``). On CPU, simulate N devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set BEFORE jax is
imported — tests spawn a subprocess tier for this, see tests/conftest.py).
"""
from __future__ import annotations

import jax
import numpy as np


def make_node_mesh(n: int, devices=None) -> jax.sharding.Mesh:
    """1-D mesh with a ``"node"`` axis of ``n`` devices, one graph node each.

    devices: explicit device list (defaults to ``jax.devices()``); the
    first ``n`` back the mesh. Raises with a reproduction hint when fewer
    than ``n`` devices exist rather than building a short mesh.
    """
    devs = list(devices) if devices is not None else jax.devices()
    if len(devs) < n:
        raise ValueError(
            f"node mesh needs {n} devices, found {len(devs)}; on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} before "
            "importing jax"
        )
    return jax.make_mesh((n,), ("node",), devices=np.asarray(devs[:n]))


def make_production_mesh(*, multi_pod: bool = False):
    """The (pod,) data x model production mesh over exactly-counted devices.

    Raises when fewer devices exist than the mesh shape needs instead of
    silently handing ``jax.make_mesh`` a short device array (which used to
    fail deep inside jax's mesh reshape with an inscrutable error).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    avail = len(jax.devices())
    if avail == n:
        return jax.make_mesh(shape, axes)
    if avail < n:
        raise ValueError(
            f"production mesh {dict(zip(axes, shape))} needs {n} devices, "
            f"found {avail}; for a host dry-run set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n}"
        )
    # host-device dry-run with a surplus: the first n placeholders back it
    return jax.make_mesh(shape, axes, devices=np.asarray(jax.devices()[:n]))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many host devices a test configured."""
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=np.asarray(jax.devices()[:n]))

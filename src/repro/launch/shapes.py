"""The assigned input-shape grid and per-(arch x shape) input specs.

Every cell provides ShapeDtypeStruct stand-ins (weak-type-correct, shardable,
ZERO device allocation) for the function the dry-run lowers:

  train_4k     train_step   tokens/targets (256, 4096)
  prefill_32k  serve prefill — decode_step over the full (32, 32768) prompt
  decode_32k   serve decode — ONE new token, KV/SSM cache of 32768 (batch 128)
  long_500k    decode with 524288-token cache (batch 1) — sub-quadratic archs

decode/long lower `serve_step`, NOT train_step. long_500k runs only for
archs with supports_long_context (mamba2, zamba2) — see DESIGN.md §6.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import transformer as T
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def cells_for(cfg: ModelConfig) -> list[str]:
    names = ["train_4k", "prefill_32k"]
    if cfg.has_decoder:
        names.append("decode_32k")
        if cfg.supports_long_context:
            names.append("long_500k")
    return names


def _div(n: int, axes_sizes: list[int]) -> bool:
    p = 1
    for a in axes_sizes:
        p *= a
    return n % p == 0


def batch_axes_for(batch: int, mesh) -> tuple[str, ...]:
    """Largest prefix of (pod, data) that divides the batch."""
    names = [n for n in ("pod", "data") if n in mesh.axis_names]
    sizes = [mesh.shape[n] for n in names]
    while names and not _div(batch, sizes):
        names.pop()
        sizes.pop()
    return tuple(names)


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """Returns (args_sds, in_shardings_for_args) for the lowered function,
    EXCLUDING the state/params argument (see dryrun.build_cell)."""
    baxes = batch_axes_for(shape.batch, mesh)
    bspec = P(baxes if baxes else None)

    if shape.kind == "train":
        sds = {
            "tokens": jax.ShapeDtypeStruct((shape.batch, shape.seq), jnp.int32),
            "targets": jax.ShapeDtypeStruct((shape.batch, shape.seq), jnp.int32),
        }
        spec = {"tokens": P(*bspec, None), "targets": P(*bspec, None)}
        if cfg.family == "encdec":
            sds["enc_embeds"] = jax.ShapeDtypeStruct(
                (shape.batch, cfg.encoder_len, cfg.d_model), cfg.compute_dtype
            )
            spec["enc_embeds"] = P(*bspec, None, None)
        return sds, spec

    if shape.kind == "prefill":
        tok = jax.ShapeDtypeStruct((shape.batch, shape.seq), jnp.int32)
        cache = T.cache_defs(cfg, shape.batch, shape.seq)
        return (
            {"tokens": tok, "cache": cache},
            {"tokens": P(*bspec, None),
             "cache": cache_specs(cfg, shape, mesh)},
        )

    # decode: one new token against a cache of length seq
    tok = jax.ShapeDtypeStruct((shape.batch, 1), jnp.int32)
    cache = T.cache_defs(cfg, shape.batch, shape.seq)
    return (
        {"tokens": tok, "cache": cache},
        {"tokens": P(*bspec, None), "cache": cache_specs(cfg, shape, mesh)},
    )


def cache_specs(cfg: ModelConfig, shape: ShapeSpec, mesh) -> dict:
    """Cache shardings. Batch over ('pod','data') when divisible; otherwise
    (long_500k batch=1) shard the cache LENGTH over those axes instead."""
    baxes = batch_axes_for(shape.batch, mesh)
    shard_len = not baxes  # batch unshardable -> spread the 500k cache
    laxes = (
        tuple(n for n in ("pod", "data") if n in mesh.axis_names)
        if shard_len
        else None
    )
    kvspec = lambda: {
        "k": P(None, baxes if baxes else None, laxes, "model", None),
        "v": P(None, baxes if baxes else None, laxes, "model", None),
        "pos": P(),
    }
    ssm_spec = {
        "state": P(None, baxes if baxes else None, "model", None, None),
        "conv": P(None, baxes if baxes else None, None, "model"),
    }
    if cfg.family in ("dense", "moe"):
        return kvspec()
    if cfg.family == "ssm":
        return ssm_spec
    if cfg.family == "hybrid":
        return {"ssm": ssm_spec, "attn": kvspec()}
    if cfg.family == "encdec":
        return {
            "self": kvspec(),
            "cross": {
                "k": P(None, baxes if baxes else None, None, "model", None),
                "v": P(None, baxes if baxes else None, None, "model", None),
            },
        }
    raise ValueError(cfg.family)

"""Recompute roofline terms from saved HLO dumps (no recompilation).

    PYTHONPATH=src python -m repro.launch.reanalyze [--dir experiments/dryrun]

The dry-run saves each cell's optimized HLO as <cell>.hlo.zst; whenever
hlo_analysis improves, this refreshes every JSON in place.
"""
from __future__ import annotations

import argparse
import json
import pathlib

import zstandard

from repro.configs import get_config
from repro.launch import hlo_analysis as H
from repro.launch.shapes import SHAPES

DRY = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def reanalyze(path: pathlib.Path) -> bool:
    hlo_path = path.with_suffix(".hlo.zst")
    if not hlo_path.exists():
        return False
    rec = json.loads(path.read_text())
    if not rec.get("ok"):
        return False
    text = zstandard.ZstdDecompressor().decompress(
        hlo_path.read_bytes()
    ).decode()
    pc = H.program_costs(text)
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    mf = H.model_flops(cfg, shape.kind, shape.batch, shape.seq)
    colls = H.CollectiveStats(dict(pc.coll_bytes_by_op),
                              dict(pc.coll_count_by_op))
    rl = H.roofline_terms(
        {"flops": pc.flops, "bytes accessed": pc.bytes}, colls,
        rec["chips"], mf,
    )
    rec.update(
        hlo_flops=rl.hlo_flops,
        hlo_bytes=rl.hlo_bytes,
        collective_bytes=rl.collective_bytes,
        collectives={"bytes": colls.bytes_by_op, "count": colls.count_by_op},
        model_flops=mf,
        roofline={
            "compute_s": rl.compute_s,
            "memory_s": rl.memory_s,
            "collective_s": rl.collective_s,
            "dominant": rl.dominant,
            "useful_flop_ratio": rl.useful_flop_ratio,
            "roofline_fraction": rl.roofline_fraction,
        },
    )
    path.write_text(json.dumps(rec, indent=2, default=str))
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=str(DRY))
    args = ap.parse_args()
    n = 0
    for p in sorted(pathlib.Path(args.dir).glob("*.json")):
        if reanalyze(p):
            n += 1
            print(f"reanalyzed {p.name}")
    print(f"{n} records refreshed")


if __name__ == "__main__":
    main()

"""Post-compile HLO analysis: trip-count-aware flops/bytes/collective costs.

XLA's ``compiled.cost_analysis()`` counts every computation ONCE — including
``while`` (lax.scan) bodies — so a scanned-L-layer model under-reports
flops/bytes/collectives by ~L x. This module parses the optimized HLO text,
reconstructs the call graph (while/fusion/call/conditional), extracts loop
trip counts from the loop-condition constants, and accumulates per-
instruction costs weighted by execution multiplicity:

  flops             dot ops: 2 * |out| * |contracting| (plus elementwise)
  bytes accessed    sum(operand bytes + output bytes) per executed op
  collective bytes  operand bytes of all-gather / all-reduce /
                    reduce-scatter / all-to-all / collective-permute

Validated against cost_analysis() on loop-free programs and against manual
math on scanned programs (tests/test_hlo_analysis.py).

Contract: `program_costs(hlo_text)` is pure text analysis — it never
executes the program, tolerates unknown ops (counted as zero-cost), and
weights every instruction by the product of the trip counts of the while
loops enclosing it. `xla_cost_analysis(compiled)` is the only function
that touches a live executable, and only to normalize the dict/list API
drift. Hardware model (TPU v5e target): 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# elementwise-ish ops counted as 1 flop per output element
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs",
    "compare", "select", "and", "or", "xor", "floor", "ceil",
    "exponential-minus-one", "log-plus-one", "cosine", "sine", "atan2",
}

# zero-cost meta ops: no HBM traffic (aliases/views/plumbing). XLA's
# bytes-accessed ignores these too; counting them would charge the whole
# loop-carried state tuple once per get-tuple-element line.
_NO_BYTES = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "after-all", "while", "conditional", "call", "custom-call",
    "opt-barrier", "domain", "partition-id", "replica-id", "iota",
}

def xla_cost_analysis(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across the JAX API drift.

    Older jaxlibs return a dict; current ones (>= 0.4.34) return a list with
    one properties dict per executable program. Callers always want the
    entry program's dict — indexing the list with a string key was the
    failure mode this wraps.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# tuple types contain /*index=N*/ comments (with '=') but never nested
# parens, so the tuple branch is "anything but parens"
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^()]*\))|(?:[\w\[\],\s{}]+?))\s+"
    r"([\w\-]+)\((.*)$"
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\([^\n]*\)\s*->")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|condition|body|to_apply)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_dims(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(x) for x in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


def _nelems(shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


def _shape_bytes(type_str: str) -> int:
    return sum(
        _nelems(s) * _DTYPE_BYTES[dt] for dt, s in _shape_dims(type_str)
    )


@dataclasses.dataclass
class _CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict = dataclasses.field(default_factory=dict)
    coll_count: dict = dataclasses.field(default_factory=dict)
    calls: list = dataclasses.field(default_factory=list)  # (name, kind)
    trip_const: int = 1  # max int const (trip count when used as a cond)


def _parse_computations(text: str) -> dict[str, _CompCost]:
    comps: dict[str, _CompCost] = {}
    cur: _CompCost | None = None
    shapes: dict[str, str] = {}
    for line in text.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr and ("{" in line or line.rstrip().endswith("->") or "->" in line):
            cur = _CompCost()
            comps[hdr.group(1)] = cur
            shapes = {}
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op, rest = m.groups()
        shapes[name] = type_str
        out_bytes = _shape_bytes(type_str)
        out_dims = _shape_dims(type_str)
        out_elems = sum(_nelems(s) for _, s in out_dims)

        # integer constants (trip-count fallback for loop conditions)
        if op == "constant" and type_str.strip().rstrip("{}0,: ") in (
            "s32[]", "s64[]", "u32[]", "u64[]"
        ):
            c = re.search(r"constant\((\d+)\)", line)
            if c:
                cur.trip_const = max(cur.trip_const, int(c.group(1)))

        # operand bytes: resolve names defined earlier in this computation
        call_part = rest.split(")", 1)[0]
        operand_names = _OPERAND_RE.findall(call_part)
        in_bytes = sum(
            _shape_bytes(shapes.get(nm, "")) for nm in operand_names
        )
        if op not in _NO_BYTES:
            cur.bytes += out_bytes + in_bytes

        if op == "dot":
            cm = _CONTRACT_RE.search(line)
            contract = 1
            if cm and operand_names:
                lhs_shape = None
                for dt, s in _shape_dims(shapes.get(operand_names[0], "")):
                    lhs_shape = s
                    break
                if lhs_shape and cm.group(1):
                    for di in cm.group(1).split(","):
                        if int(di) < len(lhs_shape):
                            contract *= lhs_shape[int(di)]
            cur.flops += 2.0 * out_elems * contract
        elif op == "convolution":
            # dominated elsewhere; approximate via output x window if present
            cur.flops += 2.0 * out_elems
        elif op in _ELEMENTWISE:
            cur.flops += float(out_elems)

        base = op[:-6] if op.endswith("-start") else op
        if base in COLLECTIVE_OPS and not op.endswith("-done"):
            cur.coll_bytes[base] = cur.coll_bytes.get(base, 0) + in_bytes
            cur.coll_count[base] = cur.coll_count.get(base, 0) + 1

        # authoritative trip count: XLA annotates the while instruction
        ktc = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
        trip_hint = int(ktc.group(1)) if ktc else None
        for cm in _CALLS_RE.finditer(line):
            kind = "body" if "body=" in cm.group(0) else (
                "cond" if "condition=" in cm.group(0) else "call"
            )
            cur.calls.append((cm.group(1), kind, op, trip_hint))
        bm = _BRANCHES_RE.search(line)
        if bm:
            for nm in _OPERAND_RE.findall(bm.group(1)):
                cur.calls.append((nm, "call", op, None))
    return comps


@dataclasses.dataclass
class ProgramCosts:
    flops: float
    bytes: float
    coll_bytes_by_op: dict
    coll_count_by_op: dict

    @property
    def collective_bytes(self) -> float:
        return float(sum(self.coll_bytes_by_op.values()))


def program_costs(text: str, entry: str | None = None) -> ProgramCosts:
    """Walk the call graph from ENTRY accumulating multiplicity-weighted costs."""
    comps = _parse_computations(text)
    em = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
    entry = entry or (em.group(1) if em else next(iter(comps)))

    total = ProgramCosts(0.0, 0.0, {}, {})

    def _sibling_cond(comp: _CompCost, body_name: str) -> str | None:
        # a while instruction contributes both a 'cond' and a 'body' call;
        # pair them by order of appearance
        conds = [n for n, k, *_ in comp.calls if k == "cond"]
        bodies = [n for n, k, *_ in comp.calls if k == "body"]
        if body_name in bodies and len(conds) > bodies.index(body_name):
            return conds[bodies.index(body_name)]
        return conds[0] if conds else None

    def visit(name: str, mult: float, stack: frozenset, count_bytes: bool):
        if name not in comps or name in stack:
            return
        c = comps[name]
        total.flops += mult * c.flops
        if count_bytes:
            # bytes are only HBM-level: instructions INSIDE fusion bodies
            # are registers/VMEM, already accounted at the fusion call site
            total.bytes += mult * c.bytes
        for k, v in c.coll_bytes.items():
            total.coll_bytes_by_op[k] = total.coll_bytes_by_op.get(k, 0) + mult * v
        for k, v in c.coll_count.items():
            total.coll_count_by_op[k] = total.coll_count_by_op.get(k, 0) + mult * v
        stack = stack | {name}
        for callee, kind, op, trip_hint in c.calls:
            child_bytes = count_bytes and op != "fusion"
            if kind in ("body", "cond"):
                trip = trip_hint
                if trip is None:
                    # fallback: constants in the loop-condition computation
                    cond_name = (
                        callee if kind == "cond" else _sibling_cond(c, callee)
                    )
                    trip = (
                        comps[cond_name].trip_const
                        if cond_name in comps else 1
                    )
                visit(callee, mult * max(trip, 1), stack, child_bytes)
            else:
                visit(callee, mult, stack, child_bytes)

    visit(entry, 1.0, frozenset(), True)
    return total


# ---------------------------------------------------------------------------
# legacy simple interface (kept for callers that want raw per-text stats)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: dict[str, float]
    count_by_op: dict[str, float]

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_op.values()))


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Multiplicity-aware collective stats for the whole program."""
    pc = program_costs(hlo_text)
    return CollectiveStats(dict(pc.coll_bytes_by_op), dict(pc.coll_count_by_op))


def compiled_collective_costs(compiled, iterations: int = 1) -> dict:
    """Per-iteration collective traffic MEASURED from a compiled executable.

    Parses the optimized (post-SPMD-partitioning) HLO of ``compiled`` —
    e.g. a ``jit(shard_map(...)).lower(...).compile()`` of one sharded
    solver chunk — and divides the trip-count-weighted collective bytes by
    ``iterations`` (the scan length the program executes). All figures are
    PER DEVICE: a ``collective-permute`` is charged its operand bytes on
    each sender, matching the per-node accounting convention of the
    modeled ``doubles_received`` columns.

    Returns ``{"bytes_per_iter", "count_per_iter", "bytes_by_op",
    "count_by_op"}`` (the by-op dicts are also per iteration).
    """
    stats = collective_stats(compiled.as_text())
    it = max(int(iterations), 1)
    return {
        "bytes_per_iter": stats.total_bytes / it,
        "count_per_iter": float(sum(stats.count_by_op.values())) / it,
        "bytes_by_op": {k: v / it for k, v in stats.bytes_by_op.items()},
        "count_by_op": {k: v / it for k, v in stats.count_by_op.items()},
    }


@dataclasses.dataclass
class Roofline:
    """All terms are SECONDS for one step of the lowered program."""

    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float  # per device
    hlo_bytes: float  # per device
    collective_bytes: float  # per device
    model_flops: float  # global useful flops (6ND / 2ND)
    chips: int

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flop_ratio(self) -> float:
        tot = self.hlo_flops * self.chips
        return self.model_flops / tot if tot else float("nan")

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / bound time — the MFU analogue derivable
        without wall clocks: (model_flops/chips/peak) / max(terms)."""
        ideal = self.model_flops / self.chips / PEAK_FLOPS
        return ideal / self.bound_s if self.bound_s else float("nan")


def roofline_terms(
    cost: dict, colls: CollectiveStats, chips: int, model_flops: float,
    links_per_chip: float = 1.0,
) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cb = float(colls.total_bytes)
    return Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=cb / (ICI_BW * links_per_chip),
        hlo_flops=flops,
        hlo_bytes=byts,
        collective_bytes=cb,
        model_flops=model_flops,
        chips=chips,
    )


def model_flops(cfg, kind: str, batch: int, seq: int) -> float:
    """Useful FLOPs: 6*N*D train, 2*N*D inference (+ attention terms)."""
    n_active = cfg.active_param_count()
    L = cfg.n_layers
    H, hd = cfg.n_heads, cfg.head_dim
    if kind == "train":
        tokens = batch * seq
        # causal attn fwd ~ 2 * S^2/2 * H*hd * 2(qk+av); x3 with backward
        attn = 2.0 * 3.0 * L * batch * seq * seq * H * hd
        return 6.0 * n_active * tokens + attn
    if kind == "prefill":
        tokens = batch * seq
        attn = 2.0 * L * batch * seq * seq * H * hd
        return 2.0 * n_active * tokens + attn
    # decode: one token, attends over `seq` cache entries
    attn = 4.0 * L * batch * seq * H * hd
    return 2.0 * n_active * batch + attn

"""Production serving launcher: batched prefill + continuous decode.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b \
        --requests 8 --prompt-len 64 --tokens 32        # CPU-runnable

Serves a (reduced, unless --full) model: a request queue is prefillled in
batches, then decoded token-by-token with KV/SSM caches. On a real pod, add
--mesh single to shard with the production layout.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ALIASES, get_config, get_reduced
from repro.models import transformer as T
from repro.models.params import tree_materialize


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b", choices=list(ALIASES))
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    params = tree_materialize(T.model_defs(cfg), jax.random.PRNGKey(0),
                              cfg.param_dtype)
    prefill = jax.jit(lambda p, t, c: T.decode_step(cfg, p, t, c))
    decode = jax.jit(lambda p, t, c: T.decode_step(cfg, p, t, c))
    max_len = args.prompt_len + args.tokens

    key = jax.random.PRNGKey(args.seed)
    done_tokens = 0
    t_start = time.time()
    for batch_start in range(0, args.requests, args.batch):
        bsz = min(args.batch, args.requests - batch_start)
        key, k1 = jax.random.split(key)
        prompts = jax.random.randint(k1, (bsz, args.prompt_len), 0,
                                     cfg.vocab_size)
        cache = T.init_cache(cfg, bsz, max_len)
        if cfg.family == "encdec":
            enc = jax.random.normal(
                jax.random.fold_in(key, 7), (bsz, cfg.encoder_len, cfg.d_model)
            )
            cache["cross"] = T.encode_cross_cache(cfg, params, enc, bsz)
        t0 = time.time()
        cache, logits = prefill(params, prompts, cache)
        tok = jnp.argmax(logits, -1)[:, None]
        for _ in range(args.tokens):
            cache, logits = decode(params, tok, cache)
            if args.temperature > 0:
                key, k2 = jax.random.split(key)
                tok = jax.random.categorical(
                    k2, logits / args.temperature
                )[:, None]
            else:
                tok = jnp.argmax(logits, -1)[:, None]
        jax.block_until_ready(tok)
        dt = time.time() - t0
        done_tokens += bsz * args.tokens
        print(f"batch {batch_start // args.batch}: {bsz} reqs, "
              f"{bsz * args.tokens / dt:.1f} tok/s decode", flush=True)
    print(f"served {args.requests} requests, "
          f"{done_tokens / (time.time() - t_start):.1f} tok/s overall")


if __name__ == "__main__":
    main()

"""Persistent XLA compilation cache: sweeps pay for each compile once, ever.

The runner caches in ``core.runner_cache`` already amortize compilation
*within* a process, but every fresh process (a new benchmark run, a pytest
tier, a CI job) still recompiles every chunked scan from scratch — and on
CPU those compiles dominate small-problem wall time. JAX ships a
content-addressed on-disk cache (``jax_compilation_cache_dir``) that
serializes compiled executables keyed by HLO + compile options + backend;
this module turns it on with repo-appropriate defaults.

``enable_persistent_cache()`` is called from ``repro.core.__init__`` so
every entrypoint (tests, benchmarks, notebooks) gets it without
ceremony. Policy:

* Default location is ``<repo root>/.jax_compile_cache`` (git-ignored)
  when the source tree is recognizable, else ``~/.cache/repro_jax``.
* ``REPRO_COMPILE_CACHE_DIR`` overrides the location.
* ``REPRO_NO_COMPILE_CACHE`` (any non-empty value) disables the cache —
  the escape hatch for cold-start benchmarks and cache-behavior tests.
* Thresholds are zeroed (``min_compile_time_secs``/``min_entry_size``)
  because this repo's compiles are many-small: the default 1 s floor
  would exclude nearly everything we want cached.

Enabling is idempotent and silent; it never raises (an unwritable cache
dir degrades to a warning from XLA at worst, not a crash).
"""
from __future__ import annotations

import os
from pathlib import Path

_ENABLED: str | None = None  # cache dir once enabled, for introspection


def default_cache_dir() -> Path:
    """Repo-local ``.jax_compile_cache`` if we can find the repo root.

    Walks up from this file looking for ``pyproject.toml``; falls back to
    ``~/.cache/repro_jax`` for installed-package deployments.
    """
    here = Path(__file__).resolve()
    for parent in here.parents:
        if (parent / "pyproject.toml").exists():
            return parent / ".jax_compile_cache"
    return Path.home() / ".cache" / "repro_jax"


def enable_persistent_cache() -> str | None:
    """Point JAX at the on-disk compilation cache. Returns the dir, or None.

    Safe to call any number of times and before/after the first JAX
    computation (config updates apply to subsequent compiles). Honors
    ``REPRO_NO_COMPILE_CACHE`` / ``REPRO_COMPILE_CACHE_DIR``.
    """
    global _ENABLED
    if os.environ.get("REPRO_NO_COMPILE_CACHE"):
        return None
    if _ENABLED is not None:
        return _ENABLED
    cache_dir = os.environ.get("REPRO_COMPILE_CACHE_DIR") or str(
        default_cache_dir()
    )
    try:
        import jax

        jax.config.update("jax_compilation_cache_dir", cache_dir)
        # This repo compiles many small programs; the stock 1 s /
        # non-zero-size floors would skip nearly all of them.
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
    except Exception:  # pragma: no cover - never block import on cache setup
        return None
    _ENABLED = cache_dir
    return cache_dir


def enabled_dir() -> str | None:
    """The active cache directory, or None if disabled/not yet enabled."""
    return _ENABLED

"""Paper Table 1 (communication column) + Section 5.1 cost model validation.

Runs the ACTUAL DSBA-s relay simulator and checks measured DOUBLEs per node
per iteration against the closed-form O(N rho d) model and against the dense
O(Delta(G) d) baselines; prints the crossover ratios the paper claims.
"""
from __future__ import annotations

import numpy as np

from repro.core import mixing
from repro.core.dsba import DSBAConfig, draw_indices
from repro.core.operators import OperatorSpec
from repro.core.sparse_comm import (
    dense_doubles_per_iter, run_sparse, sparse_doubles_per_iter,
)
from repro.data.synthetic import DATASET_PRESETS, make_regression


def measure(n=8, q=10, d=800, k=12, steps=25, seed=0):
    data = make_regression(n, q, d, k=k, seed=seed)
    graph = mixing.erdos_renyi_graph(n, 0.4, seed=2)
    w = mixing.laplacian_mixing(graph)
    cfg = DSBAConfig(OperatorSpec("ridge"), alpha=0.3, lam=1e-3)
    idx = draw_indices(steps, n, q, seed=3)
    res = run_sparse(cfg, data, graph, w, steps, idx)
    steady = np.diff(res.doubles_received, axis=0)[-8:]
    return data, graph, steady, res


def main():
    data, graph, steady, res = measure()
    model = sparse_doubles_per_iter(data.n_nodes, data.k, 0)
    dense = dense_doubles_per_iter(graph, data.d)
    print("measured steady-state DOUBLEs/node/iter:",
          sorted(set(steady.reshape(-1).tolist())))
    print("closed-form (N-1)*k                     :", model)
    assert (steady == model).all()
    print("dense per-iter (deg*d) min..max          :",
          int(dense.min()), "..", int(dense.max()))
    print(f"sparse/dense ratio: {model / dense.max():.4f} "
          f"(= O(N rho d) / O(Delta d))")
    print(f"protocol reconstruction max error: {res.recon_max_err:.2e}")

    print("\nprojected per-iteration DOUBLEs at paper-scale datasets "
          "(N=10, ER(0.4) E[deg]~3.6):")
    print(f"{'dataset':>10} {'d':>9} {'k':>5} {'DSBA-s':>10} {'dense':>12} {'ratio':>8}")
    for name in ("news20", "rcv1", "sector"):
        p = DATASET_PRESETS[name]
        s = sparse_doubles_per_iter(10, p["k"], 0)
        dd = 4 * p["d"]  # deg ~ 4
        print(f"{name:>10} {p['d']:>9} {p['k']:>5} {s:>10,} {dd:>12,} "
              f"{dd / s:>7.0f}x")


if __name__ == "__main__":
    main()

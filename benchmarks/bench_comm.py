"""Paper Table 1 (communication column) + Section 5.1 cost model validation.

Runs the ACTUAL DSBA-s relay via ``solve(..., comm="sparse")`` and checks
the ``SolveResult.doubles_received`` accounting against the closed-form
O(N rho d) model and against the dense O(Delta(G) d) baselines; prints the
crossover ratios the paper claims.

Also sweeps ring topologies at N in {8, 16, 32} — the regime where DSA's
O(N) relay delays and Lan et al.'s communication-complexity analysis bite,
and where the pre-vectorization per-observer Python loop was intractable.

``sharded_scaling_sweep`` is the ``comm="sharded"`` half (bench-group
``comm-sharded``): for N in {8, 16, 32, 64} simulated nodes it times the
single-device dense matmul backend against the node-per-device shard_map
backend and reports the HLO-measured collective bytes — the matmul-vs-
ppermute crossover table. Each N runs in a CHILD process because
``--xla_force_host_platform_device_count`` must be set before jax
initializes (``--sharded-child`` below).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import mixing
from repro.core.dsba import draw_indices
from repro.core.solvers import make_problem, solve
from repro.core.sparse_comm import (
    dense_doubles_per_iter, sparse_doubles_per_iter,
)
from repro.data.synthetic import DATASET_PRESETS, make_regression


def measure(n=8, q=10, d=800, k=12, steps=25, seed=0):
    data = make_regression(n, q, d, k=k, seed=seed)
    graph = mixing.erdos_renyi_graph(n, 0.4, seed=2)
    problem = make_problem("ridge", data, graph, lam=1e-3)
    idx = draw_indices(steps, n, q, seed=3)
    res = solve(problem, "dsba", comm="sparse", steps=steps, record_every=1,
                indices=idx, alpha=0.3, comm_options={"verify": True})
    steady = np.diff(res.doubles_received, axis=0)[-8:]
    return data, graph, steady, res


def warm_sweep_demo(alphas=(0.3, 0.45, 0.6), n=8, q=10, d=800, k=12,
                    steps=25, seed=0):
    """Per-call relay latency across a step-size sweep on one problem.

    The first call compiles the jitted relay scan; later alphas are traced
    arguments into the cached executable (core.runner_cache), so the sweep
    runs at solver speed. Returns the per-call wall times in sweep order.
    """
    data = make_regression(n, q, d, k=k, seed=seed)
    graph = mixing.erdos_renyi_graph(n, 0.4, seed=2)
    problem = make_problem("ridge", data, graph, lam=1e-3)
    idx = draw_indices(steps, n, q, seed=3)
    times = []
    for a in alphas:
        t0 = time.perf_counter()
        solve(problem, "dsba", comm="sparse", steps=steps,
              record_every=steps, indices=idx, alpha=a)
        times.append(time.perf_counter() - t0)
    return times


def topology_sweep(sizes=(8, 16, 32), q=10, d=256, k=8, seed=0):
    """Ring-graph sweep: steady-state doubles must match the closed form.

    Rings maximize the diameter (N/2 relay hops), so this exercises the
    deepest reconstruction recursion the protocol supports. Runs long enough
    past warm-up (2*diam + 40 iterations) that steady state is unambiguous.
    """
    print(f"\nring-topology sweep (q={q}, d={d}, k={k}):")
    print(f"{'N':>4} {'diam':>5} {'steps':>6} {'doubles/node/iter':>18} "
          f"{'model':>6} {'dense':>8} {'wall':>7} {'ms/iter':>8}")
    for n in sizes:
        graph = mixing.ring_graph(n)
        data = make_regression(n, q, d, k=k, seed=seed)
        problem = make_problem("ridge", data, graph, lam=1e-3)
        steps = 2 * graph.diameter + 40
        extra = 600
        idx = draw_indices(steps + extra, n, q, seed=3)
        t0 = time.perf_counter()
        res = solve(problem, "dsba", comm="sparse", steps=steps,
                    record_every=1, indices=idx, alpha=0.3)
        wall = time.perf_counter() - t0
        # wall above is compile-dominated (one jitted scan per call); the
        # marginal cost of `extra` more iterations isolates the engine speed
        t0 = time.perf_counter()
        solve(problem, "dsba", comm="sparse", steps=steps + extra,
              record_every=steps + extra, indices=idx, alpha=0.3)
        ms_iter = 1e3 * (time.perf_counter() - t0 - wall) / extra
        steady = np.diff(res.doubles_received, axis=0)[graph.diameter + 2 :]
        measured = sorted(set(steady.reshape(-1).tolist()))
        model = sparse_doubles_per_iter(n, k, 0)
        assert measured == [model], (n, measured, model)
        dense = int(dense_doubles_per_iter(graph, d).max())
        print(f"{n:>4} {graph.diameter:>5} {steps:>6} {str(measured):>18} "
              f"{model:>6} {dense:>8} {wall:>6.2f}s "
              f"{'<noise' if ms_iter <= 0 else f'{ms_iter:.2f}':>8}")
    print("(wall includes the one-time XLA compile of the jitted scan; "
          "ms/iter is the marginal cost of 600 extra iterations, '<noise' "
          "when it is below compile-time variance)")


def _sharded_child(n: int, q=10, d=64, k=8, steps=60, seed=0) -> None:
    """Measure one N inside a forced-device process; print a JSON line.

    Warm per-iteration wall time for both backends (second solve() call —
    the compiled runner is cached), plus the sharded run's HLO-measured
    collective traffic and the modeled dense exchange for the same graph.
    """
    graph = mixing.ring_graph(n)
    data = make_regression(n, q, d, k=k, seed=seed)
    problem = make_problem("ridge", data, graph, lam=1e-3)
    idx = draw_indices(steps, n, q, seed=3)

    def one(comm, alpha):
        return solve(problem, "dsba", comm=comm, steps=steps,
                     record_every=steps, indices=idx, alpha=alpha)

    out = {"n": n, "d": d, "steps": steps}
    for comm in ("dense", "sharded"):
        one(comm, 0.3)  # compile
        t0 = time.perf_counter()
        res = one(comm, 0.31)
        out[f"{comm}_us_iter"] = (time.perf_counter() - t0) / steps * 1e6
    cc = res.extras["collectives"]
    out["bytes_per_iter"] = cc["bytes_per_iter"]
    out["permutes_per_iter"] = cc["count_per_iter"]
    out["measured_bytes_total"] = float(
        np.asarray(res.measured_collective_bytes)[-1]
    )
    out["modeled_dense_doubles_iter"] = int(
        dense_doubles_per_iter(graph, d).max()
    )
    print("SHARDED_CHILD " + json.dumps(out))


def sharded_scaling_sweep(sizes=(8, 16, 32, 64)) -> list[dict]:
    """Spawn one forced-device child per N; return the measured records."""
    records = []
    for n in sizes:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n}"
        ).strip()
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_comm",
             "--sharded-child", str(n)],
            env=env, capture_output=True, text=True, timeout=1800,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        if proc.returncode != 0:
            raise RuntimeError(
                f"sharded child N={n} failed:\n{proc.stdout}\n{proc.stderr}"
            )
        line = [ln for ln in proc.stdout.splitlines()
                if ln.startswith("SHARDED_CHILD ")][-1]
        records.append(json.loads(line.split(" ", 1)[1]))
    return records


def print_sharded_table(records) -> None:
    """The bench-group ``comm-sharded`` headline: matmul vs ppermute."""
    print("\nsharded-vs-dense scaling (ring, warm us/iter, one node per "
          "forced host device):")
    print(f"{'N':>4} {'dense':>9} {'sharded':>9} {'ratio':>7} "
          f"{'KB/iter':>8} {'permutes':>9}")
    for r in records:
        ratio = r["sharded_us_iter"] / r["dense_us_iter"]
        print(f"{r['n']:>4} {r['dense_us_iter']:>8.0f} "
              f"{r['sharded_us_iter']:>8.0f} {ratio:>6.1f}x "
              f"{r['bytes_per_iter'] / 1024:>7.2f} "
              f"{r['permutes_per_iter']:>9.0f}")
    print("(dense = one-device matmul mixing; sharded = per-edge "
          "collective-permute on the node mesh. KB/iter is HLO-measured "
          "per-device collective traffic, not a model.)")


def main():
    if "--sharded-child" in sys.argv:
        _sharded_child(int(sys.argv[sys.argv.index("--sharded-child") + 1]))
        return
    data, graph, steady, res = measure()
    model = sparse_doubles_per_iter(data.n_nodes, data.k, 0)
    dense = dense_doubles_per_iter(graph, data.d)
    print("measured steady-state DOUBLEs/node/iter:",
          sorted(set(steady.reshape(-1).tolist())))
    print("closed-form (N-1)*k                     :", model)
    assert (steady == model).all()
    print("dense per-iter (deg*d) min..max          :",
          int(dense.min()), "..", int(dense.max()))
    print(f"sparse/dense ratio: {model / dense.max():.4f} "
          f"(= O(N rho d) / O(Delta d))")
    print("protocol reconstruction max error: "
          f"{res.extras['recon_max_err']:.2e}")

    times = warm_sweep_demo()
    warm = min(times[1:])
    print(f"\nrelay sweep latency: cold {times[0]:.2f}s (compiles the scan), "
          f"then {warm * 1e3:.0f}ms/alpha warm "
          f"({times[0] / warm:.0f}x — compiled-runner cache)")

    print("\nprojected per-iteration DOUBLEs at paper-scale datasets "
          "(N=10, ER(0.4) E[deg]~3.6):")
    print(f"{'dataset':>10} {'d':>9} {'k':>5} {'DSBA-s':>10} "
          f"{'dense':>12} {'ratio':>8}")
    for name in ("news20", "rcv1", "sector"):
        p = DATASET_PRESETS[name]
        s = sparse_doubles_per_iter(10, p["k"], 0)
        dd = 4 * p["d"]  # deg ~ 4
        print(f"{name:>10} {p['d']:>9} {p['k']:>5} {s:>10,} {dd:>12,} "
              f"{dd / s:>7.0f}x")

    topology_sweep()
    print_sharded_table(sharded_scaling_sweep())


if __name__ == "__main__":
    main()

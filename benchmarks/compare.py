"""Benchmark regression gate.

    python -m benchmarks.compare BASELINE.json NEW.json [--max-ratio 1.5]

Compares two ``benchmarks.run --json`` payloads entry-by-entry and exits
non-zero if any shared entry's us_per_call regressed by more than
``--max-ratio`` x the committed baseline (CI runs this against the
repo-root ``BENCH_kernels.json``). New entries (no baseline yet) and
removed entries are reported but never fail the gate — refresh the
baseline in the same PR that adds or retires a benchmark.

Entries listed under a payload's ``"informational"`` key (union of both
files) are reported with their ratio but NEVER gated: the mesh-backend
``comm_sharded_*`` family mixes single-device modeled timings with
multi-device measured collectives, where a ratio is a property of the
machine's device simulation, not a regression.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys


def load(path: str) -> tuple[dict[str, float], set[str]]:
    payload = json.loads(pathlib.Path(path).read_text())
    if payload.get("schema") != 1:
        raise SystemExit(f"{path}: unknown benchmark schema "
                         f"{payload.get('schema')!r}")
    entries = {k: float(v) for k, v in payload["entries"].items()}
    return entries, set(payload.get("informational", ()))


def compare(base: dict[str, float], new: dict[str, float],
            max_ratio: float, informational: set[str] = frozenset()) -> list[str]:
    """Entry-by-entry report; returns the list of gate failures.

    Only entries present in BOTH payloads are gated. Baseline-missing
    entries print as ``NEW`` (informational) so a PR introducing a
    benchmark — e.g. the ``sweep_*`` family — passes before its baseline
    is committed; entries only in the baseline print as ``REMOVED``.
    Entries in ``informational`` print as ``INFO`` and never gate.
    """
    failures = []
    fresh = removed = 0
    for name in sorted(set(base) | set(new)):
        if name not in base:
            print(f"NEW      {name}: {new[name]:.1f} us (no baseline; "
                  "informational — refresh the baseline to gate it)")
            fresh += 1
            continue
        if name not in new:
            print(f"REMOVED  {name}: baseline {base[name]:.1f} us")
            removed += 1
            continue
        ratio = new[name] / base[name] if base[name] else float("inf")
        if name in informational:
            print(f"INFO     {name}: {base[name]:.1f} -> {new[name]:.1f} us "
                  f"({ratio:.2f}x; informational, never gated)")
            continue
        status = "FAIL" if ratio > max_ratio else "ok"
        print(f"{status:8} {name}: {base[name]:.1f} -> {new[name]:.1f} us "
              f"({ratio:.2f}x)")
        if ratio > max_ratio:
            failures.append(
                f"{name}: {ratio:.2f}x > {max_ratio}x "
                f"({base[name]:.1f} -> {new[name]:.1f} us)"
            )
    if fresh or removed:
        print(f"({fresh} new / {removed} removed entries — never gated)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("new")
    ap.add_argument("--max-ratio", type=float, default=1.5,
                    help="fail when new/baseline exceeds this (default 1.5)")
    args = ap.parse_args()
    base, info_b = load(args.baseline)
    new, info_n = load(args.new)
    failures = compare(base, new, args.max_ratio, info_b | info_n)
    if failures:
        print("\nbenchmark regressions:")
        for f in failures:
            print(f"  {f}")
        return 1
    print("\nno benchmark regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())

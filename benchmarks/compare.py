"""Benchmark regression gate.

    python -m benchmarks.compare BASELINE.json NEW.json [--max-ratio 1.5]

Compares two ``benchmarks.run --json`` payloads entry-by-entry and exits
non-zero if any shared entry's us_per_call regressed by more than
``--max-ratio`` x the committed baseline (CI runs this against the
repo-root ``BENCH_kernels.json``). New entries (no baseline yet) and
removed entries are reported but never fail the gate — refresh the
baseline in the same PR that adds or retires a benchmark.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys


def load(path: str) -> dict[str, float]:
    payload = json.loads(pathlib.Path(path).read_text())
    if payload.get("schema") != 1:
        raise SystemExit(f"{path}: unknown benchmark schema "
                         f"{payload.get('schema')!r}")
    return {k: float(v) for k, v in payload["entries"].items()}


def compare(base: dict[str, float], new: dict[str, float],
            max_ratio: float) -> list[str]:
    failures = []
    for name in sorted(set(base) | set(new)):
        if name not in base:
            print(f"NEW      {name}: {new[name]:.1f} us (no baseline)")
            continue
        if name not in new:
            print(f"REMOVED  {name}: baseline {base[name]:.1f} us")
            continue
        ratio = new[name] / base[name] if base[name] else float("inf")
        status = "FAIL" if ratio > max_ratio else "ok"
        print(f"{status:8} {name}: {base[name]:.1f} -> {new[name]:.1f} us "
              f"({ratio:.2f}x)")
        if ratio > max_ratio:
            failures.append(
                f"{name}: {ratio:.2f}x > {max_ratio}x "
                f"({base[name]:.1f} -> {new[name]:.1f} us)"
            )
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("new")
    ap.add_argument("--max-ratio", type=float, default=1.5,
                    help="fail when new/baseline exceeds this (default 1.5)")
    args = ap.parse_args()
    failures = compare(load(args.baseline), load(args.new), args.max_ratio)
    if failures:
        print("\nbenchmark regressions:")
        for f in failures:
            print(f"  {f}")
        return 1
    print("\nno benchmark regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())

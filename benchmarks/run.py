"""Benchmark harness — one entry per paper table/figure + system benches.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--bench-group G]

Prints `name,us_per_call,derived` CSV rows. Convergence/communication
benchmarks reproduce the paper's experiments (Figures 1-3, Table 1); kernel
and step benches time this framework's hot paths on CPU (reference path —
TPU wall-clock is out of scope for this container; see EXPERIMENTS.md
§Roofline for the TPU performance model). The `*_bwd` entries time the
training-path gradients (jax.grad through the same reference paths as
their forward twins).

--bench-group picks which families run (docs/benchmarks.md):
  kernels      dsba step + kernel fwd/bwd + gossip step + the sweep-engine
               entries (`sweep_*`) — the CI gate grid
  sweep        just the sweep-engine entries (compiled-runner cache warm
               latency + batched solve_many)
  convergence  solve() entrypoint timings (`solve_*`) + the paper's
               convergence/communication tables
  serve        continuous-batching decode throughput at batch 1/64/512
               (`serve_*`, informational — container-timed)
  faults       link-fault degradation curves on the dense backend
               (`faults_*`, informational — the curve lives in the
               derived column)
  all          everything (default)
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

jax.config.update("jax_enable_x64", True)


def timeit(fn, *args, n=10, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6  # us


def bench_dsba_step(rows):
    from repro.core import mixing
    from repro.core.dsba import DSBAConfig, dsba_step, draw_indices, init_state
    from repro.core.operators import OperatorSpec
    from repro.core.mixing import w_tilde
    from repro.data.synthetic import make_regression
    import jax.numpy as jnp

    for d, k in ((2_000, 40), (50_000, 160)):
        data = make_regression(10, 100, d, k=k, seed=0)
        g = mixing.erdos_renyi_graph(10, 0.4, seed=1)
        w = jnp.asarray(mixing.laplacian_mixing(g))
        wt = jnp.asarray(w_tilde(np.asarray(w)))
        cfg = DSBAConfig(OperatorSpec("ridge"), 0.5, 1e-3)
        st = init_state(cfg, data, jnp.zeros((10, d)))
        idx = jnp.asarray(draw_indices(1, 10, 100)[0])
        f = jax.jit(lambda s, i: dsba_step(
            cfg, w, wt, jnp.asarray(data.idx), jnp.asarray(data.val),
            jnp.asarray(data.y), s, i))
        us = timeit(f, st, idx)
        rows.append((f"dsba_step_d{d}", us, f"N=10 q=100 k={k}"))


def bench_kernels(rows, fast):
    from repro.kernels import ref as R
    import jax.numpy as jnp

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, Hq, Hkv, S, D = 1, 8, 2, 1024 if fast else 2048, 64
    q = jax.random.normal(ks[0], (B, Hq, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
    f = jax.jit(lambda q, k, v: R.attention_ref(q, k, v, causal=True))
    us = timeit(f, q, k, v, n=3)
    flops = 4 * B * Hq * S * S * D / 2
    rows.append((f"attention_ref_S{S}", us, f"{flops / us / 1e3:.1f} GFLOP/s"))

    # training path: fwd + bwd through the same reference attention (the
    # gradient oracle the blocked Pallas bwd kernels are parity-checked
    # against; TPU kernel wall-clock is out of scope on CPU)
    fb = jax.jit(jax.grad(
        lambda q, k, v: jnp.sum(R.attention_ref(q, k, v, causal=True)),
        argnums=(0, 1, 2),  # dq AND dk/dv — argnums=0 would let XLA prune them
    ))
    us = timeit(fb, q, k, v, n=3)
    rows.append((f"attention_bwd_S{S}", us, f"{3 * flops / us / 1e3:.1f} GFLOP/s"))

    from repro.models.ssm import _ssd_chunked
    Bz, Ssz, nh, hd, ds = 1, 1024, 8, 64, 64
    xh = jax.random.normal(ks[0], (Bz, Ssz, nh, hd))
    dt = jax.random.uniform(ks[1], (Bz, Ssz, nh), minval=0.1, maxval=1.0)
    al = -dt * 0.1
    Bc = jax.random.normal(ks[2], (Bz, Ssz, ds))
    f = jax.jit(lambda *a: _ssd_chunked(*a, 256)[0])
    us = timeit(f, xh, dt, al, Bc, Bc, n=3)
    rows.append((f"ssd_chunked_S{Ssz}", us, f"nh={nh} ds={ds}"))

    fb = jax.jit(jax.grad(
        lambda xh, Bc: jnp.sum(_ssd_chunked(xh, dt, al, Bc, Bc, 256)[0]),
        argnums=(0, 1),
    ))
    us = timeit(fb, xh, Bc, n=3)
    rows.append((f"ssd_chunked_bwd_S{Ssz}", us, f"nh={nh} ds={ds}"))


def bench_gossip(rows):
    import dataclasses
    from repro.configs import get_reduced
    from repro.core.gossip import (GossipConfig, init_gossip_state,
                                   make_gossip_train_step)
    from repro.optim.adam import AdamConfig
    from repro.train.step import TrainConfig

    cfg = dataclasses.replace(get_reduced("minitron_8b"), n_layers=2)
    tc = TrainConfig(optimizer=AdamConfig())
    for mode, comp in (("allreduce", "none"), ("dsba", "none"),
                       ("dsgd", "topk")):
        gc = GossipConfig(n_pods=4, mode=mode, compression=comp,
                          topk_ratio=0.05)
        st = init_gossip_state(cfg, tc, gc, jax.random.PRNGKey(0))
        step = jax.jit(make_gossip_train_step(None, cfg, tc, gc))
        key = jax.random.PRNGKey(1)
        toks = jax.random.randint(key, (4, 2, 65), 0, cfg.vocab_size)
        batch = {"tokens": toks[..., :-1], "targets": toks[..., 1:]}
        us = timeit(step, st, batch, n=3)
        rows.append((f"gossip_step_{mode}_{comp}", us, "pods=4 tiny-lm"))


def bench_convergence_tables(rows, fast):
    from benchmarks import bench_convergence as BC

    passes = 15 if fast else 120
    tasks = ("ridge",) if fast else ("ridge", "logistic", "auc", "bilinear")
    for task in tasks:
        t0 = time.perf_counter()
        md = BC.render(task, passes)
        BC.OUT.mkdir(exist_ok=True, parents=True)
        (BC.OUT / f"convergence_{task}.md").write_text(md)
        dt = (time.perf_counter() - t0) * 1e6
        final = [ln for ln in md.splitlines() if ln.startswith("| ")][-1]
        rows.append((f"paper_fig_{task}", dt, final.replace("|", "/").strip()))

    # ISSUE 7 acceptance: mudag's dense rounds to 1e-9 <= half of DSA's on
    # the paper-shaped ridge problem (informational entry: it reports a
    # round-count ratio, not a latency to gate on)
    t0 = time.perf_counter()
    acc = BC.accel_rounds_to_target()
    dt = (time.perf_counter() - t0) * 1e6
    ratio = acc["ratio"]
    rows.append((
        "paper_accel_ridge", dt,
        f"mudag={acc['mudag_rounds']} dsa={acc['dsa_rounds']} rounds to "
        f"1e-9; ratio={ratio:.2f} (acceptance <= 0.5)"
        if ratio is not None else "target never reached",
    ))


def bench_comm_table(rows):
    from repro.core.sparse_comm import sparse_doubles_per_iter
    from benchmarks import bench_comm as BCm

    t0 = time.perf_counter()
    data, graph, steady, res = BCm.measure()
    dt = (time.perf_counter() - t0) * 1e6
    model = sparse_doubles_per_iter(data.n_nodes, data.k, 0)
    err = res.extras["recon_max_err"]
    ok = (steady == model).all() and err < 1e-9
    rows.append((
        "paper_table1_comm", dt,
        f"measured==model({model})={bool(ok)} recon_err={err:.1e}",
    ))


def bench_sweep(rows):
    """The sweep-engine entries CI gates (ISSUE 5 acceptance criteria).

    ``sweep_solve_second_call`` / ``sweep_solve_sparse_second_call`` time a
    WARM ``solve()`` — same problem shape, a fresh hyperparameter value
    every call, served by the compiled-runner cache. The derived column
    carries the cold-call latency and the cold/warm ratio (the >= 10x
    claim). ``sweep_solve_many_grid8`` times an 8-point alpha grid as one
    vmapped ``solve_many`` against 8 warm sequential calls. A retrace
    regression (hp values accidentally baked back into the compiled scan)
    pushes warm latency back to cold and trips the 1.5x gate immediately.
    """
    from repro.core import mixing
    from repro.core.dsba import draw_indices
    from repro.core.solvers import (
        clear_runner_caches, make_problem, solve, solve_many,
    )
    from repro.data.synthetic import make_regression

    n, q, d, k, steps = 8, 20, 200, 8, 200
    data = make_regression(n, q, d, k=k, seed=0)
    graph = mixing.erdos_renyi_graph(n, 0.4, seed=1)
    problem = make_problem("ridge", data, graph, lam=1e-3)
    idx = draw_indices(steps, n, q, seed=3)
    # a fresh value per call: warm latency must not depend on value reuse
    alphas = [0.30 + 0.01 * i for i in range(64)]

    def one(comm, alpha):
        return solve(problem, "dsba", comm=comm, steps=steps,
                     record_every=steps, indices=idx, alpha=alpha)

    for comm, name in (("dense", "sweep_solve_second_call"),
                       ("sparse", "sweep_solve_sparse_second_call")):
        clear_runner_caches()
        t0 = time.perf_counter()
        one(comm, alphas.pop())
        cold = (time.perf_counter() - t0) * 1e6
        reps = 5
        t0 = time.perf_counter()
        for _ in range(reps):
            one(comm, alphas.pop())
        warm = (time.perf_counter() - t0) / reps * 1e6
        rows.append((
            name, warm,
            f"cold={cold / 1e3:.0f}ms speedup={cold / warm:.0f}x",
        ))

    grid = [{"alpha": alphas.pop()} for _ in range(8)]
    for _ in range(2):  # first batched call compiles the vmapped runner
        solve_many(problem, "dsba", steps=steps, record_every=steps,
                   indices=idx, grid=grid)
    us = timeit(
        lambda: solve_many(problem, "dsba", steps=steps, record_every=steps,
                           indices=idx, grid=grid),
        n=3, warmup=0,
    )
    t0 = time.perf_counter()
    for g in grid:
        one("dense", g["alpha"])
    seq = (time.perf_counter() - t0) * 1e6
    rows.append((
        "sweep_solve_many_grid8", us,
        f"{us / 8:.0f}us/point vs {seq / 8:.0f}us/point sequential",
    ))


def bench_solvers(rows):
    """Time the registry entrypoint itself: `solve()` per method x comm.

    One small shared ridge problem; entries report us per solve() call at a
    fixed step count — the END-TO-END cost a consumer of the one-solver API
    pays. Since the compiled-runner cache landed these are WARM costs
    (timeit's warmup calls compile once; the timed calls reuse the cached
    runner) — the cold-vs-warm split is what the `sweep_*` entries measure.
    """
    from repro.core import mixing
    from repro.core.dsba import draw_indices
    from repro.core.solvers import make_problem, solve
    from repro.data.synthetic import make_regression

    n, q, d, k, steps = 8, 20, 200, 8, 200
    data = make_regression(n, q, d, k=k, seed=0)
    graph = mixing.erdos_renyi_graph(n, 0.4, seed=1)
    problem = make_problem("ridge", data, graph, lam=1e-3)
    idx = draw_indices(steps, n, q, seed=3)

    grid = (
        ("solve_dsba_dense", "dsba", "dense", steps),
        ("solve_dsba_sparse", "dsba", "sparse", steps),
        ("solve_extra_dense", "extra", "dense", steps),
    )
    def one(method, comm, nsteps):
        return solve(problem, method, comm=comm, steps=nsteps,
                     record_every=nsteps, indices=idx)

    for name, method, comm, nsteps in grid:
        us = timeit(one, method, comm, nsteps, n=3)
        rows.append((name, us, f"N={n} d={d} steps={nsteps}"))


def bench_comm_sharded(rows, fast):
    """The ``comm="sharded"`` scaling sweep (bench-group ``comm-sharded``).

    One forced-device child process per N (XLA_FLAGS must precede jax
    import); entries carry warm us/iter for the dense matmul backend and
    the node-mesh shard_map backend, with HLO-measured collective bytes in
    the derived column. ALL ``comm_sharded_*`` entries are tagged
    informational in the JSON payload: they mix single-device modeled
    timings with multi-device measured ones, so the 1.5x regression gate
    must not fire across that comparison (benchmarks/compare.py).
    """
    from benchmarks import bench_comm as BCm

    sizes = (8, 16) if fast else (8, 16, 32, 64)
    records = BCm.sharded_scaling_sweep(sizes)
    BCm.print_sharded_table(records)
    for r in records:
        ratio = r["sharded_us_iter"] / r["dense_us_iter"]
        rows.append((
            f"comm_sharded_N{r['n']}_dense", r["dense_us_iter"],
            f"ring d={r['d']} matmul mixing (modeled comm)",
        ))
        rows.append((
            f"comm_sharded_N{r['n']}_sharded", r["sharded_us_iter"],
            f"{r['bytes_per_iter'] / 1024:.2f}KB/iter "
            f"{r['permutes_per_iter']:.0f} permutes/iter "
            f"{ratio:.1f}x dense (measured comm)",
        ))


def bench_serve(rows, fast):
    """Continuous-batching serving throughput (bench-group ``serve``).

    Tokens/sec through the paged-cache scheduler at batch 1/64/512
    (benchmarks/bench_serve.py). ALL ``serve_*`` entries are tagged
    informational in the JSON payload: a serving step times device
    decode plus host scheduler bookkeeping, too container-noisy for
    the 1.5x gate.
    """
    from benchmarks import bench_serve as BS

    for r in BS.measure(fast=fast):
        rows.append((
            f"serve_decode_b{r['batch']}", r["us_per_step"],
            f"{r['tok_s']:.1f} tok/s occupancy={r['occupancy']:.2f} "
            f"admit={r['admit_s'] * 1e3:.0f}ms",
        ))


def bench_faults(rows, fast):
    """Fault-injection degradation curves (bench-group ``faults``).

    Iterations-to-``dist2 <= 1e-6`` vs link drop rate p in {0, .1, .2, .4}
    for dsba/dsa/mudag on the dense backend (benchmarks/bench_faults.py).
    At p=0 the derived column carries the iteration count; at p>0 the run
    converges to a bias neighborhood (iid drops + row renormalization
    inject mixing noise every round), so it carries the plateau level
    instead — which grows with p. ALL ``faults_*`` entries are tagged
    informational in the JSON payload: the timing is a container-timed
    whole-solve wall clock; the curve in the derived column is the
    meaningful output.
    """
    from benchmarks import bench_faults as BF

    for r in BF.measure(fast=fast):
        it = r["iters_to_tol"]
        curve = (
            f"iters_to_1e-6={it}" if it is not None
            else f"never<=1e-6 in {r['steps']} plateau={r['plateau']:.1e}"
        )
        rows.append((
            f"faults_{r['method']}_p{r['p']:g}", r["us"],
            f"{curve} dense link-drop p={r['p']:g}",
        ))


def informational_entries(rows) -> list[str]:
    """Entries compare.py reports but never gates: mesh-backend rows mix
    modeled and measured communication, the PR 7 rows (bilinear figure,
    mudag-vs-dsa round ratio) report convergence facts rather than
    latencies, and the serving rows time host scheduler + device decode
    in one container-noisy number, and the fault rows report degradation
    curves (iterations / plateau levels) rather than latencies."""
    return sorted(
        name for name, _, _ in rows
        if name.startswith(("comm_sharded_", "paper_accel_", "serve_",
                            "faults_"))
        or name == "paper_fig_bilinear"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument(
        "--bench-group",
        choices=("kernels", "sweep", "convergence", "comm-sharded", "serve",
                 "faults", "all"),
        default="all",
        help="kernels = dsba/kernel-fwd+bwd/gossip/sweep timings (what CI "
             "gates); sweep = just the sweep-engine entries; convergence = "
             "the paper's convergence + communication tables; comm-sharded "
             "= the node-mesh scaling sweep (informational entries); serve "
             "= continuous-batching decode throughput (informational); "
             "faults = link-fault degradation curves (informational)",
    )
    ap.add_argument(
        "--json", default=None, metavar="PATH",
        help="also write {schema, fast, entries: {name: us_per_call}} JSON "
             "(the format benchmarks/compare.py gates CI regressions on)",
    )
    args, _ = ap.parse_known_args()

    rows: list[tuple[str, float, str]] = []
    if args.bench_group in ("kernels", "all"):
        bench_dsba_step(rows)
        bench_kernels(rows, args.fast)
        bench_gossip(rows)
    if args.bench_group in ("kernels", "sweep", "all"):
        # sweep entries ride in the kernels CI gate (docs/benchmarks.md)
        bench_sweep(rows)
    if args.bench_group in ("convergence", "all"):
        bench_solvers(rows)
        bench_comm_table(rows)
        bench_convergence_tables(rows, args.fast)
    if args.bench_group in ("comm-sharded", "all"):
        bench_comm_sharded(rows, args.fast)
    if args.bench_group in ("serve", "all"):
        bench_serve(rows, args.fast)
    if args.bench_group in ("faults", "all"):
        bench_faults(rows, args.fast)

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    if args.json:
        import json
        import pathlib

        payload = {
            "schema": 1,
            "fast": bool(args.fast),
            "entries": {name: round(us, 1) for name, us, _ in rows},
            "derived": {name: derived for name, _, derived in rows},
            "informational": informational_entries(rows),
        }
        pathlib.Path(args.json).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()

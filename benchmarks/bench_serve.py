"""Serving throughput: continuous-batching decode at batch 1/64/512.

Measures the steady-state decode loop of ``repro.serve.Scheduler`` on
the reduced minitron config — the scheduler admits `batch` requests,
the pool fills, and we time warm fixed-shape decode steps (everything
jitted is already traced; the host side does admission bookkeeping +
argmax sampling).  Entries report us per decode step; the derived
column carries tokens/sec and pool occupancy.

All ``serve_*`` entries are informational in the regression gate:
container-timed CPU wall-clock of a whole serving step (device decode
+ host scheduler) is too noisy across runners to gate at 1.5x.

    PYTHONPATH=src python -m benchmarks.run --bench-group serve
"""
from __future__ import annotations

import time

import jax
import numpy as np

BATCHES = (1, 64, 512)


def measure(batches=BATCHES, fast=False):
    """Returns one record per batch size: us/step, tok/s, occupancy."""
    from repro.configs import get_reduced
    from repro.models import transformer as T
    from repro.models.params import tree_materialize
    from repro.serve import PoolConfig, Request, Scheduler

    cfg = get_reduced("minitron_8b")
    params = tree_materialize(
        T.model_defs(cfg), jax.random.PRNGKey(0), cfg.param_dtype
    )
    warmup, timed = (1, 4) if fast else (2, 16)
    records = []
    for batch in batches:
        pc = PoolConfig(
            max_batch=batch, block_size=16, n_blocks=2 * batch + 2,
            max_len=32, prompt_pad=16,
        )
        sch = Scheduler(cfg, params, pc)
        rng = np.random.default_rng(0)
        for i in range(batch):
            plen = int(rng.integers(3, 9))
            sch.submit(Request(
                rid=i, tokens=rng.integers(0, cfg.vocab_size, size=plen),
                max_new_tokens=warmup + timed + 4,
            ))
        t0 = time.perf_counter()
        sch.step()  # admits the whole batch (prefills) + first decode
        admit_s = time.perf_counter() - t0
        for _ in range(warmup):
            sch.step()
        t0 = time.perf_counter()
        for _ in range(timed):
            stats = sch.step()
            assert stats.tokens_generated == batch
        dt = time.perf_counter() - t0
        records.append({
            "batch": batch,
            "us_per_step": dt / timed * 1e6,
            "tok_s": batch * timed / dt,
            "occupancy": sch.pool.occupancy(),
            "admit_s": admit_s,
            "traces": dict(sch.trace_counts),
        })
    return records


def main():
    for r in measure():
        print(
            f"batch={r['batch']:4d}  {r['us_per_step']:10.1f} us/step  "
            f"{r['tok_s']:8.1f} tok/s  occupancy={r['occupancy']:.2f}  "
            f"admit={r['admit_s']:.2f}s  traces={r['traces']}"
        )


if __name__ == "__main__":
    main()

"""Paper Figures 1-3: convergence vs effective passes + communication cost.

One synthetic dataset per task family (stats matched to the paper's LIBSVM
sets, d capped for the CPU reference solve), every registered method that
supports the family through the one registry entrypoint
``core.solvers.solve``, paper hyper-struct: N=10, ER(0.4), lambda=1/(10Q),
||a||=1. The PR 7 families ride along: mudag/sliding on the minimization
tasks (with their 2K-rounds / skipped-rounds communication accounting) and
dsgda on the saddle tasks (auc + the bilinear minimax family).

Emits a markdown/CSV table per task into experiments/convergence_<task>.md.
"""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import pathlib

import numpy as np

from repro.core import mixing
from repro.core.solvers import make_problem, solve, solve_many
from repro.core.sparse_comm import sparse_doubles_per_iter
from repro.data.synthetic import make_classification, make_regression

OUT = pathlib.Path(__file__).resolve().parents[1] / "experiments"

# per-method tuned hyperparameters (grid-searched — `tune_stochastic` below
# replays the search as ONE batched solve_many; the paper also tunes
# per-method). The problem is deliberately run at the paper's
# lambda = 1/(10Q), i.e. kappa ~ L/lambda ~ 10^3: DSBA's backward step stays
# stable at alpha = 4 while the forward/deterministic methods are
# condition-limited — exactly Table 1's story. dsgda above alpha = 0.3
# diverges on bilinear at this shape (the SAGA-GT descent-ascent stability
# limit), which is why the saddle entries sit there.
TUNING = {
    "ridge": dict(dsba=dict(alpha=4.0), dsa=dict(alpha=0.5),
                  extra=dict(alpha=0.5), dlm=dict(c=0.2, beta=0.5),
                  ssda=dict(eta=1e-4, momentum=0.0),
                  mudag=dict(eta=2.0, momentum=0.9, gossip_rounds=3),
                  sliding=dict(alpha=1.0, comm_period=4)),
    "logistic": dict(dsba=dict(alpha=8.0), dsa=dict(alpha=1.0),
                     extra=dict(alpha=1.0), dlm=dict(c=0.1, beta=0.5),
                     ssda=dict(eta=1e-4, momentum=0.0),
                     mudag=dict(eta=2.0, momentum=0.9, gossip_rounds=3),
                     sliding=dict(alpha=1.0, comm_period=4)),
    "auc": dict(dsba=dict(alpha=1.0), dsa=dict(alpha=0.05),
                extra=dict(alpha=0.5), dsgda=dict(alpha=0.3, eta=0.3)),
    "bilinear": dict(dsba=dict(alpha=2.0), dsa=dict(alpha=0.3),
                     dsgda=dict(alpha=0.3, eta=0.3)),
}


def setup(task: str, n=10, q=100, d=800, k=30, seed=0):
    """Paper-shaped ``Problem`` for one task family, z* cached."""
    if task in ("ridge", "bilinear"):
        data = make_regression(n, q, d, k=k, seed=seed)
    elif task == "logistic":
        data = make_classification(n, q, d, k=k, seed=seed)
    else:
        data = make_classification(n, q, d, k=k, positive_ratio=0.3, seed=seed)
    graph = mixing.erdos_renyi_graph(n, 0.4, seed=1)
    problem = make_problem(task, data, graph)
    problem.solve_star()
    return problem


def tune_stochastic(task: str, method: str = "dsba",
                    alphas=(0.5, 1.0, 2.0, 4.0, 8.0), passes: int = 30,
                    problem=None):
    """Replay the step-size grid search as ONE batched ``solve_many``.

    The whole alpha grid advances in lockstep inside a single vmapped
    compiled runner — this is how the TUNING table above was produced.
    Pass ``problem`` to reuse an already-built instance (shares the z*
    solve and the dataset's runner-cache key across methods); otherwise
    one is built. Returns {alpha: final dist2}, best alpha first.
    """
    if problem is None:
        problem = setup(task)
    q = problem.data.q
    res = solve_many(
        problem, method, steps=passes * q, record_every=passes * q,
        grid=[{"alpha": float(a)} for a in alphas],
    )
    finals = dict(zip(alphas, res.dist2[:, -1]))
    return dict(sorted(finals.items(), key=lambda kv: kv[1]))


def run_all(task: str, passes: int = 120):
    """dist2-vs-passes for every tuned method + the communication model.

    Returns (problem, out, comm, per_pass): ``out`` maps display name to
    the dist2 curve, ``comm`` is the human-readable DOUBLEs summary, and
    ``per_pass`` maps display name to hottest-node DOUBLEs per curve point
    (one effective pass for the stochastic methods, one iteration for the
    deterministic ones — mudag pays 2K rounds per iteration, sliding only
    2/period, both straight from the ``comm_rounds`` accounting hooks).
    """
    problem = setup(task)
    data = problem.data
    q = data.q
    tune = TUNING[task]
    out = {}

    stochastic = [("DSBA", "dsba"), ("DSA", "dsa")]
    if task in ("auc", "bilinear"):  # descent-ascent: saddle families only
        stochastic.append(("DSGDA", "dsgda"))
    first = None
    for name, method in stochastic:
        res = solve(problem, method, steps=passes * q, record_every=q,
                    **tune[method])
        first = first or res
        out[name] = res.dist2

    # deterministic / accelerated: one full-gradient iteration per point,
    # restricted to each method's problem families (capability records)
    if task in ("ridge", "logistic"):
        deterministic = [("EXTRA", "extra"), ("DLM", "dlm"),
                         ("SSDA", "ssda"), ("MUDAG", "mudag"),
                         ("SLIDING", "sliding")]
    elif task == "auc":  # paper: SSDA n/a for AUC; DLM does not converge
        deterministic = [("EXTRA", "extra")]
    else:  # bilinear: no descent-only baseline applies
        deterministic = []
    det_rounds = {}
    for name, method in deterministic:
        res = solve(problem, method, steps=passes, record_every=1,
                    **tune[method])
        out[name] = res.dist2
        # cumulative rounds from the accounting itself (hottest node)
        det_rounds[name] = int(res.doubles_received[-1].max())

    # communication: DOUBLEs at the hottest node per effective pass — the
    # dense numbers straight from the SolveResult accounting (one dense
    # exchange per iteration for the stochastic methods)
    dense = int(first.doubles_received[-1].max() // first.iters[-1])
    sparse = sparse_doubles_per_iter(data.n_nodes, data.k, problem.spec.tail_dim)
    comm = {"DSBA-s": sparse * q, "DSBA(dense)": dense * q,
            "DSA-s": sparse * q}
    per_pass = {"DSBA": sparse * q, "DSA": sparse * q}
    if "DSGDA" in out:
        comm["DSGDA(dense)"] = dense * q
        per_pass["DSGDA"] = dense * q
    for name in det_rounds:
        per_iter = det_rounds[name] // passes
        per_pass[name] = per_iter
        if name in ("MUDAG", "SLIDING"):
            comm[f"{name}/iter"] = per_iter
        else:
            comm.setdefault("EXTRA/DLM/SSDA", per_iter)
    comm["dense/iter"] = dense
    return problem, out, comm, per_pass


def render(task: str, passes: int = 120) -> str:
    """Markdown table of dist2 vs passes and vs DOUBLE budget for one task."""
    problem, out, comm, per_pass = run_all(task, passes)
    data = problem.data
    lines = [
        f"### {task} (d={data.d}, rho={data.rho:.4f}, N={data.n_nodes}, "
        f"q={data.q})",
        "",
        "| effective passes | " + " | ".join(out) + " |",
        "|---|" + "---|" * len(out),
    ]
    n_rows = max(len(v) for v in out.values())
    marks = sorted(
        {0, 1, 3, 7, 15, 31, passes // 2 - 1, passes - 1} & set(range(n_rows))
    )
    for i in marks:
        cells = []
        for v in out.values():
            cells.append(f"{v[min(i, len(v) - 1)]:.2e}")
        lines.append(f"| {i + 1} | " + " | ".join(cells) + " |")
    lines += [
        "",
        "Communication per effective pass, hottest node (DOUBLEs): "
        + ", ".join(f"{k}={v:,}" for k, v in comm.items()),
        "",
    ]

    # ---- the paper's right panels: suboptimality vs COMMUNICATION --------
    # DSBA-s / DSA-s pay sparse_doubles per stochastic pass; deterministic
    # methods pay dense doubles per iteration (mudag 2K of them, sliding
    # 2/period — the comm_rounds accounting). Tabulate dist^2 at equal
    # hottest-node DOUBLE budgets.
    budgets = [comm["DSBA-s"] * 8, comm["dense/iter"] * 4,
               comm["dense/iter"] * 16]
    lines += [
        "| DOUBLEs received (hottest node) | "
        + " | ".join(out) + " |",
        "|---|" + "---|" * len(out),
    ]
    for b in budgets:
        cells = []
        for m, v in out.items():
            i = min(int(b // per_pass[m]), len(v)) - 1
            cells.append(f"{v[i]:.2e}" if i >= 0 else "-")
        lines.append(f"| {b:,} | " + " | ".join(cells) + " |")
    lines.append("")
    return "\n".join(lines)


def accel_rounds_to_target(lam: float = 1e-2, target: float = 1e-9):
    """ISSUE 7 acceptance: mudag's dense-communication rounds to reach
    ``dist2 <= target`` on the paper-shaped ridge problem vs DSA's (dense
    comm, one round per iteration) — the ratio must be <= 0.5.

    Run at lam=1e-2 (kappa ~ 10^2) so the 1e-9 target is reachable in
    benchmark wall time; at the paper's lambda = 1/(10Q) every method is
    condition-limited and none of them touch 1e-9 in a bounded run (the
    same comparison at test scale: tests/test_accel_minimax.py).
    """
    data = make_regression(10, 100, 800, k=30, seed=0)
    graph = mixing.erdos_renyi_graph(10, 0.4, seed=1)
    problem = make_problem("ridge", data, graph, lam=lam)
    problem.solve_star()
    k = 3
    rm = solve(problem, "mudag", steps=400, record_every=20,
               eta=2.0, momentum=0.8, gossip_rounds=k)
    rd = solve(problem, "dsa", steps=4000, record_every=100, alpha=0.5,
               seed=0)

    def rounds(res, per_iter):
        hit = np.flatnonzero(res.dist2 <= target)
        return int(res.iters[hit[0]]) * per_iter if hit.size else None

    mudag = rounds(rm, 2 * k)  # 2K FastMix exchanges per iteration
    dsa = rounds(rd, 1)
    ratio = (mudag / dsa) if (mudag and dsa) else None
    return {"mudag_rounds": mudag, "dsa_rounds": dsa, "ratio": ratio}


def dynamic_scenarios(steps: int = 4000) -> str:
    """Dynamic-network scenario table: one ROW per scenario, not a fork.

    Every scenario reuses the same base problem and reports the same
    columns — final dist2 against the scenario's own ground truth, the
    worst-node consensus residual, and the hottest-node DOUBLE total —
    so static vs switch vs churn vs personalization read as one table.
    ``dist2*`` is measured against each scenario's OWN root: the survivor
    system's after a kill, the grown system's after a join, and the
    consensus-regularized fixed point (``personalized_root``) for the
    personalization row.
    """
    import dataclasses

    from repro.core.solvers import (
        ChurnEvent, ChurnPlan, personalized_root, solve,
    )
    from repro.data.synthetic import make_noniid_regression

    n, q, d, k = 10, 50, 200, 20
    data = make_regression(n, q, d, k=k, seed=0)
    ring = mixing.ring_graph(n)
    er = mixing.erdos_renyi_graph(n, 0.4, seed=1)
    base = make_problem("ridge", data, ring, lam=1e-2)
    base.solve_star()
    half = steps // 2
    rows = []

    def consensus(z):
        z = np.asarray(z)
        return float(np.max(np.sum((z - z.mean(0)) ** 2, -1)))

    def row(name, res, z_ref, note):
        z = np.asarray(res.z)
        d2 = float(np.mean(np.sum((z - z_ref) ** 2, -1)))
        rows.append((name, d2, consensus(z),
                     int(res.doubles_received[-1].max()), note))

    r = solve(base, "dsba", steps=steps, record_every=steps, alpha=2.0)
    row("static ring", r, base.z_star, "baseline")

    ps = dataclasses.replace(base, schedule=((0, ring), (half, er)))
    r = solve(ps, "dsba", steps=steps, record_every=steps, alpha=2.0)
    gaps = "->".join(f"{s['spectral_gap']:.3f}" for s in r.extras["schedule"])
    row("switch ring->ER", r, base.z_star, f"gaps {gaps}")

    plan = ChurnPlan((ChurnEvent(at=half, kind="kill", nodes=(8, 9)),))
    r = solve(base, "dsba", steps=steps, record_every=steps, alpha=2.0,
              comm_options={"fault_plan": plan})
    surv = make_problem(
        "ridge",
        dataclasses.replace(data, idx=data.idx[:8], val=data.val[:8],
                            y=data.y[:8]),
        ring.subgraph(range(8)), lam=1e-2)
    row("kill 2 @ T/2", r, surv.solve_star(), "vs survivor root")

    plan = ChurnPlan((ChurnEvent(at=half, kind="join", n_new=2, seed_from=0,
                                 graph=mixing.ring_graph(n + 2)),))
    r = solve(base, "dsba", steps=steps, record_every=steps, alpha=2.0,
              comm_options={"fault_plan": plan})
    grown = make_problem(
        "ridge",
        dataclasses.replace(
            data,
            idx=np.concatenate([data.idx, data.idx[[0, 0]]]),
            val=np.concatenate([data.val, data.val[[0, 0]]]),
            y=np.concatenate([data.y, data.y[[0, 0]]]),
        ),
        mixing.ring_graph(n + 2), lam=1e-2)
    row("join 2 @ T/2", r, grown.solve_star(), "vs grown root")

    ndata, _ = make_noniid_regression(n, q, d, k=k, shift=1.5, seed=0)
    pp = make_problem("ridge", ndata, ring,
                      lam=np.linspace(0.05, 0.2, n))
    r = solve(pp, "personal", steps=steps, record_every=steps, mu=1.0)
    row("personal non-iid", r, personalized_root(pp, mu=1.0),
        "per-node lam, mu=1")

    lines = [
        f"### dynamic networks (dsba unless noted; N={n}, q={q}, d={d}, "
        f"T={steps})",
        "",
        "| scenario | dist2* (own root) | worst consensus | DOUBLEs "
        "(hottest) | note |",
        "|---|---|---|---|---|",
    ]
    for name, d2, cons, dbl, note in rows:
        lines.append(f"| {name} | {d2:.2e} | {cons:.2e} | {dbl:,} | {note} |")
    lines.append("")
    return "\n".join(lines)


def main(passes: int = 120, tune: bool = False):
    """Render + write the per-task experiment tables.

    tune=True additionally prints the batched step-size grid search
    (``tune_stochastic``) for the stochastic methods on each task.
    """
    OUT.mkdir(exist_ok=True, parents=True)
    acc = accel_rounds_to_target()
    ratio = f"{acc['ratio']:.2f}" if acc["ratio"] else "n/a"
    print(f"mudag vs dsa, ridge @ lam=1e-2, rounds to 1e-9: "
          f"{acc['mudag_rounds']} vs {acc['dsa_rounds']} "
          f"(ratio {ratio}, acceptance <= 0.5)")
    dyn = dynamic_scenarios()
    (OUT / "convergence_dynamic.md").write_text(dyn)
    print(dyn)
    for task in ("ridge", "logistic", "auc", "bilinear"):
        md = render(task, passes)
        (OUT / f"convergence_{task}.md").write_text(md)
        print(md)
        if tune:
            problem = setup(task)  # shared across methods: one z*, one key
            for method in ("dsba", "dsa"):
                finals = tune_stochastic(task, method, problem=problem)
                line = ", ".join(
                    f"alpha={a:g}: {v:.2e}" for a, v in finals.items()
                )
                print(f"{task}/{method} alpha sweep (solve_many): {line}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--passes", type=int, default=120)
    ap.add_argument("--tune", action="store_true",
                    help="also run the batched alpha grid search")
    args = ap.parse_args()
    main(args.passes, tune=args.tune)

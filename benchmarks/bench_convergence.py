"""Paper Figures 1-3: convergence vs effective passes + communication cost.

One synthetic dataset per task family (stats matched to the paper's LIBSVM
sets, d capped for the CPU reference solve), all five methods, paper
hyper-struct: N=10, ER(0.4), lambda=1/(10Q), ||a||=1.

Emits a markdown/CSV table per task into experiments/convergence_<task>.md.
"""
from __future__ import annotations

import pathlib


from repro.core import mixing, reference
from repro.core.baselines import run_dlm, run_extra, run_ssda
from repro.core.dsba import DSBAConfig, run
from repro.core.operators import OperatorSpec
from repro.core.sparse_comm import dense_doubles_per_iter, sparse_doubles_per_iter
from repro.data.synthetic import make_classification, make_regression

OUT = pathlib.Path(__file__).resolve().parents[1] / "experiments"

# per-method tuned step sizes (grid-searched; the paper also tunes per-method).
# The problem is deliberately run at the paper's lambda = 1/(10Q), i.e.
# kappa ~ L/lambda ~ 10^3: DSBA's backward step stays stable at alpha = 4
# while the forward/deterministic methods are condition-limited — exactly
# Table 1's story.
TUNING = {
    "ridge": dict(dsba=4.0, dsa=0.5, extra=0.5, dlm=(0.2, 0.5),
                  ssda=(1e-4, 0.0)),
    "logistic": dict(dsba=8.0, dsa=1.0, extra=1.0, dlm=(0.1, 0.5),
                     ssda=(1e-4, 0.0)),
    "auc": dict(dsba=1.0, dsa=0.05),
}


def setup(task: str, n=10, q=100, d=800, k=30, seed=0):
    if task == "ridge":
        data = make_regression(n, q, d, k=k, seed=seed)
        spec = OperatorSpec("ridge")
    elif task == "logistic":
        data = make_classification(n, q, d, k=k, seed=seed)
        spec = OperatorSpec("logistic")
    else:
        data = make_classification(n, q, d, k=k, positive_ratio=0.3, seed=seed)
        spec = OperatorSpec("auc", p=data.positive_ratio())
    graph = mixing.erdos_renyi_graph(n, 0.4, seed=1)
    w = mixing.laplacian_mixing(graph)
    lam = 1.0 / (10.0 * data.total)
    z_star = reference.solve_root(spec, data, lam)
    return data, spec, graph, w, lam, z_star


def run_all(task: str, passes: int = 120):
    data, spec, graph, w, lam, z_star = setup(task)
    q = data.q
    tune = TUNING[task]
    out = {}

    res = run(DSBAConfig(spec, tune["dsba"], lam), data, w, passes * q,
              z_star=z_star, record_every=q)
    out["DSBA"] = res.dist2
    res = run(DSBAConfig(spec, tune["dsa"], lam, method="dsa"), data, w,
              passes * q, z_star=z_star, record_every=q)
    out["DSA"] = res.dist2

    if task != "auc":  # paper: SSDA n/a for AUC; DLM does not converge there
        res = run_extra(spec, data, w, tune["extra"], lam, passes,
                        z_star=z_star, record_every=1)
        out["EXTRA"] = res.dist2
        c, beta = tune["dlm"]
        res = run_dlm(spec, data, graph, c, beta, lam, passes,
                      z_star=z_star, record_every=1)
        out["DLM"] = res.dist2
        eta, mom = tune["ssda"]
        res = run_ssda(spec, data, w, eta, mom, lam, passes,
                       z_star=z_star, record_every=1)
        out["SSDA"] = res.dist2
    else:
        res = run_extra(spec, data, w, 0.5, lam, passes, z_star=z_star,
                        record_every=1)
        out["EXTRA"] = res.dist2

    # communication: DOUBLEs at the hottest node per effective pass
    comm = {}
    dense = int(dense_doubles_per_iter(graph, data.d + spec.tail_dim).max())
    sparse = sparse_doubles_per_iter(data.n_nodes, data.k, spec.tail_dim)
    comm["DSBA-s"] = sparse * q
    comm["DSBA(dense)"] = dense * q
    comm["DSA-s"] = sparse * q
    comm["EXTRA/DLM/SSDA"] = dense
    return data, out, comm


def render(task: str, passes: int = 120) -> str:
    data, out, comm = run_all(task, passes)
    lines = [
        f"### {task} (d={data.d}, rho={data.rho:.4f}, N={data.n_nodes}, "
        f"q={data.q})",
        "",
        "| effective passes | " + " | ".join(out) + " |",
        "|---|" + "---|" * len(out),
    ]
    n_rows = max(len(v) for v in out.values())
    marks = sorted(
        {0, 1, 3, 7, 15, 31, passes // 2 - 1, passes - 1} & set(range(n_rows))
    )
    for i in marks:
        cells = []
        for v in out.values():
            cells.append(f"{v[min(i, len(v) - 1)]:.2e}")
        lines.append(f"| {i + 1} | " + " | ".join(cells) + " |")
    lines += [
        "",
        "Communication per effective pass, hottest node (DOUBLEs): "
        + ", ".join(f"{k}={v:,}" for k, v in comm.items()),
        "",
    ]

    # ---- the paper's right panels: suboptimality vs COMMUNICATION --------
    # DSBA-s / DSA-s pay sparse_doubles per stochastic pass; deterministic
    # methods pay dense doubles per iteration. Tabulate dist^2 at equal
    # hottest-node DOUBLE budgets.
    per_pass = {
        "DSBA": comm["DSBA-s"],  # sparse implementation (Section 5.1)
        "DSA": comm["DSA-s"],
    }
    for m in out:
        if m not in per_pass:
            per_pass[m] = comm["EXTRA/DLM/SSDA"]
    budgets = [comm["DSBA-s"] * 8, comm["EXTRA/DLM/SSDA"] * 4,
               comm["EXTRA/DLM/SSDA"] * 16]
    lines += [
        "| DOUBLEs received (hottest node) | "
        + " | ".join(out) + " |",
        "|---|" + "---|" * len(out),
    ]
    for b in budgets:
        cells = []
        for m, v in out.items():
            i = min(int(b // per_pass[m]), len(v)) - 1
            cells.append(f"{v[i]:.2e}" if i >= 0 else "-")
        lines.append(f"| {b:,} | " + " | ".join(cells) + " |")
    lines.append("")
    return "\n".join(lines)


def main(passes: int = 120):
    OUT.mkdir(exist_ok=True, parents=True)
    for task in ("ridge", "logistic", "auc"):
        md = render(task, passes)
        (OUT / f"convergence_{task}.md").write_text(md)
        print(md)


if __name__ == "__main__":
    main()

"""Paper Figures 1-3: convergence vs effective passes + communication cost.

One synthetic dataset per task family (stats matched to the paper's LIBSVM
sets, d capped for the CPU reference solve), all five methods through the
one registry entrypoint ``core.solvers.solve``, paper hyper-struct: N=10,
ER(0.4), lambda=1/(10Q), ||a||=1.

Emits a markdown/CSV table per task into experiments/convergence_<task>.md.
"""
from __future__ import annotations

import pathlib


from repro.core import mixing
from repro.core.solvers import make_problem, solve, solve_many
from repro.core.sparse_comm import sparse_doubles_per_iter
from repro.data.synthetic import make_classification, make_regression

OUT = pathlib.Path(__file__).resolve().parents[1] / "experiments"

# per-method tuned hyperparameters (grid-searched — `tune_stochastic` below
# replays the search as ONE batched solve_many; the paper also tunes
# per-method). The problem is deliberately run at the paper's
# lambda = 1/(10Q), i.e. kappa ~ L/lambda ~ 10^3: DSBA's backward step stays
# stable at alpha = 4 while the forward/deterministic methods are
# condition-limited — exactly Table 1's story.
TUNING = {
    "ridge": dict(dsba=dict(alpha=4.0), dsa=dict(alpha=0.5),
                  extra=dict(alpha=0.5), dlm=dict(c=0.2, beta=0.5),
                  ssda=dict(eta=1e-4, momentum=0.0)),
    "logistic": dict(dsba=dict(alpha=8.0), dsa=dict(alpha=1.0),
                     extra=dict(alpha=1.0), dlm=dict(c=0.1, beta=0.5),
                     ssda=dict(eta=1e-4, momentum=0.0)),
    "auc": dict(dsba=dict(alpha=1.0), dsa=dict(alpha=0.05),
                extra=dict(alpha=0.5)),
}


def setup(task: str, n=10, q=100, d=800, k=30, seed=0):
    """Paper-shaped ``Problem`` for one task family, z* cached."""
    if task == "ridge":
        data = make_regression(n, q, d, k=k, seed=seed)
    elif task == "logistic":
        data = make_classification(n, q, d, k=k, seed=seed)
    else:
        data = make_classification(n, q, d, k=k, positive_ratio=0.3, seed=seed)
    graph = mixing.erdos_renyi_graph(n, 0.4, seed=1)
    problem = make_problem(task, data, graph)
    problem.solve_star()
    return problem


def tune_stochastic(task: str, method: str = "dsba",
                    alphas=(0.5, 1.0, 2.0, 4.0, 8.0), passes: int = 30,
                    problem=None):
    """Replay the step-size grid search as ONE batched ``solve_many``.

    The whole alpha grid advances in lockstep inside a single vmapped
    compiled runner — this is how the TUNING table above was produced.
    Pass ``problem`` to reuse an already-built instance (shares the z*
    solve and the dataset's runner-cache key across methods); otherwise
    one is built. Returns {alpha: final dist2}, best alpha first.
    """
    if problem is None:
        problem = setup(task)
    q = problem.data.q
    res = solve_many(
        problem, method, steps=passes * q, record_every=passes * q,
        grid=[{"alpha": float(a)} for a in alphas],
    )
    finals = dict(zip(alphas, res.dist2[:, -1]))
    return dict(sorted(finals.items(), key=lambda kv: kv[1]))


def run_all(task: str, passes: int = 120):
    """dist2-vs-passes for every tuned method + the communication model."""
    problem = setup(task)
    data = problem.data
    q = data.q
    tune = TUNING[task]
    out = {}

    res = solve(problem, "dsba", steps=passes * q, record_every=q,
                **tune["dsba"])
    out["DSBA"] = res.dist2
    res = solve(problem, "dsa", steps=passes * q, record_every=q,
                **tune["dsa"])
    out["DSA"] = res.dist2

    det = solve(problem, "extra", steps=passes, record_every=1,
                **tune["extra"])
    out["EXTRA"] = det.dist2
    if task != "auc":  # paper: SSDA n/a for AUC; DLM does not converge there
        res = solve(problem, "dlm", steps=passes, record_every=1,
                    **tune["dlm"])
        out["DLM"] = res.dist2
        res = solve(problem, "ssda", steps=passes, record_every=1,
                    **tune["ssda"])
        out["SSDA"] = res.dist2

    # communication: DOUBLEs at the hottest node per effective pass — the
    # dense numbers straight from the SolveResult accounting
    comm = {}
    dense = int(det.doubles_received[-1].max() // det.iters[-1])
    sparse = sparse_doubles_per_iter(data.n_nodes, data.k, problem.spec.tail_dim)
    comm["DSBA-s"] = sparse * q
    comm["DSBA(dense)"] = dense * q
    comm["DSA-s"] = sparse * q
    comm["EXTRA/DLM/SSDA"] = dense
    return problem, out, comm


def render(task: str, passes: int = 120) -> str:
    """Markdown table of dist2 vs passes and vs DOUBLE budget for one task."""
    problem, out, comm = run_all(task, passes)
    data = problem.data
    lines = [
        f"### {task} (d={data.d}, rho={data.rho:.4f}, N={data.n_nodes}, "
        f"q={data.q})",
        "",
        "| effective passes | " + " | ".join(out) + " |",
        "|---|" + "---|" * len(out),
    ]
    n_rows = max(len(v) for v in out.values())
    marks = sorted(
        {0, 1, 3, 7, 15, 31, passes // 2 - 1, passes - 1} & set(range(n_rows))
    )
    for i in marks:
        cells = []
        for v in out.values():
            cells.append(f"{v[min(i, len(v) - 1)]:.2e}")
        lines.append(f"| {i + 1} | " + " | ".join(cells) + " |")
    lines += [
        "",
        "Communication per effective pass, hottest node (DOUBLEs): "
        + ", ".join(f"{k}={v:,}" for k, v in comm.items()),
        "",
    ]

    # ---- the paper's right panels: suboptimality vs COMMUNICATION --------
    # DSBA-s / DSA-s pay sparse_doubles per stochastic pass; deterministic
    # methods pay dense doubles per iteration. Tabulate dist^2 at equal
    # hottest-node DOUBLE budgets.
    per_pass = {
        "DSBA": comm["DSBA-s"],  # sparse implementation (Section 5.1)
        "DSA": comm["DSA-s"],
    }
    for m in out:
        if m not in per_pass:
            per_pass[m] = comm["EXTRA/DLM/SSDA"]
    budgets = [comm["DSBA-s"] * 8, comm["EXTRA/DLM/SSDA"] * 4,
               comm["EXTRA/DLM/SSDA"] * 16]
    lines += [
        "| DOUBLEs received (hottest node) | "
        + " | ".join(out) + " |",
        "|---|" + "---|" * len(out),
    ]
    for b in budgets:
        cells = []
        for m, v in out.items():
            i = min(int(b // per_pass[m]), len(v)) - 1
            cells.append(f"{v[i]:.2e}" if i >= 0 else "-")
        lines.append(f"| {b:,} | " + " | ".join(cells) + " |")
    lines.append("")
    return "\n".join(lines)


def main(passes: int = 120, tune: bool = False):
    """Render + write the three per-task experiment tables.

    tune=True additionally prints the batched step-size grid search
    (``tune_stochastic``) for the stochastic methods on each task.
    """
    OUT.mkdir(exist_ok=True, parents=True)
    for task in ("ridge", "logistic", "auc"):
        md = render(task, passes)
        (OUT / f"convergence_{task}.md").write_text(md)
        print(md)
        if tune:
            problem = setup(task)  # shared across methods: one z*, one key
            for method in ("dsba", "dsa"):
                finals = tune_stochastic(task, method, problem=problem)
                line = ", ".join(
                    f"alpha={a:g}: {v:.2e}" for a, v in finals.items()
                )
                print(f"{task}/{method} alpha sweep (solve_many): {line}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--passes", type=int, default=120)
    ap.add_argument("--tune", action="store_true",
                    help="also run the batched alpha grid search")
    args = ap.parse_args()
    main(args.passes, tune=args.tune)

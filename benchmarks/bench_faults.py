"""Fault-injection degradation curves: iterations-to-tolerance vs drop rate.

For each method in {dsba, dsa, mudag} and link drop rate p in
{0, 0.1, 0.2, 0.4}, run the DENSE backend on the small ridge problem
under ``FaultPlan(link=LinkFault(p=p))`` with ``record_every=1`` and
report the first iteration whose ``dist2`` falls to ``TOL = 1e-6``.
The dense backend is the right axis for this curve: its masked-matvec
model re-normalizes surviving rows each round, so the iterate stays a
convex combination and degradation is a clean slowdown/bias story. (The
sparse relay has no resync and drifts at a fixed drop rate — a genuine
property of reference-point compression, documented in
docs/solvers.md — so its curve would measure the drift, not the method.)

Entries report wall-clock us per solve; the derived column carries the
curve point: the iteration count at p=0, or — because iid drops with
row-renormalization inject round-to-round mixing noise, so every p>0
run converges to a BIAS NEIGHBORHOOD rather than the root
(test_degradation_sweep_dense pins "finite, biased-not-divergent") —
the plateau level, which grows with p. All ``faults_*`` entries are
informational in the regression gate: the meaningful output is the
curve in the derived column, not the container-timed latency.

    PYTHONPATH=src python -m benchmarks.run --bench-group faults
"""
from __future__ import annotations

import time

import numpy as np

METHODS = (
    ("dsba", {}),
    ("dsa", {}),
    ("mudag", {"eta": 0.5, "momentum": 0.5}),
)
DROP_RATES = (0.0, 0.1, 0.2, 0.4)
TOL = 1e-6


def measure(fast=False):
    """One record per (method, p): us per solve, iters to TOL, final dist2."""
    from repro.core import mixing
    from repro.core.solvers import FaultPlan, LinkFault, make_problem, solve
    from repro.data.synthetic import make_regression

    n = 8
    data = make_regression(n, 12, 6, k=3, seed=0)
    problem = make_problem("ridge", data, mixing.ring_graph(n), lam=1e-2)
    problem.solve_star()
    steps = 300 if fast else 600

    records = []
    for method, hp in METHODS:
        for p in DROP_RATES:
            opts = (
                {"fault_plan": FaultPlan(link=LinkFault(p=p, seed=7))}
                if p > 0 else None
            )
            t0 = time.perf_counter()
            res = solve(problem, method, comm="dense", steps=steps,
                        record_every=1, seed=1, comm_options=opts, **hp)
            us = (time.perf_counter() - t0) * 1e6
            dist2 = np.asarray(res.dist2)
            hit = np.flatnonzero(dist2 <= TOL)
            records.append({
                "method": method,
                "p": p,
                "us": us,
                # dist2[i] is recorded AFTER iteration i+1 (record_every=1)
                "iters_to_tol": int(hit[0]) + 1 if hit.size else None,
                # the bias-neighborhood level wiggles stochastically round
                # to round; the last-quarter median is a stable estimate
                "plateau": float(np.median(dist2[-(steps // 4):])),
                "final_dist2": float(dist2[-1]),
                "steps": steps,
            })
    return records


def main():
    import jax

    jax.config.update("jax_enable_x64", True)  # run.py does this globally
    for r in measure():
        it = r["iters_to_tol"]
        print(
            f"{r['method']:>6s} p={r['p']:.1f}  "
            f"iters_to_{TOL:.0e}={it if it is not None else 'never'}  "
            f"plateau={r['plateau']:.2e}  ({r['us'] / 1e3:.0f} ms)"
        )


if __name__ == "__main__":
    main()

"""Paper Table 1 (convergence-rate column), verified empirically.

The paper's headline: DSBA's iteration complexity is LINEAR in the problem
condition number kappa, while DSA's has kappa^4 and EXTRA's kappa^2 terms.
We sweep kappa via the regularizer (kappa ~ L/lam) and report iterations to
reach dist^2 <= eps for each method at its tuned step size — every run
through ``core.solvers.solve``, the registry's one entrypoint. The measured
growth of iterations with kappa separates the methods exactly as Table 1
predicts.
"""
from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import time

import numpy as np

from repro.core import mixing
from repro.core.solvers import (
    clear_runner_caches, make_problem, runner_cache_stats, solve,
)
from repro.data.synthetic import make_regression

EPS = 1e-10
MAX_PASSES = 400


def iters_to_eps(dist2, record_every):
    idx = np.argmax(dist2 <= EPS)
    if dist2[idx] > EPS:
        return None
    return (idx + 1) * record_every


def main():
    n, q, d, k = 6, 30, 200, 8
    data = make_regression(n, q, d, k=k, seed=0)
    graph = mixing.erdos_renyi_graph(n, 0.4, seed=1)

    # the lam sweep is the sweep-engine showcase: one Problem per lam over
    # the SAME data/graph, so each method compiles once (first lam) and
    # every later lam/alpha lands on the cached runner with lam traced
    clear_runner_caches()
    t_start = time.perf_counter()

    print(f"{'lam':>8} {'~kappa':>8} {'DSBA iters':>11} {'DSA iters':>10} "
          f"{'EXTRA iters':>12} {'MUDAG iters':>12} {'SLIDING iters':>14}")
    rows = []
    for lam in (1e-1, 1e-2, 1e-3):
        kappa = (0.25 + lam) / lam  # L ~ max eig of per-sample op ~ ||a||^2
        problem = make_problem("ridge", data, graph, lam=lam)
        problem.solve_star()
        r_b = solve(problem, "dsba", steps=MAX_PASSES * q, record_every=q,
                    alpha=1.0)
        it_b = iters_to_eps(r_b.dist2, q)
        r_a = solve(problem, "dsa", steps=MAX_PASSES * q, record_every=q,
                    alpha=0.15)
        it_a = iters_to_eps(r_a.dist2, q)
        r_e = solve(problem, "extra", steps=MAX_PASSES * 4, record_every=4,
                    alpha=0.3)
        it_e = iters_to_eps(r_e.dist2, 4)
        # Table 1's accelerated row (Ye et al. 2020): sqrt(kappa) iteration
        # growth; each iteration costs 2K gossip rounds (comm_rounds hook)
        r_m = solve(problem, "mudag", steps=MAX_PASSES * 4, record_every=4,
                    eta=2.0, momentum=0.9, gossip_rounds=3)
        it_m = iters_to_eps(r_m.dist2, 4)
        # sliding communicates every 4th iteration only
        r_s = solve(problem, "sliding", steps=MAX_PASSES * 4, record_every=4,
                    alpha=0.5, comm_period=4)
        it_s = iters_to_eps(r_s.dist2, 4)
        fmt = lambda v: f"{v}" if v else f">{MAX_PASSES * q}"
        print(f"{lam:8.0e} {kappa:8.0f} {fmt(it_b):>11} {fmt(it_a):>10} "
              f"{fmt(it_e):>12} {fmt(it_m):>12} {fmt(it_s):>14}")
        rows.append((lam, kappa, it_b, it_a, it_e))

    # DSBA's iteration growth must be the flattest in kappa
    grow = lambda pair: (pair[1] or MAX_PASSES * q * 10) / max(pair[0] or 1, 1)
    g_b = grow((rows[0][2], rows[-1][2]))
    g_a = grow((rows[0][3], rows[-1][3]))
    print(f"\niteration growth x{g_b:.1f} (DSBA) vs x{g_a:.1f} (DSA) over a "
          f"{rows[-1][1] / rows[0][1]:.0f}x kappa increase")

    # ---- the saddle families (PR 7): iterations to eps on bilinear ------
    # the same table for the minimax family: the scalar-table methods
    # (dsba/dsa) against the variance-reduced descent-ascent (dsgda)
    print(f"\nbilinear minimax (lam=1e-2): "
          f"{'DSBA iters':>11} {'DSA iters':>10} {'DSGDA iters':>12}")
    bproblem = make_problem("bilinear", data, graph, lam=1e-2)
    bproblem.solve_star()
    its = []
    for method, hp in (("dsba", dict(alpha=1.0)), ("dsa", dict(alpha=0.15)),
                       ("dsgda", dict(alpha=0.3, eta=0.3))):
        r = solve(bproblem, method, steps=MAX_PASSES * q, record_every=q,
                  **hp)
        its.append(iters_to_eps(r.dist2, q))
    fmt = lambda v: f"{v}" if v else f">{MAX_PASSES * q}"
    print(f"{'':27}{fmt(its[0]):>11} {fmt(its[1]):>10} {fmt(its[2]):>12}")

    stats = runner_cache_stats()["dense"]
    print(f"wall {time.perf_counter() - t_start:.1f}s; runner cache "
          f"{stats['misses']} compiles / {stats['hits']} warm hits "
          "(one compiled runner per method across the lam sweep)")


if __name__ == "__main__":
    main()

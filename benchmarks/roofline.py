"""Render the roofline table (EXPERIMENTS.md §Roofline) from dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.roofline [--mesh single] [--tag x]
"""
from __future__ import annotations

import argparse
import json
import pathlib

EXP = pathlib.Path(__file__).resolve().parents[1] / "experiments"
DRY = EXP / "dryrun"


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def fmt_b(x):
    for unit, f in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= f:
            return f"{x / f:.1f}{unit}"
    return f"{x:.0f}B"


def load(mesh: str, tag: str = ""):
    recs = []
    suffix = f"_{mesh}{('_' + tag) if tag else ''}.json"
    for p in sorted(DRY.glob(f"*{suffix}")):
        recs.append(json.loads(p.read_text()))
    return recs


def render(mesh: str = "single", tag: str = "") -> str:
    recs = load(mesh, tag)
    if not recs:
        return f"(no dry-run records for mesh={mesh} tag={tag!r})"
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "useful-flop ratio | roofline frac | temp/dev | coll bytes/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if not r.get("ok"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | FAILED: "
                f"{r.get('error', '?')[:60]} | | | | | | | |"
            )
            continue
        rl = r["roofline"]
        lines.append(
            "| {a} | {s} | {c} | {m} | {x} | **{dom}** | {ur:.2f} | {rf:.1%} "
            "| {tmp} | {cb} |".format(
                a=r["arch"], s=r["shape"],
                c=fmt_s(rl["compute_s"]), m=fmt_s(rl["memory_s"]),
                x=fmt_s(rl["collective_s"]), dom=rl["dominant"],
                ur=rl["useful_flop_ratio"], rf=rl["roofline_fraction"],
                tmp=fmt_b(r["memory"]["temp_bytes"]),
                cb=fmt_b(r["collective_bytes"]),
            )
        )
    return "\n".join(lines)


HBM_PER_CHIP = 16e9  # v5e


def render_dryrun(mesh: str = "single", tag: str = "") -> str:
    """§Dry-run table: per-device bytes + collective schedule + compile."""
    recs = load(mesh, tag)
    if not recs:
        return f"(no dry-run records for mesh={mesh})"
    lines = [
        "| arch | shape | state+args/dev | temp/dev | fits 16GB? | "
        "collectives (count) | compile |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | |")
            continue
        m = r["memory"]
        total = m["argument_bytes"] + m["temp_bytes"]
        colls = r["collectives"]["count"]
        cstr = ", ".join(
            f"{k.replace('all-', 'a').replace('collective-', 'c')}:{int(v)}"
            for k, v in sorted(colls.items())
        ) or "none"
        lines.append(
            "| {a} | {s} | {arg} | {tmp} | {fit} | {c} | {t:.0f}s |".format(
                a=r["arch"], s=r["shape"], arg=fmt_b(m["argument_bytes"]),
                tmp=fmt_b(m["temp_bytes"]),
                fit="yes" if total <= HBM_PER_CHIP else
                f"no ({total / HBM_PER_CHIP:.1f}x)",
                c=cstr, t=r.get("compile_s", 0),
            )
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--table", default="roofline",
                    choices=["roofline", "dryrun"])
    args = ap.parse_args()
    if args.table == "dryrun":
        print(render_dryrun(args.mesh, args.tag))
    else:
        print(render(args.mesh, args.tag))


if __name__ == "__main__":
    main()

"""Fill EXPERIMENTS.md table placeholders from recorded dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.render_experiments
"""
from __future__ import annotations

import pathlib
import re

from benchmarks.roofline import render, render_dryrun

EXP = pathlib.Path(__file__).resolve().parents[1] / "EXPERIMENTS.md"

MARKERS = {
    "DRYRUN_SINGLE": lambda: "### Single pod (16x16 = 256 chips)\n\n"
    + render_dryrun("single"),
    "DRYRUN_MULTI": lambda: "### Multi-pod (2x16x16 = 512 chips; pod axis = "
    "DSBA gossip)\n\n" + render_dryrun("multi"),
    "ROOFLINE_SINGLE": lambda: render("single"),
}


def main():
    text = EXP.read_text()
    for name, fn in MARKERS.items():
        marker = f"<!-- {name} -->"
        block_re = re.compile(
            re.escape(marker) + r".*?(?=\n<!-- |\n## |\Z)", re.S
        )
        replacement = marker + "\n\n" + fn() + "\n"
        if marker in text:
            text = block_re.sub(replacement.replace("\\", "\\\\"), text)
    EXP.write_text(text)
    print("EXPERIMENTS.md tables refreshed")


if __name__ == "__main__":
    main()

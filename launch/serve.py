"""Continuous-batching serving driver over the paged cache pool.

A thin driver over ``repro.serve.Scheduler``: submit a synthetic
request trace, drain it, and report throughput plus the per-step
ServeStats counters.  The decode loop runs at a fixed (max_batch, 1)
shape — after warmup the jit trace counts stay frozen no matter how
requests churn (printed at the end as the zero-recompile witness).

    PYTHONPATH=src python launch/serve.py --arch minitron-8b --requests 16
    PYTHONPATH=src python launch/serve.py --arch mamba2-1.3b \
        --max-batch 8 --n-blocks 128
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ALIASES, get_reduced
from repro.models import transformer as T
from repro.models.params import tree_materialize
from repro.serve import PoolConfig, Request, Scheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-8b", choices=list(ALIASES))
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16,
                    help="new tokens per request")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--n-blocks", type=int, default=64)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--prompt-pad", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params = tree_materialize(T.model_defs(cfg), jax.random.PRNGKey(0),
                              cfg.param_dtype)
    pc = PoolConfig(
        max_batch=args.max_batch, block_size=args.block_size,
        n_blocks=args.n_blocks, max_len=args.max_len,
        prompt_pad=args.prompt_pad,
    )
    sch = Scheduler(cfg, params, pc, temperature=args.temperature)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, args.prompt_pad + 1))
        kw = {}
        if cfg.family == "encdec":
            kw["enc_embeds"] = np.asarray(jax.random.normal(
                jax.random.PRNGKey(100 + i),
                (cfg.encoder_len, cfg.d_model),
            ))
        reqs.append(Request(
            rid=i, tokens=rng.integers(0, cfg.vocab_size, size=plen),
            max_new_tokens=args.tokens, **kw,
        ))

    t0 = time.time()
    results, stats = sch.run(reqs)
    wall = time.time() - t0

    total = stats.total_tokens + args.requests  # + one token per prefill
    print(f"arch={args.arch} requests={args.requests} "
          f"max_batch={args.max_batch} pool={args.n_blocks}x{args.block_size}")
    print(f"drained in {len(stats.steps)} steps / {wall:.2f}s "
          f"({total / wall:.0f} tok/s)")
    print(f"peak active slots: {stats.peak_active}/{args.max_batch}  "
          f"peak pool occupancy: {stats.peak_occupancy:.2f}  "
          f"preemptions: {stats.preemptions}")
    print(f"jit traces (frozen after warmup): {sch.trace_counts}")
    for r in reqs[:2]:
        print(f"  request[{r.rid}] generated ids: {results[r.rid][:12]} ...")


if __name__ == "__main__":
    main()

"""Paper Figure-1-style experiment: DSBA vs DSA vs EXTRA vs DLM vs SSDA on
sparse ridge regression, reporting suboptimality vs effective passes AND
communication cost C_max (DOUBLEs received by the hottest node).

    PYTHONPATH=src python examples/decentralized_ridge.py [--dataset small]
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)


from repro.core import mixing, reference
from repro.core.baselines import run_dlm, run_extra, run_ssda
from repro.core.dsba import DSBAConfig, run
from repro.core.operators import OperatorSpec
from repro.core.sparse_comm import dense_doubles_per_iter, sparse_doubles_per_iter
from repro.data.synthetic import DATASET_PRESETS, make_regression


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="small", choices=list(DATASET_PRESETS))
    ap.add_argument("--q", type=int, default=50)
    ap.add_argument("--passes", type=int, default=40)
    args = ap.parse_args()

    p = DATASET_PRESETS[args.dataset]
    d = min(p["d"], 4000)  # cap for the CPU reference solve
    N = 10
    data = make_regression(N, args.q, d, k=p["k"], seed=0)
    graph = mixing.erdos_renyi_graph(N, 0.4, seed=1)
    W = mixing.laplacian_mixing(graph)
    spec = OperatorSpec("ridge")
    lam = 1.0 / (10 * data.total)
    z_star = reference.solve_root(spec, data, lam)

    q = data.q
    stoch_steps = args.passes * q  # 1 effective pass = q stochastic steps
    det_steps = args.passes  # deterministic methods touch all data per step

    results = {}
    res = run(DSBAConfig(spec, 0.5, lam), data, W, stoch_steps,
              z_star=z_star, record_every=q)
    results["DSBA"] = (res.iters / q, res.dist2)
    res = run(DSBAConfig(spec, 0.2, lam, method="dsa"), data, W, stoch_steps,
              z_star=z_star, record_every=q)
    results["DSA"] = (res.iters / q, res.dist2)
    res = run_extra(spec, data, W, alpha=0.3, lam=lam, steps=det_steps,
                    z_star=z_star, record_every=1)
    results["EXTRA"] = (res.iters, res.dist2)
    res = run_dlm(spec, data, graph, c=0.3, beta=1.0, lam=lam, steps=det_steps,
                  z_star=z_star, record_every=1)
    results["DLM"] = (res.iters, res.dist2)
    # SSDA's dual step must satisfy eta < 2*lam/||I-W||: tiny at the
    # paper's lambda = 1/(10Q) conditioning
    res = run_ssda(spec, data, W, eta=1e-4, momentum=0.0, lam=lam,
                   steps=det_steps, z_star=z_star, record_every=1)
    results["SSDA"] = (res.iters, res.dist2)

    print(f"\ndataset={args.dataset} d={d} rho={data.rho:.4f} "
          f"N={N} q={q} lam={lam:.2e}")
    print(f"{'passes':>7}", *[f"{m:>12}" for m in results])
    idx = range(0, args.passes, max(1, args.passes // 10))
    for i in idx:
        row = [f"{i + 1:7d}"]
        for m, (xs, ys) in results.items():
            j = min(i, len(ys) - 1)
            row.append(f"{ys[j]:12.2e}")
        print(*row)

    # communication cost per effective pass (DOUBLEs at the hottest node)
    dense = int(dense_doubles_per_iter(graph, d).max())
    sparse = sparse_doubles_per_iter(N, data.k, 0)
    print("\ncommunication per effective pass (hottest node, DOUBLEs):")
    print(f"  dense methods (EXTRA/DLM/SSDA): {dense}  (deg*d per iter x 1)")
    print(f"  DSBA/DSA dense exchange       : {dense * q}")
    print(f"  DSBA-s sparse exchange        : {sparse * q}   "
          f"({dense * q / (sparse * q):.1f}x less than dense stochastic)")


if __name__ == "__main__":
    main()

"""Paper Figure-1-style experiment: DSBA vs DSA vs EXTRA vs DLM vs SSDA on
sparse ridge regression, reporting suboptimality vs effective passes AND
communication cost C_max (DOUBLEs received by the hottest node).

Every method runs through the one registry entrypoint
``core.solvers.solve``; the communication numbers come straight from the
uniform ``SolveResult.doubles_received`` accounting (closed-form relay
accounting for the sparse runs, deg*d dense exchange otherwise).

    PYTHONPATH=src python examples/decentralized_ridge.py [--dataset small]
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)


from repro.core import mixing
from repro.core.solvers import make_problem, solve
from repro.core.sparse_comm import sparse_doubles_per_iter
from repro.data.synthetic import DATASET_PRESETS, make_regression


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="small", choices=list(DATASET_PRESETS))
    ap.add_argument("--q", type=int, default=50)
    ap.add_argument("--passes", type=int, default=40)
    ap.add_argument("--d", type=int, default=None,
                    help="override the preset dimension (smoke tests)")
    args = ap.parse_args(argv)

    p = DATASET_PRESETS[args.dataset]
    d = min(p["d"], 4000) if args.d is None else args.d  # cap: CPU ref solve
    k = min(p["k"], max(1, d // 2))
    N = 10
    data = make_regression(N, args.q, d, k=k, seed=0)
    graph = mixing.erdos_renyi_graph(N, 0.4, seed=1)
    problem = make_problem("ridge", data, graph)  # lam = 1/(10 Q)
    problem.solve_star()

    q = data.q
    stoch_steps = args.passes * q  # 1 effective pass = q stochastic steps
    det_steps = args.passes  # deterministic methods touch all data per step

    results = {}
    res = solve(problem, "dsba", steps=stoch_steps, record_every=q, alpha=0.5)
    results["DSBA"] = (res.iters / q, res.dist2)
    res = solve(problem, "dsa", steps=stoch_steps, record_every=q, alpha=0.2)
    results["DSA"] = (res.iters / q, res.dist2)
    res = solve(problem, "extra", steps=det_steps, record_every=1, alpha=0.3)
    results["EXTRA"] = (res.iters, res.dist2)
    res = solve(problem, "dlm", steps=det_steps, record_every=1, c=0.3, beta=1.0)
    results["DLM"] = (res.iters, res.dist2)
    # SSDA's dual step must satisfy eta < 2*lam/||I-W||: tiny at the
    # paper's lambda = 1/(10Q) conditioning
    res = solve(problem, "ssda", steps=det_steps, record_every=1,
                eta=1e-4, momentum=0.0)
    results["SSDA"] = (res.iters, res.dist2)
    dense_res = res  # any dense run carries the deg*d accounting

    print(f"\ndataset={args.dataset} d={d} rho={data.rho:.4f} "
          f"N={N} q={q} lam={problem.lam:.2e}")
    print(f"{'passes':>7}", *[f"{m:>12}" for m in results])
    idx = range(0, args.passes, max(1, args.passes // 10))
    for i in idx:
        row = [f"{i + 1:7d}"]
        for m, (xs, ys) in results.items():
            j = min(i, len(ys) - 1)
            row.append(f"{ys[j]:12.2e}")
        print(*row)

    # communication cost per effective pass (DOUBLEs at the hottest node):
    # dense methods from the SolveResult accounting, DSBA-s from the relay's
    # closed-form steady state
    dense = int(dense_res.doubles_received[-1].max() // dense_res.iters[-1])
    sparse = sparse_doubles_per_iter(N, data.k, 0)
    print("\ncommunication per effective pass (hottest node, DOUBLEs):")
    print(f"  dense methods (EXTRA/DLM/SSDA): {dense}  (deg*d per iter x 1)")
    print(f"  DSBA/DSA dense exchange       : {dense * q}")
    print(f"  DSBA-s sparse exchange        : {sparse * q}   "
          f"({dense * q / (sparse * q):.1f}x less than dense stochastic)")
    return results


if __name__ == "__main__":
    main()

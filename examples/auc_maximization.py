"""Paper Figure-3 experiment: decentralized l2-relaxed AUC maximization.

AUC involves PAIRWISE losses that classic decentralized methods cannot
handle with one sample per step; the saddle reformulation (Ying et al. 2016,
eq. 11-12) + DSBA's monotone-operator view makes it a one-sample-per-step
decentralized problem with closed-form resolvents (paper appendix 9.7).

    PYTHONPATH=src python examples/auc_maximization.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import mixing, reference
from repro.core.dsba import DSBAConfig, run
from repro.core.operators import OperatorSpec
from repro.data.synthetic import make_classification


def main():
    N, q, d = 10, 50, 300
    data = make_classification(N, q, d, k=10, positive_ratio=0.25, seed=0)
    graph = mixing.erdos_renyi_graph(N, 0.4, seed=1)
    W = mixing.laplacian_mixing(graph)
    p = data.positive_ratio()
    spec = OperatorSpec("auc", p=p)
    lam = 1.0 / (10 * data.total)
    z_star = reference.solve_root(spec, data, lam)

    cfg = DSBAConfig(spec, alpha=1.0, lam=lam)
    res = run(cfg, data, W, steps=30 * q, z_star=z_star, record_every=2 * q,
              keep_snapshots=True)

    print(f"positive ratio p = {p:.3f};  z in R^{d + 3} = [w; a; b; theta]")
    print(f"{'passes':>7} {'dist^2 to saddle':>18} {'AUC (node mean)':>16}")
    for i, (it, d2) in enumerate(zip(res.iters, res.dist2)):
        w_nodes = res.zs[i][:, :d]
        auc = np.mean([reference.auc_score(w, data) for w in w_nodes])
        print(f"{it // q:7d} {d2:18.3e} {auc:16.4f}")
    auc_star = reference.auc_score(z_star[:d], data)
    print(f"\nAUC at the exact saddle point: {auc_star:.4f}")


if __name__ == "__main__":
    main()

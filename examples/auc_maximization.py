"""Paper Figure-3 experiment: decentralized l2-relaxed AUC maximization.

AUC involves PAIRWISE losses that classic decentralized methods cannot
handle with one sample per step; the saddle reformulation (Ying et al. 2016,
eq. 11-12) + DSBA's monotone-operator view makes it a one-sample-per-step
decentralized problem with closed-form resolvents (paper appendix 9.7).

    PYTHONPATH=src python examples/auc_maximization.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import mixing, reference
from repro.core.solvers import make_problem, solve
from repro.data.synthetic import make_classification


def main(passes=30, record_passes=2):
    N, q, d = 10, 50, 300
    data = make_classification(N, q, d, k=10, positive_ratio=0.25, seed=0)
    graph = mixing.erdos_renyi_graph(N, 0.4, seed=1)
    problem = make_problem("auc", data, graph)  # z = [w; a; b; theta]
    z_star = problem.solve_star()
    p = problem.spec.p

    res = solve(problem, "dsba", steps=passes * q, record_every=record_passes * q,
                alpha=1.0, keep_snapshots=True)

    print(f"positive ratio p = {p:.3f};  z in R^{d + 3} = [w; a; b; theta]")
    print(f"{'passes':>7} {'dist^2 to saddle':>18} {'AUC (node mean)':>16}")
    for i, (it, d2) in enumerate(zip(res.iters, res.dist2)):
        w_nodes = res.zs[i][:, :d]
        auc = np.mean([reference.auc_score(w, data) for w in w_nodes])
        print(f"{it // q:7d} {d2:18.3e} {auc:16.4f}")
    auc_star = reference.auc_score(z_star[:d], data)
    print(f"\nAUC at the exact saddle point: {auc_star:.4f}")
    return res


if __name__ == "__main__":
    main()

"""Batched serving example: prefill a batch of prompts, then decode.

A thin driver over ``repro.serve.engine.generate`` — the shared
prefill + incremental-decode loop (contiguous caches, one jitted step).
For continuous batching over the paged cache pool, see
``launch/serve.py``.

    PYTHONPATH=src python examples/serve_decode.py --arch mamba2-1.3b
    PYTHONPATH=src python examples/serve_decode.py --arch gemma2-2b --tokens 32
"""
import argparse

import jax

from repro.configs import ALIASES, get_reduced
from repro.models import transformer as T
from repro.models.params import tree_materialize
from repro.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-8b", choices=list(ALIASES))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params = tree_materialize(T.model_defs(cfg), jax.random.PRNGKey(0),
                              cfg.param_dtype)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size,
    )
    enc = None
    if cfg.family == "encdec":
        enc = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.encoder_len, cfg.d_model)
        )

    res = generate(
        cfg, params, prompts, max_new_tokens=args.tokens,
        temperature=args.temperature, enc_embeds=enc,
    )

    print(f"arch={args.arch} batch={args.batch} "
          f"prompt={args.prompt_len} new_tokens={args.tokens}")
    print(f"prefill: {res.prefill_s * 1e3:.1f} ms "
          f"({res.prefill_tok_s:.0f} tok/s)")
    print(f"decode : {res.decode_s * 1e3:.1f} ms "
          f"({res.decode_tok_s:.0f} tok/s)")
    for b in range(min(2, args.batch)):
        print(f"  sample[{b}] generated ids: {res.tokens[b][:12]} ...")


if __name__ == "__main__":
    main()

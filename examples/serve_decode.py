"""Batched serving driver: prefill a batch of prompts, then decode tokens.

Exercises the real serving path (KV/SSM caches, prefill -> incremental
decode) on any assigned architecture's reduced config:

    PYTHONPATH=src python examples/serve_decode.py --arch mamba2-1.3b
    PYTHONPATH=src python examples/serve_decode.py --arch gemma2-2b --tokens 32
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALIASES, get_reduced
from repro.models import transformer as T
from repro.models.params import tree_materialize


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-8b", choices=list(ALIASES))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params = tree_materialize(T.model_defs(cfg), jax.random.PRNGKey(0),
                              cfg.param_dtype)
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    max_len = args.prompt_len + args.tokens
    cache = T.init_cache(cfg, args.batch, max_len)
    if cfg.family == "encdec":
        enc = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.encoder_len, cfg.d_model)
        )
        cache["cross"] = T.encode_cross_cache(cfg, params, enc, args.batch)

    prefill = jax.jit(lambda p, t, c: T.decode_step(cfg, p, t, c))
    decode = jax.jit(lambda p, t, c: T.decode_step(cfg, p, t, c))

    t0 = time.time()
    cache, logits = prefill(params, prompts, cache)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    out = []
    tok = jnp.argmax(logits, -1)[:, None]
    t0 = time.time()
    for i in range(args.tokens):
        out.append(tok)
        cache, logits = decode(params, tok, cache)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits, -1)[:, None]
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"arch={args.arch} batch={args.batch} "
          f"prompt={args.prompt_len} new_tokens={args.tokens}")
    print(f"prefill: {t_prefill * 1e3:.1f} ms "
          f"({args.batch * args.prompt_len / t_prefill:.0f} tok/s)")
    print(f"decode : {t_decode * 1e3:.1f} ms "
          f"({args.batch * args.tokens / t_decode:.0f} tok/s)")
    for b in range(min(2, args.batch)):
        print(f"  sample[{b}] generated ids: {gen[b][:12]} ...")


if __name__ == "__main__":
    main()

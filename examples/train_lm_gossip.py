"""End-to-end decentralized LM training driver.

Trains a transformer with the POD-AXIS DSBA gossip optimizer (the paper's
technique at datacenter scale): P simulated pods, each with its own replica
and data shard, exchanging extrapolated parameters with ring neighbors only
— optionally with top-k compressed delta streams. Includes checkpointing
with exact resume and an elastic pod-failure drill.

    PYTHONPATH=src python examples/train_lm_gossip.py --steps 200
    PYTHONPATH=src python examples/train_lm_gossip.py --model 100m --steps 300
    PYTHONPATH=src python examples/train_lm_gossip.py --compression topk

On this CPU container the default model is small; --model 100m selects a
~100M-param config (same code path, budget wall time accordingly).
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs import get_reduced
from repro.core.gossip import (
    GossipConfig, consensus_distance, init_gossip_state,
    make_gossip_train_step,
)
from repro.data.sharded_loader import LoaderConfig, batch_at
from repro.ft import ElasticGossip
from repro.models.config import ModelConfig
from repro.optim.adam import AdamConfig
from repro.train.step import TrainConfig

MODELS = {
    "tiny": lambda: dataclasses.replace(
        get_reduced("minitron_8b"), n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=512, vocab_size=4096),
    "100m": lambda: dataclasses.replace(
        get_reduced("minitron_8b"), n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, head_dim=64, d_ff=3072, vocab_size=32_768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="tiny", choices=list(MODELS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--pods", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch-per-pod", type=int, default=4)
    ap.add_argument("--mode", default="dsba",
                    choices=["dsba", "dsgd", "allreduce"])
    ap.add_argument("--compression", default="none", choices=["none", "topk"])
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_gossip_ckpt")
    ap.add_argument("--kill-pod-at", type=int, default=0,
                    help="simulate pod failure at this step (0 = off)")
    args = ap.parse_args()

    cfg: ModelConfig = MODELS[args.model]()
    # dsba mode is the plain-SGD EXTRA structure (needs a real step size);
    # dsgd/allreduce modes are Adam-preconditioned
    lr = 0.5 if args.mode == "dsba" else 3e-3
    tc = TrainConfig(optimizer=AdamConfig(lr=lr, warmup_steps=20))
    gc = GossipConfig(n_pods=args.pods, mode=args.mode,
                      compression=args.compression, topk_ratio=0.05)
    from repro.models.params import tree_num_params
    from repro.models.transformer import model_defs
    print(f"model={args.model} params={tree_num_params(model_defs(cfg)):,} "
          f"pods={gc.n_pods} mode={gc.mode} compression={gc.compression}")

    ld_cfg = LoaderConfig(cfg.vocab_size, args.pods * args.batch_per_pod,
                          args.seq, n_shards=args.pods)
    mgr = CheckpointManager(args.ckpt_dir)
    state = init_gossip_state(cfg, tc, gc, jax.random.PRNGKey(0))
    try:
        restored, at = mgr.restore(state)
    except ValueError as e:
        print(f"checkpoint incompatible ({e}); starting fresh")
        restored = None
    if restored is not None:
        state = restored
        print(f"resumed from step {at}")
    step_fn = jax.jit(make_gossip_train_step(None, cfg, tc, gc))

    t0 = time.time()
    start = int(state["step"])
    for i in range(start, args.steps):
        b = batch_at(ld_cfg, i)
        batch = {
            k: np.asarray(v).reshape(args.pods, args.batch_per_pod, -1)
            for k, v in b.items()
        }
        state, m = step_fn(state, batch)

        if args.kill_pod_at and i == args.kill_pod_at:
            el = ElasticGossip(gc)
            state, gc = el.shrink(state, dead=[gc.n_pods - 1])
            step_fn = jax.jit(make_gossip_train_step(None, cfg, tc, gc))
            batch_pods = gc.n_pods
            print(f"[ft] pod killed at step {i}: continuing with "
                  f"{gc.n_pods} pods (no global restart)")
            args.pods = batch_pods
            ld_cfg = LoaderConfig(cfg.vocab_size,
                                  args.pods * args.batch_per_pod, args.seq,
                                  n_shards=args.pods)

        if i % 20 == 0 or i == args.steps - 1:
            cons = float(consensus_distance(state["params"]))
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"consensus {cons:.3e}  "
                  f"({(time.time() - t0) / max(1, i - start + 1):.2f}s/step)")
        if args.ckpt_every and i and i % args.ckpt_every == 0:
            mgr.save(i, state, async_=True)
    mgr.wait()
    print("done.")


if __name__ == "__main__":
    main()

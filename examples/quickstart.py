"""Quickstart: decentralized ridge regression with DSBA in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)


from repro.core import mixing, reference
from repro.core.dsba import DSBAConfig, run
from repro.core.operators import OperatorSpec
from repro.data.synthetic import make_regression

# 10 nodes, Erdos-Renyi(0.4) topology — the paper's setup (Section 7)
N, Q_PER_NODE, DIM = 10, 50, 200
data = make_regression(n_nodes=N, q=Q_PER_NODE, d=DIM, k=10, seed=0)
graph = mixing.erdos_renyi_graph(N, 0.4, seed=1)
W = mixing.laplacian_mixing(graph)

spec = OperatorSpec("ridge")
lam = 1.0 / (10 * data.total)  # paper: lambda = 1/(10 Q)
z_star = reference.solve_root(spec, data, lam)

cfg = DSBAConfig(spec=spec, alpha=2.0, lam=lam)  # backward steps: large alpha is stable
res = run(cfg, data, W, steps=8000, z_star=z_star, record_every=500)

print("iter   mean ||z_n - z*||^2      consensus error")
for it, d2, ce in zip(res.iters, res.dist2, res.consensus):
    print(f"{it:5d}   {d2:20.3e}   {ce:16.3e}")
print(f"\nlinear convergence to the centralized optimum: {res.dist2[-1]:.2e}")

"""Quickstart: decentralized ridge regression with DSBA in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)


from repro.core import mixing
from repro.core.solvers import make_problem, solve
from repro.data.synthetic import make_regression


def main(steps=8000, record_every=500):
    # 10 nodes, Erdos-Renyi(0.4) topology — the paper's setup (Section 7)
    N, Q_PER_NODE, DIM = 10, 50, 200
    data = make_regression(n_nodes=N, q=Q_PER_NODE, d=DIM, k=10, seed=0)
    graph = mixing.erdos_renyi_graph(N, 0.4, seed=1)

    problem = make_problem("ridge", data, graph)  # lam = 1/(10 Q), W Laplacian
    problem.solve_star()  # centralized root, cached on the problem

    # backward steps: large alpha is stable
    res = solve(problem, method="dsba", steps=steps,
                record_every=record_every, alpha=2.0)

    print("iter   mean ||z_n - z*||^2      consensus error")
    for it, d2, ce in zip(res.iters, res.dist2, res.consensus):
        print(f"{it:5d}   {d2:20.3e}   {ce:16.3e}")
    print(f"\nlinear convergence to the centralized optimum: {res.dist2[-1]:.2e}")
    return res


if __name__ == "__main__":
    main()

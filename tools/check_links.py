"""Dead-relative-link check for the markdown docs.

    python tools/check_links.py [files...]

With no arguments, checks README.md, ROADMAP.md, and every .md under
docs/. For each markdown link or image `[text](target)`:

- http(s)/mailto targets are skipped (no network in CI),
- pure-anchor targets (`#section`) are skipped,
- targets that resolve OUTSIDE the repo root are skipped (GitHub
  site-relative URLs like the CI badge's `../../actions/...`),
- everything else must exist on disk relative to the file containing the
  link (a `#fragment` suffix is stripped first).

Exits non-zero listing every dead link. Run by the CI lint job and by
tests/test_docs_links.py.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")


def default_files() -> list[pathlib.Path]:
    files = [ROOT / "README.md", ROOT / "ROADMAP.md"]
    files += sorted((ROOT / "docs").glob("**/*.md"))
    return [f for f in files if f.exists()]


def dead_links(md_file: pathlib.Path) -> list[str]:
    dead = []
    for target in LINK_RE.findall(md_file.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = (md_file.parent / path).resolve()
        if not resolved.is_relative_to(ROOT):
            continue  # site-relative (escapes the repo): not checkable
        if not resolved.exists():
            dead.append(f"{md_file.relative_to(ROOT)}: ({target}) -> "
                        f"{resolved.relative_to(ROOT)} does not exist")
    return dead


def main(argv: list[str]) -> int:
    files = [pathlib.Path(a).resolve() for a in argv] or default_files()
    failures = [msg for f in files for msg in dead_links(f)]
    for msg in failures:
        print(f"DEAD LINK  {msg}")
    if failures:
        print(f"\n{len(failures)} dead link(s)")
        return 1
    print(f"checked {len(files)} file(s): all relative links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

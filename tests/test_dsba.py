"""End-to-end convergence of DSBA (Algorithm 1) and Remark 5.1 degeneracies,
driven through the one registry entrypoint `core.solvers.solve`."""
import numpy as np
import pytest

from repro.core import mixing
from repro.core.solvers import Problem, make_problem, solve
from repro.core.operators import OperatorSpec
from repro.data.synthetic import make_classification, make_regression


def _setup(task="ridge", n_nodes=6, q=20, d=30, seed=0, positive_ratio=0.3,
           lam=None):
    if task == "ridge":
        data = make_regression(n_nodes, q, d, k=6, seed=seed)
    elif task == "logistic":
        data = make_classification(n_nodes, q, d, k=6, seed=seed)
    else:
        data = make_classification(
            n_nodes, q, d, k=6, positive_ratio=positive_ratio, seed=seed
        )
    graph = mixing.erdos_renyi_graph(n_nodes, 0.4, seed=1)
    problem = make_problem(task, data, graph, lam=lam)  # lam None -> 1/(10Q)
    problem.solve_star()
    return problem


# backward (resolvent) steps stay stable at large alpha — a DSBA selling point
ALPHAS = {"ridge": 0.5, "logistic": 4.0, "auc": 1.0}


@pytest.mark.parametrize("task", ["ridge", "logistic", "auc"])
def test_dsba_converges_to_centralized_root(task):
    problem = _setup(task)
    res = solve(problem, "dsba", steps=4000, record_every=200,
                alpha=ALPHAS[task])
    assert res.dist2[-1] < 1e-12, f"{task}: dist2={res.dist2[-1]:.3e}"
    assert res.consensus[-1] < 1e-12


def test_dsba_linear_convergence_rate():
    """dist^2 should decay geometrically: check log-linear slope."""
    problem = _setup("ridge")
    res = solve(problem, "dsba", steps=3000, record_every=100, alpha=0.5)
    logs = np.log10(np.maximum(res.dist2, 1e-300))
    # strictly decreasing after warmup and large total drop
    assert logs[-1] < logs[2] - 6.0
    drops = np.diff(logs[2:])
    assert (drops < 0.2).all()  # monotone-ish decay


def test_dsa_recovered_and_converges():
    """Remark 5.1: forward-delta variant is DSA; both converge to the same
    root, DSBA at least as fast at its (larger stable) step size."""
    problem = _setup("ridge")
    steps = 6000
    res_b = solve(problem, "dsba", steps=steps, alpha=0.5)
    res_a = solve(problem, "dsa", steps=steps, alpha=0.2)
    assert res_b.dist2[-1] < 1e-16
    assert res_a.dist2[-1] < 1e-10  # DSA converges too (smaller stable alpha)
    assert res_b.dist2[-1] <= res_a.dist2[-1]


def test_single_node_dsba_is_point_saga():
    """N=1: no mixing; DSBA == Point-SAGA (Defazio 2016) — converges to the
    local regularized root."""
    data = make_regression(n_nodes=1, q=40, d=20, k=5, seed=3)
    problem = Problem(
        spec=OperatorSpec("ridge"), data=data, graph=mixing.Graph(1, ()),
        w=np.ones((1, 1)), lam=1e-3,
    )
    problem.solve_star()
    res = solve(problem, "dsba", steps=3000, record_every=100, alpha=1.0)
    assert res.dist2[-1] < 1e-14


def test_dsba_iterates_satisfy_resolvent_identity():
    """Internal consistency: every update solves
    (1+alpha*lam) z_new + alpha B_{n,i}(z_new) = psi, so the table coeff at
    the sampled index must equal g(x^T z_new)."""
    problem = _setup("ridge", n_nodes=3, q=5, d=10)
    res = solve(problem, "dsba", steps=50, record_every=50, alpha=0.5)
    st = res.state
    data = problem.data
    # recompute coeffs at current z for every (n, i): table rows touched most
    # recently must match exactly
    z = np.asarray(st.z)
    idx, val, y = data.idx, data.val, data.y
    u = np.einsum("nqk,nqk->nq", val, z[np.arange(3)[:, None, None], idx])
    g = u - y
    table = np.asarray(st.table_g)
    # each row i of the table was set to g(x_i^T z^{t_i+1}) for the step t_i
    # when i was last sampled; for the LAST sampled index per node it must
    # match the current iterate's coefficient.
    # We can't know which index was last sampled from outside, so check that
    # at least one index per node matches the current-z coefficient.
    match = np.isclose(table, g, atol=1e-10).any(axis=1)
    assert match.all()


def test_extra_dlm_ssda_converge():
    # well-conditioned setup (lam=0.05): these tests verify implementation
    # correctness; the paper-regime comparison lives in benchmarks/.
    problem = _setup("ridge", n_nodes=5, q=20, d=12, lam=0.05)

    res_e = solve(problem, "extra", steps=2000, record_every=100, alpha=0.3)
    assert res_e.dist2[-1] < 1e-10, f"EXTRA {res_e.dist2[-1]:.2e}"

    res_d = solve(problem, "dlm", steps=4000, record_every=200, c=0.3, beta=1.0)
    assert res_d.dist2[-1] < 1e-8, f"DLM {res_d.dist2[-1]:.2e}"

    res_s = solve(problem, "ssda", steps=2000, record_every=200,
                  eta=0.03, momentum=0.5)
    assert res_s.dist2[-1] < 1e-10, f"SSDA {res_s.dist2[-1]:.2e}"


def test_ssda_logistic_inner_newton():
    problem = _setup("logistic", n_nodes=4, q=16, d=8, lam=0.1)
    res = solve(problem, "ssda", steps=1500, record_every=300,
                eta=0.05, momentum=0.5)
    assert res.dist2[-1] < 1e-10, f"SSDA-logistic {res.dist2[-1]:.2e}"

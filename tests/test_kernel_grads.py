"""Blocked backward kernels vs ref autodiff, via the registry's grad policy.

Two claims per differentiable kernel (flash_attention, ssd_chunk):

  1. PARITY — the registry-resolved custom_vjp backward matches plain jax
     autodiff of the pure-jnp oracle within the declared grad tolerance,
     over the statics grid (GQA / window / softcap) x dtype x mode.
  2. MEMORY — the blocked backward never materializes an S x S
     intermediate (checked structurally on the jaxpr, where the dense
     oracle's autodiff provably does).

The exhaustive grid is marked `slow` (CI's full run); the default run keeps
one representative per claim, matching the repo's sweep convention.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention

FLASH_STATICS = [
    (True, None, None),   # plain causal
    (True, 64, None),     # sliding window
    (True, None, 30.0),   # softcap (gemma2)
    (False, None, None),  # bidirectional
    (True, 64, 30.0),     # window + softcap
]


def _flash_args(key, dtype, Hkv=2):
    B, Hq, S, D = 1, 4, 96, 32  # GQA (Hq != Hkv), ragged seq (96 % 64 != 0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Hq, S, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), dtype)
    return q, k, v


def _ssd_args(key, nh=4, dtype=jnp.float32):
    B, nc, Q, hd, ds = 1, 2, 32, 16, 8
    ks = jax.random.split(key, 4)
    xdt = jax.random.normal(ks[0], (B, nc, Q, nh, hd), dtype)
    cum = -jnp.cumsum(
        jax.random.uniform(ks[1], (B, nc, Q, nh), dtype,
                           minval=0.01, maxval=0.2), axis=2)
    Bc = jax.random.normal(ks[2], (B, nc, Q, ds), dtype)
    Cc = jax.random.normal(ks[3], (B, nc, Q, ds), dtype)
    return xdt, cum, Bc, Cc


# ---------------------------------------------------------------------------
# parity: registry-resolved vjp == ref autodiff (representatives, fast)
# ---------------------------------------------------------------------------

def test_flash_vjp_parity_representative():
    q, k, v = _flash_args(jax.random.PRNGKey(0), jnp.float32)
    err = ops.parity_check("flash_attention", q, k, v, causal=True,
                           grads=True)
    assert np.isfinite(err)


def test_flash_vjp_parity_bf16_window_softcap():
    q, k, v = _flash_args(jax.random.PRNGKey(1), jnp.bfloat16)
    err = ops.parity_check("flash_attention", q, k, v, causal=True,
                           window=64, softcap=30.0, grads=True)
    assert np.isfinite(err)


def test_ssd_vjp_parity_representative():
    args = _ssd_args(jax.random.PRNGKey(2))
    err = ops.parity_check("ssd_chunk", *args, grads=True)
    assert np.isfinite(err)


# ---------------------------------------------------------------------------
# parity: the full statics grid (slow; CI's -m "" run)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("causal,window,softcap", FLASH_STATICS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["interpret", "off"])
def test_flash_vjp_grid(causal, window, softcap, dtype, mode):
    q, k, v = _flash_args(jax.random.PRNGKey(3), dtype)
    err = ops.parity_check(
        "flash_attention", q, k, v, use_pallas=mode, causal=causal,
        window=window, softcap=softcap, grads=True,
    )
    assert np.isfinite(err)


@pytest.mark.slow
def test_flash_vjp_mqa():
    q, k, v = _flash_args(jax.random.PRNGKey(4), jnp.float32, Hkv=1)
    err = ops.parity_check("flash_attention", q, k, v, causal=True,
                           grads=True)
    assert np.isfinite(err)


@pytest.mark.slow
@pytest.mark.parametrize("nh", [2, 3, 4])  # 3 exercises the odd head_block
@pytest.mark.parametrize("mode", ["interpret", "off"])
def test_ssd_vjp_grid(nh, mode):
    args = _ssd_args(jax.random.PRNGKey(5), nh=nh)
    err = ops.parity_check("ssd_chunk", *args, use_pallas=mode, grads=True)
    assert np.isfinite(err)


def test_grad_policy_declared_and_nondiff_rejected():
    """Grad tolerances live in the registry; kernels without grad_argnums
    are rejected by the grads harness instead of failing deep in jax.vjp."""
    fa = ops.get_kernel("flash_attention")
    assert fa.grad_argnums == (0, 1, 2)
    assert fa.grad_tolerance(jnp.float32).atol == 2e-4
    assert fa.grad_tolerance(jnp.bfloat16).atol == 5e-2
    ssd = ops.get_kernel("ssd_chunk")
    assert ssd.grad_argnums == (0, 1, 2, 3)
    # undeclared dtype falls back to the f32 grad entry
    assert ssd.grad_tolerance(jnp.bfloat16) == ssd.grad_tolerance(jnp.float32)
    # sparse kernels carry int index args: no differentiable surface
    sd = ops.get_kernel("sparse_dot")
    assert sd.grad_argnums is None
    # grad_tol=None falls back to the FORWARD tolerance map
    assert sd.grad_tolerance(jnp.float64) == sd.tolerance(jnp.float64)
    with pytest.raises(ValueError, match="grad_argnums"):
        x = jnp.ones((4, 16))
        ops.parity_check("sparse_dot", x, jnp.zeros((4, 2), jnp.int32),
                         jnp.ones((4, 2)), grads=True)


# ---------------------------------------------------------------------------
# memory: the blocked backward has no S x S intermediate
# ---------------------------------------------------------------------------

def _jaxprs(closed):
    """Yield a jaxpr and every sub-jaxpr reachable through eqn params."""
    jaxpr_cls = type(closed.jaxpr)
    closed_cls = type(closed)

    def walk(j):
        yield j
        for eqn in j.eqns:
            for val in jax.tree_util.tree_leaves(
                eqn.params, is_leaf=lambda x: isinstance(
                    x, (jaxpr_cls, closed_cls))
            ):
                if isinstance(val, closed_cls):
                    yield from walk(val.jaxpr)
                elif isinstance(val, jaxpr_cls):
                    yield from walk(val)

    yield from walk(closed.jaxpr)


def _has_square_aval(closed, s: int) -> bool:
    """True if any var anywhere in the program has two trailing dims >= s."""
    for j in _jaxprs(closed):
        for eqn in j.eqns:
            for v in list(eqn.invars) + list(eqn.outvars):
                shape = getattr(getattr(v, "aval", None), "shape", ())
                if len(shape) >= 2 and shape[-1] >= s and shape[-2] >= s:
                    return True
    return False


def test_blocked_bwd_never_materializes_s_by_s():
    B, Hq, Hkv, S, D = 1, 2, 1, 256, 32
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    q = jax.random.normal(ks[0], (B, Hq, S, D))
    k = jax.random.normal(ks[1], (B, Hkv, S, D))
    v = jax.random.normal(ks[2], (B, Hkv, S, D))
    do = jax.random.normal(ks[3], (B, Hq, S, D))

    def kernel_grads(q, k, v, do):
        out, pullback = jax.vjp(
            lambda q, k, v: flash_attention(q, k, v, True, None, None,
                                            64, 64, True), q, k, v)
        return pullback(do)

    closed = jax.make_jaxpr(kernel_grads)(q, k, v, do)
    assert not _has_square_aval(closed, S), (
        "blocked backward materialized an S x S buffer")

    # control: the dense oracle's autodiff DOES hold (S, S) probabilities —
    # proves the structural check can actually see such a buffer
    def ref_grads(q, k, v, do):
        out, pullback = jax.vjp(
            lambda q, k, v: R.attention_ref(q, k, v, causal=True), q, k, v)
        return pullback(do)

    dense = jax.make_jaxpr(ref_grads)(q, k, v, do)
    assert _has_square_aval(dense, S)

"""Pod-axis decentralized training: convergence, consensus, compression.

Runs on a small multi-device CPU mesh (subprocess-free: uses the 8 host
devices configured in tests/conftest_mesh — NO, we keep 1 device here and
test the mesh path in the dry-run subprocess test). Here: mesh=None paths
exercise the math; tiny real-mesh paths are covered by test_dryrun_small.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.gossip import (
    GossipConfig,
    consensus_distance,
    init_gossip_state,
    leaf_k,
    make_gossip_train_step,
    scatter_decompress,
    topk_compress,
)
from repro.optim.adam import AdamConfig
from repro.train.step import TrainConfig


def _toy_setup(mode, compression="none", n_pods=4):
    cfg = dataclasses.replace(get_reduced("minitron_8b"), n_layers=1)
    # dsba mode is plain-SGD EXTRA structure -> needs a real step size;
    # adam modes use a small lr
    lr = 0.5 if mode == "dsba" else 1e-2
    tc = TrainConfig(optimizer=AdamConfig(lr=lr, warmup_steps=1))
    gc = GossipConfig(n_pods=n_pods, mode=mode, compression=compression,
                      topk_ratio=0.25)
    state = init_gossip_state(cfg, tc, gc, jax.random.PRNGKey(0))
    step = jax.jit(make_gossip_train_step(None, cfg, tc, gc))
    return cfg, tc, gc, state, step


def _batch(cfg, n_pods, bsz=4, seq=16, seed=0):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (n_pods, bsz, seq + 1), 0, cfg.vocab_size)
    return {"tokens": toks[..., :-1], "targets": toks[..., 1:]}


@pytest.mark.parametrize("mode", ["allreduce", "dsgd", "dsba"])
def test_gossip_modes_reduce_loss(mode):
    cfg, tc, gc, state, step = _toy_setup(mode)
    steps = 80 if mode == "dsba" else 30  # SGD-EXTRA vs Adam pace
    losses = []
    for i in range(steps):
        state, m = step(state, _batch(cfg, gc.n_pods, seed=i % 3))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses[::10]
    assert np.isfinite(losses[-1])


def test_dsba_compressed_reduces_loss():
    cfg, tc, gc, state, step = _toy_setup("dsba", compression="topk")
    losses = []
    for i in range(80):
        state, m = step(state, _batch(cfg, gc.n_pods, seed=i % 3))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses[::10]
    assert np.isfinite(losses[-1])


def test_allreduce_keeps_exact_consensus():
    cfg, tc, gc, state, step = _toy_setup("allreduce")
    for i in range(5):
        state, _ = step(state, _batch(cfg, gc.n_pods, seed=i))
    assert float(consensus_distance(state["params"])) < 1e-9


@pytest.mark.parametrize("mode", ["dsgd", "dsba"])
def test_gossip_consensus_stays_bounded(mode):
    """Different pods see different data -> replicas drift but the mixing
    keeps them within a bounded neighborhood (decentralized consensus)."""
    cfg, tc, gc, state, step = _toy_setup(mode)
    dists = []
    for i in range(40):
        # deliberately different batches per step -> persistent gradient noise
        state, _ = step(state, _batch(cfg, gc.n_pods, seed=i))
        dists.append(float(consensus_distance(state["params"])))
    assert np.isfinite(dists[-1])
    # consensus error does not blow up: late average ~ mid average
    assert np.mean(dists[-5:]) < 10 * np.mean(dists[10:20]) + 1e-6


@pytest.mark.parametrize("compression", ["topk", "block_topk"])
def test_compressed_gossip_converges(compression):
    cfg, tc, gc, state, step = _toy_setup("dsgd", compression=compression)
    losses, dists = [], []
    for i in range(40):
        state, m = step(state, _batch(cfg, gc.n_pods, seed=i % 3))
        losses.append(float(m["loss"]))
        dists.append(float(consensus_distance(state["params"])))
    assert losses[-1] < losses[0] * 0.9
    assert np.isfinite(dists[-1])


def test_block_topk_wire_format():
    from repro.core.gossip import block_topk_compress

    x = jnp.asarray(np.random.default_rng(0).standard_normal(1000))
    vals, idx = block_topk_compress(x, ratio=0.05, block=256)
    # 4 blocks (last padded) x k_b=12
    assert vals.shape == idx.shape == (4 * 12,)
    # every reported (idx, val) pair is consistent with x
    np.testing.assert_allclose(np.asarray(x)[np.asarray(idx)][np.asarray(vals) != 0],
                               np.asarray(vals)[np.asarray(vals) != 0])


def test_topk_compress_roundtrip():
    x = jnp.asarray([[0.1, -3.0, 0.5], [2.0, -0.2, 0.01]])
    vals, idx = topk_compress(x, 2)
    got = scatter_decompress(x.shape, vals, idx)
    want = jnp.asarray([[0.0, -3.0, 0.0], [2.0, 0.0, 0.0]])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))
    assert leaf_k((100, 10), 0.01) == 10


def test_reconstruction_residual_is_self_correcting():
    """Repeated top-k of (target - recon) transmits a constant target fully
    in ceil(n/k) rounds — the CHOCO residual needs no error-feedback term."""
    rng = np.random.default_rng(0)
    target = jnp.asarray(rng.standard_normal((64,)))
    recon = jnp.zeros_like(target)
    for _ in range(8):  # 64/8 = 8 rounds
        vals, idx = topk_compress(target - recon, 8)
        recon = recon + scatter_decompress(target.shape, vals, idx)
    np.testing.assert_allclose(np.asarray(recon), np.asarray(target),
                               atol=1e-12)


def test_dense_mix_local_backend_matches_w_tilde_matmul():
    """roll-backend mixing == explicit W~ matmul over the pod dim."""
    from repro.core import mixing as MX
    from repro.core.gossip import make_dense_mix

    gc = GossipConfig(n_pods=6, topology="ring")
    g, w = gc.graph_and_weights()
    wt = MX.w_tilde(w)
    mix = make_dense_mix(None, gc, None)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((6, 5, 3)))
    got = mix({"a": x})["a"]
    want = jnp.einsum("pq,qij->pij", jnp.asarray(wt), x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-12)


def test_dense_mix_exponential_topology_matches():
    from repro.core import mixing as MX
    from repro.core.gossip import make_dense_mix

    gc = GossipConfig(n_pods=8, topology="exponential")
    g, w = gc.graph_and_weights()
    wt = MX.w_tilde(w)
    mix = make_dense_mix(None, gc, None)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((8, 4)))
    got = mix({"a": x})["a"]
    want = jnp.einsum("pq,qi->pi", jnp.asarray(wt), x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-12)

"""Dynamic networks: time-varying graph schedules, node churn, personalization.

The tentpole contract (docs/solvers.md "Dynamic networks"):

- a ``Problem.schedule`` of (start_iter, Graph/W) segments runs each segment
  through its own cached runner, carrying solver state across boundaries
  (restart-on-new-W, docs/algorithm.md) and recording per-segment spectral
  gaps in ``SolveResult.extras["schedule"]``;
- a ``ChurnPlan`` via ``comm_options={"fault_plan": ...}`` kills/joins nodes
  mid-run through ``ElasticGossip`` state remapping + the solver's reanchor
  hook, after which the run reconverges geometrically on the new membership;
- a single-segment schedule is BIT-equal to the static path — the dynamic
  machinery must cost exactly nothing when the network never changes.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import mixing
from repro.core.solvers import (
    ChurnEvent,
    ChurnPlan,
    make_problem,
    personalized_root,
    solve,
)
from repro.data.synthetic import make_noniid_regression, make_regression


def _ridge(n=6, seed=3, lam=0.3, graph=None):
    data = make_regression(n_nodes=n, q=12, d=12, k=4, seed=seed)
    return make_problem("ridge", data, graph or mixing.ring_graph(n), lam=lam)


def _flip_edge(g):
    """Replace ring edge (0,1) with chord (0,3): same nodes, new topology."""
    edges = tuple(e for e in g.edges if e != (0, 1)) + ((0, 3),)
    g2 = mixing.Graph(g.n, tuple(sorted(edges)))
    assert g2.is_connected()
    return g2


# ---------------------------------------------------------------------------
# single-segment schedules are bit-equal to the static path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["dsba", "dsa", "mudag"])
def test_single_segment_schedule_bit_equal_static_dense(method):
    p = _ridge()
    p.solve_star()
    ps = dataclasses.replace(p, schedule=((0, p.graph),))
    kw = dict(steps=60, record_every=20, seed=0)
    r0 = solve(p, method, "dense", **kw)
    r1 = solve(ps, method, "dense", **kw)
    assert np.array_equal(np.asarray(r0.z), np.asarray(r1.z))  # BIT equal
    assert np.array_equal(np.asarray(r0.dist2), np.asarray(r1.dist2))
    assert np.array_equal(r0.doubles_received, r1.doubles_received)
    # the only trace of the schedule is its extras record
    assert len(r1.extras["schedule"]) == 1
    assert r1.extras["schedule"][0]["entry"] is None


@pytest.mark.parametrize("engine", ["reference", "vectorized"])
def test_single_segment_schedule_bit_equal_static_sparse(engine):
    p = _ridge()
    p.solve_star()
    ps = dataclasses.replace(p, schedule=((0, p.graph),))
    kw = dict(steps=40, record_every=20, seed=0,
              comm_options={"engine": engine})
    r0 = solve(p, "dsba", "sparse", **kw)
    r1 = solve(ps, "dsba", "sparse", **kw)
    assert np.array_equal(np.asarray(r0.z), np.asarray(r1.z))
    assert np.array_equal(r0.doubles_received, r1.doubles_received)
    assert np.array_equal(r0.ints_received, r1.ints_received)


# ---------------------------------------------------------------------------
# multi-segment schedules: state carries, per-segment gaps recorded
# ---------------------------------------------------------------------------

def test_schedule_extras_record_per_segment_gaps():
    p = _ridge()
    g2 = _flip_edge(p.graph)
    ps = dataclasses.replace(p, schedule=((0, p.graph), (20, g2)))
    r = solve(ps, "dsba", "dense", steps=50, record_every=10, seed=0)
    segs = r.extras["schedule"]
    assert [s["start"] for s in segs] == [0, 20]
    assert [s["end"] for s in segs] == [20, 50]
    assert segs[0]["entry"] is None and segs[1]["entry"] == "switch"
    np.testing.assert_allclose(
        segs[0]["spectral_gap"],
        mixing.spectral_gap(mixing.laplacian_mixing(p.graph)),
    )
    np.testing.assert_allclose(
        segs[1]["spectral_gap"],
        mixing.spectral_gap(mixing.laplacian_mixing(g2)),
    )
    assert all(s["spectral_gap"] > 0 for s in segs)


@pytest.mark.parametrize("method", ["dsba", "dsa"])
def test_schedule_switch_converges_to_root(method):
    """Carried state across a W switch still reaches the (W-independent)
    root: the mean-drift invariant only uses double stochasticity."""
    p = _ridge()
    p.solve_star()
    g2 = _flip_edge(p.graph)
    ps = dataclasses.replace(p, schedule=((0, p.graph), (150, g2), (400, p.graph)))
    r = solve(ps, method, "dense", steps=2500, record_every=250, seed=0)
    assert float(r.dist2[-1]) < 1e-18


def test_schedule_reference_vs_vectorized_relay_across_edge_flip():
    """The sparse relay re-derives its reconstruction waves at the boundary:
    the vectorized engine must track the per-edge oracle across the flip."""
    p = _ridge()
    p.solve_star()
    ps = dataclasses.replace(p, schedule=((0, p.graph), (25, _flip_edge(p.graph))))
    kw = dict(steps=60, record_every=20, seed=0)
    rr = solve(ps, "dsba", "sparse", comm_options={"engine": "reference"}, **kw)
    rv = solve(ps, "dsba", "sparse",
               comm_options={"engine": "vectorized", "verify": True}, **kw)
    np.testing.assert_allclose(
        np.asarray(rv.z), np.asarray(rr.z), atol=1e-12, rtol=0
    )
    assert float(rv.extras["recon_max_err"]) < 1e-10
    # cumulative accounting stays monotone across the boundary
    assert (np.diff(rv.doubles_received, axis=0) >= 0).all()
    assert (np.diff(rv.ints_received, axis=0) >= 0).all()


def test_sparse_schedule_restart_charges_extra_flood():
    """A segment boundary re-floods dense iterates once: the schedule run
    moves strictly more doubles than the static run, same step count."""
    p = _ridge()
    ps = dataclasses.replace(p, schedule=((0, p.graph), (25, _flip_edge(p.graph))))
    kw = dict(steps=50, record_every=50, seed=0,
              comm_options={"engine": "vectorized"})
    r0 = solve(p, "dsba", "sparse", **kw)
    r1 = solve(ps, "dsba", "sparse", **kw)
    assert r1.doubles_received[-1].sum() > r0.doubles_received[-1].sum()


# ---------------------------------------------------------------------------
# node churn: kill / join mid-run
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["dsba", "dsa"])
def test_kill_resumes_geometric_decay_on_survivor_ring(method):
    """Acceptance: after a mid-run kill, the survivor-ring run reaches
    dist2 <= 1e-9 of the SURVIVOR system's root (not the stale parent's)."""
    p = _ridge(n=6)
    p.solve_star()
    plan = ChurnPlan((ChurnEvent(at=300, kind="kill", nodes=(4, 5)),))
    r = solve(p, method, "dense", steps=2500, record_every=100, seed=0,
              comm_options={"fault_plan": plan})
    # survivor ground truth: nodes 0..3 on the induced ring
    cdata = dataclasses.replace(
        p.data, idx=p.data.idx[:4], val=p.data.val[:4], y=p.data.y[:4]
    )
    child = make_problem("ridge", cdata, p.graph.subgraph([0, 1, 2, 3]),
                         lam=0.3)
    zc = child.solve_star()
    assert r.z.shape == (4, zc.shape[-1])
    assert float(np.mean(np.sum((np.asarray(r.z) - zc) ** 2, -1))) < 1e-9
    # recorded dist2 switches to the survivor root at the kill and decays
    # geometrically afterwards (factor >= 10 per 500 iters here)
    post = np.asarray(r.dist2)[np.asarray(r.iters) > 300]
    assert post[-1] < 1e-9
    assert post[-1] < post[0] * 1e-6
    # accounting: dead nodes' rows freeze, survivors' keep growing
    rows = r.extras["churn_rows"]
    assert rows == 6
    d = r.doubles_received
    assert d.shape[1] == 6
    frozen = d[np.asarray(r.iters) > 300][:, 4:]
    assert (np.diff(frozen, axis=0) == 0).all()
    live = d[:, :4]
    assert (np.diff(live, axis=0) > 0).all()


@pytest.mark.parametrize("method", ["dsba", "dsa"])
def test_join_pulls_new_nodes_into_consensus(method):
    p = _ridge(n=6)
    plan = ChurnPlan((
        ChurnEvent(at=300, kind="join", n_new=2, seed_from=0,
                   graph=mixing.ring_graph(8)),
    ))
    r = solve(p, method, "dense", steps=3000, record_every=100, seed=0,
              comm_options={"fault_plan": plan})
    assert r.z.shape[0] == 8
    # the joined nodes are in consensus with the incumbents...
    z = np.asarray(r.z)
    assert float(np.max(np.sum((z - z.mean(0)) ** 2, -1))) < 1e-16
    # ...at the GROWN system's root (joined nodes replicate node 0's shard)
    gdata = dataclasses.replace(
        p.data,
        idx=np.concatenate([p.data.idx, p.data.idx[[0, 0]]]),
        val=np.concatenate([p.data.val, p.data.val[[0, 0]]]),
        y=np.concatenate([p.data.y, p.data.y[[0, 0]]]),
    )
    grown = make_problem("ridge", gdata, mixing.ring_graph(8), lam=0.3)
    zg = grown.solve_star()
    assert float(np.mean(np.sum((z - zg) ** 2, -1))) < 1e-9


def test_kill_then_join_sequence():
    """A plan with several events chains children; joined node seeds from a
    SURVIVOR index (post-kill numbering)."""
    p = _ridge(n=6)
    plan = ChurnPlan((
        ChurnEvent(at=200, kind="kill", nodes=(5,)),
        ChurnEvent(at=500, kind="join", n_new=1, seed_from=2,
                   graph=mixing.ring_graph(6)),
    ))
    r = solve(p, "dsba", "dense", steps=2000, record_every=200, seed=0,
              comm_options={"fault_plan": plan})
    assert r.z.shape[0] == 6
    assert r.extras["churn_rows"] == 7  # 6 original + 1 joined
    segs = r.extras["schedule"]
    assert [s["entry"] for s in segs] == [None, "kill", "join"]
    z = np.asarray(r.z)
    assert float(np.max(np.sum((z - z.mean(0)) ** 2, -1))) < 1e-16


def test_fault_plan_validation():
    p = _ridge(n=6)
    with pytest.raises(ValueError, match="strictly increase"):
        ChurnPlan((ChurnEvent(at=5, kind="kill", nodes=(1,)),
                   ChurnEvent(at=5, kind="kill", nodes=(2,))))
    with pytest.raises(ValueError, match="graph"):
        ChurnEvent(at=5, kind="join", n_new=1)  # join needs the new graph
    # killing nodes that disconnect the default survivor subgraph
    plan = ChurnPlan((ChurnEvent(at=5, kind="kill", nodes=(1, 4)),))
    with pytest.raises(ValueError, match="connect"):
        solve(p, "dsba", "dense", steps=10, record_every=5, seed=0,
              comm_options={"fault_plan": plan})
    # schedule and fault_plan cannot be combined
    ps = dataclasses.replace(p, schedule=((0, p.graph), (5, p.graph)))
    okplan = ChurnPlan((ChurnEvent(at=5, kind="kill", nodes=(5,)),))
    with pytest.raises(ValueError, match="schedule"):
        solve(ps, "dsba", "dense", steps=10, record_every=5, seed=0,
              comm_options={"fault_plan": okplan})


# ---------------------------------------------------------------------------
# elastic remap invariants (deterministic twins of the hypothesis tests)
# ---------------------------------------------------------------------------

def test_shrink_grow_roundtrip_shapes_and_seeding():
    from repro.core.gossip import GossipConfig
    from repro.ft.elastic import ElasticGossip

    rng = np.random.default_rng(0)
    state = {
        "z": rng.standard_normal((6, 4)),
        "table": rng.standard_normal((6, 3, 2)),
        "scalar": np.float64(7.0),
        "step": np.int32(11),
    }
    eg = ElasticGossip(GossipConfig(n_pods=6))
    small, gc4 = eg.shrink(state, dead=[1, 4])
    assert gc4.n_pods == 4
    assert small["z"].shape == (4, 4) and small["table"].shape == (4, 3, 2)
    np.testing.assert_array_equal(np.asarray(small["z"])[0], state["z"][0])
    np.testing.assert_array_equal(np.asarray(small["z"])[1], state["z"][2])
    assert small["scalar"] == state["scalar"]  # non-node leaves untouched
    back, gc6 = ElasticGossip(gc4).grow(small, n_new=2, seed_from=3)
    assert gc6.n_pods == 6
    for k in ("z", "table"):
        assert np.asarray(back[k]).shape == np.asarray(state[k]).shape
        np.testing.assert_array_equal(  # joined rows replicate the seed
            np.asarray(back[k])[4], np.asarray(back[k])[3]
        )


def test_segment_mixing_matrices_valid():
    """Every normalized segment W is doubly stochastic, supported on its
    graph, and has positive spectral gap (connected segments only)."""
    p = _ridge()
    g2 = _flip_edge(p.graph)
    ps = dataclasses.replace(p, schedule=((0, p.graph), (20, g2)))
    for _, g, w in ps.schedule:
        mixing.validate_mixing(w, g)
        np.testing.assert_allclose(w.sum(0), 1.0, atol=1e-10)
        np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-10)
        assert mixing.spectral_gap(w) > 0


# ---------------------------------------------------------------------------
# personalization: per-node lam on deliberately non-iid splits
# ---------------------------------------------------------------------------

def test_per_node_lam_dsba_dsa_agree_on_noniid_data():
    """Two different methods, one coupled fixed point: per-node lam enters
    the problem, not the solver."""
    data, _ = make_noniid_regression(n_nodes=6, q=20, d=16, k=5, shift=1.5,
                                     seed=0)
    lam = np.linspace(0.05, 0.4, 6)
    p = make_problem("ridge", data, mixing.ring_graph(6), lam=lam)
    ra = solve(p, "dsba", "dense", steps=2500, record_every=500, seed=0)
    rb = solve(p, "dsa", "dense", steps=2500, record_every=500, seed=0)
    za, zb = np.asarray(ra.z), np.asarray(rb.z)
    np.testing.assert_allclose(za, zb, atol=1e-8, rtol=0)
    assert float(np.max(np.sum((za - za.mean(0)) ** 2, -1))) < 1e-16


def test_personalized_root_matches_personal_descent():
    data, _ = make_noniid_regression(n_nodes=5, q=16, d=12, k=4, shift=1.0,
                                     seed=1)
    lam = np.full(5, 0.2)
    p = make_problem("ridge", data, mixing.ring_graph(5), lam=lam)
    zp = personalized_root(p, mu=1.0)
    r = solve(p, "personal", "dense", steps=8000, record_every=2000, seed=0,
              mu=1.0)
    np.testing.assert_allclose(np.asarray(r.z), zp, atol=1e-10, rtol=0)


def test_personalization_interpolates_local_to_consensus():
    """mu -> 0 decouples the nodes (local ridge fits); mu large approaches
    consensus. Local training residual is monotone in mu on non-iid data."""
    data, _ = make_noniid_regression(n_nodes=5, q=16, d=12, k=4, shift=2.0,
                                     seed=2)
    lam = np.full(5, 0.2)
    p = make_problem("ridge", data, mixing.ring_graph(5), lam=lam)

    def local_sse(z):
        a = data.dense()  # (N, q, d)
        pred = np.einsum("nqd,nd->nq", a, np.asarray(z))
        return float(((pred - data.y) ** 2).sum())

    def spread(z):
        z = np.asarray(z)
        return float(np.max(np.sum((z - z.mean(0)) ** 2, -1)))

    sse, sp = {}, {}
    for mu in (0.01, 1.0, 100.0):
        z = personalized_root(p, mu=mu)
        sse[mu], sp[mu] = local_sse(z), spread(z)
    assert sse[0.01] < sse[1.0] < sse[100.0]  # local fit degrades with mu
    assert sp[0.01] > sp[1.0] > sp[100.0]  # spread contracts toward consensus


# ---------------------------------------------------------------------------
# exhaustive sweeps (slow tier)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("method", ["dsba", "dsa", "mudag", "sliding"])
@pytest.mark.parametrize("n_segments", [2, 4, 7])
def test_schedule_sweep_every_method_converges(method, n_segments):
    p = _ridge(n=6)
    p.solve_star()
    graphs = [p.graph, _flip_edge(p.graph),
              mixing.complete_graph(6), mixing.erdos_renyi_graph(6, 0.5, 9)]
    sched = tuple(
        (120 * i, graphs[i % len(graphs)]) for i in range(n_segments)
    )
    ps = dataclasses.replace(p, schedule=sched)
    r = solve(ps, method, "dense", steps=4000, record_every=1000, seed=0)
    assert float(r.dist2[-1]) < 1e-15


@pytest.mark.slow
@pytest.mark.parametrize("at", [50, 299, 300, 301, 777])
def test_kill_timing_sweep(at):
    p = _ridge(n=6)
    plan = ChurnPlan((ChurnEvent(at=at, kind="kill", nodes=(4, 5)),))
    r = solve(p, "dsba", "dense", steps=at + 2200, record_every=200, seed=0,
              comm_options={"fault_plan": plan})
    cdata = dataclasses.replace(
        p.data, idx=p.data.idx[:4], val=p.data.val[:4], y=p.data.y[:4]
    )
    child = make_problem("ridge", cdata, p.graph.subgraph([0, 1, 2, 3]),
                         lam=0.3)
    zc = child.solve_star()
    assert float(np.mean(np.sum((np.asarray(r.z) - zc) ** 2, -1))) < 1e-9

"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype sweeps.

Tolerances come from the kernels/ops.py registry (the per-kernel parity
policy the dispatch tests also enforce): flash attention 2e-5 f32 / 2e-2
bf16.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention, flash_attention_fwd
from repro.kernels.sparse_saga import sparse_axpy, sparse_dot
from repro.kernels.ssd_scan import ssd_chunk_fwd
from repro.kernels.topk_compress import block_topk


def _tol(name, dtype):
    t = ops.get_kernel(name).tolerance(dtype)
    return dict(rtol=t.rtol, atol=t.atol)


TOL = {dt: _tol("flash_attention", dt) for dt in (jnp.float32, jnp.bfloat16)}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (1, 4, 4, 128, 64),     # MHA
    (2, 8, 2, 256, 64),     # GQA 4:1
    (1, 4, 1, 128, 128),    # MQA
    (1, 2, 2, 96, 64),      # ragged seq (not multiple of block)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(B, Hq, Hkv, S, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), dtype)
    got = flash_attention_fwd(q, k, v, causal=True, block_q=64, block_k=64,
                              interpret=True)
    want = R.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **TOL[dtype],
    )


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    got = flash_attention_fwd(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64, interpret=True)
    want = R.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_attention_softcap_gemma2():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = 3.0 * jax.random.normal(ks[0], (1, 4, 128, 64))
    k = 3.0 * jax.random.normal(ks[1], (1, 4, 128, 64))
    v = jax.random.normal(ks[2], (1, 4, 128, 64))
    got = flash_attention_fwd(q, k, v, causal=True, softcap=50.0,
                              block_q=64, block_k=64, interpret=True)
    want = R.attention_ref(q, k, v, causal=True, softcap=50.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64))
    k = jax.random.normal(ks[1], (1, 2, 128, 64))
    v = jax.random.normal(ks[2], (1, 2, 128, 64))
    got = flash_attention_fwd(q, k, v, causal=False, block_q=64, block_k=64,
                              interpret=True)
    want = R.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_attention_lse_matches_dense_logsumexp():
    ks = jax.random.split(jax.random.PRNGKey(10), 3)
    B, H, S, D = 1, 2, 96, 32
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    _, lse = flash_attention_fwd(q, k, v, causal=True, block_q=64,
                                 block_k=64, interpret=True, return_lse=True)
    s = jnp.einsum("bhsd,bhtd->bhst", q / jnp.sqrt(D), k)
    mask = jnp.arange(S)[:, None] >= jnp.arange(S)[None, :]
    s = jnp.where(mask, s, -1e30)
    want = jax.scipy.special.logsumexp(s, axis=-1)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("causal,window,softcap", [
    (True, None, None), (True, 64, None), (True, None, 30.0),
    (False, None, None),
])
def test_flash_attention_custom_vjp_matches_ref_grads(causal, window, softcap):
    """The saved-residual backward == autodiff of the dense oracle."""
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    B, Hq, Hkv, S, D = 1, 4, 2, 96, 32
    q = jax.random.normal(ks[0], (B, Hq, S, D))
    k = jax.random.normal(ks[1], (B, Hkv, S, D))
    v = jax.random.normal(ks[2], (B, Hkv, S, D))
    do = jax.random.normal(ks[3], (B, Hq, S, D))
    gk = jax.grad(
        lambda q, k, v: jnp.sum(
            flash_attention(q, k, v, causal, window, softcap, 64, 64, True)
            * do
        ),
        argnums=(0, 1, 2),
    )(q, k, v)
    gr = jax.grad(
        lambda q, k, v: jnp.sum(
            R.attention_ref(q, k, v, causal=causal, window=window,
                            softcap=softcap) * do
        ),
        argnums=(0, 1, 2),
    )(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# SSD within-chunk kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,nc,Q,nh,hd,ds,hb", [
    (1, 2, 64, 4, 32, 16, 4),
    (2, 3, 128, 8, 64, 32, 4),
    (1, 1, 64, 2, 32, 64, 2),
])
def test_ssd_chunk_matches_ref(B, nc, Q, nh, hd, ds, hb):
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    xdt = jax.random.normal(ks[0], (B, nc, Q, nh, hd))
    cum = -jnp.cumsum(
        jax.random.uniform(ks[1], (B, nc, Q, nh), minval=0.01, maxval=0.2),
        axis=2,
    )
    Bc = jax.random.normal(ks[2], (B, nc, Q, ds))
    Cc = jax.random.normal(ks[3], (B, nc, Q, ds))
    y, st = ssd_chunk_fwd(xdt, cum, Bc, Cc, head_block=hb, interpret=True)
    y_ref, st_ref = R.ssd_chunk_ref(xdt, cum, Bc, Cc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=2e-5, atol=2e-5)


def test_ssd_kernel_plus_jnp_recurrence_equals_full_ssd():
    """kernel within-chunk + jnp across-chunk == models/ssm._ssd_chunked."""
    from repro.models.ssm import _ssd_chunked

    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    B, S, nh, hd, ds, Q = 1, 256, 4, 32, 16, 64
    xh = jax.random.normal(ks[0], (B, S, nh, hd))
    dt = jax.random.uniform(ks[1], (B, S, nh), minval=0.1, maxval=1.0)
    a_log = -jax.random.uniform(ks[2], (B, S, nh), minval=0.01, maxval=0.3)
    Bc = jax.random.normal(ks[3], (B, S, ds))
    Cc = jax.random.normal(ks[4], (B, S, ds))

    want, h_want = _ssd_chunked(xh, dt, a_log, Bc, Cc, Q)

    nc = S // Q
    xdt = (xh * dt[..., None]).reshape(B, nc, Q, nh, hd)
    cum = jnp.cumsum(a_log.reshape(B, nc, Q, nh), axis=2)
    Bc_ = Bc.reshape(B, nc, Q, ds)
    Cc_ = Cc.reshape(B, nc, Q, ds)
    y_intra, states = ssd_chunk_fwd(xdt, cum, Bc_, Cc_, head_block=4,
                                    interpret=True)
    total = jnp.exp(cum[:, :, -1, :])

    def scan_fn(h, inp):
        tot_c, st_c = inp
        return tot_c[:, :, None, None] * h + st_c, h

    h_fin, h_prevs = jax.lax.scan(
        scan_fn, jnp.zeros((B, nh, ds, hd)),
        (jnp.moveaxis(total, 1, 0), jnp.moveaxis(states, 1, 0)),
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)
    y_inter = jnp.einsum("bcis,bchsd->bcihd", Cc_, h_prevs) * jnp.exp(cum)[..., None]
    got = (y_intra + y_inter).reshape(B, S, nh, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_fin), np.asarray(h_want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# sparse SAGA row ops
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,D,k,block_d", [
    (4, 256, 8, 64),
    (10, 1000, 16, 512),   # D not a multiple of block
    (2, 64, 64, 64),       # dense-ish row
])
def test_sparse_dot_matches_ref(N, D, k, block_d):
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    psi = jax.random.normal(ks[0], (N, D))
    idx = jax.random.randint(ks[1], (N, k), 0, D)
    val = jax.random.normal(ks[2], (N, k))
    got = sparse_dot(psi, idx, val, block_d=block_d, interpret=True)
    want = R.sparse_dot_ref(psi, idx, val)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("N,D,k", [(4, 256, 8), (6, 500, 12)])
def test_sparse_axpy_matches_ref(N, D, k):
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    psi = jax.random.normal(ks[0], (N, D))
    # distinct indices per row (padded-CSR guarantee in data/synthetic.py)
    idx = jnp.stack([
        jax.random.permutation(jax.random.fold_in(ks[1], n), D)[:k]
        for n in range(N)
    ]).astype(jnp.int32)
    val = jax.random.normal(ks[2], (N, k))
    coef = jax.random.normal(ks[3], (N,))
    rho = jax.random.uniform(ks[4], (N,), minval=0.5, maxval=1.0)
    got = sparse_axpy(psi, idx, val, coef, rho, block_d=128, interpret=True)
    want = R.sparse_axpy_ref(psi, idx, val, coef, rho)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_dsba_ridge_step_via_kernels_matches_core():
    """Full DSBA resolvent step assembled from the two kernels == closed form."""
    from repro.core.operators import ridge_resolvent_coeff

    ks = jax.random.split(jax.random.PRNGKey(8), 4)
    N, D, k = 5, 300, 10
    psi = jax.random.normal(ks[0], (N, D))
    idx = jnp.stack([
        jax.random.permutation(jax.random.fold_in(ks[1], n), D)[:k]
        for n in range(N)
    ]).astype(jnp.int32)
    val = jax.random.normal(ks[2], (N, k))
    val = val / jnp.linalg.norm(val, axis=1, keepdims=True)
    y = jax.random.normal(ks[3], (N,))
    alpha, lam = 0.5, 0.01
    rho = 1.0 / (1.0 + alpha * lam)
    a_eff = rho * alpha

    s = sparse_dot(psi, idx, val, block_d=128, interpret=True)
    g = ridge_resolvent_coeff(rho * s, y, a_eff, 1.0)
    z = sparse_axpy(psi, idx, val, -a_eff * g, jnp.full((N,), rho),
                    block_d=128, interpret=True)
    # check the resolvent identity (1+alpha lam) z + alpha B(z) = psi rowwise
    u = jax.vmap(lambda zz, ii, vv: jnp.sum(vv * zz[ii]))(z, idx, val)
    B_z = jax.vmap(lambda ii, vv, gg: jnp.zeros((D,)).at[ii].add(gg * vv))(
        idx, val, u - y
    )
    res = (1 + alpha * lam) * z + alpha * B_z
    np.testing.assert_allclose(np.asarray(res), np.asarray(psi),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# block top-k
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nb,block,k", [(4, 128, 8), (1, 64, 64), (8, 256, 1)])
def test_block_topk_matches_ref(nb, block, k):
    x = jax.random.normal(jax.random.PRNGKey(9), (nb, block))
    vals, idx = block_topk(x, k, interpret=True)
    vals_r, idx_r = R.block_topk_ref(x, k)
    # selected SETS must match (order may differ on ties); compare sorted
    np.testing.assert_allclose(
        np.sort(np.abs(np.asarray(vals)), axis=1),
        np.sort(np.abs(np.asarray(vals_r)), axis=1),
        rtol=1e-6, atol=1e-6,
    )
    # values must correspond to their indices
    got_gather = np.take_along_axis(np.asarray(x), np.asarray(idx), axis=1)
    np.testing.assert_allclose(np.asarray(vals), got_gather)

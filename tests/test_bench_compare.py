"""The benchmark regression gate (`benchmarks/compare.py`).

Pins the gate semantics the sweep entries rely on: baseline-missing
entries are informational ("NEW", never fail — a PR adding `sweep_*`
benchmarks passes before its baseline lands), removed entries are
informational, and only matched entries are gated at the ratio.
"""
import json

import pytest

from benchmarks import compare as C


def _payload(tmp_path, name, entries):
    p = tmp_path / name
    p.write_text(json.dumps({"schema": 1, "fast": True, "entries": entries}))
    return str(p)


def test_new_entries_are_informational_not_failures(capsys):
    base = {"dsba_step": 100.0}
    new = {"dsba_step": 110.0, "sweep_solve_second_call": 9000.0}
    failures = C.compare(base, new, max_ratio=1.5)
    assert failures == []
    out = capsys.readouterr().out
    assert "NEW      sweep_solve_second_call" in out
    assert "informational" in out
    assert "1 new / 0 removed" in out


def test_removed_entries_are_informational(capsys):
    failures = C.compare({"gone": 50.0, "kept": 10.0}, {"kept": 10.0}, 1.5)
    assert failures == []
    assert "REMOVED  gone" in capsys.readouterr().out


def test_matched_regression_still_fails():
    failures = C.compare({"hot": 100.0}, {"hot": 151.0}, 1.5)
    assert len(failures) == 1 and "hot" in failures[0]
    assert C.compare({"hot": 100.0}, {"hot": 149.0}, 1.5) == []


def test_main_exit_codes(tmp_path, monkeypatch, capsys):
    base = _payload(tmp_path, "base.json", {"a": 100.0})
    ok = _payload(tmp_path, "ok.json", {"a": 120.0, "b": 5.0})
    bad = _payload(tmp_path, "bad.json", {"a": 200.0})
    monkeypatch.setattr("sys.argv", ["compare", base, ok])
    assert C.main() == 0
    monkeypatch.setattr("sys.argv", ["compare", base, bad])
    assert C.main() == 1
    capsys.readouterr()


def test_informational_entries_never_gate(capsys):
    """The mesh-backend family: reported with a ratio, never a failure."""
    base = {"comm_sharded_N8_sharded": 100.0, "hot": 100.0}
    new = {"comm_sharded_N8_sharded": 900.0, "hot": 100.0}
    failures = C.compare(base, new, 1.5,
                         informational={"comm_sharded_N8_sharded"})
    assert failures == []
    out = capsys.readouterr().out
    assert "INFO     comm_sharded_N8_sharded" in out
    assert "never gated" in out


def test_main_unions_informational_from_both_payloads(tmp_path, monkeypatch,
                                                      capsys):
    """A baseline written before the tagging existed still never gates the
    family, because the NEW payload's list is honored too."""
    base = tmp_path / "base.json"
    base.write_text(json.dumps({
        "schema": 1, "entries": {"comm_sharded_N8_sharded": 100.0},
    }))
    new = tmp_path / "new.json"
    new.write_text(json.dumps({
        "schema": 1, "entries": {"comm_sharded_N8_sharded": 900.0},
        "informational": ["comm_sharded_N8_sharded"],
    }))
    monkeypatch.setattr("sys.argv", ["compare", str(base), str(new)])
    assert C.main() == 0
    capsys.readouterr()


def test_serve_entries_tagged_informational(capsys):
    """The serving family: a 10x 'regression' in container-timed decode
    throughput reports but never gates (same contract as comm_sharded)."""
    base = {"serve_decode_b64": 100.0, "hot": 100.0}
    new = {"serve_decode_b64": 1000.0, "hot": 100.0}
    failures = C.compare(base, new, 1.5, informational={"serve_decode_b64"})
    assert failures == []
    out = capsys.readouterr().out
    assert "INFO     serve_decode_b64" in out


def test_run_payload_tags_serve_informational():
    """benchmarks.run must tag every serve_* row informational in the
    JSON payload compare.py consumes."""
    from benchmarks.run import informational_entries

    rows = [("serve_decode_b1", 10.0, ""), ("serve_decode_b512", 10.0, ""),
            ("dsba_step_d2000", 10.0, "")]
    assert informational_entries(rows) == [
        "serve_decode_b1", "serve_decode_b512"
    ]


def test_run_payload_tags_faults_informational():
    """benchmarks.run must tag every faults_* row informational: the
    degradation curve lives in the derived column; the entry's number is
    a container-timed whole-solve wall clock nobody should gate on."""
    from benchmarks.run import informational_entries

    rows = [("faults_dsba_p0", 10.0, ""), ("faults_mudag_p0.4", 10.0, ""),
            ("dsba_step_d2000", 10.0, "")]
    assert informational_entries(rows) == [
        "faults_dsba_p0", "faults_mudag_p0.4"
    ]


def test_committed_faults_baseline_is_fully_informational():
    """The committed BENCH_faults.json artifact: schema 1, every entry in
    its own informational list — the whole family reports, never gates."""
    import pathlib

    path = pathlib.Path(__file__).resolve().parents[1] / "BENCH_faults.json"
    payload = json.loads(path.read_text())
    assert payload["schema"] == 1
    names = set(payload["entries"])
    assert names and all(n.startswith("faults_") for n in names)
    assert set(payload["informational"]) == names
    # the curve is the artifact: every derived column carries either an
    # iteration count (p=0) or a plateau level (p>0)
    for name, derived in payload["derived"].items():
        assert ("iters_to_1e-6=" in derived) or ("plateau=" in derived)


def test_unknown_schema_rejected(tmp_path):
    p = tmp_path / "x.json"
    p.write_text(json.dumps({"schema": 99, "entries": {}}))
    with pytest.raises(SystemExit, match="schema"):
        C.load(str(p))

"""Operator/resolvent correctness + monotonicity properties (Section 3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.operators import (
    OperatorSpec,
    logistic_coeff,
    logistic_coeff_prime,
)

SPECS = {
    "ridge": OperatorSpec("ridge"),
    "logistic": OperatorSpec("logistic"),
    "auc": OperatorSpec("auc", p=0.3),
    "bilinear": OperatorSpec("bilinear", gamma=0.7),
}


def full_component_operator(spec, z, x, y):
    """Dense B_{n,i}(z) for one sample — direct from the paper's formulas."""
    d = x.shape[0]
    u = x @ z[:d]
    tail = z[d:]
    g, tail_out = spec.coeff_and_tail(u, y, tail)
    return jnp.concatenate([g * x, tail_out])


@pytest.mark.parametrize("kind", sorted(SPECS))
def test_resolvent_solves_implicit_equation(kind):
    """z = J_{a B}(psi)  <=>  z + a B(z) = psi (eq. 6-7)."""
    spec = SPECS[kind]
    rng = np.random.default_rng(0)
    d = 7
    x = rng.standard_normal(d)
    x /= np.linalg.norm(x)
    for y in (1.0, -1.0):
        psi = jnp.asarray(rng.standard_normal(d + spec.tail_dim))
        alpha = 0.37
        s = x @ psi[:d]
        g, tail_z = spec.resolvent_coeff_and_tail(
            jnp.asarray(s), psi[d:], jnp.asarray(y), alpha, 1.0
        )
        z = psi.at[:d].add(-alpha * g * jnp.asarray(x))
        if spec.tail_dim:
            z = z.at[d:].set(tail_z)
        res = z + alpha * full_component_operator(spec, z, jnp.asarray(x), y)
        np.testing.assert_allclose(np.asarray(res), np.asarray(psi), atol=1e-8)


@pytest.mark.parametrize("kind", sorted(SPECS))
def test_resolvent_regularized_scaling_trick(kind):
    """J_{a B^lam}(psi) == J_{rho a B}(rho psi), rho = 1/(1+lam a) (Sec. 7)."""
    spec = SPECS[kind]
    rng = np.random.default_rng(1)
    d = 5
    x = rng.standard_normal(d)
    x /= np.linalg.norm(x)
    y, alpha, lam = -1.0, 0.21, 0.3
    rho = 1.0 / (1.0 + alpha * lam)
    psi = jnp.asarray(rng.standard_normal(d + spec.tail_dim))
    s = x @ psi[:d]
    g, tail_z = spec.resolvent_coeff_and_tail(
        jnp.asarray(rho * s), rho * psi[d:], jnp.asarray(y), rho * alpha, 1.0
    )
    z = rho * psi
    z = z.at[:d].add(-rho * alpha * g * jnp.asarray(x))
    if spec.tail_dim:
        z = z.at[d:].set(tail_z)
    # must satisfy (1 + a lam) z + a B(z) = psi
    res = (1 + alpha * lam) * z + alpha * full_component_operator(
        spec, z, jnp.asarray(x), y
    )
    np.testing.assert_allclose(np.asarray(res), np.asarray(psi), atol=1e-8)


def test_auc_operator_matches_autodiff_of_saddle_function():
    """B = [df/dw; df/da; df/db; -df/dtheta] for f of eq. (12), lam=0."""
    p = 0.3
    spec = OperatorSpec("auc", p=p)
    rng = np.random.default_rng(2)
    d = 6
    x = rng.standard_normal(d)
    x /= np.linalg.norm(x)

    def f(z, y):
        w, a, b, th = z[:d], z[d], z[d + 1], z[d + 2]
        u = x @ w
        pos = y > 0
        return (
            -p * (1 - p) * th**2
            + jnp.where(pos, (1 - p) * (u - a) ** 2, p * (u - b) ** 2)
            + 2 * (1 + th) * jnp.where(pos, -(1 - p) * u, p * u)
        )

    for y in (1.0, -1.0):
        z = jnp.asarray(rng.standard_normal(d + 3))
        grad = jax.grad(f)(z, y)
        expected = grad.at[-1].multiply(-1.0)  # negate theta component
        got = full_component_operator(spec, z, jnp.asarray(x), y)
        np.testing.assert_allclose(np.asarray(got), np.asarray(expected), atol=1e-9)


def test_bilinear_operator_matches_autodiff_of_saddle_function():
    """B = [dL/dw; -dL/dtheta] for the bilinear-coupled minimax loss."""
    gamma = 0.7
    spec = SPECS["bilinear"]
    rng = np.random.default_rng(4)
    d = 6
    x = rng.standard_normal(d)
    x /= np.linalg.norm(x)

    def L(z, y):
        w, th = z[:d], z[d]
        u = x @ w
        return 0.5 * (u - y) ** 2 + th * y * u - 0.5 * gamma * th**2

    for y in (1.0, -1.0, 0.4):
        z = jnp.asarray(rng.standard_normal(d + 1))
        grad = jax.grad(L)(z, y)
        expected = grad.at[-1].multiply(-1.0)  # negate theta component
        got = full_component_operator(spec, z, jnp.asarray(x), y)
        np.testing.assert_allclose(np.asarray(got), np.asarray(expected),
                                   atol=1e-9)


@settings(max_examples=50, deadline=None)
@given(
    st.lists(st.floats(-3, 3), min_size=5, max_size=5),
    st.lists(st.floats(-3, 3), min_size=5, max_size=5),
    st.sampled_from([1.0, -1.0, 0.4]),
)
def test_bilinear_operator_is_monotone(z1_l, z2_l, y):
    """PSD symmetric part + antisymmetric coupling => monotone."""
    spec = SPECS["bilinear"]
    x = np.asarray([0.5, -0.5, 0.5, 0.5])
    z1, z2 = jnp.asarray(z1_l), jnp.asarray(z2_l)
    b1 = full_component_operator(spec, z1, jnp.asarray(x), y)
    b2 = full_component_operator(spec, z2, jnp.asarray(x), y)
    inner = float((b1 - b2) @ (z1 - z2))
    assert inner >= -1e-9


def test_logistic_coeff_prime_matches_autodiff():
    u = jnp.linspace(-4, 4, 23)
    for y in (1.0, -1.0):
        want = jax.vmap(jax.grad(lambda uu: logistic_coeff(uu, y)))(u)
        got = logistic_coeff_prime(u, y)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-10)


@settings(max_examples=50, deadline=None)
@given(
    st.lists(st.floats(-3, 3), min_size=4, max_size=4),
    st.lists(st.floats(-3, 3), min_size=4, max_size=4),
    st.sampled_from(["ridge", "logistic"]),
    st.sampled_from([1.0, -1.0]),
)
def test_component_operator_is_monotone(z1_l, z2_l, kind, y):
    """(B(z1)-B(z2))^T (z1-z2) >= 0 (eq. 2) for convex-loss operators."""
    spec = SPECS[kind]
    x = np.asarray([0.5, -0.5, 0.5, 0.5])
    z1, z2 = jnp.asarray(z1_l), jnp.asarray(z2_l)
    b1 = full_component_operator(spec, z1, jnp.asarray(x), y)
    b2 = full_component_operator(spec, z2, jnp.asarray(x), y)
    inner = float((b1 - b2) @ (z1 - z2))
    assert inner >= -1e-9


@settings(max_examples=50, deadline=None)
@given(
    st.lists(st.floats(-3, 3), min_size=7, max_size=7),
    st.lists(st.floats(-3, 3), min_size=7, max_size=7),
    st.sampled_from([1.0, -1.0]),
)
def test_auc_operator_is_monotone(z1_l, z2_l, y):
    """The AUC saddle differential is monotone (Rockafellar 1970)."""
    spec = SPECS["auc"]
    x = np.full(4, 0.5)
    z1, z2 = jnp.asarray(z1_l), jnp.asarray(z2_l)
    b1 = full_component_operator(spec, z1, jnp.asarray(x), y)
    b2 = full_component_operator(spec, z2, jnp.asarray(x), y)
    inner = float((b1 - b2) @ (z1 - z2))
    assert inner >= -1e-9


@settings(max_examples=30, deadline=None)
@given(
    st.lists(st.floats(-5, 5), min_size=5, max_size=5),
    st.lists(st.floats(-5, 5), min_size=5, max_size=5),
    st.sampled_from(["ridge", "logistic", "auc", "bilinear"]),
    st.sampled_from([1.0, -1.0]),
    st.floats(0.05, 2.0),
)
def test_resolvent_is_firmly_nonexpansive(p1, p2, kind, y, alpha):
    """||J(psi1) - J(psi2)|| <= ||psi1 - psi2|| for monotone B (+lam)."""
    spec = SPECS[kind]
    d = 5 - 0
    x = np.full(d, 1.0 / np.sqrt(d))
    t = spec.tail_dim
    rng = np.random.default_rng(3)
    tail_extra = rng.standard_normal((2, t))

    def J(psi):
        s = x @ psi[:d]
        g, tail_z = spec.resolvent_coeff_and_tail(
            jnp.asarray(s), psi[d:], jnp.asarray(y), alpha, 1.0
        )
        z = psi.at[:d].add(-alpha * g * jnp.asarray(x))
        if t:
            z = z.at[d:].set(tail_z)
        return z

    psi1 = jnp.asarray(np.concatenate([p1, tail_extra[0]]))
    psi2 = jnp.asarray(np.concatenate([p2, tail_extra[1]]))
    n_out = float(jnp.linalg.norm(J(psi1) - J(psi2)))
    n_in = float(jnp.linalg.norm(psi1 - psi2))
    assert n_out <= n_in + 1e-8

"""Serving subsystem tests: paged cache, scheduler, decode parity.

Three claims (ISSUE 9 acceptance):
  1. every servable reduced config prefills + decodes through the
     continuous-batching scheduler (smoke, all ARCH_IDS);
  2. paged-cache decode logits match contiguous-cache decode within the
     registered decode_attention kernel tolerance (dense GQA, MQA,
     windowed gemma2, ssm, encdec + one Pallas-interpret run);
  3. the page pool never leaks or double-books a page across random
     admit/grow/evict episodes, and a 64-request trace triggers zero
     recompiles after warmup (jit trace counts frozen).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.kernels import ops as KO
from repro.models import transformer as T
from repro.models.params import tree_materialize
from repro.serve import CachePool, PoolConfig, Request, Scheduler

_PC = PoolConfig(
    max_batch=3, block_size=8, n_blocks=24, max_len=32, prompt_pad=16
)


def _make(arch, **over):
    cfg = get_reduced(arch)
    if over:
        cfg = dataclasses.replace(cfg, **over)
    params = tree_materialize(
        T.model_defs(cfg), jax.random.PRNGKey(0), cfg.param_dtype
    )
    return cfg, params


def _requests(cfg, n, max_new, seed=0, prompt_pad=16):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        kw = {}
        if cfg.family == "encdec":
            kw["enc_embeds"] = np.asarray(jax.random.normal(
                jax.random.PRNGKey(100 + i), (cfg.encoder_len, cfg.d_model)
            ))
        plen = int(rng.integers(3, prompt_pad - 1))
        reqs.append(Request(
            rid=i, tokens=rng.integers(0, cfg.vocab_size, size=plen),
            max_new_tokens=max_new, **kw,
        ))
    return reqs


# ---------------------------------------------------------------------------
# 1. smoke: every servable config through the scheduler
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCH_IDS)
def test_scheduler_smoke_decode(arch):
    cfg, params = _make(arch)
    sch = Scheduler(cfg, params, _PC)
    results, stats = sch.run(_requests(cfg, 2, 4))
    assert set(results) == {0, 1}
    for toks in results.values():
        assert toks.shape == (4,)
        assert toks.dtype == np.int32
        assert np.all((0 <= toks) & (toks < cfg.vocab_size))
    # one token per request comes from prefill logits; three from decode
    assert stats.total_tokens == 2 * 3
    # shape-stable loop: exactly one trace per jitted piece
    assert sch.trace_counts["prefill"] == 1
    assert sch.trace_counts["decode"] == 1


# ---------------------------------------------------------------------------
# 2. paged vs contiguous decode parity (logits, kernel tolerance)
# ---------------------------------------------------------------------------

# dense GQA, MQA, windowed local/global, ssm, hybrid, encdec — plus one
# run through the Pallas interpreter to cover the real kernel's masking
_PARITY = {
    "dense_gqa": ("minitron_8b", {}),
    "dense_mqa": ("minitron_8b", {"n_kv_heads": 1}),
    "windowed": ("gemma2_2b", {}),
    "ssm": ("mamba2_1p3b", {}),
    "hybrid": ("zamba2_1p2b", {}),
    "encdec": ("whisper_small", {}),
    "interpret": ("minitron_8b", {"decode_kernel": "interpret"}),
}


@pytest.mark.parametrize("variant", sorted(_PARITY))
def test_paged_matches_contiguous(variant):
    arch, over = _PARITY[variant]
    cfg, params = _make(arch, compute_dtype=jnp.float32, **over)
    plen, n_new = 11, 5  # prompt deliberately not a page multiple
    tokens = jax.random.randint(
        jax.random.PRNGKey(3), (1, plen), 0, cfg.vocab_size
    )
    enc = None
    if cfg.family == "encdec":
        enc = jax.random.normal(
            jax.random.PRNGKey(4), (1, cfg.encoder_len, cfg.d_model)
        )

    # contiguous reference: prefill + greedy decode, collecting logits
    cache = T.init_cache(cfg, 1, plen + n_new)
    if enc is not None:
        cache["cross"] = T.encode_cross_cache(cfg, params, enc, 1)
    cache, lg = T.prefill(cfg, params, tokens, cache)
    want = [np.asarray(lg)[0]]
    toks = [int(np.argmax(want[-1]))]
    for _ in range(n_new - 1):
        cache, lg = T.decode_step(
            cfg, params, jnp.asarray([[toks[-1]]]), cache
        )
        want.append(np.asarray(lg)[0])
        toks.append(int(np.argmax(want[-1])))

    # paged path: same tokens through the pool at padded/fixed shapes
    pc = _PC
    pool = CachePool(cfg, pc)
    slot = pool.alloc_slot()
    assert pool.ensure(slot, plen)
    padded = jnp.zeros((1, pc.prompt_pad), tokens.dtype).at[:, :plen].set(
        tokens
    )
    pcache = T.init_cache(cfg, 1, pc.prompt_pad)
    if enc is not None:
        pcache["cross"] = T.encode_cross_cache(cfg, params, enc, 1)
    pcache, lg = T.prefill(
        cfg, params, padded, pcache, valid_len=jnp.asarray([plen], jnp.int32)
    )
    pool.write_prefill(slot, pcache)
    pool.set_length(slot, plen)
    got = [np.asarray(lg)[0]]
    for t in toks[:-1]:
        assert pool.ensure(slot, int(pool.lengths[slot]) + 1)
        batch_tok = np.zeros((pc.max_batch, 1), np.int32)
        batch_tok[slot, 0] = t
        pool.pools, lg = T.decode_step_paged(
            cfg, params, jnp.asarray(batch_tok), pool.pools,
            pool.device_table(), pool.device_lengths(),
        )
        pool.bump_lengths([slot])
        got.append(np.asarray(lg)[slot])

    tol = KO.get_kernel("decode_attention").tolerance(jnp.float32)
    # the kernel tolerance bounds ONE attention output; logits see it
    # through n_layers residual adds, so scale atol by the layer count
    depth = max(cfg.n_layers, 1)
    np.testing.assert_allclose(
        np.stack(got), np.stack(want),
        rtol=tol.rtol * depth, atol=tol.atol * depth,
    )


# ---------------------------------------------------------------------------
# 3a. pool accounting: no page leaked or double-booked (100 episodes)
# ---------------------------------------------------------------------------

def _check_pool_invariants(pool):
    held = [p for pages in pool._pages_of for p in pages]
    free = pool._free_pages
    assert 0 not in held, "null page handed out"
    assert 0 not in free, "null page in the free list"
    assert len(set(held)) == len(held), "page double-booked"
    assert len(set(free)) == len(free), "free list duplicate"
    assert sorted(held + free) == list(range(1, pool.pc.n_blocks)), (
        "pages leaked or invented"
    )
    for slot, pages in enumerate(pool._pages_of):
        assert list(pool.table[slot, : len(pages)]) == pages
        assert np.all(pool.table[slot, len(pages):] == 0)


def test_no_page_leak_100_random_episodes():
    """Random admit/grow/evict sequences conserve the page pool exactly.

    (The hypothesis-driven twin lives in test_property.py; this seeded
    version keeps the invariant in the tier-1 run even where hypothesis
    is not installed.)
    """
    cfg = get_reduced("minitron_8b")
    rng = np.random.default_rng(42)
    for _ in range(100):
        pc = PoolConfig(
            max_batch=4, block_size=4,
            n_blocks=int(rng.integers(3, 20)), max_len=32, prompt_pad=8,
        )
        pool = CachePool(cfg, pc)
        live: dict[int, int] = {}  # slot -> ensured tokens
        for _ in range(30):
            op = rng.integers(0, 3)
            if op == 0:  # admit
                slot = pool.alloc_slot()
                if slot is None:
                    continue
                want = int(rng.integers(1, pc.max_len + 1))
                if pool.ensure(slot, want):
                    live[slot] = want
                else:
                    pool.release(slot)
            elif op == 1 and live:  # grow
                slot = int(rng.choice(list(live)))
                want = int(rng.integers(live[slot], pc.max_len + 1))
                if pool.ensure(slot, want):
                    live[slot] = want
            elif op == 2 and live:  # evict
                slot = int(rng.choice(list(live)))
                pool.release(slot)
                del live[slot]
            _check_pool_invariants(pool)
        for slot in list(live):
            pool.release(slot)
        _check_pool_invariants(pool)
        assert pool.free_page_count == pc.n_blocks - 1
        assert pool.free_slot_count == pc.max_batch


# ---------------------------------------------------------------------------
# 3b. continuous batching: 64-request churn, zero recompiles after warmup
# ---------------------------------------------------------------------------

def test_zero_recompiles_after_warmup_64_requests():
    cfg, params = _make("minitron_8b")
    pc = PoolConfig(
        max_batch=8, block_size=8, n_blocks=48, max_len=32, prompt_pad=16
    )
    sch = Scheduler(cfg, params, pc)
    # warmup: one short request compiles every jitted piece
    sch.run(_requests(cfg, 1, 2, seed=1))
    warm = dict(sch.trace_counts)

    rng = np.random.default_rng(2)
    reqs = []
    for i in range(64):
        plen = int(rng.integers(1, pc.prompt_pad + 1))
        reqs.append(Request(
            rid=100 + i, tokens=rng.integers(0, cfg.vocab_size, size=plen),
            max_new_tokens=int(rng.integers(1, 8)),
        ))
    results, stats = sch.run(reqs)
    assert len(results) == 64 + 1  # warmup request included
    assert sch.trace_counts == warm, (
        f"recompiled after warmup: {sch.trace_counts} != {warm}"
    )
    assert stats.peak_active == pc.max_batch  # batching actually happened


# ---------------------------------------------------------------------------
# edges: admission validation, preemption, instant finish
# ---------------------------------------------------------------------------

def test_submit_validation():
    cfg, params = _make("minitron_8b")
    sch = Scheduler(cfg, params, _PC)
    with pytest.raises(ValueError, match="prompt length"):
        sch.submit(Request(0, np.zeros(17, np.int64), 4))
    with pytest.raises(ValueError, match="max_new_tokens"):
        sch.submit(Request(0, np.zeros(4, np.int64), 0))


def test_max_new_tokens_one_finishes_at_admit():
    """The prefill logits already yield one token — no decode step."""
    cfg, params = _make("minitron_8b")
    sch = Scheduler(cfg, params, _PC)
    results, stats = sch.run(
        [Request(7, np.arange(5, dtype=np.int64), max_new_tokens=1)]
    )
    assert results[7].shape == (1,)
    assert stats.total_tokens == 0  # never hit the decode loop
    assert sch.pool.free_slot_count == _PC.max_batch


def test_oom_preemption_restarts_victim():
    """A pool too small for all admitted sequences preempts the
    youngest back to the queue, and every request still completes."""
    cfg, params = _make("minitron_8b")
    # 7 allocatable pages of 4 tokens: two 16-token sequences cannot
    # coexist at full length
    pc = PoolConfig(
        max_batch=2, block_size=4, n_blocks=8, max_len=16, prompt_pad=8
    )
    sch = Scheduler(cfg, params, pc)
    reqs = [
        Request(i, np.arange(1, 7, dtype=np.int64), max_new_tokens=10)
        for i in range(2)
    ]
    results, stats = sch.run(reqs)
    assert set(results) == {0, 1}
    assert all(r.shape == (10,) for r in results.values())
    assert stats.preemptions >= 1


def test_preemption_victim_selection_starvation_guard():
    """Victim policy unit check: youngest-first among non-exempt slots;
    when every candidate has hit max_preempts, oldest-first fallback."""
    cfg, params = _make("minitron_8b")
    sch = Scheduler(cfg, params, _PC, max_preempts=1)
    for r in _requests(cfg, 3, max_new=8):
        sch.submit(r)
    sch._admit()
    assert len(sch._admit_order) == 3
    oldest, mid, youngest = sch._admit_order
    y_rid = sch.active[youngest].req.rid

    # plain youngest-first while nobody is exempt
    assert sch._preempt_youngest(protect=oldest)
    assert sch.stats.preempt_counts == {y_rid: 1}
    assert youngest not in sch.active

    # the youngest survivor is now `mid`; exempt it -> falls to oldest
    m_rid = sch.active[mid].req.rid
    sch.stats.preempt_counts[m_rid] = 1
    assert sch._preempt_youngest(protect=-1)
    o_rid = sch.stats.preempt_counts.get(sch.queue[0].rid)
    assert sch.queue[0].rid not in (y_rid, m_rid) and o_rid == 1
    assert oldest not in sch.active

    # all remaining candidates exempt -> oldest-first fallback still evicts
    assert sch._preempt_youngest(protect=-1)
    assert sch.stats.preempt_counts[m_rid] == 2  # exceeded cap via fallback
    assert not sch.active
    assert not sch._preempt_youngest(protect=-1)  # nothing left


def test_starved_request_completes_with_frozen_traces():
    """A thrash-prone workload (pool covers barely more than one full
    sequence, several competing requests): the starvation guard caps
    per-request preemptions, every request completes, and the thrash
    never triggers a recompile after warmup."""
    cfg, params = _make("minitron_8b")
    pc = PoolConfig(
        max_batch=2, block_size=4, n_blocks=8, max_len=16, prompt_pad=8
    )
    sch = Scheduler(cfg, params, pc, max_preempts=2)
    reqs = [
        Request(i, np.arange(1, 7, dtype=np.int64), max_new_tokens=10)
        for i in range(4)
    ]
    for r in reqs:
        sch.submit(r)
    for _ in range(3):  # warmup: prefill + decode + pool jits all traced
        sch.step()
    warm = dict(sch.trace_counts)
    results, stats = sch.run()
    assert set(results) == {0, 1, 2, 3}
    assert all(r.shape == (10,) for r in results.values())
    assert stats.preemptions >= 2 and stats.preempt_counts
    assert sch.trace_counts == warm

"""Checkpointing: round-trip, atomic commit, async write, exact resume."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.ckpt.checkpoint import committed_steps
from repro.configs import get_reduced
from repro.optim.adam import AdamConfig
from repro.train.step import TrainConfig, init_train_state, train_step


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (4, 3)),
        "nested": {"b": jnp.arange(5, dtype=jnp.int32), "c": jnp.float32(2.5)},
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 7, t)
    restored, step = restore_checkpoint(tmp_path, t)
    assert step == 7
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        t, restored,
    )


def test_restore_picks_latest_committed_and_ignores_tmp(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 1, t)
    save_checkpoint(tmp_path, 5, jax.tree_util.tree_map(lambda x: x + 1, t))
    # simulate a crash mid-write: stale tmp dir
    (tmp_path / "step_9.tmp").mkdir()
    restored, step = restore_checkpoint(tmp_path, t)
    assert step == 5
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(t["a"]) + 1)


def test_keep_last_prunes(tmp_path):
    t = _tree()
    for s in range(6):
        save_checkpoint(tmp_path, s, t, keep_last=2)
    assert committed_steps(tmp_path) == [4, 5]


def test_tree_mismatch_raises(tmp_path):
    save_checkpoint(tmp_path, 0, _tree())
    bad = {"a": jnp.zeros((4, 3)), "other": jnp.zeros(2)}
    with pytest.raises(ValueError, match="mismatch"):
        restore_checkpoint(tmp_path, bad)


def test_async_manager(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    t = _tree()
    mgr.save(3, t, async_=True)
    mgr.wait()
    restored, step = mgr.restore(t)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(t["a"]))


def test_training_resume_exactness(tmp_path):
    """train 5 steps == train 3 + checkpoint + restore + train 2."""
    cfg = dataclasses.replace(get_reduced("minitron_8b"), n_layers=1)
    tc = TrainConfig(optimizer=AdamConfig(lr=1e-2, warmup_steps=1))

    def batch(i):
        k = jax.random.PRNGKey(100 + i)
        toks = jax.random.randint(k, (2, 17), 0, cfg.vocab_size)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    step = jax.jit(lambda s, b: train_step(cfg, tc, s, b))

    s_a = init_train_state(cfg, tc, jax.random.PRNGKey(0))
    for i in range(5):
        s_a, _ = step(s_a, batch(i))

    s_b = init_train_state(cfg, tc, jax.random.PRNGKey(0))
    for i in range(3):
        s_b, _ = step(s_b, batch(i))
    save_checkpoint(tmp_path, 3, s_b)
    s_c, _ = restore_checkpoint(tmp_path, s_b)
    for i in range(3, 5):
        s_c, _ = step(s_c, batch(i))

    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float64), np.asarray(b, np.float64),
            rtol=0, atol=0,
        ),
        s_a["params"], s_c["params"],
    )

"""Tier-1 driver for the multi-device tier + single-device sharded errors.

The sharded comm backend and the gossip spmd path need >= 8 devices, which
only exist if ``--xla_force_host_platform_device_count`` was set before
jax initialized. The driver spawns tests/multidevice/ in a subprocess with
the flag forced (``forced_devices_pytest`` in conftest.py) and asserts the
whole inner tier ran — zero skips — and passed. The error-path tests below
need no devices and run inline.
"""
import re

import numpy as np
import pytest

from repro.core import mixing
from repro.core.comm import DenseComm, ShardedComm, edge_coloring


def test_multidevice_tier_passes(forced_devices_pytest):
    proc = forced_devices_pytest("tests/multidevice", n_devices=8)
    out = proc.stdout + proc.stderr
    assert proc.returncode == 0, out
    m = re.search(r"(\d+) passed", out)
    assert m, out
    # 14 parity cases (7 methods x 2 graphs) + the dsgda/bilinear parity,
    # the sharded capability matrix, the accounting/cache/error/gossip
    # tests, and the dynamic-network leg (churn shrink 8->6 parity + the
    # schedule switch): the tier must actually RUN under 8 devices, not
    # skip itself away
    assert int(m.group(1)) >= 24, out
    assert "skipped" not in out, out


def test_make_node_mesh_raises_with_reproduction_hint():
    from repro.launch.mesh import make_node_mesh

    import jax

    n = len(jax.devices()) + 1
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        make_node_mesh(n)


def test_production_mesh_raises_on_short_devices():
    """The old behavior built a short-device mesh and failed inside jax's
    reshape; now the device-count check fails first, with the fix."""
    from repro.launch.mesh import make_production_mesh

    with pytest.raises(ValueError, match="256 devices"):
        make_production_mesh()
    with pytest.raises(ValueError, match="512 devices"):
        make_production_mesh(multi_pod=True)


def test_edge_coloring_is_a_partition_into_matchings():
    graph = mixing.erdos_renyi_graph(12, 0.4, seed=3)
    colors = edge_coloring(graph.edges, graph.n)
    seen = []
    for color in colors:
        nodes = [v for e in color for v in e]
        assert len(nodes) == len(set(nodes))  # a matching
        seen.extend(tuple(sorted(e)) for e in color)
    assert sorted(seen) == sorted(tuple(sorted(e)) for e in graph.edges)
    maxdeg = max(
        sum(1 for e in graph.edges if v in e) for v in range(graph.n)
    )
    assert len(colors) <= 2 * maxdeg - 1
    # deterministic: same input, same schedule (stable HLO across processes)
    assert colors == edge_coloring(graph.edges, graph.n)


def test_dense_comm_matvec_is_the_matmul():
    import jax.numpy as jnp

    graph = mixing.ring_graph(6)
    w = mixing.metropolis_mixing(graph)
    comm = DenseComm(graph)
    x = np.random.default_rng(0).standard_normal((6, 4))
    got = comm.matvec(w, jnp.float64)(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(
        jnp.asarray(w, jnp.float64) @ jnp.asarray(x)))
    np.testing.assert_array_equal(
        np.asarray(comm.local(jnp.asarray(x))), x
    )


def test_sharded_comm_rejects_off_graph_matrix():
    from repro.core.comm import _check_support

    graph = mixing.ring_graph(5)
    m = np.asarray(mixing.metropolis_mixing(graph))
    bad = m.copy()
    bad[0, 2] = 0.1  # (0, 2) is not a ring edge
    with pytest.raises(ValueError, match="not an edge"):
        _check_support(bad, graph)
    _check_support(m, graph)  # the real mixing matrix passes


def test_sharded_comm_requires_node_axis_mesh():
    import jax

    graph = mixing.ring_graph(4)
    mesh = jax.make_mesh((1,), ("pod",), devices=np.asarray(jax.devices()[:1]))
    with pytest.raises(ValueError, match="'node' mesh axis"):
        ShardedComm(graph, mesh)

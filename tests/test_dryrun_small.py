"""Small-mesh dry-run smoke: lower + compile the REAL step functions on an
8-device host mesh in a SUBPROCESS (so the 1-device default of the rest of
the test suite is untouched — the spec forbids setting the device-count flag
globally)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json, sys
    import jax
    import numpy as np
    from repro.configs import get_reduced
    from repro.launch.dryrun import build_cell
    from repro.launch import hlo_analysis as H
    from repro.models.layers import use_constraint_mesh

    arch, shape, multi = sys.argv[1], sys.argv[2], sys.argv[3] == "multi"
    mesh_shape = (2, 2, 2) if multi else (2, 4)
    axes = ("pod", "data", "model") if multi else ("data", "model")
    mesh = jax.make_mesh(mesh_shape, axes, devices=np.asarray(jax.devices()))

    cfg = get_reduced(arch)
    # shrink the shape grid to smoke scale
    from repro.launch import shapes as S
    S.SHAPES = {
        "train_4k": S.ShapeSpec("train_4k", "train", 64, 8),
        "prefill_32k": S.ShapeSpec("prefill_32k", "prefill", 128, 4),
        "decode_32k": S.ShapeSpec("decode_32k", "decode", 128, 8),
        "long_500k": S.ShapeSpec("long_500k", "decode", 256, 1),
    }
    with mesh, use_constraint_mesh(mesh):
        fn, sds = build_cell(cfg, shape, mesh, multi)
        compiled = fn.lower(*sds).compile()
        cost = H.xla_cost_analysis(compiled)
        colls = H.collective_stats(compiled.as_text())
    print(json.dumps({
        "flops": float(cost.get("flops", 0)),
        "collective_bytes": colls.total_bytes,
        "collective_ops": sorted(colls.count_by_op),
    }))
    """
)


def run_cell(arch, shape, mesh="single"):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, arch, shape, mesh],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("arch,shape", [
    ("minitron_8b", "train_4k"),
    ("gemma2_2b", "train_4k"),
    ("kimi_k2", "train_4k"),
    ("mamba2_1p3b", "train_4k"),
    ("whisper_small", "train_4k"),
    ("zamba2_1p2b", "decode_32k"),
    ("qwen2_moe", "prefill_32k"),
])
def test_single_pod_cells_compile(arch, shape):
    rec = run_cell(arch, shape, "single")
    assert rec["flops"] > 0


def test_multi_pod_gossip_train_compiles_with_collective_permute():
    rec = run_cell("minitron_8b", "train_4k", "multi")
    assert rec["flops"] > 0
    # the pod axis must communicate via neighbor permutes (the paper's
    # pattern), which XLA emits as collective-permute
    assert "collective-permute" in rec["collective_ops"], rec["collective_ops"]


def test_multi_pod_serve_compiles():
    rec = run_cell("mamba2_1p3b", "decode_32k", "multi")
    assert rec["flops"] > 0

"""Multi-device tier: dynamic networks under the sharded backend, 8 devices.

The churn leg of the dynamic-network contract (tests/test_dynamic_graphs.py
covers the single-device legs): a mid-run shrink 8 -> 6 re-meshes the
sharded runner onto the survivor device set, with per-step parity against
the dense reference, and a graph schedule re-derives its edge colorings per
segment on the same mesh. Run via tests/test_sharded.py (forced host
devices); collected single-device, everything here skips.
"""
import dataclasses

import numpy as np
import pytest

import jax

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs >= 8 devices (run via tests/test_sharded.py)",
)

N = 8


def _problem():
    from repro.core import mixing
    from repro.core.solvers import make_problem
    from repro.data.synthetic import make_regression

    data = make_regression(N, 12, 6, k=4, seed=0)
    return make_problem("ridge", data, mixing.ring_graph(N), lam=1e-2)


def test_single_segment_schedule_bit_equal_static_sharded():
    """The third backend's leg of the bit-equality contract (dense and
    sparse run in tests/test_dynamic_graphs.py)."""
    from repro.core.solvers import solve

    problem = _problem()
    problem.solve_star()
    ps = dataclasses.replace(problem, schedule=((0, problem.graph),))
    kw = dict(steps=20, record_every=5, seed=1, alpha=0.05)
    r0 = solve(problem, "dsba", comm="sharded", **kw)
    r1 = solve(ps, "dsba", comm="sharded", **kw)
    assert np.array_equal(np.asarray(r0.z), np.asarray(r1.z))  # BIT equal
    assert np.array_equal(np.asarray(r0.dist2), np.asarray(r1.dist2))
    np.testing.assert_array_equal(
        r0.measured_collective_bytes, r1.measured_collective_bytes
    )


@pytest.mark.parametrize("method", ["dsba", "dsa"])
def test_sharded_churn_shrink_8_to_6_matches_dense(method):
    """Kill two nodes mid-run: the sharded run re-meshes onto 6 devices and
    stays in 1e-12 parity with the dense run, before and after the event."""
    from repro.core.solvers import ChurnEvent, ChurnPlan, solve

    problem = _problem()
    problem.solve_star()
    plan = ChurnPlan((ChurnEvent(at=10, kind="kill", nodes=(6, 7)),))
    kw = dict(steps=24, record_every=4, seed=1, alpha=0.05,
              comm_options={"fault_plan": plan})
    rd = solve(problem, method, comm="dense", **kw)
    rs = solve(problem, method, comm="sharded", **kw)
    assert rs.z.shape == (6, rd.z.shape[1])
    np.testing.assert_allclose(
        np.asarray(rs.z), np.asarray(rd.z), atol=1e-12, rtol=0
    )
    np.testing.assert_allclose(
        np.asarray(rs.dist2), np.asarray(rd.dist2), atol=1e-12, rtol=1e-9
    )
    assert rs.extras["mesh_devices"] == N  # first phase's mesh
    assert rs.extras["churn_rows"] == N
    # modeled accounting identical across backends; measured bytes recorded
    np.testing.assert_array_equal(rd.doubles_received, rs.doubles_received)
    mb = np.asarray(rs.measured_collective_bytes)
    assert mb.shape == rs.iters.shape and (np.diff(mb) > 0).all()


def test_sharded_churn_reconverges_on_survivors():
    """Longer horizon: the survivor system's root is actually reached
    (the reanchored state targets the NEW membership, not the stale one)."""
    from repro.core import mixing
    from repro.core.solvers import ChurnEvent, ChurnPlan, make_problem, solve

    problem = _problem()
    plan = ChurnPlan((ChurnEvent(at=100, kind="kill", nodes=(6, 7)),))
    r = solve(problem, "dsba", comm="sharded", steps=1500, record_every=500,
              seed=1, comm_options={"fault_plan": plan})
    data = problem.data
    cdata = dataclasses.replace(
        data, idx=data.idx[:6], val=data.val[:6], y=data.y[:6]
    )
    child = make_problem("ridge", cdata, problem.graph.subgraph(range(6)),
                         lam=1e-2)
    zc = child.solve_star()
    assert float(np.mean(np.sum((np.asarray(r.z) - zc) ** 2, -1))) < 1e-9


def test_sharded_schedule_matches_dense_across_switch():
    """Two segments, same membership: each segment's edge coloring is
    re-derived on the same 8-device mesh; dense parity holds throughout."""
    from repro.core import mixing
    from repro.core.solvers import solve

    problem = _problem()
    problem.solve_star()
    g2 = mixing.erdos_renyi_graph(N, 0.4, seed=1)
    ps = dataclasses.replace(problem, schedule=((0, problem.graph), (12, g2)))
    kw = dict(steps=24, record_every=4, seed=1, alpha=0.05)
    rd = solve(ps, "dsba", comm="dense", **kw)
    rs = solve(ps, "dsba", comm="sharded", **kw)
    np.testing.assert_allclose(
        np.asarray(rs.z), np.asarray(rd.z), atol=1e-12, rtol=0
    )
    np.testing.assert_allclose(
        np.asarray(rs.dist2), np.asarray(rd.dist2), atol=1e-12, rtol=1e-9
    )
    gaps = [s["spectral_gap"] for s in rs.extras["schedule"]]
    assert len(gaps) == 2 and all(g > 0 for g in gaps)

"""Multi-device tier: sharded comm parity + gossip spmd backend, 8 devices.

These tests require a real (forced-host) multi-device runtime:
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` must be set BEFORE
jax initializes, which a normal pytest process cannot retrofit. The tier-1
driver ``tests/test_sharded.py`` runs this directory in a fresh subprocess
with the flag set (the ``forced_devices_pytest`` fixture in conftest.py);
collected in an ordinary single-device run, everything here skips.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs >= 8 devices (run via tests/test_sharded.py)",
)

N = 8
METHOD_HP = {
    "dsba": {"alpha": 0.05},
    "dsa": {"alpha": 0.05},
    "extra": {"alpha": 0.05},
    "dlm": {"c": 0.5, "beta": 1.0},
    "ssda": {"eta": 0.05},
    # accelerated/sliding methods route K inner gossip rounds (resp. the
    # periodic mixing select) through the same comm.matvec primitive
    "mudag": {"eta": 0.5, "momentum": 0.5, "gossip_rounds": 2},
    "sliding": {"alpha": 0.05, "comm_period": 2},
}


def _problem(topology):
    from repro.core import mixing
    from repro.core.solvers import make_problem
    from repro.data.synthetic import make_regression

    data = make_regression(N, 12, 6, k=4, seed=0)
    if topology == "ring":
        graph = mixing.ring_graph(N)
    else:
        graph = mixing.erdos_renyi_graph(N, 0.4, seed=1)
    return make_problem("ridge", data, graph, lam=1e-2)


@pytest.mark.parametrize("topology", ["ring", "erdos_renyi"])
@pytest.mark.parametrize("method", sorted(METHOD_HP))
def test_sharded_matches_dense(method, topology):
    """Every method, both graphs: shard_map mixing == dense matmul 1e-12."""
    from repro.core.solvers import solve

    problem = _problem(topology)
    hp = METHOD_HP[method]
    rd = solve(problem, method, steps=20, record_every=10, seed=1,
               comm="dense", **hp)
    rs = solve(problem, method, steps=20, record_every=10, seed=1,
               comm="sharded", **hp)
    np.testing.assert_allclose(
        np.asarray(rs.z), np.asarray(rd.z), atol=1e-12, rtol=0
    )
    np.testing.assert_allclose(
        np.asarray(rs.dist2), np.asarray(rd.dist2), atol=1e-12, rtol=1e-9
    )


def test_dsgda_sharded_matches_dense_on_bilinear():
    """The minimax family through the sharded backend: same 1e-12 parity."""
    from repro.core import mixing
    from repro.core.solvers import make_problem, solve
    from repro.data.synthetic import make_regression

    data = make_regression(N, 12, 6, k=4, seed=2)
    problem = make_problem(
        "bilinear", data, mixing.ring_graph(N), lam=5e-2
    )
    problem.solve_star()
    kw = dict(steps=20, record_every=10, seed=1, alpha=0.2, eta=0.2)
    rd = solve(problem, "dsgda", comm="dense", **kw)
    rs = solve(problem, "dsgda", comm="sharded", **kw)
    np.testing.assert_allclose(
        np.asarray(rs.z), np.asarray(rd.z), atol=1e-12, rtol=0
    )
    np.testing.assert_allclose(
        np.asarray(rs.dist2), np.asarray(rd.dist2), atol=1e-12, rtol=1e-9
    )


def test_sharded_capability_matrix_no_third_outcome():
    """The sharded leg of tests/test_capabilities.py: every (method,
    family) on a 4-node ring either solves under comm="sharded" or raises
    CapabilityError, in exact agreement with the capability record."""
    from repro.core import mixing
    from repro.core.operators import FAMILIES
    from repro.core.solvers import (
        CapabilityError, available_solvers, make_problem, solve,
    )
    from repro.data.synthetic import make_classification, make_regression

    n, q, d = 4, 4, 6
    hp = {"ssda": dict(eta=1e-3, momentum=0.0),
          "mudag": dict(eta=0.5, momentum=0.5)}
    for family in FAMILIES:
        if family in ("ridge", "bilinear"):
            data = make_regression(n, q, d, k=3, seed=0)
        else:
            data = make_classification(n, q, d, k=3, positive_ratio=0.5,
                                       seed=0)
        problem = make_problem(family, data, mixing.ring_graph(n), lam=1e-2)
        for method, caps in sorted(available_solvers().items()):
            try:
                res = solve(problem, method, comm="sharded", steps=2,
                            record_every=2, seed=0, **hp.get(method, {}))
            except CapabilityError as e:
                assert not caps.supports("sharded", family)
                assert (e.method, e.comm, e.family) == (
                    method, "sharded", family
                )
                continue
            assert caps.supports("sharded", family), (method, family)
            assert np.isfinite(np.asarray(res.z)).all(), (method, family)


def test_measured_collective_bytes_accounting():
    """SolveResult carries HLO-measured collective traffic, scaling with
    iterations, and denser graphs move proportionally more bytes."""
    from repro.core.solvers import solve

    res = {}
    for topology in ("ring", "erdos_renyi"):
        problem = _problem(topology)
        r = solve(problem, "dsba", steps=20, record_every=5, seed=1,
                  comm="sharded", alpha=0.05)
        mb = np.asarray(r.measured_collective_bytes)
        assert mb.shape == r.iters.shape
        assert (mb > 0).all()
        # linear in iteration count: bytes/iter is a compile-time constant
        np.testing.assert_allclose(mb / r.iters, mb[0] / r.iters[0])
        assert r.extras["collectives"]["count_per_iter"] > 0
        assert r.extras["mesh_devices"] == N
        res[topology] = r
    ring, er = res["ring"], res["erdos_renyi"]
    # the ER draw has more edges than the ring -> more collective traffic
    assert (
        er.extras["collectives"]["bytes_per_iter"]
        > ring.extras["collectives"]["bytes_per_iter"]
    )
    # dense comm never reports measured bytes
    rd = solve(_problem("ring"), "dsba", steps=4, seed=1, alpha=0.05)
    assert rd.measured_collective_bytes is None


def test_explicit_mesh_and_runner_cache_key():
    """A prebuilt mesh via comm_options reuses the cached sharded runner."""
    from repro.core import runner_cache
    from repro.core.solvers import solve
    from repro.launch.mesh import make_node_mesh

    problem = _problem("ring")
    mesh = make_node_mesh(N)
    before = runner_cache.SHARDED.stats()["misses"]
    r1 = solve(problem, "dsba", steps=8, seed=1, comm="sharded",
               alpha=0.05, comm_options={"mesh": mesh})
    mid = runner_cache.SHARDED.stats()
    r2 = solve(problem, "dsba", steps=8, seed=1, comm="sharded",
               alpha=0.1, comm_options={"mesh": mesh})
    after = runner_cache.SHARDED.stats()
    assert mid["misses"] == before + 1
    assert after["misses"] == mid["misses"]  # second call: pure hits
    assert after["hits"] > mid["hits"]
    assert not np.array_equal(np.asarray(r1.z), np.asarray(r2.z))


def test_sharded_rejects_wrong_mesh_and_options():
    from repro.core.comm import ShardedComm
    from repro.core.solvers import solve
    from repro.launch.mesh import make_node_mesh

    problem = _problem("ring")
    small = make_node_mesh(4)
    with pytest.raises(ValueError, match="node"):
        ShardedComm(problem.graph, small)
    with pytest.raises(ValueError, match="comm_options"):
        solve(problem, "dsba", steps=2, comm_options={"mesh": small})
    with pytest.raises(ValueError, match="unknown sharded comm_options"):
        solve(problem, "dsba", steps=2, comm="sharded",
              comm_options={"engine": "vectorized"})


def test_gossip_dense_mix_spmd_matches_local():
    """The pod-axis gossip mixing: shard_map backend == local roll backend."""
    from jax.sharding import PartitionSpec as P

    from repro.core.gossip import GossipConfig, make_dense_mix

    gc = GossipConfig(n_pods=8, topology="ring")
    mesh = jax.make_mesh((8,), ("pod",))
    leaf_specs = {"a": P(), "b": P()}
    rng = np.random.default_rng(3)
    tree = {
        "a": jnp.asarray(rng.standard_normal((8, 5, 3))),
        "b": jnp.asarray(rng.standard_normal((8, 4))),
    }
    local = make_dense_mix(None, gc, None)(tree)
    spmd = jax.jit(make_dense_mix(mesh, gc, leaf_specs))(tree)
    for k in tree:
        np.testing.assert_allclose(
            np.asarray(spmd[k]), np.asarray(local[k]), atol=1e-12
        )

"""Multi-device tier: link faults on the sharded backend, 8 devices.

The sharded legs of the fault-injection contract (tests/test_faults.py
covers dense/sparse): a p=0 plan is bit-equal to plan-free by routing,
a p>0 plan matches the dense masked-matvec run while leaving the
PHYSICAL ppermute schedule — and hence the measured collective bytes —
untouched (drops are modeled in the combine, not the transport). Run
via tests/test_sharded.py (forced host devices); collected
single-device, everything here skips.
"""
import numpy as np
import pytest

import jax

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs >= 8 devices (run via tests/test_sharded.py)",
)

N = 8


def _problem():
    from repro.core import mixing
    from repro.core.solvers import make_problem
    from repro.data.synthetic import make_regression

    data = make_regression(N, 12, 6, k=4, seed=0)
    p = make_problem("ridge", data, mixing.ring_graph(N), lam=1e-2)
    p.solve_star()
    return p


def test_sharded_p0_plan_bit_equal_plan_free():
    from repro.core.solvers import FaultPlan, LinkFault, solve

    problem = _problem()
    kw = dict(steps=20, record_every=5, seed=1)
    r0 = solve(problem, "dsba", comm="sharded", **kw)
    r1 = solve(problem, "dsba", comm="sharded",
               comm_options={"fault_plan": FaultPlan(link=LinkFault(p=0.0))},
               **kw)
    assert np.array_equal(np.asarray(r0.z), np.asarray(r1.z))  # BIT equal
    assert np.array_equal(np.asarray(r0.dist2), np.asarray(r1.dist2))
    np.testing.assert_array_equal(
        r0.measured_collective_bytes, r1.measured_collective_bytes
    )
    f = r1.extras["faults"]
    assert f["drop_rate"] == 0.0
    assert f["injected_messages"] == f["delivered_messages"] > 0


def test_sharded_link_faults_match_dense_and_keep_physical_bytes():
    """The same delivery mask drives both backends' combines, so the
    iterates agree; the ppermutes still run every round, so measured
    bytes equal the fault-free run's."""
    from repro.core.solvers import FaultPlan, LinkFault, solve

    problem = _problem()
    plan = FaultPlan(link=LinkFault(p=0.2, seed=7))
    kw = dict(steps=24, record_every=4, seed=1,
              comm_options={"fault_plan": plan})
    rd = solve(problem, "dsba", comm="dense", **kw)
    rs = solve(problem, "dsba", comm="sharded", **kw)
    np.testing.assert_allclose(np.asarray(rs.z), np.asarray(rd.z),
                               atol=1e-10, rtol=0)
    np.testing.assert_allclose(np.asarray(rs.dist2), np.asarray(rd.dist2),
                               atol=1e-10, rtol=1e-6)
    # modeled (delivered-only) accounting agrees across backends
    np.testing.assert_array_equal(rd.doubles_received, rs.doubles_received)
    # physical transport unchanged: bytes match the fault-free schedule
    r0 = solve(problem, "dsba", comm="sharded", steps=24, record_every=4,
               seed=1)
    np.testing.assert_array_equal(
        rs.measured_collective_bytes, r0.measured_collective_bytes
    )


def test_sharded_churn_composes_with_link_faults():
    from repro.core.solvers import (
        ChurnEvent, ChurnPlan, FaultPlan, LinkFault, solve,
    )

    problem = _problem()
    plan = FaultPlan(
        churn=ChurnPlan((ChurnEvent(at=10, kind="kill", nodes=(6, 7)),)),
        link=LinkFault(p=0.15, seed=11),
    )
    kw = dict(steps=24, record_every=4, seed=1,
              comm_options={"fault_plan": plan})
    rd = solve(problem, "dsba", comm="dense", **kw)
    rs = solve(problem, "dsba", comm="sharded", **kw)
    assert rs.z.shape == (6, rd.z.shape[1])
    np.testing.assert_allclose(np.asarray(rs.z), np.asarray(rd.z),
                               atol=1e-10, rtol=0)
    np.testing.assert_array_equal(rd.doubles_received, rs.doubles_received)
    assert rs.extras["churn_rows"] == N
    f = rs.extras["faults"]
    assert 0 < f["delivered_messages"] < f["injected_messages"]

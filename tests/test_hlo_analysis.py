"""Trip-count-aware HLO cost analysis vs XLA cost_analysis + manual math."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as H


def _compile(fn, *sds):
    return jax.jit(fn).lower(*sds).compile()


def test_dot_flops_match_cost_analysis_no_loops():
    """On a loop-free program our counter matches XLA's flops closely."""
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 512), jnp.float32)

    comp = _compile(lambda a, b: a @ b, x, w)
    want = H.xla_cost_analysis(comp)["flops"]
    got = H.program_costs(comp.as_text()).flops
    assert abs(got - want) / want < 0.05, (got, want)


def test_scan_flops_multiplied_by_trip_count():
    """XLA counts a scan body once; program_costs multiplies by trips."""
    L, M = 16, 128
    x = jax.ShapeDtypeStruct((M, M), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, M, M), jnp.float32)

    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    comp = _compile(scanned, x, ws)
    xla_flops = H.xla_cost_analysis(comp)["flops"]
    ours = H.program_costs(comp.as_text()).flops
    one_matmul = 2 * M * M * M
    # XLA reports ~1 matmul; we must report ~L matmuls
    assert xla_flops < 2 * one_matmul
    assert ours == pytest.approx(L * one_matmul, rel=0.1), (
        ours / one_matmul, L
    )


def test_nested_scan_multiplicities_compose():
    L1, L2, M = 4, 8, 64
    x = jax.ShapeDtypeStruct((M, M), jnp.float32)
    ws = jax.ShapeDtypeStruct((L1, L2, M, M), jnp.float32)

    def nested(x, ws):
        def outer(c, wrow):
            def inner(ci, w):
                return ci @ w, None
            c2, _ = jax.lax.scan(inner, c, wrow)
            return c2, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    comp = _compile(nested, x, ws)
    ours = H.program_costs(comp.as_text()).flops
    want = L1 * L2 * 2 * M**3
    assert ours == pytest.approx(want, rel=0.15), ours / (2 * M**3)


def test_shape_bytes_tuple_types():
    assert H._shape_bytes("f32[2,3]") == 24
    assert H._shape_bytes("(f32[4], bf16[8])") == 16 + 16
    assert H._shape_bytes("s32[]") == 4
    assert H._shape_bytes("pred[10]") == 10


def test_collective_bytes_inside_loops_are_multiplied():
    """all-reduce inside a scan counts trip_count times."""
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch import hlo_analysis as H

        mesh = jax.make_mesh((4,), ("data",), devices=np.asarray(jax.devices()))
        L, M = 8, 64

        def f(x, ws):
            def body(c, w):
                y = c @ w
                return jax.lax.with_sharding_constraint(
                    y, NamedSharding(mesh, P())), None
            out, _ = jax.lax.scan(body, x, ws)
            return out

        xs = jax.ShapeDtypeStruct((M, M), jnp.float32)
        ws = jax.ShapeDtypeStruct((L, M, M), jnp.float32)
        with mesh:
            comp = jax.jit(
                f,
                in_shardings=(NamedSharding(mesh, P("data", None)),
                              NamedSharding(mesh, P(None, "data", None))),
            ).lower(xs, ws).compile()
        pc = H.program_costs(comp.as_text())
        ops = set(pc.coll_count_by_op)
        counts = {k: int(v) for k, v in pc.coll_count_by_op.items()}
        # some collective must appear with multiplicity ~L
        print("OK", max(counts.values()) >= L / 2, counts)
    """)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=300, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK True" in out.stdout, out.stdout

"""PR 7 solver families: mudag / sliding (accelerated + sliding descent)
and dsgda on the bilinear minimax family.

Four claims:

1. Convergence — mudag converges linearly at the accelerated rate on the
   ridge consensus problem; sliding converges with periodic communication;
   dsgda reaches the exact regularized saddle on bilinear AND auc.
2. Communication accounting — the ``comm_rounds`` hooks feed
   ``doubles_received``: mudag reports 2K dense exchanges per iteration,
   sliding reports only the rounds actually taken (2*ceil(iters/period)),
   and mudag's rounds-to-1e-9 beat DSA's by >= 2x on the same problem.
3. No-retrace K sweeps — ``gossip_rounds`` is runtime-traced (fori_loop
   with a traced trip count), so a K sweep reuses one compiled runner.
4. The bilinear saddle — ``solve_star()`` is a genuine saddle oracle
   (stationary point of the regularized Lagrangian), and the scalar-table
   machinery (dsba, dense and sparse comm) handles the family unchanged.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mixing
from repro.core.solvers import (
    clear_runner_caches,
    make_problem,
    runner_cache_stats,
    solve,
    solve_many,
)
from repro.data.synthetic import make_classification, make_regression

N, DEG = 4, 2  # ring: every node has two neighbors


def _ridge_problem(d=12, lam=1e-2):
    data = make_regression(N, 8, d, k=4, seed=0)
    problem = make_problem("ridge", data, mixing.ring_graph(N), lam=lam)
    problem.solve_star()
    return problem


def _bilinear_problem(d=10, lam=5e-2, gamma=1.0):
    data = make_regression(N, 8, d, k=4, seed=1)
    problem = make_problem(
        "bilinear", data, mixing.ring_graph(N), lam=lam, gamma=gamma
    )
    problem.solve_star()
    return problem


# ---------------------------------------------------------------------------
# mudag: accelerated convergence + 2K-rounds-per-iteration accounting
# ---------------------------------------------------------------------------


def test_mudag_converges_at_accelerated_rate():
    problem = _ridge_problem()
    res = solve(problem, "mudag", steps=150, record_every=50,
                eta=0.8, momentum=0.8, gossip_rounds=3)
    assert res.dist2[-1] < 1e-12
    # linear: every 50-iteration block contracts by orders of magnitude
    assert (res.dist2[1:] < 1e-4 * res.dist2[:-1]).all()


def test_mudag_comm_accounting_is_2k_rounds_per_iter():
    problem = _ridge_problem()
    k = 3
    res = solve(problem, "mudag", steps=100, record_every=50,
                eta=0.8, momentum=0.8, gossip_rounds=k)
    want = 2 * k * res.iters[:, None] * DEG * problem.data.d
    np.testing.assert_array_equal(
        res.doubles_received, np.broadcast_to(want, res.doubles_received.shape)
    )


def test_mudag_halves_dsa_dense_rounds_to_target():
    """The acceptance bar (ISSUE 7): dist2 <= 1e-9 in at most HALF the
    dense-communication rounds DSA needs, on the same ridge problem.
    (The paper-sized version of this comparison lives in
    ``benchmarks/bench_convergence.py``.)"""
    problem = _ridge_problem()
    k = 3
    rm = solve(problem, "mudag", steps=150, record_every=10,
               eta=0.8, momentum=0.8, gossip_rounds=k)
    rd = solve(problem, "dsa", steps=6000, record_every=100, alpha=0.2,
               seed=0)

    def rounds_to_target(res, rounds_per_iter):
        hit = np.flatnonzero(res.dist2 <= 1e-9)
        assert hit.size, "never reached 1e-9"
        return int(res.iters[hit[0]]) * rounds_per_iter

    mudag_rounds = rounds_to_target(rm, 2 * k)
    dsa_rounds = rounds_to_target(rd, 1)
    assert mudag_rounds <= dsa_rounds / 2, (mudag_rounds, dsa_rounds)


def test_mudag_k_sweep_reuses_one_compiled_runner():
    """gossip_rounds is traced (fori_loop trip count): new K, zero retraces."""
    clear_runner_caches()
    problem = _ridge_problem()
    r2 = solve(problem, "mudag", steps=40, record_every=40, gossip_rounds=2)
    s0 = runner_cache_stats()["dense"]
    assert s0["misses"] == 1
    r6 = solve(problem, "mudag", steps=40, record_every=40, gossip_rounds=6)
    s1 = runner_cache_stats()["dense"]
    assert s1["traces"] == s0["traces"], "new K must not recompile"
    assert s1["hits"] == s0["hits"] + 1
    # and K genuinely changes the run: more gossip, better consensus
    assert not np.array_equal(r2.z, r6.z)
    assert r6.consensus[-1] < r2.consensus[-1]
    # accounting follows K through the same compiled runner
    assert r6.doubles_received[-1, 0] == 3 * r2.doubles_received[-1, 0]


def test_mudag_k_grid_through_solve_many_matches_sequential():
    """A K grid vmaps over the traced trip count (while-loop batching) and
    must agree with sequential solves, accounting included."""
    problem = _ridge_problem()
    grid = [{"gossip_rounds": 2.0}, {"gossip_rounds": 5.0}]
    batched = solve_many(problem, "mudag", steps=30, record_every=15,
                         grid=grid)
    for b, g in enumerate(grid):
        seq = solve(problem, "mudag", steps=30, record_every=15, **g)
        np.testing.assert_allclose(batched.z[b], seq.z, atol=1e-12, rtol=0)
        np.testing.assert_array_equal(
            batched.doubles_received[b], seq.doubles_received
        )


# ---------------------------------------------------------------------------
# sliding: skipped rounds must show up as savings in the accounting
# ---------------------------------------------------------------------------


def test_sliding_converges_with_periodic_communication():
    problem = _ridge_problem()
    res = solve(problem, "sliding", steps=1200, record_every=400,
                alpha=0.5, comm_period=4)
    assert res.dist2[-1] < 1e-8
    assert (np.diff(res.dist2) < 0).all()


def test_sliding_accounts_only_taken_rounds():
    problem = _ridge_problem()
    d = problem.data.d
    res = solve(problem, "sliding", steps=10, record_every=5,
                alpha=0.3, comm_period=4)
    rounds = 2 * np.ceil(res.iters / 4)  # z and s exchanged on-round only
    want = rounds[:, None] * DEG * d
    np.testing.assert_array_equal(
        res.doubles_received, np.broadcast_to(want, res.doubles_received.shape)
    )
    # the point of sliding: strictly fewer doubles than one-round-per-iter
    ref = solve(problem, "dsa", steps=10, record_every=5, alpha=0.2, seed=0)
    assert (res.doubles_received < ref.doubles_received).all()


# ---------------------------------------------------------------------------
# the bilinear minimax family: saddle oracle + dsgda + scalar tables
# ---------------------------------------------------------------------------


def test_solve_star_is_a_saddle_point_of_the_lagrangian():
    """z* from the generic Newton root-finder must be a stationary point of
    the regularized Lagrangian L + lam/2 ||w||^2 - lam/2 theta^2 — i.e. a
    genuine saddle oracle, not just a root of some operator."""
    problem = _bilinear_problem()
    d = problem.data.d
    gamma, lam = problem.spec.gamma, problem.lam
    feats = jnp.asarray(problem.data.dense()).reshape(-1, d)
    labels = jnp.asarray(problem.data.y).reshape(-1)

    def lagrangian(z):
        w, th = z[:d], z[d]
        u = feats @ w
        val = jnp.mean(0.5 * (u - labels) ** 2 + th * labels * u)
        val = val - 0.5 * gamma * th**2
        return val + 0.5 * lam * jnp.sum(w * w) - 0.5 * lam * th**2

    grad = jax.grad(lagrangian)(jnp.asarray(problem.solve_star()))
    # min block: gradient vanishes; max block: d/dtheta vanishes too (the
    # operator negates it, so a root is stationary in BOTH directions)
    np.testing.assert_allclose(np.asarray(grad), 0.0, atol=1e-8)


def test_dsgda_converges_to_saddle_oracle_bilinear():
    """ISSUE 7 acceptance: dist2 to the saddle oracle <= 1e-6."""
    problem = _bilinear_problem()
    res = solve(problem, "dsgda", steps=1500, record_every=500,
                alpha=0.3, eta=0.3, seed=0)
    assert res.dist2[-1] <= 1e-6
    assert res.dist2[-1] < 1e-3 * res.dist2[0]


def test_dsgda_converges_on_auc_saddle():
    data = make_classification(N, 8, 10, k=4, positive_ratio=0.3, seed=0)
    problem = make_problem("auc", data, mixing.ring_graph(N), lam=1e-1)
    problem.solve_star()
    res = solve(problem, "dsgda", steps=2000, record_every=1000,
                alpha=0.1, eta=0.1, seed=0)
    assert res.dist2[-1] <= 1e-6


def test_dsba_scalar_tables_cover_bilinear_dense_and_sparse():
    """The family rides the existing machinery: dsba's backward step
    converges on bilinear and the sparse relay reproduces the dense run."""
    problem = _bilinear_problem()
    rd = solve(problem, "dsba", steps=400, record_every=400, alpha=0.5,
               seed=0)
    rs = solve(problem, "dsba", comm="sparse", steps=400, record_every=400,
               alpha=0.5, seed=0)
    assert rd.dist2[-1] < 1e-10
    np.testing.assert_allclose(rs.z, rd.z, atol=1e-10, rtol=0)


def test_make_problem_passes_gamma_through():
    p1 = _bilinear_problem(gamma=1.0)
    p2 = _bilinear_problem(gamma=3.0)
    assert p1.spec.gamma == 1.0 and p2.spec.gamma == 3.0
    # a stiffer dual curvature moves the saddle: the oracle must see gamma
    assert not np.allclose(p1.solve_star(), p2.solve_star())
    with pytest.raises(ValueError, match="unknown task"):
        make_problem("quantile", p1.data, p1.graph)

"""MoE dispatch paths: grouped-einsum (GShard-style) == scatter (dropless)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.models.layers import moe
from repro.models.params import tree_materialize


def _cfg(**kw):
    kw.setdefault("capacity_factor", 8.0)  # dropless at test scale
    return dataclasses.replace(
        get_reduced("qwen2_moe"), compute_dtype=jnp.float32, **kw,
    )


@pytest.mark.parametrize("groups", [1, 2, 4])
def test_grouped_einsum_matches_scatter_dropless(groups):
    base = _cfg()
    grouped = _cfg(moe_groups=groups)
    params = tree_materialize(T.model_defs(base), jax.random.PRNGKey(0),
                              base.param_dtype)
    # use one layer's moe params directly
    p = jax.tree_util.tree_map(lambda a: a[0], params["blocks"])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, base.d_model))
    y_scatter = moe(base, p, x)
    y_grouped = moe(grouped, p, x)
    np.testing.assert_allclose(np.asarray(y_grouped), np.asarray(y_scatter),
                               rtol=1e-4, atol=1e-5)


def test_grouped_full_forward_matches():
    base = _cfg()
    grouped = _cfg(moe_groups=4)
    params = tree_materialize(T.model_defs(base), jax.random.PRNGKey(0),
                              base.param_dtype)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                base.vocab_size)
    a = T.forward(base, params, tokens)
    b = T.forward(grouped, params, tokens)
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=1e-4,
                               atol=1e-4)


def test_grouped_capacity_drops_are_group_local():
    """With a tight capacity, drops occur but outputs stay finite and the
    kept tokens match the scatter path where both keep them."""
    tight = _cfg(moe_groups=2, capacity_factor=1.0)
    params = tree_materialize(T.model_defs(tight), jax.random.PRNGKey(0),
                              tight.param_dtype)
    p = jax.tree_util.tree_map(lambda a: a[0], params["blocks"])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, tight.d_model))
    y = moe(tight, p, x)
    assert bool(jnp.isfinite(y).all())

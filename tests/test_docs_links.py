"""The docs link-check (tools/check_links.py) as a tier-1 test.

CI runs the same checker in the lint job; running it here too means a dead
relative link fails `pytest -x -q` locally before a PR ever reaches CI.
"""
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import check_links  # noqa: E402


def test_tracked_markdown_has_no_dead_relative_links():
    files = check_links.default_files()
    assert files, "checker found no markdown files"
    names = {f.name for f in files}
    # the three docs the README links must be in the default sweep
    assert {"README.md", "kernels.md", "algorithm.md",
            "benchmarks.md"} <= names
    failures = [msg for f in files for msg in check_links.dead_links(f)]
    assert not failures, "\n".join(failures)


def test_checker_detects_a_dead_link(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("see [missing](./nope.md) and [ok](#anchor) and "
                   "[site](../../actions/x) and [web](https://x.y)")
    # tmp_path is outside the repo root, so everything resolves as
    # site-relative; exercise the core logic on an in-repo temp file instead
    probe = ROOT / "docs" / "_linkcheck_probe.md"
    probe.write_text(bad.read_text())
    try:
        dead = check_links.dead_links(probe)
    finally:
        probe.unlink()
    assert len(dead) == 1 and "nope.md" in dead[0]

"""Compiled-runner cache + ``solve_many`` sweep-engine semantics.

Three claims (ISSUE 5 / docs/solvers.md):

1. Keying — distinct problems (different N/d/dtype/operator family) never
   collide; a problem rebuilt around the same data/graph (fresh equal W,
   new lam) shares one runner.
2. No retrace on hyperparameter sweeps — a second ``solve()`` on the same
   (problem shape, method, comm) with NEW hp values must not re-trace:
   asserted via the cache's trace counter, which is incremented from
   *inside* the traced function (counts XLA traces, not calls).
3. Correctness — warm-cache results are bit-equal to a cold call, and the
   vmapped ``solve_many`` grid — dense AND sparse — is bit-identical to
   sequential ``solve()`` calls (with the documented sequential fallback
   for ``engine="reference"`` and for grids that vary a static
   hyperparameter).
"""
import numpy as np
import pytest

from repro.core import mixing, runner_cache
from repro.core.solvers import (
    clear_runner_caches,
    make_problem,
    runner_cache_stats,
    solve,
    solve_many,
)
from repro.data.synthetic import make_classification, make_regression

STEPS = 24
REC = 8


def _problem(task="ridge", n_nodes=5, q=6, d=16, k=4, lam=1e-2, seed=0,
             dtype=np.float64):
    if task == "ridge":
        data = make_regression(n_nodes, q, d, k=k, seed=seed, dtype=dtype)
    else:
        data = make_classification(n_nodes, q, d, k=k, seed=seed)
    graph = mixing.erdos_renyi_graph(n_nodes, 0.5, seed=1)
    return make_problem(task, data, graph, lam=lam)


@pytest.fixture(autouse=True)
def _fresh_caches():
    clear_runner_caches()
    yield
    clear_runner_caches()


# ---------------------------------------------------------------------------
# no-retrace: hp values are traced arguments, not cache-key material
# ---------------------------------------------------------------------------


def test_second_solve_with_new_hp_does_not_retrace():
    problem = _problem()
    solve(problem, "dsba", steps=STEPS, record_every=REC, alpha=0.3)
    s0 = runner_cache_stats()["dense"]
    assert s0["misses"] == 1 and s0["traces"] >= 1
    solve(problem, "dsba", steps=STEPS, record_every=REC, alpha=0.9)
    s1 = runner_cache_stats()["dense"]
    assert s1["traces"] == s0["traces"], "new alpha must not recompile"
    assert s1["hits"] == s0["hits"] + 1
    assert s1["misses"] == s0["misses"]


def test_new_lam_on_same_data_does_not_retrace():
    """bench_table1's sweep shape: fresh Problem per lam, same data/graph."""
    data = make_regression(5, 6, 16, k=4, seed=0)
    graph = mixing.ring_graph(5)
    for lam in (1e-1, 1e-2, 1e-3):
        problem = make_problem("ridge", data, graph, lam=lam)
        solve(problem, "dsba", steps=STEPS, record_every=REC, alpha=0.5)
    s = runner_cache_stats()["dense"]
    assert s["misses"] == 1 and s["hits"] == 2


def test_sparse_second_call_with_new_hp_does_not_retrace():
    problem = _problem()
    solve(problem, "dsba", comm="sparse", steps=STEPS, record_every=REC,
          alpha=0.3)
    s0 = runner_cache_stats()["sparse"]
    assert s0["misses"] == 1 and s0["traces"] == 1
    solve(problem, "dsba", comm="sparse", steps=STEPS, record_every=REC,
          alpha=0.7)
    s1 = runner_cache_stats()["sparse"]
    assert s1["traces"] == s0["traces"], "new alpha must not recompile"
    assert s1["hits"] == s0["hits"] + 1


def test_static_hp_change_recompiles_but_value_sweep_does_not():
    problem = _problem()
    solve(problem, "ssda", steps=4, record_every=4, eta=0.05)
    s0 = runner_cache_stats()["dense"]
    solve(problem, "ssda", steps=4, record_every=4, eta=0.01, momentum=0.9)
    s1 = runner_cache_stats()["dense"]
    assert s1["traces"] == s0["traces"]  # eta/momentum are traced
    solve(problem, "ssda", steps=4, record_every=4, inner_newton=4)
    s2 = runner_cache_stats()["dense"]
    assert s2["misses"] == s1["misses"] + 1  # structural: new runner


# ---------------------------------------------------------------------------
# keying: distinct problems never collide
# ---------------------------------------------------------------------------


def test_distinct_problems_do_not_collide():
    problems = [
        _problem(),                      # base
        _problem(n_nodes=6),             # different N (and graph)
        _problem(d=24),                  # different d
        _problem(dtype=np.float32),      # different dtype
        _problem(task="logistic"),       # different operator family
    ]
    results = [
        solve(p, "dsba", steps=STEPS, record_every=REC, alpha=0.3)
        for p in problems
    ]
    assert runner_cache_stats()["dense"]["misses"] == len(problems)
    # every cached runner keeps answering for ITS problem
    for p, r in zip(problems, results):
        again = solve(p, "dsba", steps=STEPS, record_every=REC, alpha=0.3)
        assert np.array_equal(r.z, again.z)
    s = runner_cache_stats()["dense"]
    assert s["misses"] == len(problems) and s["hits"] == len(problems)


def test_same_shape_different_data_objects_do_not_collide():
    """Identity keying: equal shapes but different samples must miss."""
    graph = mixing.ring_graph(5)
    pa = make_problem(
        "ridge", make_regression(5, 6, 16, k=4, seed=0), graph, lam=1e-2
    )
    pb = make_problem(
        "ridge", make_regression(5, 6, 16, k=4, seed=7), graph, lam=1e-2
    )
    ra = solve(pa, "dsba", steps=STEPS, record_every=REC, alpha=0.3)
    rb = solve(pb, "dsba", steps=STEPS, record_every=REC, alpha=0.3)
    assert runner_cache_stats()["dense"]["misses"] == 2
    assert not np.array_equal(ra.z, rb.z)


# ---------------------------------------------------------------------------
# correctness: cached == cold, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method,hp", [
    ("dsba", {"alpha": 0.4}),
    ("dsa", {"alpha": 0.2}),
    ("extra", {"alpha": 0.2}),
    ("dlm", {"c": 0.3, "beta": 1.0}),
    ("ssda", {"eta": 0.05, "momentum": 0.5}),
])
def test_cached_results_bit_equal_to_cold(method, hp):
    problem = _problem()
    problem.solve_star()
    kw = dict(steps=STEPS, record_every=REC, keep_snapshots=True)
    cold = solve(problem, method, **kw, **hp)
    # pollute the runner with other hp values, then replay the originals
    other = {k: 0.5 * v for k, v in hp.items()}
    solve(problem, method, **kw, **other)
    warm = solve(problem, method, **kw, **hp)
    assert runner_cache_stats()["dense"]["hits"] >= 2
    assert np.array_equal(cold.z, warm.z)
    assert np.array_equal(cold.zs, warm.zs)
    assert np.array_equal(cold.dist2, warm.dist2)
    assert np.array_equal(cold.consensus, warm.consensus)


def test_sparse_cached_bit_equal_to_cold():
    problem = _problem()
    kw = dict(comm="sparse", steps=STEPS, record_every=REC)
    cold = solve(problem, "dsba", **kw, alpha=0.3)
    solve(problem, "dsba", **kw, alpha=0.8)
    warm = solve(problem, "dsba", **kw, alpha=0.3)
    assert np.array_equal(cold.z, warm.z)
    assert np.array_equal(
        cold.extras["z_trace"], warm.extras["z_trace"]
    )
    assert np.array_equal(cold.doubles_received, warm.doubles_received)


# ---------------------------------------------------------------------------
# solve_many: vmapped grid == sequential solves; documented fallbacks
# ---------------------------------------------------------------------------


def test_solve_many_grid_matches_sequential_bit_equal():
    problem = _problem()
    problem.solve_star()
    grid = [{"alpha": 0.3}, {"alpha": 0.5}, {"alpha": 0.8}]
    many = solve_many(problem, "dsba", steps=STEPS, record_every=REC,
                      grid=grid, keep_snapshots=True)
    assert many.extras["batched"] is True
    assert many.dist2.shape == (3, len(many.iters))
    for b, hp in enumerate(grid):
        seq = solve(problem, "dsba", steps=STEPS, record_every=REC,
                    keep_snapshots=True, **hp)
        assert np.array_equal(many.z[b], seq.z)
        assert np.array_equal(many.zs[b], seq.zs)
        assert np.array_equal(many.dist2[b], seq.dist2)
        assert np.array_equal(many.consensus[b], seq.consensus)
        assert np.array_equal(many.doubles_received[b], seq.doubles_received)


def test_solve_many_seed_axis_matches_sequential():
    problem = _problem()
    seeds = [3, 4, 5]
    many = solve_many(problem, "dsba", steps=STEPS, record_every=REC,
                      seeds=seeds, alpha=0.4)
    for b, s in enumerate(seeds):
        seq = solve(problem, "dsba", steps=STEPS, record_every=REC,
                    seed=s, alpha=0.4)
        assert np.array_equal(many.z[b], seq.z)


def test_solve_many_sparse_batched_matches_sequential_bit_equal():
    """The vmapped relay sweep is bit-identical to sequential solve()s,
    including the closed-form message accounting (hoisted out of the scan,
    so batching cannot perturb it)."""
    problem = _problem()
    grid = [{"alpha": 0.3}, {"alpha": 0.6}]
    seeds = [3, 4]
    many = solve_many(problem, "dsba", comm="sparse", steps=STEPS,
                      record_every=REC, grid=grid, seeds=seeds)
    assert many.extras["batched"] is True
    assert many.doubles_received.shape[0] == 2
    for b, hp in enumerate(grid):
        seq = solve(problem, "dsba", comm="sparse", steps=STEPS,
                    record_every=REC, seed=seeds[b], **hp)
        assert np.array_equal(many.z[b], seq.z)
        assert np.array_equal(many.doubles_received[b], seq.doubles_received)
        assert np.array_equal(many.ints_received[b], seq.ints_received)
        assert np.array_equal(
            many.extras["per_run_extras"][b]["z_trace"],
            seq.extras["z_trace"],
        )


def test_solve_many_sparse_reference_engine_falls_back_sequential():
    """The per-observer oracle loop is not vmappable: engine="reference"
    declines the batch and runs the documented sequential path."""
    problem = _problem()
    many = solve_many(problem, "dsba", comm="sparse", steps=STEPS,
                      record_every=REC, grid=[{"alpha": 0.3}, {"alpha": 0.6}],
                      comm_options={"engine": "reference"})
    assert many.extras["batched"] is False
    assert many.z.shape[0] == 2


def test_solve_many_static_hp_grid_falls_back_sequential():
    problem = _problem()
    many = solve_many(problem, "ssda", steps=4, record_every=4,
                      grid=[{"inner_newton": 4}, {"inner_newton": 8}])
    assert many.extras["batched"] is False
    assert many.z.shape[0] == 2


def test_solve_many_validation():
    problem = _problem()
    with pytest.raises(ValueError, match="grid, seeds"):
        solve_many(problem, "dsba", steps=4)
    with pytest.raises(ValueError, match="pair up"):
        solve_many(problem, "dsba", steps=4, grid=[{}], seeds=[0, 1])
    with pytest.raises(ValueError, match="at least one"):
        solve_many(problem, "dsba", steps=4, grid=[])
    with pytest.raises(TypeError, match="unknown hyperparameters"):
        solve_many(problem, "dsba", steps=4, grid=[{"learning_rate": 0.1}])
    with pytest.raises(ValueError, match="indices"):
        solve_many(problem, "dsba", steps=40, seeds=[0, 1],
                   indices=np.zeros((2, 10, 5), np.int32))


def test_factory_hp_guard_is_a_mapping_of_statics_only():
    """Reading a runtime-traced name at factory time fails loudly; the
    Mapping protocol (in / get / iteration) stays honest for probing."""
    from repro.core.solvers import TracedHPError, _FactoryHP

    fhp = _FactoryHP({"alpha": 0.3, "inner": 4}, static=("inner",))
    assert fhp["inner"] == 4
    with pytest.raises(TracedHPError, match="runtime-traced"):
        fhp["alpha"]
    with pytest.raises(KeyError):
        fhp["nope"]
    assert "alpha" not in fhp and "inner" in fhp
    assert fhp.get("alpha", None) is None  # probing never explodes
    assert dict(fhp) == {"inner": 4}


def test_cache_is_lru_bounded():
    cap = runner_cache.DENSE.capacity
    runner_cache.DENSE.capacity = 2
    try:
        problems = [_problem(seed=s) for s in range(3)]
        for p in problems:
            solve(p, "dsba", steps=4, record_every=4, alpha=0.3)
        s = runner_cache_stats()["dense"]
        assert s["size"] == 2 and s["evictions"] == 1
        # evicted (oldest) problem rebuilds; the newest still hits
        solve(problems[-1], "dsba", steps=4, record_every=4, alpha=0.5)
        assert runner_cache_stats()["dense"]["hits"] >= 1
        solve(problems[0], "dsba", steps=4, record_every=4, alpha=0.3)
        assert runner_cache_stats()["dense"]["misses"] == 4
    finally:
        runner_cache.DENSE.capacity = cap

"""Unified fault injection & recovery (ISSUE 10 acceptance).

Four claims:
  1. fault plans are validated up front — illegal combinations raise a
     typed error BEFORE any factory or compile runs, and p=0 plans are
     BIT-equal to plan-free runs (by routing: an all-delivered mask
     collapses to the plain compiled runner);
  2. link faults and stragglers degrade gracefully on the dense backend
     (finite, biased-not-divergent, delivered-only accounting) and the
     staleness bound genuinely bounds every node's delivery gap;
  3. churn recovery: a kill under ``comm="sparse"`` re-derives the relay
     per membership segment, parity-matches dense churn, and reaches the
     survivor root; mudag's tracker reanchor reconverges geometrically
     where the no-reanchor run plateaus (regression-pinned);
  4. ``solve(..., checkpoint=...)`` + ``solve(..., resume=...)`` is
     bit-equal to an uninterrupted run for dsba/dsa on dense and sparse.

The sharded legs of the same claims run under the forced-8-device tier
(``tests/multidevice/test_faults_inner.py``). Exhaustive drop-rate x
method sweeps are ``slow``-marked.
"""
import functools

import numpy as np
import pytest

from repro.ckpt import CheckpointSpec, committed_steps
from repro.core import mixing
from repro.core.solvers import (
    ChurnEvent,
    ChurnPlan,
    FaultPlan,
    LinkFault,
    StragglerSpec,
    get_solver,
    make_problem,
    solve,
)
from repro.data.synthetic import make_regression
from repro.ft.faults import straggler_delivered_mask

N, Q, D, K = 8, 12, 6, 3


@functools.lru_cache(maxsize=None)
def _problem(n=N):
    data = make_regression(n, Q, D, k=K, seed=0)
    p = make_problem("ridge", data, mixing.ring_graph(n), lam=1e-2)
    p.solve_star()
    return p


def _solve(p, method="dsba", comm="dense", plan=None, **kw):
    kw.setdefault("steps", 120)
    kw.setdefault("record_every", 30)
    kw.setdefault("seed", 1)
    opts = {"fault_plan": plan} if plan is not None else None
    return solve(p, method, comm=comm, comm_options=opts, **kw)


# ---------------------------------------------------------------------------
# 1. up-front validation + p=0 routing bit-equality
# ---------------------------------------------------------------------------


def test_illegal_fault_combinations_raise_up_front():
    import dataclasses

    p = _problem()
    kill = ChurnPlan((ChurnEvent(at=10, kind="kill", nodes=(7,)),))
    # schedule x fault_plan
    ps = dataclasses.replace(p, schedule=((0, p.graph),))
    with pytest.raises(ValueError, match="schedule and a fault_plan"):
        _solve(ps, plan=FaultPlan(link=LinkFault(p=0.1)))
    # churn x node/edge-targeted families (ids relabel across segments)
    with pytest.raises(ValueError, match="scheduled link faults"):
        _solve(p, plan=FaultPlan(
            churn=kill, link=LinkFault(edges=((0, 1),), at=(5,))))
    with pytest.raises(ValueError, match="straggler node subset"):
        _solve(p, plan=FaultPlan(
            churn=kill, straggler=StragglerSpec(p=0.5, nodes=(0,))))
    with pytest.raises(ValueError, match="keep_snapshots"):
        _solve(p, plan=FaultPlan(churn=kill), keep_snapshots=True)
    # checkpoint/resume exclusions
    ck = CheckpointSpec("/tmp/nonexistent-ck", every=30)
    with pytest.raises(ValueError, match="not checkpointable"):
        solve(p, "dsba", comm="sharded", steps=60, checkpoint=ck)
    with pytest.raises(ValueError, match="fault_plan"):
        _solve(p, plan=FaultPlan(link=LinkFault(p=0.1)), checkpoint=ck)
    with pytest.raises(ValueError, match="multiple of"):
        solve(p, "dsba", steps=60, record_every=25,
              checkpoint=CheckpointSpec("/tmp/nonexistent-ck", every=30))
    # the plan itself validates its fields
    with pytest.raises(ValueError, match="at least one fault family"):
        FaultPlan()
    with pytest.raises(ValueError, match="not in \\[0, 1\\]"):
        LinkFault(p=1.5)
    with pytest.raises(ValueError, match="max_staleness"):
        StragglerSpec(p=0.5, max_staleness=0)
    with pytest.raises(ValueError, match="edges without"):
        LinkFault(edges=((0, 1),))


@pytest.mark.parametrize("comm", ["dense", "sparse"])
def test_p0_plan_bit_equal_to_plan_free(comm):
    """An all-delivered plan routes through the SAME compiled runner as a
    plan-free run — bit-equality by routing, not by masked arithmetic."""
    p = _problem()
    base = _solve(p, comm=comm)
    plan = FaultPlan(link=LinkFault(p=0.0),
                     straggler=(None if comm == "sparse"
                                else StragglerSpec(p=0.0)))
    res = _solve(p, comm=comm, plan=plan)
    assert np.array_equal(np.asarray(base.z), np.asarray(res.z))
    assert np.array_equal(np.asarray(base.dist2), np.asarray(res.dist2))
    np.testing.assert_array_equal(base.doubles_received, res.doubles_received)
    # the p=0 record still reports the accounting, with zero drop rate
    f = res.extras["faults"]
    assert f["drop_rate"] == 0.0
    inj = f.get("injected_messages", f.get("injected_broadcasts"))
    dlv = f.get("delivered_messages", f.get("delivered_broadcasts"))
    assert inj == dlv > 0


# ---------------------------------------------------------------------------
# 2. graceful degradation + delivered-only accounting
# ---------------------------------------------------------------------------


def test_dense_link_faults_degrade_gracefully():
    """p=0.2 drops: the run stays finite and converges to a biased
    neighborhood (row-renormalization keeps the masked W stochastic),
    and the doubles accounting counts only delivered messages."""
    p = _problem()
    base = _solve(p, steps=400, record_every=100)
    res = _solve(p, steps=400, record_every=100,
                 plan=FaultPlan(link=LinkFault(p=0.2, seed=7)))
    assert np.isfinite(res.z).all() and np.isfinite(res.dist2).all()
    assert base.dist2[-1] < 1e-12          # fault-free converges hard
    assert 1e-12 < res.dist2[-1] < 1.0     # faulted: biased, not divergent
    f = res.extras["faults"]
    assert 0 < f["delivered_messages"] < f["injected_messages"]
    assert 0.1 < f["drop_rate"] < 0.3
    assert res.doubles_received[-1].sum() < base.doubles_received[-1].sum()


def test_dense_stragglers_and_composition():
    """Stragglers alone and composed with link faults: finite runs,
    composed delivery is the AND of the two masks (strictly fewer
    messages than either family alone)."""
    p = _problem()
    link = LinkFault(p=0.2, seed=3)
    strag = StragglerSpec(p=0.4, max_staleness=3, seed=5)
    r_s = _solve(p, steps=200, record_every=50, plan=FaultPlan(straggler=strag))
    r_l = _solve(p, steps=200, record_every=50, plan=FaultPlan(link=link))
    r_b = _solve(p, steps=200, record_every=50,
                 plan=FaultPlan(link=link, straggler=strag))
    for r in (r_s, r_l, r_b):
        assert np.isfinite(r.z).all() and np.isfinite(r.dist2).all()
    both = r_b.extras["faults"]["delivered_messages"]
    assert both < r_s.extras["faults"]["delivered_messages"]
    assert both < r_l.extras["faults"]["delivered_messages"]


def test_staleness_bound_is_enforced():
    """Even at p=0.95 no node goes more than max_staleness iterations
    without a delivery (the forced catch-up), and the first iteration
    always delivers (no uninitialized buffer reads)."""
    for bound in (1, 2, 4):
        m = straggler_delivered_mask(
            StragglerSpec(p=0.95, max_staleness=bound, seed=9), 6, 300
        )
        assert m[0].all()
        gaps = np.zeros(6, dtype=int)
        for t in range(1, 300):
            gaps = np.where(m[t], 0, gaps + 1)
            assert (gaps <= bound).all()
        assert not m.all()  # the fault actually fired


# ---------------------------------------------------------------------------
# 3. churn recovery: sparse kill parity + tracker reanchor
# ---------------------------------------------------------------------------


def test_sparse_kill_parity_with_dense_and_survivor_root():
    """ISSUE 10 acceptance: a kill under comm="sparse" re-derives the
    relay per membership segment, chains via state0 with the step-0
    reanchor, parity-matches the dense churn run, and reaches the
    survivor system's root to <= 1e-9."""
    p = _problem()
    plan = ChurnPlan((ChurnEvent(at=150, kind="kill", nodes=(5,)),))
    kw = dict(steps=600, record_every=50, seed=1,
              comm_options={"fault_plan": plan})
    rd = solve(p, "dsba", comm="dense", **kw)
    rs = solve(p, "dsba", comm="sparse", **kw)
    assert rs.z.shape == (N - 1, rd.z.shape[1])
    np.testing.assert_allclose(np.asarray(rs.z), np.asarray(rd.z),
                               atol=1e-11, rtol=0)
    np.testing.assert_allclose(np.asarray(rs.dist2), np.asarray(rd.dist2),
                               atol=1e-11, rtol=1e-6)
    assert rs.dist2[-1] <= 1e-9  # the survivor root (per-phase z_star)
    assert rs.extras["churn_rows"] == N
    # the relay's modeled traffic is still the closed-form count
    assert np.isfinite(rs.doubles_received).all()
    assert (np.diff(rs.doubles_received.sum(axis=1)) > 0).all()


def test_sparse_join_parity_with_dense():
    p = _problem()
    plan = ChurnPlan((ChurnEvent(
        at=100, kind="join", n_new=2, seed_from=0,
        graph=mixing.ring_graph(N + 2)),))
    kw = dict(steps=300, record_every=50, seed=1,
              comm_options={"fault_plan": plan})
    rd = solve(p, "dsba", comm="dense", **kw)
    rs = solve(p, "dsba", comm="sparse", **kw)
    assert rs.z.shape == (N + 2, rd.z.shape[1])
    np.testing.assert_allclose(np.asarray(rs.z), np.asarray(rd.z),
                               atol=1e-11, rtol=0)


def test_mudag_kill_reanchor_reconverges_geometrically():
    """The tracking family's churn gap (ROADMAP item 2): with the tracker
    reanchor (s, g_prev zeroed, t rewound so the step re-seeds the
    tracker from the survivors' gradients) the kill run reconverges to
    the survivor root; without it, the telescoped tracker still encodes
    the departed node's gradients and the run PLATEAUS (regression-pinned
    by temporarily nulling the spec's reanchor hook)."""
    import jax.numpy as jnp  # noqa: F401  (reanchor lambdas use jnp)

    p = _problem()
    plan = ChurnPlan((ChurnEvent(at=150, kind="kill", nodes=(5,)),))
    kw = dict(steps=600, record_every=50, seed=1, eta=0.5, momentum=0.5,
              comm_options={"fault_plan": plan})
    res = solve(p, "mudag", comm="dense", **kw)
    assert res.dist2[-1] < 1e-12  # geometric reconvergence

    spec = get_solver("mudag")
    orig = spec.reanchor
    object.__setattr__(spec, "reanchor", None)
    try:
        res_no = solve(p, "mudag", comm="dense", **kw)
    finally:
        object.__setattr__(spec, "reanchor", orig)
    # plateau: orders of magnitude off the root, and flat at the tail
    assert res_no.dist2[-1] > 1e-6
    assert abs(res_no.dist2[-1] - res_no.dist2[-2]) < 0.1 * res_no.dist2[-1]


@pytest.mark.parametrize("method,hp", [
    ("sliding", dict(alpha=0.1, comm_period=4)),
    ("dsgda", dict()),
])
def test_tracking_family_churn_stays_finite_and_improves(method, hp):
    """sliding/dsgda share the reanchor contract: the kill run keeps
    descending after the event instead of locking onto the dead
    system's root."""
    if method == "dsgda":
        data = make_regression(6, 10, 5, k=3, seed=2)
        p = make_problem("auc", data, mixing.ring_graph(6), lam=1e-2)
        p.solve_star()
        plan = ChurnPlan((ChurnEvent(at=200, kind="kill", nodes=(4,)),))
        res = solve(p, method, steps=800, record_every=100, seed=3,
                    comm_options={"fault_plan": plan}, **hp)
    else:
        p = _problem()
        plan = ChurnPlan((ChurnEvent(at=150, kind="kill", nodes=(5,)),))
        res = solve(p, method, steps=600, record_every=50, seed=1,
                    comm_options={"fault_plan": plan}, **hp)
    assert np.isfinite(res.dist2).all()
    assert res.dist2[-1] < 1e-3 and res.dist2[-1] < res.dist2[-3]


def test_churn_composes_with_link_faults():
    """Churn + probabilistic link faults in ONE plan: each membership
    segment re-derives its masks deterministically; accounting reports
    both the relabeled rows and the delivered totals."""
    p = _problem()
    plan = FaultPlan(
        churn=ChurnPlan((ChurnEvent(at=60, kind="kill", nodes=(7,)),)),
        link=LinkFault(p=0.15, seed=11),
    )
    res = _solve(p, steps=160, record_every=40, plan=plan)
    assert res.z.shape[0] == N - 1
    assert np.isfinite(res.z).all()
    assert res.extras["churn_rows"] == N
    f = res.extras["faults"]
    assert 0 < f["delivered_messages"] < f["injected_messages"]


# ---------------------------------------------------------------------------
# 4. checkpoint / resume bit-equality
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["dsba", "dsa"])
@pytest.mark.parametrize("comm", ["dense", "sparse"])
def test_checkpoint_resume_bit_equal(tmp_path, method, comm):
    """Interrupt at step 40 of 60, resume from the newest committed
    checkpoint: iterate trace, recorder arrays, AND accounting are
    bit-equal to the uninterrupted run (the sample streams are
    prefix-stable in steps, so the restored position lines up exactly)."""
    p = _problem()
    kw = dict(record_every=10, seed=3)
    full = solve(p, method, comm=comm, steps=60, **kw)

    ck = tmp_path / f"{method}_{comm}"
    solve(p, method, comm=comm, steps=40,
          checkpoint=CheckpointSpec(ck, every=20), **kw)
    assert committed_steps(ck) == [20, 40]
    res = solve(p, method, comm=comm, steps=60, resume=str(ck), **kw)

    assert np.array_equal(np.asarray(full.z), np.asarray(res.z))
    assert np.array_equal(np.asarray(full.dist2), np.asarray(res.dist2))
    np.testing.assert_array_equal(full.iters, res.iters)
    np.testing.assert_array_equal(full.doubles_received, res.doubles_received)
    np.testing.assert_array_equal(full.ints_received, res.ints_received)


def test_resume_validates_method_and_comm(tmp_path):
    p = _problem()
    ck = tmp_path / "ck"
    solve(p, "dsba", steps=40, record_every=10, seed=3,
          checkpoint=CheckpointSpec(ck, every=20))
    with pytest.raises(ValueError, match="method"):
        solve(p, "dsa", steps=60, record_every=10, seed=3, resume=str(ck))
    with pytest.raises(ValueError, match="comm"):
        solve(p, "dsba", comm="sparse", steps=60, record_every=10, seed=3,
              resume=str(ck))
    with pytest.raises(ValueError, match="beyond steps"):
        solve(p, "dsba", steps=30, record_every=10, seed=3, resume=str(ck))
    with pytest.raises(ValueError, match="no committed checkpoint"):
        solve(p, "dsba", steps=60, record_every=10, seed=3,
              resume=str(tmp_path / "empty"))


def test_resume_at_completed_run_returns_final_state(tmp_path):
    """Resuming a run whose newest checkpoint IS the final step performs
    zero further iterations and still returns the full result."""
    p = _problem()
    ck = tmp_path / "done"
    kw = dict(record_every=10, seed=3)
    full = solve(p, "dsba", steps=40, **kw)
    solve(p, "dsba", steps=40, checkpoint=CheckpointSpec(ck, every=20), **kw)
    res = solve(p, "dsba", steps=40, resume=str(ck), **kw)
    assert np.array_equal(np.asarray(full.z), np.asarray(res.z))
    assert np.array_equal(np.asarray(full.dist2), np.asarray(res.dist2))


# ---------------------------------------------------------------------------
# slow: exhaustive drop-rate x method sweeps
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("method,hp", [
    ("dsba", dict()), ("dsa", dict()), ("mudag", dict(eta=0.5, momentum=0.5)),
])
@pytest.mark.parametrize("pdrop", [0.1, 0.2, 0.4])
def test_degradation_sweep_dense(method, hp, pdrop):
    """Dense degradation is monotone-ish in p and never divergent: the
    bias neighborhood grows with the drop rate but every run stays
    finite with delivered-only accounting below the no-fault count."""
    p = _problem()
    base = solve(p, method, steps=400, record_every=100, seed=1, **hp)
    res = solve(p, method, steps=400, record_every=100, seed=1,
                comm_options={"fault_plan": FaultPlan(
                    link=LinkFault(p=pdrop, seed=7))}, **hp)
    assert np.isfinite(res.dist2).all()
    assert res.dist2[-1] < 10.0
    assert res.doubles_received[-1].sum() < base.doubles_received[-1].sum()


@pytest.mark.slow
@pytest.mark.parametrize("method", ["dsba", "dsa"])
def test_sparse_short_horizon_link_faults(method):
    """Short-horizon sparse link faults: the suppressed-broadcast model
    runs finite and its modeled traffic stays below the fault-free relay
    (docs/solvers.md documents the long-horizon drift caveat)."""
    p = _problem()
    base = solve(p, method, comm="sparse", steps=80, record_every=20, seed=1)
    res = solve(p, method, comm="sparse", steps=80, record_every=20, seed=1,
                comm_options={"fault_plan": FaultPlan(
                    link=LinkFault(p=0.1, seed=7))})
    assert np.isfinite(res.z).all()
    assert res.doubles_received[-1].sum() <= base.doubles_received[-1].sum()
    f = res.extras["faults"]
    assert 0 < f["delivered_broadcasts"] < f["injected_broadcasts"]

"""Mixing-matrix conditions (i)-(iv) of Section 4 + graph utilities."""
import numpy as np
import pytest

from repro.core import mixing


TOPOLOGIES = {
    "ring8": mixing.ring_graph(8),
    "ring2": mixing.ring_graph(2),
    "complete5": mixing.complete_graph(5),
    "torus3x3": mixing.torus_graph(3, 3),
    "er10": mixing.erdos_renyi_graph(10, 0.4, seed=0),
    "exp16": mixing.exponential_graph(16),
}


@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_laplacian_mixing_satisfies_paper_conditions(name):
    g = TOPOLOGIES[name]
    w = mixing.laplacian_mixing(g)
    mixing.validate_mixing(w, g)


@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_metropolis_mixing_satisfies_paper_conditions(name):
    g = TOPOLOGIES[name]
    w = mixing.metropolis_mixing(g)
    # Metropolis is doubly stochastic and symmetric; eigenvalues can dip
    # below 0 on some graphs, so validate sparsity/symmetry/null-space and
    # row sums only.
    assert np.allclose(w.sum(1), 1.0)
    assert np.allclose(w, w.T)
    adj = g.adjacency + np.eye(g.n)
    assert not np.any((np.abs(w) > 1e-12) & (adj == 0))


def test_graphs_connected_and_diameter():
    for name, g in TOPOLOGIES.items():
        assert g.is_connected(), name
    assert mixing.ring_graph(8).diameter == 4
    assert mixing.complete_graph(5).diameter == 1
    # exponential graph has log-diameter
    assert mixing.exponential_graph(16).diameter <= 4


def test_graph_condition_number_complete_graph():
    g = mixing.complete_graph(4)
    w = mixing.laplacian_mixing(g)
    gamma = mixing.graph_gamma(w)
    # complete graph: L = nI - J, lmax = n, W = I - L/n = J/n;
    # (I - W)/2 has nonzero eigs (1 - 0)/2 = 1/2
    assert np.isclose(gamma, 0.5)
    assert np.isclose(mixing.graph_condition_number(w), 2.0)


def test_distances_match_bfs():
    g = mixing.ring_graph(6)
    d = g.distances_from(0)
    assert list(d) == [0, 1, 2, 3, 2, 1]


def test_pod_mixing_single_pod():
    g, w = mixing.make_pod_mixing(1)
    assert w.shape == (1, 1) and w[0, 0] == 1.0


def test_w_tilde():
    g = mixing.ring_graph(4)
    w = mixing.laplacian_mixing(g)
    wt = mixing.w_tilde(w)
    assert np.allclose(wt, (w + np.eye(4)) / 2)
    # powers of W respect graph distance: [W^k]_{0i} == 0 iff dist > k (eq. 33)
    dist = g.distances_from(0)
    for k in range(1, 4):
        wk = np.linalg.matrix_power(w, k)
        for i in range(4):
            if dist[i] > k:
                assert abs(wk[0, i]) < 1e-12

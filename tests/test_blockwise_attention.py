"""Blockwise (online-softmax) attention == naive attention, all variants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.models.params import tree_materialize


def _pair(arch, **kw):
    base = dataclasses.replace(get_reduced(arch), compute_dtype=jnp.float32,
                               **kw)
    blk = dataclasses.replace(base, blockwise_attention=True,
                              attention_block_k=8)
    params = tree_materialize(T.model_defs(base), jax.random.PRNGKey(0),
                              base.param_dtype)
    return base, blk, params


@pytest.mark.parametrize("arch", ["minitron_8b", "gemma2_2b", "qwen2_72b",
                                  "whisper_small", "zamba2_1p2b"])
def test_blockwise_forward_matches_naive(arch):
    base, blk, params = _pair(arch)
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (2, 20), 0, base.vocab_size)
    kwargs = {}
    if base.family == "encdec":
        kwargs["enc_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (2, base.encoder_len, base.d_model)
        )
    naive = T.forward(base, params, tokens, **kwargs)
    fast = T.forward(blk, params, tokens, **kwargs)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(naive),
                               rtol=1e-4, atol=1e-4)


def test_blockwise_gradients_match():
    base, blk, params = _pair("minitron_8b")
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                base.vocab_size)
    targets = jnp.roll(tokens, -1, 1)

    def loss(cfg, p):
        logits = T.forward(cfg, p, tokens)
        lp = jax.nn.log_softmax(logits, -1)
        return -jnp.take_along_axis(lp, targets[..., None], -1).mean()

    g_naive = jax.grad(lambda p: loss(base, p))(params)
    g_fast = jax.grad(lambda p: loss(blk, p))(params)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5
        ),
        g_naive, g_fast,
    )


def test_shard_q_heads_matches_naive():
    """K/V group expansion changes sharding, not math."""
    base, _, params = _pair("minitron_8b")
    qh = dataclasses.replace(base, shard_q_heads=True)
    qh_blk = dataclasses.replace(qh, blockwise_attention=True,
                                 attention_block_k=8)
    tokens = jax.random.randint(jax.random.PRNGKey(5), (2, 20), 0,
                                base.vocab_size)
    naive = T.forward(base, params, tokens)
    a = T.forward(qh, params, tokens)
    b = T.forward(qh_blk, params, tokens)
    np.testing.assert_allclose(np.asarray(a), np.asarray(naive),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(b), np.asarray(naive),
                               rtol=1e-4, atol=1e-4)


def test_blockwise_decode_matches_naive_decode():
    base, blk, params = _pair("gemma2_2b")
    tokens = jax.random.randint(jax.random.PRNGKey(3), (1, 10), 0,
                                base.vocab_size)
    outs = {}
    for name, cfg in (("naive", base), ("blockwise", blk)):
        cache = T.init_cache(cfg, 1, max_len=12)
        cache, lp = T.decode_step(cfg, params, tokens[:, :8], cache)
        cache, l8 = T.decode_step(cfg, params, tokens[:, 8:9], cache)
        outs[name] = (np.asarray(lp), np.asarray(l8))
    np.testing.assert_allclose(outs["blockwise"][0], outs["naive"][0],
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(outs["blockwise"][1], outs["naive"][1],
                               rtol=1e-4, atol=1e-4)

"""Mamba2/SSD correctness: chunked scan == naive sequential recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import _ssd_chunked


def ssd_sequential(xh, dt, a_log, Bc, Cc, h0=None):
    """Naive per-step recurrence oracle: h_t = a_t h_{t-1} + B_t (x) (dt_t x_t)."""
    B, S, nh, hd = xh.shape
    ds = Bc.shape[-1]
    h = np.zeros((B, nh, ds, hd)) if h0 is None else np.array(h0, np.float64)
    ys = []
    xh, dt, a_log, Bc, Cc = map(lambda t: np.asarray(t, np.float64),
                                (xh, dt, a_log, Bc, Cc))
    for t in range(S):
        a = np.exp(a_log[:, t])  # (B, nh)
        xdt = xh[:, t] * dt[:, t, :, None]  # (B, nh, hd)
        h = a[:, :, None, None] * h + np.einsum("bs,bhd->bhsd", Bc[:, t], xdt)
        ys.append(np.einsum("bs,bhsd->bhd", Cc[:, t], h))
    return np.stack(ys, 1), h


@pytest.mark.parametrize("S,chunk", [(16, 4), (16, 16), (12, 5), (7, 8), (32, 8)])
def test_chunked_equals_sequential(S, chunk):
    rng = np.random.default_rng(0)
    B, nh, hd, ds = 2, 3, 4, 5
    xh = jnp.asarray(rng.standard_normal((B, S, nh, hd)))
    dt = jnp.asarray(rng.uniform(0.1, 1.0, (B, S, nh)))
    a_log = jnp.asarray(-rng.uniform(0.01, 0.5, (B, S, nh)))
    Bc = jnp.asarray(rng.standard_normal((B, S, ds)))
    Cc = jnp.asarray(rng.standard_normal((B, S, ds)))

    y, h = _ssd_chunked(xh, dt, a_log, Bc, Cc, chunk)
    y_ref, h_ref = ssd_sequential(xh, dt, a_log, Bc, Cc)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-8, atol=1e-8)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=1e-8, atol=1e-8)


def test_chunked_with_initial_state():
    rng = np.random.default_rng(1)
    B, S, nh, hd, ds = 1, 8, 2, 4, 3
    xh = jnp.asarray(rng.standard_normal((B, S, nh, hd)))
    dt = jnp.asarray(rng.uniform(0.1, 1.0, (B, S, nh)))
    a_log = jnp.asarray(-rng.uniform(0.01, 0.5, (B, S, nh)))
    Bc = jnp.asarray(rng.standard_normal((B, S, ds)))
    Cc = jnp.asarray(rng.standard_normal((B, S, ds)))
    h0 = jnp.asarray(rng.standard_normal((B, nh, ds, hd)))

    y, h = _ssd_chunked(xh, dt, a_log, Bc, Cc, 4, h0=h0)
    y_ref, h_ref = ssd_sequential(xh, dt, a_log, Bc, Cc, h0=h0)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-8, atol=1e-8)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=1e-8, atol=1e-8)


def test_split_prefill_equals_full():
    """prefill(first half) state -> prefill(second half) == full scan."""
    rng = np.random.default_rng(2)
    B, S, nh, hd, ds = 1, 16, 2, 4, 3
    mk = lambda *s: jnp.asarray(rng.standard_normal(s))
    xh, Bc, Cc = mk(B, S, nh, hd), mk(B, S, ds), mk(B, S, ds)
    dt = jnp.asarray(rng.uniform(0.1, 1.0, (B, S, nh)))
    a_log = jnp.asarray(-rng.uniform(0.01, 0.5, (B, S, nh)))

    y_full, h_full = _ssd_chunked(xh, dt, a_log, Bc, Cc, 4)
    h = S // 2
    y1, h1 = _ssd_chunked(xh[:, :h], dt[:, :h], a_log[:, :h], Bc[:, :h],
                          Cc[:, :h], 4)
    y2, h2 = _ssd_chunked(xh[:, h:], dt[:, h:], a_log[:, h:], Bc[:, h:],
                          Cc[:, h:], 4, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-8, atol=1e-8)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-8, atol=1e-8)


@pytest.mark.parametrize("S", [16, 32, 64])
@pytest.mark.parametrize("kern", ["off", "interpret"])
def test_grad_finiteness_under_fast_decay(S, kern):
    """Masked-exp NaN-cotangent regression (ROADMAP carried thread): with
    fast decay (|a_log| ~ 8, realistic post-softplus dt * A near the A_init
    lower bound) the above-diagonal cum_i - cum_j reaches Q * 8, whose
    unmasked exp overflows f32 to inf — and inf * 0 upstream cotangent NaNs
    every gradient. The reference ('off') and Pallas-interpret paths must
    both mask BEFORE the exp and return finite grads."""
    rng = np.random.default_rng(S)
    B, nh, hd, ds = 1, 2, 4, 3
    f32 = jnp.float32
    xh = jnp.asarray(rng.standard_normal((B, S, nh, hd)), f32)
    dt = jnp.asarray(rng.uniform(0.5, 1.5, (B, S, nh)), f32)
    a_log = jnp.asarray(-rng.uniform(6.0, 8.0, (B, S, nh)), f32)
    Bc = jnp.asarray(rng.standard_normal((B, S, ds)), f32)
    Cc = jnp.asarray(rng.standard_normal((B, S, ds)), f32)

    def loss(x, d, a, b, c):
        y, h = _ssd_chunked(x, d, a, b, c, 16, kernel=kern)
        return jnp.sum(y) + jnp.sum(h)

    grads = jax.grad(loss, argnums=(0, 1, 2, 3, 4))(xh, dt, a_log, Bc, Cc)
    for g in grads:
        assert np.all(np.isfinite(np.asarray(g))), kern


def test_kernel_routing_matches_jnp_fwd_and_grad():
    """cfg.ssm_kernel routing: the registry's ssd_chunk custom_vjp path ==
    the inline einsum path, forward AND backward, through the full chunked
    scan (ragged S -> zero-pad path, h0, nh=3 odd head_block)."""
    rng = np.random.default_rng(3)
    B, S, nh, hd, ds = 2, 20, 3, 4, 5  # 20 % chunk(8) != 0 -> pad branch
    f32 = jnp.float32
    xh = jnp.asarray(rng.standard_normal((B, S, nh, hd)), f32)
    dt = jnp.asarray(rng.uniform(0.1, 1.0, (B, S, nh)), f32)
    a_log = jnp.asarray(-rng.uniform(0.01, 0.5, (B, S, nh)), f32)
    Bc = jnp.asarray(rng.standard_normal((B, S, ds)), f32)
    Cc = jnp.asarray(rng.standard_normal((B, S, ds)), f32)
    h0 = jnp.asarray(rng.standard_normal((B, nh, ds, hd)), f32)

    y0, hf0 = _ssd_chunked(xh, dt, a_log, Bc, Cc, 8, h0=h0)
    y1, hf1 = _ssd_chunked(xh, dt, a_log, Bc, Cc, 8, h0=h0,
                           kernel="interpret")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(hf1), np.asarray(hf0),
                               rtol=2e-5, atol=2e-5)

    def grads(kern):
        return jax.grad(
            lambda x, b: jnp.sum(jnp.sin(
                _ssd_chunked(x, dt, a_log, b, Cc, 8, kernel=kern)[0]
            )),
            argnums=(0, 1),
        )(xh, Bc)

    for g_k, g_j in zip(grads("interpret"), grads("jnp")):
        np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_j),
                                   rtol=2e-4, atol=2e-4)

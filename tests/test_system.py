"""End-to-end behaviour: the paper's full pipeline, assembled.

Decentralized ridge on a sparse dataset -> DSBA with sparse communication
protocol -> convergence to the centralized optimum, with communication cost
matching the closed-form O(N rho d) model — the paper's two claims, one test.
"""
import numpy as np

from repro.core import mixing, reference
from repro.core.dsba import DSBAConfig, draw_indices, run
from repro.core.operators import OperatorSpec
from repro.core.sparse_comm import (
    dense_doubles_per_iter, run_sparse, sparse_doubles_per_iter,
)
from repro.data.synthetic import make_regression


def test_end_to_end_paper_pipeline():
    n, q, d, k = 8, 20, 400, 10
    data = make_regression(n, q, d, k=k, seed=0)
    graph = mixing.erdos_renyi_graph(n, 0.4, seed=1)
    w = mixing.laplacian_mixing(graph)
    mixing.validate_mixing(w, graph)
    spec = OperatorSpec("ridge")
    lam = 1.0 / (10 * data.total)
    z_star = reference.solve_root(spec, data, lam)

    # claim 1: linear convergence to the centralized root
    cfg = DSBAConfig(spec, alpha=2.0, lam=lam)
    res = run(cfg, data, w, steps=6000, z_star=z_star, record_every=1000)
    assert res.dist2[-1] < 1e-8, res.dist2
    drops = np.diff(np.log10(np.maximum(res.dist2, 1e-300)))
    assert drops.mean() < -0.3  # geometric decay

    # claim 2: sparse communication reproduces the dense trajectory at
    # O(N rho d) cost
    steps = 40
    idx = draw_indices(steps, n, q, seed=2)
    dense = run(cfg, data, w, steps, record_every=steps, indices=idx)
    sparse = run_sparse(cfg, data, graph, w, steps, idx)
    np.testing.assert_allclose(
        sparse.z_trace[-1], np.asarray(dense.state.z), atol=1e-12
    )
    per_iter = np.diff(sparse.doubles_received, axis=0)[-8:]
    assert (per_iter == sparse_doubles_per_iter(n, k, 0)).all()
    assert per_iter.max() * 5 < dense_doubles_per_iter(graph, d).max()

"""End-to-end behaviour: the paper's full pipeline, assembled.

Decentralized ridge on a sparse dataset -> one `solve()` call per claim:
DSBA dense for linear convergence to the centralized optimum, DSBA sparse
for trajectory-exact relay communication at the closed-form O(N rho d)
cost — the paper's two claims, one test, one API.
"""
import numpy as np

from repro.core import mixing
from repro.core.dsba import draw_indices
from repro.core.solvers import make_problem, solve
from repro.core.sparse_comm import (
    dense_doubles_per_iter, sparse_doubles_per_iter,
)
from repro.data.synthetic import make_regression


def test_end_to_end_paper_pipeline():
    n, q, d, k = 8, 20, 400, 10
    data = make_regression(n, q, d, k=k, seed=0)
    graph = mixing.erdos_renyi_graph(n, 0.4, seed=1)
    problem = make_problem("ridge", data, graph)  # lam = 1/(10 Q)
    mixing.validate_mixing(problem.w, graph)
    problem.solve_star()

    # claim 1: linear convergence to the centralized root
    res = solve(problem, "dsba", steps=6000, record_every=1000, alpha=2.0)
    assert res.dist2[-1] < 1e-8, res.dist2
    drops = np.diff(np.log10(np.maximum(res.dist2, 1e-300)))
    assert drops.mean() < -0.3  # geometric decay

    # claim 2: sparse communication reproduces the dense trajectory at
    # O(N rho d) cost — same schema, same entrypoint, comm= flipped
    steps = 40
    idx = draw_indices(steps, n, q, seed=2)
    dense = solve(problem, "dsba", steps=steps, record_every=1,
                  indices=idx, alpha=2.0)
    sparse = solve(problem, "dsba", comm="sparse", steps=steps,
                   record_every=1, indices=idx, alpha=2.0)
    np.testing.assert_allclose(sparse.z, dense.z, atol=1e-12)
    per_iter = np.diff(sparse.doubles_received, axis=0)[-8:]
    assert (per_iter == sparse_doubles_per_iter(n, k, 0)).all()
    assert per_iter.max() * 5 < dense_doubles_per_iter(graph, d).max()
    # and the dense side of the same schema reports the deg*d model
    assert (np.diff(dense.doubles_received, axis=0)
            == dense_doubles_per_iter(graph, d)[None, :]).all()

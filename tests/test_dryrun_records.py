"""Integrity of the recorded dry-run grid (deliverable e/g evidence).

Skips when the experiments/dryrun directory hasn't been populated (fresh
checkout); in this repo the full grid is committed as JSON records.
"""
import json
import pathlib

import pytest

DRY = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

EXPECTED_SINGLE = 32  # 10 archs x (train, prefill) + 8 decode-capable x
# decode... = 30 + 2 long_500k
EXPECTED_MULTI = 32


def _load(mesh):
    if not DRY.exists():
        pytest.skip("dry-run records not generated")
    out = []
    for p in sorted(DRY.glob(f"*_{mesh}.json")):
        out.append(json.loads(p.read_text()))
    return out


@pytest.mark.parametrize("mesh,expected", [("single", EXPECTED_SINGLE),
                                           ("multi", EXPECTED_MULTI)])
def test_grid_complete_and_all_ok(mesh, expected):
    recs = _load(mesh)
    if not recs:
        pytest.skip("dry-run records not generated")
    assert len(recs) == expected, [r["arch"] + "/" + r["shape"] for r in recs]
    bad = [f"{r['arch']}/{r['shape']}: {r.get('error')}" for r in recs
           if not r.get("ok")]
    assert not bad, bad


def test_records_have_roofline_terms():
    recs = _load("single")
    if not recs:
        pytest.skip("dry-run records not generated")
    for r in recs:
        rl = r["roofline"]
        assert rl["compute_s"] >= 0 and rl["memory_s"] > 0
        assert rl["dominant"] in ("compute", "memory", "collective")
        assert r["model_flops"] > 0
        # flops accounting sanity: compiled >= 25% of model-useful flops
        # (remat/replication can only ADD compiled flops; analyzer missing
        # most flops would push this way below 0.25... except decode cells,
        # whose useful-flops are tiny vs always-on substrate work)
        if r["shape"] in ("train_4k",):
            assert rl["useful_flop_ratio"] < 1.5, (r["arch"], r["shape"])


def test_multi_pod_train_cells_have_collective_permute():
    recs = [r for r in _load("multi") if r["shape"] == "train_4k"]
    if not recs:
        pytest.skip("dry-run records not generated")
    for r in recs:
        counts = r["collectives"]["count"]
        assert counts.get("collective-permute", 0) > 0, r["arch"]

import jax

# Convex-optimization tests need f64 to verify linear convergence to 1e-10+.
# Model/kernel tests run in f32/bf16 explicitly.
jax.config.update("jax_enable_x64", True)

import os
import subprocess
import sys
from pathlib import Path

import pytest

import jax

# Convex-optimization tests need f64 to verify linear convergence to 1e-10+.
# Model/kernel tests run in f32/bf16 explicitly.
jax.config.update("jax_enable_x64", True)

REPO = Path(__file__).resolve().parents[1]


@pytest.fixture
def forced_devices_pytest():
    """Run a pytest target in a subprocess with N forced host devices.

    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` only takes
    effect before jax initializes, which this (already-initialized)
    process cannot retrofit — so multi-device tiers (tests/multidevice/)
    run in a fresh interpreter. The child inherits the persistent compile
    cache, keeping repeat runs cheap.
    """

    def run(target, n_devices=8, extra_env=None, timeout=1200):
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n_devices}"
        ).strip()
        env["PYTHONPATH"] = (
            str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
        )
        env.update(extra_env or {})
        return subprocess.run(
            [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
             str(target)],
            cwd=REPO, env=env, capture_output=True, text=True,
            timeout=timeout,
        )

    return run

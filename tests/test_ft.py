"""Fault tolerance: heartbeat detection, elastic re-mixing, staleness, loader."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.gossip import (
    GossipConfig, init_gossip_state,
    make_gossip_train_step,
)
from repro.data.sharded_loader import LoaderConfig, ShardedTokenLoader, batch_at
from repro.ft import ElasticGossip, HeartbeatMonitor
from repro.ft.elastic import BoundedStalenessBuffer
from repro.optim.adam import AdamConfig
from repro.train.step import TrainConfig


def test_heartbeat_detects_dead_pod():
    hb = HeartbeatMonitor(3, timeout=2)
    for _ in range(2):
        hb.heartbeat(0)
        hb.heartbeat(1)  # pod 2 silent
        dead = hb.tick()
    assert dead == [2]


def test_heartbeat_reports_each_death_exactly_once():
    """Regression: tick() used to re-report already-dead pods every tick,
    so a supervisor driving ElasticGossip.shrink off the tick() list would
    shrink the same pod twice."""
    hb = HeartbeatMonitor(3, timeout=2)

    def tick_with_live(n=1):
        out = []
        for _ in range(n):
            hb.heartbeat(0)
            hb.heartbeat(1)  # pod 2 silent
            out = hb.tick()
        return out

    assert tick_with_live(2) == [2]
    assert tick_with_live() == []  # already reported: stays silent
    assert tick_with_live() == []
    # a late heartbeat resurrects the pod...
    hb.heartbeat(2)
    assert tick_with_live() == []
    # ...and a NEW silence is reported again (exactly once)
    assert tick_with_live() == [2]
    assert tick_with_live() == []
    # explicit re-add after removal also re-arms reporting
    hb.remove(2)
    hb.add(2)
    assert tick_with_live() == []
    assert tick_with_live() == [2]


def test_heartbeat_membership_is_explicit():
    """remove() of an unknown pod and add() of a monitored pod both raise
    (the silent no-op / silent-reset behaviors masked supervisor bugs:
    double-shrink of the same dead pod, join-id collisions)."""
    hb = HeartbeatMonitor(2, timeout=2)
    with pytest.raises(KeyError, match="not monitored"):
        hb.remove(7)
    with pytest.raises(ValueError, match="already monitored"):
        hb.add(1)
    # remove -> add re-registers; double-remove raises
    hb.remove(1)
    with pytest.raises(KeyError, match="not monitored"):
        hb.remove(1)
    hb.add(1)
    assert 1 in hb.last_seen
    # a declared-dead pod is still monitored (late heartbeats resurrect),
    # so add() of it raises and remove() of it works
    hb2 = HeartbeatMonitor(2, timeout=2)
    for _ in range(2):
        hb2.heartbeat(0)
        dead = hb2.tick()
    assert dead == [1]
    with pytest.raises(ValueError, match="already monitored"):
        hb2.add(1)
    hb2.remove(1)
    assert 1 not in hb2.declared_dead and 1 not in hb2.last_seen


def _setup(n_pods=4):
    cfg = dataclasses.replace(get_reduced("minitron_8b"), n_layers=1)
    tc = TrainConfig(optimizer=AdamConfig(lr=1e-2, warmup_steps=1))
    gc = GossipConfig(n_pods=n_pods, mode="dsgd")
    state = init_gossip_state(cfg, tc, gc, jax.random.PRNGKey(0))
    return cfg, tc, gc, state


def _batch(cfg, n_pods, seed=0):
    k = jax.random.PRNGKey(seed)
    toks = jax.random.randint(k, (n_pods, 2, 17), 0, cfg.vocab_size)
    return {"tokens": toks[..., :-1], "targets": toks[..., 1:]}


def test_elastic_shrink_then_training_continues():
    cfg, tc, gc, state = _setup(4)
    step4 = jax.jit(make_gossip_train_step(None, cfg, tc, gc))
    for i in range(3):
        state, _ = step4(state, _batch(cfg, 4, i))

    el = ElasticGossip(gc)
    state3, gc3 = el.shrink(state, dead=[2])
    assert state3["params"]["embed"].shape[0] == 3
    step3 = jax.jit(make_gossip_train_step(None, cfg, tc, gc3))
    losses = []
    for i in range(10):
        state3, m = step3(state3, _batch(cfg, 3, 10 + i))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()


def test_elastic_grow_seeds_consensus():
    cfg, tc, gc, state = _setup(3)
    el = ElasticGossip(gc)
    state5, gc5 = el.grow(state, n_new=2, seed_from=0)
    assert gc5.n_pods == 5
    p = state5["params"]["embed"]
    np.testing.assert_array_equal(np.asarray(p[3]), np.asarray(p[0]))
    step5 = jax.jit(make_gossip_train_step(None, cfg, tc, gc5))
    state5, m = step5(state5, _batch(cfg, 5, 1))
    assert np.isfinite(float(m["loss"]))


def test_bounded_staleness_buffer():
    buf = BoundedStalenessBuffer(max_staleness=2)
    buf.deliver(1, "v0")
    assert buf.get(1) == "v0"
    buf.advance()
    buf.advance()
    assert buf.get(1) == "v0"  # age 2 == max_staleness: still usable
    buf.advance()
    assert buf.get(1) is None  # too stale -> caller drops the term
    assert buf.get(9) is None  # never delivered


def test_loader_determinism_and_resume():
    cfg = LoaderConfig(vocab_size=1000, global_batch=4, seq_len=16, n_shards=2)
    b5 = batch_at(cfg, 5)
    b5_again = batch_at(cfg, 5)
    np.testing.assert_array_equal(b5["tokens"], b5_again["tokens"])

    # streaming loader produces the same cells, in order, from any start
    ld = ShardedTokenLoader(cfg, shard=0, start_step=5)
    step_b = next(ld)
    ld.close()
    np.testing.assert_array_equal(
        step_b["tokens"], b5["tokens"][: cfg.shard_batch]
    )
    # different shards see different data
    assert not np.array_equal(b5["tokens"][:2], b5["tokens"][2:])

"""The PR 4 deprecation shims, pinned in ONE file for one-file removal.

`core.dsba.run` and `core.baselines.run_*` have survived since PR 4 as
parity-pinned delegates to `core.solvers.solve`. Everything that guards
them lives here — parity pins (dsba/dsa bit-equal snapshot traces,
baselines <= 1e-12 across ridge/logistic/auc on ring + Erdős–Rényi),
once-per-process warning behavior, and the final-warning text with its
removal version — so deleting the shims in v0.2 is this file plus the
shim bodies, nothing else.
"""
import warnings

import numpy as np
import pytest

from repro.core import deprecation, mixing
from repro.core.baselines import run_dlm, run_extra, run_ssda
from repro.core.dsba import DSBAConfig, draw_indices
from repro.core.dsba import run as legacy_run
from repro.core.solvers import make_problem, solve
from repro.data.synthetic import make_classification, make_regression

STEPS = 24
REC = 8
GRAPHS = ["ring", "erdos_renyi"]
TASKS = ["ridge", "logistic", "auc"]


@pytest.fixture
def fresh_deprecations():
    """Shim warnings fire once per process; reset so this test sees them."""
    deprecation.reset()
    yield
    deprecation.reset()


def _problem(task, gname="erdos_renyi", n_nodes=5, q=6, d=16, k=4, lam=1e-2,
             seed=0):
    if task == "ridge":
        data = make_regression(n_nodes, q, d, k=k, seed=seed)
    elif task == "logistic":
        data = make_classification(n_nodes, q, d, k=k, seed=seed)
    else:
        data = make_classification(n_nodes, q, d, k=k, positive_ratio=0.3,
                                   seed=seed)
    if gname == "ring":
        graph = mixing.ring_graph(n_nodes)
    else:
        graph = mixing.erdos_renyi_graph(n_nodes, 0.4, seed=1)
    return make_problem(task, data, graph, lam=lam)


# ---------------------------------------------------------------------------
# shim parity: dsba/dsa bit-equal, baselines <= 1e-12
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("gname", GRAPHS)
@pytest.mark.parametrize("task", TASKS)
def test_dsba_dsa_shims_bit_identical(task, gname, fresh_deprecations):
    problem = _problem(task, gname)
    n, q = problem.data.n_nodes, problem.data.q
    indices = draw_indices(STEPS, n, q, seed=5)
    for method in ("dsba", "dsa"):
        cfg = DSBAConfig(problem.spec, 0.3, problem.lam, method=method)
        deprecation.reset()
        with pytest.warns(DeprecationWarning):
            legacy = legacy_run(
                cfg, problem.data, problem.w, STEPS, record_every=REC,
                indices=indices, keep_snapshots=True,
            )
        new = solve(problem, method, steps=STEPS, record_every=REC,
                    indices=indices, keep_snapshots=True, alpha=0.3)
        assert np.array_equal(legacy.zs, new.zs), (task, gname, method)
        assert np.array_equal(np.asarray(legacy.state.z), new.z)
        assert (legacy.iters == new.iters).all()


@pytest.mark.parametrize("gname", GRAPHS)
@pytest.mark.parametrize("task", TASKS)
def test_baseline_shims_trace_match(task, gname, fresh_deprecations):
    problem = _problem(task, gname)
    z_star = problem.solve_star()
    data, w, lam = problem.data, problem.w, problem.lam

    deprecation.reset()
    with pytest.warns(DeprecationWarning):
        legacy = run_extra(problem.spec, data, w, alpha=0.2, lam=lam,
                           steps=STEPS, z_star=z_star, record_every=REC)
    new = solve(problem, "extra", steps=STEPS, record_every=REC, alpha=0.2)
    np.testing.assert_allclose(
        np.asarray(legacy.state[0]), new.z, rtol=0, atol=1e-12
    )
    np.testing.assert_allclose(legacy.dist2, new.dist2, rtol=0, atol=1e-12)
    np.testing.assert_allclose(legacy.consensus, new.consensus, rtol=0,
                               atol=1e-12)

    deprecation.reset()
    with pytest.warns(DeprecationWarning):
        legacy = run_dlm(problem.spec, data, problem.graph, c=0.3, beta=1.0,
                         lam=lam, steps=STEPS, z_star=z_star,
                         record_every=REC)
    new = solve(problem, "dlm", steps=STEPS, record_every=REC, c=0.3,
                beta=1.0)
    np.testing.assert_allclose(
        np.asarray(legacy.state[0]), new.z, rtol=0, atol=1e-12
    )
    np.testing.assert_allclose(legacy.dist2, new.dist2, rtol=0, atol=1e-12)

    if task != "auc":  # the paper: SSDA does not apply to the AUC saddle
        deprecation.reset()
        with pytest.warns(DeprecationWarning):
            legacy = run_ssda(problem.spec, data, w, eta=0.05, momentum=0.5,
                              lam=lam, steps=STEPS, z_star=z_star,
                              record_every=REC)
        new = solve(problem, "ssda", steps=STEPS, record_every=REC,
                    eta=0.05, momentum=0.5)
        np.testing.assert_allclose(legacy.dist2, new.dist2, rtol=0,
                                   atol=1e-12)
        np.testing.assert_allclose(legacy.consensus, new.consensus, rtol=0,
                                   atol=1e-12)


# ---------------------------------------------------------------------------
# warning behavior: once per process, attributed to the caller, final text
# ---------------------------------------------------------------------------


def test_shims_warn_once_per_process_at_caller(fresh_deprecations):
    """Sweep loops through legacy shims must not spam: one warning per shim
    per process, attributed (stacklevel) to the caller's file."""
    problem = _problem("ridge")
    cfg = DSBAConfig(problem.spec, 0.3, problem.lam, method="dsba")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        for _ in range(3):
            legacy_run(cfg, problem.data, problem.w, 4, record_every=4)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1
    assert dep[0].filename == __file__

    deprecation.reset()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        for _ in range(3):
            run_extra(problem.spec, problem.data, problem.w, alpha=0.2,
                      lam=problem.lam, steps=4)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1
    assert dep[0].filename == __file__


def test_shims_announce_removal_version(fresh_deprecations):
    """The final-warning text names the removal version, per shim."""
    problem = _problem("ridge")
    cfg = DSBAConfig(problem.spec, 0.3, problem.lam, method="dsba")
    with pytest.warns(DeprecationWarning, match=r"REMOVED in v0\.2"):
        legacy_run(cfg, problem.data, problem.w, 4, record_every=4)
    deprecation.reset()
    with pytest.warns(DeprecationWarning, match=r"REMOVED in v0\.2"):
        run_extra(problem.spec, problem.data, problem.w, alpha=0.2,
                  lam=problem.lam, steps=4)
    deprecation.reset()
    with pytest.warns(DeprecationWarning, match=r"REMOVED in v0\.2"):
        run_dlm(problem.spec, problem.data, problem.graph, c=0.3, beta=1.0,
                lam=problem.lam, steps=4)
    deprecation.reset()
    with pytest.warns(DeprecationWarning, match=r"REMOVED in v0\.2"):
        run_ssda(problem.spec, problem.data, problem.w, eta=0.05,
                 momentum=0.5, lam=problem.lam, steps=4)

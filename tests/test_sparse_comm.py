"""DSBA-s (Section 5.1): protocol == dense algorithm, costs == O(N rho d).

The fast (default) tests share one compiled configuration via a module
fixture: a ridge/DSBA run on the paper's Erdős–Rényi topology, executed by
the dense runtime, the vectorized relay engine (verify=True, Pallas-routed
delta path), and the legacy reference loop. The `slow`-marked sweeps extend
the same claims to every task x method x graph combination; run them with
`pytest -m ""`.
"""
import numpy as np
import pytest

from repro.core import mixing
from repro.core.dsba import DSBAConfig, draw_indices, run
from repro.core.operators import OperatorSpec
from repro.core.sparse_comm import (
    dense_doubles_per_iter,
    run_sparse,
    sparse_doubles_per_iter,
)
from repro.data.synthetic import make_classification, make_regression

STEPS = 40


def _setup(task, n_nodes=6, q=8, d=24, k=4, seed=0):
    if task == "ridge":
        data = make_regression(n_nodes, q, d, k=k, seed=seed)
        spec = OperatorSpec("ridge")
    elif task == "logistic":
        data = make_classification(n_nodes, q, d, k=k, seed=seed)
        spec = OperatorSpec("logistic")
    else:
        data = make_classification(n_nodes, q, d, k=k, positive_ratio=0.3, seed=seed)
        spec = OperatorSpec("auc", p=data.positive_ratio())
    graph = mixing.erdos_renyi_graph(n_nodes, 0.4, seed=2)
    w = mixing.laplacian_mixing(graph)
    return data, spec, graph, w


def _graph(name, n):
    return mixing.ring_graph(n) if name == "ring" else mixing.erdos_renyi_graph(
        n, 0.4, seed=2
    )


@pytest.fixture(scope="module")
def shared():
    """Dense + vectorized + reference runs of one shared configuration."""
    data, spec, graph, w = _setup("ridge")
    cfg = DSBAConfig(spec, alpha=0.3, lam=1.0 / (10 * data.total))
    indices = draw_indices(STEPS, data.n_nodes, data.q, seed=7)
    dense = run(cfg, data, w, STEPS, record_every=STEPS, indices=indices)
    vec = run_sparse(cfg, data, graph, w, STEPS, indices, verify=True)
    ref = run_sparse(cfg, data, graph, w, STEPS, indices, engine="reference")
    return data, graph, dense, vec, ref


def test_sparse_comm_trajectory_equals_dense(shared):
    """The relay protocol must reproduce the dense trajectory exactly."""
    _, _, dense, vec, _ = shared
    np.testing.assert_allclose(
        vec.z_trace[-1], np.asarray(dense.state.z), rtol=0, atol=1e-12
    )
    assert vec.recon_max_err < 1e-9, vec.recon_max_err


def test_vectorized_engine_matches_reference(shared):
    """Ring-buffer engine == legacy loop: trajectory, costs, recon error."""
    _, _, _, vec, ref = shared
    np.testing.assert_allclose(vec.z_trace, ref.z_trace, rtol=0, atol=1e-12)
    assert (vec.doubles_received == ref.doubles_received).all()
    assert (vec.ints_received == ref.ints_received).all()
    assert ref.recon_max_err < 1e-9
    assert vec.recon_max_err < 1e-9


def test_sparse_comm_cost_is_o_n_rho_d(shared):
    """Steady-state per-iteration DOUBLEs: (N-1)*k  vs  dense deg*d."""
    data, graph, _, vec, _ = shared
    per_iter = np.diff(vec.doubles_received, axis=0)[-10:]  # steady state
    expect = sparse_doubles_per_iter(data.n_nodes, data.k, 0)
    assert (per_iter == expect).all(), (per_iter, expect)

    # the headline claim at paper-like dimension (cost model is d-free on
    # the sparse side; the dense side scales with d): rho*d << d
    d_paper = 600
    dense_cost = dense_doubles_per_iter(graph, d_paper)
    assert per_iter.max() * 10 < dense_cost.min()


def test_sparse_comm_warmup_cost_is_one_time(shared):
    data, graph, _, vec, _ = shared
    E = graph.diameter
    total_warmup = vec.doubles_received[E + 1].max()
    # warm-up includes the one-time dense z^1 flood: (N-1)*D doubles
    assert total_warmup >= (data.n_nodes - 1) * data.d
    # after warm-up, growth is exactly the sparse rate
    growth = np.diff(vec.doubles_received, axis=0)[E + 2 :]
    assert (growth == sparse_doubles_per_iter(data.n_nodes, data.k, 0)).all()


def test_verify_mode_catches_protocol_violations(shared, monkeypatch):
    """A corrupted relay schedule must trip the availability guard."""
    import repro.core.sparse_comm as sc

    data, graph, _, _, _ = shared
    w = mixing.laplacian_mixing(graph)
    cfg = DSBAConfig(OperatorSpec("ridge"), alpha=0.3, lam=1e-3)
    indices = draw_indices(8, data.n_nodes, data.q, seed=7)

    real_tables = sc._protocol_tables

    def shallow_tables(g, wt):
        # depth=2 makes the write slot collide with the s-2 read slot, so
        # reconstructions consume clobbered history — exactly the class of
        # bookkeeping bug verify= exists to catch.
        import dataclasses as dc

        return dc.replace(real_tables(g, wt), depth=2)

    monkeypatch.setattr(sc, "_protocol_tables", shallow_tables)
    with pytest.raises(sc.ProtocolViolation):
        sc.run_sparse(
            cfg, data, graph, w, 8, indices, verify=True, use_pallas="off"
        )


def test_fast_path_reports_nan_recon_err(shared):
    """Without verify= the engine skips truth checking (allocation-lean)."""
    data, graph, _, _, _ = shared
    spec = OperatorSpec("ridge")
    cfg = DSBAConfig(spec, alpha=0.3, lam=1.0 / (10 * data.total))
    w = mixing.laplacian_mixing(graph)
    indices = draw_indices(4, data.n_nodes, data.q, seed=7)
    res = run_sparse(cfg, data, graph, w, 4, indices, use_pallas="off")
    assert np.isnan(res.recon_max_err)


# ---------------------------------------------------------------------------
# Exhaustive sweeps (slow): every task x method against the dense runtime,
# and engine parity on ring + Erdős–Rényi graphs for all three tasks.
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("task", ["ridge", "logistic", "auc"])
@pytest.mark.parametrize("method", ["dsba", "dsa"])
def test_sparse_comm_trajectory_equals_dense_matrix(task, method):
    data, spec, graph, w = _setup(task)
    steps = 60
    lam = 1.0 / (10 * data.total)
    cfg = DSBAConfig(spec, alpha=0.3, lam=lam, method=method)
    indices = draw_indices(steps, data.n_nodes, data.q, seed=7)

    dense = run(cfg, data, w, steps, record_every=steps, indices=indices,
                keep_snapshots=True)
    sparse = run_sparse(cfg, data, graph, w, steps, indices, verify=True)

    np.testing.assert_allclose(
        sparse.z_trace[-1], np.asarray(dense.state.z), rtol=0, atol=1e-12
    )
    assert sparse.recon_max_err < 1e-9, sparse.recon_max_err


@pytest.mark.slow
@pytest.mark.parametrize("gname", ["ring", "erdos_renyi"])
@pytest.mark.parametrize("task", ["ridge", "logistic", "auc"])
@pytest.mark.parametrize("method", ["dsba", "dsa"])
def test_vectorized_matches_reference_matrix(gname, task, method):
    """Parity on multi-hop topologies: z_trace, doubles, ints, recon err."""
    data, spec, _, _ = _setup(task, n_nodes=7)
    graph = _graph(gname, 7)
    w = mixing.laplacian_mixing(graph)
    steps = 40
    cfg = DSBAConfig(spec, alpha=0.3, lam=1e-3, method=method)
    indices = draw_indices(steps, 7, data.q, seed=3)
    ref = run_sparse(cfg, data, graph, w, steps, indices, engine="reference")
    vec = run_sparse(cfg, data, graph, w, steps, indices, verify=True)
    np.testing.assert_allclose(vec.z_trace, ref.z_trace, rtol=0, atol=1e-12)
    assert (vec.doubles_received == ref.doubles_received).all()
    assert (vec.ints_received == ref.ints_received).all()
    assert vec.recon_max_err < 1e-9
    assert ref.recon_max_err < 1e-9


@pytest.mark.slow
def test_sparse_comm_reconstruction_on_larger_diameter_graph():
    """Ring graph (diameter 3): deltas arrive with multi-hop delays."""
    data, spec, _, _ = _setup("ridge", n_nodes=7)
    graph = mixing.ring_graph(7)
    w = mixing.laplacian_mixing(graph)
    steps = 40
    cfg = DSBAConfig(spec, alpha=0.3, lam=1e-3)
    indices = draw_indices(steps, 7, data.q, seed=3)
    dense = run(cfg, data, w, steps, record_every=steps, indices=indices)
    sparse = run_sparse(cfg, data, graph, w, steps, indices, verify=True)
    np.testing.assert_allclose(
        sparse.z_trace[-1], np.asarray(dense.state.z), atol=1e-12
    )
    assert sparse.recon_max_err < 1e-9


@pytest.mark.slow
def test_sparse_comm_cost_at_paper_dimension():
    """Seed-strength cost check: measured accounting at d=600."""
    data, spec, graph, w = _setup("ridge", n_nodes=6, d=600, k=5)
    steps = 30
    cfg = DSBAConfig(spec, alpha=0.3, lam=1e-3)
    indices = draw_indices(steps, 6, data.q, seed=3)
    res = run_sparse(cfg, data, graph, w, steps, indices)
    per_iter = np.diff(res.doubles_received, axis=0)[-10:]
    expect = sparse_doubles_per_iter(6, data.k, spec.tail_dim)
    assert (per_iter == expect).all(), (per_iter, expect)
    dense_cost = dense_doubles_per_iter(graph, data.d)
    assert per_iter.max() * 10 < dense_cost.min()

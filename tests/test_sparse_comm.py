"""DSBA-s (Section 5.1): protocol == dense algorithm, costs == O(N rho d)."""
import numpy as np
import pytest

from repro.core import mixing
from repro.core.dsba import DSBAConfig, draw_indices, run
from repro.core.operators import OperatorSpec
from repro.core.sparse_comm import (
    dense_doubles_per_iter,
    run_sparse,
    sparse_doubles_per_iter,
)
from repro.data.synthetic import make_classification, make_regression


def _setup(task, n_nodes=6, q=8, d=24, k=4, seed=0):
    if task == "ridge":
        data = make_regression(n_nodes, q, d, k=k, seed=seed)
        spec = OperatorSpec("ridge")
    elif task == "logistic":
        data = make_classification(n_nodes, q, d, k=k, seed=seed)
        spec = OperatorSpec("logistic")
    else:
        data = make_classification(n_nodes, q, d, k=k, positive_ratio=0.3, seed=seed)
        spec = OperatorSpec("auc", p=data.positive_ratio())
    graph = mixing.erdos_renyi_graph(n_nodes, 0.4, seed=2)
    w = mixing.laplacian_mixing(graph)
    return data, spec, graph, w


@pytest.mark.parametrize("task", ["ridge", "logistic", "auc"])
@pytest.mark.parametrize("method", ["dsba", "dsa"])
def test_sparse_comm_trajectory_equals_dense(task, method):
    """The relay protocol must reproduce the dense trajectory exactly."""
    data, spec, graph, w = _setup(task)
    steps = 60
    lam = 1.0 / (10 * data.total)
    cfg = DSBAConfig(spec, alpha=0.3, lam=lam, method=method)
    indices = draw_indices(steps, data.n_nodes, data.q, seed=7)

    dense = run(cfg, data, w, steps, record_every=steps, indices=indices,
                keep_snapshots=True)
    sparse = run_sparse(cfg, data, graph, w, steps, indices)

    np.testing.assert_allclose(
        sparse.z_trace[-1], np.asarray(dense.state.z), rtol=0, atol=1e-12
    )
    assert sparse.recon_max_err < 1e-9, sparse.recon_max_err


def test_sparse_comm_reconstruction_on_larger_diameter_graph():
    """Ring graph (diameter 3): deltas arrive with multi-hop delays."""
    data, spec, _, _ = _setup("ridge", n_nodes=7)
    graph = mixing.ring_graph(7)
    w = mixing.laplacian_mixing(graph)
    steps = 40
    cfg = DSBAConfig(spec, alpha=0.3, lam=1e-3)
    indices = draw_indices(steps, 7, data.q, seed=3)
    dense = run(cfg, data, w, steps, record_every=steps, indices=indices)
    sparse = run_sparse(cfg, data, graph, w, steps, indices)
    np.testing.assert_allclose(
        sparse.z_trace[-1], np.asarray(dense.state.z), atol=1e-12
    )
    assert sparse.recon_max_err < 1e-9


def test_sparse_comm_cost_is_o_n_rho_d():
    """Steady-state per-iteration DOUBLEs: (N-1)*k  vs  dense deg*d."""
    data, spec, graph, w = _setup("ridge", n_nodes=6, d=600, k=5)
    steps = 30
    cfg = DSBAConfig(spec, alpha=0.3, lam=1e-3)
    indices = draw_indices(steps, 6, data.q, seed=3)
    res = run_sparse(cfg, data, graph, w, steps, indices)

    per_iter = np.diff(res.doubles_received, axis=0)[-10:]  # steady state
    expect = sparse_doubles_per_iter(6, data.k, spec.tail_dim)
    assert (per_iter == expect).all(), (per_iter, expect)

    dense_cost = dense_doubles_per_iter(graph, data.d)
    # the headline claim: sparse cost << dense cost when rho*d << d
    assert per_iter.max() * 10 < dense_cost.min()


def test_sparse_comm_warmup_cost_is_one_time():
    data, spec, graph, w = _setup("ridge", n_nodes=5, d=200, k=4)
    steps = 25
    cfg = DSBAConfig(spec, alpha=0.3, lam=1e-3)
    indices = draw_indices(steps, 5, data.q, seed=3)
    res = run_sparse(cfg, data, graph, w, steps, indices)
    E = graph.diameter
    total_warmup_dense = res.doubles_received[E + 1].max()
    # warm-up includes the one-time dense z^1 flood: (N-1)*D doubles
    assert total_warmup_dense >= (5 - 1) * data.d
    # after warm-up, growth is exactly the sparse rate
    growth = np.diff(res.doubles_received, axis=0)[E + 2 :]
    assert (growth == sparse_doubles_per_iter(5, data.k, 0)).all()

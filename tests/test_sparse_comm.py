"""DSBA-s (Section 5.1): protocol == dense algorithm, costs == O(N rho d).

All runs go through `core.solvers.solve` — the sparse relay is just the
`comm="sparse"` backend of the registry (backend options pass through
`comm_options`). The fast (default) tests share one compiled configuration
via a module fixture: a ridge/DSBA run on the paper's Erdős–Rényi topology,
executed by the dense backend, the vectorized relay engine (verify=True,
Pallas-routed delta path), and the legacy reference loop. The `slow`-marked
sweeps extend the same claims to every task x method x graph combination;
run them with `pytest -m ""`.
"""
import numpy as np
import pytest

from repro.core import mixing
from repro.core.dsba import draw_indices
from repro.core.solvers import make_problem, solve
from repro.core.sparse_comm import (
    dense_doubles_per_iter,
    sparse_doubles_per_iter,
)
from repro.data.synthetic import make_classification, make_regression

STEPS = 40


def _setup(task, n_nodes=6, q=8, d=24, k=4, seed=0, lam=None):
    if task == "ridge":
        data = make_regression(n_nodes, q, d, k=k, seed=seed)
    elif task == "logistic":
        data = make_classification(n_nodes, q, d, k=k, seed=seed)
    else:
        data = make_classification(n_nodes, q, d, k=k, positive_ratio=0.3,
                                   seed=seed)
    graph = mixing.erdos_renyi_graph(n_nodes, 0.4, seed=2)
    return make_problem(task, data, graph, lam=lam)


def _graph(name, n):
    return mixing.ring_graph(n) if name == "ring" else mixing.erdos_renyi_graph(
        n, 0.4, seed=2
    )


@pytest.fixture(scope="module")
def shared():
    """Dense + vectorized + reference runs of one shared configuration."""
    problem = _setup("ridge")
    indices = draw_indices(STEPS, problem.data.n_nodes, problem.data.q, seed=7)
    kw = dict(steps=STEPS, record_every=1, indices=indices, alpha=0.3)
    dense = solve(problem, "dsba", comm="dense", **kw)
    vec = solve(problem, "dsba", comm="sparse",
                comm_options={"verify": True}, **kw)
    ref = solve(problem, "dsba", comm="sparse",
                comm_options={"engine": "reference"}, **kw)
    return problem, dense, vec, ref


def test_sparse_comm_trajectory_equals_dense(shared):
    """The relay protocol must reproduce the dense trajectory exactly."""
    _, dense, vec, _ = shared
    np.testing.assert_allclose(vec.z, dense.z, rtol=0, atol=1e-12)
    assert vec.extras["recon_max_err"] < 1e-9, vec.extras["recon_max_err"]


def test_vectorized_engine_matches_reference(shared):
    """Ring-buffer engine == legacy loop: trajectory, costs, recon error."""
    _, _, vec, ref = shared
    np.testing.assert_allclose(
        vec.extras["z_trace"], ref.extras["z_trace"], rtol=0, atol=1e-12
    )
    assert (vec.doubles_received == ref.doubles_received).all()
    assert (vec.ints_received == ref.ints_received).all()
    assert ref.extras["recon_max_err"] < 1e-9
    assert vec.extras["recon_max_err"] < 1e-9


def test_solve_result_schema_uniform_across_backends(shared):
    """One schema: both backends fill iters/metrics/comm the same way."""
    _, dense, vec, _ = shared
    assert (dense.iters == vec.iters).all()
    n = dense.doubles_received.shape[1]
    assert vec.doubles_received.shape == dense.doubles_received.shape
    assert (dense.ints_received == 0).all()  # dense blocks carry no indices
    # dense accounting is the closed-form deg*D model at every record point
    problem = shared[0]
    per_node = dense_doubles_per_iter(problem.graph, problem.dim)
    assert (dense.doubles_received
            == dense.iters[:, None] * per_node[None, :]).all()
    assert dense.wall_time > 0 and vec.wall_time > 0
    assert dense.z.shape == vec.z.shape == (n, shared[0].dim)


def test_sparse_comm_cost_is_o_n_rho_d(shared):
    """Steady-state per-iteration DOUBLEs: (N-1)*k  vs  dense deg*d."""
    problem, _, vec, _ = shared
    data, graph = problem.data, problem.graph
    per_iter = np.diff(vec.doubles_received, axis=0)[-10:]  # steady state
    expect = sparse_doubles_per_iter(data.n_nodes, data.k, 0)
    assert (per_iter == expect).all(), (per_iter, expect)

    # the headline claim at paper-like dimension (cost model is d-free on
    # the sparse side; the dense side scales with d): rho*d << d
    d_paper = 600
    dense_cost = dense_doubles_per_iter(graph, d_paper)
    assert per_iter.max() * 10 < dense_cost.min()


def test_sparse_comm_warmup_cost_is_one_time(shared):
    problem, _, vec, _ = shared
    data, graph = problem.data, problem.graph
    E = graph.diameter
    total_warmup = vec.doubles_received[E + 1].max()
    # warm-up includes the one-time dense z^1 flood: (N-1)*D doubles
    assert total_warmup >= (data.n_nodes - 1) * data.d
    # after warm-up, growth is exactly the sparse rate
    growth = np.diff(vec.doubles_received, axis=0)[E + 2 :]
    assert (growth == sparse_doubles_per_iter(data.n_nodes, data.k, 0)).all()


def test_sparse_comm_requires_a_sparse_backend(shared):
    """comm="sparse" on a dense-only method is a clear error, not a fallback."""
    problem = shared[0]
    with pytest.raises(ValueError, match="sparse-communication backend"):
        solve(problem, "extra", comm="sparse", steps=4)


def test_verify_mode_catches_protocol_violations(shared, monkeypatch):
    """A corrupted relay schedule must trip the availability guard."""
    import repro.core.sparse_comm as sc

    problem = _setup("ridge", lam=1e-3)
    indices = draw_indices(8, problem.data.n_nodes, problem.data.q, seed=7)

    real_tables = sc._protocol_tables

    def shallow_tables(g, wt):
        # depth=2 makes the write slot collide with the s-2 read slot, so
        # reconstructions consume clobbered history — exactly the class of
        # bookkeeping bug verify= exists to catch.
        import dataclasses as dc

        return dc.replace(real_tables(g, wt), depth=2)

    monkeypatch.setattr(sc, "_protocol_tables", shallow_tables)
    with pytest.raises(sc.ProtocolViolation):
        solve(problem, "dsba", comm="sparse", steps=8, indices=indices,
              alpha=0.3, comm_options={"verify": True, "use_pallas": "off"})


def test_fast_path_reports_nan_recon_err(shared):
    """Without verify= the engine skips truth checking (allocation-lean)."""
    problem = _setup("ridge")
    indices = draw_indices(4, problem.data.n_nodes, problem.data.q, seed=7)
    res = solve(problem, "dsba", comm="sparse", steps=4, indices=indices,
                alpha=0.3, comm_options={"use_pallas": "off"})
    assert np.isnan(res.extras["recon_max_err"])


# ---------------------------------------------------------------------------
# Exhaustive sweeps (slow): every task x method against the dense backend,
# and engine parity on ring + Erdős–Rényi graphs for all three tasks.
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("task", ["ridge", "logistic", "auc"])
@pytest.mark.parametrize("method", ["dsba", "dsa"])
def test_sparse_comm_trajectory_equals_dense_matrix(task, method):
    problem = _setup(task)
    steps = 60
    indices = draw_indices(steps, problem.data.n_nodes, problem.data.q, seed=7)
    kw = dict(steps=steps, record_every=steps, indices=indices, alpha=0.3)
    dense = solve(problem, method, comm="dense", keep_snapshots=True, **kw)
    sparse = solve(problem, method, comm="sparse",
                   comm_options={"verify": True}, **kw)

    np.testing.assert_allclose(sparse.z, dense.z, rtol=0, atol=1e-12)
    err = sparse.extras["recon_max_err"]
    assert err < 1e-9, err


@pytest.mark.slow
@pytest.mark.parametrize("gname", ["ring", "erdos_renyi"])
@pytest.mark.parametrize("task", ["ridge", "logistic", "auc"])
@pytest.mark.parametrize("method", ["dsba", "dsa"])
def test_vectorized_matches_reference_matrix(gname, task, method):
    """Parity on multi-hop topologies: z_trace, doubles, ints, recon err."""
    base = _setup(task, n_nodes=7, lam=1e-3)
    graph = _graph(gname, 7)
    problem = make_problem(task, base.data, graph, lam=1e-3)
    steps = 40
    indices = draw_indices(steps, 7, problem.data.q, seed=3)
    kw = dict(steps=steps, record_every=1, indices=indices, alpha=0.3)
    ref = solve(problem, method, comm="sparse",
                comm_options={"engine": "reference"}, **kw)
    vec = solve(problem, method, comm="sparse",
                comm_options={"verify": True}, **kw)
    np.testing.assert_allclose(
        vec.extras["z_trace"], ref.extras["z_trace"], rtol=0, atol=1e-12
    )
    assert (vec.doubles_received == ref.doubles_received).all()
    assert (vec.ints_received == ref.ints_received).all()
    assert vec.extras["recon_max_err"] < 1e-9
    assert ref.extras["recon_max_err"] < 1e-9


@pytest.mark.slow
def test_sparse_comm_reconstruction_on_larger_diameter_graph():
    """Ring graph (diameter 3): deltas arrive with multi-hop delays."""
    base = _setup("ridge", n_nodes=7)
    graph = mixing.ring_graph(7)
    problem = make_problem("ridge", base.data, graph, lam=1e-3)
    steps = 40
    indices = draw_indices(steps, 7, problem.data.q, seed=3)
    kw = dict(steps=steps, record_every=steps, indices=indices, alpha=0.3)
    dense = solve(problem, "dsba", comm="dense", **kw)
    sparse = solve(problem, "dsba", comm="sparse",
                   comm_options={"verify": True}, **kw)
    np.testing.assert_allclose(sparse.z, dense.z, atol=1e-12)
    assert sparse.extras["recon_max_err"] < 1e-9


@pytest.mark.slow
def test_sparse_comm_cost_at_paper_dimension():
    """Seed-strength cost check: measured accounting at d=600."""
    problem = _setup("ridge", n_nodes=6, d=600, k=5, lam=1e-3)
    steps = 30
    indices = draw_indices(steps, 6, problem.data.q, seed=3)
    res = solve(problem, "dsba", comm="sparse", steps=steps, record_every=1,
                indices=indices, alpha=0.3)
    per_iter = np.diff(res.doubles_received, axis=0)[-10:]
    expect = sparse_doubles_per_iter(6, problem.data.k, problem.spec.tail_dim)
    assert (per_iter == expect).all(), (per_iter, expect)
    dense_cost = dense_doubles_per_iter(problem.graph, problem.data.d)
    assert per_iter.max() * 10 < dense_cost.min()

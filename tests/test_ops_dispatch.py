"""kernels/ops.py backend registry: resolution, dispatch, parity harness.

Every registered kernel x every use_pallas mode must resolve to a backend
callable; 'interpret' must match 'off' within the kernel's declared
tolerance over a shape/dtype grid; the sparse-AXPY f64 interpret path is
bit-exact by registry policy.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.kernels import ops


def _distinct_idx(key, N, D, k):
    """Distinct indices per row (padded-CSR guarantee in data/synthetic.py)."""
    return jnp.stack([
        jax.random.permutation(jax.random.fold_in(key, n), D)[:k]
        for n in range(N)
    ]).astype(jnp.int32)


def _example_args(name, key, dtype=jnp.float32, small=True):
    ks = jax.random.split(key, 5)
    if name == "flash_attention":
        B, Hq, Hkv, S, D = (1, 4, 2, 96, 32) if small else (2, 8, 2, 192, 64)
        q = jax.random.normal(ks[0], (B, Hq, S, D), dtype)
        k = jax.random.normal(ks[1], (B, Hkv, S, D), dtype)
        v = jax.random.normal(ks[2], (B, Hkv, S, D), dtype)
        return (q, k, v), {"causal": True}
    if name == "decode_attention":
        B, Hq, Hkv, D, bs, nb, npg = (
            (2, 4, 2, 24, 8, 9, 3) if small else (4, 8, 2, 64, 16, 33, 6)
        )
        q = jax.random.normal(ks[0], (B, Hq, D), dtype)
        kp = jax.random.normal(ks[1], (nb, bs, Hkv, D), dtype)
        vp = jax.random.normal(ks[2], (nb, bs, Hkv, D), dtype)
        table = jax.random.randint(ks[3], (B, npg), 1, nb).astype(jnp.int32)
        lengths = jnp.asarray(
            [npg * bs - 3, 0, 1, 5][:B], jnp.int32
        )
        return (q, kp, vp, table, lengths), {"window": 7, "softcap": 30.0}
    if name == "ssd_chunk":
        B, nc, Q, nh, hd, ds = (1, 2, 32, 2, 16, 8) if small else (2, 2, 64, 4, 32, 16)
        xdt = jax.random.normal(ks[0], (B, nc, Q, nh, hd), dtype)
        cum = -jnp.cumsum(
            jax.random.uniform(ks[1], (B, nc, Q, nh), dtype,
                               minval=0.01, maxval=0.2), axis=2)
        Bc = jax.random.normal(ks[2], (B, nc, Q, ds), dtype)
        Cc = jax.random.normal(ks[3], (B, nc, Q, ds), dtype)
        return (xdt, cum, Bc, Cc), {}
    if name == "sparse_dot":
        N, D, k = (4, 200, 8) if small else (8, 1000, 16)
        psi = jax.random.normal(ks[0], (N, D), dtype)
        idx = _distinct_idx(ks[1], N, D, k)
        val = jax.random.normal(ks[2], (N, k), dtype)
        return (psi, idx, val), {}
    if name == "sparse_axpy":
        N, D, k = (4, 200, 8) if small else (8, 1000, 16)
        psi = jax.random.normal(ks[0], (N, D), dtype)
        idx = _distinct_idx(ks[1], N, D, k)
        val = jax.random.normal(ks[2], (N, k), dtype)
        coef = jax.random.normal(ks[3], (N,), dtype)
        rho = jax.random.uniform(ks[4], (N,), dtype, minval=0.5, maxval=1.0)
        return (psi, idx, val, coef, rho), {}
    if name == "block_topk":
        nb, block, k = (4, 64, 8) if small else (8, 256, 16)
        x = jax.random.normal(ks[0], (nb, block), dtype)
        return (x, k), {}
    raise ValueError(name)


# ---------------------------------------------------------------------------
# registry surface
# ---------------------------------------------------------------------------

def test_registry_is_complete():
    assert ops.registered_kernels() == (
        "block_topk", "decode_attention", "flash_attention", "sparse_axpy",
        "sparse_dot", "ssd_chunk",
    )


@pytest.mark.parametrize("name", ops.registered_kernels())
@pytest.mark.parametrize("mode", ops.MODES)
def test_every_kernel_x_mode_resolves(name, mode):
    backend = ops.resolve_mode(mode)
    assert backend in ops.BACKENDS
    impl = ops.get_kernel(name).impl(backend)
    assert callable(impl)


def test_auto_resolves_to_ref_off_tpu():
    want = "pallas" if jax.default_backend() == "tpu" else "ref"
    assert ops.resolve_mode("auto") == want


def test_unknown_mode_and_backend_raise():
    with pytest.raises(ValueError):
        ops.resolve_mode("pallas")  # backend name, not a mode
    with pytest.raises(ValueError):
        ops.get_kernel("flash_attention").impl("jit")


def test_duplicate_registration_rejected():
    spec = ops.get_kernel("flash_attention")
    with pytest.raises(ValueError):
        ops.register_kernel(spec)


def test_tolerance_fallback_to_f32():
    spec = ops.get_kernel("flash_attention")
    assert spec.tolerance(jnp.float64) == spec.tolerance(jnp.float32)
    assert spec.tolerance(jnp.bfloat16).atol == 2e-2


# ---------------------------------------------------------------------------
# parity: interpret matches off within the declared tolerance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ops.registered_kernels())
@pytest.mark.parametrize("small", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_interpret_matches_ref_within_declared_tol(name, small, dtype):
    if dtype == jnp.bfloat16 and name not in (
        "flash_attention", "decode_attention"
    ):
        # DSBA/selection kernels are f32/f64 paths; ssd_chunk's oracle
        # accumulates in the input dtype, so bf16 parity is not a kernel
        # property (models/ssm.py always feeds it f32)
        pytest.skip("bf16 policy only declared for the attention kernels")
    args, kw = _example_args(name, jax.random.PRNGKey(0), dtype, small)
    err = ops.parity_check(name, *args, use_pallas="interpret", **kw)
    assert np.isfinite(err)


def test_flash_attention_parity_tol_matches_acceptance():
    # the declared policy IS the acceptance bar: 2e-5 (f32) / 2e-2 (bf16)
    spec = ops.get_kernel("flash_attention")
    assert spec.tolerance(jnp.float32).atol == 2e-5
    assert spec.tolerance(jnp.bfloat16).atol == 2e-2


def test_sparse_axpy_f64_interpret_is_bit_exact():
    """The relay's CPU fallback: exact-zero tolerance enforced centrally.

    The contract is the relay's call shape — unit decay (rho = 1, delta
    densification). With arbitrary rho, XLA's FMA fusion of rho*psi + ...
    legally differs from the oracle by 1 ulp.
    """
    tol = ops.get_kernel("sparse_axpy").tolerance(jnp.float64)
    assert (tol.rtol, tol.atol) == (0.0, 0.0)
    with enable_x64():
        args, kw = _example_args(
            "sparse_axpy", jax.random.PRNGKey(1), jnp.float64, small=False
        )
        psi, idx, val, coef, _ = args
        rho = jnp.ones_like(coef)
        err = ops.parity_check("sparse_axpy", psi, idx, val, coef, rho, **kw)
    assert err == 0.0


def test_sparse_dot_f64_interpret_meets_policy_with_kernel_kwargs():
    """f64 oracle stays f64 (1e-12 policy is meetable), and kernel-only
    kwargs (block_d) are stripped before the oracle leg runs."""
    with enable_x64():
        args, _ = _example_args(
            "sparse_dot", jax.random.PRNGKey(5), jnp.float64, small=False
        )
        err = ops.parity_check("sparse_dot", *args, block_d=64)
    assert err <= 1e-12


def test_topk_parity_rejects_inconsistent_indices():
    """_topk_compare cross-checks vals against x[idx]: corrupt indices with
    correct values must fail, not pass silently."""
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 64))
    vals, idx = ops.dispatch("block_topk", x, 8, use_pallas="interpret")
    spec = ops.get_kernel("block_topk")
    tol = spec.tolerance(x.dtype)
    spec.compare((x, 8), (vals, idx), (vals, idx), tol)  # consistent: ok
    bad_idx = (idx + 1) % x.shape[1]
    with pytest.raises(AssertionError):
        spec.compare((x, 8), (vals, bad_idx), (vals, idx), tol)


def test_wrapper_axpy_interpret_defaults_to_input_dtype():
    """compute_dtype is resolved in ONE place (the registry adapter):
    interpret -> psi.dtype, so f64 inputs give bit-exact oracles without
    call sites re-deriving the dtype."""
    with enable_x64():
        args, _ = _example_args(
            "sparse_axpy", jax.random.PRNGKey(2), jnp.float64
        )
        psi, idx, val, coef, _ = args
        rho = jnp.ones_like(coef)  # the relay's unit-decay call shape
        got = ops.saga_sparse_axpy(psi, idx, val, coef, rho,
                                   use_pallas="interpret")
        from repro.kernels import ref as R

        want = R.sparse_axpy_ref(psi, idx, val, coef, rho)
    assert got.dtype == jnp.float64
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# dispatch through the public wrappers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["interpret", "off"])
def test_public_wrappers_dispatch(mode):
    key = jax.random.PRNGKey(3)
    (q, k, v), _ = _example_args("flash_attention", key)
    o = ops.flash_attention(q, k, v, use_pallas=mode)
    assert o.shape == q.shape
    (x, kk), _ = _example_args("block_topk", key)
    vals, idx = ops.topk_blocks(x, kk, use_pallas=mode)
    assert vals.shape == idx.shape == (x.shape[0], kk)
    (xdt, cum, Bc, Cc), _ = _example_args("ssd_chunk", key)
    y, st = ops.ssd_chunk(xdt, cum, Bc, Cc, use_pallas=mode)
    assert y.shape == xdt.shape
    (psi, idx2, val), _ = _example_args("sparse_dot", key)
    s = ops.saga_sparse_dot(psi, idx2, val, use_pallas=mode)
    assert s.shape == (psi.shape[0],)


def test_flash_attention_wrapper_is_differentiable_in_interpret():
    """The custom_vjp path: grads flow through the Pallas kernel without a
    reference-forward recompute (the old wrapper was fwd-only)."""
    (q, k, v), _ = _example_args("flash_attention", jax.random.PRNGKey(4))
    g = jax.grad(
        lambda q: jnp.sum(ops.flash_attention(q, k, v, use_pallas="interpret"))
    )(q)
    assert g.shape == q.shape and bool(jnp.all(jnp.isfinite(g)))

"""Hypothesis property tests on system-wide invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import mixing
from repro.core.gossip import (
    GossipConfig, block_topk_compress, scatter_decompress, topk_compress,
)
from repro.data.synthetic import make_regression
from repro.train.step import ce_loss


# ---------------------------------------------------------------------------
# mixing matrices
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(2, 12), st.floats(0.3, 0.9), st.integers(0, 100))
def test_er_laplacian_mixing_always_valid(n, p, seed):
    g = mixing.erdos_renyi_graph(n, p, seed=seed)
    w = mixing.laplacian_mixing(g)
    mixing.validate_mixing(w, g)
    gamma = mixing.graph_gamma(w)
    assert 0 < gamma <= 1.0 + 1e-9


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 16))
def test_ring_gamma_decreases_with_size(n):
    """Bigger rings are worse-connected: kappa_g grows."""
    w_n = mixing.laplacian_mixing(mixing.ring_graph(n))
    w_2n = mixing.laplacian_mixing(mixing.ring_graph(2 * n))
    assert mixing.graph_gamma(w_2n) <= mixing.graph_gamma(w_n) + 1e-9


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 10))
def test_w_tilde_spectrum_in_half_one(n):
    """W~ = (I+W)/2 has eigenvalues in [1/2, 1] (used by Lemma 6.4)."""
    g = mixing.erdos_renyi_graph(n, 0.5, seed=n)
    wt = mixing.w_tilde(mixing.laplacian_mixing(g))
    eig = np.linalg.eigvalsh(wt)
    assert eig.min() >= 0.5 - 1e-9 and eig.max() <= 1 + 1e-9


# ---------------------------------------------------------------------------
# gossip weights == W~ row (circulant decomposition)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(2, 12), st.sampled_from(["ring", "exponential"]))
def test_shifts_and_weights_reconstruct_w_tilde(n, topo):
    gc = GossipConfig(n_pods=n, topology=topo)
    g, w = gc.graph_and_weights()
    wt = mixing.w_tilde(w)
    shifts, weights, w_self = gc.shifts_and_weights()
    rec = np.zeros(n)
    rec[0] = w_self
    for s, wgt in zip(shifts, weights):
        scale = wgt if (2 * s) % n else wgt / 2.0
        rec[s % n] += scale
        rec[(-s) % n] += scale
    np.testing.assert_allclose(rec, wt[0], atol=1e-9)


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(8, 200), st.integers(1, 8), st.integers(0, 50))
def test_topk_selects_largest_and_decompress_is_partial_identity(n, k, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n))
    k = min(k, n)
    vals, idx = topk_compress(x, k)
    # selected = k largest magnitudes
    thresh = np.sort(np.abs(np.asarray(x)))[-k]
    assert (np.abs(np.asarray(vals)) >= thresh - 1e-12).all()
    # decompression reproduces exactly those coordinates
    d = scatter_decompress(x.shape, vals, idx)
    np.testing.assert_allclose(np.asarray(d)[np.asarray(idx)],
                               np.asarray(x)[np.asarray(idx)])
    # residual norm shrinks
    assert float(jnp.linalg.norm(x - d)) <= float(jnp.linalg.norm(x)) + 1e-12


@settings(max_examples=25, deadline=None)
@given(st.integers(10, 400), st.floats(0.02, 0.5), st.integers(4, 64),
       st.integers(0, 20))
def test_block_topk_residual_contracts(n, ratio, block, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n))
    vals, idx = block_topk_compress(x, ratio, block)
    d = scatter_decompress(x.shape, vals, idx)
    assert float(jnp.linalg.norm(x - d)) < float(jnp.linalg.norm(x)) + 1e-12
    # reported pairs are true coordinates of x
    nz = np.asarray(vals) != 0
    np.testing.assert_allclose(np.asarray(x)[np.asarray(idx)[nz]],
                               np.asarray(vals)[nz])


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(2, 16), st.integers(2, 50), st.integers(0, 10))
def test_ce_loss_nonnegative_and_bounded_for_uniform(v, s, seed):
    rng = np.random.default_rng(seed)
    logits = jnp.zeros((1, s, v))
    targets = jnp.asarray(rng.integers(0, v, (1, s)))
    l = float(ce_loss(logits, targets))
    np.testing.assert_allclose(l, np.log(v), rtol=1e-6)
    logits2 = jnp.asarray(rng.standard_normal((1, s, v)))
    assert float(ce_loss(logits2, targets)) >= 0.0


# ---------------------------------------------------------------------------
# dynamic networks: segment mixing matrices + elastic state remapping
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(2, 10), st.floats(0.3, 0.9), st.integers(0, 50))
def test_generated_segment_w_doubly_stochastic_supported_gapped(n, p, seed):
    """Any Graph segment a schedule normalizes: its W is doubly stochastic,
    supported on the graph, and (connected by construction) has gap > 0."""
    import dataclasses

    from repro.core.solvers import make_problem

    g = mixing.erdos_renyi_graph(n, p, seed=seed)
    data = make_regression(n, 4, 8, k=3, seed=seed)
    prob = make_problem("ridge", data, mixing.ring_graph(n) if n > 1 else g,
                        lam=1e-2)
    prob = dataclasses.replace(prob, schedule=((0, g),))
    ((_, gg, w),) = prob.schedule
    mixing.validate_mixing(w, gg)
    np.testing.assert_allclose(w.sum(0), 1.0, atol=1e-9)
    np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-9)
    assert mixing.spectral_gap(w) > 0


@settings(max_examples=25, deadline=None)
@given(
    st.integers(3, 9),
    st.lists(st.integers(0, 8), min_size=1, max_size=3, unique=True),
    st.integers(0, 30),
)
def test_elastic_shrink_grow_roundtrips_pytree_shapes(n, dead, seed):
    """shrink(dead) then grow(len(dead)) restores every leaf's shape for an
    arbitrary pytree: leading-n leaves remap, the rest pass through."""
    from repro.ft.elastic import ElasticGossip

    dead = sorted(d for d in set(dead) if d < n)
    if len(dead) >= n:
        return  # need at least one survivor
    rng = np.random.default_rng(seed)
    state = {
        "z": jnp.asarray(rng.standard_normal((n, 3))),
        "nested": {"table": jnp.asarray(rng.standard_normal((n, 2, 2)))},
        "per_node_flat": jnp.asarray(rng.standard_normal(n)),
        "scalar": jnp.asarray(3.5),
        "step": jnp.asarray(7, jnp.int32),
        "not_node_axis": jnp.asarray(rng.standard_normal((n + 1, 2))),
    }
    eg = ElasticGossip(GossipConfig(n_pods=n))
    small, gc_s = eg.shrink(state, dead=dead)
    keep = [i for i in range(n) if i not in dead]
    assert gc_s.n_pods == len(keep)
    for kk, leaf in (("z", state["z"]),
                     ("nested", state["nested"]["table"]),
                     ("per_node_flat", state["per_node_flat"])):
        got = small[kk]["table"] if kk == "nested" else small[kk]
        src = np.asarray(leaf)
        np.testing.assert_array_equal(np.asarray(got), src[keep])
    back, gc_b = ElasticGossip(gc_s).grow(small, n_new=len(dead), seed_from=0)
    assert gc_b.n_pods == n
    flat0, _ = jax.tree_util.tree_flatten(state)
    flat1, _ = jax.tree_util.tree_flatten(back)
    for a, b in zip(flat0, flat1):
        assert np.asarray(a).shape == np.asarray(b).shape
    # non-node leaves survive both remaps bit-identically
    np.testing.assert_array_equal(
        np.asarray(back["not_node_axis"]), np.asarray(state["not_node_axis"])
    )
    assert int(back["step"]) == 7


# ---------------------------------------------------------------------------
# fault plans: delivered-message accounting
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    st.integers(3, 10),
    st.floats(0.0, 1.0),
    st.floats(0.0, 1.0),
    st.integers(1, 5),
    st.integers(5, 40),
    st.integers(0, 100),
)
def test_fault_plan_accounting_monotone_bounded_exact_at_p0(
    n, p_link, p_strag, bound, steps, seed
):
    """For ANY valid FaultPlan the delivered-message accounting is
    (a) monotone: composing a second fault family never increases the
    delivered count, and the cumulative count never decreases in t;
    (b) bounded by the no-fault accounting (deg(u) per node per round);
    (c) exact at p=0: masks are all-True and the counts equal the
    plan-free accounting bit-for-bit."""
    from repro.ft.faults import (
        LinkFault, StragglerSpec, delivered_in_messages,
        link_delivered_mask, straggler_delivered_mask,
    )

    g = mixing.erdos_renyi_graph(n, 0.6, seed=seed)
    deg = np.asarray(g.degrees, dtype=np.int64)

    lm = link_delivered_mask(LinkFault(p=p_link, seed=seed), g, steps)
    sm = straggler_delivered_mask(
        StragglerSpec(p=p_strag, max_staleness=bound, seed=seed), n, steps
    )
    d_none = delivered_in_messages(g, None, None, steps)
    d_link = delivered_in_messages(g, lm, None, steps)
    d_both = delivered_in_messages(g, lm, sm, steps)

    # (b) bounded by the no-fault count, which is deg(u) every iteration
    np.testing.assert_array_equal(d_none, np.broadcast_to(deg, (steps, n)))
    assert (d_both >= 0).all()
    # (a) AND-composition is monotone, per (iteration, node)
    assert (d_both <= d_link).all() and (d_link <= d_none).all()
    # cumulative delivered never decreases
    assert (np.diff(np.cumsum(d_both.sum(axis=1))) >= 0).all()
    # staleness bound: no node's delivery gap ever exceeds the bound
    gaps = np.zeros(n, dtype=int)
    for t in range(steps):
        gaps = np.where(sm[t], 0, gaps + 1)
        assert (gaps <= bound).all()
    # (c) exact at p=0 — all-True masks, bit-equal to the plan-free count
    lm0 = link_delivered_mask(LinkFault(p=0.0, seed=seed), g, steps)
    sm0 = straggler_delivered_mask(
        StragglerSpec(p=0.0, max_staleness=bound, seed=seed), n, steps
    )
    assert lm0.all() and sm0.all()
    np.testing.assert_array_equal(
        delivered_in_messages(g, lm0, sm0, steps), d_none
    )


# ---------------------------------------------------------------------------
# dataset invariants
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(4, 20), st.integers(8, 64),
       st.integers(2, 8), st.integers(0, 5))
def test_synthetic_rows_normalized_distinct_indices(n, q, d, k, seed):
    k = min(k, d)
    data = make_regression(n, q, d, k=k, seed=seed)
    norms = np.sqrt((data.val**2).sum(-1))
    np.testing.assert_allclose(norms, 1.0, rtol=1e-6)
    # padded-CSR guarantee: indices distinct within each row
    for nn in range(n):
        for qq in range(q):
            row = data.idx[nn, qq]
            assert len(set(row.tolist())) == k


# ---------------------------------------------------------------------------
# serving: page-pool conservation
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(3, 20), st.lists(st.tuples(st.integers(0, 2),
                                              st.integers(1, 32)),
                                    max_size=25),
       st.integers(0, 1000))
def test_cache_pool_never_leaks_pages(n_blocks, ops, seed):
    """Random admit/grow/evict sequences conserve the page pool: every
    page is either free or held by exactly one slot, the null page is
    never handed out, and draining returns the pool to pristine."""
    from repro.configs import get_reduced
    from repro.serve import CachePool, PoolConfig

    rng = np.random.default_rng(seed)
    pool = CachePool(get_reduced("minitron_8b"), PoolConfig(
        max_batch=4, block_size=4, n_blocks=n_blocks, max_len=32,
        prompt_pad=8,
    ))
    live = {}
    for op, arg in ops:
        if op == 0:  # admit
            slot = pool.alloc_slot()
            if slot is None:
                continue
            if pool.ensure(slot, arg):
                live[slot] = arg
            else:
                pool.release(slot)
        elif op == 1 and live:  # grow
            slot = int(rng.choice(list(live)))
            want = max(arg, live[slot])
            if pool.ensure(slot, want):
                live[slot] = want
        elif op == 2 and live:  # evict
            slot = int(rng.choice(list(live)))
            pool.release(slot)
            del live[slot]
        held = [p for pages in pool._pages_of for p in pages]
        assert 0 not in held and 0 not in pool._free_pages
        assert len(set(held)) == len(held)
        assert sorted(held + pool._free_pages) == list(range(1, n_blocks))
    for slot in list(live):
        pool.release(slot)
    assert pool.free_page_count == n_blocks - 1
    assert pool.free_slot_count == 4
